"""Direct coverage of repro.compat — the version-drift shim layer.

Every other test exercises compat incidentally (via the evaluator or the
kernels); these pin the shim's own contract so a jax upgrade that silently
changes a symbol fails here with a named test rather than deep inside a
shard-mapped trace.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat


def test_all_exports_exist():
    for name in compat.__all__:
        assert callable(getattr(compat, name)), name


def test_resolve_shard_map_kwarg_matches_jax_version():
    fn, kw = compat._resolve_shard_map()
    assert callable(fn)
    assert kw in ("check_vma", "check_rep")
    # the chosen kwarg must match which API was resolved
    if getattr(jax, "shard_map", None) is fn:
        assert kw == "check_vma"
    else:
        assert kw == "check_rep"


def test_default_search_devices_nonempty():
    devs = compat.default_search_devices()
    assert devs and devs == list(jax.local_devices())


def test_make_mesh_shapes():
    mesh = compat.make_mesh()
    assert mesh.axis_names == ("search",)
    assert mesh.devices.size == len(jax.local_devices())
    one = compat.make_mesh(jax.local_devices()[:1], axis="x")
    assert one.axis_names == ("x",)
    assert one.devices.size == 1


def test_shard_map_runs_and_matches_unsharded():
    from jax.sharding import PartitionSpec as P

    mesh = compat.make_mesh()
    n = mesh.devices.size

    def body(x):
        return x * 2.0 + 1.0

    f = compat.shard_map(
        body, mesh=mesh, in_specs=P("search"), out_specs=P("search"))
    x = jnp.arange(4 * n, dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x) * 2.0 + 1.0)


def test_shard_map_check_vma_flag_accepted():
    from jax.sharding import PartitionSpec as P

    mesh = compat.make_mesh()
    n = mesh.devices.size

    def body(x):
        return x + 1.0

    # both spellings of the replication check must be forwardable
    f = compat.shard_map(
        body, mesh=mesh, in_specs=P("search"), out_specs=P("search"),
        check_vma=False)
    x = jnp.ones((2 * n,), dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x) + 1.0)


def test_pallas_tpu_compiler_params_fields():
    params = compat.pallas_tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert tuple(params.dimension_semantics) == ("parallel", "arbitrary")
    # the resolved class is one of the two known spellings
    from jax.experimental.pallas import tpu as pltpu

    expected = getattr(pltpu, "CompilerParams", None) or \
        pltpu.TPUCompilerParams
    assert isinstance(params, expected)


def test_pallas_tpu_compiler_params_rejects_unknown_field():
    with pytest.raises(TypeError):
        compat.pallas_tpu_compiler_params(not_a_real_field=1)
