"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU).

Every kernel is swept over shapes and dtypes and checked with
``assert_allclose`` against ``kernels/ref.py``; masks (causal, sliding
window, ring slots, k_len padding) and GQA group sizes are all exercised.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, gqa_decode_attention, seg_combine
from repro.kernels.ref import (
    decode_attention_ref,
    flash_attention_ref,
    seg_combine_ref,
)

TOL = dict(rtol=2e-2, atol=2e-2)      # bf16-dominated paths
TOL32 = dict(rtol=1e-5, atol=1e-5)


def _qkv(key, B, H, KV, Sq, Sk, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, Sq, hd), dtype)
    k = jax.random.normal(kk, (B, KV, Sk, hd), dtype)
    v = jax.random.normal(kv, (B, KV, Sk, hd), dtype)
    return q, k, v


# ----------------------------------------------------------- flash attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,Sq,Sk,hd",
    [
        (1, 4, 2, 256, 256, 64),     # GQA, hd padded 64->128
        (2, 2, 2, 128, 384, 128),    # cross-ish Sq != Sk
        (1, 8, 1, 256, 256, 80),     # MQA, odd head dim
    ],
)
def test_flash_matches_ref(B, H, KV, Sq, Sk, hd, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, H, KV, Sq, Sk, hd, dtype)
    out = flash_attention(q, k, v, True, None, None, 0)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32),
        **(TOL if dtype == jnp.bfloat16 else TOL32),
    )


@pytest.mark.parametrize("window", [64, 128, 1024])
def test_flash_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 4, 4, 256, 256, 64, jnp.float32)
    out = flash_attention(q, k, v, True, window, None, 0)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, **TOL32)


def test_flash_logit_softcap_and_offset():
    # gemma2-style soft-capping + continuation prefill (q_offset > 0)
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 4, 2, 128, 384, 128, jnp.float32)
    out = flash_attention(q, k, v, True, None, 50.0, 256)
    ref = flash_attention_ref(q, k, v, causal=True, logit_cap=50.0, q_offset=256)
    np.testing.assert_allclose(out, ref, **TOL32)


def test_flash_bidirectional_encoder():
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 4, 4, 128, 128, 64, jnp.float32)
    out = flash_attention(q, k, v, False, None, None, 0)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, **TOL32)


def test_flash_unaligned_seq_padding():
    # Sq=200, Sk=333: exercises block padding + k_len masking
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 2, 2, 200, 333, 64, jnp.float32)
    out = flash_attention(q, k, v, False, None, None, 0)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, **TOL32)


def test_flash_grad_matches_ref_grad():
    q, k, v = _qkv(jax.random.PRNGKey(5), 1, 2, 1, 128, 128, 64, jnp.float32)

    def loss_pallas(q, k, v):
        return (flash_attention(q, k, v, True, None, None, 0) ** 2).sum()

    def loss_ref(q, k, v):
        return (flash_attention_ref(q, k, v, causal=True) ** 2).sum()

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- decode attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,hd", [(2, 8, 2, 512, 64), (1, 4, 4, 300, 128)])
def test_decode_full_cache(B, H, KV, S, hd, dtype):
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, 1, hd), dtype)
    kc = jax.random.normal(kk, (B, KV, S, hd), dtype)
    vc = jax.random.normal(kv, (B, KV, S, hd), dtype)
    slot_pos = jnp.arange(S, dtype=jnp.int32)
    pos = jnp.asarray(S // 2, jnp.int32)      # only half the cache is valid

    out = gqa_decode_attention(q, kc, vc, slot_pos, pos)
    ref = decode_attention_ref(
        q.reshape(B, KV, H // KV, hd), kc, vc, slot_pos, pos
    ).reshape(B, H, 1, hd)
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32),
        **(TOL if dtype == jnp.bfloat16 else TOL32),
    )


def test_decode_ring_cache_with_window():
    # ring cache: slot i holds latest position == i (mod S); window masking
    B, H, KV, S, hd, window = 1, 4, 1, 256, 64, 200
    key = jax.random.PRNGKey(8)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, 1, hd), jnp.float32)
    kc = jax.random.normal(kk, (B, KV, S, hd), jnp.float32)
    vc = jax.random.normal(kv, (B, KV, S, hd), jnp.float32)
    pos = jnp.asarray(1000, jnp.int32)
    i = jnp.arange(S)
    slot_pos = (pos - jnp.mod(pos - i, S)).astype(jnp.int32)

    out = gqa_decode_attention(q, kc, vc, slot_pos, pos, window=window, logit_cap=30.0)
    ref = decode_attention_ref(
        q.reshape(B, KV, H // KV, hd), kc, vc, slot_pos, pos,
        window=window, logit_cap=30.0,
    ).reshape(B, H, 1, hd)
    np.testing.assert_allclose(out, ref, **TOL32)


# ----------------------------------------------------------- seg combine

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,D,P", [(1024, 256, 16), (777, 130, 7), (64, 8, 3)])
def test_seg_combine_matches_scatter(N, D, P, dtype):
    key = jax.random.PRNGKey(9)
    kv_, kp = jax.random.split(key)
    values = jax.random.normal(kv_, (N, D), dtype)
    pids = jax.random.randint(kp, (N,), 0, P, jnp.int32)
    out = seg_combine(values, pids, P)
    ref = seg_combine_ref(values, pids, P)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-3)


def test_seg_combine_drops_negative_ids():
    values = jnp.ones((128, 8), jnp.float32)
    pids = jnp.where(jnp.arange(128) % 2 == 0, 0, -1).astype(jnp.int32)
    out = seg_combine(values, pids, 4)
    assert out[0, 0] == 64.0 and out[1:].sum() == 0.0


def test_seg_combine_pair_counts():
    # the paper's pairs-per-partition measurement: ones column
    N, P = 640, 10
    pids = (jnp.arange(N) % P).astype(jnp.int32)
    counts = seg_combine(jnp.ones((N, 1), jnp.float32), pids, P)
    np.testing.assert_allclose(counts[:, 0], np.full(P, N // P), rtol=0, atol=0)
