"""Gradient-safety regression tests for the differentiable model stack.

Two hazards are pinned down here:

* **Straight-through rounding** (``merge_math.ste_floor``/``ste_ceil``/
  ``ste_round``): forward values must be bit-for-bit identical to
  ``jnp.floor``/``ceil``/``round`` — including at ``inf``, where a naive
  ``x - stop_gradient(x)`` formulation produces ``inf - inf = nan`` — while
  the gradient passes through as identity for finite inputs.

* **The where/inf cotangent bug**: ``jnp.where(valid, cost, inf)`` masking
  produces an exactly-zero cotangent for masked rows, but upstream VJPs
  multiply that zero by local derivatives; a ``0 * inf`` anywhere in the
  chain poisons the whole gradient with NaN.  The model applies the
  double-``where`` trick at the dangerous divisions (Eq. 11 pair-width
  division in particular) so gradients of the *masked* total stay finite
  even on invalid or degenerate configurations.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.cluster.workload import default_job_classes
from repro.core.hadoop.merge_math import ste_ceil, ste_floor, ste_round
from repro.core.hadoop.model import CONFIG_KEYS, job_model_jnp, pack_config
from repro.core.hadoop.params import CostFactors
from repro.spec import hadoop_space

PROFILES = default_job_classes()


def _base_cfg(jc):
    return pack_config(jc.params, jc.stats, jc.costs)


def _masked_total(cfg):
    out = job_model_jnp(cfg)
    return jnp.where(out["valid"] > 0, out["j_totalCost"], jnp.inf)


def _grad_masked(cfg, **overrides):
    cfg = dict(cfg)
    for k, v in overrides.items():
        cfg[k] = jnp.asarray(v, dtype=jnp.float64)
    out = job_model_jnp(cfg)
    grads = jax.grad(lambda c: _masked_total(c))(cfg)
    return out, grads


def _nonfinite(grads):
    return sorted(k for k, v in grads.items() if not bool(jnp.isfinite(v).all()))


# --------------------------------------------------------------------------
# straight-through rounding helpers
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "ste_fn,ref_fn",
    [(ste_floor, jnp.floor), (ste_ceil, jnp.ceil), (ste_round, jnp.round)],
    ids=["floor", "ceil", "round"],
)
def test_ste_forward_bit_exact(ste_fn, ref_fn):
    xs = jnp.asarray(
        [
            0.0, -0.0, 0.5, -0.5, 1.0 + 2 ** -52, 25.05350053888,
            1e15 + 0.4999, -3.75, 2.5, 3.5, 1e-300, 7e12,
            jnp.inf, -jnp.inf,
        ],
        dtype=jnp.float64,
    )
    got = ste_fn(xs)
    want = ref_fn(xs)
    # bit-for-bit: nan-free and exactly equal, inf included
    assert bool(jnp.array_equal(got, want)), (got, want)


@pytest.mark.parametrize(
    "ste_fn", [ste_floor, ste_ceil, ste_round], ids=["floor", "ceil", "round"]
)
def test_ste_gradient_identity_for_finite_inputs(ste_fn):
    for x in (0.25, 3.0, -7.6, 1e9 + 0.3):
        g = jax.grad(lambda v: ste_fn(v))(jnp.asarray(x, dtype=jnp.float64))
        assert float(g) == 1.0, (ste_fn.__name__, x, float(g))


@pytest.mark.parametrize(
    "ste_fn", [ste_floor, ste_ceil, ste_round], ids=["floor", "ceil", "round"]
)
def test_ste_gradient_finite_at_inf(ste_fn):
    # At non-finite inputs the naive x - stop_gradient(x) form evaluates
    # inf - inf = nan in both the forward value and the cotangent; the
    # double-where form must give a zero (finite) gradient instead.
    g = jax.grad(ste_fn)(jnp.asarray(jnp.inf, dtype=jnp.float64))
    assert bool(jnp.isfinite(g)), float(g)


# --------------------------------------------------------------------------
# masked-total gradients on invalid / degenerate configs
# --------------------------------------------------------------------------


def test_masked_total_grad_finite_on_invalid_config():
    # pSortMB=0.25 with F=2 drives numSpills far beyond F**2 -> valid == 0,
    # so the masked total is inf; its gradient must still be finite.
    out, grads = _grad_masked(_base_cfg(PROFILES[0]), pSortMB=0.25, pSortFactor=2.0)
    assert float(out["valid"]) == 0.0
    assert _nonfinite(grads) == []


def test_masked_total_grad_finite_on_degenerate_profile():
    # sMapSizeSel=0 zeroes the map output size, making the Eq. 10 pair width
    # 0 and the Eq. 11 division +inf — the exact site of the 0*inf cotangent
    # hazard guarded by the double-where.
    out, grads = _grad_masked(_base_cfg(PROFILES[0]), sMapSizeSel=0.0)
    assert bool(jnp.isfinite(out["j_totalCost"]))
    assert _nonfinite(grads) == []


def test_masked_total_grad_finite_under_vmap_with_degenerate_row():
    # One poisoned row must not produce NaN in its own gradient row (vmapped
    # grads of other rows were never affected; the masked row itself was).
    base = _base_cfg(PROFILES[0])
    cfgs = {k: jnp.stack([jnp.asarray(base[k], dtype=jnp.float64)] * 3) for k in base}
    cfgs["sMapSizeSel"] = jnp.asarray([1.0, 0.5, 0.0], dtype=jnp.float64)
    grads = jax.vmap(jax.grad(_masked_total))(cfgs)
    assert _nonfinite(grads) == []


# --------------------------------------------------------------------------
# property test: grads finite across every profile, cost factor, float axis
# --------------------------------------------------------------------------


def _float_axis_names():
    packed = set(CONFIG_KEYS)
    return [
        ax.name
        for ax in hadoop_space().axes
        if ax.kind == "float" and ax.name in packed
    ]


COST_FIELDS = list(CostFactors.__dataclass_fields__)


@pytest.mark.parametrize("jc", PROFILES, ids=[jc.name for jc in PROFILES])
def test_grad_finite_wrt_cost_factors_and_float_axes(jc):
    """jax.grad of j_totalCost w.r.t. every CostFactors field and every float
    Axis is finite and non-NaN at each mapreduce.JOBS profile."""
    cfg = _base_cfg(jc)
    wanted = set(COST_FIELDS) | set(_float_axis_names())

    def total(c):
        return job_model_jnp(c)["j_totalCost"]

    grads = jax.grad(total)(dict(cfg))
    bad = [k for k in sorted(wanted) if not bool(jnp.isfinite(grads[k]).all())]
    assert bad == [], f"{jc.name}: non-finite grads for {bad}"


def test_grad_finite_wrt_cost_factors_property():
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    space = hadoop_space()
    # Knobs whose in-range perturbation should never break differentiability.
    knobs = {
        "pSortMB": (8.0, 512.0),
        "pSpillPerc": (0.05, 0.99),
        "pSortRecPerc": (0.01, 0.5),
        "pSortFactor": (2, 128),
        "pNumReducers": (1, 512),
        "sMapSizeSel": (1e-3, 4.0),
        "sMapPairsSel": (1e-3, 4.0),
        "sIntermCompressRatio": (0.1, 1.0),
    }

    @settings(max_examples=40, deadline=None)
    @given(
        idx=st.integers(min_value=0, max_value=len(PROFILES) - 1),
        draws=st.fixed_dictionaries(
            {
                k: st.floats(min_value=lo, max_value=hi, allow_nan=False)
                if space[k].kind == "float"
                else st.integers(min_value=lo, max_value=hi)
                for k, (lo, hi) in knobs.items()
            }
        ),
    )
    def check(idx, draws):
        cfg = dict(_base_cfg(PROFILES[idx]))
        for k, v in draws.items():
            cfg[k] = jnp.asarray(float(v), dtype=jnp.float64)
        grads = jax.grad(lambda c: _masked_total(c))(cfg)
        wanted = set(COST_FIELDS) | set(_float_axis_names())
        bad = [k for k in sorted(wanted) if not bool(jnp.isfinite(grads[k]).all())]
        assert bad == [], f"profile={PROFILES[idx].name} draws={draws} bad={bad}"

    check()
