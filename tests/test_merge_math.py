"""Merge-round mathematics (paper §2.3, Eqs. 20-25) — unit + property tests."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.hadoop.merge_math import (
    calc_num_spills_final_merge,
    calc_num_spills_first_pass,
    calc_num_spills_interm_merge,
    merge_plan,
    num_merge_passes,
    simulate_merge,
)


class TestPaperWorkedExample:
    """numSpills=30, pSortFactor=10 — the example worked in §2.3."""

    def test_first_pass(self):
        assert calc_num_spills_first_pass(30, 10) == 3

    def test_interm_merge(self):
        assert calc_num_spills_interm_merge(30, 10) == 23

    def test_final_merge(self):
        assert calc_num_spills_final_merge(30, 10) == 10

    def test_passes(self):
        # 3 first-round passes create 3 files, merged in a 2nd round:
        # pass structure = first(3) + 2x10 + final(10) = 4 passes.
        assert num_merge_passes(30, 10) == 4


@pytest.mark.parametrize(
    "n,f,first,interm,final",
    [
        (1, 10, 1, 0, 1),      # Eq. 20 literal: returns N for N <= F
        (5, 10, 5, 0, 5),      # N <= F: one final merge only
        (10, 10, 10, 0, 10),
        (11, 10, 2, 2, 10),    # (11-1) mod 9 = 1 -> first pass 2
        (19, 10, 10, 10, 10),  # (19-1) mod 9 = 0 -> first pass F
        (100, 10, 10, 100, 10),  # N = F^2 boundary
    ],
)
def test_closed_form_cases(n, f, first, interm, final):
    assert calc_num_spills_first_pass(n, f) == first
    assert calc_num_spills_interm_merge(n, f) == interm
    assert calc_num_spills_final_merge(n, f) == final


@given(st.integers(2, 100), st.integers(2, 10))
@settings(max_examples=300, deadline=None)
def test_simulation_matches_closed_form(n, f):
    """The paper's closed forms must equal the exact simulation for N<=F^2."""
    if n > f * f:
        return
    plan = simulate_merge(n, f)
    if n > f:
        assert plan.first_pass == calc_num_spills_first_pass(n, f)
    assert plan.interm_reads == calc_num_spills_interm_merge(n, f)
    assert plan.final_merge_width == calc_num_spills_final_merge(n, f)
    assert plan.passes == num_merge_passes(n, f)


@given(st.integers(1, 5000), st.integers(2, 12))
@settings(max_examples=300, deadline=None)
def test_simulation_invariants(n, f):
    """Structural invariants of any merge plan (also beyond N<=F^2)."""
    plan = simulate_merge(n, f)
    assert 0 <= plan.first_pass <= f
    assert 1 <= plan.final_merge_width <= max(f, n) if n >= 1 else True
    if n > 1:
        assert plan.final_merge_width <= f or n <= f
    # Every intermediate read is of a real spill: bounded by total re-reads.
    assert plan.interm_reads >= 0
    if n <= f:
        assert plan.interm_reads == 0
    # passes: 0 for n<=1, else at least 1, and first+interm+final accounting.
    if n <= 1:
        assert plan.passes == 0
    elif n <= f:
        assert plan.passes == 1
    else:
        assert plan.passes >= 2


@given(st.integers(101, 4000))
@settings(max_examples=100, deadline=None)
def test_merge_plan_beyond_closed_form(n):
    """merge_plan transparently switches to simulation when N > F^2."""
    f = 10
    if n <= f * f:
        return
    plan = merge_plan(n, f)
    sim = simulate_merge(n, f)
    assert plan == sim
    # Re-merging merged files means interm reads exceed the first-touch count.
    assert plan.interm_reads > n - plan.final_merge_width


def test_example_beyond_f2():
    """N=150, F=10: first pass 6, then 14 passes of 10 ones, then one re-merge
    pass touching 60 spill-equivalents, final width 10."""
    plan = simulate_merge(150, 10)
    assert plan.first_pass == 6
    assert plan.final_merge_width == 10
    assert plan.passes == 17
    assert plan.interm_reads == 6 + 140 + 60
