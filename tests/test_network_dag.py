"""Topology-aware network model + DAG workloads.

Three contracts pinned here:

* **Seed regression** — :func:`repro.cluster.network.per_reducer_shuffle`
  is bit-for-bit the ``netCost / pNumReducers`` term the seed computed
  inline (single-job simulator and workload task costs), and
  ``Topology.flat()`` reproduces the no-topology DES record-for-record
  under every scheduler with noise on.
* **Contention semantics** — max-min fair shares by progressive filling,
  ``effective_bandwidth`` differentiable and NaN-free at every boundary,
  contended topologies strictly slower, uncontended ones bit-identical.
* **DAG invariant** — ``DagReport.critical_path_s <= makespan_s`` always,
  with equality on serial (width-1) chains, across every
  ``mapreduce.JOBS`` profile, both edge kinds and all four schedulers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (
    ClusterConfig,
    SimConfig,
    StageDag,
    StageEdge,
    Topology,
    dag_from_templates,
    dag_report,
    dag_trace,
    default_job_classes,
    effective_bandwidth,
    per_reducer_shuffle,
    simulate_workload,
)
from repro.cluster.network import flow_rates, max_min_rates
from repro.cluster.vector_sim import pack_trace, simulate_batch
from repro.cluster.workload import (
    JobArrival,
    WorkloadTrace,
    _job_model_cached,
    stage_output_bytes,
    task_costs,
)
from repro.mapreduce.jobs import JOBS

CLASSES = default_job_classes()
BY_NAME = {jc.name: jc for jc in CLASSES}

NOISY = SimConfig(seed=11, task_time_jitter=0.2, straggler_prob=0.1)
SCHEDULERS = ("fifo", "fair", "fair_preempt", "capacity")


def _record_tuples(res):
    return [(r.kind, r.index, r.job_id, r.node, r.start, r.end,
             r.speculative, r.killed) for r in res.records]


# ---------------------------------------------------------------------------
# seed regression: the hoisted shuffle term + the flat topology
# ---------------------------------------------------------------------------


def test_per_reducer_shuffle_pins_seed_term():
    # the exact expression the seed computed inline at both call sites
    for jc in CLASSES:
        jm = _job_model_cached(jc.params, jc.stats, jc.costs)
        expected = jm.netCost / jc.params.pNumReducers
        assert task_costs(jc)[2] == expected                    # bit-for-bit
        assert per_reducer_shuffle(jm.netCost, jc.params.pNumReducers) \
            == expected
    assert per_reducer_shuffle(123.0, 0) == 0.0                 # map-only


@pytest.mark.parametrize("sched", SCHEDULERS)
def test_flat_topology_bit_for_bit(sched):
    from repro.cluster.workload import poisson_trace, rescale

    tr = rescale(poisson_trace(CLASSES, 10, seed=5), 0.2)
    base = ClusterConfig(num_nodes=6, scheduler=sched)
    ref = simulate_workload(tr, base, NOISY)
    for topo in (Topology.flat(), Topology(num_racks=1),
                 Topology(num_racks=3)):     # racks with inf bw stay flat
        got = simulate_workload(
            tr, ClusterConfig(num_nodes=6, scheduler=sched, topology=topo),
            NOISY)
        assert _record_tuples(got) == _record_tuples(ref)
        assert got.makespan == ref.makespan


def test_contended_topology_strictly_slower_uncontended_identical():
    from repro.cluster.workload import poisson_trace, rescale

    tr = rescale(poisson_trace(CLASSES, 8, seed=2), 0.3)
    flat = simulate_workload(tr, ClusterConfig(num_nodes=8), SimConfig(seed=0))
    tight = Topology(num_racks=4, cross_rack_bw=0.5, oversub=2.0)
    slow = simulate_workload(
        tr, ClusterConfig(num_nodes=8, topology=tight), SimConfig(seed=0))
    assert slow.makespan > flat.makespan
    # non-flat but huge uplink: every fair share caps at the nominal rate
    roomy = Topology(num_racks=2, cross_rack_bw=1e9)
    same = simulate_workload(
        tr, ClusterConfig(num_nodes=8, topology=roomy), SimConfig(seed=0))
    assert _record_tuples(same) == _record_tuples(flat)


# ---------------------------------------------------------------------------
# max-min fair sharing + the differentiable approximation
# ---------------------------------------------------------------------------


def test_max_min_progressive_filling_hand_cases():
    # one saturated link shared by two flows -> 0.5 each; a third flow on
    # an uncontended link keeps the nominal rate
    rates = max_min_rates(
        [{"a": 1.0}, {"a": 1.0}, {"b": 1.0}], {"a": 1.0, "b": 5.0})
    assert rates == pytest.approx([0.5, 0.5, 1.0])
    # progressive filling: the flow leaving the saturated link is frozen at
    # the saturation level, the other keeps rising to its own bottleneck
    rates = max_min_rates(
        [{"a": 1.0, "b": 1.0}, {"b": 1.0}], {"a": 0.4, "b": 2.0})
    assert rates == pytest.approx([0.4, 1.0])
    # infinite-capacity links never constrain; empty usage = nominal rate
    assert max_min_rates([{"x": 2.0}, {}], {"x": float("inf")}) == [1.0, 1.0]


def test_flow_rates_incast_shares_rack_uplink():
    topo = Topology(num_racks=2, cross_rack_bw=1.0, oversub=2.0)
    # four concurrent reducers on rack 0's nodes: rack capacity 0.5 split
    # by cross_frac weight 0.5 each -> 0.25 apiece... (4 flows, weight 1/2)
    rates = flow_rates(topo, [0, 2, 4, 6], num_nodes=8)
    assert rates == pytest.approx([0.25] * 4)
    # a single flow is uncontended but still uplink-bounded below nominal
    assert flow_rates(topo, [0], num_nodes=8) == pytest.approx([1.0])


def test_effective_bandwidth_values_and_grads():
    fdt = jnp.result_type(float)
    one = jnp.asarray(1.0, fdt)
    # flat spellings: one rack, or an infinite uplink
    assert float(effective_bandwidth(one, jnp.asarray(jnp.inf, fdt),
                                     one, 8.0 * one)) == 1.0
    assert float(effective_bandwidth(4.0 * one, jnp.asarray(jnp.inf, fdt),
                                     one, 8.0 * one)) == 1.0
    # 4 racks, capacity 0.5/rack, 8 flows: 2/rack, demand 0.75*2 = 1.5
    got = effective_bandwidth(4.0 * one, one, 2.0 * one, 8.0 * one)
    assert float(got) == pytest.approx(0.5 / 1.5)
    # never exceeds nominal
    assert float(effective_bandwidth(2.0 * one, 100.0 * one, one, one)) == 1.0
    # gradients finite everywhere, including the flat boundary (the
    # double-where contract every model path relies on)
    g = jax.grad(lambda x: effective_bandwidth(4.0 * one, x, 2.0 * one,
                                               8.0 * one))(one)
    assert jnp.isfinite(g) and float(g) > 0
    g0 = jax.grad(lambda r: effective_bandwidth(r, one, one, 8.0 * one))(one)
    assert jnp.isfinite(g0)


def test_topology_validation():
    with pytest.raises(ValueError):
        Topology(num_racks=0)
    with pytest.raises(ValueError):
        Topology(num_racks=2, cross_rack_bw=0.0)
    with pytest.raises(ValueError):
        Topology(num_racks=2, oversub=0.5)
    assert Topology.flat().is_flat
    assert not Topology(num_racks=2, cross_rack_bw=1.0).is_flat


def test_job_model_topology_hook_double_where():
    from repro.core.hadoop.model import job_model_jnp, pack_config
    from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats

    fdt = jnp.result_type(float)
    cfg = pack_config(HadoopParams(pNumMappers=16, pNumReducers=8,
                                   pNumNodes=8),
                      ProfileStats(), CostFactors())
    flat = job_model_jnp(dict(cfg))["j_totalCost"]
    # racks=1 hook present == hook absent, bit-for-bit
    same = job_model_jnp(dict(cfg, pNumRacks=jnp.asarray(1.0, fdt)))
    assert float(same["j_totalCost"]) == float(flat)
    topo = dict(cfg, pNumRacks=jnp.asarray(4.0, fdt),
                crossRackBw=jnp.asarray(0.5, fdt),
                oversubscription=jnp.asarray(2.0, fdt))
    assert float(job_model_jnp(topo)["j_totalCost"]) > float(flat)
    # the searched gradient is finite and points the right way (more
    # uplink -> cheaper), including at the racks=1 boundary
    g = jax.grad(lambda x: job_model_jnp(
        {**topo, "crossRackBw": x})["j_totalCost"])(jnp.asarray(0.5, fdt))
    assert jnp.isfinite(g) and float(g) < 0
    g1 = jax.grad(lambda r: job_model_jnp(
        {**topo, "pNumRacks": r})["j_totalCost"])(jnp.asarray(1.0, fdt))
    assert jnp.isfinite(g1)


# ---------------------------------------------------------------------------
# DES <-> wave agreement under contention
# ---------------------------------------------------------------------------


def _wave_one(trace, *, nodes, topo=None):
    cols = pack_trace(trace)
    n = len(trace.arrivals)
    frac = (nodes - 1.0) / nodes
    scen = {k: v[None] for k, v in cols.items()}
    scen["shuffle"] = scen["shuffle"] * frac
    scen["map_slots"] = np.asarray([[nodes * 2.0]])
    scen["red_slots"] = np.asarray([[nodes * 2.0]])
    scen["policy"] = np.zeros(1)
    scen["slowstart"] = np.full(1, 0.05)
    scen["queue_frac"] = np.ones((1, 1))
    scen["queue"] = np.zeros((1, n))
    if topo is not None:
        scen["topo_racks"] = np.full(1, float(topo.num_racks))
        scen["topo_cross_bw"] = np.full(1, topo.cross_rack_bw)
        scen["topo_oversub"] = np.full(1, topo.oversub)
    return simulate_batch(scen, n_steps=256)


def test_wave_matches_des_single_incast_job():
    # one sort job saturating the uplink: the wave count-approximation and
    # the DES fair-share integration see the identical contention state
    tr = WorkloadTrace((JobArrival(0, BY_NAME["sort"], 0.0),))
    topo = Topology(num_racks=4, cross_rack_bw=0.5, oversub=2.0)
    des = simulate_workload(
        tr, ClusterConfig(num_nodes=8, topology=topo), SimConfig(seed=0))
    out = _wave_one(tr, nodes=8, topo=topo)
    assert out["converged"][0] == 1.0
    np.testing.assert_allclose(out["makespan"][0], des.makespan, rtol=1e-3)


def test_wave_flat_unchanged_by_topology_columns():
    from repro.cluster.workload import poisson_trace

    tr = poisson_trace(CLASSES, 6, seed=4)
    base = _wave_one(tr, nodes=8)
    flat = _wave_one(tr, nodes=8, topo=Topology(num_racks=1))
    np.testing.assert_array_equal(base["latency"], flat["latency"])


# ---------------------------------------------------------------------------
# DAG workloads
# ---------------------------------------------------------------------------


def test_dag_validation_errors():
    wc = BY_NAME["wordcount"]
    with pytest.raises(ValueError, match="cycle"):
        StageDag("c", (wc, wc), (StageEdge(0, 1), StageEdge(1, 0)))
    with pytest.raises(ValueError, match="self-edge"):
        StageDag("s", (wc,), (StageEdge(0, 0),))
    with pytest.raises(ValueError, match="out of range"):
        StageDag("r", (wc,), (StageEdge(0, 3),))
    with pytest.raises(ValueError, match="duplicate"):
        StageDag("d", (wc, wc), (StageEdge(0, 1), StageEdge(0, 1)))
    with pytest.raises(ValueError, match="edge kind"):
        StageDag("k", (wc, wc), (StageEdge(0, 1, "sloppy"),))


def test_dag_dataflow_sizes_downstream_stages():
    # the child's mapper count comes from the parent's Table-1 output
    # bytes, not from the template
    dag = dag_from_templates(
        "two", [BY_NAME["sort"], BY_NAME["sort"]], [(0, 1)])
    parent = dag.stages[0]
    child = dag.stages[1]
    expect = max(1, int(np.ceil(
        stage_output_bytes(parent) / child.params.pSplitSize)))
    assert child.params.pNumMappers == expect
    assert parent.params.pNumMappers == BY_NAME["sort"].params.pNumMappers


def test_dag_releases_at_barrier_and_slowstart():
    dag = dag_from_templates(
        "chain", [BY_NAME["wordcount"], BY_NAME["sort"], BY_NAME["filter"]],
        [(0, 1, "barrier"), (1, 2, "slowstart")])
    tr = dag_trace(dag)
    res = simulate_workload(tr, ClusterConfig(num_nodes=8), SimConfig(seed=1))
    js = {j.job_id: j for j in res.jobs}
    assert js[1].submit_time == js[0].finish
    assert js[2].submit_time == js[1].map_finish
    assert js[2].submit_time < js[1].finish


def test_wave_rejects_multi_parent_dags():
    wc = BY_NAME["wordcount"]
    tr = WorkloadTrace((
        JobArrival(0, wc, 0.0),
        JobArrival(1, wc, 0.0),
        JobArrival(2, wc, 0.0, deps=((0, "barrier"), (1, "barrier"))),
    ))
    with pytest.raises(ValueError, match="single-parent"):
        pack_trace(tr)
    # the DES handles the same trace fine (fan-in joins are its territory)
    res = simulate_workload(tr, ClusterConfig(num_nodes=8), SimConfig(seed=0))
    assert res.n_unfinished == 0


def test_wave_dag_chain_tracks_des():
    dag = dag_from_templates(
        "chain", [BY_NAME["sort"], BY_NAME["sort"]], [(0, 1, "barrier")])
    tr = dag_trace(dag)
    des = simulate_workload(tr, ClusterConfig(num_nodes=8), SimConfig(seed=0))
    out = _wave_one(tr, nodes=8)
    assert out["converged"][0] == 1.0
    np.testing.assert_allclose(out["makespan"][0], des.makespan, rtol=1e-3)


@pytest.mark.parametrize("profile", sorted(JOBS))
@pytest.mark.parametrize("kind", ["barrier", "slowstart"])
def test_critical_path_equals_makespan_on_serial_chains(profile, kind):
    jc = BY_NAME[profile]
    dag = dag_from_templates(f"{profile}-{kind}", [jc, jc, jc],
                             [(0, 1, kind), (1, 2, kind)])
    assert dag.is_serial
    tr = dag_trace(dag)
    res = simulate_workload(tr, ClusterConfig(num_nodes=8), SimConfig(seed=3))
    rep = dag_report(tr, res)
    cp, mk = float(rep.critical_path_s), float(rep.makespan_s)
    assert cp == pytest.approx(mk, abs=1e-9)
    assert float(rep.slack_s) == pytest.approx(0.0, abs=1e-9)


@pytest.mark.parametrize("sched", SCHEDULERS)
def test_critical_path_never_exceeds_makespan(sched):
    # a diamond per profile pair, two interleaved instances, noisy DES,
    # small cluster so stages really queue — the adversarial setting for
    # the invariant
    stages = [BY_NAME[n] for n in ("wordcount", "sort", "filter", "aggregate")]
    dag = dag_from_templates(
        "diamond", stages,
        [(0, 1), (0, 2, "slowstart"), (1, 3), (2, 3, "slowstart")])
    tr = dag_trace(dag, n_instances=2, inter_arrival=3.0)
    res = simulate_workload(
        tr, ClusterConfig(num_nodes=3, map_slots_per_node=1,
                          reduce_slots_per_node=1, scheduler=sched),
        NOISY)
    rep = dag_report(tr, res)
    assert float(rep.critical_path_s) <= float(rep.makespan_s) + 1e-9
    assert float(rep.slack_s) >= -1e-9
    # the report is a registered pytree of arrays (spec contract)
    leaves = jax.tree_util.tree_leaves(rep)
    assert len(leaves) == 6
    assert rep.stage_runtime_s.shape == (tr.n_jobs,)


def test_dag_report_rejects_cyclic_edges():
    from repro.spec import DagReport

    with pytest.raises(ValueError, match="cycle"):
        DagReport.from_times([0.0, 0.0], [0.0, 0.0], [1.0, 1.0], [2.0, 2.0],
                             [(0, 1, "barrier"), (1, 0, "barrier")])
