"""Unit tests for the map/reduce/job analytical models (paper §2-§5).

All expected values below are hand-computed from the paper's equations for a
fully-traceable scenario (no combiner, no compression):

* split = 128 MiB, pair width = 100 B         -> 1 342 177.28 input pairs
* io.sort.mb = 100, spill .8, record .05      -> maxSer 796 917, maxAcc 262 144
* spill buffer = 262 144 pairs = 26 214 400 B -> numSpills = ceil(5.12) = 6
* N=6 <= F=10                                 -> single final merge pass
* 10 mappers, 4 reducers, 200 MiB task mem    -> shuffle Case 2 (big segments)
"""

import math

import pytest

from repro.core.hadoop import (
    CostFactors,
    HadoopParams,
    MiB,
    ProfileStats,
    job_model,
    map_task_model,
    reduce_task_model,
)

P = HadoopParams(
    pNumNodes=5,
    pNumMappers=10,
    pNumReducers=4,
    pSplitSize=128 * MiB,
)
S = ProfileStats(sInputPairWidth=100.0)
C = CostFactors()


class TestMapTask:
    def test_read_phase(self):
        m = map_task_model(P, S, C)
        assert m.inputMapSize == 128 * MiB                      # Eq. 2
        assert m.inputMapPairs == pytest.approx(1342177.28)     # Eq. 3
        assert m.ioReadCost == pytest.approx(128 * MiB * C.cHdfsReadCost)
        assert m.cpuReadCost == pytest.approx(1342177.28 * C.cMapCPUCost)

    def test_spill_buffer_accounting(self):
        m = map_task_model(P, S, C)
        assert m.maxSerPairs == 796917                          # Eq. 11
        assert m.maxAccPairs == 262144                          # Eq. 12
        assert m.spillBufferPairs == 262144                     # Eq. 13
        assert m.spillBufferSize == 26214400                    # Eq. 14
        assert m.numSpills == 6                                 # Eq. 15
        assert m.spillFileSize == 26214400                      # Eq. 17 (no comb/compr)

    def test_merge_phase_small_n(self):
        m = map_task_model(P, S, C)
        # N=6 <= F=10: no intermediate merging, final merge of 6 streams.
        assert m.numSpillsIntermMerge == 0
        assert m.numSpillsFinalMerge == 6
        assert m.numMergePasses == 1
        assert m.intermDataSize == 6 * 26214400                 # Eq. 29
        # Eq. 31 with S=0: read all spills once + write the merged file.
        expected_io = (6 * 26214400 + 6 * 26214400) * C.cLocalIOCost
        assert m.ioMergeCost == pytest.approx(expected_io)

    def test_map_only_job(self):
        p0 = P.replace(pNumReducers=0)
        m = map_task_model(p0, S, C)
        assert m.ioSpillCost == 0 and m.ioMergeCost == 0
        assert m.ioMapWriteCost == pytest.approx(
            m.outMapSize * C.cHdfsWriteCost
        )                                                        # Eq. 6
        assert m.ioCost == pytest.approx(m.ioReadCost + m.ioMapWriteCost)

    def test_combiner_reduces_spill_size(self):
        p1 = P.replace(pUseCombine=True)
        s1 = S.replace(sCombineSizeSel=0.3, sCombinePairsSel=0.2)
        m0 = map_task_model(P, S, C)
        m1 = map_task_model(p1, s1, C)
        assert m1.spillFileSize == pytest.approx(0.3 * m0.spillFileSize)
        assert m1.spillFilePairs == pytest.approx(0.2 * m0.spillFilePairs)
        # Final merge re-applies the combiner (numSpillsFinalMerge=6 >= 3).
        assert m1.useCombInMerge
        assert m1.intermDataSize == pytest.approx(
            6 * m1.spillFileSize * 0.3
        )                                                        # Eq. 29

    def test_intermediate_compression_shrinks_spills(self):
        p1 = P.replace(pIsIntermCompressed=True)
        s1 = S.replace(sIntermCompressRatio=0.4)
        m = map_task_model(p1, s1, C)
        assert m.spillFileSize == pytest.approx(0.4 * 26214400)  # Eq. 17


class TestReduceTask:
    def test_segment_sizes(self):
        m = map_task_model(P, S, C)
        r = reduce_task_model(P, S, C, m)
        assert r.segmentComprSize == pytest.approx(6 * 26214400 / 4)   # Eq. 35
        assert r.totalShuffleSize == pytest.approx(10 * 6 * 26214400 / 4)

    def test_case2_big_segments(self):
        """segment (37.5 MiB) >= 25% of shuffle buffer (35 MiB) -> Case 2."""
        m = map_task_model(P, S, C)
        r = reduce_task_model(P, S, C, m)
        assert not r.inMemCase
        assert r.numSegInShuffleFile == 1
        assert r.numShuffleFiles == 10                           # Eq. 51
        assert r.numSegmentsInMem == 0
        assert r.numShuffleMerges == 0       # 10 < 2F-1 = 19    # Eq. 53

    def test_case1_small_segments(self):
        """Shrink segments below the 25% threshold -> in-memory pipeline."""
        p1 = P.replace(pNumReducers=64, pNumMappers=300)
        m = map_task_model(p1, S, C)
        r = reduce_task_model(p1, S, C, m)
        assert r.inMemCase
        seg = 6 * 26214400 / 64
        assert r.segmentUncomprSize == pytest.approx(seg)
        # mergeSizeThr = .66 * (.7 * 200MiB) = 96 888 422.4; /seg = 39.42 ->
        # ceil=40, 40*seg = 98.3e6 <= buffer 146.8e6 -> 40 segments per file.
        assert r.numSegInShuffleFile == 40                       # Eq. 43
        assert r.numShuffleFiles == 7        # floor(300/40)     # Eq. 46
        assert r.numSegmentsInMem == 20      # 300 mod 40        # Eq. 47

    def test_sort_phase_no_merging_when_files_fit(self):
        m = map_task_model(P, S, C)
        r = reduce_task_model(P, S, C, m)
        # 10 files on disk, F=10: step2 interm reads = 0 -> no sort IO.
        assert r.filesToMergeStep2 == 10
        assert r.totalMergingSize == 0
        assert r.ioSortCost == 0

    def test_write_phase(self):
        m = map_task_model(P, S, C)
        r = reduce_task_model(P, S, C, m)
        assert r.inReducePairs == pytest.approx(10 * 6 * 262144 / 4)   # Eq. 82
        assert r.inRedDiskSize == pytest.approx(10 * r.shuffleFileSize)  # Eq. 85
        assert r.ioWriteCost == pytest.approx(
            r.inRedDiskSize * C.cLocalIOCost
            + r.outReduceSize * C.cHdfsWriteCost
        )                                                        # Eq. 86


class TestJobModel:
    def test_wave_aggregation(self):
        j = job_model(P, S, C)
        # Eq. 92: 10 maps over 5 nodes x 2 slots = 1 wave.
        assert j.ioAllMaps == pytest.approx(10 * j.map.ioCost / 10)
        assert j.ioAllReducers == pytest.approx(4 * j.reduce.ioCost / 10)
        assert j.totalCost == pytest.approx(
            j.ioJobCost + j.cpuJobCost + j.netCost
        )                                                        # Eq. 98

    def test_network_transfer(self):
        j = job_model(P, S, C)
        # Eq. 90: all map output, 10 mappers, (5-1)/5 leaves the node.
        assert j.netTransferSize == pytest.approx(
            j.map.intermDataSize * 10 * 4 / 5
        )
        assert j.netCost == pytest.approx(j.netTransferSize * C.cNetworkCost)

    def test_map_only_job_has_no_reduce_or_net_cost(self):
        j = job_model(P.replace(pNumReducers=0), S, C)
        assert j.ioAllReducers == 0 and j.cpuAllReducers == 0
        assert j.netCost == 0
        assert j.totalCost == pytest.approx(j.ioAllMaps + j.cpuAllMaps)

    def test_more_nodes_cheaper_wall_clock(self):
        small = job_model(P, S, C)
        big = job_model(P.replace(pNumNodes=50), S, C)
        assert big.totalCost < small.totalCost

    def test_compression_tradeoff_is_visible(self):
        """Intermediate compression trades CPU for IO/NET — both must move."""
        j0 = job_model(P, S, C)
        j1 = job_model(
            P.replace(pIsIntermCompressed=True),
            S.replace(sIntermCompressRatio=0.4),
            C,
        )
        assert j1.ioJobCost < j0.ioJobCost
        assert j1.netCost < j0.netCost
        assert j1.cpuJobCost > j0.cpuJobCost
