"""repro.obs — metrics registry, Chrome-trace tracer, and the contract
the whole stack's instrumentation hangs off.

Three layers of coverage:

* the primitives: counter/gauge/histogram semantics, snapshot/merge,
  exact percentile interpolation (against numpy's linear method), the
  null singletons' zero-surface;
* the trace format: every emitted event is schema-valid Chrome trace
  JSON (required keys per phase, balanced B/E per track, monotonic
  timestamps), and off-by-default means *zero* events recorded;
* the integrations: DES virtual-time swimlanes (golden: deterministic,
  phase-carved, shuffle_end invariant), the evaluator under
  ``api.observe`` (same numbers, live counters), the serve-loop's
  read-only stats view, and calibration's grad-norm series.
"""

import json

import numpy as np
import pytest

from repro.obs import (
    NULL_OBS,
    NULL_REGISTRY,
    NULL_TRACER,
    MetricsRegistry,
    Tracer,
    current,
    observe,
    percentile_interp,
)

# ------------------------------------------------------------------
# metrics primitives
# ------------------------------------------------------------------


def test_percentile_interp_matches_numpy_linear():
    rng = np.random.default_rng(0)
    xs = sorted(rng.normal(size=37).tolist())
    for p in (0.0, 1.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0):
        assert percentile_interp(xs, p) == pytest.approx(
            float(np.percentile(xs, p)), rel=1e-12, abs=1e-12), p


def test_percentile_interp_edges():
    assert percentile_interp([], 50.0) == 0.0
    assert percentile_interp([7.0], 99.0) == 7.0
    assert percentile_interp([1.0, 2.0], -5.0) == 1.0
    assert percentile_interp([1.0, 2.0], 200.0) == 2.0


def test_counter_gauge_histogram_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    reg.gauge("g").set(2.5)
    reg.gauge("g").add(0.5)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("h").record(v)
    snap = reg.snapshot()
    assert snap["c"] == 5 and isinstance(snap["c"], int)
    assert snap["g"] == 3.0
    h = snap["h"]
    assert h["count"] == 4 and h["sum"] == 10.0 and h["mean"] == 2.5
    assert h["min"] == 1.0 and h["max"] == 4.0
    assert h["p50"] == pytest.approx(2.5)
    # JSON export round-trips
    assert json.loads(reg.to_json())["c"] == 5
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError, match="x"):
        reg.gauge("x")


def test_registry_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("n").inc(2)
    b.counter("n").inc(3)
    a.gauge("g").set(1.0)
    b.gauge("g").set(9.0)
    a.histogram("h").record(1.0)
    b.histogram("h").record(3.0)
    a.merge(b)
    snap = a.snapshot()
    assert snap["n"] == 5
    assert snap["g"] == 9.0            # gauges: last write wins
    assert snap["h"]["count"] == 2 and snap["h"]["sum"] == 4.0


def test_null_registry_is_inert():
    assert not NULL_REGISTRY.enabled
    NULL_REGISTRY.counter("x").inc(10)
    NULL_REGISTRY.gauge("y").set(1.0)
    NULL_REGISTRY.histogram("z").record(2.0)
    assert NULL_REGISTRY.snapshot() == {}
    live = MetricsRegistry()
    live.counter("k").inc()
    NULL_REGISTRY.merge(live)
    assert NULL_REGISTRY.snapshot() == {}


# ------------------------------------------------------------------
# LatencyStats (runtime.batching) — built on percentile_interp
# ------------------------------------------------------------------


def test_latency_stats_percentiles_and_small_samples():
    from repro.runtime.batching import LatencyStats

    empty = LatencyStats()
    assert empty.count == 0 and empty.p50 == 0.0 and empty.p99 == 0.0

    one = LatencyStats()
    one.record(0.25)
    assert one.p50 == 0.25 and one.p99 == 0.25 and one.mean() == 0.25

    many = LatencyStats()
    rng = np.random.default_rng(1)
    xs = rng.exponential(size=101).tolist()
    for x in xs:
        many.record(x)
    for p in (50.0, 90.0, 99.0):
        assert many.percentile(p) == pytest.approx(
            float(np.percentile(xs, p)), rel=1e-12)


def test_latency_stats_merge_pools_samples():
    from repro.runtime.batching import LatencyStats

    a, b = LatencyStats(), LatencyStats()
    for x in (1.0, 2.0):
        a.record(x)
    for x in (3.0, 4.0):
        b.record(x)
    assert a.merge(b) is a
    assert a.count == 4
    assert a.mean() == pytest.approx(2.5)
    assert b.count == 2                # source unchanged


# ------------------------------------------------------------------
# trace format
# ------------------------------------------------------------------


def _assert_valid_chrome_trace(events):
    """Schema validity + balanced/monotonic B/E per (pid, tid) track."""
    open_spans: dict[tuple, list] = {}
    last_ts: dict[tuple, float] = {}
    for e in events:
        assert isinstance(e.get("name"), str) and e["name"], e
        assert "ph" in e and "pid" in e and "tid" in e, e
        ph = e["ph"]
        if ph == "M":
            continue
        ts = e["ts"]
        assert isinstance(ts, (int, float)) and ts >= 0.0, e
        key = (e["pid"], e["tid"])
        if ph in ("B", "E"):
            assert ts >= last_ts.get(key, 0.0), f"ts went backwards: {e}"
            last_ts[key] = ts
            stack = open_spans.setdefault(key, [])
            if ph == "B":
                stack.append(e["name"])
            else:
                assert stack and stack[-1] == e["name"], (
                    f"unbalanced E {e['name']!r}; open: {stack}")
                stack.pop()
        elif ph == "X":
            assert e.get("dur", -1.0) >= 0.0, e
        elif ph == "i":
            assert e.get("s") in ("t", "p", "g"), e
        elif ph == "C":
            assert isinstance(e.get("args"), dict) and e["args"], e
        elif ph in ("b", "e", "n"):
            assert "id" in e and "cat" in e, e
        else:
            pytest.fail(f"unknown phase {ph!r}: {e}")
    for key, stack in open_spans.items():
        assert not stack, f"unclosed spans on {key}: {stack}"


def test_tracer_emits_schema_valid_events():
    tr = Tracer()
    tr.process_name(1, "test")
    tr.thread_name(1, 7, "lane", sort_index=7)
    with tr.span("outer", depth=0):
        with tr.span("inner"):
            tr.instant("tick", scope="p")
        tr.counter("load", depth=1.5)
    tr.complete("done", tr.now_us(), 10.0, pid=3, tid=4)
    tr.async_begin("q", 42)
    tr.async_instant("q-progress", 42)
    tr.async_end("q", 42)
    events = tr.events()
    assert len(events) >= 10
    _assert_valid_chrome_trace(events)
    doc = json.loads(tr.to_json())
    assert list(doc) == ["traceEvents"]
    assert len(doc["traceEvents"]) == len(events)


def test_tracer_write(tmp_path):
    tr = Tracer()
    with tr.span("s"):
        pass
    out = tmp_path / "t.json"
    tr.write(str(out))
    assert json.loads(out.read_text())["traceEvents"]


def test_span_unwinds_on_exception():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("bad"):
            raise RuntimeError("boom")
    _assert_valid_chrome_trace(tr.events())   # E still emitted


# ------------------------------------------------------------------
# off-by-default: the null path records nothing
# ------------------------------------------------------------------


def test_ambient_defaults_to_null_and_observe_restores():
    assert current() is NULL_OBS
    assert not current().enabled
    with observe() as ob:
        assert current() is ob and ob.enabled
        with observe() as inner:                  # contexts nest
            assert current() is inner
        assert current() is ob
    assert current() is NULL_OBS


def test_null_tracer_records_nothing():
    with NULL_TRACER.span("x", a=1):
        NULL_TRACER.instant("i")
        NULL_TRACER.counter("c", v=1)
    NULL_TRACER.complete("x", 0.0, 1.0)
    assert NULL_TRACER.events() == []
    assert not NULL_TRACER.enabled


def test_uninstrumented_run_touches_no_ambient_state():
    """A DES run with observability off must leave the null singletons
    empty — the guard is `ob.enabled`, checked before any recording."""
    from repro.cluster import (
        ClusterConfig,
        JobArrival,
        JobClass,
        WorkloadTrace,
        simulate_workload,
    )
    from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
    from repro.core.hadoop.simulator import SimConfig

    p = HadoopParams(pNumNodes=2, pNumMappers=8, pNumReducers=2,
                     pSplitSize=64 * MiB)
    jc = JobClass("one", p, ProfileStats(), CostFactors())
    tr = WorkloadTrace((JobArrival(0, jc, 0.0),))
    assert current() is NULL_OBS
    simulate_workload(tr, ClusterConfig(num_nodes=2),
                      SimConfig(speculative_execution=False))
    assert NULL_TRACER.events() == []
    assert NULL_REGISTRY.snapshot() == {}


def test_observe_writes_trace_file(tmp_path):
    out = tmp_path / "obs.json"
    with observe(str(out)) as ob:
        with ob.tracer.span("work"):
            ob.registry.counter("n").inc()
    doc = json.loads(out.read_text())
    assert [e["name"] for e in doc["traceEvents"] if e["ph"] == "B"] == ["work"]


# ------------------------------------------------------------------
# DES virtual-time swimlanes (golden on the canonical one-job workload)
# ------------------------------------------------------------------


def _one_job_des():
    from repro.cluster import (
        ClusterConfig,
        JobArrival,
        JobClass,
        WorkloadTrace,
        simulate_workload,
    )
    from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
    from repro.core.hadoop.simulator import SimConfig

    p = HadoopParams(pNumNodes=4, pNumMappers=32, pNumReducers=8,
                     pSplitSize=64 * MiB)
    jc = JobClass("one", p, ProfileStats(), CostFactors())
    tr = WorkloadTrace((JobArrival(0, jc, 0.0),))
    cc = ClusterConfig.from_params(p)
    res = simulate_workload(tr, cc, SimConfig(speculative_execution=False))
    return tr, res, cc


MAP_PHASES = {"map_read", "map_spill", "map_merge", "map_write"}
REDUCE_PHASES = {"network", "shuffle", "reduce_merge", "reduce_write"}


def test_workload_trace_golden_one_job():
    from repro.obs import workload_trace
    from repro.obs.destrace import SIM_SECOND_US

    tr, res, cc = _one_job_des()
    events = workload_trace(tr, res, cc).events()
    _assert_valid_chrome_trace(events)

    # deterministic: same simulation -> identical event list (virtual time)
    again = workload_trace(tr, res, cc).events()
    assert events == again

    xs = [e for e in events if e["ph"] == "X"]
    task_spans = [e for e in xs if "[" in e["name"]]
    phase_spans = [e for e in xs if e["name"] in MAP_PHASES | REDUCE_PHASES]
    assert len(task_spans) == 32 + 8          # every map + reduce rendered
    assert {e["name"] for e in phase_spans} >= {
        "map_read", "map_spill", "network", "reduce_write"}

    # virtual-time axis: the last span ends at the simulated makespan
    end_us = max(e["ts"] + e["dur"] for e in xs)
    assert end_us == pytest.approx(res.makespan * SIM_SECOND_US, rel=1e-9)

    # per-job lane: queued + running spans, running ends at job finish
    job = res.jobs[0]
    running = [e for e in xs if e["name"] == "running"]
    assert len(running) == 1
    assert running[0]["ts"] + running[0]["dur"] == pytest.approx(
        job.finish * SIM_SECOND_US)

    # counter sweep present, on tid 0
    counters = [e for e in events if e["ph"] == "C"]
    assert counters and all(e["tid"] == 0 for e in counters)
    assert {"maps", "reduces"} <= set(counters[0]["args"])


def test_des_records_shuffle_end_invariant():
    _, res, _ = _one_job_des()
    reduces = [r for r in res.records if r.kind == "reduce" and not r.killed]
    assert reduces
    for r in reduces:
        assert r.start <= r.shuffle_end <= r.end
    for r in res.records:
        assert (r.kill_reason != "") == r.killed


def test_des_simulate_records_metrics_under_observe():
    from repro.cluster import (
        ClusterConfig,
        JobArrival,
        JobClass,
        WorkloadTrace,
        simulate_workload,
    )
    from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
    from repro.core.hadoop.simulator import SimConfig

    p = HadoopParams(pNumNodes=2, pNumMappers=8, pNumReducers=2,
                     pSplitSize=64 * MiB)
    jc = JobClass("one", p, ProfileStats(), CostFactors())
    tr = WorkloadTrace((JobArrival(0, jc, 0.0),))
    with observe() as ob:
        res = simulate_workload(tr, ClusterConfig(num_nodes=2),
                                SimConfig(speculative_execution=False))
    snap = ob.registry.snapshot()
    assert snap["des.runs"] == 1 and snap["des.jobs"] == 1
    assert snap["des.tasks"] == len(res.records)
    assert [e["name"] for e in ob.tracer.events()
            if e["ph"] == "X"] == ["des.simulate"]


# ------------------------------------------------------------------
# evaluator + api.observe: live counters, unchanged numbers
# ------------------------------------------------------------------


def test_api_observe_evaluator_counters_and_equivalence(tmp_path):
    import repro.api as api
    from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
    from repro.search import ChunkedEvaluator

    hp = HadoopParams(pNumNodes=4, pNumMappers=32, pNumReducers=8,
                      pSplitSize=64 * MiB)
    ev = ChunkedEvaluator(hp, ProfileStats(), CostFactors(), chunk=64)
    rows = {"pSortMB": np.array([50.0, 100.0, 200.0])}
    plain = ev.evaluate(rows)
    out = tmp_path / "ev.json"
    with api.observe(str(out)) as ob:
        traced = ev.evaluate(rows)
    assert np.array_equal(plain.total_cost, traced.total_cost)
    snap = ob.registry.snapshot()
    assert snap["evaluator.rows"] == 3
    assert snap["evaluator.chunks"] >= 1
    assert snap["evaluator.evaluate_s"]["count"] == 1
    doc = json.loads(out.read_text())
    _assert_valid_chrome_trace(doc["traceEvents"])
    assert any(e["name"] == "evaluator.evaluate"
               for e in doc["traceEvents"])


# ------------------------------------------------------------------
# serve-loop stats view
# ------------------------------------------------------------------


def test_server_stats_view_reads_registry():
    from repro.runtime.serve_loop import _CounterView

    reg = MetricsRegistry()
    view = _CounterView(reg)
    assert set(view) == {"prefills", "decode_ticks", "tokens_out"}
    assert len(view) == 3
    assert view["prefills"] == 0
    reg.counter("server.prefills").inc(3)
    assert view["prefills"] == 3 and isinstance(view["prefills"], int)
    assert dict(view)["tokens_out"] == 0
    with pytest.raises(KeyError):
        view["no_such_counter"]


# ------------------------------------------------------------------
# calibration series
# ------------------------------------------------------------------


def test_calibrate_reports_grad_norm_series():
    from repro.calib import Observation, calibrate
    from repro.core.hadoop.model import job_model_jnp
    from repro.spec import JobSpec

    base = JobSpec()

    def total(s):
        return float(job_model_jnp(s.pack())["j_totalCost"])

    obs = [Observation(spec=s, cost=total(s))
           for s in (base.replace(pSortMB=mb) for mb in (64.0, 128.0))]
    with observe() as ob:
        rep = calibrate(obs, ["cMapCPUCost"], steps=20, history_every=5)
    assert len(rep.grad_norm_history) == len(rep.loss_history) - 1
    assert all(np.isfinite(g) for g in rep.grad_norm_history)
    assert rep.n_model_evals == 22
    snap = ob.registry.snapshot()
    assert snap["calib.runs"] == 1 and snap["calib.model_evals"] == 22
    assert any(e["name"] == "calibration" for e in ob.tracer.events()
               if e["ph"] == "C")
