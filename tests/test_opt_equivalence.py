"""Every §Perf OptFlags variant must be mathematically equivalent to the
paper-faithful baseline — same losses, same gradients, same MoE routing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm, moe
from repro.models.attention import chunked_attention, flash_attention_xla
from repro.models.opt_flags import OptFlags, clear_flags, set_flags


@pytest.fixture(autouse=True)
def _clean_flags():
    clear_flags()
    yield
    clear_flags()


@pytest.mark.parametrize("capacity_factor", [1.25, 0.5, 8.0])
def test_moe_gather_equals_einsum(capacity_factor):
    cfg = get_config("deepseek-moe-16b").smoke().replace(
        moe_capacity_factor=capacity_factor
    )
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y1, a1 = moe.apply_moe(p, x, cfg)
    set_flags(OptFlags(moe_impl="gather"))
    y2, a2 = moe.apply_moe(p, x, cfg)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
    assert float(a1) == float(a2)


def test_moe_gather_grads_match():
    cfg = get_config("deepseek-moe-16b").smoke()
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))

    def loss(p):
        y, aux = moe.apply_moe(p, x, cfg)
        return (y ** 2).sum() + aux

    g1 = jax.grad(loss)(p)
    set_flags(OptFlags(moe_impl="gather"))
    g2 = jax.grad(loss)(p)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5),
        g1, g2,
    )


@pytest.mark.parametrize(
    "causal,window,cap,off",
    [(True, None, None, 0), (True, 64, 50.0, 0), (False, None, None, 0),
     (True, None, 30.0, 128)],
)
def test_flash_bwd_matches_autodiff(causal, window, cap, off):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 192, 32))
    v = jax.random.normal(ks[2], (1, 2, 192, 32))

    def f_ref(q, k, v):
        return (chunked_attention(
            q, k, v, causal=causal, window=window, logit_cap=cap,
            q_offset=off, chunk=64,
        ) ** 2).sum()

    def f_new(q, k, v):
        return (flash_attention_xla(q, k, v, causal, window, cap, off) ** 2).sum()

    np.testing.assert_allclose(f_ref(q, k, v), f_new(q, k, v), rtol=1e-5)
    g1 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_sharded_loss_and_flash_bwd_full_model():
    """End-to-end: loss value + all grads identical with every flag on."""
    cfg = get_config("gemma2-9b").smoke()
    p = lm.init(jax.random.PRNGKey(0), cfg)
    batch = {
        "inputs": jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 48), 0, cfg.vocab_size),
    }

    def loss(p):
        return lm.loss_fn(p, cfg, batch)[0]

    l1, g1 = jax.value_and_grad(loss)(p)
    set_flags(OptFlags(sharded_loss=True, flash_bwd=True, moe_impl="gather"))
    l2, g2 = jax.value_and_grad(loss)(p)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=5e-4, atol=1e-5),
        g1, g2,
    )


def test_inplace_cache_decode_equals_stream():
    import jax.numpy as jnp

    cfg = get_config("gemma2-9b").smoke()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    _, caches, _ = lm.prefill(params, cfg, toks, 24)
    pos = jnp.asarray(12, jnp.int32)

    l1, c1 = lm.decode_step(params, cfg, toks[:, :1], caches, pos)
    set_flags(OptFlags(cache_update="inplace"))
    l2, c2 = lm.decode_step(params, cfg, toks[:, :1], caches, pos)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
