"""repro.search.service: the async what-if query service.

The contract under test:
* N concurrent mixed-shape queries (probes / sweeps / grids) resolve
  bit-for-bit identically to sequential ``ChunkedEvaluator.evaluate`` calls
  on the same rows;
* ``valid == 0`` rows resolve through the exact task-scheduler simulator
  when the query opts in (and ``best()`` raises otherwise);
* queue pressure coalesces: many small queries ride far fewer evaluator
  chunks than there are queries, and the accounting (latency, queue depth,
  chunk sharing) reflects it.
"""

import threading

import numpy as np
import pytest

from repro.core.hadoop import CostFactors, HadoopParams, MiB, ProfileStats
from repro.core.whatif import evaluate_queries
from repro.search import (
    ChunkedEvaluator,
    InvalidGridError,
    WhatIfService,
    space_block,
    space_size,
)

P = HadoopParams(pNumNodes=8, pNumMappers=64, pNumReducers=16, pSplitSize=128 * MiB)
S = ProfileStats(sMapSizeSel=0.8, sReduceSizeSel=0.5)
C = CostFactors()

# numSpills >> pSortFactor**2 -> closed-form merge math out of domain
INVALID = {"pSortMB": 0.25, "pSortFactor": 2.0}


@pytest.fixture(scope="module")
def evaluator():
    return ChunkedEvaluator(P, S, C, chunk=64)


def _mixed_queries(rng, n):
    """A mixed workload: ~1/3 probes, ~1/3 sweeps, ~1/3 small grids."""
    sortmb = np.array([16.0, 25.0, 50.0, 100.0, 200.0, 400.0])
    queries = []
    for i in range(n):
        kind = i % 3
        if kind == 0:       # single-config probe
            queries.append({"pSortMB": np.array([rng.choice(sortmb)])})
        elif kind == 1:     # per-axis sweep, pinned base
            queries.append({
                "pNumReducers": np.array([4.0, 8.0, 16.0, 32.0]),
                "pSortMB": np.full(4, rng.choice(sortmb)),
            })
        else:               # small product grid
            space = {"pSortMB": sortmb[:3].tolist(),
                     "pSortFactor": [5.0, 10.0, 25.0]}
            queries.append(space_block(space, 0, space_size(space)))
    return queries


def _assert_bitwise(result, ref):
    assert np.array_equal(result.total_cost, ref.total_cost)
    for k in ref.outputs:
        assert np.array_equal(result.outputs[k], ref.outputs[k]), k


# ------------------------------------------------------------------
# equivalence
# ------------------------------------------------------------------


def test_concurrent_mixed_queries_match_sequential_evaluate(evaluator):
    queries = _mixed_queries(np.random.default_rng(0), 24)
    with WhatIfService(evaluator) as svc:
        results = svc.map(queries)
    assert len(results) == len(queries)
    for q, r in zip(queries, results):
        _assert_bitwise(r, evaluator.evaluate(q))
        assert r.stats.n_rows == len(next(iter(q.values())))
        assert r.stats.latency_s > 0 and r.stats.n_chunks >= 1


def test_threaded_submission_matches_sequential(evaluator):
    """True concurrency: every query arrives from its own thread."""
    queries = _mixed_queries(np.random.default_rng(1), 12)
    results = [None] * len(queries)

    def submit(i):
        results[i] = svc.submit(queries[i]).result(timeout=120)

    with WhatIfService(evaluator, window_s=0.01) as svc:
        threads = [threading.Thread(target=submit, args=(i,))
                   for i in range(len(queries))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    for q, r in zip(queries, results):
        _assert_bitwise(r, evaluator.evaluate(q))


def test_grid_query_streams_across_chunks(evaluator):
    """A query bigger than one chunk spans several chunks, same results."""
    space = {"pSortMB": [16.0, 25.0, 50.0, 100.0, 200.0],
             "pSortFactor": [5.0, 10.0, 25.0, 50.0],
             "pNumReducers": [4.0, 8.0, 16.0, 32.0, 64.0]}
    assert space_size(space) > evaluator.chunk
    with WhatIfService(evaluator) as svc:
        r = svc.grid(space).result(timeout=300)
    cols = space_block(space, 0, space_size(space))
    _assert_bitwise(r, evaluator.evaluate(cols))
    assert r.stats.n_chunks >= 2
    i, cost, assignment = r.best()
    assert np.isfinite(cost)
    assert assignment == {k: float(v[i]) for k, v in cols.items()}


def test_probe_and_sweep_helpers(evaluator):
    with WhatIfService(evaluator) as svc:
        pr = svc.probe({"pSortMB": 100.0}, exact_fallback=False).result(60)
        sw = svc.sweep("pSortMB", [25.0, 50.0, 100.0],
                       base={"pSortFactor": 25.0}).result(60)
    assert pr.total_cost.shape == (1,)
    _assert_bitwise(pr, evaluator.evaluate({"pSortMB": np.array([100.0])}))
    ref = evaluator.evaluate({"pSortMB": np.array([25.0, 50.0, 100.0]),
                              "pSortFactor": np.full(3, 25.0)})
    _assert_bitwise(sw, ref)


def test_evaluate_queries_multi_query_path():
    """core.whatif.evaluate_queries routes through the service."""
    queries = [{"pSortMB": np.array([50.0, 100.0])},
               {"pNumReducers": np.array([8.0, 16.0, 32.0])}]
    ev = ChunkedEvaluator(P, S, C, chunk=64)
    results = evaluate_queries(P, S, C, queries, evaluator=ev)
    for q, r in zip(queries, results):
        _assert_bitwise(r, ev.evaluate(q))


# ------------------------------------------------------------------
# escape hatch / error semantics
# ------------------------------------------------------------------


def test_escape_hatch_rows_resolve_via_simulator(evaluator):
    with WhatIfService(evaluator) as svc:
        r = svc.probe(INVALID).result(timeout=120)          # hatch on by default
        r_raw = svc.probe(INVALID, exact_fallback=False).result(timeout=120)
    assert r.exact.all() and np.isfinite(r.total_cost).all()
    assert r.total_cost[0] == pytest.approx(evaluator.exact_cost(INVALID))
    assert r.stats.n_exact == 1
    # without the hatch: inf cost, and best() raises instead of lying
    assert not np.isfinite(r_raw.total_cost).any()
    with pytest.raises(InvalidGridError):
        r_raw.best()


def test_mixed_valid_invalid_rows(evaluator):
    ov = {"pSortMB": np.array([0.25, 100.0]), "pSortFactor": np.array([2.0, 25.0])}
    with WhatIfService(evaluator) as svc:
        r = svc.submit(ov, exact_fallback=True).result(timeout=120)
    assert list(r.exact) == [True, False]
    assert np.isfinite(r.total_cost).all()
    assert r.total_cost[0] == pytest.approx(
        evaluator.exact_cost({"pSortMB": 0.25, "pSortFactor": 2.0})
    )
    # the valid row is untouched model cost
    ref = evaluator.evaluate(ov)
    assert r.total_cost[1] == ref.total_cost[1]


def test_submit_validation(evaluator):
    with WhatIfService(evaluator) as svc:
        with pytest.raises(KeyError):
            svc.submit({"nope": 1.0})
        with pytest.raises(ValueError):
            svc.submit({})
        with pytest.raises(ValueError):
            svc.submit({"pSortMB": np.array([])})
        with pytest.raises(ValueError):
            svc.submit({"pSortMB": np.array([1.0, 2.0]),
                        "pSortFactor": np.array([1.0])})
        # the service survives rejected submissions
        r = svc.probe({"pSortMB": 100.0}, exact_fallback=False).result(60)
        assert np.isfinite(r.total_cost).all()


def test_evaluator_failure_resolves_future_and_drops_remaining_rows():
    """A chunk that raises must fail that query's future, drop its not-yet-
    evaluated rows (no wasted chunks), and leave the service serving."""
    class FlakyEvaluator(ChunkedEvaluator):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self.fail_next = 0
            self.calls = 0

        def evaluate(self, overrides):
            self.calls += 1
            if self.fail_next > 0:
                self.fail_next -= 1
                raise RuntimeError("injected evaluator failure")
            return super().evaluate(overrides)

    ev = FlakyEvaluator(P, S, C, chunk=8)
    with WhatIfService(ev) as svc:
        ev.fail_next = 1
        big = svc.submit({"pSortMB": np.linspace(16.0, 400.0, 20)})  # 3 chunks
        with pytest.raises(RuntimeError, match="injected"):
            big.result(timeout=120)
        calls_after_failure = ev.calls
        # the dead query's remaining 12 rows were dropped, not evaluated
        r = svc.probe({"pSortMB": 100.0}, exact_fallback=False).result(120)
        assert np.isfinite(r.total_cost).all()
        assert ev.calls == calls_after_failure + 1


def test_closed_service_rejects_submissions(evaluator):
    svc = WhatIfService(evaluator)
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit({"pSortMB": 100.0})


# ------------------------------------------------------------------
# coalescing / accounting
# ------------------------------------------------------------------


def test_queue_pressure_coalesces_queries_into_fewer_chunks(evaluator):
    """32 small queries against a 64-row chunk must share chunks: the
    evaluator is called far fewer times than there are queries."""
    rng = np.random.default_rng(2)
    queries = [{"pSortMB": np.array([rng.choice([25.0, 50.0, 100.0])])}
               for _ in range(32)]
    with WhatIfService(evaluator) as svc:
        results = svc.map(queries)
        summary = svc.summary()
    assert summary["queries"] == 32 and summary["rows"] == 32
    assert summary["chunks"] < 32          # coalescing happened
    assert summary["shared_chunks"] >= 1
    assert any(r.stats.n_shared_chunks > 0 for r in results)
    assert summary["latency_count"] == 32
    assert summary["latency_p99_s"] >= summary["latency_p50_s"] > 0
    for q, r in zip(queries, results):
        _assert_bitwise(r, evaluator.evaluate(q))


def test_fixed_key_universe_coalesces_across_key_sets(evaluator):
    """With keys=..., queries with DIFFERENT own key-sets are expanded to
    the shared universe (absent keys at base values) and ride one chunk —
    one compiled executable for every tenant."""
    universe = ["pSortMB", "pSortFactor"]
    queries = [{"pSortMB": np.array([25.0, 50.0])},
               {"pSortFactor": np.array([5.0, 10.0, 25.0])},
               {"pSortMB": np.array([100.0]), "pSortFactor": np.array([50.0])}]
    with WhatIfService(evaluator, keys=universe) as svc:
        results = svc.map(queries)
        summary = svc.summary()
        with pytest.raises(KeyError):
            svc.submit({"pNumReducers": 8.0})       # outside the universe
    assert summary["chunks"] == 1                   # all three shared it
    base = evaluator.base_cfg
    for q, r in zip(queries, results):
        n = len(next(iter(q.values())))
        expanded = {k: np.asarray(q.get(k, np.full(n, float(np.asarray(base[k])))))
                    for k in universe}
        _assert_bitwise(r, evaluator.evaluate(expanded))
        assert set(r.overrides) == set(universe)


def test_queue_depth_recorded(evaluator):
    queries = [{"pSortMB": np.array([50.0])} for _ in range(8)]
    with WhatIfService(evaluator) as svc:
        results = svc.map(queries)
    depths = [r.stats.queue_depth for r in results]
    assert depths == sorted(depths)        # FIFO admission order
    assert max(depths) >= 1                # pressure was visible
