"""Roofline HLO parser: trip-count recovery, collective wire accounting,
dot-flop census — on hand-written HLO fragments with known answers."""

from repro.core.roofline import (
    HW,
    _Program,
    collective_bytes,
    hlo_totals,
    roofline_terms,
)

HLO = """
body.1 (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %dot.5 = f32[8,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar.1 = f32[8,128]{1,0} all-reduce(%dot.5), replica_groups=[16,16]<=[256], to_apply=%add.1
  %rs.1 = f32[8,8]{1,0} reduce-scatter(%ar.1), replica_groups=[16,16]<=[256], dimensions={1}
}

cond.1 (p: (s32[], f32[8,128])) -> pred[] {
  %p2 = (s32[], f32[8,128]) parameter(0)
  %c10 = s32[] constant(10)
  %lt = pred[] compare(%gte, %c10), direction=LT
}

ENTRY main (x: f32[8,64]) -> f32[8,128] {
  %a = f32[8,64]{1,0} parameter(0)
  %b = f32[64,128]{1,0} constant(0)
  %w = (s32[], f32[8,128]) while(%init), condition=%cond.1, body=%body.1
  %ag.1 = f32[128,128]{1,0} all-gather(%gte2), replica_groups=[16,16]<=[256], dimensions={0}
}
"""


def test_trip_count_from_condition_constant():
    prog = _Program(HLO)
    assert prog.body_trips.get("body.1") == 10
    assert prog.eff_mult("body.1") == 10.0
    assert prog.eff_mult("main") == 1.0


def test_collective_wire_accounting():
    stats = collective_bytes(HLO)
    # all-reduce: 8*128*4 B x2 (wire) x10 trips
    ar = 8 * 128 * 4 * 2 * 10
    # reduce-scatter: result 8*8*4 x group 16 x10
    rs = 8 * 8 * 4 * 16 * 10
    # all-gather: 128*128*4 x1
    ag = 128 * 128 * 4
    assert stats.by_kind["all-reduce"] == ar
    assert stats.by_kind["reduce-scatter"] == rs
    assert stats.by_kind["all-gather"] == ag
    assert stats.total_bytes == ar + rs + ag


def test_dot_flop_census():
    parsed = hlo_totals(HLO)
    # dot: out 8x128, contracted 64 (lhs dim 1), x10 trips
    assert parsed["dot_flops"] == 2 * 8 * 128 * 64 * 10


def test_roofline_terms_per_device_convention():
    parsed = hlo_totals(HLO)
    coll = collective_bytes(HLO)
    t = roofline_terms({"flops": 0.0, "bytes accessed": 1e9}, coll, 256,
                       model_fl=2 * 8 * 128 * 64 * 10 * 256, parsed=parsed)
    assert t.compute_s == parsed["dot_flops"] / HW["peak_flops"]
    assert t.collective_s == coll.total_bytes / HW["ici_bw"]
    assert 0.99 < t.useful_ratio <= 1.0


def test_mapreduce_compressed_paths():
    """Engine accounting under intermediate/output compression flags."""
    from repro.core.hadoop.params import HadoopParams, MiB
    from repro.mapreduce import JOBS, MapReduceEngine, make_input

    job = JOBS["sort"]
    n = 10_000
    hp = HadoopParams(
        pNumMappers=1, pNumReducers=2, pSortMB=0.5,
        pIsIntermCompressed=True, pIsOutCompressed=True,
        pSplitSize=n * job.pair_width, pTaskMem=8.0 * MiB,
    )
    jc = MapReduceEngine(hp, job).run_job(*make_input(job, n))
    mc = jc.maps[0]
    # compressed spill bytes = pairs x width x 0.3
    assert abs(sum(mc.spillFileSize) - n * job.pair_width * 0.3) < 1e-6
    rc = jc.reduces[0]
    assert rc.outReduceSize < rc.inReducePairs * job.out_pair_width  # 0.4x
