"""Property tests: the vectorized JAX model == the pure-Python oracle.

Hypothesis drives random (HadoopParams, ProfileStats, CostFactors) triples
through both implementations of Eqs. 2-98; wherever the closed-form merge
math is applicable (``valid == 1``) every reported quantity must agree to
float64 round-off.  This is the same oracle pattern the Pallas kernels use.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.hadoop import (
    CostFactors,
    HadoopParams,
    MiB,
    ProfileStats,
    job_model,
    job_model_jnp,
    pack_config,
)

# Map-task oracle field -> batched-model output key.
# Fields valid for every job type:
MAP_COMMON_FIELDS = [
    ("inputMapSize", "m_inputMapSize"),
    ("inputMapPairs", "m_inputMapPairs"),
    ("outPairWidth", "m_outPairWidth"),
    ("intermDataSize", "m_intermDataSize"),
    ("intermDataPairs", "m_intermDataPairs"),
    ("ioCost", "m_ioCost"),
    ("cpuCost", "m_cpuCost"),
]
# Spill/merge fields exist only when the job has reducers (the oracle
# returns early for map-only jobs and leaves them zero):
MAP_FIELDS = [
    ("maxSerPairs", "m_maxSerPairs"),
    ("maxAccPairs", "m_maxAccPairs"),
    ("spillBufferPairs", "m_spillBufferPairs"),
    ("numSpills", "m_numSpills"),
    ("spillFileSize", "m_spillFileSize"),
    ("numSpillsIntermMerge", "m_numSpillsIntermMerge"),
    ("numSpillsFinalMerge", "m_numSpillsFinalMerge"),
    ("numMergePasses", "m_numMergePasses"),
]
REDUCE_FIELDS = [
    ("segmentComprSize", "r_segmentComprSize"),
    ("numSegInShuffleFile", "r_numSegInShuffleFile"),
    ("shuffleFileSize", "r_shuffleFileSize"),
    ("numShuffleFiles", "r_numShuffleFiles"),
    ("numSegmentsInMem", "r_numSegmentsInMem"),
    ("numShuffleMerges", "r_numShuffleMerges"),
    ("numFilesOnDisk", "r_numFilesOnDisk"),
    ("filesToMergeStep2", "r_filesToMergeStep2"),
    ("step2MergingSize", "r_step2MergingSize"),
    ("filesToMergeStep3", "r_filesToMergeStep3"),
    ("step3MergingSize", "r_step3MergingSize"),
    ("totalMergingSize", "r_totalMergingSize"),
    ("inReduceSize", "r_inReduceSize"),
    ("inRedDiskSize", "r_inRedDiskSize"),
    ("ioCost", "r_ioCost"),
    ("cpuCost", "r_cpuCost"),
]

params_st = st.builds(
    HadoopParams,
    pNumNodes=st.integers(1, 200),
    pNumMappers=st.integers(1, 2000),
    pNumReducers=st.integers(0, 400),
    pSplitSize=st.sampled_from([16 * MiB, 64 * MiB, 128 * MiB, 256 * MiB]),
    pSortMB=st.sampled_from([50.0, 100.0, 200.0, 400.0]),
    pSpillPerc=st.sampled_from([0.6, 0.8, 0.9]),
    pSortRecPerc=st.sampled_from([0.05, 0.1, 0.2]),
    pSortFactor=st.sampled_from([5, 10, 25, 100]),
    pNumSpillsForComb=st.sampled_from([3, 9999]),
    pInMemMergeThr=st.sampled_from([10, 100, 1000]),
    pShuffleInBufPerc=st.sampled_from([0.5, 0.7]),
    pShuffleMergePerc=st.sampled_from([0.5, 0.66, 0.9]),
    pReducerInBufPerc=st.sampled_from([0.0, 0.3, 0.6]),
    pTaskMem=st.sampled_from([200.0 * MiB, 1024.0 * MiB]),
    pUseCombine=st.booleans(),
    pIsIntermCompressed=st.booleans(),
    pIsOutCompressed=st.booleans(),
    pIsInCompressed=st.booleans(),
)
stats_st = st.builds(
    ProfileStats,
    sInputPairWidth=st.sampled_from([24.0, 100.0, 650.0]),
    sMapSizeSel=st.sampled_from([0.1, 0.7, 1.0, 2.3]),
    sMapPairsSel=st.sampled_from([0.1, 1.0, 1.8]),
    sReduceSizeSel=st.sampled_from([0.1, 1.0]),
    sReducePairsSel=st.sampled_from([0.1, 1.0]),
    sCombineSizeSel=st.sampled_from([0.25, 0.8]),
    sCombinePairsSel=st.sampled_from([0.2, 0.7]),
    sInputCompressRatio=st.sampled_from([0.3, 0.6]),
    sIntermCompressRatio=st.sampled_from([0.3, 0.6]),
    sOutCompressRatio=st.sampled_from([0.3, 0.6]),
)


@given(params_st, stats_st)
@settings(max_examples=400, deadline=None)
def test_jnp_model_matches_python_oracle(p, s):
    c = CostFactors()
    out = {k: float(np.asarray(v)) for k, v in job_model_jnp(pack_config(p, s, c)).items()}
    if out["valid"] != 1.0:
        return  # closed-form domain exceeded; the oracle simulates instead

    j = job_model(p, s, c)
    for ref_f, jnp_k in MAP_COMMON_FIELDS:
        ref_v = float(getattr(j.map, ref_f))
        assert out[jnp_k] == pytest.approx(ref_v, rel=1e-9, abs=1e-12), (
            f"map field {ref_f}: oracle={ref_v} jnp={out[jnp_k]}"
        )
    if p.pNumReducers > 0:
        for ref_f, jnp_k in MAP_FIELDS:
            ref_v = float(getattr(j.map, ref_f))
            assert out[jnp_k] == pytest.approx(ref_v, rel=1e-9, abs=1e-12), (
                f"map field {ref_f}: oracle={ref_v} jnp={out[jnp_k]}"
            )
        for ref_f, jnp_k in REDUCE_FIELDS:
            ref_v = float(getattr(j.reduce, ref_f))
            assert out[jnp_k] == pytest.approx(ref_v, rel=1e-9, abs=1e-12), (
                f"reduce field {ref_f}: oracle={ref_v} jnp={out[jnp_k]}"
            )
    for lvl in ("j_ioJobCost", "j_cpuJobCost", "j_netCost", "j_totalCost"):
        ref_v = {
            "j_ioJobCost": j.ioJobCost,
            "j_cpuJobCost": j.cpuJobCost,
            "j_netCost": j.netCost,
            "j_totalCost": j.totalCost,
        }[lvl]
        assert out[lvl] == pytest.approx(ref_v, rel=1e-9, abs=1e-12)


@given(params_st, stats_st)
@settings(max_examples=200, deadline=None)
def test_costs_are_finite_and_nonnegative(p, s):
    """Invariant: every cost the model reports is finite and >= 0."""
    c = CostFactors()
    j = job_model(p, s, c)
    for v in (
        j.map.ioCost, j.map.cpuCost, j.reduce.ioCost, j.reduce.cpuCost,
        j.netCost, j.ioJobCost, j.cpuJobCost, j.totalCost,
    ):
        assert np.isfinite(v) and v >= 0.0


@given(params_st, stats_st)
@settings(max_examples=150, deadline=None)
def test_split_size_monotonicity(p, s):
    """More input per map task can never make a single map task cheaper."""
    c = CostFactors()
    small = job_model(p.replace(pSplitSize=64 * MiB), s, c)
    large = job_model(p.replace(pSplitSize=256 * MiB), s, c)
    assert large.map.ioCost >= small.map.ioCost - 1e-9
    assert large.map.cpuCost >= small.map.cpuCost - 1e-9


def test_vmap_grid_matches_scalar_calls():
    """A batched sweep over pSortMB equals per-point scalar evaluation."""
    p, s, c = HadoopParams(pNumNodes=4, pNumMappers=40, pNumReducers=8), ProfileStats(), CostFactors()
    grid = [32.0, 64.0, 128.0, 256.0, 512.0]
    cfg = pack_config(p, s, c)
    cfg["pSortMB"] = jnp.asarray(grid)
    import jax

    batched = jax.vmap(lambda v: job_model_jnp({**cfg, "pSortMB": v}))(
        jnp.asarray(grid)
    )
    for i, v in enumerate(grid):
        jref = job_model(p.replace(pSortMB=v), s, c)
        assert float(batched["j_totalCost"][i]) == pytest.approx(
            jref.totalCost, rel=1e-9
        )
