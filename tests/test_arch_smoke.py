"""Per-architecture smoke tests: reduced configs, one forward/train step.

Every assigned architecture gets (a) a loss+grad step on CPU asserting
output shapes and finiteness, and (b) a prefill/decode *consistency* check:
decoding token ``n`` against the prefilled cache must reproduce the
teacher-forced forward logits at position ``n`` — this validates the KV
ring-buffer caches, recurrent states and cross-attention caches end to end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import encdec as ed
from repro.models import lm

DEC_ARCHS = [a for a in ARCHS if a != "seamless-m4t-large-v2"]
B, S = 2, 24


def _smoke(name):
    return get_config(name).smoke()


def _batch(cfg, key):
    kt, ke = jax.random.split(key)
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    batch = {"inputs": tokens[:, :-1], "targets": tokens[:, 1:]}
    if cfg.frontend == "vision":
        batch["extra_embeds"] = (
            jax.random.normal(ke, (B, 4, cfg.d_model), jnp.float32) * 0.02
        )
    return batch


@pytest.mark.parametrize("name", DEC_ARCHS)
def test_train_step_shapes_and_finiteness(name):
    cfg = _smoke(name)
    key = jax.random.PRNGKey(0)
    params = lm.init(key, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch), has_aux=True
    )(params)
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    leaves = jax.tree.leaves(grads)
    assert leaves, f"{name}: no grads"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g))), f"{name}: non-finite grad"

    logits, _, _ = lm.forward(params, cfg, batch["inputs"],
                              extra_embeds=batch.get("extra_embeds"))
    extra = batch.get("extra_embeds")
    exp_len = batch["inputs"].shape[1] + (extra.shape[1] if extra is not None else 0)
    assert logits.shape == (B, exp_len, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("name", DEC_ARCHS)
def test_prefill_decode_consistency(name):
    """decode_step(cache(prefill(t[:n])), t[n]) == forward(t[:n+2])[:, n]."""
    cfg = _smoke(name)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    n = S - 4
    max_len = S + 8

    extra = None
    if cfg.frontend == "vision":
        extra = jax.random.normal(jax.random.PRNGKey(3), (B, 4, cfg.d_model)) * 0.02

    # Reference: teacher-forced logits at positions n and n+1.
    ref_logits, _, _ = lm.forward(params, cfg, tokens, extra_embeds=extra)
    off = 0 if extra is None else extra.shape[1]

    # Serve path: prefill on the first n tokens, then decode two steps.
    logits_p, caches, pos = lm.prefill(
        params, cfg, tokens[:, :n], max_len, extra_embeds=extra
    )
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(ref_logits[:, off + n - 1]),
        rtol=2e-4, atol=2e-4,
    )
    logits_d, caches = lm.decode_step(params, cfg, tokens[:, n : n + 1], caches, pos)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(ref_logits[:, off + n]),
        rtol=2e-4, atol=2e-4, err_msg=f"{name}: decode step 1 mismatch",
    )
    logits_d2, _ = lm.decode_step(
        params, cfg, tokens[:, n + 1 : n + 2], caches, pos + 1
    )
    np.testing.assert_allclose(
        np.asarray(logits_d2[:, 0]), np.asarray(ref_logits[:, off + n + 1]),
        rtol=2e-4, atol=2e-4, err_msg=f"{name}: decode step 2 mismatch",
    )


def test_encdec_train_step():
    cfg = _smoke("seamless-m4t-large-v2")
    params = ed.init_encdec(jax.random.PRNGKey(0), cfg)
    src = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.02
    tgt = jax.random.randint(jax.random.PRNGKey(2), (B, 12), 0, cfg.vocab_size)
    batch = {"src_embeds": src, "inputs": tgt[:, :-1], "targets": tgt[:, 1:]}
    (loss, _), grads = jax.value_and_grad(
        lambda p: ed.loss_fn_encdec(p, cfg, batch), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g)))


def test_encdec_prefill_decode_consistency():
    cfg = _smoke("seamless-m4t-large-v2")
    params = ed.init_encdec(jax.random.PRNGKey(0), cfg)
    src = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.02
    tgt = jax.random.randint(jax.random.PRNGKey(2), (B, 12), 0, cfg.vocab_size)
    n = 8

    ref_logits, _ = ed.forward_encdec(params, cfg, src, tgt)
    logits_p, caches, pos = ed.prefill_encdec(params, cfg, src, tgt[:, :n], 16)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(ref_logits[:, n - 1]),
        rtol=2e-4, atol=2e-4,
    )
    logits_d, _ = ed.decode_step_encdec(params, cfg, tgt[:, n : n + 1], caches, pos)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(ref_logits[:, n]),
        rtol=2e-4, atol=2e-4,
    )


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_structure(name):
    """The FULL configs must be structurally sound (no allocation here)."""
    cfg = get_config(name)
    assert cfg.n_groups > 0
    assert cfg.d_model > 0 and cfg.vocab_size > 0
    if cfg.n_experts:
        assert cfg.moe_top_k > 0 and cfg.d_expert > 0
    if "ssm" in cfg.layer_pattern:
        assert cfg.ssm_state > 0
        assert cfg.d_inner_ssm % cfg.ssm_head_dim == 0
    # long_500k applicability matches DESIGN.md §Shape-skips
    expected_long = {"gemma2-9b", "recurrentgemma-9b", "mamba2-130m"}
    assert cfg.supports_long_context == (name in expected_long)
