"""repro.spec + repro.api: the typed layer IS the dict layer, bit for bit.

The contract this file guards (and CI runs explicitly):

* ``JobSpec`` <-> ``pack_config`` round-trips losslessly, with int/bool
  fields recovered through the ``hadoop_space()`` axis kinds;
* the typed path (``ChunkedEvaluator.from_spec`` + ``CostReport``) is
  bit-for-bit equal to the dict path over every ``mapreduce.JOBS``
  profile;
* ``PhaseBreakdown`` fields sum to ``j_totalCost`` (Eqs. 96-98) — the
  phase decomposition loses nothing;
* specs and reports are registered pytrees (vmap-able, tree-mappable);
* validity is disaggregated: reports and fallback log lines say WHICH
  §2.3 merge constraint failed;
* a per-phase what-if query (minimize shuffle subject to a total budget)
  runs end-to-end through the async service via the ``repro.api`` facade.
"""

import logging

import numpy as np
import pytest

import repro.api as api
from repro.cluster.evaluator import ClusterEvaluator, cluster_space
from repro.cluster.workload import default_job_classes
from repro.core.hadoop import CostFactors, HadoopParams, MiB, ProfileStats
from repro.core.hadoop.model import CONFIG_KEYS, job_model_jnp, pack_config
from repro.search import (
    ChunkedEvaluator,
    InvalidGridError,
    masked_total,
    sanitize_costs,
    search_topk,
)
from repro.spec import CostReport, JobSpec, PhaseBreakdown, hadoop_space

P = HadoopParams(pNumNodes=8, pNumMappers=64, pNumReducers=16, pSplitSize=128 * MiB)
S = ProfileStats(sMapSizeSel=0.8, sReduceSizeSel=0.5)
C = CostFactors()

# every mapreduce.JOBS profile as a (name, params, stats, costs) tuple
PROFILES = [(jc.name, jc.params, jc.stats, jc.costs)
            for jc in default_job_classes()]

SWEEP = {
    "pSortMB": np.array([0.25, 25.0, 50.0, 100.0, 400.0]),
    "pSortFactor": np.array([3.0, 5.0, 10.0, 25.0, 50.0]),
    "pNumReducers": np.array([0.0, 4.0, 8.0, 16.0, 64.0]),
}

# numSpills >> pSortFactor**2 everywhere -> closed-form merge math invalid
INVALID = {"pSortMB": np.array([0.25, 0.5]), "pSortFactor": np.array([2.0, 2.0])}


# ------------------------------------------------------------------
# JobSpec <-> pack_config round trip
# ------------------------------------------------------------------


@pytest.mark.parametrize("name,p,s,c", PROFILES)
def test_jobspec_pack_is_pack_config(name, p, s, c):
    spec = JobSpec(p, s, c, name=name)
    flat, ref = spec.pack(), pack_config(p, s, c)
    assert list(flat) == CONFIG_KEYS == list(ref)
    for k in ref:
        assert np.array_equal(np.asarray(flat[k]), np.asarray(ref[k])), k


@pytest.mark.parametrize("name,p,s,c", PROFILES)
def test_jobspec_round_trip(name, p, s, c):
    spec = JobSpec(p, s, c)
    back = JobSpec.from_flat({k: float(v) for k, v in spec.pack().items()})
    assert back == spec
    # int/bool fields come back as their typed selves, not floats
    assert isinstance(back.params.pSortFactor, int)
    assert isinstance(back.params.pUseCombine, bool)


def test_jobspec_replace_routes_and_coerces():
    spec = JobSpec(P, S, C).replace(
        pSortMB=200.0, pSortFactor=25.4, pUseCombine=1.0, sMapSizeSel=0.5)
    assert spec.params.pSortMB == 200.0
    assert spec.params.pSortFactor == 25 and isinstance(
        spec.params.pSortFactor, int)
    assert spec.params.pUseCombine is True
    assert spec.stats.sMapSizeSel == 0.5
    assert spec["pSortFactor"] == 25
    with pytest.raises(KeyError, match="unknown config key"):
        spec.replace(notAKey=1.0)


def test_jobspec_is_pytree_and_hashable():
    import jax

    spec = JobSpec(P, S, C, name="wc")
    leaves, treedef = jax.tree_util.tree_flatten(spec)
    assert len(leaves) == len(CONFIG_KEYS)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back == spec and back.name == "wc"
    doubled = jax.tree_util.tree_map(lambda x: x * 2, spec)
    assert doubled.params.pNumMappers == 2 * P.pNumMappers
    assert hash(spec) == hash(JobSpec(P, S, C, name="wc"))


# ------------------------------------------------------------------
# typed path == dict path, bit for bit, over all JOBS profiles
# ------------------------------------------------------------------


@pytest.mark.parametrize("name,p,s,c", PROFILES)
def test_typed_evaluator_bit_for_bit(name, p, s, c):
    spec = JobSpec(p, s, c, name=name)
    ev_typed = ChunkedEvaluator.from_spec(spec, chunk=16)
    ev_dict = ChunkedEvaluator(p, s, c, chunk=16)
    rt, rd = ev_typed.evaluate(SWEEP), ev_dict.evaluate(SWEEP)
    assert set(rt.outputs) == set(rd.outputs)
    for k in rd.outputs:
        assert np.array_equal(rt.outputs[k], rd.outputs[k]), k
    assert np.array_equal(rt.total_cost, rd.total_cost)
    # the typed report's aggregates are the dict arrays, not a recomputation
    rep = ev_typed.report(SWEEP)
    assert np.array_equal(np.asarray(rep.total_cost), rd.outputs["j_totalCost"])
    assert np.array_equal(np.asarray(rep.io_cost), rd.outputs["j_ioJobCost"])
    assert np.array_equal(np.asarray(rep.cpu_cost), rd.outputs["j_cpuJobCost"])
    assert np.array_equal(np.asarray(rep.valid), rd.outputs["valid"])


@pytest.mark.parametrize("name,p,s,c", PROFILES)
def test_phase_breakdown_sums_to_total(name, p, s, c):
    """PhaseBreakdown fields sum to j_totalCost (Eqs. 96-98)."""
    rep = ChunkedEvaluator.from_spec(JobSpec(p, s, c), chunk=16).report(SWEEP)
    np.testing.assert_allclose(
        np.asarray(rep.phases.total()), np.asarray(rep.total_cost), rtol=1e-12)


def test_phase_breakdown_sums_property():
    """Same invariant under randomized configurations (hypothesis)."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    spec0 = JobSpec(P, S, C)

    @settings(max_examples=30, deadline=None)
    @given(
        sort_mb=st.floats(0.25, 512.0),
        factor=st.integers(2, 100),
        reducers=st.integers(0, 128),
        mappers=st.integers(1, 256),
        combine=st.booleans(),
        compress=st.booleans(),
    )
    def check(sort_mb, factor, reducers, mappers, combine, compress):
        cfg = spec0.replace(
            pSortMB=sort_mb, pSortFactor=factor, pNumReducers=reducers,
            pNumMappers=mappers, pUseCombine=combine,
            pIsIntermCompressed=compress,
        ).pack()
        out = {k: np.asarray(v) for k, v in job_model_jnp(cfg).items()}
        rep = CostReport.from_outputs(out, cfg)
        np.testing.assert_allclose(
            float(rep.phases.total()), float(out["j_totalCost"]), rtol=1e-12)

    check()


def test_costreport_is_a_vmappable_pytree():
    import jax
    import jax.numpy as jnp

    base = JobSpec(P, S, C).pack()

    def rep_fn(sort_mb):
        cfg = dict(base)
        cfg["pSortMB"] = sort_mb
        return CostReport.from_outputs(job_model_jnp(cfg), cfg)

    vals = jnp.asarray([25.0, 50.0, 100.0])
    batched = jax.vmap(rep_fn)(vals)
    assert isinstance(batched, CostReport)
    assert batched.total_cost.shape == (3,)
    for i, v in enumerate(vals):
        single = rep_fn(v)
        np.testing.assert_array_equal(
            np.asarray(batched.phases.shuffle)[i], np.asarray(single.phases.shuffle))
    # equation metadata is attached to the fields
    assert PhaseBreakdown.eq("shuffle") == "Eqs. 35-61"
    assert "Eq" in PhaseBreakdown.describe("map_merge")


# ------------------------------------------------------------------
# disaggregated validity
# ------------------------------------------------------------------


def test_report_says_which_constraint_failed():
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    rep = ev.report(INVALID)
    assert np.all(np.asarray(rep.valid) == 0)
    assert np.all(np.asarray(rep.merge_valid) == 0)
    reasons = rep.invalid_reasons(0)
    assert any("mapMerge" in r for r in reasons)
    with pytest.raises(InvalidGridError, match="mapMerge"):
        rep.best()


def test_maponly_rows_do_not_fail_reduce_constraints():
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    rep = ev.report({"pNumReducers": np.array([0.0, 0.0]),
                     "pSortMB": np.array([50.0, 100.0])})
    # the model zeroes r_* flags for map-only jobs; the report must not
    # read that as a failed reduce-side constraint
    assert np.all(np.asarray(rep.shuffle_valid) == 1)
    assert np.all(np.asarray(rep.sort_valid) == 1)
    assert rep.invalid_reasons() == []


def test_topk_accumulates_reason_counts():
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    res = search_topk(ev, {k: list(v) for k, v in INVALID.items()},
                      k=1, exact_fallback=True)
    assert res.invalid_reason_counts.get("mapMerge", 0) > 0


def test_base_chunk_topk_reason_counts_match_device_path():
    """The numpy (base Evaluator) and on-device (ChunkedEvaluator) reason
    counts agree — including the reduce-side gating for map-only rows,
    whose r_* flags the model zeroes."""
    from repro.search.evaluator import Evaluator, SearchResult, evaluate_unchunked

    class Plain(Evaluator):
        def __init__(self, p, s, c):
            self.base_cfg = ChunkedEvaluator(p, s, c).base_cfg

        def evaluate(self, overrides):
            out = evaluate_unchunked(self.base_cfg, overrides)
            return SearchResult(
                overrides={k: np.asarray(v) for k, v in overrides.items()},
                outputs=out, total_cost=masked_total(out, "j_totalCost"))

    # map-only rows that are ALSO merge-invalid: only mapMerge may be counted
    rows = {"pSortMB": np.array([0.25, 0.25, 100.0]),
            "pSortFactor": np.array([2.0, 2.0, 10.0]),
            "pNumReducers": np.array([0.0, 0.0, 0.0])}
    plain = Plain(P, S, C).chunk_topk(rows, k=3)
    dev = ChunkedEvaluator(P, S, C, chunk=4).chunk_topk(rows, k=3)
    assert plain.reason_counts == dev.reason_counts == {"mapMerge": 2}


def test_exact_fallback_log_names_the_constraint(caplog):
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    from repro.search import WhatIfService

    with caplog.at_level(logging.INFO, logger="repro.search.service"):
        with WhatIfService(ev) as svc:
            r = svc.probe({"pSortMB": 0.25, "pSortFactor": 2.0},
                          exact_fallback=True).result()
    assert r.exact.all() and np.isfinite(r.total_cost).all()
    msgs = [rec.getMessage() for rec in caplog.records
            if "exact fallback" in rec.getMessage()]
    assert msgs and any("mapMerge" in m for m in msgs)


# ------------------------------------------------------------------
# hoisted sanitization helpers
# ------------------------------------------------------------------


def test_sanitize_and_masked_total_helpers():
    raw = np.array([1.0, np.nan, np.inf, -np.inf])
    assert np.array_equal(sanitize_costs(raw), [1.0, np.inf, np.inf, np.inf])
    out = {"valid": np.array([1.0, 0.0]), "cost": np.array([3.0, 4.0])}
    assert np.array_equal(masked_total(out, "cost"), [3.0, np.inf])


# ------------------------------------------------------------------
# declared param spaces
# ------------------------------------------------------------------


def test_hadoop_space_matches_config_keys_and_coerces():
    space = hadoop_space()
    assert list(space.names) == CONFIG_KEYS
    assert space["pSortFactor"].kind == "int"
    assert space["pUseCombine"].kind == "bool"
    assert space["pSortMB"].unit == "MB"
    assert space["cMapCPUCost"].table == "Table 3"
    assert space.coerce("pSortFactor", 9.6) == 10
    assert space.coerce("pUseCombine", 0.9) is True
    with pytest.raises(ValueError, match="outside domain"):
        space.grid({"pSortFactor": [1.0]})      # below the merge minimum
    with pytest.raises(KeyError, match="unknown config key"):
        space.grid({"pNope": [1.0]})
    g = space.grid({"pSortMB": [25, 50]})
    assert g["pSortMB"].dtype == np.float64


def test_cluster_mask_is_the_declared_axis_rule():
    ev = ClusterEvaluator(default_job_classes(names=["filter"]),
                          n_jobs=4, n_seeds=1, chunk=8)
    ov = {
        "pNumNodes": np.array([0.0, 1.0, 4.0, 2.0]),
        "pMaxMapsPerNode": np.array([2.0, 0.0, 2.0, 2.0]),
        "arrivalRate": np.array([0.1, 0.1, 0.0, 0.1]),
    }
    res = ev.evaluate(ov)
    manual = ((np.round(ov["pNumNodes"]) >= 1)
              & (np.round(ov["pMaxMapsPerNode"]) >= 1)
              & (ov["arrivalRate"] > 0))
    # knob-invalid rows are exactly the declared-axis violations (a valid
    # knob row can still be invalid if the rollout did not converge)
    assert not res.outputs["valid"][~manual].any()
    assert list(cluster_space().names) == list(ev.base_cfg)
    mask, reasons = cluster_space().validity_mask(ov)
    assert np.array_equal(mask, manual)
    assert not reasons["pNumNodes bounds"][0]
    assert not reasons["arrivalRate bounds"][2]


def test_tpu_space_predicates_name_the_failure():
    pytest.importorskip("repro.configs")
    from repro.configs import SHAPES, get_config
    from repro.search.tpu import TpuEvaluator

    ev = TpuEvaluator(get_config("gemma2-9b"), SHAPES["train_4k"], n_chips=256)
    mask, reasons = ev.param_space.validity_mask(
        {"dp": np.array([16.0, 3.0]), "tp": np.array([16.0, 4.0]),
         "n_micro": np.array([2.0, 1.0])})
    assert mask[0] and not mask[1]
    assert not reasons["chipBudget"][1]


# ------------------------------------------------------------------
# the repro.api facade
# ------------------------------------------------------------------


def test_api_model_and_sweep_match_dict_path():
    spec = JobSpec(P, S, C)
    rep = api.sweep(spec, SWEEP)
    ref = ChunkedEvaluator(P, S, C).evaluate(SWEEP)
    assert np.array_equal(np.asarray(rep.total_cost), ref.outputs["j_totalCost"])
    one = api.model(spec, {"pSortMB": 100.0, "pSortFactor": 10.0})
    assert isinstance(one, CostReport)
    assert np.asarray(one.total_cost).shape == (1,)
    assert "hadoop" in api.available_models()
    assert {"tpu", "cluster"} <= set(api.available_models())


def test_api_tune_validates_space_against_axes():
    spec = JobSpec(P, S, C)
    res = api.tune(spec, {"pSortMB": [25.0, 50.0, 100.0]}, strategy="descent")
    assert np.isfinite(res.best_cost)
    with pytest.raises(KeyError, match="unknown config key"):
        api.tune(spec, {"pBogus": [1.0]})
    with pytest.raises(ValueError, match="outside domain"):
        api.tune(spec, {"pSortFactor": [0.0, 10.0]})
    with pytest.raises(ValueError, match="boolean"):
        api.tune(spec, {"pUseCombine": [0.0, 2.0]})


def test_api_get_evaluator_passthrough_and_errors():
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    assert api.get_evaluator(ev) is ev
    with pytest.raises(TypeError, match="already-built"):
        api.get_evaluator(ev, chunk=16)
    with pytest.raises(KeyError, match="unknown cost model"):
        api.get_evaluator("nope")
    cl = api.get_evaluator(
        "cluster", classes=default_job_classes(names=["filter"]),
        n_jobs=4, n_seeds=1, chunk=8)
    assert cl.cost_key == "w_p95Lat"


def test_phase_query_end_to_end_through_the_facade():
    """Minimize shuffle time subject to a total-cost budget, via the async
    service — the acceptance-criteria query."""
    spec = JobSpec(P, S, C)
    rows = {
        "pSortMB": np.array([25.0, 50.0, 100.0, 200.0, 400.0]),
        "pNumReducers": np.array([4.0, 8.0, 16.0, 32.0, 64.0]),
    }
    oracle = api.sweep(spec, rows)
    total = np.asarray(oracle.total_cost)
    budget = float(np.percentile(total, 60))
    feas = (np.asarray(oracle.valid) > 0) & (total <= budget)
    assert feas.any() and not feas.all()
    shuffle = np.where(feas, np.asarray(oracle.phases.shuffle), np.inf)
    want_i = int(np.argmin(shuffle))

    with api.serve(spec) as svc:
        pr = svc.phase_query(rows, phase="shuffle", total_max=budget).result()
    i, cost, assignment = pr.best()
    assert i == want_i
    assert cost == float(shuffle[want_i])           # bit-for-bit, not approx
    assert assignment["pSortMB"] == rows["pSortMB"][want_i]
    np.testing.assert_array_equal(pr.objective, np.asarray(oracle.phases.shuffle))
    # unknown phases and constraint-infeasible queries fail intelligibly
    with pytest.raises(KeyError, match="unknown phase"):
        svc.phase_query(rows, phase="nope")
    with api.serve(spec) as svc:
        pr2 = svc.phase_query(rows, phase="shuffle", total_max=0.0).result()
        with pytest.raises(InvalidGridError, match="no feasible"):
            pr2.best()
