"""TPU analytical step model (paper-methodology adaptation): structural
invariants + agreement with the compiled dry-run where artifacts exist."""

import glob
import json
import os

import pytest

from repro.configs import SHAPES, get_config
from repro.core.tpu_model import TpuCostFactors, TpuParams, step_model


def test_train_cost_decreases_with_more_chips():
    cfg = get_config("granite-3-8b")
    shape = SHAPES["train_4k"]
    small = step_model(cfg, shape, TpuParams(dp=8, tp=8, n_micro=8))
    big = step_model(cfg, shape, TpuParams(dp=32, tp=16, n_micro=8))
    assert big.compute_s < small.compute_s


def test_backward_roughly_doubles_forward():
    cfg = get_config("stablelm-1.6b")
    train = step_model(cfg, SHAPES["train_4k"], TpuParams(remat=False))
    fwd_fl = sum(p.flops for p in train.phases if not p.name.startswith(("bwd_", "optimizer")))
    bwd_fl = sum(p.flops for p in train.phases if p.name.startswith("bwd_"))
    assert 1.8 <= bwd_fl / fwd_fl <= 2.2


def test_remat_adds_recompute():
    cfg = get_config("stablelm-1.6b")
    base = step_model(cfg, SHAPES["train_4k"], TpuParams(remat=False))
    remat = step_model(cfg, SHAPES["train_4k"], TpuParams(remat=True))
    assert remat.compute_s > base.compute_s
    assert remat.compute_s < 1.6 * base.compute_s


def test_moe_shuffle_appears_with_ep():
    cfg = get_config("deepseek-moe-16b")
    m = step_model(cfg, SHAPES["train_4k"], TpuParams(ep=16))
    names = [p.name for p in m.phases]
    assert "moe_shuffle" in names
    no_ep = step_model(cfg, SHAPES["train_4k"], TpuParams(ep=1))
    assert "moe_shuffle" not in [p.name for p in no_ep.phases]


def test_decode_is_memory_bound():
    cfg = get_config("granite-3-8b")
    m = step_model(cfg, SHAPES["decode_32k"], TpuParams(n_micro=1))
    assert m.bound in ("memory", "collective")
    assert m.memory_s > m.compute_s


def test_efficiency_factors_scale_terms():
    cfg = get_config("gemma2-9b")
    base = step_model(cfg, SHAPES["train_4k"], TpuParams())
    fitted = step_model(
        cfg, SHAPES["train_4k"], TpuParams(),
        TpuCostFactors(eff_memory=10.0),
    )
    assert fitted.memory_s == pytest.approx(10.0 * base.memory_s)
    assert fitted.compute_s == pytest.approx(base.compute_s)


_ARTS = sorted(glob.glob("artifacts/dryrun/*__train_4k__single.json"))


@pytest.mark.skipif(not _ARTS, reason="no dry-run artifacts")
def test_compute_term_tracks_dryrun_for_dense_archs():
    """E9 core claim: for dense architectures the analytical compute term
    matches the compiled dry-run within 2x (it is within ~20% for most)."""
    checked = 0
    for f in _ARTS:
        cell = json.load(open(f))
        if cell.get("status") != "ok":
            continue
        cfg = get_config(cell["arch"])
        if cfg.n_experts or "ssm" in cfg.layer_pattern or "rglru" in cfg.layer_pattern:
            continue  # documented divergences (dense-MoE waste, scan archs)
        shape = SHAPES[cell["shape"]]
        m = step_model(
            cfg, shape,
            TpuParams(dp=16, tp=16, n_micro=cell.get("n_microbatches", 8)),
        )
        meas = cell["roofline"]["compute_s"]
        # includes starcoder2: the divisibility-aware model charges the
        # replicated 36-head attention (pred/meas = 1.05 at tp=16)
        assert 0.5 < m.compute_s / meas < 2.0, (cell["arch"], m.compute_s, meas)
        checked += 1
    assert checked >= 4
