"""Shared test configuration.

float64 is enabled globally so the JAX job model matches the pure-Python
float64 oracle bit-for-bit in the equivalence property tests; neural-net
code paths pin their own dtypes explicitly and are unaffected.

NOTE: XLA_FLAGS / device-count forcing is deliberately NOT set here — tests
and benches must see the single real CPU device.  Only ``launch/dryrun.py``
forces 512 placeholder devices, in its own process.
"""

import jax

jax.config.update("jax_enable_x64", True)
