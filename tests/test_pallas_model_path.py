"""End-to-end: the model with attention_impl='pallas' (interpret mode)
matches the XLA attention path on forward, prefill and decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention, lm


@pytest.fixture(autouse=True)
def _reset_impl():
    yield
    attention.set_attention_impl("xla")


def _run_paths(cfg, tokens, fn):
    attention.set_attention_impl("xla")
    ref = fn()
    attention.set_attention_impl("pallas")
    out = fn()
    return ref, out


@pytest.mark.parametrize("arch", ["gemma2-9b", "stablelm-1.6b"])
def test_forward_pallas_vs_xla(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(0)
    params = lm.init(key, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)

    def fwd():
        logits, _, _ = lm.forward(params, cfg, tokens)
        return logits

    ref, out = _run_paths(cfg, tokens, fwd)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_prefill_decode_pallas_vs_xla():
    cfg = get_config("gemma2-9b").smoke()   # exercises local ring + softcap
    key = jax.random.PRNGKey(2)
    params = lm.init(key, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 48), 0, cfg.vocab_size)
    max_len = 64

    def serve():
        logits, caches, pos = lm.prefill(params, cfg, tokens, max_len)
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        logits2, _ = lm.decode_step(params, cfg, nxt, caches, pos)
        return logits, logits2

    ref, out = _run_paths(cfg, tokens, serve)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-3, atol=2e-3,
        )
