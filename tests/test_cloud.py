"""repro.cloud — priced fleets, spot reclamation, autoscaling, $-search."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cloud import (
    AUTOSCALE_POLICIES,
    CloudEvaluator,
    ElasticFleet,
    SloUnmetError,
    bill_workload,
    cloud_space,
    dollars_for,
    pareto_front,
    spot_inflation,
    wave_columns,
)
from repro.cluster import (
    ClusterConfig,
    NodeClass,
    default_job_classes,
    latency_quantile,
    pack_trace,
    poisson_trace,
    rescale,
    simulate_batch,
    simulate_workload,
)
from repro.cluster.workload import _PROFILES
from repro.core.hadoop.simulator import SimConfig
from repro.obs import percentile_interp
from repro.spec import ProvisioningReport

CLEAN = SimConfig(speculative_execution=False)
PRICE = 0.36


# ---------------------------------------------------------------- pricing


def test_spot_inflation_semantics():
    # rate 0 (on-demand) is exactly 1; positive rates inflate monotonically
    assert float(spot_inflation(0.0, 30.0)) == 1.0
    lo = float(spot_inflation(1e-4, 30.0))
    hi = float(spot_inflation(1e-2, 30.0))
    assert 1.0 < lo < hi
    # the closed form: E[wall] = (e^{lam d} - 1) / lam
    lam, d = 3e-3, 45.0
    assert np.isclose(float(spot_inflation(lam, d)) * d,
                      np.expm1(lam * d) / lam, rtol=1e-12)
    # the double-where guard: grad is finite across the rate=0 boundary
    g = jax.grad(lambda r: spot_inflation(r, 30.0))(0.0)
    assert np.isfinite(float(g))


def test_dollars_for_quantum_and_grad():
    # 2 nodes x $0.30/h for 30 min = $0.30
    assert np.isclose(float(dollars_for(1800.0, [2.0], [0.30])), 0.30,
                      rtol=1e-12)
    # hour-granularity billing rounds the span up
    assert np.isclose(
        float(dollars_for(1800.0, [2.0], [0.30], billing_quantum=3600.0)),
        0.60, rtol=1e-12)
    # a concrete zero quantum keeps the path ceil-free and differentiable
    g = jax.grad(lambda s: dollars_for(s, jnp.ones(2), jnp.full(2, 0.4)))(
        1800.0)
    assert np.isclose(float(g), 2 * 0.4 / 3600.0, rtol=1e-12)


def test_elastic_fleet_validation():
    assert ElasticFleet(policy="queue", max_extra_nodes=2).policy_code == 1
    assert AUTOSCALE_POLICIES[0] == "off"
    with pytest.raises(ValueError):
        ElasticFleet(policy="bogus")
    with pytest.raises(ValueError):
        ElasticFleet(reclaim_rate=-1.0)
    with pytest.raises(ValueError):
        NodeClass(2, hourly_price=-0.1)


def test_pareto_front_mask():
    costs = np.array([1.0, 2.0, 3.0, 2.5, np.inf])
    qual = np.array([5.0, 3.0, 1.0, 1.0, 0.0])
    keep = pareto_front(costs, qual)
    # (3.0, 1.0) dominates (inf, .) trivially; (2.5, 1.0) dominates (3.0, 1.0)
    assert keep.tolist() == [True, True, False, True, False]


# ---------------------------------------------- degenerate-pricing property


@pytest.mark.parametrize("profile", sorted(_PROFILES))
def test_degenerate_pricing_closed_form(profile):
    """Zero spot, autoscaler off, zero provisioning latency: dollars_per_job
    == makespan * fleet_size * hourly_price / n_jobs exactly, on both
    simulator backends."""
    classes = default_job_classes(names=[profile])
    n_jobs, n, rate = 10, 4, 0.05
    tr = poisson_trace(classes, n_jobs, seed=3)
    ev = CloudEvaluator(classes, traces=[tr], base=ClusterConfig(num_nodes=n),
                        base_rate=rate, on_demand_price=PRICE, sim=CLEAN,
                        chunk=8)

    # DES side: bill the recorded episodes of the same cluster exact_cost
    # builds, against the closed form over its makespan
    cc = ClusterConfig(num_nodes=n,
                       node_classes=(NodeClass(n, 1.0, PRICE, spot=False),))
    res = simulate_workload(rescale(tr, rate), cc, CLEAN)
    want_des = res.makespan * n * PRICE / 3600.0 / n_jobs
    assert np.isclose(ev.exact_cost({"pOnDemandNodes": n, "pSpotNodes": 0}),
                      want_des, rtol=1e-12)
    assert np.isclose(bill_workload(res, cc, window=(0.0, res.makespan)),
                      want_des * n_jobs, rtol=1e-12)

    # wave side: the evaluator's dollars against the closed form over the
    # wave rollout's own makespan
    cols = pack_trace(tr)
    scen = {
        "arrival": (cols["arrival"] / rate)[None, :],
        "n_maps": cols["n_maps"][None, :],
        "n_reds": cols["n_reds"][None, :],
        "map_cost": cols["map_cost"][None, :],
        "red_work": cols["red_work"][None, :],
        "shuffle": (cols["shuffle"] * (n - 1) / n)[None, :],
        "queue": cols["queue"][None, :],
        "map_slots": np.array([float(n * cc.map_slots_per_node)]),
        "red_slots": np.array([float(n * cc.reduce_slots_per_node)]),
        "speedup": np.ones(1),
        "policy": np.zeros(1),
        "slowstart": np.array([cc.reduce_slowstart]),
    }
    span_w = float(np.asarray(simulate_batch(scen)["makespan"])[0])
    r = ev.evaluate({"pOnDemandNodes": np.array([float(n)]),
                     "pSpotNodes": np.array([0.0])})
    want_wave = span_w * n * PRICE / 3600.0 / n_jobs
    assert np.isclose(float(r.outputs["c_dollarsPerJob"][0]), want_wave,
                      rtol=1e-12)
    assert np.isclose(float(r.outputs["c_dollarMakespan"][0]),
                      want_wave * n_jobs, rtol=1e-12)
    assert r.outputs["valid"][0] == 1.0
    assert r.outputs["c_sloAttain"][0] == 1.0


# ------------------------------------------------------- percentile unification


def test_latency_quantile_matches_percentile_interp():
    rng = np.random.default_rng(0)
    vals = np.sort(rng.exponential(10.0, size=23))
    for q in (0.0, 12.5, 37.0, 50.0, 95.0, 100.0):
        assert np.isclose(float(latency_quantile(jnp.asarray(vals), q)),
                          percentile_interp(vals.tolist(), q), rtol=1e-12)
        # and both match numpy's linear interpolation rule
        assert np.isclose(percentile_interp(vals.tolist(), q),
                          float(np.percentile(vals, q)), rtol=1e-9)
    # small-sample rules
    assert float(latency_quantile(jnp.asarray([7.5]), 95.0)) == 7.5
    assert float(latency_quantile(jnp.zeros((0,)), 95.0)) == 0.0
    # equal-neighbour interpolation between two infs would be inf - inf =
    # nan without the double-where guard; it must report inf instead
    inf_pair = jnp.asarray([1.0, jnp.inf, jnp.inf])
    assert float(latency_quantile(inf_pair, 95.0)) == np.inf
    assert not np.isnan(float(latency_quantile(inf_pair, 50.0)))
    assert not np.isnan(float(latency_quantile(inf_pair, 25.0)))


def test_workload_result_p95_uses_shared_rule():
    classes = default_job_classes()
    tr = poisson_trace(classes, 12, seed=5)
    res = simulate_workload(rescale(tr, 0.1), ClusterConfig(num_nodes=4),
                            CLEAN)
    lats = np.sort(res.latencies())
    assert np.isclose(res.p95_latency, percentile_interp(lats.tolist(), 95.0),
                      rtol=1e-12)
    assert np.isclose(res.latency_quantile(50.0),
                      float(np.percentile(lats, 50.0)), rtol=1e-9)


# ------------------------------------------------------------ DES elasticity


def test_spot_reclaim_kills_and_requeues():
    classes = default_job_classes()
    tr = poisson_trace(classes, 8, seed=2)
    cc = ClusterConfig(num_nodes=4, node_classes=(
        NodeClass(2, 1.0, 0.10, spot=True), NodeClass(2, 1.0, 0.40)))
    el = ElasticFleet(reclaim_rate=0.05, provision_latency=10.0, seed=1)
    res = simulate_workload(rescale(tr, 0.1), cc, CLEAN, elastic=el)
    assert res.n_unfinished == 0
    assert res.num_reclaimed > 0
    reasons = {r.kill_reason for r in res.records if r.killed}
    assert "reclaim" in reasons
    # reclaimed spot nodes cycle offline/online: multiple capacity episodes
    assert any(len(eps) > 1 for eps in res.node_online[:2])
    # on-demand nodes never reclaim: one episode covering the whole run
    assert all(len(eps) == 1 for eps in res.node_online[2:4])


def test_fixed_fleet_untouched_by_pricing_metadata():
    # prices/spot flags without an elastic fleet replay bit-identically
    classes = default_job_classes()
    tr = rescale(poisson_trace(classes, 10, seed=4), 0.1)
    plain = simulate_workload(tr, ClusterConfig(num_nodes=4), CLEAN)
    priced = simulate_workload(
        tr, ClusterConfig(num_nodes=4, node_classes=(
            NodeClass(4, 1.0, PRICE, spot=True),)), CLEAN)
    assert plain.makespan == priced.makespan
    assert np.array_equal(plain.latencies(), priced.latencies())
    assert priced.num_reclaimed == 0


def test_autoscaler_queue_policy_adds_capacity():
    classes = default_job_classes()
    tr = rescale(poisson_trace(classes, 12, seed=6), 0.5)  # contended
    cc = ClusterConfig(num_nodes=2)
    el = ElasticFleet(policy="queue", max_extra_nodes=2, high_water=2.0,
                      provision_latency=5.0)
    fixed = simulate_workload(tr, cc, CLEAN)
    scaled = simulate_workload(tr, cc, CLEAN, elastic=el)
    assert scaled.n_unfinished == 0
    # the extra nodes exist, came online after the provision latency, and
    # record billable episodes
    assert len(scaled.node_online) == 4
    extra_eps = [e for eps in scaled.node_online[2:] for e in eps]
    assert extra_eps and all(s >= el.provision_latency for s, _ in extra_eps)
    assert scaled.makespan <= fixed.makespan + 1e-9
    # some task actually ran on an autoscaled node
    assert any(r.node >= 2 for r in scaled.records)


def test_predicted_policy_provisions_up_front():
    classes = default_job_classes()
    tr = rescale(poisson_trace(classes, 8, seed=7), 0.5)
    el = ElasticFleet(policy="predicted", max_extra_nodes=2,
                      provision_latency=3.0)
    res = simulate_workload(tr, ClusterConfig(num_nodes=2), CLEAN, elastic=el)
    starts = [s for eps in res.node_online[2:] for s, _ in eps]
    assert starts and np.isclose(min(starts), 3.0, atol=1e-9)


# ------------------------------------------------------------- the evaluator


def _small_ev(**kw):
    classes = default_job_classes()
    kw.setdefault("n_jobs", 8)
    kw.setdefault("n_seeds", 1)
    kw.setdefault("chunk", 8)
    kw.setdefault("sim", CLEAN)
    return CloudEvaluator(classes, **kw)


def test_cloud_space_predicates():
    ev = _small_ev()
    r = ev.evaluate({
        "pOnDemandNodes": np.array([2.0, 0.0, 0.0]),
        "pSpotNodes": np.array([0.0, 0.0, 0.0]),
        "spotReclaimRate": np.array([0.0, 0.0, 1e-3]),
    })
    # empty fleet and reclaim-without-spot are masked, not silently costed
    assert r.outputs["valid"].tolist() == [1.0, 0.0, 0.0]
    assert np.isinf(r.total_cost[1]) and np.isinf(r.total_cost[2])
    names = list(cloud_space().names)
    assert names.index("pOnDemandNodes") == 0 and "sloLatency" in names


def test_wave_dollars_match_des_dollars_contention_free():
    # light load, no reclamation: the two backends bill the same window
    ev = _small_ev(base_rate=0.02, on_demand_price=PRICE, spot_price=0.09)
    r = ev.evaluate({"pOnDemandNodes": np.array([2.0]),
                     "pSpotNodes": np.array([2.0])})
    exact = ev.exact_cost({"pOnDemandNodes": 2, "pSpotNodes": 2})
    assert np.isclose(float(r.outputs["c_dollarsPerJob"][0]), exact,
                      rtol=1e-3)


def test_cloud_evaluator_through_strategies():
    from repro.search import (
        coordinate_descent_ev,
        grid_search_ev,
        random_search_ev,
    )

    ev = _small_ev(slo_target=0.5)
    space = {"pOnDemandNodes": [1.0, 2.0, 4.0], "pSpotNodes": [0.0, 2.0]}
    best = grid_search_ev(ev, space)
    cost, assign = best.best_cost, best.best_assignment
    assert np.isfinite(cost) and assign["pOnDemandNodes"] >= 1.0
    r2 = random_search_ev(ev, space, samples=4, seed=0)
    assert np.isfinite(r2.best_cost)
    r3 = coordinate_descent_ev(ev, space)
    assert r3.best_cost <= cost + 1e-9
    # spot capacity is strictly cheaper here (no reclamation, lower price)
    full = ev.evaluate({"pOnDemandNodes": np.array([4.0, 2.0]),
                        "pSpotNodes": np.array([0.0, 2.0])})
    assert full.total_cost[1] < full.total_cost[0]


def test_cloud_evaluator_exact_cost_contract():
    ev = _small_ev()
    # invalid assignment resolves to inf, unknown keys raise
    assert ev.exact_cost({"pOnDemandNodes": 0, "pSpotNodes": 0}) == np.inf
    with pytest.raises(KeyError):
        ev.exact_cost({"nope": 1.0})
    # an unreachable SLO raises the typed ExactCostUnavailable subclass
    with pytest.raises(SloUnmetError):
        ev.exact_cost({"pOnDemandNodes": 2, "sloLatency": 1e-6})


def test_cloud_evaluator_grad_objective_not_differentiable():
    from repro.search.evaluator import NotDifferentiableError

    with pytest.raises(NotDifferentiableError):
        _small_ev().grad_objective()


def test_whatif_service_and_api_facade():
    import repro.api as api
    from repro.search import WhatIfService

    assert "cloud" in api.available_models()
    ev = api.get_evaluator("cloud", n_jobs=8, n_seeds=1, chunk=8, sim=CLEAN)
    assert isinstance(ev, CloudEvaluator)
    with WhatIfService(ev) as svc:
        fut = svc.sweep("pOnDemandNodes", [1.0, 2.0, 4.0])
        res = fut.result(timeout=60)
    assert np.isfinite(res.total_cost).any()
    rep = api.sweep(ev, {"pOnDemandNodes": [1.0, 2.0]})
    assert isinstance(rep, ProvisioningReport)
    assert np.asarray(rep.dollars_per_job).shape == (2,)


def test_provisioning_report_is_a_pytree():
    ev = _small_ev()
    rep = ev.report({"pOnDemandNodes": np.array([1.0, 2.0, 4.0])})
    leaves, treedef = jax.tree_util.tree_flatten(rep)
    assert len(leaves) == 7
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert np.array_equal(np.asarray(back.dollars_per_job),
                          np.asarray(rep.dollars_per_job))
    # cheaper fleets cost less per job; utilization stays a fraction
    dpj = np.asarray(rep.dollars_per_job)
    assert dpj[0] < dpj[2]
    assert np.all((np.asarray(rep.utilization) >= 0)
                  & (np.asarray(rep.utilization) <= 1.0 + 1e-9))


def test_wave_columns_helper():
    cc = ClusterConfig(num_nodes=4, node_classes=(
        NodeClass(2, 1.0, 0.10, spot=True), NodeClass(2, 1.0, 0.40)))
    el = ElasticFleet(policy="queue", max_extra_nodes=2, high_water=1.0,
                      reclaim_rate=2e-3, billing_quantum=60.0)
    colsd = wave_columns(el, cc)
    assert colsd["reclaim_rate"].tolist() == [2e-3, 0.0]
    assert colsd["autoscale"] == 1.0
    assert colsd["extra_map_slots"] == 2 * cc.map_slots_per_node
    assert colsd["billing_quantum"] == 60.0
    off = wave_columns(ElasticFleet(), cc)
    assert off["extra_map_slots"] == 0.0 and off["autoscale"] == 0.0


# ------------------------------------------------------------- observability


def test_destrace_renders_reclaims_and_spend():
    from repro.obs.destrace import workload_trace

    classes = default_job_classes()
    tr = rescale(poisson_trace(classes, 8, seed=2), 0.1)
    cc = ClusterConfig(num_nodes=4, node_classes=(
        NodeClass(2, 1.0, 0.10, spot=True), NodeClass(2, 1.0, 0.40)))
    el = ElasticFleet(policy="queue", max_extra_nodes=1, high_water=2.0,
                      provision_latency=5.0, reclaim_rate=0.05, seed=1)
    res = simulate_workload(tr, cc, CLEAN, elastic=el)
    assert res.num_reclaimed > 0
    tracer = workload_trace(tr, res, cc)
    evs = tracer.events()
    instants = {e["name"] for e in evs if e.get("ph") == "i"}
    assert "reclaim" in instants          # distinct from preempt/failure
    assert "provisioned" in instants
    counters = {e["name"] for e in evs if e.get("ph") == "C"}
    assert "fleet" in counters and "spend" in counters
    # the spend track is cumulative and ends at the workload's exact bill
    spend = [e["args"]["dollars"] for e in evs
             if e.get("ph") == "C" and e["name"] == "spend"]
    assert spend == sorted(spend)
    want = bill_workload(res, cc, elastic=el, window=(0.0, res.makespan))
    assert np.isclose(spend[-1], want, rtol=1e-9)
