"""Task Scheduler Simulator (paper §5(i)) — behaviour tests."""

import pytest

from repro.core.hadoop import (
    CostFactors,
    HadoopParams,
    MiB,
    ProfileStats,
    SimConfig,
    job_model,
    simulate_job,
)

P = HadoopParams(pNumNodes=4, pNumMappers=32, pNumReducers=8, pSplitSize=64 * MiB)
S = ProfileStats()
C = CostFactors()


def test_deterministic_given_seed():
    a = simulate_job(P, S, C, SimConfig(seed=7, task_time_jitter=0.2))
    b = simulate_job(P, S, C, SimConfig(seed=7, task_time_jitter=0.2))
    assert a.makespan == b.makespan
    assert len(a.records) == len(b.records)


def test_wave_structure_matches_analytic_bound():
    """32 maps / (4 nodes x 2 slots) = 4 waves: makespan >= 4 x map cost."""
    r = simulate_job(P, S, C, SimConfig(speculative_execution=False))
    jm = job_model(P, S, C)
    map_cost = jm.map.ioCost + jm.map.cpuCost
    assert r.map_finish_time == pytest.approx(4 * map_cost, rel=1e-6)
    # The analytic model (Eq. 92/93) predicts exactly the 4-wave cost.
    analytic_map_time = (jm.ioAllMaps + jm.cpuAllMaps)
    assert r.map_finish_time == pytest.approx(analytic_map_time, rel=1e-6)


def test_simulation_close_to_analytic_for_uniform_tasks():
    """No noise, divisible waves -> simulation == analytic composition."""
    r = simulate_job(P, S, C, SimConfig(speculative_execution=False))
    jm = job_model(P, S, C)
    analytic = (
        jm.ioAllMaps + jm.cpuAllMaps + jm.ioAllReducers + jm.cpuAllReducers
        + jm.netCost
    )
    # Reducers overlap the map phase after slowstart, so simulated makespan
    # is bounded by sequential analytic estimate but close to it.
    assert r.makespan <= analytic * 1.05
    assert r.makespan >= analytic * 0.5


def test_stragglers_hurt_and_speculation_helps():
    slow = simulate_job(
        P, S, C,
        SimConfig(seed=3, straggler_prob=0.15, straggler_slowdown=5.0,
                  speculative_execution=False),
    )
    spec = simulate_job(
        P, S, C,
        SimConfig(seed=3, straggler_prob=0.15, straggler_slowdown=5.0,
                  speculative_execution=True),
    )
    base = simulate_job(P, S, C, SimConfig(seed=3))
    assert slow.makespan > base.makespan
    assert spec.num_speculative_launched > 0
    assert spec.makespan <= slow.makespan


def test_node_failure_requeues_and_completes():
    base = simulate_job(P, S, C, SimConfig(seed=1, speculative_execution=False))
    fail_t = base.map_finish_time * 0.5
    failed = simulate_job(
        P, S, C,
        SimConfig(seed=1, node_failures=((fail_t, 0),),
                  speculative_execution=False),
    )
    assert failed.num_failure_reruns > 0
    assert failed.makespan > base.makespan
    # Every map and reduce index completed exactly once (non-killed record).
    done_maps = {r.index for r in failed.records if r.kind == "map" and not r.killed}
    done_reds = {r.index for r in failed.records if r.kind == "reduce" and not r.killed}
    assert done_maps == set(range(P.pNumMappers))
    assert done_reds == set(range(P.pNumReducers))


def test_map_only_job():
    p0 = P.replace(pNumReducers=0)
    r = simulate_job(p0, S, C, SimConfig(speculative_execution=False))
    assert r.makespan == pytest.approx(r.map_finish_time)
    assert all(rec.kind == "map" for rec in r.records)


def test_reduce_speculation_launches_backups():
    """Reduce stragglers get Hadoop-style backup tasks too (they used to be
    map-only, diverging from the documented semantics)."""
    sc = SimConfig(seed=9, straggler_prob=0.3, straggler_slowdown=8.0,
                   speculative_execution=True, speculative_min_completed=2)
    r = simulate_job(P, S, C, sc)
    spec_reduces = [rec for rec in r.records
                    if rec.kind == "reduce" and rec.speculative]
    assert spec_reduces, "no speculative reduce copies launched"
    no_spec = simulate_job(P, S, C, SimConfig(
        seed=9, straggler_prob=0.3, straggler_slowdown=8.0,
        speculative_execution=False))
    assert r.makespan <= no_spec.makespan
    # every reduce index still completes exactly once (first copy wins)
    done = {rec.index for rec in r.records
            if rec.kind == "reduce" and not rec.killed}
    assert done == set(range(P.pNumReducers))


def test_node_failure_does_not_bypass_slowstart():
    """A failure used to fill reduce slots unconditionally, launching
    reducers before the slowstart threshold."""
    p = P.replace(pReduceSlowstart=1.0)     # reducers only after ALL maps
    r = simulate_job(p, S, C, SimConfig(
        speculative_execution=False, node_failures=((1.0, 3),)))
    first_reduce = min(rec.start for rec in r.records if rec.kind == "reduce")
    assert first_reduce >= r.map_finish_time


def test_slot_utilization_summary():
    r = simulate_job(P, S, C, SimConfig(speculative_execution=False))
    assert len(r.node_busy_s) == P.pNumNodes
    assert sum(r.node_busy_s) == pytest.approx(
        sum(rec.end - rec.start for rec in r.records))
    assert 0.0 < r.slot_utilization <= 1.0
    # uniform tasks on a divisible cluster keep every node equally busy
    assert max(r.node_busy_s) == pytest.approx(min(r.node_busy_s), rel=1e-6)


def test_reduce_bookkeeping_survives_noisy_failure_run():
    """Stragglers + speculation + a failure kill reduce copies through every
    branch (failure kill, sibling kill, stall/resume) — the in-simulator
    reduce_durs invariant asserts no entry outlives its task, and every
    reduce still completes exactly once."""
    r = simulate_job(P, S, C, SimConfig(
        seed=3, straggler_prob=0.3, task_time_jitter=0.2,
        node_failures=((2.0, 1),)))
    done = {rec.index for rec in r.records
            if rec.kind == "reduce" and not rec.killed}
    assert done == set(range(P.pNumReducers))
