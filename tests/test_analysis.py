"""repro.analysis — the analyzer's own regression suite.

Three layers:

* interval-domain unit tests (the abstract arithmetic the checkers rely on),
* known-bad fixtures — every checker must fire on its fixture and stay
  silent on the registered models (modulo the checked-in baseline),
* the guard-reversion gate: monkeypatching the PR-6 double-``where`` guard
  in ``core/hadoop/model.py`` back to single-``where`` MUST re-fire
  nan-hazard, proving the CI gate actually protects that fix.
"""

from __future__ import annotations

import json
import math
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

# ---------------------------------------------------------------------------
# interval domain
# ---------------------------------------------------------------------------


def test_interval_attains_zero_respects_openness():
    from repro.analysis.interval import Interval

    assert Interval(0.0, 5.0).attains_zero
    assert not Interval(0.0, 5.0, lo_open=True).attains_zero
    assert Interval(-1.0, 1.0).attains_zero          # interior zero
    assert not Interval(1.0, 2.0).attains_zero


def test_interval_open_infinity_is_not_attained():
    from repro.analysis.interval import Interval

    unbounded = Interval(0.0, math.inf, False, True)   # open at inf
    assert not unbounded.attains_pinf
    literal_inf = Interval(0.0, math.inf, False, False)
    assert literal_inf.attains_pinf


def test_interval_mul_has_no_spurious_zero_times_inf_corner():
    from repro.analysis.interval import Interval

    a = Interval(0.0, math.inf, True, True)            # (0, inf)
    p = a.mul(a)
    assert (p.lo, p.hi) == (0.0, math.inf)
    assert p.lo_open and p.hi_open                     # still (0, inf)
    assert not p.maybe_nan


def test_interval_mul_signs_and_nan():
    from repro.analysis.interval import Interval

    a = Interval(-2.0, 3.0)
    b = Interval(-1.0, 4.0)
    p = a.mul(b)
    assert (p.lo, p.hi) == (-8.0, 12.0)
    # attained 0 times attained inf => possible nan
    z = Interval(0.0, 1.0)
    inf = Interval(0.0, math.inf, False, False)
    assert z.mul(inf).maybe_nan


def test_interval_div_by_zero_capable_denominator():
    from repro.analysis.interval import Interval

    num = Interval(1.0, 2.0)
    den = Interval(0.0, 5.0)
    q = num.div(den)
    assert q.hi == math.inf
    # guarded denominator (0 excluded) divides clean
    den_open = Interval(0.0, 5.0, lo_open=True)
    q2 = num.div(den_open)
    assert not q2.maybe_nan


def test_interval_hull_and_intersect():
    from repro.analysis.interval import Interval

    a = Interval(0.0, 2.0)
    b = Interval(1.0, 5.0, hi_open=True)
    h = a.hull(b)
    assert (h.lo, h.hi, h.lo_open, h.hi_open) == (0.0, 5.0, False, True)
    i = a.intersect(b)
    assert (i.lo, i.hi) == (1.0, 2.0)


# ---------------------------------------------------------------------------
# abstract interpretation end-to-end
# ---------------------------------------------------------------------------


def test_absint_flags_unguarded_division():
    import jax
    import jax.numpy as jnp

    from repro.analysis.absint import analyze_jaxpr
    from repro.analysis.interval import Interval

    def f(x):
        return 1.0 / x

    closed = jax.make_jaxpr(f)(jnp.asarray(1.0))
    an = analyze_jaxpr(closed, [Interval(0.0, math.inf, False, True)])
    assert any(e.kind == "div0" for e in an.events)


def test_absint_double_where_guard_suppresses_div0():
    import jax
    import jax.numpy as jnp

    from repro.analysis.absint import analyze_jaxpr
    from repro.analysis.interval import Interval

    def f(x):
        ok = x > 0.0
        return jnp.where(ok, 1.0 / jnp.where(ok, x, 1.0), jnp.inf)

    closed = jax.make_jaxpr(f)(jnp.asarray(1.0))
    an = analyze_jaxpr(closed, [Interval(0.0, math.inf, False, True)])
    assert not [e for e in an.events if e.kind == "div0"], (
        "guard refinement through pjit[_where]/select_n broke")


def test_absint_ste_interior_exempt_in_grad_mode():
    import jax
    import jax.numpy as jnp

    from repro.analysis.absint import analyze_jaxpr
    from repro.analysis.interval import FINITE_TOP
    from repro.core.hadoop.merge_math import ste_floor

    def good(x):
        return ste_floor(x) * x

    def bad(x):
        return jnp.floor(x) * x

    x = jnp.asarray(4.0)
    an_good = analyze_jaxpr(jax.make_jaxpr(good)(x), [FINITE_TOP],
                            grad_mode=True)
    an_bad = analyze_jaxpr(jax.make_jaxpr(bad)(x), [FINITE_TOP],
                           grad_mode=True)
    assert not [e for e in an_good.events if e.kind == "rounding"]
    assert [e for e in an_bad.events if e.kind == "rounding"]


def test_ste_helpers_forward_values_unchanged():
    import jax
    import jax.numpy as jnp

    from repro.core.hadoop.merge_math import ste_ceil, ste_floor, ste_round

    x = jnp.asarray([0.2, 1.5, -2.7, 3.0])
    assert jnp.array_equal(ste_floor(x), jnp.floor(x))
    assert jnp.array_equal(ste_ceil(x), jnp.ceil(x))
    assert jnp.array_equal(ste_round(x), jnp.round(x))
    # straight-through gradient is 1 (not 0) on finite inputs
    g = jax.grad(lambda v: ste_floor(v) * 2.0)(1.7)
    assert float(g) == 2.0


# ---------------------------------------------------------------------------
# checkers: known-bad fixtures fire, registered models stay clean
# ---------------------------------------------------------------------------


def test_every_checker_fires_on_its_fixture():
    from repro.analysis.fixtures import selftest

    results = selftest()
    assert sorted(results) == sorted(
        ["nan-hazard", "grad-blocker", "recompile-hazard", "mask-contract",
         "pallas-kernel"])
    for name, findings in results.items():
        assert findings, f"checker {name} no longer fires on its fixture"


def test_fixture_finding_kinds():
    from repro.analysis.fixtures import selftest

    results = selftest()
    kinds = {n: {f.kind for f in fs} for n, fs in results.items()}
    assert "div0" in kinds["nan-hazard"]
    assert "rounding" in kinds["grad-blocker"]
    assert {"weak_type_input", "trace_error"} <= kinds["recompile-hazard"]
    assert "unmasked_total" in kinds["mask-contract"]
    assert {"block_divisibility", "index_map_arity"} <= kinds["pallas-kernel"]


@pytest.fixture(scope="module")
def full_report():
    from repro.analysis import run_all

    return run_all()


def test_registered_models_clean_or_baselined(full_report):
    from repro.analysis import DEFAULT_BASELINE, load_baseline

    baseline = load_baseline(str(ROOT / DEFAULT_BASELINE))
    new = full_report.new_findings(baseline)
    assert not new, (
        "non-baselined findings on registered models:\n" + "\n".join(
            f"{f.checker}/{f.kind} {f.target} {f.location}: {f.message}"
            for f in new))


def test_report_covers_every_registered_target(full_report):
    from repro.analysis import iter_targets

    names = {t.name for t in iter_targets()}
    assert {"hadoop-model", "hadoop-grad", "calib-loss", "tuner-objective",
            "cluster-rollout", "tpu-model"} <= names
    # untraceable targets are reported as skipped-with-reason, not dropped
    assert "tpu-model" in full_report.skipped
    assert full_report.skipped["tpu-model"]


def test_no_unmodeled_primitives_on_registered_models(full_report):
    assert not full_report.coverage_gaps, (
        "interval transfer functions missing for primitives: "
        f"{full_report.coverage_gaps}")


# ---------------------------------------------------------------------------
# the reversion gate: un-fixing the PR-6 guard must fail CI
# ---------------------------------------------------------------------------


def test_reverting_masked_div_guard_refires_nan_hazard(monkeypatch):
    import jax.numpy as jnp

    import repro.core.hadoop.model as model
    from repro.analysis import run_all

    def single_where_div(num, den, ok):    # the pre-PR-6 buggy form
        return jnp.where(ok, num / den, jnp.inf)

    monkeypatch.setattr(model, "_masked_div", single_where_div)
    report = run_all(checkers=["nan-hazard"])
    hits = [f for f in report.findings
            if f.kind == "div0" and f.target == "hadoop-model"]
    assert hits, (
        "nan-hazard no longer detects the single-where masked division — "
        "the CI gate would not catch a reversion of the PR-6 guard")
    # and the gate logic itself: these findings are not in the baseline
    from repro.analysis import DEFAULT_BASELINE, load_baseline

    baseline = load_baseline(str(ROOT / DEFAULT_BASELINE))
    assert report.new_findings(baseline), "reversion finding was baselined?!"


def test_reverting_p95_latency_guard_refires_nan_hazard():
    """The cluster-side true positive fixed in this PR: ``jnp.percentile``
    computes ``lo*(1-frac) + hi*frac`` between sorted neighbours; whenever
    ``0.95*(n-1)`` lands on an integer (n=21 jobs, say) one weight is
    exactly 0 and an infinite (unconverged) latency makes it ``0 * inf``."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.absint import analyze_jaxpr
    from repro.analysis.interval import Interval

    def unguarded(latency):
        return jnp.percentile(latency, 95.0)

    def guarded(latency):
        finite = jnp.isfinite(latency)
        lat_safe = jnp.where(finite, latency, 0.0)
        return jnp.percentile(lat_safe, 95.0)

    lat = jnp.zeros((21,))                 # 0.95 * 20 == 19: frac == 0
    ival = [Interval(0.0, math.inf, False, False)]   # inf is attained
    an_bad = analyze_jaxpr(jax.make_jaxpr(unguarded)(lat), ival)
    an_good = analyze_jaxpr(jax.make_jaxpr(guarded)(lat), ival)
    bad_kinds = {e.kind for e in an_bad.events}
    assert "zero_times_inf" in bad_kinds, bad_kinds
    assert not an_good.events


# ---------------------------------------------------------------------------
# baseline round-trip + CLI
# ---------------------------------------------------------------------------


def test_baseline_round_trip(tmp_path):
    from repro.analysis import Report, load_baseline, save_baseline
    from repro.analysis.findings import Finding

    f = Finding(checker="nan-hazard", target="demo", kind="div0",
                message="m", location="a/b.py:3 in fn")
    rep = Report(findings=[f])
    path = tmp_path / "baseline.json"
    save_baseline(str(path), rep)
    fps = load_baseline(str(path))
    assert fps == {f.fingerprint()}
    assert not rep.new_findings(fps)
    # fingerprints survive a line-number move but not a file move
    f2 = Finding(checker="nan-hazard", target="demo", kind="div0",
                 message="m", location="a/b.py:99 in fn")
    assert f2.fingerprint() in fps
    f3 = Finding(checker="nan-hazard", target="demo", kind="div0",
                 message="m", location="a/other.py:3 in fn")
    assert f3.fingerprint() not in fps
    assert rep.stale_baseline(fps | {"ghost|x|y|z|w"}) == ["ghost|x|y|z|w"]


def test_missing_baseline_is_empty():
    from repro.analysis import load_baseline

    assert load_baseline("/nonexistent/baseline.json") == set()


def _cli_env():
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_ENABLE_X64"] = "1"
    return env


def test_cli_smoke_subprocess():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--smoke"],
        capture_output=True, text=True, cwd=str(ROOT), env=_cli_env(),
        timeout=300,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "all checkers fire" in proc.stdout


def test_cli_json_report():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--json",
         "--checker", "pallas-kernel", "--checker", "mask-contract"],
        capture_output=True, text=True, cwd=str(ROOT), env=_cli_env(),
        timeout=300,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["checkers_run"] == ["mask-contract", "pallas-kernel"]
    assert payload["findings"] == []
    assert "new_findings" in payload and "stale_baseline" in payload


# ---------------------------------------------------------------------------
# pallas geometry validation (pure, no monkeypatching needed)
# ---------------------------------------------------------------------------


def test_validate_launch_accepts_good_geometry():
    import jax

    from repro.analysis.checkers.pallas_kernel import validate_launch

    class Spec:
        def __init__(self, block_shape, index_map):
            self.block_shape = block_shape
            self.index_map = index_map

    class Op:
        def __init__(self, shape):
            self.shape = shape

    out = validate_launch(
        name="demo",
        kernel=lambda x_ref, o_ref: None,
        grid=(4, 8),
        in_specs=[Spec((1, 128), lambda i, j: (i, j))],
        out_specs=Spec((1, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((4, 1024), "float32"),
        scratch_shapes=None,
        compiler_params=None,
        operands=[Op((4, 1024))],
        location="test")
    assert out == []


def test_validate_launch_rejects_bad_geometry():
    import jax

    from repro.analysis.checkers.pallas_kernel import validate_launch

    class Spec:
        def __init__(self, block_shape, index_map):
            self.block_shape = block_shape
            self.index_map = index_map

    class Op:
        def __init__(self, shape):
            self.shape = shape

    out = validate_launch(
        name="demo",
        kernel=lambda x_ref: None,               # missing the out ref
        grid=(4,),
        in_specs=[Spec((1, 300), lambda i, j: (i, j))],   # 2-ary for 1-d grid
        out_specs=Spec((1, 300), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((4, 1000), "float32"),
        scratch_shapes=None,
        compiler_params=None,
        operands=[Op((4, 1000))],
        location="test")
    kinds = {f.kind for f in out}
    assert {"block_divisibility", "index_map_arity", "kernel_arity"} <= kinds
