"""Fault tolerance: checkpoint atomicity, kill/auto-resume bit-exactness,
elastic resharding, data-pipeline statelessness, straggler detection,
cross-pod compressed reduction."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import PipelineConfig, TokenPipeline
from repro.optim import AdamWConfig
from repro.runtime import StragglerDetector, Trainer, TrainerConfig, should_speculate


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
    }


# ------------------------------------------------------------- checkpoints

def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    cm.save(3, t)
    restored, manifest = cm.restore(t)
    assert manifest["step"] == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, restored)


def test_checkpoint_keep_n_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(s))
    assert cm.all_steps() == [3, 4]
    _, manifest = cm.restore(_tree())
    assert manifest["step"] == 4


def test_checkpoint_skips_corrupt(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=5)
    cm.save(1, _tree(1))
    cm.save(2, _tree(2))
    # corrupt the newest checkpoint's first leaf
    path = os.path.join(str(tmp_path), "step_000000002", "leaf_00000.npy")
    arr = np.load(path)
    arr = arr + 1.0
    np.save(path, arr)
    restored, manifest = cm.restore(_tree())
    assert manifest["step"] == 1  # CRC check rejected step 2


def test_checkpoint_async(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    t = _tree()
    cm.save(7, t, blocking=False)
    cm.wait()
    restored, manifest = cm.restore(t)
    assert manifest["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, restored)


def test_checkpoint_partial_write_is_invisible(tmp_path):
    """A .tmp directory (simulated crash mid-save) is never restored."""
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _tree(1))
    os.makedirs(os.path.join(str(tmp_path), "step_000000009.tmp"))
    restored, manifest = cm.restore(_tree())
    assert manifest["step"] == 1


# ------------------------------------------------------ kill/resume trainer

def _make_trainer(tmp, **kw):
    cfg = get_config("stablelm-1.6b").smoke()
    tcfg = TrainerConfig(
        global_batch=4, seq_len=32, ckpt_dir=str(tmp), ckpt_every=5,
        async_ckpt=False, log_every=1,
        opt=AdamWConfig(peak_lr=1e-3, warmup_steps=5, total_steps=40),
        **kw,
    )
    return Trainer(cfg, tcfg)


def test_kill_resume_bitwise_identical(tmp_path):
    """Crash at step 12 (after a save at step 9), auto-resume, and compare
    against an uninterrupted run: final params must be bit-identical."""
    a = str(tmp_path / "a")
    b = str(tmp_path / "b")

    ref = _make_trainer(a).run(20, resume=False)

    crashy = _make_trainer(b, fail_at_step=12)
    with pytest.raises(RuntimeError, match="injected failure"):
        crashy.run(20, resume=False)
    resumed = _make_trainer(b).run(20, resume=True)

    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        ref["params"], resumed["params"],
    )
    assert ref["final_loss"] == pytest.approx(resumed["final_loss"], abs=0)


def test_elastic_reshard(tmp_path):
    """Save from a 1-device layout, restore onto a 2-axis mesh sharding —
    the elastic scale-up path (device_put with new sharding)."""
    cm = CheckpointManager(str(tmp_path))
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    cm.save(1, t)
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, _ = cm.restore(t, shardings=shardings)
    assert restored["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))


# ----------------------------------------------------------- data pipeline

def test_pipeline_stateless_and_host_invariant():
    base = PipelineConfig(vocab_size=512, seq_len=64, global_batch=8, seed=3)
    p1 = TokenPipeline(base)
    # same step -> same batch, different step -> different batch
    b1 = p1.batch(10)
    b2 = p1.batch(10)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert not np.array_equal(p1.batch(11)["inputs"], b1["inputs"])

    # 4-host sharding concatenates to the 1-host global batch
    hosts = [
        TokenPipeline(PipelineConfig(
            vocab_size=512, seq_len=64, global_batch=8, seed=3,
            num_hosts=4, host_index=i,
        )).batch(10)["inputs"]
        for i in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(hosts, 0), b1["inputs"])


def test_pipeline_has_learnable_structure():
    """The copy structure makes position t predictable from t-97: a model
    must be able to beat uniform entropy (sanity for the e2e example)."""
    p = TokenPipeline(PipelineConfig(vocab_size=512, seq_len=200, global_batch=4))
    toks = p.batch(0)["inputs"]
    assert np.array_equal(toks[:, 97 * 2], toks[:, 97])


# -------------------------------------------------------------- stragglers

def test_straggler_detector_flags_outlier():
    det = StragglerDetector(warmup=3, sigmas=3.0)
    flagged = []
    times = [0.100, 0.101, 0.099, 0.102, 0.100, 0.100, 0.500, 0.101]
    for t in times:
        flagged.append(det.observe("h0", t))
    assert flagged[6] is True          # the 0.5s spike
    assert sum(flagged) == 1           # nothing else


def test_should_speculate_late_heuristic():
    # slow task with lots of work left -> speculate
    assert should_speculate(
        0.1, 1.0, 0.2, remaining_work=50, est_fresh_time=60,
    )
    # slow task but nearly done -> not worth it
    assert not should_speculate(
        0.1, 1.0, 0.2, remaining_work=1, est_fresh_time=60,
    )
    # healthy task -> never
    assert not should_speculate(
        1.0, 1.0, 0.2, remaining_work=50, est_fresh_time=60,
    )


def test_trainer_straggler_hook(tmp_path):
    tr = _make_trainer(tmp_path)
    # feed synthetic step times through the same detector the loop uses
    for t in [0.1] * 6 + [2.0]:
        tr.stragglers.observe("host0", t)
    mean, sd = tr.stragglers.fleet_stats()
    assert mean < 0.2  # outlier did not poison the EWMA


# ------------------------------------------------------ cross-pod compress

def test_crosspod_compression_int8_error_feedback():
    """int8+EF over a 2-'pod' mesh: mean-reduction error is small and the
    error-feedback state carries the residual."""
    if len(jax.devices()) < 2:
        devs = np.array(jax.devices() * 2)[:2]  # single device twice: skip
        pytest.skip("needs 2 devices; covered by subprocess test")


_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.runtime.crosspod import crosspod_reduce
from repro.optim.compress import compress_init

mesh = Mesh(np.array(jax.devices()).reshape(2), ("pod",))
g = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8)}
err = compress_init(g, "int8")
red, err2 = jax.jit(
    lambda g, e: crosspod_reduce(g, e, mesh, method="int8")
)(g, err)
np.testing.assert_allclose(np.asarray(red["w"]), np.asarray(g["w"]), atol=2e-2)
red_bf, _ = jax.jit(
    lambda g, e: crosspod_reduce(g, e, mesh, method="bf16")
)(g, None)
np.testing.assert_allclose(np.asarray(red_bf["w"]), np.asarray(g["w"]), atol=1e-2)
print("OK")
"""


def test_crosspod_compression_subprocess():
    import subprocess
    import sys

    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SUB], env=env, capture_output=True,
        text=True, timeout=240, cwd=os.getcwd(),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


def test_checkpoint_bf16_and_int_leaves(tmp_path):
    """Serving weights are bf16 (ml_dtypes numpy) — roundtrip must be exact."""
    import jax.numpy as jnp

    cm = CheckpointManager(str(tmp_path))
    t = {
        "w_bf16": jnp.linspace(-2, 2, 64, dtype=jnp.bfloat16).reshape(8, 8),
        "step": jnp.asarray(7, jnp.int32),
        "flags": jnp.asarray([True, False]),
    }
    cm.save(1, t)
    restored, _ = cm.restore(t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
