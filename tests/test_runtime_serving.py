"""Serving runtime + data pipeline coverage."""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import PipelineConfig, TokenPipeline
from repro.launch.steps import init_params
from repro.runtime.batching import AdmissionQueue, LatencyStats
from repro.runtime.serve_loop import Request, Server


def test_server_generate_and_throughput():
    cfg = get_config("stablelm-1.6b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_len=64)

    reqs = [Request(rid=i, prompt=[2, 3, 4, 5 + i], max_new_tokens=4)
            for i in range(2)]
    done = server.generate(reqs)
    assert all(r.done and len(r.generated) == 4 for r in done)
    assert server.stats["tokens_out"] >= 6   # 2 reqs x (4-1) decode tokens + prefill tokens

    out = server.throughput_batch(
        np.random.default_rng(0).integers(2, cfg.vocab_size, (2, 8)), 4
    )
    assert out["output"].shape == (2, 4)
    assert out["tok_per_s"] > 0


def test_generate_stats_tick_counts_and_e2e_latency():
    """Regression for two Server.generate accounting bugs:
    * latency_s froze at prefill time and never included decode;
    * decode_ticks incremented once per ACTIVE REQUEST per tick instead of
      once per lockstep tick."""
    cfg = get_config("stablelm-1.6b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_len=64)

    reqs = [Request(rid=i, prompt=[2, 3, 4 + i], max_new_tokens=5)
            for i in range(3)]
    t0 = time.perf_counter()
    server.generate(reqs)
    wall = time.perf_counter() - t0

    # lockstep: all 3 requests decode 4 tokens in the SAME 4 ticks
    assert server.stats["decode_ticks"] == 4          # was 12 before the fix
    assert server.stats["tokens_out"] == 12
    # end-to-end latency: admitted together, finished on the last tick =>
    # every request's latency spans (almost) the whole call, and none of
    # them is frozen at its tiny prefill-only value
    for r in reqs:
        assert 0.5 * wall < r.latency_s <= wall
    assert server.latency.count == 3
    assert server.latency.p99 >= server.latency.p50 > 0


def test_generate_slot_limited_admission():
    """max_slots=1 serializes requests through the admission queue; output
    tokens are unchanged vs. unconstrained slots (greedy decode)."""
    cfg = get_config("stablelm-1.6b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[2, 3, 4], [5, 6, 7, 8]]

    outs = []
    for slots in (None, 1):
        server = Server(cfg, params, max_len=64)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        server.generate(reqs, max_slots=slots)
        assert all(r.done and len(r.generated) == 4 for r in reqs)
        outs.append([r.generated for r in reqs])
    assert outs[0] == outs[1]


def test_admission_queue_and_latency_stats():
    q = AdmissionQueue()
    assert q.put("a") == 1 and q.put_many(["b", "c"]) == 2
    assert q.peak_depth == 3 and len(q) == 3
    assert q.peek() == "a" and q.pop() == "a"
    assert q.take(5) == ["b", "c"] and len(q) == 0
    assert q.wait(timeout=0.01) is False
    q.close()
    assert q.wait() is False                 # closed + empty: don't block
    try:
        q.put("d")
        assert False, "put into closed queue must raise"
    except RuntimeError:
        pass

    ls = LatencyStats()
    assert ls.p50 == 0.0 and ls.count == 0
    for v in (0.1, 0.2, 0.3, 0.4):
        ls.record(v)
    assert ls.count == 4
    assert ls.p50 == np.percentile([0.1, 0.2, 0.3, 0.4], 50)
    assert ls.p99 <= 0.4 and ls.mean() == np.mean([0.1, 0.2, 0.3, 0.4])


def test_server_greedy_decode_deterministic():
    cfg = get_config("stablelm-1.6b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_len=32)
    prompts = np.full((1, 8), 7)
    a = server.throughput_batch(prompts, 4)["output"]
    b = server.throughput_batch(prompts, 4)["output"]
    np.testing.assert_array_equal(a, b)


def test_decode_matches_forward_logits():
    """Decode-with-cache must agree with full forward at each position."""
    import jax.numpy as jnp

    from repro.models import lm

    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)

    full_logits, _, _ = lm.forward(params, cfg, toks)
    logits_p, caches, pos = lm.prefill(params, cfg, toks[:, :8], 16)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1]), np.asarray(full_logits[:, 7]),
        rtol=2e-3, atol=2e-3,
    )
    # decode tokens 8..11 and compare against the parallel forward
    for t in range(8, 12):
        logits_d, caches = lm.decode_step(
            params, cfg, toks[:, t:t+1], caches, jnp.asarray(t, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
        )


def test_pipeline_batch_contract():
    p = TokenPipeline(PipelineConfig(vocab_size=128, seq_len=32, global_batch=4))
    b = p.batch(0)
    assert b["inputs"].shape == (4, 32) and b["targets"].shape == (4, 32)
    # next-token alignment: targets[t] == inputs[t+1]
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])
    assert b["inputs"].max() < 128 and b["inputs"].min() >= 0
