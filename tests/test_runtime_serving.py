"""Serving runtime + data pipeline coverage."""

import jax
import numpy as np

from repro.configs import get_config
from repro.data import PipelineConfig, TokenPipeline
from repro.launch.steps import init_params
from repro.runtime.serve_loop import Request, Server


def test_server_generate_and_throughput():
    cfg = get_config("stablelm-1.6b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_len=64)

    reqs = [Request(rid=i, prompt=[2, 3, 4, 5 + i], max_new_tokens=4)
            for i in range(2)]
    done = server.generate(reqs)
    assert all(r.done and len(r.generated) == 4 for r in done)
    assert server.stats["tokens_out"] >= 6   # 2 reqs x (4-1) decode tokens + prefill tokens

    out = server.throughput_batch(
        np.random.default_rng(0).integers(2, cfg.vocab_size, (2, 8)), 4
    )
    assert out["output"].shape == (2, 4)
    assert out["tok_per_s"] > 0


def test_server_greedy_decode_deterministic():
    cfg = get_config("stablelm-1.6b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_len=32)
    prompts = np.full((1, 8), 7)
    a = server.throughput_batch(prompts, 4)["output"]
    b = server.throughput_batch(prompts, 4)["output"]
    np.testing.assert_array_equal(a, b)


def test_decode_matches_forward_logits():
    """Decode-with-cache must agree with full forward at each position."""
    import jax.numpy as jnp

    from repro.models import lm

    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)

    full_logits, _, _ = lm.forward(params, cfg, toks)
    logits_p, caches, pos = lm.prefill(params, cfg, toks[:, :8], 16)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1]), np.asarray(full_logits[:, 7]),
        rtol=2e-3, atol=2e-3,
    )
    # decode tokens 8..11 and compare against the parallel forward
    for t in range(8, 12):
        logits_d, caches = lm.decode_step(
            params, cfg, toks[:, t:t+1], caches, jnp.asarray(t, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
        )


def test_pipeline_batch_contract():
    p = TokenPipeline(PipelineConfig(vocab_size=128, seq_len=32, global_batch=4))
    b = p.batch(0)
    assert b["inputs"].shape == (4, 32) and b["targets"].shape == (4, 32)
    # next-token alignment: targets[t] == inputs[t+1]
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])
    assert b["inputs"].max() < 128 and b["inputs"].min() >= 0
