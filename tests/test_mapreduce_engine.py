"""Engine-vs-model validation: the paper's closed-form dataflow equations
against an actual MapReduce execution (the reproduction's E7-core).

For jobs with exact selectivities (sort: identity everywhere) the model's
dataflow quantities must match the engine's *measured* counters exactly
(integer equality for spill/pass counts).  For statistical jobs (wordcount
with a combiner) the Starfish workflow is validated: measure ProfileStats
from one profiled run, feed them to the closed-form model, and require its
dataflow predictions to track the measured counters.
"""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.hadoop import ref
from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.mapreduce import JOBS, MapReduceEngine, make_input
from repro.mapreduce.profiler import (
    fit_cost_factors,
    prediction_error,
    profile_job,
    run_measured,
)


def _sort_stats(job, n_pairs):
    return ProfileStats(
        sInputPairWidth=job.pair_width,
        sMapSizeSel=1.0, sMapPairsSel=1.0,
        sReduceSizeSel=1.0, sReducePairsSel=1.0,
    )


def _hp_for(job, n_pairs, **kw) -> HadoopParams:
    base = dict(
        pNumMappers=1,
        pNumReducers=4,
        pSplitSize=n_pairs * job.pair_width,
        pUseCombine=job.use_combine,
        pSortMB=1.0,                 # small buffer -> several spills
        pTaskMem=8.0 * MiB,
    )
    base.update(kw)
    return HadoopParams(**base)


# --------------------------------------------------------------- exact jobs

@pytest.mark.parametrize("sort_mb,factor", [(1.0, 10), (0.5, 3), (2.0, 4)])
def test_sort_job_spills_match_model_exactly(sort_mb, factor):
    job = JOBS["sort"]
    n = 60_000
    hp = _hp_for(job, n, pSortMB=sort_mb, pSortFactor=factor)
    keys, values = make_input(job, n)
    jc = MapReduceEngine(hp, job).run_job(keys, values)

    m = ref.map_task_model(hp, _sort_stats(job, n), CostFactors())
    mc = jc.maps[0]
    assert mc.outMapPairs == n
    assert mc.spillBufferPairs == int(m.spillBufferPairs)
    assert mc.numSpills == m.numSpills
    assert mc.numMergePasses == m.numMergePasses
    assert mc.numSpillsFinalMerge == m.numSpillsFinalMerge
    # identity map+no combine: every pair spilled once, none dropped
    assert mc.intermDataPairs == n
    assert sum(mc.spillFilePairs) == n
    # model's equal-size-spill approximation: exact for all but the last
    assert mc.spillFilePairs[0] == int(m.spillFilePairs)


def test_sort_job_reduce_side_counts():
    job = JOBS["sort"]
    n = 40_000
    hp = _hp_for(job, n, pNumReducers=8, pSortMB=1.0)
    keys, values = make_input(job, n)
    jc = MapReduceEngine(hp, job).run_job(keys, values)

    m = ref.map_task_model(hp, _sort_stats(job, n), CostFactors())
    r = ref.reduce_task_model(hp, _sort_stats(job, n), CostFactors(), m)
    total_in = sum(rc.inReducePairs for rc in jc.reduces)
    total_out = sum(rc.outReducePairs for rc in jc.reduces)
    assert total_in == n and total_out == n
    # per-reducer average matches the model's segment accounting, up to the
    # paper's equal-size-spill approximation: intermDataPairs is modeled as
    # numSpills x spillBufferPairs (Eq. 30), which rounds the last partial
    # spill up, so the model is an upper bound within one spill's worth.
    measured = np.mean([rc.totalShufflePairs for rc in jc.reduces])
    overcount = m.numSpills * m.spillBufferPairs / n
    assert measured <= r.totalShufflePairs <= measured * overcount * 1.001
    # output preserved and globally key-sorted within each reducer
    ok, ov = jc.output
    assert ok.shape[0] == n
    np.testing.assert_allclose(np.sort(ov), np.sort(values), rtol=1e-6)


def test_map_only_job():
    job = JOBS["filter"]
    n = 20_000
    hp = _hp_for(job, n, pNumReducers=0, pNumMappers=3)
    keys, values = make_input(job, n)
    jc = MapReduceEngine(hp, job).run_job(keys, values)
    assert not jc.reduces
    ok, _ = jc.output
    assert ok.shape[0] == sum(m.outMapPairs for m in jc.maps)
    assert np.all(ok % 5 == 0)


# ----------------------------------------------------- property: sort spills

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(5_000, 80_000),
    sort_kb=st.integers(256, 4096),
    factor=st.integers(2, 12),
    reducers=st.integers(1, 16),
)
def test_spill_accounting_property(n, sort_kb, factor, reducers):
    """Engine numSpills/buffer sizing == paper Eqs. 11-15 for identity maps."""
    job = JOBS["sort"]
    hp = _hp_for(
        job, n, pSortMB=sort_kb / 1024.0, pSortFactor=factor,
        pNumReducers=reducers,
    )
    keys, values = make_input(job, n)
    mc = MapReduceEngine(hp, job).run_map_task(keys, values)[1]
    m = ref.map_task_model(hp, _sort_stats(job, n), CostFactors())
    assert mc.spillBufferPairs == int(m.spillBufferPairs)
    assert mc.numSpills == m.numSpills == math.ceil(n / mc.spillBufferPairs)
    assert mc.numSpillsFinalMerge == m.numSpillsFinalMerge
    assert mc.intermDataPairs == n


# ------------------------------------------------- statistical job: wordcount

def test_wordcount_profile_predicts_other_config():
    """Starfish loop: profile at config A, predict dataflow at config B."""
    job = JOBS["wordcount"]
    n = 30_000
    hp_a = _hp_for(job, n, pSortMB=2.0)
    keys, values = make_input(job, n)
    jc_a = MapReduceEngine(hp_a, job).run_job(keys, values)
    stats = profile_job(jc_a, job, hp_a)

    # combiner reduces pairs: selectivity must be measured < 1
    assert 0.0 < stats.sCombinePairsSel < 1.0
    assert stats.sMapPairsSel == pytest.approx(4.0)

    # (B) same buffer size, different reducers/sort-factor: the paper's
    # constant-selectivity assumption holds and predictions track closely.
    hp_b = _hp_for(job, n, pSortMB=2.0, pNumReducers=2, pSortFactor=4)
    jc_b = MapReduceEngine(hp_b, job).run_job(keys, values)
    m = ref.map_task_model(hp_b, stats, CostFactors())
    mc = jc_b.maps[0]
    assert mc.numSpills == m.numSpills
    assert np.isclose(
        np.mean(mc.spillFilePairs[:-1] or mc.spillFilePairs),
        m.spillFilePairs, rtol=0.15,
    )
    # final-merge combine: the model re-applies sCombinePairsSel (Eq. 30);
    # in reality a second combine over already-combined spills saturates at
    # the number of distinct keys, so the model can only over-predict.
    assert mc.intermDataPairs <= m.intermDataPairs
    assert mc.usedCombineInMerge == m.useCombInMerge


def test_wordcount_selectivity_buffer_dependence():
    """Documented model limitation (paper §1 assumes config-independent
    selectivities): a combiner's pair selectivity *rises* as the spill
    buffer shrinks (fewer duplicates per chunk), so a profile measured at a
    large pSortMB *under*-predicts spill pairs at a small pSortMB.  The
    engine exposes exactly that bias direction."""
    job = JOBS["wordcount"]
    n = 30_000
    keys, values = make_input(job, n)
    hp_a = _hp_for(job, n, pSortMB=2.0)
    stats = profile_job(MapReduceEngine(hp_a, job).run_job(keys, values), job, hp_a)

    hp_small = _hp_for(job, n, pSortMB=0.5)
    mc = MapReduceEngine(hp_small, job).run_job(keys, values).maps[0]
    m = ref.map_task_model(hp_small, stats, CostFactors())
    measured = np.mean(mc.spillFilePairs[:-1] or mc.spillFilePairs)
    assert measured > m.spillFilePairs  # model under-predicts, as analyzed


def test_combiner_pallas_equals_numpy():
    job = JOBS["wordcount"]
    n = 8_000
    hp = _hp_for(job, n)
    keys, values = make_input(job, n)
    jc_np = MapReduceEngine(hp, job, use_pallas_combine=False).run_job(keys, values)
    jc_pl = MapReduceEngine(hp, job, use_pallas_combine=True).run_job(keys, values)
    k1, v1 = jc_np.output
    k2, v2 = jc_pl.output
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_allclose(v1, v2, rtol=1e-5)


# ----------------------------------------------------------------- fitting

def test_cost_factor_fit_and_prediction():
    job = JOBS["sort"]
    n = 50_000
    fit_hps = [
        _hp_for(job, n, pSortMB=0.5),
        _hp_for(job, n, pSortMB=2.0, pNumReducers=2),
        _hp_for(job, n, pSortMB=1.0, pSortFactor=4),
    ]
    test_hps = [
        _hp_for(job, n, pSortMB=1.5, pNumReducers=8),
        _hp_for(job, n, pSortMB=0.75, pSortFactor=5),
    ]
    out = prediction_error(job, fit_hps, test_hps, n)
    # engine runs are real timed executions on this host; the paper's linear
    # cost structure should predict unseen configs well within 2x
    assert out["mean_rel_err"] < 0.6, out
    costs = out["costs"]
    assert all(
        getattr(costs, f) >= 0.0
        for f in ("cHdfsReadCost", "cMapCPUCost", "cSortCPUCost")
    )


def test_fitted_model_ranks_configs():
    """The tuning use case: the fitted model must *rank* a bad config (tiny
    sort buffer -> many spills+passes) worse than a good one."""
    job = JOBS["sort"]
    n = 50_000
    runs = [
        run_measured(job, _hp_for(job, n, pSortMB=mb), n)
        for mb in (0.25, 1.0, 4.0)
    ]
    costs = fit_cost_factors(runs)
    stats = runs[0].stats
    bad = ref.job_model(_hp_for(job, n, pSortMB=0.25), stats, costs).totalCost
    good = ref.job_model(_hp_for(job, n, pSortMB=4.0), stats, costs).totalCost
    assert bad > good
