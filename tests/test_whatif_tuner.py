"""What-if engine + configuration tuner tests (the paper's use case)."""

import numpy as np
import pytest

from repro.core.hadoop import CostFactors, HadoopParams, MiB, ProfileStats, job_model
from repro.core.tuner import coordinate_descent, grid_search, random_search
from repro.core.whatif import evaluate_grid, evaluate_product_grid

P = HadoopParams(pNumNodes=8, pNumMappers=64, pNumReducers=16, pSplitSize=128 * MiB)
S = ProfileStats(sMapSizeSel=0.8, sReduceSizeSel=0.5)
C = CostFactors()

SPACE = {
    "pSortMB": [50.0, 100.0, 200.0, 400.0],
    "pSortFactor": [5.0, 10.0, 25.0, 50.0],
    "pNumReducers": [4.0, 8.0, 16.0, 32.0, 64.0],
    "pIsIntermCompressed": [0.0, 1.0],
}


def test_evaluate_grid_matches_oracle_pointwise():
    res = evaluate_grid(P, S, C, {"pSortMB": np.array([64.0, 128.0, 256.0])})
    for i, v in enumerate([64.0, 128.0, 256.0]):
        ref = job_model(P.replace(pSortMB=v), S, C)
        assert res.total_cost[i] == pytest.approx(ref.totalCost, rel=1e-9)


def test_product_grid_shape_and_validity():
    res = evaluate_product_grid(P, S, C, SPACE)
    n = 4 * 4 * 5 * 2
    assert len(res.total_cost) == n
    assert np.isfinite(res.total_cost).any()


def test_grid_search_finds_global_min_of_grid():
    res = evaluate_product_grid(P, S, C, SPACE)
    best = grid_search(P, S, C, SPACE)
    assert best.best_cost == pytest.approx(np.min(res.total_cost))


def test_random_search_upper_bounds_grid_optimum():
    g = grid_search(P, S, C, SPACE)
    r = random_search(P, S, C, SPACE, samples=2048, seed=0)
    assert r.best_cost >= g.best_cost - 1e-12
    assert r.best_cost <= g.best_cost * 1.5  # dense sampling gets close


def test_coordinate_descent_converges_to_grid_optimum():
    g = grid_search(P, S, C, SPACE)
    cd = coordinate_descent(P, S, C, SPACE)
    assert cd.best_cost == pytest.approx(g.best_cost, rel=1e-6)
    assert cd.evaluations < g.evaluations  # far fewer model evaluations


def test_tuning_result_applies_to_params():
    g = grid_search(P, S, C, SPACE)
    tuned = g.apply(P)
    assert isinstance(tuned.pSortFactor, int)
    j_base = job_model(P, S, C)
    j_tuned = job_model(tuned, S, C)
    assert j_tuned.totalCost <= j_base.totalCost + 1e-9


def test_compression_chosen_when_network_is_slow():
    """Slow network -> tuner should enable intermediate compression."""
    slow_net = C.replace(cNetworkCost=1e-7)  # ~10 MB/s
    s = S.replace(sIntermCompressRatio=0.3)
    g = grid_search(P, s, slow_net, SPACE)
    assert g.best_assignment["pIsIntermCompressed"] == 1.0
