"""repro.search: chunked/sharded evaluator, streaming top-k, escape hatch.

Covers the contract the subsystem was built around:
* chunked+sharded evaluation is bit-for-bit identical to the seed's
  unchunked single-device ``jit(vmap(...))`` path;
* padding at non-divisible batch sizes changes nothing;
* a fixed chunk size means ONE compile across arbitrary grid sizes;
* streamed on-device top-k agrees with a numpy argsort oracle;
* an all-invalid grid raises from ``best()`` but the search path routes
  invalid survivors through the exact task-scheduler simulator;
* the multi-device sharded path (8 forced host devices, subprocess) matches
  the single-device result.
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.hadoop import CostFactors, HadoopParams, MiB, ProfileStats
from repro.core.whatif import evaluate_grid, evaluate_product_grid
from repro.search import (
    ChunkedEvaluator,
    InvalidGridError,
    TpuEvaluator,
    coordinate_descent_ev,
    evaluate_unchunked,
    grid_search,
    grid_search_ev,
    random_search,
    search_topk,
    space_block,
    space_size,
)

P = HadoopParams(pNumNodes=8, pNumMappers=64, pNumReducers=16, pSplitSize=128 * MiB)
S = ProfileStats(sMapSizeSel=0.8, sReduceSizeSel=0.5)
C = CostFactors()

SPACE = {
    "pSortMB": [25.0, 50.0, 100.0, 200.0, 400.0],
    "pSortFactor": [5.0, 10.0, 25.0, 50.0],
    "pNumReducers": [4.0, 8.0, 16.0, 32.0, 64.0],
    "pIsIntermCompressed": [0.0, 1.0],
}

# numSpills >> pSortFactor**2 everywhere -> closed-form merge math invalid
INVALID_SPACE = {
    "pSortMB": [0.25, 0.5],
    "pSortFactor": [2.0, 3.0],
}


def _oracle_cost(space):
    """Full-grid costs via the seed's unchunked single-device path."""
    ev = ChunkedEvaluator(P, S, C, chunk=64)
    cols = space_block(space, 0, space_size(space))
    out = evaluate_unchunked(ev.base_cfg, cols)
    return np.where(out["valid"] > 0, out["j_totalCost"], np.inf)


# ------------------------------------------------------------------
# chunked == unchunked
# ------------------------------------------------------------------


def test_chunked_matches_unchunked_bit_for_bit():
    ref = _oracle_cost(SPACE)
    for chunk in (7, 64, 1 << 13):  # non-divisible, divisible, one-chunk
        res = evaluate_product_grid(P, S, C, SPACE,
                                    evaluator=ChunkedEvaluator(P, S, C, chunk=chunk))
        assert res.total_cost.shape == ref.shape
        assert np.array_equal(res.total_cost, ref), f"chunk={chunk}"


def test_padding_correct_at_non_divisible_sizes():
    ev = ChunkedEvaluator(P, S, C, chunk=16)
    rng = np.random.default_rng(3)
    vals = rng.choice([25.0, 50.0, 100.0, 200.0], 64)
    # one full-chunk evaluation of every row = the padding-free reference
    full = ev.evaluate({"pSortMB": vals}).outputs["j_totalCost"]
    for n in (1, 15, 16, 17, 33):   # around the chunk boundary
        res = ev.evaluate({"pSortMB": vals[:n]})
        assert len(res.total_cost) == n
        # same compiled chunk executable, rows now padded -> identical bits
        assert np.array_equal(res.outputs["j_totalCost"], full[:n])
        # and still equal (to round-off) to a fresh unchunked compile at size n
        ref = evaluate_unchunked(ev.base_cfg, {"pSortMB": vals[:n]})
        np.testing.assert_allclose(
            res.outputs["j_totalCost"], ref["j_totalCost"], rtol=1e-12
        )


def test_fixed_chunk_means_single_compile_across_grid_sizes():
    ev = ChunkedEvaluator(P, S, C, chunk=32)
    for n in (5, 31, 32, 100):
        ev.evaluate({"pSortMB": np.linspace(32.0, 256.0, n)})
    assert ev.eval_cache_size() == 1
    for n in (40, 64, 333):
        list(search_topk(ev, {"pSortMB": np.linspace(32.0, 256.0, n)}, k=3).entries)
    assert ev.topk_cache_size() == 1


def test_empty_grid_fails_intelligibly():
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    with pytest.raises(ValueError, match="empty"):
        ev.evaluate({"pSortMB": np.array([])})
    with pytest.raises(ValueError, match="empty"):
        ev.chunk_topk({"pSortMB": np.array([])}, k=1)


def test_evaluate_small_matches_chunked_costs():
    ev = ChunkedEvaluator(P, S, C, chunk=64)
    ov = {"pSortMB": np.array([50.0, 100.0, 200.0]), "pSortFactor": 25.0}
    np.testing.assert_allclose(
        ev.evaluate_small(ov).total_cost, ev.evaluate(ov).total_cost, rtol=1e-12
    )


def test_scalar_overrides_and_errors():
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    res = ev.evaluate({"pSortMB": np.array([64.0, 128.0]), "pSortFactor": 25.0})
    ref = ev.evaluate({"pSortMB": np.array([64.0, 128.0]),
                       "pSortFactor": np.array([25.0, 25.0])})
    assert np.array_equal(res.total_cost, ref.total_cost)
    with pytest.raises(KeyError):
        ev.evaluate({"nope": np.array([1.0])})
    with pytest.raises(ValueError):
        ev.evaluate({"pSortMB": 64.0})  # nothing batched
    with pytest.raises(ValueError):
        ev.evaluate({"pSortMB": np.array([1.0, 2.0]),
                     "pSortFactor": np.array([1.0])})


# ------------------------------------------------------------------
# top-k
# ------------------------------------------------------------------


def test_streamed_topk_agrees_with_numpy_oracle():
    ref = _oracle_cost(SPACE)
    k = 7
    # oracle ranking with the same deterministic tie-break (cost, then index)
    order = np.lexsort((np.arange(ref.size), ref))[:k]
    for chunk in (13, 50, 4096):
        ev = ChunkedEvaluator(P, S, C, chunk=chunk)
        res = search_topk(ev, SPACE, k=k)
        assert [e.index for e in res.entries] == [int(i) for i in order], chunk
        assert np.allclose([e.cost for e in res.entries], ref[order], rtol=0, atol=0)
        assert res.n_evaluated == ref.size
        assert res.n_valid == int(np.isfinite(ref).sum())
    # the winning assignment matches the grid row it claims to be
    best = res.entries[0]
    row = space_block(SPACE, best.index, best.index + 1)
    assert best.assignment == {k2: float(v[0]) for k2, v in row.items()}


def test_topk_k_larger_than_grid():
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    res = search_topk(ev, {"pSortMB": [64.0, 128.0]}, k=10)
    assert len(res.entries) == 2


# ------------------------------------------------------------------
# invalid configs: raise vs escape hatch
# ------------------------------------------------------------------


def test_best_raises_on_all_invalid_grid():
    res = evaluate_product_grid(P, S, C, INVALID_SPACE)
    assert not np.isfinite(res.total_cost).any()
    with pytest.raises(InvalidGridError):
        res.best()


def test_escape_hatch_routes_invalid_survivors_to_simulator():
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    res = search_topk(ev, INVALID_SPACE, k=2)
    assert res.n_valid == 0
    assert len(res.entries) == 2
    for e in res.entries:
        assert e.exact and not e.valid
        assert np.isfinite(e.cost) and e.cost > 0
        assert e.cost == pytest.approx(ev.exact_cost(e.assignment))
    assert res.entries[0].cost <= res.entries[1].cost
    # without the hatch the old behavior (nothing rankable) raises
    with pytest.raises(InvalidGridError):
        search_topk(ev, INVALID_SPACE, k=2, exact_fallback=False).best()


def test_coordinate_descent_all_invalid_routes_through_simulator():
    """Regression: on an all-invalid space, argmin of an all-inf sweep used
    to silently return ``best_cost == inf`` with an arbitrary assignment."""
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    res = coordinate_descent_ev(ev, INVALID_SPACE)
    assert np.isfinite(res.best_cost) and res.exact
    # best_cost is the exact-simulator cost of the returned assignment
    assert res.best_cost == pytest.approx(ev.exact_cost(res.best_assignment))
    # ...and it is the optimum the simulator sees over the (tiny) grid
    exact_grid = [
        ev.exact_cost({k: float(v[0]) for k, v in
                       space_block(INVALID_SPACE, i, i + 1).items()})
        for i in range(space_size(INVALID_SPACE))
    ]
    assert res.best_cost == pytest.approx(min(exact_grid))


def test_coordinate_descent_all_invalid_raises_without_hatch():
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    with pytest.raises(InvalidGridError):
        coordinate_descent_ev(ev, INVALID_SPACE, exact_fallback=False)


def test_coordinate_descent_valid_space_unchanged():
    """The hatch must not perturb descent on a space with valid configs."""
    ev = ChunkedEvaluator(P, S, C, chunk=64)
    a = coordinate_descent_ev(ev, SPACE)
    b = coordinate_descent_ev(ev, SPACE, exact_fallback=False)
    assert a.best_assignment == b.best_assignment
    assert a.best_cost == b.best_cost and not a.exact


def test_seed_wrappers_forward_exact_fallback():
    """Regression: grid_search/random_search/coordinate_descent dropped the
    exact_fallback flag instead of forwarding it to the _ev strategies."""
    # hatch on (default): all-invalid space still yields a usable result
    res = grid_search(P, S, C, INVALID_SPACE, chunk=8)
    assert np.isfinite(res.best_cost)
    assert res.topk.best().exact
    # hatch explicitly off: nothing rankable -> raise, not a silent inf
    with pytest.raises(InvalidGridError):
        grid_search(P, S, C, INVALID_SPACE, chunk=8, exact_fallback=False)
    with pytest.raises(InvalidGridError):
        random_search(P, S, C, INVALID_SPACE, samples=16, chunk=8,
                      exact_fallback=False)
    res = random_search(P, S, C, INVALID_SPACE, samples=16, chunk=8)
    assert np.isfinite(res.best_cost)


def test_mixed_grid_prefers_valid_configs():
    space = {"pSortMB": [0.25, 100.0], "pSortFactor": [2.0, 10.0]}
    ev = ChunkedEvaluator(P, S, C, chunk=8)
    res = search_topk(ev, space, k=4)
    assert 0 < res.n_valid < res.n_evaluated
    kinds = [e.valid for e in res.entries]
    # all valid entries come before any exact-costed invalid one
    assert kinds == sorted(kinds, reverse=True)


# ------------------------------------------------------------------
# multi-device sharding (subprocess with 8 forced host devices)
# ------------------------------------------------------------------


def test_sharded_matches_single_device_on_8_devices():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8 "
            + os.environ.get("XLA_FLAGS", "")
        )
        import numpy as np, jax
        jax.config.update("jax_enable_x64", True)
        from repro.core.hadoop import CostFactors, HadoopParams, MiB, ProfileStats
        from repro.search import ChunkedEvaluator, evaluate_unchunked, search_topk
        assert jax.local_device_count() == 8
        P = HadoopParams(pNumNodes=8, pNumMappers=64, pNumReducers=16,
                         pSplitSize=128 * MiB)
        S, C = ProfileStats(sMapSizeSel=0.8), CostFactors()
        ev = ChunkedEvaluator(P, S, C, chunk=40)   # rounded up to 8 devices
        assert ev.chunk % 8 == 0
        vals = np.linspace(16.0, 512.0, 101)       # non-divisible batch
        res = ev.evaluate({"pSortMB": vals})
        ref = evaluate_unchunked(ev.base_cfg, {"pSortMB": vals})
        assert np.array_equal(res.outputs["j_totalCost"], ref["j_totalCost"])
        top = search_topk(ev, {"pSortMB": list(vals)}, k=3)
        order = np.lexsort((np.arange(101), np.where(ref["valid"] > 0,
                            ref["j_totalCost"], np.inf)))[:3]
        assert [e.index for e in top.entries] == [int(i) for i in order]
        print("OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ------------------------------------------------------------------
# TPU evaluator behind the same interface
# ------------------------------------------------------------------


def test_tpu_evaluator_shares_the_strategy_stack():
    pytest.importorskip("repro.configs")
    from repro.configs import SHAPES, get_config

    cfg = get_config("gemma2-9b")
    shape = SHAPES["train_4k"]
    ev = TpuEvaluator(cfg, shape, n_chips=256)
    space = {"dp": [16.0, 32.0, 64.0, 3.0], "tp": [16.0, 8.0, 4.0],
             "n_micro": [1.0, 2.0]}
    res = grid_search_ev(ev, space, exact_fallback=False)
    assert np.isfinite(res.best_cost)
    a = res.best_assignment
    assert a["dp"] * a["tp"] == 256          # chip budget respected
    # oracle: direct step_model on every valid candidate
    from repro.core.tpu_model import TpuParams, step_model
    best = min(
        step_model(cfg, shape, TpuParams(dp=dp, tp=tp, n_micro=nm,
                                         ep=1)).overlap_s
        for dp in (16, 32, 64) for tp in (16, 8, 4) for nm in (1, 2)
        if dp * tp == 256 and shape.global_batch % dp == 0
        and (shape.global_batch // dp) % nm == 0
    )
    assert res.best_cost == pytest.approx(best)
