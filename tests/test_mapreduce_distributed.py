"""shard_map MapReduce pipeline: semantics vs the host engine, and the real
multi-device all_to_all shuffle (8 host devices via subprocess)."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.mapreduce import JOBS, MapReduceEngine, make_input
from repro.mapreduce.distributed import (
    identity_map_jax,
    make_pipeline,
    wordcount_map_jax,
)
from repro.core.hadoop.params import HadoopParams, MiB


def _dense_expected(job, keys, values, key_space):
    """Ground truth via the host engine: aggregate output to dense sums."""
    hp = HadoopParams(
        pNumMappers=2, pNumReducers=4, pUseCombine=job.use_combine,
        pSplitSize=keys.shape[0] * job.pair_width, pTaskMem=8.0 * MiB,
    )
    jc = MapReduceEngine(hp, job).run_job(keys, values)
    ok, ov = jc.output
    dense = np.zeros(key_space, np.float32)
    np.add.at(dense, ok % key_space, ov)
    return dense


def test_pipeline_matches_engine_single_device():
    key_space = 1024
    job = JOBS["wordcount"]
    keys, values = make_input(job, 4096)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    pipe = make_pipeline(mesh, map_fn=wordcount_map_jax, key_space=key_space)
    out = np.asarray(pipe(keys.astype(np.int32), values))
    expected = _dense_expected(job, keys, values, key_space)
    np.testing.assert_allclose(out, expected, rtol=1e-5)


def test_pipeline_pallas_combine_single_device():
    key_space = 512
    job = JOBS["wordcount"]
    keys, values = make_input(job, 2048, seed=3)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    ref_pipe = make_pipeline(mesh, key_space=key_space, use_pallas=False)
    pl_pipe = make_pipeline(mesh, key_space=key_space, use_pallas=True)
    a = np.asarray(ref_pipe(keys.astype(np.int32), values))
    b = np.asarray(pl_pipe(keys.astype(np.int32), values))
    np.testing.assert_allclose(a, b, rtol=1e-5)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.mapreduce import JOBS, make_input
    from repro.mapreduce.distributed import make_pipeline, wordcount_map_jax

    key_space = 1024
    job = JOBS["wordcount"]
    keys, values = make_input(job, 8192)
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
    pipe = make_pipeline(mesh, map_fn=wordcount_map_jax, key_space=key_space)
    lowered = pipe.lower(keys.astype(np.int32), values)
    hlo = lowered.compile().as_text()
    assert "all-to-all" in hlo, "shuffle must lower to all-to-all"
    out = np.asarray(pipe(keys.astype(np.int32), values))
    np.save("/tmp/mr_dist_out.npy", out)
    print("OK", out.sum())
""")


def test_pipeline_8way_shuffle_subprocess():
    """Real 8-device mesh: the shuffle lowers to all-to-all and the result
    equals the host engine's."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, cwd=os.getcwd(),
        capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
    out = np.load("/tmp/mr_dist_out.npy")
    job = JOBS["wordcount"]
    keys, values = make_input(job, 8192)
    expected = _dense_expected(job, keys, values, 1024)
    np.testing.assert_allclose(out, expected, rtol=1e-5)


def test_pipeline_identity_map_sort_semantics():
    """Range partitioning: reducer r owns keys [r*block, (r+1)*block) — the
    pipeline's dense output is globally key-ordered (TotalOrderPartitioner)."""
    key_space = 256
    job = JOBS["sort"]
    keys, values = make_input(job, 2000, seed=5)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    pipe = make_pipeline(mesh, map_fn=identity_map_jax, key_space=key_space)
    out = np.asarray(pipe(keys.astype(np.int32), values))
    dense = np.zeros(key_space, np.float32)
    np.add.at(dense, keys % key_space, values)
    np.testing.assert_allclose(out, dense, rtol=1e-5)
