"""Unit tests for the fine-grained MoE layer (routing, capacity, aux loss)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models.moe import apply_moe, init_moe

CFG = get_config("deepseek-moe-16b").smoke()


def _setup(key=0, B=2, S=16):
    params = init_moe(jax.random.PRNGKey(key), CFG)
    x = jax.random.normal(jax.random.PRNGKey(key + 1), (B, S, CFG.d_model)) * 0.5
    return params, x


def test_output_shape_and_finite():
    params, x = _setup()
    y, aux = apply_moe(params, x, CFG)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.isfinite(float(aux))


def test_aux_loss_lower_bound():
    """Switch aux loss is minimized at 1.0 for perfectly uniform routing."""
    params, x = _setup()
    _, aux = apply_moe(params, x, CFG)
    assert float(aux) >= 1.0 - 1e-3


def test_dropless_capacity_is_length_independent():
    """With capacity=T, a token's output is independent of later tokens."""
    params, x = _setup(B=1, S=12)
    y_full, _ = apply_moe(params, x, CFG, capacity=12)
    y_short, _ = apply_moe(params, x[:, :8], CFG, capacity=8)
    np.testing.assert_allclose(
        np.asarray(y_full[:, :8]), np.asarray(y_short), rtol=1e-5, atol=1e-6
    )


def test_tiny_capacity_drops_tokens():
    """capacity=1 must drop expert traffic: output differs from dropless and
    dropped tokens fall back to (shared-expert-only or zero) contribution."""
    params, x = _setup(B=2, S=32)
    y_drop, _ = apply_moe(params, x, CFG, capacity=1)
    y_free, _ = apply_moe(params, x, CFG, capacity=64)
    assert not np.allclose(np.asarray(y_drop), np.asarray(y_free), atol=1e-5)


def test_priority_is_token_order():
    """With capacity=1, the first token claiming an expert wins its slot:
    prepending a competing token changes later tokens' outputs, never the
    other way around (causal capacity competition)."""
    params, x = _setup(B=1, S=8)
    y, _ = apply_moe(params, x, CFG, capacity=1)
    # duplicate token 0 at the front: token 0's (now token 1) slots may be
    # stolen by its twin, but output for the *first* occurrence is unchanged.
    x2 = jnp.concatenate([x[:, :1], x], axis=1)
    y2, _ = apply_moe(params, x2, CFG, capacity=1)
    np.testing.assert_allclose(
        np.asarray(y2[:, 0]), np.asarray(y[:, 0]), rtol=1e-5, atol=1e-6
    )


def test_shared_expert_always_on():
    """Zero routed capacity still yields the shared-expert contribution."""
    params, x = _setup()
    assert "shared" in params  # deepseek smoke keeps 1 shared expert
    y, _ = apply_moe(params, x, CFG, capacity=1)
    assert np.abs(np.asarray(y)).max() > 0


@given(st.integers(1, 4), st.integers(1, 24))
@settings(max_examples=20, deadline=None)
def test_moe_shapes_property(b, s):
    params = init_moe(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, CFG.d_model)) * 0.3
    y, aux = apply_moe(params, x, CFG)
    assert y.shape == (b, s, CFG.d_model)
    assert np.all(np.isfinite(np.asarray(y)))
