"""repro.cluster — multi-job DES, vectorized wave simulator, planner."""

import numpy as np
import pytest

from repro.cluster import (
    ClusterConfig,
    ClusterEvaluator,
    JobArrival,
    JobClass,
    WorkloadTrace,
    bursty_trace,
    default_job_classes,
    estimate_steps,
    pack_trace,
    poisson_trace,
    rescale,
    simulate_batch,
    simulate_workload,
)
from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.core.hadoop.simulator import SimConfig, simulate_job
from repro.search import WhatIfService, grid_search_ev, search_topk

CLASSES = default_job_classes()
CLEAN = SimConfig(speculative_execution=False)
NOISY = SimConfig(seed=11, task_time_jitter=0.2, straggler_prob=0.1)


def scenario_for(trace, cc: ClusterConfig, rate: float, fair: float = 0.0):
    cols = pack_trace(trace)
    n = cc.num_nodes
    return {
        "arrival": (cols["arrival"] / rate)[None, :],
        "n_maps": cols["n_maps"][None, :],
        "n_reds": cols["n_reds"][None, :],
        "map_cost": cols["map_cost"][None, :],
        "red_work": cols["red_work"][None, :],
        "shuffle": (cols["shuffle"] * (n - 1) / n)[None, :],
        "map_slots": np.array([float(n * cc.map_slots_per_node)]),
        "red_slots": np.array([float(n * cc.reduce_slots_per_node)]),
        "fair": np.array([fair]),
        "slowstart": np.array([cc.reduce_slowstart]),
    }


# ------------------------------------------------------------------ workload


def test_traces_sorted_and_rescaled():
    tr = poisson_trace(CLASSES, 16, rate=1.0, seed=3)
    times = tr.submit_times
    assert tr.n_jobs == 16 and times[0] == 0.0
    assert np.all(np.diff(times) >= 0)
    fast = rescale(tr, 4.0)
    assert np.allclose(fast.submit_times, times / 4.0)
    with pytest.raises(ValueError):
        rescale(tr, 0.0)


def test_bursty_trace_shape():
    tr = bursty_trace(CLASSES, n_bursts=3, burst_size=4, burst_gap=50.0)
    assert tr.n_jobs == 12
    # each burst's jobs land within one intra-gap window of each other
    t = tr.submit_times.reshape(3, 4)
    assert np.all(t[:, -1] - t[:, 0] < 50.0)


# ------------------------------------------------------------- multi-job DES


def test_single_job_trace_reproduces_simulate_job():
    """One job on the shared cluster == the single-job simulator, exactly —
    including under jitter, stragglers and speculation (same RNG draws)."""
    p = HadoopParams(pNumNodes=4, pNumMappers=32, pNumReducers=8,
                     pSplitSize=64 * MiB)
    jc = JobClass("one", p, ProfileStats(), CostFactors())
    tr = WorkloadTrace((JobArrival(0, jc, 0.0),))
    for sim in (CLEAN, NOISY, SimConfig(seed=2, task_time_jitter=0.3)):
        ref = simulate_job(p, ProfileStats(), CostFactors(), sim)
        got = simulate_workload(tr, ClusterConfig.from_params(p), sim)
        assert got.jobs[0].finish == ref.makespan
        assert got.jobs[0].map_finish == ref.map_finish_time
        assert got.num_speculative_launched == ref.num_speculative_launched


def test_workload_deterministic_and_seed_sensitive():
    tr = rescale(poisson_trace(CLASSES, 10, seed=4), 0.1)
    a = simulate_workload(tr, ClusterConfig(), NOISY)
    b = simulate_workload(tr, ClusterConfig(), NOISY)
    assert a.latencies().tolist() == b.latencies().tolist()
    assert len(a.records) == len(b.records)
    c = simulate_workload(tr, ClusterConfig(), SimConfig(
        seed=NOISY.seed + 1, task_time_jitter=0.2, straggler_prob=0.1))
    assert a.latencies().tolist() != c.latencies().tolist()


def test_all_jobs_complete_and_accounting():
    tr = rescale(poisson_trace(CLASSES, 12, seed=5), 0.2)
    r = simulate_workload(tr, ClusterConfig(num_nodes=4), CLEAN)
    assert all(np.isfinite(j.finish) for j in r.jobs)
    assert all(j.queueing_delay >= 0 and j.latency > 0 for j in r.jobs)
    assert len(r.node_busy_s) == 4
    assert 0 < r.slot_utilization <= 1
    # busy time equals the sum of record occupancy
    assert sum(r.node_busy_s) == pytest.approx(
        sum(rec.end - rec.start for rec in r.records))


def test_fair_share_protects_small_job_behind_big_one():
    """FIFO invariant: a small job queued behind a big one waits; fair-share
    gives it a share of the slots immediately."""
    big = JobClass("big", HadoopParams(pNumMappers=64, pNumReducers=8,
                                       pSplitSize=64 * MiB),
                   ProfileStats(), CostFactors())
    small = JobClass("small", HadoopParams(pNumMappers=4, pNumReducers=1,
                                           pSplitSize=64 * MiB),
                     ProfileStats(), CostFactors())
    tr = WorkloadTrace((JobArrival(0, big, 0.0), JobArrival(1, small, 1.0)))
    fifo = simulate_workload(tr, ClusterConfig(num_nodes=2), CLEAN)
    fair = simulate_workload(
        tr, ClusterConfig(num_nodes=2, scheduler="fair"), CLEAN)
    assert fair.jobs[1].latency < fifo.jobs[1].latency
    # work conservation: both policies complete both jobs
    assert all(np.isfinite(j.finish) for j in fifo.jobs + fair.jobs)


def test_node_failure_requeues_across_jobs():
    tr = rescale(poisson_trace(CLASSES, 6, seed=6), 0.05)
    base = simulate_workload(tr, ClusterConfig(), CLEAN)
    # t=1.0: the first job's map fleet (>= 16 tasks on 8 slots) is still
    # occupying every node, so the failure must kill in-flight work
    failed = simulate_workload(
        tr, ClusterConfig(),
        SimConfig(speculative_execution=False, node_failures=((1.0, 0),)))
    assert failed.num_failure_reruns > 0
    assert all(np.isfinite(j.finish) for j in failed.jobs)
    assert failed.makespan >= base.makespan


# ------------------------------------------------- DES <-> vectorized rollout


@pytest.mark.parametrize("label,nodes,rate", [
    ("serialized", 4, 0.002),
    ("uncontended", 64, 0.1),
    ("contended", 4, 0.1),
    ("heavy", 2, 0.5),
])
def test_vector_sim_matches_des_fifo(label, nodes, rate):
    """Wave rollout vs DES per-job finish times (exact wave structure on
    contention-free FIFO; the contended rows document that the wave-merge
    approximation stays tight on these workloads)."""
    tr = poisson_trace(CLASSES, 10, rate=1.0, seed=1)
    cc = ClusterConfig(num_nodes=nodes)
    des = simulate_workload(rescale(tr, rate), cc, CLEAN)
    out = simulate_batch(scenario_for(tr, cc, rate))
    assert out["converged"][0] == 1.0
    des_fin = np.array([j.finish for j in des.jobs])
    np.testing.assert_allclose(out["finish"][0], des_fin, rtol=1e-3)
    assert out["p95_latency"][0] == pytest.approx(des.p95_latency, rel=1e-3)


def test_vector_sim_property_uncontended_agreement():
    """Property test: random uncontended FIFO scenarios agree with the DES
    (slots cover every job's full parallelism, so waves never fragment)."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    # slowstart floor at 0.01: with ss == 0 exactly, the DES launches
    # reducers at the first map *completion* (its check runs on completion
    # events) while the wave model launches at arrival — a documented
    # granularity edge, not a wave-structure bug
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000), rate=st.floats(0.01, 0.5),
           n_jobs=st.integers(2, 8), slowstart=st.floats(0.01, 1.0))
    def check(seed, rate, n_jobs, slowstart):
        tr = poisson_trace(CLASSES, n_jobs, rate=1.0, seed=seed)
        # uncontended: slots cover every job's full parallelism at once
        need = max(sum(a.klass.n_maps for a in tr.arrivals),
                   sum(a.klass.n_reduces for a in tr.arrivals), 1)
        nodes = -(-need // 2)
        cc = ClusterConfig(num_nodes=nodes, reduce_slowstart=slowstart)
        des = simulate_workload(rescale(tr, rate), cc, CLEAN)
        out = simulate_batch(scenario_for(tr, cc, rate))
        assert out["converged"][0] == 1.0
        des_fin = np.array([j.finish for j in des.jobs])
        np.testing.assert_allclose(out["finish"][0], des_fin, rtol=2e-3)

    check()


def test_vector_sim_fair_converges_and_orders():
    tr = poisson_trace(CLASSES, 12, rate=1.0, seed=2)
    cc = ClusterConfig(num_nodes=2)
    out = simulate_batch(scenario_for(tr, cc, 0.5, fair=1.0))
    assert out["converged"][0] == 1.0
    assert np.isfinite(out["p95_latency"][0])


def test_truncation_is_flagged_not_silent():
    tr = poisson_trace(CLASSES, 8, rate=1.0, seed=0)
    out = simulate_batch(scenario_for(tr, ClusterConfig(num_nodes=2), 0.5),
                         n_steps=4)
    assert out["converged"][0] == 0.0


def test_estimate_steps_power_of_two():
    tr = poisson_trace(CLASSES, 8, rate=1.0, seed=0)
    scen = scenario_for(tr, ClusterConfig(), 0.1)
    n = estimate_steps(scen)
    assert n & (n - 1) == 0 and n > 0


# ------------------------------------------------------------------ planner


@pytest.fixture(scope="module")
def evaluator():
    return ClusterEvaluator(CLASSES, n_jobs=10, n_seeds=2, chunk=16,
                            base_rate=0.05, objective="p95")


def test_evaluator_monotone_in_capacity(evaluator):
    res = evaluator.evaluate({"pNumNodes": np.array([2.0, 4.0, 8.0, 16.0])})
    assert res.outputs["valid"].all()
    assert np.all(np.diff(res.total_cost) <= 1e-3)      # more nodes, no worse
    assert np.all(np.diff(res.outputs["w_util"]) < 0)   # ... less utilized


def test_evaluator_exact_cost_close_on_light_load(evaluator):
    vec = float(evaluator.evaluate({"pNumNodes": np.array([16.0])}).total_cost[0])
    des = evaluator.exact_cost({"pNumNodes": 16.0})
    assert vec == pytest.approx(des, rel=0.05)


def test_evaluator_invalid_rows(evaluator):
    res = evaluator.evaluate({"pNumNodes": np.array([0.0, 4.0])})
    assert res.outputs["valid"][0] == 0.0 and np.isinf(res.total_cost[0])
    assert res.outputs["valid"][1] == 1.0
    assert evaluator.exact_cost({"pNumNodes": 0.0}) == np.inf
    # a zero-slot row is masked invalid AND must not stall the chunk's
    # shared while_loop (its lane simulates sanitized knobs instead)
    res2 = evaluator.evaluate({"pMaxMapsPerNode": np.array([0.0, 2.0])})
    assert res2.outputs["valid"][0] == 0.0 and np.isinf(res2.total_cost[0])
    assert res2.outputs["valid"][1] == 1.0 and np.isfinite(res2.total_cost[1])


def test_grid_search_and_topk_end_to_end(evaluator):
    space = {"pNumNodes": [2.0, 4.0, 8.0], "schedFair": [0.0, 1.0]}
    plan = grid_search_ev(evaluator, space)
    assert np.isfinite(plan.best_cost) and plan.evaluations == 6
    assert set(plan.best_assignment) == set(space)
    top = search_topk(evaluator, space, k=3)
    assert top.best().cost == pytest.approx(plan.best_cost)
    assert [e.cost for e in top.entries] == sorted(e.cost for e in top.entries)


def test_whatif_service_bit_for_bit(evaluator):
    vals = np.asarray([0.02, 0.05, 0.1], np.float32)
    with WhatIfService(evaluator) as svc:
        swept = svc.sweep("arrivalRate", vals).result()
        probe = svc.probe({"pNumNodes": 8.0}).result()
    seq = evaluator.evaluate({"arrivalRate": vals})
    assert np.array_equal(swept.total_cost, seq.total_cost)
    for k in seq.outputs:
        assert np.array_equal(swept.outputs[k], seq.outputs[k]), k
    assert probe.total_cost.shape == (1,) and np.isfinite(probe.total_cost[0])
