"""repro.cluster — multi-job DES, vectorized wave simulator, planner."""

import numpy as np
import pytest

from repro.cluster import (
    ClusterConfig,
    ClusterEvaluator,
    JobArrival,
    JobClass,
    NodeClass,
    UnfinishedWorkloadError,
    WorkloadTrace,
    bursty_trace,
    default_job_classes,
    estimate_steps,
    pack_trace,
    poisson_trace,
    rescale,
    simulate_batch,
    simulate_workload,
)
from repro.cluster.workload import task_costs
from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.core.hadoop.simulator import SimConfig, simulate_job
from repro.search import WhatIfService, grid_search_ev, search_topk

CLASSES = default_job_classes()
CLEAN = SimConfig(speculative_execution=False)
NOISY = SimConfig(seed=11, task_time_jitter=0.2, straggler_prob=0.1)


def scenario_for(trace, cc: ClusterConfig, rate: float, fair: float = 0.0,
                 *, policy: float | None = None,
                 queue_frac: list | None = None):
    """Wave-model scenario mirroring ``cc`` (including a heterogeneous
    ``node_classes`` fleet as per-class slot columns, fastest first)."""
    cols = pack_trace(trace)
    n = cc.num_nodes
    fleet = sorted(cc.node_classes, key=lambda nc: -nc.speedup) \
        or [NodeClass(n, 1.0)]
    scen = {
        "arrival": (cols["arrival"] / rate)[None, :],
        "n_maps": cols["n_maps"][None, :],
        "n_reds": cols["n_reds"][None, :],
        "map_cost": cols["map_cost"][None, :],
        "red_work": cols["red_work"][None, :],
        "shuffle": (cols["shuffle"] * (n - 1) / n)[None, :],
        "queue": cols["queue"][None, :],
        "map_slots": np.array(
            [[float(nc.count * cc.map_slots_per_node) for nc in fleet]]),
        "red_slots": np.array(
            [[float(nc.count * cc.reduce_slots_per_node) for nc in fleet]]),
        "speedup": np.array([[nc.speedup for nc in fleet]]),
        "policy": np.array([float(fair) if policy is None else float(policy)]),
        "slowstart": np.array([cc.reduce_slowstart]),
    }
    if queue_frac is not None:
        scen["queue_frac"] = np.array([queue_frac], dtype=np.float64)
    return scen


# ------------------------------------------------------------------ workload


def test_traces_sorted_and_rescaled():
    tr = poisson_trace(CLASSES, 16, rate=1.0, seed=3)
    times = tr.submit_times
    assert tr.n_jobs == 16 and times[0] == 0.0
    assert np.all(np.diff(times) >= 0)
    fast = rescale(tr, 4.0)
    assert np.allclose(fast.submit_times, times / 4.0)
    with pytest.raises(ValueError):
        rescale(tr, 0.0)


def test_bursty_trace_shape():
    tr = bursty_trace(CLASSES, n_bursts=3, burst_size=4, burst_gap=50.0)
    assert tr.n_jobs == 12
    # each burst's jobs land within one intra-gap window of each other
    t = tr.submit_times.reshape(3, 4)
    assert np.all(t[:, -1] - t[:, 0] < 50.0)


# ------------------------------------------------------------- multi-job DES


def test_single_job_trace_reproduces_simulate_job():
    """One job on the shared cluster == the single-job simulator, exactly —
    including under jitter, stragglers and speculation (same RNG draws)."""
    p = HadoopParams(pNumNodes=4, pNumMappers=32, pNumReducers=8,
                     pSplitSize=64 * MiB)
    jc = JobClass("one", p, ProfileStats(), CostFactors())
    tr = WorkloadTrace((JobArrival(0, jc, 0.0),))
    for sim in (CLEAN, NOISY, SimConfig(seed=2, task_time_jitter=0.3)):
        ref = simulate_job(p, ProfileStats(), CostFactors(), sim)
        got = simulate_workload(tr, ClusterConfig.from_params(p), sim)
        assert got.jobs[0].finish == ref.makespan
        assert got.jobs[0].map_finish == ref.map_finish_time
        assert got.num_speculative_launched == ref.num_speculative_launched


def test_workload_deterministic_and_seed_sensitive():
    tr = rescale(poisson_trace(CLASSES, 10, seed=4), 0.1)
    a = simulate_workload(tr, ClusterConfig(), NOISY)
    b = simulate_workload(tr, ClusterConfig(), NOISY)
    assert a.latencies().tolist() == b.latencies().tolist()
    assert len(a.records) == len(b.records)
    c = simulate_workload(tr, ClusterConfig(), SimConfig(
        seed=NOISY.seed + 1, task_time_jitter=0.2, straggler_prob=0.1))
    assert a.latencies().tolist() != c.latencies().tolist()


def test_all_jobs_complete_and_accounting():
    tr = rescale(poisson_trace(CLASSES, 12, seed=5), 0.2)
    r = simulate_workload(tr, ClusterConfig(num_nodes=4), CLEAN)
    assert all(np.isfinite(j.finish) for j in r.jobs)
    assert all(j.queueing_delay >= 0 and j.latency > 0 for j in r.jobs)
    assert len(r.node_busy_s) == 4
    assert 0 < r.slot_utilization <= 1
    # busy time equals the sum of record occupancy
    assert sum(r.node_busy_s) == pytest.approx(
        sum(rec.end - rec.start for rec in r.records))


def test_fair_share_protects_small_job_behind_big_one():
    """FIFO invariant: a small job queued behind a big one waits; fair-share
    gives it a share of the slots immediately."""
    big = JobClass("big", HadoopParams(pNumMappers=64, pNumReducers=8,
                                       pSplitSize=64 * MiB),
                   ProfileStats(), CostFactors())
    small = JobClass("small", HadoopParams(pNumMappers=4, pNumReducers=1,
                                           pSplitSize=64 * MiB),
                     ProfileStats(), CostFactors())
    tr = WorkloadTrace((JobArrival(0, big, 0.0), JobArrival(1, small, 1.0)))
    fifo = simulate_workload(tr, ClusterConfig(num_nodes=2), CLEAN)
    fair = simulate_workload(
        tr, ClusterConfig(num_nodes=2, scheduler="fair"), CLEAN)
    assert fair.jobs[1].latency < fifo.jobs[1].latency
    # work conservation: both policies complete both jobs
    assert all(np.isfinite(j.finish) for j in fifo.jobs + fair.jobs)


def test_node_failure_requeues_across_jobs():
    tr = rescale(poisson_trace(CLASSES, 6, seed=6), 0.05)
    base = simulate_workload(tr, ClusterConfig(), CLEAN)
    # t=1.0: the first job's map fleet (>= 16 tasks on 8 slots) is still
    # occupying every node, so the failure must kill in-flight work
    failed = simulate_workload(
        tr, ClusterConfig(),
        SimConfig(speculative_execution=False, node_failures=((1.0, 0),)))
    assert failed.num_failure_reruns > 0
    assert all(np.isfinite(j.finish) for j in failed.jobs)
    assert failed.makespan >= base.makespan


# ------------------------------------------------- DES <-> vectorized rollout


@pytest.mark.parametrize("label,nodes,rate", [
    ("serialized", 4, 0.002),
    ("uncontended", 64, 0.1),
    ("contended", 4, 0.1),
    ("heavy", 2, 0.5),
])
def test_vector_sim_matches_des_fifo(label, nodes, rate):
    """Wave rollout vs DES per-job finish times (exact wave structure on
    contention-free FIFO; the contended rows document that the wave-merge
    approximation stays tight on these workloads)."""
    tr = poisson_trace(CLASSES, 10, rate=1.0, seed=1)
    cc = ClusterConfig(num_nodes=nodes)
    des = simulate_workload(rescale(tr, rate), cc, CLEAN)
    out = simulate_batch(scenario_for(tr, cc, rate))
    assert out["converged"][0] == 1.0
    des_fin = np.array([j.finish for j in des.jobs])
    np.testing.assert_allclose(out["finish"][0], des_fin, rtol=1e-3)
    assert out["p95_latency"][0] == pytest.approx(des.p95_latency, rel=1e-3)


def test_vector_sim_property_uncontended_agreement():
    """Property test: random uncontended FIFO scenarios agree with the DES
    (slots cover every job's full parallelism, so waves never fragment)."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    # slowstart floor at 0.01: with ss == 0 exactly, the DES launches
    # reducers at the first map *completion* (its check runs on completion
    # events) while the wave model launches at arrival — a documented
    # granularity edge, not a wave-structure bug
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000), rate=st.floats(0.01, 0.5),
           n_jobs=st.integers(2, 8), slowstart=st.floats(0.01, 1.0))
    def check(seed, rate, n_jobs, slowstart):
        tr = poisson_trace(CLASSES, n_jobs, rate=1.0, seed=seed)
        # uncontended: slots cover every job's full parallelism at once
        need = max(sum(a.klass.n_maps for a in tr.arrivals),
                   sum(a.klass.n_reduces for a in tr.arrivals), 1)
        nodes = -(-need // 2)
        cc = ClusterConfig(num_nodes=nodes, reduce_slowstart=slowstart)
        des = simulate_workload(rescale(tr, rate), cc, CLEAN)
        out = simulate_batch(scenario_for(tr, cc, rate))
        assert out["converged"][0] == 1.0
        des_fin = np.array([j.finish for j in des.jobs])
        np.testing.assert_allclose(out["finish"][0], des_fin, rtol=2e-3)

    check()


def test_vector_sim_fair_converges_and_orders():
    tr = poisson_trace(CLASSES, 12, rate=1.0, seed=2)
    cc = ClusterConfig(num_nodes=2)
    out = simulate_batch(scenario_for(tr, cc, 0.5, fair=1.0))
    assert out["converged"][0] == 1.0
    assert np.isfinite(out["p95_latency"][0])


def test_truncation_is_flagged_not_silent():
    tr = poisson_trace(CLASSES, 8, rate=1.0, seed=0)
    out = simulate_batch(scenario_for(tr, ClusterConfig(num_nodes=2), 0.5),
                         n_steps=4)
    assert out["converged"][0] == 0.0


def test_estimate_steps_power_of_two():
    tr = poisson_trace(CLASSES, 8, rate=1.0, seed=0)
    scen = scenario_for(tr, ClusterConfig(), 0.1)
    n = estimate_steps(scen)
    assert n & (n - 1) == 0 and n > 0


# ------------------------------------------------------------------ planner


@pytest.fixture(scope="module")
def evaluator():
    return ClusterEvaluator(CLASSES, n_jobs=10, n_seeds=2, chunk=16,
                            base_rate=0.05, objective="p95")


def test_evaluator_monotone_in_capacity(evaluator):
    res = evaluator.evaluate({"pNumNodes": np.array([2.0, 4.0, 8.0, 16.0])})
    assert res.outputs["valid"].all()
    assert np.all(np.diff(res.total_cost) <= 1e-3)      # more nodes, no worse
    assert np.all(np.diff(res.outputs["w_util"]) < 0)   # ... less utilized


def test_evaluator_exact_cost_close_on_light_load(evaluator):
    vec = float(evaluator.evaluate({"pNumNodes": np.array([16.0])}).total_cost[0])
    des = evaluator.exact_cost({"pNumNodes": 16.0})
    assert vec == pytest.approx(des, rel=0.05)


def test_evaluator_invalid_rows(evaluator):
    res = evaluator.evaluate({"pNumNodes": np.array([0.0, 4.0])})
    assert res.outputs["valid"][0] == 0.0 and np.isinf(res.total_cost[0])
    assert res.outputs["valid"][1] == 1.0
    assert evaluator.exact_cost({"pNumNodes": 0.0}) == np.inf
    # a zero-slot row is masked invalid AND must not stall the chunk's
    # shared while_loop (its lane simulates sanitized knobs instead)
    res2 = evaluator.evaluate({"pMaxMapsPerNode": np.array([0.0, 2.0])})
    assert res2.outputs["valid"][0] == 0.0 and np.isinf(res2.total_cost[0])
    assert res2.outputs["valid"][1] == 1.0 and np.isfinite(res2.total_cost[1])


def test_grid_search_and_topk_end_to_end(evaluator):
    space = {"pNumNodes": [2.0, 4.0, 8.0], "schedFair": [0.0, 1.0]}
    plan = grid_search_ev(evaluator, space)
    assert np.isfinite(plan.best_cost) and plan.evaluations == 6
    assert set(plan.best_assignment) == set(space)
    top = search_topk(evaluator, space, k=3)
    assert top.best().cost == pytest.approx(plan.best_cost)
    assert [e.cost for e in top.entries] == sorted(e.cost for e in top.entries)


# ---------------------------------------------- heterogeneity + preemption


def _big_small_trace():
    """One big job hogging the cluster, one small job behind it — the
    canonical preemption scenario (distinct class names = two queues)."""
    big = JobClass("batch", HadoopParams(pNumMappers=64, pNumReducers=8,
                                         pSplitSize=64 * MiB),
                   ProfileStats(), CostFactors())
    small = JobClass("adhoc", HadoopParams(pNumMappers=4, pNumReducers=1,
                                           pSplitSize=64 * MiB),
                     ProfileStats(), CostFactors())
    return WorkloadTrace((JobArrival(0, big, 0.0), JobArrival(1, small, 30.0)))


def test_heterogeneous_fleet_orders_latency():
    """More fast silicon at a fixed fleet size strictly helps; num_nodes is
    derived from the class counts."""
    tr = rescale(poisson_trace(CLASSES, 8, seed=1), 0.05)
    cc_het = ClusterConfig(node_classes=(NodeClass(2, 2.0), NodeClass(2, 1.0)))
    assert cc_het.num_nodes == 4
    base = simulate_workload(tr, ClusterConfig(num_nodes=4), CLEAN)
    het = simulate_workload(tr, cc_het, CLEAN)
    fast = simulate_workload(
        tr, ClusterConfig(node_classes=(NodeClass(4, 2.0),)), CLEAN)
    assert fast.p95_latency < het.p95_latency < base.p95_latency
    for r in (base, het, fast):
        assert all(np.isfinite(j.finish) for j in r.jobs)


def test_heterogeneous_homogeneous_speedup_one_is_identical():
    """A one-class fleet at speedup 1.0 is byte-for-byte the homogeneous
    simulation (same RNG draw order, same schedule)."""
    tr = rescale(poisson_trace(CLASSES, 6, seed=2), 0.1)
    a = simulate_workload(tr, ClusterConfig(num_nodes=4), NOISY)
    b = simulate_workload(
        tr, ClusterConfig(node_classes=(NodeClass(4, 1.0),)), NOISY)
    assert a.latencies().tolist() == b.latencies().tolist()
    assert len(a.records) == len(b.records)


def test_preemption_protects_small_job_and_respects_timeout():
    tr = _big_small_trace()
    runs = {
        sched + str(to): simulate_workload(
            tr, ClusterConfig(num_nodes=2, scheduler=sched,
                              preempt_timeout=to), CLEAN)
        for sched, to in [("fifo", 0.0), ("fair", 0.0),
                          ("fair_preempt", 0.0), ("fair_preempt", 20.0)]
    }
    small = {k: r.jobs[1].latency for k, r in runs.items()}
    # preemption beats non-preemptive fair beats FIFO for the queued job
    assert small["fair_preempt0.0"] < small["fair0.0"] < small["fifo0.0"]
    # a longer grace period preempts later (and kills fewer tasks)
    assert small["fair_preempt0.0"] < small["fair_preempt20.0"] < small["fair0.0"]
    assert (runs["fair_preempt0.0"].num_preempted
            >= runs["fair_preempt20.0"].num_preempted > 0)
    assert runs["fifo0.0"].num_preempted == 0
    # work conservation: killed-and-requeued tasks still complete every job
    for r in runs.values():
        assert all(np.isfinite(j.finish) for j in r.jobs)
        assert r.n_unfinished == 0


def test_capacity_scheduler_guarantees_queue_share():
    tr = _big_small_trace()
    fifo = simulate_workload(tr, ClusterConfig(num_nodes=2), CLEAN)
    cap = simulate_workload(
        tr, ClusterConfig(num_nodes=2, scheduler="capacity",
                          preempt_timeout=0.0), CLEAN)
    weighted = simulate_workload(
        tr, ClusterConfig(num_nodes=2, scheduler="capacity",
                          preempt_timeout=0.0,
                          capacities={"adhoc": 3.0, "batch": 1.0}), CLEAN)
    assert cap.jobs[1].latency < fifo.jobs[1].latency
    assert weighted.jobs[1].latency <= cap.jobs[1].latency
    assert cap.num_preempted > 0


@pytest.mark.parametrize("policy,sched", [
    (2.0, "fair_preempt"),
    (3.0, "capacity"),
])
def test_vector_sim_matches_des_preemptive(policy, sched):
    """Kill-and-requeue preemption agrees DES<->wave on the canonical
    big/small scenario (rtol 1e-3) — and preemption actually fires."""
    tr = _big_small_trace()
    cc = ClusterConfig(num_nodes=2, scheduler=sched, preempt_timeout=0.0)
    des = simulate_workload(tr, cc, CLEAN)
    assert des.num_preempted > 0
    out = simulate_batch(scenario_for(tr, cc, 1.0, policy=policy,
                                      queue_frac=[0.5, 0.5]))
    assert out["converged"][0] == 1.0
    des_fin = np.array([j.finish for j in des.jobs])
    np.testing.assert_allclose(out["finish"][0], des_fin, rtol=1e-3)


def test_vector_sim_matches_des_heterogeneous_uncontended():
    """Mixed fleets agree DES<->wave exactly when slots cover the offered
    parallelism (both fill the fast class first; each class's sub-wave
    completes at its own scaled duration)."""
    tr = poisson_trace(CLASSES, 10, rate=1.0, seed=1)
    cc = ClusterConfig(node_classes=(NodeClass(32, 2.0), NodeClass(32, 1.0)))
    des = simulate_workload(rescale(tr, 0.1), cc, CLEAN)
    out = simulate_batch(scenario_for(tr, cc, 0.1))
    assert out["converged"][0] == 1.0
    des_fin = np.array([j.finish for j in des.jobs])
    np.testing.assert_allclose(out["finish"][0], des_fin, rtol=1e-3)
    # and the fast fleet is strictly faster than an all-baseline one
    hom = simulate_batch(scenario_for(
        tr, ClusterConfig(num_nodes=64), 0.1))
    assert out["p95_latency"][0] < hom["p95_latency"][0]


# ------------------------------------------------------- failure-path fixes


def test_unfinished_workload_is_flagged_not_silent():
    """Every node failing leaves jobs unfinished: the result says so
    explicitly (n_unfinished) instead of only an inf latency aggregate."""
    tr = rescale(poisson_trace(CLASSES, 6, seed=3), 0.2)
    dead = simulate_workload(
        tr, ClusterConfig(num_nodes=2),
        SimConfig(speculative_execution=False,
                  node_failures=((1.0, 0), (1.0, 1))))
    assert dead.n_unfinished > 0
    assert np.isinf(dead.mean_latency) and np.isinf(dead.p95_latency)
    ok = simulate_workload(tr, ClusterConfig(num_nodes=2), CLEAN)
    assert ok.n_unfinished == 0 and np.isfinite(ok.mean_latency)


def test_exact_cost_raises_on_unfinished_workload():
    ev = ClusterEvaluator(
        CLASSES, n_jobs=6, n_seeds=1, chunk=8, base_rate=0.2,
        sim=SimConfig(speculative_execution=False,
                      node_failures=((1.0, 0), (1.0, 1))))
    with pytest.raises(UnfinishedWorkloadError, match="never finished"):
        ev.exact_cost({"pNumNodes": 2.0})


def test_slot_utilization_two_segment_hand_computed():
    """2 nodes x 1 map slot, 2 equal maps, node 1 dies halfway through:
    node 0 is busy for the whole (doubled) run and node 1 contributes
    capacity only until its failure — utilization is exactly 1.  The old
    denominator charged the dead node for the full makespan (0.625)."""
    jc = JobClass("maps", HadoopParams(pNumMappers=2, pNumReducers=0,
                                       pSplitSize=64 * MiB),
                  ProfileStats(), CostFactors())
    mc, _, _ = task_costs(jc, num_nodes=2)
    tr = WorkloadTrace((JobArrival(0, jc, 0.0),))
    r = simulate_workload(
        tr,
        ClusterConfig(num_nodes=2, map_slots_per_node=1,
                      reduce_slots_per_node=0),
        SimConfig(speculative_execution=False,
                  node_failures=((mc / 2, 1),)))
    assert r.num_failure_reruns == 1
    assert r.makespan == pytest.approx(2 * mc)
    assert sum(r.node_busy_s) == pytest.approx(2.5 * mc)
    assert r.slot_utilization == pytest.approx(1.0)


def test_failure_runs_utilization_bounded_and_finite():
    """Noisy failure runs: finite costs or an explicit n_unfinished, and a
    time-integrated utilization that stays physical (<= 1)."""
    for seed in range(4):
        tr = rescale(poisson_trace(CLASSES, 8, seed=seed), 0.1)
        r = simulate_workload(
            tr, ClusterConfig(num_nodes=4),
            SimConfig(seed=seed, straggler_prob=0.2, task_time_jitter=0.3,
                      node_failures=((5.0, seed % 4), (9.0, (seed + 1) % 4))))
        assert 0.0 <= r.slot_utilization <= 1.0 + 1e-9
        if r.n_unfinished == 0:
            assert all(np.isfinite(j.finish) for j in r.jobs)
            assert np.isfinite(r.mean_latency)
        else:
            assert np.isinf(r.mean_latency)


@pytest.mark.parametrize("sched", ["fifo", "fair"])
def test_map_output_resurrection_completes(sched):
    """A node failure after the maps finish resurrects map work while the
    reduces are mid-flight: the stalled reduces must wait for the re-run
    outputs and then complete (the reduce_durs bookkeeping survives the
    kill/stall/resume cycle under both policies)."""
    jc = JobClass("one", HadoopParams(pNumMappers=16, pNumReducers=4,
                                      pSplitSize=64 * MiB),
                  ProfileStats(), CostFactors())
    tr = WorkloadTrace((JobArrival(0, jc, 0.0), JobArrival(1, jc, 1.0)))
    cc = ClusterConfig(num_nodes=4, scheduler=sched)
    base = simulate_workload(tr, cc, CLEAN)
    mf = max(j.map_finish for j in base.jobs)
    fin = max(j.finish for j in base.jobs)
    ftime = mf + 0.25 * (fin - mf)         # reduces running, maps done
    failed = simulate_workload(
        tr, cc, SimConfig(speculative_execution=False,
                          node_failures=((ftime, 0),)))
    assert failed.num_failure_reruns > 0
    # map work was resurrected after the original map fleet finished ...
    assert any(rec.kind == "map" and rec.start >= ftime and not rec.killed
               for rec in failed.records)
    # ... and every job still completed, later than the clean run
    assert failed.n_unfinished == 0
    assert all(np.isfinite(j.finish) for j in failed.jobs)
    assert max(j.finish for j in failed.jobs) > fin


def test_task_costs_memoized_per_class(monkeypatch):
    """Packing a big trace does ~one job_model call per class, not one per
    arrival (the old pack_trace re-evaluated the model 2x per job)."""
    from repro.cluster import workload as wl

    calls = {"n": 0}
    real = wl.job_model

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(wl, "job_model", counting)
    wl._job_model_cached.cache_clear()
    tr = poisson_trace(CLASSES, 200, rate=1.0, seed=7)
    pack_trace(tr)
    assert calls["n"] <= len(CLASSES)
    wl._job_model_cached.cache_clear()


# --------------------------------------------------- planner, new axes


def test_evaluator_heterogeneous_axes(evaluator):
    res = evaluator.evaluate({
        "pNumFastNodes": np.array([0.0, 2.0, 4.0]), "fastSpeedup": 2.0})
    assert res.outputs["valid"].all()
    # more fast nodes at a fixed fleet size never hurts the tail
    assert np.all(np.diff(res.total_cost) <= 1e-3)
    # the cross-axis predicate: a fast class larger than the fleet is invalid
    bad = evaluator.evaluate({"pNumFastNodes": np.array([8.0, 1.0])})
    assert bad.outputs["valid"][0] == 0.0 and np.isinf(bad.total_cost[0])
    assert bad.outputs["valid"][1] == 1.0
    assert evaluator.exact_cost({"pNumFastNodes": 8.0}) == np.inf
    # vector vs DES on a mixed fleet (light load: wave structure holds)
    vec = float(evaluator.evaluate(
        {"pNumFastNodes": np.array([2.0]), "fastSpeedup": 2.0}).total_cost[0])
    des = evaluator.exact_cost({"pNumFastNodes": 2.0, "fastSpeedup": 2.0})
    assert vec == pytest.approx(des, rel=0.1)


def test_evaluator_policy_axes_searchable(evaluator):
    space = {"schedPolicy": [0.0, 1.0, 2.0, 3.0], "pNumNodes": [2.0, 4.0]}
    plan = grid_search_ev(evaluator, space)
    assert np.isfinite(plan.best_cost) and plan.evaluations == 8
    top = search_topk(evaluator, space, k=3)
    assert top.best().cost == pytest.approx(plan.best_cost)
    # schedPolicy overrides the legacy boolean; schedFair still works alone
    legacy = evaluator.evaluate({"schedFair": np.array([1.0])})
    modern = evaluator.evaluate({"schedPolicy": np.array([1.0])})
    assert legacy.total_cost[0] == pytest.approx(modern.total_cost[0])


def test_legacy_schedfair_still_controls_fair_base():
    """A fair-scheduler base must not pin schedPolicy: sweeping the legacy
    schedFair axis over {0, 1} still toggles FIFO vs fair."""
    ev = ClusterEvaluator(CLASSES, n_jobs=8, n_seeds=1, chunk=8,
                          base=ClusterConfig(num_nodes=2, scheduler="fair"),
                          base_rate=0.2)
    fifo = ev.exact_cost({"schedFair": 0.0})
    fair = ev.exact_cost({"schedFair": 1.0})
    assert fifo != fair
    assert fair == pytest.approx(ev.exact_cost({}))   # base default is fair


def test_inexpressible_base_fleet_rejected():
    """The axis space models (fast + unit baseline); richer base fleets must
    fail loudly instead of being silently projected onto the wrong cluster."""
    three = ClusterConfig(node_classes=(
        NodeClass(2, 2.0), NodeClass(2, 1.5), NodeClass(2, 1.0)))
    with pytest.raises(ValueError, match="not expressible"):
        ClusterEvaluator(CLASSES, n_jobs=4, n_seeds=1, base=three)
    slow_base = ClusterConfig(node_classes=(NodeClass(2, 2.0),
                                            NodeClass(2, 0.5)))
    with pytest.raises(ValueError, match="not expressible"):
        ClusterEvaluator(CLASSES, n_jobs=4, n_seeds=1, base=slow_base)


def test_exact_fallback_skips_unfinishable_candidates(evaluator, monkeypatch):
    """One unfinishable candidate in the exact escape hatch must not abort a
    completed search: top-k catches ExactCostUnavailable and keeps ranking."""
    monkeypatch.setattr(
        type(evaluator), "exact_cost",
        lambda self, a: (_ for _ in ()).throw(
            UnfinishedWorkloadError("jobs never finished")))
    space = {"pNumNodes": [0.0, 4.0, 8.0]}       # row 0 invalid -> fallback
    top = search_topk(evaluator, space, k=3, exact_fallback=True)
    assert len(top.entries) == 2                  # the two valid rows ranked
    assert np.isfinite(top.best().cost)


def test_capacity_default_queue_frac_matches_equal_shares():
    """simulate_batch without queue_frac defaults to equal guarantees over
    the queues present — the DES's default — not a 100% queue-0 guarantee."""
    tr = poisson_trace(CLASSES, 8, rate=1.0, seed=4)
    cc = ClusterConfig(num_nodes=2, scheduler="capacity", preempt_timeout=0.0)
    n_q = len({a.klass.name for a in tr.arrivals})
    explicit = simulate_batch(scenario_for(tr, cc, 0.2, policy=3.0,
                                           queue_frac=[1.0 / n_q] * n_q))
    defaulted = simulate_batch(scenario_for(tr, cc, 0.2, policy=3.0))
    np.testing.assert_array_equal(explicit["finish"], defaulted["finish"])


def test_whatif_service_bit_for_bit(evaluator):
    vals = np.asarray([0.02, 0.05, 0.1], np.float32)
    with WhatIfService(evaluator) as svc:
        swept = svc.sweep("arrivalRate", vals).result()
        probe = svc.probe({"pNumNodes": 8.0}).result()
    seq = evaluator.evaluate({"arrivalRate": vals})
    assert np.array_equal(swept.total_cost, seq.total_cost)
    for k in seq.outputs:
        assert np.array_equal(swept.outputs[k], seq.outputs[k]), k
    assert probe.total_cost.shape == (1,) and np.isfinite(probe.total_cost[0])
