"""Public-API surface snapshot: exported symbols + ParamSpace axis names.

``src/repro/spec/manifest.json`` is the checked-in contract of the typed
layer.  Any drift — a symbol added to or dropped from ``repro.spec`` /
``repro.api`` ``__all__``, an axis renamed, added or removed from the
Hadoop / cluster / TPU parameter spaces — fails here, so surface changes
are always deliberate: update the manifest in the same commit and say why.
"""

import json
from pathlib import Path

import pytest

MANIFEST = Path(__file__).resolve().parents[1] / "src/repro/spec/manifest.json"


@pytest.fixture(scope="module")
def manifest():
    return json.loads(MANIFEST.read_text())


def test_spec_exports_frozen(manifest):
    import repro.spec as spec

    assert sorted(spec.__all__) == manifest["repro.spec"], (
        "repro.spec.__all__ drifted from manifest.json — update the "
        "manifest deliberately if this is intentional"
    )
    for name in spec.__all__:
        assert getattr(spec, name, None) is not None, name


def test_api_exports_frozen(manifest):
    import repro.api as api

    assert sorted(api.__all__) == manifest["repro.api"]
    for name in api.__all__:
        assert getattr(api, name, None) is not None, name


def test_obs_exports_frozen(manifest):
    import repro.obs as obs

    assert sorted(obs.__all__) == manifest["repro.obs"], (
        "repro.obs.__all__ drifted from manifest.json — the observability "
        "surface is frozen; update the manifest deliberately"
    )
    for name in obs.__all__:
        assert getattr(obs, name, None) is not None, name


def test_hadoop_axis_names_frozen(manifest):
    from repro.core.hadoop.model import CONFIG_KEYS
    from repro.spec import hadoop_space

    assert list(hadoop_space().names) == manifest["axes"]["hadoop"]
    # the flat pack_config key order IS the axis order — one enumeration
    assert manifest["axes"]["hadoop"] == CONFIG_KEYS


def test_cluster_axis_names_frozen(manifest):
    from repro.cluster.evaluator import cluster_space

    assert list(cluster_space().names) == manifest["axes"]["cluster"]


def test_tpu_axis_names_frozen(manifest):
    from repro.search.tpu import TPU_AXIS_NAMES

    assert list(TPU_AXIS_NAMES) == manifest["axes"]["tpu"]


def test_cloud_exports_frozen(manifest):
    import repro.cloud as cloud

    assert sorted(cloud.__all__) == manifest["repro.cloud"], (
        "repro.cloud.__all__ drifted from manifest.json — the elastic "
        "provisioning surface is frozen; update the manifest deliberately"
    )
    for name in cloud.__all__:
        assert getattr(cloud, name, None) is not None, name


def test_cloud_axis_names_frozen(manifest):
    from repro.cloud import cloud_space

    assert list(cloud_space().names) == manifest["axes"]["cloud"]


def test_network_exports_frozen(manifest):
    import repro.cluster.network as network

    assert sorted(network.__all__) == manifest["repro.cluster.network"], (
        "repro.cluster.network.__all__ drifted from manifest.json — the "
        "topology surface is frozen; update the manifest deliberately"
    )
    for name in network.__all__:
        assert getattr(network, name, None) is not None, name


def test_registered_backends_cover_the_manifest_spaces(manifest):
    import repro.api as api

    assert set(manifest["axes"]) <= set(api.available_models())


def test_analysis_checker_registry_frozen(manifest):
    from repro.analysis import checker_names

    assert checker_names() == manifest["analysis"]["checkers"], (
        "the static-analysis checker registry drifted from manifest.json — "
        "adding/removing/renaming a checker is a surface change: update the "
        "manifest (and analysis_baseline.json fingerprints) deliberately"
    )


def test_analysis_finding_schema_frozen(manifest):
    from dataclasses import fields

    from repro.analysis import FINDING_FIELDS
    from repro.analysis.findings import Finding

    assert list(FINDING_FIELDS) == manifest["analysis"]["finding_fields"]
    # the dataclass itself is the schema; FINDING_FIELDS must mirror it
    assert [f.name for f in fields(Finding)] == list(FINDING_FIELDS), (
        "Finding's fields drifted from FINDING_FIELDS — baseline "
        "fingerprints and the --json report are built from this schema"
    )
