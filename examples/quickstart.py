"""Quickstart: the paper's models in five minutes.

1. Predict a MapReduce job's cost with the closed-form models (Eqs. 2-98).
2. Cross-check the dataflow against a REAL execution of the same job on
   the MapReduce-on-JAX engine.
3. Ask a what-if question (the paper's headline use case) and tune a knob.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.hadoop import ref
from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.mapreduce import JOBS, MapReduceEngine, make_input
from repro.mapreduce.profiler import profile_job

# ---------------------------------------------------------------- 1. model
hp = HadoopParams(
    pNumNodes=16, pNumMappers=64, pNumReducers=16,
    pSortMB=100.0, pSortFactor=10, pUseCombine=True,
    pSplitSize=128 * MiB, pTaskMem=200 * MiB,
)
stats = ProfileStats(
    sInputPairWidth=100.0, sMapSizeSel=0.8, sMapPairsSel=1.0,
    sCombineSizeSel=0.4, sCombinePairsSel=0.4,
    sReduceSizeSel=0.5, sReducePairsSel=0.1,
)
jm = ref.job_model(hp, stats, CostFactors())
print("== closed-form prediction (paper Eqs. 2-98) ==")
print(f"  map task : numSpills={jm.map.numSpills} "
      f"mergePasses={jm.map.numMergePasses} io={jm.map.ioCost:.2f}s "
      f"cpu={jm.map.cpuCost:.2f}s")
print(f"  reduce   : shuffle={jm.reduce.totalShuffleSize/MiB:.1f}MiB "
      f"io={jm.reduce.ioCost:.2f}s cpu={jm.reduce.cpuCost:.2f}s")
print(f"  job      : total={jm.totalCost:.2f}s "
      f"(io={jm.ioJobCost:.2f} cpu={jm.cpuJobCost:.2f} net={jm.netCost:.2f})")

# ------------------------------------------------------------- 2. validate
job = JOBS["wordcount"]
n = 40_000
hp_small = HadoopParams(
    pNumMappers=2, pNumReducers=4, pUseCombine=True,
    pSortMB=1.0, pSplitSize=n / 2 * job.pair_width, pTaskMem=8 * MiB,
)
keys, values = make_input(job, n)
jc = MapReduceEngine(hp_small, job).run_job(keys, values)
measured = profile_job(jc, job, hp_small)
m = ref.map_task_model(hp_small, measured, CostFactors())
mc = jc.maps[0]
print("\n== engine vs model (live wordcount run) ==")
print(f"  numSpills        engine={mc.numSpills:<6d} model={m.numSpills}")
print(f"  spillBufferPairs engine={mc.spillBufferPairs:<6d} model={int(m.spillBufferPairs)}")
print(f"  mergePasses      engine={mc.numMergePasses:<6d} model={m.numMergePasses}")
print(f"  combine selectivity measured from run: {measured.sCombinePairsSel:.3f}")

# -------------------------------------------------------------- 3. what-if
print("\n== what-if: shrink io.sort.mb 100 -> 10 (more spills/merges) ==")
for sort_mb in (100.0, 10.0):
    jm = ref.job_model(hp.replace(pSortMB=sort_mb), stats, CostFactors())
    print(f"  io.sort.mb={sort_mb:>5.0f}MB -> numSpills={jm.map.numSpills:>3d} "
          f"total={jm.totalCost:.2f}s")

print("\n== tune pNumReducers (grid) ==")
best = min(
    (ref.job_model(hp.replace(pNumReducers=r), stats, CostFactors()).totalCost, r)
    for r in (4, 8, 16, 32, 64)
)
print(f"  best pNumReducers={best[1]} (predicted {best[0]:.2f}s)")
