"""Serving example: batched generation + request-level serving with KV
caches on a gemma2-family model (local/global attention, ring caches,
logit soft-capping) at smoke scale.

Run:  PYTHONPATH=src python examples/serve_requests.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.steps import init_params
from repro.runtime.serve_loop import Request, Server

cfg = get_config("gemma2-9b").smoke()
params = init_params(jax.random.PRNGKey(0), cfg)
server = Server(cfg, params, max_len=128, temperature=0.8)

# ---- request-level serving ----
rng = np.random.default_rng(0)
reqs = [
    Request(rid=i, prompt=rng.integers(2, cfg.vocab_size, 8 + 4 * i).tolist(),
            max_new_tokens=12)
    for i in range(4)
]
t0 = time.perf_counter()
done = server.generate(reqs)
wall = time.perf_counter() - t0
print("== request serving ==")
for r in done:
    print(f"  req {r.rid}: prompt {len(r.prompt):2d} toks -> "
          f"{len(r.generated)} new, e2e latency {r.latency_s*1e3:.0f}ms")
print(f"  {server.stats['tokens_out']} tokens in {wall:.2f}s; "
      f"stats={server.stats} p50={server.latency.p50*1e3:.0f}ms "
      f"p99={server.latency.p99*1e3:.0f}ms")

# ---- throughput batch ----
prompts = rng.integers(2, cfg.vocab_size, (8, 16))
out = server.throughput_batch(prompts, new_tokens=16)
print("\n== batched throughput ==")
print(f"  B=8 prefill {out['prefill_s']*1e3:.0f}ms, "
      f"decode {out['decode_s']*1e3:.0f}ms, {out['tok_per_s']:.0f} tok/s")
print(f"  sample: {out['output'][0].tolist()}")
