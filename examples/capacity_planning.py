"""Capacity planning end-to-end — workload, DES baseline, planner, what-ifs.

1. WORKLOAD  a Poisson stream of jobs over the 4-class mix (wordcount /
             sort / filter / aggregate), generated at unit rate so the
             offered load itself is a searchable knob.
2. BASELINE  run the multi-job DES on today's cluster: per-job queueing
             delay, p95 latency, slot utilization, FIFO vs fair-share vs
             preemptive fair-share vs capacity queues, a heterogeneous
             fleet, and what a burst or a node failure does to the tail.
3. PLAN      search (nodes x fleet mix x slots x scheduler policy x
             slowstart) with the vectorized wave simulator behind
             ``ClusterEvaluator`` — thousands of (config x workload-seed)
             scenarios per compiled call, exhaustive grid + streamed top-k.
4. ANSWER    concurrent capacity what-ifs through the same async
             WhatIfService that serves the single-job model.
5. VERIFY    the recommended cluster on the trusted DES.

Run:  PYTHONPATH=src python examples/capacity_planning.py [--trace out.json]

With ``--trace``, the whole run executes under ``repro.obs.observe`` and
writes a Perfetto-loadable Chrome trace: real-time spans for the planner
and service, plus a virtual-time swimlane rendering of the baseline DES
run (one lane per node slot, tasks carved into the paper's phases).
"""

import argparse
import contextlib

import numpy as np

from repro.cluster import (
    ClusterConfig,
    ClusterEvaluator,
    NodeClass,
    bursty_trace,
    default_job_classes,
    poisson_trace,
    rescale,
    simulate_workload,
)
from repro.core.hadoop.simulator import SimConfig
from repro.search import WhatIfService, grid_search_ev, search_topk

ap = argparse.ArgumentParser(description="capacity planning walkthrough")
ap.add_argument("--trace", default=None, metavar="OUT.json",
                help="write a Perfetto-loadable Chrome trace of this run")
args, _ = ap.parse_known_args()
_stack = contextlib.ExitStack()
if args.trace:
    from repro.obs import observe

    _stack.enter_context(observe(args.trace))

RATE = 0.08          # offered load today: jobs/s
classes = default_job_classes()
trace = poisson_trace(classes, 32, rate=1.0, seed=0)

# ---- 2: today's cluster, on the DES ----
today = ClusterConfig(num_nodes=8, map_slots_per_node=2, reduce_slots_per_node=2)
print("== multi-job DES on today's cluster (8 nodes, FIFO) ==")
for label, cc, tr, sc in [
    ("steady Poisson, FIFO", today, rescale(trace, RATE), SimConfig(seed=1)),
    ("steady Poisson, fair",
     ClusterConfig(num_nodes=8, scheduler="fair"), rescale(trace, RATE),
     SimConfig(seed=1)),
    ("steady Poisson, fair+preempt",
     ClusterConfig(num_nodes=8, scheduler="fair_preempt",
                   preempt_timeout=10.0),
     rescale(trace, RATE), SimConfig(seed=1)),
    ("capacity queues (equal)",
     ClusterConfig(num_nodes=8, scheduler="capacity", preempt_timeout=10.0),
     rescale(trace, RATE), SimConfig(seed=1)),
    ("4 fast(2x) + 4 base nodes",
     ClusterConfig(node_classes=(NodeClass(4, 2.0), NodeClass(4, 1.0))),
     rescale(trace, RATE), SimConfig(seed=1)),
    ("burst of 8 jobs", today,
     bursty_trace(classes, n_bursts=4, burst_size=8, burst_gap=120.0),
     SimConfig(seed=1)),
    ("10% stragglers + node failure", today, rescale(trace, RATE),
     SimConfig(seed=1, straggler_prob=0.1, node_failures=((40.0, 2),))),
]:
    r = simulate_workload(tr, cc, sc)
    if args.trace and label == "steady Poisson, FIFO":
        # swimlane rendering of the baseline run on the virtual-time track
        from repro.obs import workload_trace

        workload_trace(tr, r, cc)
    delays = [j.queueing_delay for j in r.jobs]
    print(f"  {label:30s} p95={r.p95_latency:7.1f}s mean={r.mean_latency:6.1f}s "
          f"queue p95={np.percentile(delays, 95):6.1f}s "
          f"util={r.slot_utilization:.2f} spec={r.num_speculative_launched} "
          f"reruns={r.num_failure_reruns} kills={r.num_preempted}")

# ---- 3: the capacity planner ----
ev = ClusterEvaluator(classes, n_jobs=32, n_seeds=2, base=today,
                      base_rate=RATE, objective="p95", chunk=256)
space = {
    "pNumNodes": [4.0, 8.0, 16.0],
    "pNumFastNodes": [0.0, 4.0],          # fleet mix: that many 2x nodes
    "fastSpeedup": [2.0],
    "pMaxMapsPerNode": [2.0, 4.0],
    "pMaxRedPerNode": [2.0, 4.0],
    "schedPolicy": [0.0, 1.0, 2.0, 3.0],  # fifo/fair/fair_preempt/capacity
    "pReduceSlowstart": [0.05, 0.8],
}
plan = grid_search_ev(ev, space)
top = search_topk(ev, space, k=5)
print("\n== capacity planner (vectorized wave simulator, exhaustive grid) ==")
print(f"  searched {plan.evaluations} cluster configs x {len(ev.traces)} "
      f"workload seeds ({top.configs_per_sec:,.0f} configs/s)")
print(f"  best: {plan.best_assignment} -> p95={plan.best_cost:.1f}s")
print("  top-5 by p95 job latency:")
for e in top.entries:
    print(f"    p95={e.cost:7.1f}s  {e.assignment}")

# ---- 4: concurrent what-ifs against the plan ----
best = plan.best_assignment
with WhatIfService(ev) as svc:
    futures = {
        "plan, at 2x load": svc.probe({**best, "arrivalRate": 2 * RATE}),
        "plan, half the nodes": svc.probe(
            {**best, "pNumNodes": max(best["pNumNodes"] / 2, 1),
             "pNumFastNodes": best.get("pNumFastNodes", 0) / 2}),
        "load sweep @plan": svc.sweep(
            "arrivalRate", [0.04, 0.08, 0.16, 0.32],
            base={k: v for k, v in best.items()}),
    }
    answers = {label: f.result() for label, f in futures.items()}
summary = svc.summary()
print("\n== capacity what-ifs (async service, coalesced chunks) ==")
for label, r in answers.items():
    i = int(np.argmin(r.total_cost))
    print(f"  {label:22s} p95={r.total_cost[i]:7.1f}s rows={r.stats.n_rows} "
          f"latency={r.stats.latency_s * 1e3:5.1f}ms")
print(f"  {summary['queries']} queries -> {summary['chunks']} evaluator "
      f"chunks ({summary['shared_chunks']} shared)")

# ---- 5: verify the winner on the trusted DES ----
exact = ev.exact_cost(best)
model = plan.best_cost
print("\n== verification (multi-job DES on the recommended cluster) ==")
print(f"  planner model p95 = {model:.1f}s, DES p95 = {exact:.1f}s "
      f"({100 * abs(model - exact) / max(exact, 1e-9):.1f}% apart)")
baseline = ev.exact_cost({})
print(f"  today's cluster DES p95 = {baseline:.1f}s -> plan is "
      f"{baseline / max(exact, 1e-9):.2f}x better on the tail")

_stack.close()
if args.trace:
    print(f"\n[trace written to {args.trace}; open at https://ui.perfetto.dev]")
