"""End-to-end training driver: a ~13M-parameter granite-family model for a
few hundred steps on CPU, with checkpointing, an injected mid-run crash,
and bit-exact auto-resume.

This is the full production path (sharded step, grad accumulation, atomic
async checkpoints, stateless data) at example scale; on a pod the same
Trainer runs the full configs on a (dp, tp) mesh.

Run:  PYTHONPATH=src python examples/train_e2e.py          (~3-5 min CPU)
      PYTHONPATH=src python examples/train_e2e.py --fast   (~1 min, 120 steps)
"""

import argparse
import shutil

import jax
import numpy as np

from repro.configs import get_config
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
args = ap.parse_args()

STEPS = 120 if args.fast else 300
cfg = get_config("granite-3-8b").replace(
    name="granite-13m",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=1024, vocab_size=2048, dtype="float32",
)
n_params = sum(
    int(np.prod(s.shape)) for s in jax.tree.leaves(
        jax.eval_shape(lambda k: __import__("repro.models.lm", fromlist=["lm"]).init(k, cfg),
                       jax.random.PRNGKey(0)))
)
print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  steps={STEPS}")

shutil.rmtree(args.ckpt_dir, ignore_errors=True)
tcfg = TrainerConfig(
    global_batch=8, seq_len=256, n_microbatches=2,
    ckpt_dir=args.ckpt_dir, ckpt_every=40, async_ckpt=True, log_every=20,
    opt=AdamWConfig(peak_lr=1e-3, warmup_steps=30, total_steps=STEPS),
    fail_at_step=STEPS // 2,              # injected crash mid-run
)

print(f"\n-- phase 1: train until the injected crash at step {STEPS//2} --")
try:
    Trainer(cfg, tcfg).run(STEPS, resume=False)
except RuntimeError as e:
    print(f"   crashed as planned: {e}")

print("-- phase 2: auto-resume from newest valid checkpoint --")
tcfg2 = TrainerConfig(**{**tcfg.__dict__, "fail_at_step": None})
trainer = Trainer(cfg, tcfg2)
out = trainer.run(STEPS, resume=True)
trainer.save_log("artifacts/train_e2e_log.jsonl")

log = out["log"]
first, last = log[0], log[-1]
print(f"\nloss: step {first['step']}: {first['loss']:.4f}  ->  "
      f"step {last['step']}: {last['loss']:.4f}")
drop = first["loss"] - last["loss"]
print(f"loss drop: {drop:.4f} ({'learning OK' if drop > 0.3 else 'WEAK'})  "
      f"straggler events: {len(trainer.straggler_events)}")
assert drop > 0.1, "model failed to learn the synthetic structure"
print("artifacts/train_e2e_log.jsonl written; checkpoints in", args.ckpt_dir)
