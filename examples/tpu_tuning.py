"""The paper's tuning loop applied to TPU execution configs — and checked
against the measured §Perf hillclimb.

Starfish uses the analytical job model to rank Hadoop configurations
without running them.  Here the TPU step model (`core/tpu_model.py`, the
Table-1/2/3 adaptation) ranks (dp, tp, n_micro) mesh factorizations for
each architecture; the ranking is then compared with what the compiled
dry-run MEASURED on the hillclimbed cells — the model must put the
measured winner above the measured loser, or the whole methodology is
decorative.

Run:  PYTHONPATH=src python examples/tpu_tuning.py
"""

import glob
import json

from repro.configs import SHAPES, get_config
from repro.search import TpuEvaluator, search_topk, space_size

SPACE = {
    "dp": [16.0, 32.0, 64.0, 128.0, 256.0],
    "tp": [16.0, 8.0, 4.0, 2.0, 1.0],
    "n_micro": [2.0, 4.0, 8.0, 16.0],
}
N_CHIPS = 256


def tune(arch: str, shape_name: str):
    """Rank execution configs with the shared search stack: the TPU step
    model behind the same Evaluator interface the Hadoop tuner uses.
    Unshardable candidates (dp*tp != chips, indivisible batch) are rejected
    by the evaluator's validity mask (cf. §Perf gemma2-prefill control)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ev = TpuEvaluator(cfg, shape, n_chips=N_CHIPS)
    # k = every candidate, so the (16,16) baseline row is always present
    top = search_topk(ev, SPACE, k=space_size(SPACE), exact_fallback=False)
    rows = []
    for e in top.entries:
        dp, tp, nm = (int(e.assignment[k]) for k in ("dp", "tp", "n_micro"))
        # which resource bounds this config, from the evaluator's own outputs
        # (same ep policy as the ranking itself)
        out = ev.evaluate({k: [v] for k, v in e.assignment.items()}).outputs
        bound = max(("compute", "memory", "collective"),
                    key=lambda t: out[f"{t}_s"][0])
        rows.append(((dp, tp, nm), e.cost, bound))
    return rows


def measured(arch: str, shape: str):
    out = {}
    for f in glob.glob(f"artifacts/dryrun/{arch}__{shape}__single*.json"):
        c = json.load(open(f))
        if c.get("status") != "ok":
            continue
        r = c["roofline"]
        out[c.get("opt", "baseline")] = max(
            r["compute_s"], r["memory_s"], r["collective_s"]
        )
    return out


for arch, shape in [
    ("starcoder2-7b", "train_4k"),
    ("gemma2-9b", "train_4k"),
    ("granite-3-8b", "train_4k"),
]:
    rows = tune(arch, shape)
    print(f"\n== {arch}/{shape}: model ranking (top 5 of {len(rows)}) ==")
    for (dp, tp, nm), t, bound in rows[:5]:
        print(f"  dp={dp:<3d} tp={tp:<2d} micro={nm:<2d} -> {t:7.2f}s ({bound})")
    base = next((t for (d, tp, _), t, _ in rows if (d, tp) == (16, 16)), None)
    best = rows[0]
    print(f"  model: best {best[0]} vs (16,16) baseline {base:.2f}s "
          f"-> predicted {base/best[1]:.1f}x")
    m = measured(arch, shape)
    if "baseline" in m:
        opt = {k: v for k, v in m.items() if k != "baseline"}
        if opt:
            k, v = min(opt.items(), key=lambda kv: kv[1])
            agree = (best[0][:2] != (16, 16)) == (v < m["baseline"])
            print(f"  measured (compiled dry-run): baseline {m['baseline']:.2f}s, "
                  f"best preset '{k}' {v:.2f}s ({m['baseline']/v:.1f}x) "
                  f"-> ranking {'AGREES' if agree else 'DISAGREES'}")
