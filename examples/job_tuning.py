"""The full Starfish loop on live executions — profile, fit, tune, verify.

1. PROFILE  a wordcount job once on the MapReduce-on-JAX engine.
2. FIT      the paper's Table-3 cost factors from measured phase timings.
3. TUNE     (io.sort.mb, io.sort.factor, numReducers, combiner) with the
            vmapped what-if engine + coordinate descent — pure model
            evaluations, no job runs (the paper's whole point).
4. ANSWER   a batch of concurrent what-if questions (probes, sweeps, a
            grid) through the async WhatIfService: all queries coalesce
            into a handful of shared evaluator chunks.
5. VERIFY   by actually running the recommended configuration: it must
            beat the default configuration's measured wall time.
6. SIMULATE the tuned job on a virtual cluster with stragglers + failures
            + speculative execution (paper §5 way (i)).

Run:  PYTHONPATH=src python examples/job_tuning.py
"""

import numpy as np

from repro.core.hadoop.params import HadoopParams, MiB
from repro.core.hadoop.simulator import SimConfig, simulate_job
from repro.mapreduce import JOBS, make_input
from repro.mapreduce.profiler import fit_cost_factors, predict, run_measured
from repro.search import (
    ChunkedEvaluator,
    WhatIfService,
    coordinate_descent_ev,
    grid_search_ev,
)

job = JOBS["wordcount"]
N = 120_000
default_hp = HadoopParams(
    pNumMappers=4, pNumReducers=2, pUseCombine=True,
    pSortMB=0.25, pSortFactor=3,                      # deliberately poor
    pSplitSize=N / 4 * job.pair_width, pTaskMem=8 * MiB,
)

# ---- 1+2: profile + fit from three probe runs ----
probes = [
    default_hp,
    default_hp.replace(pSortMB=1.0),
    default_hp.replace(pNumReducers=8, pSortFactor=8),
]
runs = [run_measured(job, hp, N, seed=1) for hp in probes]
costs = fit_cost_factors(runs)
stats = runs[0].stats
print("== fitted cost factors (paper Table 3, from live phase timings) ==")
for f in ("cHdfsReadCost", "cMapCPUCost", "cSortCPUCost", "cMergeCPUCost",
          "cNetworkCost", "cReduceCPUCost"):
    print(f"  {f:18s} = {getattr(costs, f):.3e} s/unit")
print(f"  measured sMapPairsSel={stats.sMapPairsSel:.2f} "
      f"sCombinePairsSel={stats.sCombinePairsSel:.3f}")

# ---- 3: tune on the model only ----
space = {
    "pSortMB": [0.25, 0.5, 1.0, 2.0, 4.0],
    "pSortFactor": [3, 5, 10, 20],
    "pNumReducers": [1, 2, 4, 8, 16],
    "pUseCombine": [0.0, 1.0],
}
evaluator = ChunkedEvaluator(default_hp, stats, costs, chunk=1 << 10)
tuned = coordinate_descent_ev(evaluator, space)
exhaustive = grid_search_ev(evaluator, space)
hp_tuned = tuned.apply(default_hp)
print("\n== tuner (model evaluations only, chunked/sharded evaluator) ==")
print(f"  coordinate descent: {tuned.best_assignment} "
      f"cost={tuned.best_cost:.3f}s ({tuned.evaluations} evals)")
print(f"  exhaustive optimum: cost={exhaustive.best_cost:.3f}s "
      f"({exhaustive.evaluations} evals, "
      f"{exhaustive.topk.configs_per_sec:,.0f} configs/s) -> descent within "
      f"{100 * tuned.best_cost / max(exhaustive.best_cost, 1e-9) - 100:.1f}%")

# ---- 4: concurrent what-if questions through the async service ----
# the multi-query path: heterogeneous questions share the evaluator's
# compiled chunks instead of paying one padded evaluate call each
best = tuned.best_assignment
with WhatIfService(evaluator) as svc:
    futures = {
        "tuned, combiner off": svc.probe({**best, "pUseCombine": 0.0}),
        "tuned, 2x reducers": svc.probe(
            {**best, "pNumReducers": 2 * best["pNumReducers"]}),
        "reducer sweep @tuned": svc.sweep(
            "pNumReducers", [1.0, 2.0, 4.0, 8.0, 16.0],
            base={k: v for k, v in best.items() if k != "pNumReducers"}),
        "sortMB x factor grid": svc.grid(
            {"pSortMB": space["pSortMB"], "pSortFactor": space["pSortFactor"]}),
    }
    answers = {label: f.result() for label, f in futures.items()}
summary = svc.summary()
print("\n== concurrent what-if queries (async service) ==")
for label, r in answers.items():
    _, cost, a = r.best()
    print(f"  {label:22s} best={cost:7.3f}s rows={r.stats.n_rows:2d} "
          f"latency={r.stats.latency_s*1e3:5.1f}ms")
print(f"  {summary['queries']} queries -> {summary['chunks']} evaluator "
      f"chunks ({summary['shared_chunks']} shared); "
      f"p50={summary['latency_p50_s']*1e3:.1f}ms "
      f"p99={summary['latency_p99_s']*1e3:.1f}ms")

# ---- 5: verify on the engine ----
before = run_measured(job, default_hp, N, seed=2)
after = run_measured(job, hp_tuned, N, seed=2)
print("\n== verification (real engine runs) ==")
print(f"  default config : measured {before.wall_s:.3f}s "
      f"(predicted {predict(default_hp, stats, costs):.3f}s)")
print(f"  tuned config   : measured {after.wall_s:.3f}s "
      f"(predicted {predict(hp_tuned, stats, costs):.3f}s)")
speedup = before.wall_s / max(after.wall_s, 1e-9)
print(f"  speedup {speedup:.2f}x  {'OK' if speedup > 1.0 else 'NO GAIN'}")

# ---- 6: virtual-cluster simulation (paper §5 way (i)) ----
print("\n== task-scheduler simulation: stragglers + failure + speculation ==")
sim_hp = hp_tuned.replace(pNumNodes=8, pNumMappers=64, pNumReducers=16)
for label, sc in [
    ("clean cluster", SimConfig(seed=7)),
    ("10% stragglers, no speculation",
     SimConfig(seed=7, straggler_prob=0.1, speculative_execution=False)),
    ("10% stragglers + speculation",
     SimConfig(seed=7, straggler_prob=0.1, speculative_execution=True)),
    ("node failure at t=0.3s",
     SimConfig(seed=7, node_failures=((0.3, 3),))),
]:
    r = simulate_job(sim_hp, stats, costs, sc)
    print(f"  {label:34s} makespan={r.makespan:7.2f}s "
          f"spec={r.num_speculative_launched} reruns={r.num_failure_reruns}")
