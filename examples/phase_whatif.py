"""The typed API tour: one facade, per-phase costs, phase-aware what-ifs.

Everything the paper computes, through `repro.api` + `repro.spec` instead
of prefixed dict keys:

1. SPEC     a typed JobSpec (Tables 1-3 as one value).
2. MODEL    one config -> a CostReport whose fields carry paper Eq numbers.
3. SWEEP    a batched report; phases sum to Eq. 98's total.
4. TUNE     coordinate descent over an axis-validated space.
5. SERVE    async phase-level what-if: "which config minimizes *shuffle*
            time, subject to total job cost <= budget?" — the query the
            flat j_totalCost-only API could not express.

Run:  PYTHONPATH=src python examples/phase_whatif.py
"""

import numpy as np

import repro.api as api
from repro.core.hadoop import HadoopParams, MiB
from repro.spec import JobSpec, PhaseBreakdown

# ---- 1: a typed spec (flat-key overrides route+coerce onto the tables) ----
spec = JobSpec(
    HadoopParams(pNumNodes=8, pNumMappers=64, pNumReducers=16,
                 pSplitSize=128 * MiB),
    name="wordcount-ish",
).replace(sMapSizeSel=0.8, sReduceSizeSel=0.5)

# ---- 2: one configuration -> per-phase report with paper provenance ----
rep = api.model(spec, {"pSortMB": 100.0, "pSortFactor": 10.0})
print("== per-phase cost report (job-level seconds) ==")
for phase in PhaseBreakdown.names():
    print(f"  {phase:13s} {float(rep.phases[phase][0]):8.2f}s   "
          f"[{PhaseBreakdown.eq(phase)}]")
print(f"  {'total':13s} {float(rep.total_cost[0]):8.2f}s   [Eq. 98] "
      f"(= io {float(rep.io_cost[0]):.2f} + cpu {float(rep.cpu_cost[0]):.2f} "
      f"+ net {float(rep.net_cost[0]):.2f})")

# ---- 3+4: sweep and tune through the same facade ----
space = {
    "pSortMB": [25.0, 50.0, 100.0, 200.0, 400.0],
    "pSortFactor": [5.0, 10.0, 25.0],
    "pNumReducers": [4.0, 8.0, 16.0, 32.0, 64.0],
}
tuned = api.tune(spec, space, strategy="descent")
print(f"\n== tune (axis-validated space) ==\n  best {tuned.best_assignment} "
      f"cost={tuned.best_cost:.2f}s ({tuned.evaluations} model evals)")

# ---- 5: phase-aware what-if through the async service ----
grid = {
    "pSortMB": np.repeat(space["pSortMB"], len(space["pNumReducers"])),
    "pNumReducers": np.tile(space["pNumReducers"], len(space["pSortMB"])),
}
swept = api.sweep(spec, grid)
budget = float(np.percentile(np.asarray(swept.total_cost), 40))
with api.serve(spec) as svc:
    fut = svc.phase_query(grid, phase="shuffle", total_max=budget)
    fut_any = svc.phase_query(grid, phase="shuffle")
    best = fut.result().best()
    unconstrained = fut_any.result().best()
print(f"\n== phase query: min shuffle s.t. total <= {budget:.2f}s ==")
print(f"  constrained   : shuffle={best[1]:7.3f}s at {best[2]}")
print(f"  unconstrained : shuffle={unconstrained[1]:7.3f}s "
      f"at {unconstrained[2]}")
