"""Spot planning end-to-end — priced fleets, reclamation, the $/SLO Pareto.

1. WORKLOAD  a Poisson stream over the 4-class job mix, offered at a
             fixed rate; the fleet shape is what we search.
2. BASELINE  today's all-on-demand fleet on the elastic DES: what it
             costs per job (per-node billing episodes) and where p95 sits.
3. SPOT      swap capacity to spot instances at 1/4 the price and watch
             the DES reclaim nodes mid-run (kill-and-requeue, distinct
             ``reclaim`` kill reason) — cheaper, but the tail pays.
4. SWEEP     the (on-demand x spot x reclaim-rate) grid through
             ``CloudEvaluator`` — dollars-per-job, SLO attainment, and
             p95 for every mix in one vmapped call — and keep the
             dollar/SLO Pareto front.
5. TUNE      grid search under a hard latency SLO: infeasible mixes cost
             ``inf``, the winner is the cheapest fleet that still meets
             the objective.  Verify the pick on the DES (``exact_cost``).

Run:  PYTHONPATH=src python examples/spot_planning.py [--trace out.json]

With ``--trace``, the run executes under ``repro.obs.observe`` and the
baseline DES run is rendered as a virtual-time swimlane with ``reclaim``
instants, per-node ``provisioned``/``offline`` markers, and ``fleet`` /
``spend`` counter tracks.
"""

import argparse
import contextlib

import numpy as np

from repro.cloud import (
    CloudEvaluator,
    ElasticFleet,
    bill_workload,
    pareto_front,
)
from repro.cluster import (
    ClusterConfig,
    NodeClass,
    default_job_classes,
    poisson_trace,
    rescale,
    simulate_workload,
)
from repro.core.hadoop.simulator import SimConfig
from repro.search import grid_search_ev

ap = argparse.ArgumentParser(description="spot fleet planning walkthrough")
ap.add_argument("--trace", default=None, metavar="OUT.json",
                help="write a Perfetto-loadable Chrome trace of this run")
args, _ = ap.parse_known_args()
_stack = contextlib.ExitStack()
if args.trace:
    from repro.obs import observe

    _stack.enter_context(observe(args.trace))

RATE = 0.08                  # offered load: jobs/s
ON_DEMAND, SPOT = 0.40, 0.10  # $/node-hour
CLEAN = SimConfig(speculative_execution=False)
classes = default_job_classes()
trace = rescale(poisson_trace(classes, 24, rate=1.0, seed=0), RATE)
n_jobs = len(trace.arrivals)


def dollars(res, cc, el=None):
    window = (min(j.submit_time for j in res.jobs), res.makespan)
    return bill_workload(res, cc, elastic=el, window=window)


# ---- 2: today's fleet — all on-demand ----
today = ClusterConfig(num_nodes=4,
                      node_classes=(NodeClass(4, 1.0, ON_DEMAND),))
base = simulate_workload(trace, today, CLEAN)
print("== today: 4 on-demand nodes ==")
print(f"p95 latency      {base.p95_latency:8.1f} s")
print(f"dollars per job  ${dollars(base, today) / n_jobs:.4f}")

# ---- 3: the same capacity, half on spot, reclamation live ----
mixed = ClusterConfig(num_nodes=4,
                      node_classes=(NodeClass(2, 1.0, SPOT, spot=True),
                                    NodeClass(2, 1.0, ON_DEMAND)))
el = ElasticFleet(reclaim_rate=5e-3, provision_latency=30.0, seed=0)
spot = simulate_workload(trace, mixed, CLEAN, elastic=el)
print("\n== 2 spot + 2 on-demand, reclaim rate 5e-3/s ==")
print(f"p95 latency      {spot.p95_latency:8.1f} s")
print(f"dollars per job  ${dollars(spot, mixed, el) / n_jobs:.4f}")
print(f"spot reclaims    {spot.num_reclaimed} task kills "
      f"({sum(len(e) - 1 for e in spot.node_online[:2])} node outages)")

if args.trace:
    from repro.obs.destrace import workload_trace

    workload_trace(trace, spot, mixed)

# ---- 4: sweep the fleet-mix grid, keep the $/SLO Pareto front ----
ev = CloudEvaluator(classes, traces=[poisson_trace(classes, 24, seed=0)],
                    n_seeds=2, base_rate=RATE, sim=CLEAN, chunk=64,
                    on_demand_price=ON_DEMAND, spot_price=SPOT,
                    slo_target=0.9)
SLO = 1.5 * base.p95_latency
od = np.repeat([1.0, 2.0, 4.0], 4)
sp = np.tile([0.0, 2.0, 4.0, 8.0], 3)
rep = ev.report({"pOnDemandNodes": od, "pSpotNodes": sp,
                 "spotReclaimRate": np.full(od.size, 5e-3),
                 "sloLatency": np.full(od.size, SLO)})
front = pareto_front(np.asarray(rep.dollars_per_job),
                     -np.asarray(rep.slo_attainment))
print(f"\n== fleet-mix sweep ({od.size} mixes, SLO p95 <= {SLO:.0f} s) ==")
print("  od  spot   $/job    SLO-attain  on front")
for i in np.argsort(np.asarray(rep.dollars_per_job)):
    d = float(np.asarray(rep.dollars_per_job)[i])
    a = float(np.asarray(rep.slo_attainment)[i])
    if np.isfinite(d):
        star = "  *" if front[i] else ""
        print(f"  {int(od[i])}   {int(sp[i])}     ${d:.4f}  {a:10.2f}{star}")

# ---- 5: cheapest fleet that meets the SLO, verified on the DES ----
tuned = grid_search_ev(ev, {"pOnDemandNodes": [1.0, 2.0, 4.0],
                            "pSpotNodes": [0.0, 2.0, 4.0, 8.0],
                            "spotReclaimRate": [5e-3],
                            "sloLatency": [SLO]})
pick = tuned.best_assignment
print(f"\n== winner: {int(pick['pOnDemandNodes'])} on-demand + "
      f"{int(pick['pSpotNodes'])} spot at ${tuned.best_cost:.4f}/job ==")
exact = ev.exact_cost(pick)
print(f"DES-verified     ${exact:.4f}/job "
      f"({abs(exact - tuned.best_cost) / exact:.1%} from the wave estimate)")

_stack.close()
if args.trace:
    print(f"\n[trace written to {args.trace}; open at https://ui.perfetto.dev]")
