"""Differentiable loop: observe -> calibrate by autodiff -> tune by gradient.

The closed-form job model is branch-free JAX end-to-end (straight-through
round counts, double-``where`` guarded divisions), so the same graph that
*predicts* a cost can be differentiated — against its Table-3 cost factors
(calibration) or against the configuration knobs (search):

1. OBSERVE    run a few jobs on the MapReduce-on-JAX engine and keep only
              ``(JobSpec, wall seconds)`` pairs — no phase timings needed,
              unlike the least-squares profiler fit.
2. CALIBRATE  ``api.calibrate`` fits the cost factors by ``jax.grad`` on
              the relative-error loss (repro.optim AdamW, per-axis
              log/logit transforms keep every step in-domain).
3. TUNE       ``api.tune(strategy="gradient")`` relaxes the search space
              continuously and descends on the model itself; candidates
              are rounded, validated against the declared predicates, and
              re-costed through the evaluator before being reported.

Run:  PYTHONPATH=src python examples/calibrate_and_tune.py
"""

import jax

# Cost factors span ~1e-9..1e-7 s/byte; calibrate in float64 (the pytest
# suite gets this from tests/conftest.py, scripts set it themselves).
jax.config.update("jax_enable_x64", True)

import repro.api as api
from repro.calib import Observation
from repro.core.hadoop.params import HadoopParams, MiB
from repro.mapreduce import JOBS
from repro.mapreduce.profiler import fit_cost_factors, predict, run_measured
from repro.spec import JobSpec

job = JOBS["wordcount"]
N = 120_000
base_hp = HadoopParams(
    pNumMappers=4, pNumReducers=2, pUseCombine=True,
    pSortMB=0.25, pSortFactor=3,                      # deliberately poor
    pSplitSize=N / 4 * job.pair_width, pTaskMem=8 * MiB,
)

# ---- 1: observe three configurations on the live engine ----
# Probes must sit inside the closed-form merge domain (the model weighs
# valid==0 rows out of the fit, and calibrate() refuses an all-invalid
# set) — so unlike the tuning start point, none uses pSortMB=0.25.
probes = [
    base_hp.replace(pSortMB=1.0, pSortFactor=8),
    base_hp.replace(pSortMB=2.0, pSortFactor=10),
    base_hp.replace(pSortMB=1.0, pSortFactor=8, pNumReducers=8),
]
runs = [run_measured(job, hp, N, seed=1) for hp in probes]
stats = runs[0].stats

# the lstsq profiler fit needs the per-phase timing breakdown of each run;
# the autodiff fit needs only what a production log would have: the spec
# that ran and how long it took.  Seed it from the lstsq fit's factors so
# the comparison is "does gradient refinement improve the same start".
seed_costs = fit_cost_factors(runs)
observations = [
    Observation(
        spec=JobSpec(params=r.hp, stats=r.stats, costs=seed_costs),
        cost=r.wall_s,
    )
    for r in runs
]

# ---- 2: calibrate the cost factors by jax.grad ----
report = api.calibrate(observations, steps=300)
print("== calibration (autodiff on the model itself) ==")
print(report.summary())
fitted_costs = seed_costs.replace(**report.fitted)

print("\nper-run relative error, lstsq -> autodiff:")
for r in runs:
    e0 = abs(predict(r.hp, stats, seed_costs) - r.wall_s) / r.wall_s
    e1 = abs(predict(r.hp, stats, fitted_costs) - r.wall_s) / r.wall_s
    print(f"  {r.hp.pSortMB:6.2f}MB sort, {r.hp.pNumReducers:2d} reducers: "
          f"{e0:6.1%} -> {e1:6.1%}")

# ---- 3: tune the knobs by gradient descent on the calibrated model ----
spec = JobSpec(params=base_hp, stats=stats, costs=fitted_costs)
space = {
    "pSortMB": [0.25, 0.5, 1.0, 2.0, 4.0],
    "pSortFactor": [3, 5, 8, 16],
    "pNumReducers": [2, 4, 8],
    "pUseCombine": [0.0, 1.0],
}
grad = api.tune(spec, space, strategy="gradient")
coord = api.tune(spec, space, strategy="descent")
print("\n== tuning on the calibrated model ==")
print(f"coordinate descent: {coord.best_cost:8.3f}s "
      f"in {coord.evaluations} evaluator calls")
print(f"gradient descent  : {grad.best_cost:8.3f}s "
      f"in {grad.evaluations} evaluator calls")
print(f"recommended config: {grad.best_assignment}")
