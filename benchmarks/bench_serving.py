"""E10-adjacent — serving throughput at smoke scale: prefill latency and
decode tok/s for a gemma2-family model (ring caches + softcap), XLA vs
Pallas attention path."""

from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.steps import init_params
from repro.models import attention
from repro.runtime.serve_loop import Server
from .common import table, write_md


def run(quick: bool = False) -> list[str]:
    cfg = get_config("gemma2-9b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size, (4, 16))
    rows = []
    for impl in ("xla", "pallas") if not quick else ("xla",):
        attention.set_attention_impl(impl)
        try:
            server = Server(cfg, params, max_len=64)
            out = server.throughput_batch(prompts, new_tokens=8)
            rows.append([impl, out["prefill_s"], out["decode_s"],
                         out["tok_per_s"]])
        finally:
            attention.set_attention_impl("xla")
    lines = ["gemma2-smoke serving (CPU; Pallas runs in interpret mode, so",
             "its CPU time is NOT indicative — included for path coverage):", ""]
    lines += table(["attention", "prefill s", "decode s", "tok/s"], rows)
    write_md("serving.md", "Serving throughput (smoke)", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
