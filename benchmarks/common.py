"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import os
import time

ART = "artifacts/bench"


def write_md(name: str, title: str, lines: list[str]) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, name)
    with open(path, "w") as f:
        f.write(f"# {title}\n\n")
        f.write("\n".join(lines) + "\n")
    return path


def table(headers: list[str], rows: list[list]) -> list[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(
            f"{v:.4g}" if isinstance(v, float) else str(v) for v in r
        ) + " |")
    return out


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
