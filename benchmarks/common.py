"""Shared helpers for the benchmark harness.

Besides the markdown report writers, this module is the benchmarks'
machine-readable sink: :func:`report` records per-benchmark metrics into
:class:`repro.obs.MetricsRegistry` instances, and :func:`write_results`
persists every registry as one ``artifacts/bench/BENCH_results.json``
(benchmark name -> metrics snapshot), merging with whatever is already on
disk so successive CI steps (each a separate process) accumulate into a
single artifact.

:func:`bench_main` is the standard ``__main__`` for a benchmark module: it
exposes the module's ``run(quick=..., smoke=...)`` flags plus a uniform
``--trace OUT.json`` flag that wraps the run in :func:`repro.obs.observe`
and writes a Perfetto-loadable Chrome trace.
"""

from __future__ import annotations

import contextlib
import inspect
import json
import os
import time

from repro.obs import MetricsRegistry

ART = "artifacts/bench"
RESULTS_NAME = "BENCH_results.json"

_registries: dict[str, MetricsRegistry] = {}


def registry(bench: str) -> MetricsRegistry:
    """The named benchmark's metrics registry (created on first use)."""
    return _registries.setdefault(bench, MetricsRegistry())


def report(bench: str, **metrics) -> MetricsRegistry:
    """Record scalar results for one benchmark and persist immediately
    (so a later module's crash cannot lose an earlier module's numbers).
    Values become gauges; pass a ``repro.obs`` snapshot dict via
    :func:`merge_snapshot` for nested histogram summaries."""
    reg = registry(bench)
    for k, v in metrics.items():
        reg.gauge(k).set(float(v))
    write_results()
    return reg


def merge_snapshot(bench: str, snapshot: dict) -> None:
    """Fold a ``MetricsRegistry.snapshot()`` (e.g. the ambient registry of
    an ``observe()`` run) into a benchmark's results entry."""
    reg = registry(bench)
    for k, v in snapshot.items():
        if isinstance(v, dict):          # histogram summary: keep the p50/p99
            for kk, vv in v.items():
                reg.gauge(f"{k}.{kk}").set(float(vv))
        else:
            reg.gauge(k).set(float(v))
    write_results()


def write_results(path: str | None = None) -> str:
    """Write every reported registry to ``BENCH_results.json``, merged with
    the file's current content (separate CI steps accumulate)."""
    os.makedirs(ART, exist_ok=True)
    path = path or os.path.join(ART, RESULTS_NAME)
    existing: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (OSError, ValueError):
            existing = {}
    for name, reg in _registries.items():
        merged = existing.get(name, {})
        if not isinstance(merged, dict):
            merged = {}
        merged.update(reg.snapshot())
        existing[name] = merged
    with open(path, "w") as f:
        json.dump(existing, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def bench_main(run_fn) -> None:
    """Uniform benchmark ``__main__``: module flags + ``--trace OUT.json``.

    Builds an argparse CLI from ``run_fn``'s signature (``--quick`` /
    ``--smoke`` when the corresponding parameters exist), runs the module,
    prints its report lines, and persists ``BENCH_results.json``.  With
    ``--trace``, the run executes inside :func:`repro.obs.observe`; the
    trace lands at the given path and the ambient metrics snapshot is
    folded into the benchmark's results entry.
    """
    import argparse

    from repro.obs import observe

    mod = inspect.getmodule(run_fn)
    name = (mod.__name__ if mod else "bench").rsplit(".", 1)[-1]
    if name == "__main__" and getattr(mod, "__file__", None):
        name = os.path.splitext(os.path.basename(mod.__file__))[0]
    params = inspect.signature(run_fn).parameters
    ap = argparse.ArgumentParser(description=(mod.__doc__ or "").strip()
                                 or None)
    if "quick" in params:
        ap.add_argument("--quick", action="store_true")
    if "smoke" in params:
        ap.add_argument("--smoke", action="store_true",
                        help="CI mode: small inputs + hard assertions")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record this run's spans/metrics and write a "
                         "Perfetto-loadable Chrome trace")
    args = ap.parse_args()
    kw = {k: getattr(args, k) for k in ("quick", "smoke") if k in params}

    t0 = time.perf_counter()
    cm = observe(args.trace) if args.trace else contextlib.nullcontext()
    with cm as ob:
        lines = run_fn(**kw)
    print("\n".join(lines))
    report(name, wall_s=time.perf_counter() - t0)
    if ob is not None:
        merge_snapshot(name, ob.registry.snapshot())
        print(f"[trace written to {args.trace}; open at https://ui.perfetto.dev]")


def write_md(name: str, title: str, lines: list[str]) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, name)
    with open(path, "w") as f:
        f.write(f"# {title}\n\n")
        f.write("\n".join(lines) + "\n")
    return path


def table(headers: list[str], rows: list[list]) -> list[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(
            f"{v:.4g}" if isinstance(v, float) else str(v) for v in r
        ) + " |")
    return out


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
