"""E8 — assemble the 40-cell roofline table from dry-run artifacts.

For every (arch x shape): the three terms (seconds, per step), the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs (useful ratio), and the
roofline fraction = ideal-time / dominant-term where ideal-time uses the
appropriate ceiling (compute ideal for train/prefill; HBM weight+KV read
ideal for decode).  Multi-pod cells prove the pod axis shards; their
bytes/device and terms are reported alongside.
"""

from __future__ import annotations

import glob
import json

from repro.configs import SHAPES, get_config
from repro.core.roofline import HW
from repro.core.tpu_model import TpuParams, _param_count
from .common import table, write_md


def ideal_seconds(c: dict) -> float:
    """Best achievable step time on this mesh for this cell's workload."""
    cfg = get_config(c["arch"])
    shape = SHAPES[c["shape"]]
    chips = c["chips"]
    comp = c["roofline"]["model_flops"] / (chips * HW["peak_flops"])
    if shape.kind != "decode":
        return comp
    # decode: reading the (sharded) weights + KV once bounds the step
    pbytes = _param_count(cfg) * 2 / chips          # bf16 serving weights
    kv = 0.0
    if cfg.n_kv_heads:
        kv = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim
              * shape.seq_len * shape.global_batch * 2) / chips
    return max(comp, (pbytes + kv) / HW["hbm_bw"])


def run(quick: bool = False) -> list[str]:
    rows_single, rows_multi = [], []
    for f in sorted(glob.glob("artifacts/dryrun/*.json")):
        c = json.load(open(f))
        if "arch" not in c:   # e.g. mapreduce_pipeline.json (own section)
            continue
        tag = f"{c['arch']}/{c['shape']}"
        if c.get("opt", "baseline") != "baseline":
            tag += f" **[opt:{c['opt']}]**"
        if not c.get("status", "").startswith("ok"):
            row = [tag, c["status"], "-", "-", "-", "-", "-", "-"]
            (rows_single if c["mesh"] == "16x16" else rows_multi).append(row)
            continue
        r = c["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = ideal_seconds(c) / dom if dom else 0.0
        # peak_memory is the binding HBM metric; XLA:CPU's temp_size sums
        # allocations without TPU memory-pressure scheduling (pessimistic)
        mem = c.get("memory", {}).get("peak_memory_in_bytes", 0) / 2**30
        row = [
            tag, r["bound"], r["compute_s"], r["memory_s"], r["collective_s"],
            round(r["useful_ratio"], 3), f"{100*frac:.1f}%", f"{mem:.1f}GiB",
        ]
        (rows_single if c["mesh"] == "16x16" else rows_multi).append(row)

    hdr = ["cell", "bound", "compute s", "memory s", "collective s",
           "useful", "roofline frac", "bytes/dev"]
    lines = ["## single-pod 16x16 (256 chips) — the roofline table", ""]
    lines += table(hdr, rows_single)
    lines += ["", "## multi-pod 2x16x16 (512 chips) — pod axis shards", ""]
    lines += table(hdr, rows_multi)
    write_md("roofline.md", "E8: 40-cell roofline", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
