"""E9 — TPU analytical step model vs the compiled multi-pod dry-run.

The paper validates its models against live Hadoop runs; here the "live
system" is XLA's compiled per-device program (parsed HLO from the dry-run
artifacts).  Reports, per cell: predicted vs measured compute/memory/
collective terms, and the fitted efficiency factors (the paper's
cost-factor fitting, Table-3 style) that align the memory term.
"""

from __future__ import annotations

import glob
import json

import numpy as np

from repro.configs import SHAPES, get_config
from repro.core.tpu_model import TpuCostFactors, TpuParams, step_model
from .common import table, write_md


def _cells():
    for f in sorted(glob.glob("artifacts/dryrun/*__single.json")):
        c = json.load(open(f))
        if c.get("status") == "ok":
            yield c


def run(quick: bool = False) -> list[str]:
    rows, ratios = [], {"compute": [], "memory": [], "collective": []}
    for c in _cells():
        cfg = get_config(c["arch"])
        shape = SHAPES[c["shape"]]
        tp = TpuParams(
            dp=16, tp=16, n_micro=c.get("n_microbatches") or 1,
            ep=16 if cfg.n_experts else 1,
        )
        m = step_model(cfg, shape, tp)
        r = c["roofline"]
        row = [f"{c['arch']}/{c['shape']}"]
        for key, pred in [
            ("compute_s", m.compute_s), ("memory_s", m.memory_s),
            ("collective_s", m.collective_s),
        ]:
            meas = r[key]
            row += [pred, meas]
            if pred > 0 and meas > 0:
                ratios[key.split("_")[0]].append(meas / pred)
        rows.append(row)

    lines = ["Predicted (paper-methodology model) vs measured (parsed HLO):", ""]
    lines += table(
        ["cell", "pred comp", "meas comp", "pred mem", "meas mem",
         "pred coll", "meas coll"], rows,
    )
    lines += ["", "## fitted efficiency factors (geometric mean meas/pred)"]
    fitted = {}
    for k, v in ratios.items():
        if v:
            fitted[k] = float(np.exp(np.mean(np.log(v))))
            spread = float(np.exp(np.std(np.log(v))))
            lines.append(f"- eff_{k} = {fitted[k]:.2f} (log-spread x{spread:.2f})")

    # per-shape-kind factors: train/prefill/decode have different fusion
    # and collective structure, exactly as the paper fits separate cost
    # factors per phase rather than one global constant.
    lines += ["", "## per-shape-kind factors"]
    by_kind: dict = {}
    for c in _cells():
        cfg = get_config(c["arch"])
        shape = SHAPES[c["shape"]]
        tp = TpuParams(dp=16, tp=16, n_micro=c.get("n_microbatches") or 1,
                       ep=16 if cfg.n_experts else 1)
        m = step_model(cfg, shape, tp)
        r = c["roofline"]
        for key, pred in [("compute_s", m.compute_s), ("memory_s", m.memory_s),
                          ("collective_s", m.collective_s)]:
            if pred > 0 and r[key] > 0:
                by_kind.setdefault((shape.kind, key.split("_")[0]), []).append(
                    r[key] / pred
                )
    for (kind, term), v in sorted(by_kind.items()):
        gm = float(np.exp(np.mean(np.log(v))))
        lines.append(f"- {kind:8s} eff_{term} = {gm:6.2f} (n={len(v)})")
    lines += [
        "",
        "Reading: compute tracks within ~20% for dense archs (MoE cells "
        "measure the dense-dispatch waste the §Perf hillclimb removes); the "
        "memory factor absorbs XLA temp/convert round-trips exactly as the "
        "paper's cIO factors absorb disk-cache effects; fitted factors slot "
        "into TpuCostFactors for calibrated what-if tuning.",
    ]

    # calibrated prediction with PER-KIND factors (leave-none-out demo of
    # the paper's workflow: fit Table-3 analogues, then predict)
    if by_kind:
        kind_cf = {}
        for kind in ("train", "prefill", "decode"):
            kw = {}
            for term in ("compute", "memory", "collective"):
                v = by_kind.get((kind, term))
                if v:
                    kw[f"eff_{term}"] = float(np.exp(np.mean(np.log(v))))
            kind_cf[kind] = TpuCostFactors(**kw)
        errs = []
        for c in _cells():
            cfg = get_config(c["arch"])
            shape = SHAPES[c["shape"]]
            tp = TpuParams(dp=16, tp=16, n_micro=c.get("n_microbatches") or 1,
                           ep=16 if cfg.n_experts else 1)
            m = step_model(cfg, shape, tp, kind_cf[shape.kind])
            meas = max(c["roofline"]["compute_s"], c["roofline"]["memory_s"],
                       c["roofline"]["collective_s"])
            errs.append(abs(m.overlap_s - meas) / meas)
        lines += ["", f"calibrated dominant-term prediction (per-kind factors): "
                  f"median rel err = {float(np.median(errs)):.2f} over "
                  f"{len(errs)} cells"]
    write_md("tpu_model.md", "E9: analytical model vs dry-run", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
