"""E15 — dollar-cost elastic provisioning: priced planner vs per-event DES.

The provisioning question — "which fleet mix, spot share, and autoscaler
setting meets the latency SLO at the lowest dollars-per-job?" — is answered
twice in this repo: exactly by the elastic DES
(:func:`repro.cluster.sched.simulate_workload` with a
:class:`repro.cloud.ElasticFleet`, per-node billing episodes) and at search
speed by the wave rollout behind :class:`repro.cloud.CloudEvaluator`.  This
benchmark is the contract between the two.

Claims, asserted rather than eyeballed:

1. **Autoscaled agreement** — on a contention-free (serialized) trace with
   the ``predicted`` autoscaler (provision latency 5 s), the wave rollout
   reproduces per-job DES finish times AND the episode-billed fleet dollars
   within rtol 1e-3.  Same gate for a fixed mixed spot/on-demand fleet.
2. **Contended spot** — under slot contention with live spot reclamation
   the wave's expectation model tracks the DES (averaged over reclaim
   seeds) to < 15% relative error on p95 latency and dollars-per-job.
3. **Pareto recovery** — grid search over (pOnDemandNodes, pSpotNodes)
   under a decisive SLO recovers the known-cheapest feasible fleet on a
   hand-checkable two-class grid, cross-verified against DES episode
   billing (``exact_cost``) for every feasible cell.
4. **Throughput** — the vmapped evaluator prices a planner-shaped batch
   (mixed fleets, reclaim rates, autoscaler settings) faster than the
   per-scenario elastic DES (reported; >= 10x asserted in full mode).

Run:  PYTHONPATH=src python -m benchmarks.bench_cloud [--smoke] [--quick]
"""

from __future__ import annotations

import numpy as np

from repro.cloud import (
    CloudEvaluator,
    ElasticFleet,
    SloUnmetError,
    bill_workload,
    pareto_front,
    wave_columns,
)
from repro.cluster import (
    ClusterConfig,
    NodeClass,
    UnfinishedWorkloadError,
    default_job_classes,
    pack_trace,
    poisson_trace,
    rescale,
    simulate_batch,
    simulate_workload,
)
from repro.core.hadoop.simulator import SimConfig
from repro.search import grid_search_ev
from repro.search.evaluator import ExactCostUnavailable

from .common import report, table, timer, write_md

CLEAN = SimConfig(speculative_execution=False)
PRICE_OD = 0.40
PRICE_SPOT = 0.10


def _wave_scen(cols, cc: ClusterConfig, rate: float, el: ElasticFleet):
    """One packed trace + a cluster + a fleet -> a 1-row wave scenario
    carrying the same class columns and cloud knobs the DES integrates."""
    n = cc.num_nodes
    classes = cc.node_classes or (NodeClass(n, 1.0),)
    mpn, rpn = cc.map_slots_per_node, cc.reduce_slots_per_node
    wc = wave_columns(el, cc)
    return {
        "arrival": (cols["arrival"] / rate)[None, :],
        "n_maps": cols["n_maps"][None, :],
        "n_reds": cols["n_reds"][None, :],
        "map_cost": cols["map_cost"][None, :],
        "red_work": cols["red_work"][None, :],
        "shuffle": (cols["shuffle"] * (n - 1) / n)[None, :],
        "queue": cols["queue"][None, :],
        "map_slots": np.array([[float(nc.count * mpn) for nc in classes]]),
        "red_slots": np.array([[float(nc.count * rpn) for nc in classes]]),
        "speedup": np.array([[nc.speedup for nc in classes]]),
        "policy": np.zeros(1),
        "slowstart": np.array([cc.reduce_slowstart]),
        "reclaim_rate": wc["reclaim_rate"][None, :],
        "autoscale": np.array([wc["autoscale"]]),
        "high_water": np.array([wc["high_water"]]),
        "provision_latency": np.array([wc["provision_latency"]]),
        "extra_map_slots": np.array([wc["extra_map_slots"]]),
        "extra_red_slots": np.array([wc["extra_red_slots"]]),
        "billing_quantum": np.array([wc["billing_quantum"]]),
    }


def _wave_dollars(out, cc: ClusterConfig, el: ElasticFleet) -> float:
    """The evaluator's pricing rule on a 1-row rollout: base fleet billed
    over the makespan, the autoscaled block over its online episodes."""
    classes = cc.node_classes or (NodeClass(cc.num_nodes, 1.0),)
    fleet_rate = sum(nc.count * nc.hourly_price for nc in classes)
    extra_price = (el.extra_hourly_price if el.extra_hourly_price is not None
                   else classes[-1].hourly_price)
    span = float(np.asarray(out["makespan"])[0])
    billed = float(np.asarray(out.get("extra_billed_s", np.zeros(1)))[0])
    n_extra = el.max_extra_nodes if el.policy_code > 0 else 0
    return (fleet_rate * span + extra_price * n_extra * billed) / 3600.0


def _des_dollars(res, cc: ClusterConfig, el: ElasticFleet) -> float:
    window = (min(j.submit_time for j in res.jobs), res.makespan)
    return bill_workload(res, cc, elastic=el, window=window)


def run(quick: bool = False, smoke: bool = False) -> list[str]:
    small = quick or smoke
    # 12 jobs keeps the autoscaler in a single provision/teardown cycle —
    # the zone where the wave's one-block model is exact; longer traces
    # re-provision mid-run, which the wave only tracks in aggregate
    n_jobs = 12
    # the stochastic-reclaim comparison needs the seed average to settle;
    # 12-job elastic DES runs are cheap enough to keep 8 seeds in smoke too
    n_seeds_des = 8

    classes = default_job_classes()
    trace = poisson_trace(classes, n_jobs, rate=1.0, seed=3)
    cols = pack_trace(trace)

    # ---- 1. autoscaled + fixed-fleet agreement (hard gate) ----
    agree_rows = []
    for label, cc, el, rate in [
        ("predicted autoscale",
         ClusterConfig(num_nodes=2,
                       node_classes=(NodeClass(2, 1.0, PRICE_OD),)),
         ElasticFleet(policy="predicted", max_extra_nodes=2, high_water=2.0,
                      provision_latency=5.0),
         0.002),
        ("fixed spot mix",
         ClusterConfig(num_nodes=4,
                       node_classes=(NodeClass(2, 1.0, PRICE_SPOT, spot=True),
                                     NodeClass(2, 1.0, PRICE_OD))),
         ElasticFleet(),
         0.002),
    ]:
        des = simulate_workload(rescale(trace, rate), cc, CLEAN, elastic=el)
        assert des.n_unfinished == 0, f"{label}: DES left jobs unfinished"
        out = simulate_batch(_wave_scen(cols, cc, rate, el))
        assert float(out["converged"][0]) == 1.0, f"{label}: rollout truncated"
        des_fin = np.array([j.finish for j in des.jobs])
        fin_rel = float(np.max(np.abs(np.asarray(out["finish"])[0] - des_fin)
                               / np.maximum(des_fin, 1e-9)))
        d_wave = _wave_dollars(out, cc, el)
        d_des = _des_dollars(des, cc, el)
        usd_rel = abs(d_wave - d_des) / max(d_des, 1e-12)
        assert fin_rel < 1e-3, f"{label}: finish mismatch {fin_rel:.2e}"
        assert usd_rel < 1e-3, f"{label}: dollars mismatch {usd_rel:.2e}"
        agree_rows.append([label, fin_rel, usd_rel, d_des, d_wave])

    # ---- 2. contended spot: expectation model vs stochastic reclaims ----
    cc = ClusterConfig(num_nodes=4,
                       node_classes=(NodeClass(2, 1.0, PRICE_SPOT, spot=True),
                                     NodeClass(2, 1.0, PRICE_OD)))
    rate, reclaim = 0.1, 0.01
    p95s, dpjs, reclaims = [], [], 0
    for seed in range(n_seeds_des):
        el = ElasticFleet(reclaim_rate=reclaim, provision_latency=10.0,
                          seed=seed)
        des = simulate_workload(rescale(trace, rate), cc, CLEAN, elastic=el)
        assert des.n_unfinished == 0, "contended spot DES left jobs behind"
        p95s.append(des.p95_latency)
        dpjs.append(_des_dollars(des, cc, el) / n_jobs)
        reclaims += des.num_reclaimed
    assert reclaims > 0, "no reclaim ever fired — the scenario is not spot"
    el = ElasticFleet(reclaim_rate=reclaim, provision_latency=10.0)
    out = simulate_batch(_wave_scen(cols, cc, rate, el))
    assert float(out["converged"][0]) == 1.0, "contended rollout truncated"
    p95_rel = abs(float(out["p95_latency"][0]) - float(np.mean(p95s))) \
        / max(float(np.mean(p95s)), 1e-9)
    dpj_wave = _wave_dollars(out, cc, el) / n_jobs
    dpj_rel = abs(dpj_wave - float(np.mean(dpjs))) \
        / max(float(np.mean(dpjs)), 1e-12)
    assert p95_rel < 0.15, f"contended spot p95 drifted {p95_rel:.2%} from DES"
    assert dpj_rel < 0.15, f"contended spot $ drifted {dpj_rel:.2%} from DES"

    # ---- 3. Pareto recovery: grid search finds the DES-cheapest fleet ----
    tr = poisson_trace(classes, n_jobs, seed=5)
    ev = CloudEvaluator(classes, traces=[tr], n_seeds=1, sim=CLEAN, chunk=16,
                        base_rate=0.05, on_demand_price=PRICE_OD,
                        spot_price=PRICE_SPOT, slo_target=0.9)
    od_vals, sp_vals = [1.0, 2.0, 4.0], [0.0, 2.0, 4.0]
    # a decisive SLO: 1-node fleets miss it, anything >= 3 nodes meets it
    slo = float(np.percentile(
        [j.finish - j.submit_time for j in simulate_workload(
            rescale(tr, 0.05),
            ClusterConfig(num_nodes=3,
                          node_classes=(NodeClass(3, 1.0, PRICE_OD),)),
            CLEAN).jobs], 97.0))
    tuned = grid_search_ev(ev, {"pOnDemandNodes": od_vals,
                                "pSpotNodes": sp_vals,
                                "sloLatency": [slo]})
    # DES ground truth over the same grid, billed per episode
    exact = {}
    for od in od_vals:
        for sp in sp_vals:
            try:
                exact[(od, sp)] = ev.exact_cost(
                    {"pOnDemandNodes": od, "pSpotNodes": sp,
                     "sloLatency": slo})
            except (SloUnmetError, UnfinishedWorkloadError,
                    ExactCostUnavailable):
                exact[(od, sp)] = float("inf")
    finite = {k: v for k, v in exact.items() if np.isfinite(v)}
    assert finite, "SLO infeasible everywhere — grid is not decisive"
    assert any(not np.isfinite(v) for v in exact.values()), \
        "every cell feasible — SLO is not decisive"
    want = min(finite, key=finite.get)
    got = (tuned.best_assignment["pOnDemandNodes"],
           tuned.best_assignment["pSpotNodes"])
    assert got == want, f"search picked {got}, DES-cheapest is {want}"
    assert np.isfinite(tuned.best_cost)
    # the spot-heaviest feasible mix wins on this price spread
    assert want[1] > 0, "cheapest config should carry spot capacity"

    # Pareto front over the grid: cost vs (negated) SLO attainment
    res = ev.evaluate({
        "pOnDemandNodes": np.repeat(od_vals, len(sp_vals)),
        "pSpotNodes": np.tile(sp_vals, len(od_vals)),
        "sloLatency": np.full(len(od_vals) * len(sp_vals), slo),
    })
    front = pareto_front(np.asarray(res.outputs["c_dollarsPerJob"]),
                         -np.asarray(res.outputs["c_sloAttain"]))
    assert front.any(), "empty Pareto front over a feasible grid"

    # ---- 4. throughput: vmapped pricing vs per-scenario elastic DES ----
    batch = 64 if small else 256
    ev_t = CloudEvaluator(classes, traces=[tr], n_seeds=1, sim=CLEAN,
                          chunk=batch, base_rate=0.05, slo_target=0.9)
    rng = np.random.default_rng(0)
    grid = {"pOnDemandNodes": rng.choice(od_vals, batch),
            "pSpotNodes": rng.choice(sp_vals, batch),
            "spotReclaimRate": rng.choice([0.0, 5e-3], batch),
            "autoscalePolicy": rng.choice([0.0, 1.0], batch),
            "autoscaleHighWater": np.full(batch, 2.0)}
    ev_t.evaluate(grid)                            # compile out of the timing
    with timer() as t_vec:
        ev_t.evaluate(grid)
    vec_rate = batch / t_vec.s
    n_des = 3 if small else 6
    with timer() as t_des:
        for (od, sp) in list(finite)[:n_des]:
            try:
                ev.exact_cost({"pOnDemandNodes": od, "pSpotNodes": sp})
            except ExactCostUnavailable:
                pass
    des_rate = min(n_des, len(finite)) / t_des.s
    speedup = vec_rate / des_rate
    if not small:
        assert speedup >= 10.0, f"evaluator speedup {speedup:.1f}x < 10x"

    lines = ["## DES <-> wave agreement (priced, elastic)", ""]
    lines += table(["scenario", "finish rel", "$ rel", "DES $", "wave $"],
                   agree_rows)
    lines += ["", "## contended spot (expectation vs stochastic reclaims)", ""]
    lines += table(
        ["metric", "DES mean", "wave", "rel err"],
        [["p95 latency", float(np.mean(p95s)),
          float(out["p95_latency"][0]), p95_rel],
         ["dollars/job", float(np.mean(dpjs)), dpj_wave, dpj_rel]])
    lines += ["", "## price/performance search", "",
              f"- cheapest feasible fleet: {int(want[0])} on-demand + "
              f"{int(want[1])} spot at ${tuned.best_cost:.4f}/job "
              f"(SLO {slo:.0f} s, {len(finite)}/{len(exact)} cells feasible)",
              f"- Pareto front keeps {int(front.sum())}/{front.size} "
              f"grid cells",
              f"- evaluator throughput {vec_rate:.1f} scen/s vs DES "
              f"{des_rate:.1f} scen/s ({speedup:.1f}x)"]
    report("bench_cloud",
           agree_finish_rel=max(r[1] for r in agree_rows),
           agree_dollars_rel=max(r[2] for r in agree_rows),
           contended_p95_rel=p95_rel, contended_dpj_rel=dpj_rel,
           best_dollars_per_job=tuned.best_cost,
           pareto_cells=int(front.sum()), speedup=speedup)
    write_md("BENCH_cloud.md", "E15 — elastic provisioning", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
