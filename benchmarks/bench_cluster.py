"""E13 — vectorized capacity planner vs per-scenario multi-job DES.

The capacity-planning question — "which fleet mix under which scheduler
keeps p95 job latency down under this workload?" — needs thousands of
(workload-seed x cluster-config) scenarios.  The baseline answers each with
one Python DES run (:func:`repro.cluster.sched.simulate_workload`); the
vectorized wave simulator (:mod:`repro.cluster.vector_sim`) rolls a whole
batch out in one compiled ``vmap``'d ``while_loop``.

Claims, asserted rather than eyeballed:

1. **Agreement** — on contention-free FIFO scenarios (homogeneous AND
   heterogeneous fleets) the wave rollout reproduces per-job DES finish
   times within rtol 1e-3 (float32 vs the Python floats; the wave structure
   itself is exact), and on the canonical big-job/small-job preemption
   scenario the kill-and-requeue reallocation matches the DES for both
   ``fair_preempt`` and ``capacity`` at the same rtol.
2. **Convergence accounting** — every scenario either converges or is
   flagged (``converged == 0``); nothing silently truncates.
3. **Shuffle-contention agreement** — with topology columns present the
   wave rollout still matches the DES within rtol 1e-3 when the fabric is
   flat or uncontended, and stays within p95 relative error < 15% on a
   contended incast burst (count-based max-min approximation vs the DES's
   exact progressive-filling flow rates).
4. **DAG + topology search** — on an incast-heavy two-stage DAG workload,
   a topology-aware ``api.tune`` (racks / cross-rack bandwidth /
   oversubscription searchable) strictly beats the flat-network optimum
   when both winners are re-costed by the exact DES under the contended
   ambient fabric.
5. **Throughput** — >= 50x scenarios/s over the per-scenario DES on a
   planner-shaped batch (full mode; smoke asserts 1-4 and reports numbers).
   The policy/fleet-mix batch (all four schedulers + heterogeneous rows) is
   reported alongside the classic gate batch.

Run:  PYTHONPATH=src python -m benchmarks.bench_cluster [--smoke] [--quick]
"""

from __future__ import annotations

import numpy as np

from repro.cluster import (
    ClusterConfig,
    ClusterEvaluator,
    JobArrival,
    JobClass,
    NodeClass,
    POLICIES,
    Topology,
    WorkloadTrace,
    dag_from_templates,
    dag_trace,
    default_job_classes,
    estimate_steps,
    pack_trace,
    poisson_trace,
    rescale,
    simulate_batch,
    simulate_workload,
)
from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.core.hadoop.simulator import SimConfig

from .common import table, timer, write_md

CLEAN = SimConfig(speculative_execution=False)


def scenario_batch(cols, nodes, mpn, rpn, policy, slowstart, rate, *,
                   fast=None, speedup=None, queue_frac=None, topo=None):
    """(B,)-arrays of cluster knobs + one packed trace -> a scenario dict.
    ``fast``/``speedup`` describe a two-class fleet (fast nodes + baseline
    remainder); omitted means homogeneous.  ``topo`` is a shared
    :class:`~repro.cluster.Topology` applied to every row."""
    b = len(nodes)
    tile = lambda a: np.tile(a, (b, 1))
    frac = (nodes - 1.0) / nodes
    scen = {
        "arrival": tile(cols["arrival"]) / rate[:, None],
        "n_maps": tile(cols["n_maps"]),
        "n_reds": tile(cols["n_reds"]),
        "map_cost": tile(cols["map_cost"]),
        "red_work": tile(cols["red_work"]),
        "shuffle": tile(cols["shuffle"]) * frac[:, None],
        "queue": tile(cols["queue"]),
        "policy": policy,
        "slowstart": slowstart,
    }
    if topo is not None:
        scen["topo_racks"] = np.full(b, float(topo.num_racks))
        scen["topo_cross_bw"] = np.full(b, float(topo.cross_rack_bw))
        scen["topo_oversub"] = np.full(b, float(topo.oversub))
    if fast is None:
        # homogeneous: 1-D slot columns keep the lean one-class kernel
        scen["map_slots"] = nodes * mpn
        scen["red_slots"] = nodes * rpn
    else:
        speedup = np.ones(b) if speedup is None else speedup
        base_n = nodes - fast
        scen["map_slots"] = np.stack([fast * mpn, base_n * mpn], axis=1)
        scen["red_slots"] = np.stack([fast * rpn, base_n * rpn], axis=1)
        scen["speedup"] = np.stack([speedup, np.ones(b)], axis=1)
    if queue_frac is not None:
        scen["queue_frac"] = np.tile(np.asarray(queue_frac), (b, 1))
    return scen


def _fleet_config(nodes, mpn, rpn, policy, slowstart, *, fast=0, speedup=1.0):
    fleet = ()
    if fast > 0 and speedup > 1.0:
        fleet = (NodeClass(int(fast), float(speedup)),) + (
            (NodeClass(int(nodes - fast), 1.0),) if nodes > fast else ())
    return ClusterConfig(
        num_nodes=int(nodes), map_slots_per_node=int(mpn),
        reduce_slots_per_node=int(rpn), scheduler=POLICIES[int(policy)],
        reduce_slowstart=float(slowstart), node_classes=fleet,
        preempt_timeout=0.0)


def _big_small_trace():
    big = JobClass("batch", HadoopParams(pNumMappers=64, pNumReducers=8,
                                         pSplitSize=64 * MiB),
                   ProfileStats(), CostFactors())
    small = JobClass("adhoc", HadoopParams(pNumMappers=4, pNumReducers=1,
                                           pSplitSize=64 * MiB),
                     ProfileStats(), CostFactors())
    return WorkloadTrace((JobArrival(0, big, 0.0), JobArrival(1, small, 30.0)))


def run(quick: bool = False, smoke: bool = False) -> list[str]:
    small = quick or smoke
    n_jobs = 24 if small else 64
    batch = 256 if small else 2048
    n_des = 4 if small else 6
    rate = 0.1

    classes = default_job_classes()
    trace = poisson_trace(classes, n_jobs, rate=1.0, seed=3)
    cols = pack_trace(trace)

    # ---- agreement: contention-free FIFO + preemptive scenarios vs DES ----
    agree_rows = []
    for label, n, nfast, spd, pol, scen_rate, hard in [
        ("serialized", 4, 0, 1.0, 0, 0.002, True),  # huge gaps: no overlap
        ("uncontended", 64, 0, 1.0, 0, rate, True),  # slots never exhausted
        ("het uncontended", 64, 32, 2.0, 0, rate, True),   # mixed fleet
        ("contended", 4, 0, 1.0, 0, rate, False),   # the approximation zone
        ("het contended", 4, 2, 2.0, 0, rate, False),
    ]:
        cc = _fleet_config(n, 2, 2, pol, 0.05, fast=nfast, speedup=spd)
        des = simulate_workload(rescale(trace, scen_rate), cc, CLEAN)
        des_fin = np.array([j.finish for j in des.jobs])
        out = simulate_batch(scenario_batch(
            cols, np.array([float(n)]), np.array([2.0]), np.array([2.0]),
            np.array([float(pol)]), np.array([0.05]),
            np.array([scen_rate]), fast=np.array([float(nfast)]),
            speedup=np.array([spd])))
        assert out["converged"][0] == 1.0, f"{label}: rollout truncated"
        rel = float(np.max(np.abs(out["finish"][0] - des_fin)
                           / np.maximum(des_fin, 1e-9)))
        if hard:
            assert rel < 1e-3, f"{label}: DES<->vector mismatch {rel:.2e}"
        agree_rows.append([label, n, scen_rate, rel,
                           des.p95_latency, float(out["p95_latency"][0])])

    # preemptive schedulers: the canonical big/small kill-and-requeue
    # scenario reproduces the DES exactly for fair_preempt AND capacity
    bs_trace = _big_small_trace()
    bs_cols = pack_trace(bs_trace)
    for label, pol in [("fair_preempt big/small", 2),
                       ("capacity big/small", 3)]:
        cc = _fleet_config(2, 2, 2, pol, 0.05)
        des = simulate_workload(bs_trace, cc, CLEAN)
        assert des.num_preempted > 0, f"{label}: preemption did not fire"
        out = simulate_batch(scenario_batch(
            bs_cols, np.array([2.0]), np.array([2.0]), np.array([2.0]),
            np.array([float(pol)]), np.array([0.05]), np.array([1.0]),
            queue_frac=[0.5, 0.5]))
        assert out["converged"][0] == 1.0, f"{label}: rollout truncated"
        des_fin = np.array([j.finish for j in des.jobs])
        rel = float(np.max(np.abs(out["finish"][0] - des_fin)
                           / np.maximum(des_fin, 1e-9)))
        assert rel < 1e-3, f"{label}: DES<->vector mismatch {rel:.2e}"
        agree_rows.append([label, 2, 1.0, rel,
                           des.p95_latency, float(out["p95_latency"][0])])

    # preemptive under a contended mixed workload: the wave-merge
    # approximation zone — asserted at the aggregate (p95) level only
    for label, pol in [("fair_preempt mixed", 2), ("capacity mixed", 3)]:
        cc = _fleet_config(4, 2, 2, pol, 0.05)
        des = simulate_workload(rescale(trace, 0.02), cc, CLEAN)
        qf = [1.0 / 4] * 4
        out = simulate_batch(scenario_batch(
            cols, np.array([4.0]), np.array([2.0]), np.array([2.0]),
            np.array([float(pol)]), np.array([0.05]), np.array([0.02]),
            queue_frac=qf))
        assert out["converged"][0] == 1.0, f"{label}: rollout truncated"
        des_fin = np.array([j.finish for j in des.jobs])
        rel = float(np.max(np.abs(out["finish"][0] - des_fin)
                           / np.maximum(des_fin, 1e-9)))
        p95_rel = abs(float(out["p95_latency"][0]) - des.p95_latency) \
            / max(des.p95_latency, 1e-9)
        assert p95_rel < 0.15, f"{label}: p95 drifted {p95_rel:.2%} from DES"
        agree_rows.append([label, 4, 0.02, rel,
                           des.p95_latency, float(out["p95_latency"][0])])

    # ---- shuffle contention: DES (max-min fair shares) vs wave (count
    # approximation).  Flat/uncontended rows must be exact (the topology
    # columns cost nothing when they don't bind); contended incast rows
    # are the approximation zone, asserted at p95 < 15%.
    tight = Topology(num_racks=4, cross_rack_bw=0.5, oversub=2.0)
    by_name = {c.name: c for c in classes}
    one_sort = WorkloadTrace((JobArrival(0, by_name["sort"], 0.0),))
    # heterogeneous FIFO burst: a sort's shuffle overlaps a filter's —
    # x1.4 contended, the staggered-overlap approximation zone
    burst = WorkloadTrace((JobArrival(0, by_name["sort"], 0.0),
                           JobArrival(1, by_name["filter"], 2.0)))
    # symmetric fair-share burst: three filters arrive together, every
    # wave launches into the same contended snapshot
    fair_burst = WorkloadTrace(tuple(
        JobArrival(i, by_name["filter"], 0.0) for i in range(3)))
    for label, tr_, topo, rpn, pol, hard in [
        ("topo columns, flat", trace, Topology.flat(), 2.0, 0.0, True),
        ("topo columns, uncontended", trace,
         Topology(num_racks=2, cross_rack_bw=1e9), 2.0, 0.0, True),
        ("single incast job", one_sort, tight, 2.0, 0.0, True),
        ("contended incast burst", burst, tight, 4.0, 0.0, False),
        ("contended fair-share incast", fair_burst, tight, 4.0, 1.0, False),
    ]:
        cc = ClusterConfig(num_nodes=8, map_slots_per_node=2,
                           reduce_slots_per_node=int(rpn),
                           scheduler="fair" if pol else "fifo",
                           reduce_slowstart=0.05,
                           topology=None if topo.is_flat else topo)
        des = simulate_workload(tr_, cc, CLEAN)
        cols_ = cols if tr_ is trace else pack_trace(tr_)
        out = simulate_batch(scenario_batch(
            cols_, np.array([8.0]), np.array([2.0]), np.array([rpn]),
            np.array([pol]), np.array([0.05]), np.array([1.0]), topo=topo))
        assert out["converged"][0] == 1.0, f"{label}: rollout truncated"
        des_fin = np.array([j.finish for j in des.jobs])
        rel = float(np.max(np.abs(out["finish"][0] - des_fin)
                           / np.maximum(des_fin, 1e-9)))
        p95_rel = abs(float(out["p95_latency"][0]) - des.p95_latency) \
            / max(des.p95_latency, 1e-9)
        if hard:
            assert rel < 1e-3, f"{label}: DES<->vector mismatch {rel:.2e}"
        else:
            assert p95_rel < 0.15, f"{label}: p95 drifted {p95_rel:.2%}"
        agree_rows.append([label, 8, 1.0, rel,
                           des.p95_latency, float(out["p95_latency"][0])])

    # ---- DAG + topology end-to-end search: a planner that can see the
    # network beats one that cannot.  Both tune the same reduce-slot knob
    # on an incast-heavy DAG workload (sort -> sort chains); the aware
    # planner also searches the rack striping.  Both winners are then
    # costed by the trusted DES under the contended ambient topology —
    # the topology-aware choice must be strictly cheaper.
    import repro.api as api

    chain = dag_from_templates(
        "etl", [by_name["sort"], by_name["sort"]], [(0, 1, "barrier")])
    dag_tr = dag_trace(chain, n_instances=3, inter_arrival=2.0)
    ambient = {"pNumRacks": 4.0, "crossRackBw": 0.5, "oversubscription": 2.0}
    mk_ev = lambda: ClusterEvaluator(
        traces=[dag_tr], base=ClusterConfig(num_nodes=8), base_rate=1.0,
        sim=CLEAN, chunk=8)
    knobs = {"pMaxRedPerNode": [1.0, 2.0, 4.0]}
    blind_best = dict(api.tune(mk_ev(), dict(knobs),
                               strategy="grid").best_assignment)
    aware = mk_ev()
    aware_best = dict(api.tune(
        aware, {**knobs, "pNumRacks": [4.0, 8.0], "crossRackBw": [0.5],
                "oversubscription": [2.0]},
        strategy="grid").best_assignment)
    cost_blind = aware.exact_cost({**blind_best, **ambient})
    cost_aware = aware.exact_cost(aware_best)
    assert cost_aware < cost_blind, (
        f"topology-aware search did not beat the flat-network optimum "
        f"({cost_aware:.2f} vs {cost_blind:.2f})")
    dag_gain = (cost_blind - cost_aware) / cost_blind

    # ---- throughput: planner grid, vector batch vs per-scenario DES ----
    rng = np.random.default_rng(0)
    nodes = rng.choice([8.0, 16.0, 32.0, 64.0], batch)
    mpn = rng.choice([2.0, 4.0], batch)
    rpn = rng.choice([2.0, 4.0], batch)
    fair = (rng.random(batch) > 0.5).astype(np.float64)
    slow = rng.choice([0.05, 0.8], batch)
    rates = rng.choice([0.05, rate, 0.2], batch)
    # one sub-batch per policy: pure-FIFO batches compile the lean
    # prefix-allocation kernel, and each group's rollout stops at its own
    # last event instead of the global worst case
    groups = []
    for mask in (fair < 0.5, fair >= 0.5):
        scen = scenario_batch(cols, nodes[mask], mpn[mask], rpn[mask],
                              fair[mask], slow[mask], rates[mask])
        groups.append((scen, estimate_steps(scen)))

    for scen, n_steps in groups:                   # compile out of the timing
        simulate_batch(scen, n_steps=n_steps)
    with timer() as t_vec:
        outs = [simulate_batch(scen, n_steps=n_steps)
                for scen, n_steps in groups]
    for out in outs:
        assert float(out["converged"].mean()) == 1.0, "unconverged scenarios"
    vec_rate = batch / t_vec.s

    with timer() as t_des:
        for i in range(n_des):
            cc = ClusterConfig(
                num_nodes=int(nodes[i]), map_slots_per_node=int(mpn[i]),
                reduce_slots_per_node=int(rpn[i]),
                scheduler="fair" if fair[i] else "fifo",
                reduce_slowstart=float(slow[i]))
            simulate_workload(rescale(trace, float(rates[i])), cc, CLEAN)
    des_rate = n_des / t_des.s
    speedup = vec_rate / des_rate
    if not small:
        assert speedup >= 50.0, f"vector speedup {speedup:.1f}x < 50x target"

    # the full scenario family: all four policies + heterogeneous fleets,
    # grouped by policy (one compile per scheduler family); reported, with
    # convergence asserted
    pols = rng.choice([0.0, 1.0, 2.0, 3.0], batch)
    fasts = np.minimum(rng.choice([0.0, 4.0, 8.0], batch), nodes)
    spds = np.where(fasts > 0, rng.choice([1.5, 2.0], batch), 1.0)
    qf = [1.0 / 4] * 4
    mix_groups = []
    for p in (0.0, 1.0, 2.0, 3.0):
        mask = pols == p
        scen = scenario_batch(cols, nodes[mask], mpn[mask], rpn[mask],
                              pols[mask], slow[mask], rates[mask],
                              fast=fasts[mask], speedup=spds[mask],
                              queue_frac=qf)
        mix_groups.append((scen, estimate_steps(scen)))
    for scen, n_steps in mix_groups:
        simulate_batch(scen, n_steps=n_steps)
    with timer() as t_mix:
        mix_outs = [simulate_batch(scen, n_steps=n_steps)
                    for scen, n_steps in mix_groups]
    for out in mix_outs:
        assert float(out["converged"].mean()) == 1.0, "unconverged mix rows"
    mix_rate = batch / t_mix.s

    caps = "/".join(str(ns) for _, ns in groups)
    lines = [
        f"workload: {n_jobs} Poisson jobs over the 4-class mix; planner "
        f"batch of {batch} (cluster-config x load) scenarios, "
        f"step caps {caps} (fifo/fair groups)"
        f"{', smoke' if smoke else ', quick' if quick else ''}",
        "",
        "DES<->vector agreement (per-job finish times, rtol; contention-free "
        "FIFO rows — homogeneous AND heterogeneous — plus the big/small "
        "preemption scenarios and the flat/uncontended/single-incast "
        "topology rows **asserted** < 1e-3; contended rows reported, "
        "preemptive mixed and contended-incast rows asserted at p95 < 15%):",
        "",
    ]
    lines += table(
        ["scenario", "nodes", "rate", "max rel err", "DES p95 s", "vec p95 s"],
        agree_rows,
    )
    lines += [
        "",
        "DAG + topology search gate: tuning the same knobs on an "
        "incast-heavy sort->sort DAG workload, the topology-aware planner "
        f"(racks searchable) beats the flat-network optimum by "
        f"**{dag_gain:.0%}** true (DES) p95 latency under the contended "
        "ambient fabric — asserted strict.",
        "",
        "scenario throughput (one compiled rollout vs per-scenario Python "
        "DES):",
        "",
    ]
    lines += table(
        ["path", "scenarios", "wall s", "scenarios/s"],
        [["python DES (per scenario)", n_des, t_des.s, des_rate],
         ["vectorized wave rollout (fifo/fair)", batch, t_vec.s, vec_rate],
         ["vectorized, 4 policies + het fleets", batch, t_mix.s, mix_rate]],
    )
    lines += ["", f"**vectorized speedup: {speedup:.0f}x** scenarios/s "
                  "over the per-scenario DES "
                  f"({mix_rate / des_rate:.0f}x on the full policy/fleet mix)"]
    write_md("cluster.md", "Vectorized capacity planner throughput", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
