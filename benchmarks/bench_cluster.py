"""E13 — vectorized capacity planner vs per-scenario multi-job DES.

The capacity-planning question — "which cluster shape keeps p95 job latency
down under this workload?" — needs thousands of (workload-seed x
cluster-config) scenarios.  The baseline answers each with one Python DES
run (:func:`repro.cluster.sched.simulate_workload`); the vectorized wave
simulator (:mod:`repro.cluster.vector_sim`) rolls a whole batch out in one
compiled ``vmap``'d ``while_loop``.

Three claims, asserted rather than eyeballed:

1. **Agreement** — on contention-free FIFO scenarios the wave rollout
   reproduces per-job DES finish times within rtol 1e-3 (float32 vs the
   Python floats; the wave structure itself is exact).
2. **Convergence accounting** — every scenario either converges or is
   flagged (``converged == 0``); nothing silently truncates.
3. **Throughput** — >= 50x scenarios/s over the per-scenario DES on a
   planner-shaped batch (full mode; smoke asserts 1+2 and reports numbers).

Run:  PYTHONPATH=src python -m benchmarks.bench_cluster [--smoke] [--quick]
"""

from __future__ import annotations

import numpy as np

from repro.cluster import (
    ClusterConfig,
    default_job_classes,
    estimate_steps,
    pack_trace,
    poisson_trace,
    rescale,
    simulate_batch,
    simulate_workload,
)
from repro.core.hadoop.simulator import SimConfig

from .common import table, timer, write_md

CLEAN = SimConfig(speculative_execution=False)


def scenario_batch(cols, nodes, mpn, rpn, fair, slowstart, rate):
    """(B,)-arrays of cluster knobs + one packed trace -> a scenario dict."""
    b = len(nodes)
    tile = lambda a: np.tile(a, (b, 1))
    frac = (nodes - 1.0) / nodes
    return {
        "arrival": tile(cols["arrival"]) / rate[:, None],
        "n_maps": tile(cols["n_maps"]),
        "n_reds": tile(cols["n_reds"]),
        "map_cost": tile(cols["map_cost"]),
        "red_work": tile(cols["red_work"]),
        "shuffle": tile(cols["shuffle"]) * frac[:, None],
        "map_slots": nodes * mpn,
        "red_slots": nodes * rpn,
        "fair": fair,
        "slowstart": slowstart,
    }


def run(quick: bool = False, smoke: bool = False) -> list[str]:
    small = quick or smoke
    n_jobs = 24 if small else 64
    batch = 256 if small else 2048
    n_des = 4 if small else 6
    rate = 0.1

    classes = default_job_classes()
    trace = poisson_trace(classes, n_jobs, rate=1.0, seed=3)
    cols = pack_trace(trace)

    # ---- agreement: contention-free FIFO scenarios vs the DES ----
    agree_rows = []
    for label, n, scen_rate in [
        ("serialized", 4, 0.002),          # huge gaps: jobs never overlap
        ("uncontended", 64, rate),         # overlap, slots never exhausted
        ("contended", 4, rate),            # the approximation zone (report)
    ]:
        cc = ClusterConfig(num_nodes=n)
        des = simulate_workload(rescale(trace, scen_rate), cc, CLEAN)
        des_fin = np.array([j.finish for j in des.jobs])
        out = simulate_batch(scenario_batch(
            cols, np.array([float(n)]), np.array([2.0]), np.array([2.0]),
            np.array([0.0]), np.array([0.05]), np.array([scen_rate])))
        assert out["converged"][0] == 1.0, f"{label}: rollout truncated"
        rel = float(np.max(np.abs(out["finish"][0] - des_fin)
                           / np.maximum(des_fin, 1e-9)))
        if label != "contended":
            assert rel < 1e-3, f"{label}: DES<->vector mismatch {rel:.2e}"
        agree_rows.append([label, n, scen_rate, rel,
                           des.p95_latency, float(out["p95_latency"][0])])

    # ---- throughput: planner grid, vector batch vs per-scenario DES ----
    rng = np.random.default_rng(0)
    nodes = rng.choice([8.0, 16.0, 32.0, 64.0], batch)
    mpn = rng.choice([2.0, 4.0], batch)
    rpn = rng.choice([2.0, 4.0], batch)
    fair = (rng.random(batch) > 0.5).astype(np.float64)
    slow = rng.choice([0.05, 0.8], batch)
    rates = rng.choice([0.05, rate, 0.2], batch)
    # one sub-batch per policy: pure-FIFO batches compile the lean
    # prefix-allocation kernel, and each group's rollout stops at its own
    # last event instead of the global worst case
    groups = []
    for mask in (fair < 0.5, fair >= 0.5):
        scen = scenario_batch(cols, nodes[mask], mpn[mask], rpn[mask],
                              fair[mask], slow[mask], rates[mask])
        groups.append((scen, estimate_steps(scen)))

    for scen, n_steps in groups:                   # compile out of the timing
        simulate_batch(scen, n_steps=n_steps)
    with timer() as t_vec:
        outs = [simulate_batch(scen, n_steps=n_steps)
                for scen, n_steps in groups]
    for out in outs:
        assert float(out["converged"].mean()) == 1.0, "unconverged scenarios"
    vec_rate = batch / t_vec.s

    with timer() as t_des:
        for i in range(n_des):
            cc = ClusterConfig(
                num_nodes=int(nodes[i]), map_slots_per_node=int(mpn[i]),
                reduce_slots_per_node=int(rpn[i]),
                scheduler="fair" if fair[i] else "fifo",
                reduce_slowstart=float(slow[i]))
            simulate_workload(rescale(trace, float(rates[i])), cc, CLEAN)
    des_rate = n_des / t_des.s
    speedup = vec_rate / des_rate
    if not small:
        assert speedup >= 50.0, f"vector speedup {speedup:.1f}x < 50x target"

    caps = "/".join(str(ns) for _, ns in groups)
    lines = [
        f"workload: {n_jobs} Poisson jobs over the 4-class mix; planner "
        f"batch of {batch} (cluster-config x load) scenarios, "
        f"step caps {caps} (fifo/fair groups)"
        f"{', smoke' if smoke else ', quick' if quick else ''}",
        "",
        "DES<->vector agreement (per-job finish times, rtol; contention-free "
        "FIFO rows **asserted** < 1e-3, the contended row reported):",
        "",
    ]
    lines += table(
        ["scenario", "nodes", "rate", "max rel err", "DES p95 s", "vec p95 s"],
        agree_rows,
    )
    lines += [
        "",
        "scenario throughput (one compiled rollout vs per-scenario Python "
        "DES):",
        "",
    ]
    lines += table(
        ["path", "scenarios", "wall s", "scenarios/s"],
        [["python DES (per scenario)", n_des, t_des.s, des_rate],
         ["vectorized wave rollout", batch, t_vec.s, vec_rate]],
    )
    lines += ["", f"**vectorized speedup: {speedup:.0f}x** scenarios/s "
                  "over the per-scenario DES"]
    write_md("cluster.md", "Vectorized capacity planner throughput", lines)
    return lines


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small batch, assert DES<->vector "
                         "agreement + convergence (no absolute-speedup gate)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("\n".join(run(quick=args.quick, smoke=args.smoke)))
