"""Static-analysis gate — ``repro.analysis`` over every registered model.

Two claims, asserted rather than eyeballed:

1. **Liveness** — every checker fires on its known-bad fixture (a dead
   checker is indistinguishable from a clean tree otherwise).
2. **Cleanliness** — the registered models (Hadoop job model + its grad
   path, the calibration loss, the tuner objective, the cluster rollout,
   the Pallas launches) produce no findings beyond ``analysis_baseline.json``,
   and the interval interpreter has a transfer function for every primitive
   they use (no silent coverage gaps).

Run:  PYTHONPATH=src python -m benchmarks.bench_analysis [--smoke] [--quick]
"""

from __future__ import annotations

from pathlib import Path

from .common import timer

ROOT = Path(__file__).resolve().parents[1]


def run(quick: bool = False) -> list[str]:
    from repro.analysis import DEFAULT_BASELINE, load_baseline, run_all
    from repro.analysis.fixtures import selftest

    lines: list[str] = []

    with timer() as t_fix:
        fixture_hits = selftest()
    dead = [n for n, fs in fixture_hits.items() if not fs]
    assert not dead, f"checkers no longer fire on their fixtures: {dead}"
    lines.append(
        "fixture self-test: "
        + ", ".join(f"{n}={len(fs)}" for n, fs in sorted(fixture_hits.items()))
        + f"  [{t_fix.s:.1f}s]")

    if quick:
        lines.append("quick mode: skipping the full model sweep "
                     "(fixture liveness only)")
        return lines

    with timer() as t_all:
        report = run_all()
    baseline = load_baseline(str(ROOT / DEFAULT_BASELINE))
    new = report.new_findings(baseline)
    assert not new, (
        "non-baselined findings on registered models: "
        + "; ".join(f"{f.checker}/{f.kind}@{f.target}" for f in new))
    assert not report.coverage_gaps, (
        f"unmodeled primitives: {report.coverage_gaps}")
    lines.append(
        f"full sweep: {len(report.checkers_run)} checkers, "
        f"{len(report.findings)} finding(s) "
        f"({len(new)} new, {len(baseline)} baselined), "
        f"{len(report.skipped)} target(s) skipped-with-reason  "
        f"[{t_all.s:.1f}s]")
    return lines


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fixture liveness + full sweep (same as default)")
    ap.add_argument("--quick", action="store_true",
                    help="fixture liveness only")
    args = ap.parse_args()
    print("\n".join(run(quick=args.quick and not args.smoke)))


if __name__ == "__main__":
    main()
