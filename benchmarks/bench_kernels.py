"""E11 — Pallas kernel sweep: max abs error vs the jnp oracle across
shapes/dtypes (interpret mode on CPU; Mosaic on a real TPU), plus the
VMEM working-set accounting per BlockSpec choice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention, gqa_decode_attention, seg_combine
from repro.kernels.ref import (
    decode_attention_ref,
    flash_attention_ref,
    seg_combine_ref,
)
from .common import table, write_md


def _vmem_kib(bq, bk, hd, dtype_bytes=4):
    # q + k + v tiles + fp32 scratch (m, l lanes + acc)
    tiles = (bq * hd + 2 * bk * hd) * dtype_bytes
    scratch = (2 * bq * 128 + bq * hd) * 4
    return (tiles + scratch) / 1024


def run(quick: bool = False) -> list[str]:
    rows = []
    cases = [
        (1, 4, 2, 256, 256, 64, jnp.float32),
        (2, 2, 2, 128, 384, 128, jnp.bfloat16),
        (1, 8, 1, 200, 333, 80, jnp.float32),
    ]
    for B, H, KV, Sq, Sk, hd, dt in cases:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, Sq, hd), dt)
        k = jax.random.normal(ks[1], (B, KV, Sk, hd), dt)
        v = jax.random.normal(ks[2], (B, KV, Sk, hd), dt)
        out = flash_attention(q, k, v, True, None, 50.0, 0)
        ref = flash_attention_ref(q, k, v, causal=True, logit_cap=50.0)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
        rows.append([f"flash {B}x{H}x{Sq}x{Sk}x{hd} {dt.__name__}", err,
                     f"{_vmem_kib(128, 128, max(hd, 128)):.0f} KiB"])

    for B, H, KV, S, hd in [(2, 8, 2, 512, 64), (1, 4, 4, 300, 128)]:
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, H, 1, hd))
        kc = jax.random.normal(ks[1], (B, KV, S, hd))
        vc = jax.random.normal(ks[2], (B, KV, S, hd))
        sp = jnp.arange(S, dtype=jnp.int32)
        pos = jnp.asarray(S - 1, jnp.int32)
        out = gqa_decode_attention(q, kc, vc, sp, pos)
        ref = decode_attention_ref(
            q.reshape(B, KV, H // KV, hd), kc, vc, sp, pos
        ).reshape(B, H, 1, hd)
        err = float(jnp.max(jnp.abs(out - ref)))
        rows.append([f"decode {B}x{H}xS{S}x{hd}", err, "-"])

    for N, D, P in [(1024, 256, 16), (777, 130, 7)]:
        ks = jax.random.split(jax.random.PRNGKey(2), 2)
        vals = jax.random.normal(ks[0], (N, D))
        pid = jax.random.randint(ks[1], (N,), 0, P, jnp.int32)
        out = seg_combine(vals, pid, P)
        ref = seg_combine_ref(vals, pid, P)
        err = float(jnp.max(jnp.abs(out - ref)))
        rows.append([f"seg_combine {N}x{D}->P{P}", err, "-"])

    lines = ["Kernel vs jnp-oracle max abs error (interpret mode):", ""]
    lines += table(["case", "max abs err", "VMEM tile set"], rows)
    write_md("kernels.md", "E11: Pallas kernel sweeps", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
