"""E6 — configuration tuning: does the search find the true optimum, and
how many model evaluations does each strategy need?

Ground truth = exhaustive grid (the what-if engine makes it cheap); the
regret column is (found - optimum)/optimum.
"""

from __future__ import annotations

from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.core.tuner import coordinate_descent, grid_search, random_search
from .common import table, timer, write_md

SPACE = {
    "pSortMB": [16, 32, 64, 100, 128, 256, 512],
    "pSortFactor": [3, 5, 10, 20, 50, 100],
    "pNumReducers": [2, 4, 8, 16, 32, 64, 128],
    "pShuffleInBufPerc": [0.3, 0.5, 0.7, 0.9],
    "pUseCombine": [0.0, 1.0],
}


def run(quick: bool = False) -> list[str]:
    hp = HadoopParams(pNumNodes=16, pNumMappers=128, pUseCombine=True,
                      pSplitSize=256 * MiB)
    st = ProfileStats(sMapSizeSel=1.2, sMapPairsSel=2.0,
                      sCombineSizeSel=0.35, sCombinePairsSel=0.35)
    cf = CostFactors()

    with timer() as t_ex:
        exact = grid_search(hp, st, cf, SPACE)
    rows = [["exhaustive", exact.evaluations, exact.best_cost, 0.0, t_ex.s]]
    for name, fn in [
        ("coordinate descent", lambda: coordinate_descent(hp, st, cf, SPACE)),
        ("random-512", lambda: random_search(hp, st, cf, SPACE, samples=512)),
        ("random-64", lambda: random_search(hp, st, cf, SPACE, samples=64)),
    ]:
        with timer() as t:
            res = fn()
        regret = (res.best_cost - exact.best_cost) / exact.best_cost
        rows.append([name, res.evaluations, res.best_cost, regret, t.s])

    lines = [f"space size = {exact.evaluations} configs; "
             f"optimum {exact.best_cost:.3f}s at {exact.best_assignment}", ""]
    lines += table(["strategy", "evals", "best cost s", "regret", "wall s"], rows)
    write_md("tuner.md", "E6: configuration tuner", lines)
    return lines
