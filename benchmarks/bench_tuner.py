"""E6 — configuration tuning: does the search find the true optimum, and
how many model evaluations does each strategy need?

Ground truth = exhaustive grid, streamed through the chunked/sharded
evaluator with on-device top-k (:mod:`repro.search`); the regret column is
(found - optimum)/optimum, the configs/s column is the evaluator's
streaming throughput for that strategy.
"""

from __future__ import annotations

from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.search import (
    ChunkedEvaluator,
    coordinate_descent_ev,
    grid_search_ev,
    random_search_ev,
)
from .common import table, timer, write_md

SPACE = {
    "pSortMB": [16, 32, 64, 100, 128, 256, 512],
    "pSortFactor": [3, 5, 10, 20, 50, 100],
    "pNumReducers": [2, 4, 8, 16, 32, 64, 128],
    "pShuffleInBufPerc": [0.3, 0.5, 0.7, 0.9],
    "pUseCombine": [0.0, 1.0],
}


def run(quick: bool = False) -> list[str]:
    hp = HadoopParams(pNumNodes=16, pNumMappers=128, pUseCombine=True,
                      pSplitSize=256 * MiB)
    st = ProfileStats(sMapSizeSel=1.2, sMapPairsSel=2.0,
                      sCombineSizeSel=0.35, sCombinePairsSel=0.35)
    cf = CostFactors()
    ev = ChunkedEvaluator(hp, st, cf, chunk=1 << 12)

    with timer() as t_ex:
        exact = grid_search_ev(ev, SPACE)
    rows = [["exhaustive (streamed top-k)", exact.evaluations, exact.best_cost,
             0.0, t_ex.s, exact.evaluations / t_ex.s]]
    for name, fn in [
        ("coordinate descent", lambda: coordinate_descent_ev(ev, SPACE)),
        ("random-512", lambda: random_search_ev(ev, SPACE, samples=512)),
        ("random-64", lambda: random_search_ev(ev, SPACE, samples=64)),
    ]:
        with timer() as t:
            res = fn()
        regret = (res.best_cost - exact.best_cost) / exact.best_cost
        rows.append([name, res.evaluations, res.best_cost, regret, t.s,
                     res.evaluations / t.s])

    lines = [f"space size = {exact.evaluations} configs; "
             f"optimum {exact.best_cost:.3f}s at {exact.best_assignment} "
             f"(devices={ev.num_devices}, chunk={ev.chunk})", ""]
    lines += table(
        ["strategy", "evals", "best cost s", "regret", "wall s", "configs/s"],
        rows,
    )
    write_md("tuner.md", "E6: configuration tuner", lines)
    return lines
