"""E6 — configuration tuning: does the search find the true optimum, and
how many model evaluations does each strategy need?

Ground truth = exhaustive grid, streamed through the chunked/sharded
evaluator with on-device top-k (:mod:`repro.search`); the regret column is
(found - optimum)/optimum, the configs/s column is the evaluator's
streaming throughput for that strategy.

The gradient row relaxes the space continuously and differentiates the
job model itself (:func:`repro.search.gradient_descent_ev`), so its
``evals`` column counts only the final candidate-validation batch — the
descent steps never touch the evaluator.  Because continuous values
between grid candidates are admissible, its regret can be *negative*.

``--smoke`` is the CI gate: gradient descent must land within 5% of the
exhaustive grid optimum using fewer evaluator calls than coordinate
descent.
"""

from __future__ import annotations

import jax

# The closed-form model sums per-phase costs that differ by ~9 orders of
# magnitude; the descent strategies need float64 to keep gradients and
# regret comparisons meaningful (see .claude/skills/verify/SKILL.md).
jax.config.update("jax_enable_x64", True)

from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.search import (
    ChunkedEvaluator,
    coordinate_descent_ev,
    gradient_descent_ev,
    grid_search_ev,
    random_search_ev,
)
from .common import table, timer, write_md

SPACE = {
    "pSortMB": [16, 32, 64, 100, 128, 256, 512],
    "pSortFactor": [3, 5, 10, 20, 50, 100],
    "pNumReducers": [2, 4, 8, 16, 32, 64, 128],
    "pShuffleInBufPerc": [0.3, 0.5, 0.7, 0.9],
    "pUseCombine": [0.0, 1.0],
}

#: CI gate — gradient descent must land within this relative regret of the
#: exhaustive optimum (it typically *beats* the grid via off-grid values).
_SMOKE_REGRET_MAX = 0.05


def run(quick: bool = False, smoke: bool = False) -> list[str]:
    hp = HadoopParams(pNumNodes=16, pNumMappers=128, pUseCombine=True,
                      pSplitSize=256 * MiB)
    st = ProfileStats(sMapSizeSel=1.2, sMapPairsSel=2.0,
                      sCombineSizeSel=0.35, sCombinePairsSel=0.35)
    cf = CostFactors()
    ev = ChunkedEvaluator(hp, st, cf, chunk=1 << 12)

    with timer() as t_ex:
        exact = grid_search_ev(ev, SPACE)
    rows = [["exhaustive (streamed top-k)", exact.evaluations, exact.best_cost,
             0.0, t_ex.s, exact.evaluations / t_ex.s]]
    results: dict[str, object] = {}
    for name, fn in [
        ("coordinate descent", lambda: coordinate_descent_ev(ev, SPACE)),
        ("gradient descent", lambda: gradient_descent_ev(ev, SPACE)),
        ("random-512", lambda: random_search_ev(ev, SPACE, samples=512)),
        ("random-64", lambda: random_search_ev(ev, SPACE, samples=64)),
    ]:
        with timer() as t:
            res = fn()
        results[name] = res
        regret = (res.best_cost - exact.best_cost) / exact.best_cost
        rows.append([name, res.evaluations, res.best_cost, regret, t.s,
                     res.evaluations / t.s])

    lines = [f"space size = {exact.evaluations} configs; "
             f"optimum {exact.best_cost:.3f}s at {exact.best_assignment} "
             f"(devices={ev.num_devices}, chunk={ev.chunk})", ""]
    lines += table(
        ["strategy", "evals", "best cost s", "regret", "wall s", "configs/s"],
        rows,
    )

    grad = results["gradient descent"]
    coord = results["coordinate descent"]
    grad_regret = (grad.best_cost - exact.best_cost) / exact.best_cost
    lines += ["", f"gradient regret vs exhaustive = {grad_regret:+.4f} "
                  f"(gate <= {_SMOKE_REGRET_MAX:+.2f}); evaluator calls "
                  f"{grad.evaluations} vs coordinate's {coord.evaluations}"]
    if smoke:
        assert grad_regret <= _SMOKE_REGRET_MAX, (
            f"gradient descent regret {grad_regret:.4f} exceeds "
            f"{_SMOKE_REGRET_MAX} vs the exhaustive optimum"
        )
        assert grad.evaluations < coord.evaluations, (
            f"gradient descent used {grad.evaluations} evaluator calls, "
            f"not fewer than coordinate descent's {coord.evaluations}"
        )
        lines += ["", "smoke assertions passed: gradient within regret gate "
                      "in fewer evaluator calls than coordinate descent"]

    write_md("tuner.md", "E6: configuration tuner", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
