"""E7 — the Starfish loop on live engine executions: profile once, fit
Table-3 cost factors, predict configurations never run, compare against
measured wall time.  The paper's core claim, validated end-to-end.

Two fit methods run on the *same* measured executions (so wall-time noise
cancels in the comparison):

* ``lstsq`` — the per-phase non-negative least squares (the original fit).
* ``autodiff`` — the ``repro.calib`` gradient refinement seeded at the
  least-squares solution, minimizing relative error of the Eq. 98 total
  through ``jax.grad`` of the job model itself.

Asserted (the ISSUE-6 acceptance bar): the autodiff fit matches or beats
the least-squares mean relative error on the held-out configs, using the
same 3 fit configs.
"""

from __future__ import annotations

import jax

# the gradient fit needs the same float64 mode the test suite runs in;
# the engine side is numpy float64 regardless
jax.config.update("jax_enable_x64", True)

from repro.core.hadoop.params import HadoopParams, MiB
from repro.mapreduce import JOBS
from repro.mapreduce.profiler import prediction_error_from_runs, run_measured
from .common import table, write_md

# "matches or beats": the gradient fit may not regress the held-out mean
# relative error beyond float slop of the least-squares baseline.
_MATCH_TOL = 1.005


def run(quick: bool = False) -> list[str]:
    n = 40_000 if quick else 100_000
    steps = 150 if quick else 300
    lines = []
    for jname in ("sort", "wordcount"):
        job = JOBS[jname]
        base = HadoopParams(
            pNumMappers=4, pNumReducers=4, pUseCombine=job.use_combine,
            pSortMB=1.0, pSplitSize=n / 4 * job.pair_width, pTaskMem=8 * MiB,
        )
        fit_hps = [
            base.replace(pSortMB=0.5),
            base.replace(pSortMB=2.0, pNumReducers=2),
            base.replace(pSortFactor=4, pNumReducers=8),
        ]
        test_hps = [
            base.replace(pSortMB=1.5, pNumReducers=16),
            base.replace(pSortMB=0.75, pSortFactor=5),
            base.replace(pSortMB=4.0, pNumReducers=2, pSortFactor=20),
        ]
        fit_runs = [run_measured(job, hp, n, seed=0) for hp in fit_hps]
        test_runs = [run_measured(job, hp, n, seed=1) for hp in test_hps]
        old = prediction_error_from_runs(fit_runs, test_runs, fit="lstsq")
        new = prediction_error_from_runs(
            fit_runs, test_runs, fit="autodiff", steps=steps)

        rows = [
            [f"test {i}", r_old["measured_s"], r_old["predicted_s"],
             r_old["rel_err"], r_new["predicted_s"], r_new["rel_err"]]
            for i, (r_old, r_new) in enumerate(zip(old["rows"], new["rows"]))
        ]
        lines += [f"## {jname} (n={n} pairs, fit on 3 configs)", ""]
        lines += table(
            ["config", "measured s", "lstsq pred s", "lstsq rel err",
             "autodiff pred s", "autodiff rel err"],
            rows,
        )
        lines += [
            "",
            f"mean rel err: lstsq = {old['mean_rel_err']:.3f}, "
            f"autodiff = {new['mean_rel_err']:.3f} "
            f"(max {old['max_rel_err']:.3f} vs {new['max_rel_err']:.3f})",
            "",
        ]
        assert new["mean_rel_err"] <= old["mean_rel_err"] * _MATCH_TOL, (
            f"{jname}: autodiff fit regressed held-out mean rel err: "
            f"{new['mean_rel_err']:.4f} vs lstsq {old['mean_rel_err']:.4f}"
        )
    write_md("mr_fit.md", "E7: fitted-model prediction error", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
