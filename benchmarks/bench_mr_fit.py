"""E7 — the Starfish loop on live engine executions: profile once, fit
Table-3 cost factors, predict configurations never run, compare against
measured wall time.  The paper's core claim, validated end-to-end.
"""

from __future__ import annotations

from repro.core.hadoop.params import HadoopParams, MiB
from repro.mapreduce import JOBS
from repro.mapreduce.profiler import prediction_error
from .common import table, write_md


def run(quick: bool = False) -> list[str]:
    n = 40_000 if quick else 100_000
    lines = []
    for jname in ("sort", "wordcount"):
        job = JOBS[jname]
        base = HadoopParams(
            pNumMappers=4, pNumReducers=4, pUseCombine=job.use_combine,
            pSortMB=1.0, pSplitSize=n / 4 * job.pair_width, pTaskMem=8 * MiB,
        )
        fit_hps = [
            base.replace(pSortMB=0.5),
            base.replace(pSortMB=2.0, pNumReducers=2),
            base.replace(pSortFactor=4, pNumReducers=8),
        ]
        test_hps = [
            base.replace(pSortMB=1.5, pNumReducers=16),
            base.replace(pSortMB=0.75, pSortFactor=5),
            base.replace(pSortMB=4.0, pNumReducers=2, pSortFactor=20),
        ]
        out = prediction_error(job, fit_hps, test_hps, n)
        rows = [
            [f"test {i}", r["measured_s"], r["predicted_s"], r["rel_err"]]
            for i, r in enumerate(out["rows"])
        ]
        lines += [f"## {jname} (n={n} pairs, fit on 3 configs)", ""]
        lines += table(["config", "measured s", "predicted s", "rel err"], rows)
        lines += [f"", f"mean rel err = {out['mean_rel_err']:.3f}, "
                  f"max = {out['max_rel_err']:.3f}", ""]
    write_md("mr_fit.md", "E7: fitted-model prediction error", lines)
    return lines
