"""E4 — paper §5: analytic job-cost aggregation (Eqs. 92-98) vs the Task
Scheduler Simulator, across cluster sizes and wave counts.

The analytic path divides total task cost by slot count (perfect packing);
the simulator schedules actual waves.  They must agree when tasks pack
exactly into waves and diverge by at most one wave's worth otherwise —
quantified here.  Also reports straggler/speculation/failure deltas that
only the simulator can see (the reason the paper offers both paths).
"""

from __future__ import annotations

from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.core.hadoop.ref import job_model
from repro.core.hadoop.simulator import SimConfig, simulate_job
from .common import table, write_md

STATS = ProfileStats(sMapSizeSel=0.7, sCombinePairsSel=0.5, sCombineSizeSel=0.5)
COSTS = CostFactors()


def run(quick: bool = False) -> list[str]:
    rows = []
    for nodes, mappers, reducers in [
        (4, 16, 8), (8, 64, 16), (16, 64, 32), (32, 256, 64),
        (64, 512, 128), (16, 60, 32),          # 60/32 slots: ragged wave
    ]:
        hp = HadoopParams(
            pNumNodes=nodes, pNumMappers=mappers, pNumReducers=reducers,
            pUseCombine=True, pSplitSize=128 * MiB,
        )
        jm = job_model(hp, STATS, COSTS)
        analytic = jm.totalCost
        sim = simulate_job(hp, STATS, COSTS, SimConfig(seed=1))
        map_waves = -(-mappers // (nodes * hp.pMaxMapsPerNode))
        rows.append([
            f"{nodes}", mappers, reducers, map_waves,
            analytic, sim.makespan, sim.makespan / analytic,
        ])

    lines = ["Analytic (Eqs. 92-98) vs task-scheduler simulation:", ""]
    lines += table(
        ["nodes", "maps", "reds", "map waves", "analytic s",
         "sim makespan s", "ratio"],
        rows,
    )

    hp = HadoopParams(pNumNodes=16, pNumMappers=128, pNumReducers=32,
                      pUseCombine=True, pSplitSize=128 * MiB)
    base = simulate_job(hp, STATS, COSTS, SimConfig(seed=3)).makespan
    rows2 = [["clean", base, 1.0, 0, 0]]
    for label, sc in [
        ("15% stragglers, no spec",
         SimConfig(seed=3, straggler_prob=0.15, speculative_execution=False)),
        ("15% stragglers + spec",
         SimConfig(seed=3, straggler_prob=0.15, speculative_execution=True)),
        ("2 node failures",
         SimConfig(seed=3, node_failures=((1.0, 0), (2.0, 5)))),
    ]:
        r = simulate_job(hp, STATS, COSTS, sc)
        rows2.append([label, r.makespan, r.makespan / base,
                      r.num_speculative_launched, r.num_failure_reruns])
    lines += ["", "Simulator-only effects (what the analytic path cannot see):", ""]
    lines += table(["scenario", "makespan s", "vs clean", "spec launched",
                    "reruns"], rows2)
    write_md("sim_vs_analytic.md", "E4: analytic vs simulation", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
