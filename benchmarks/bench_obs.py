"""E14 — observability overhead: instrumentation must be free when off
and cheap when on.

Two claims, asserted in ``--smoke`` (CI) mode rather than eyeballed:

1. **Bit-for-bit** — running :class:`repro.search.ChunkedEvaluator`
   under ``repro.obs.observe()`` returns *exactly* the numbers an
   uninstrumented run returns, for every output column.  Instrumentation
   reads the computation; it never participates in it.
2. **Overhead** — with tracing ON, the min-of-N wall time of a warmed
   evaluate sweep stays within 5% of the uninstrumented min-of-N (the
   hot path only pays guarded counter bumps and span dict appends; no
   allocation happens inside jitted code either way).

The report also shows what a run *records*: the ambient registry
snapshot (chunks, rows, padding waste, compiles) and the trace event
count, as a sanity check that the instrumentation actually fires.

Run:  PYTHONPATH=src python -m benchmarks.bench_obs [--smoke] [--quick]
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.obs import observe
from repro.search import ChunkedEvaluator

from .common import report, table, write_md


def _sweep(n_rows: int) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(7)
    return {
        "pSortMB": rng.choice([16.0, 25.0, 50.0, 100.0, 200.0], n_rows),
        "pSortFactor": rng.choice([5.0, 10.0, 25.0, 50.0], n_rows),
        "pNumReducers": 2.0 ** rng.integers(1, 7, n_rows),
    }


def _min_of(n: int, fn) -> float:
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False, smoke: bool = False) -> list[str]:
    small = quick or smoke
    n_rows = 1 << 10 if small else 1 << 13
    reps = 5 if small else 10
    hp = HadoopParams(pNumNodes=8, pNumMappers=64, pNumReducers=16,
                      pSplitSize=128 * MiB)
    ev = ChunkedEvaluator(hp, ProfileStats(sMapSizeSel=0.8), CostFactors(),
                          chunk=1 << 8)
    rows = _sweep(n_rows)
    ev.evaluate(rows)                      # warm the compiled executable

    # ---- claim 1: observe() does not perturb the numbers ----
    plain = ev.evaluate(rows)
    with observe() as ob:
        traced = ev.evaluate(rows)
    assert np.array_equal(plain.total_cost, traced.total_cost), \
        "observe() changed evaluator results"
    for k in plain.outputs:
        assert np.array_equal(plain.outputs[k], traced.outputs[k]), k
    snap = ob.registry.snapshot()
    n_events = len(ob.tracer.events())
    assert snap.get("evaluator.rows") == n_rows, snap
    assert n_events > 0, "tracing recorded no events"

    # ---- claim 2: overhead within 5% (min-of-N, warmed) ----
    t_off = _min_of(reps, lambda: ev.evaluate(rows))

    def traced_run():
        with observe():
            ev.evaluate(rows)

    t_on = _min_of(reps, traced_run)
    overhead = t_on / max(t_off, 1e-12) - 1.0
    if smoke:
        assert overhead < 0.05, (
            f"instrumentation overhead {overhead * 100:.1f}% >= 5%"
        )

    interesting = {k: v for k, v in snap.items()
                   if not isinstance(v, dict)}
    lines = [
        f"workload: {n_rows} rows through ChunkedEvaluator(chunk={ev.chunk}),"
        f" min-of-{reps}{', smoke' if smoke else ', quick' if quick else ''}",
        "",
        "equivalence: instrumented run **bit-for-bit identical** to the "
        "uninstrumented run, every output column (asserted)",
        f"recorded: {n_events} trace events; registry "
        + ", ".join(f"{k}={v:g}" for k, v in sorted(interesting.items())),
        "",
    ]
    lines += table(
        ["mode", "min wall s", "rows/s"],
        [["observability off (default)", t_off, n_rows / t_off],
         ["observe() tracing on", t_on, n_rows / t_on]],
    )
    lines += ["", f"**overhead: {overhead * 100:+.2f}%** wall time with "
                  "tracing on (gate: < 5% in smoke mode)"]
    report("bench_obs", overhead_pct=overhead * 100, trace_events=n_events,
           rows=n_rows)
    write_md("obs.md", "Observability overhead", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
