"""E12 — async what-if service vs one-evaluate-per-query baseline.

The workload is the production shape of the paper's use case: many small
heterogeneous what-if queries (single-config probes, per-axis sweeps, small
grids) arriving concurrently.  The naive baseline answers each with its own
``ChunkedEvaluator.evaluate`` call — every 1-row probe pays a full padded
chunk plus a dispatch.  :class:`repro.search.WhatIfService` coalesces the
waiting rows into shared chunks of the same compiled executable.

Three claims, asserted rather than eyeballed:

1. **Equivalence** — every service-resolved query is bit-for-bit identical
   to its sequential baseline call.
2. **Coalescing** — the service issues far fewer evaluator calls than there
   are queries.
3. **Throughput** — >= 3x queries/s over the baseline on a >= 64-query
   mixed workload (full mode; smoke mode asserts 1+2 and reports numbers).

Run:  PYTHONPATH=src python -m benchmarks.bench_service [--smoke] [--quick]
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.runtime.batching import LatencyStats
from repro.search import ChunkedEvaluator, WhatIfService, space_block, space_size

from .common import table, timer, write_md


def make_workload(n_queries: int, seed: int = 0) -> list[dict]:
    """~1/3 probes (1 row), ~1/3 sweeps (4-8 rows), ~1/3 grids (~10-100)."""
    rng = np.random.default_rng(seed)
    sortmb = np.array([16.0, 25.0, 50.0, 100.0, 200.0, 400.0])
    factors = np.array([5.0, 10.0, 25.0, 50.0])
    queries: list[dict] = []
    for i in range(n_queries):
        kind = i % 3
        if kind == 0:
            queries.append({"pSortMB": np.array([rng.choice(sortmb)]),
                            "pSortFactor": np.array([rng.choice(factors)])})
        elif kind == 1:
            m = int(rng.integers(4, 9))
            queries.append({
                "pNumReducers": np.array([2.0 ** k for k in range(1, m + 1)]),
                "pSortMB": np.full(m, rng.choice(sortmb)),
            })
        else:
            space = {
                "pSortMB": sortmb[: int(rng.integers(2, 5))].tolist(),
                "pSortFactor": factors[: int(rng.integers(2, 5))].tolist(),
                "pUseCombine": [0.0, 1.0][: int(rng.integers(1, 3))],
            }
            queries.append(space_block(space, 0, space_size(space)))
    return queries


def run(quick: bool = False, smoke: bool = False) -> list[str]:
    n_queries = 64 if (quick or smoke) else 96
    chunk = 1 << 8 if (quick or smoke) else 1 << 10
    hp = HadoopParams(pNumNodes=8, pNumMappers=64, pNumReducers=16,
                      pSplitSize=128 * MiB)
    st, cf = ProfileStats(sMapSizeSel=0.8), CostFactors()
    ev = ChunkedEvaluator(hp, st, cf, chunk=chunk)
    queries = make_workload(n_queries)
    n_rows = sum(len(next(iter(q.values()))) for q in queries)

    # warm the compiled executables out of both timings (one per key-set;
    # service and baseline share them — compile time is not a design point)
    for sig in {tuple(sorted(q)) for q in queries}:
        ev.evaluate(next(q for q in queries if tuple(sorted(q)) == sig))

    # ---- baseline: one evaluate call per query ----
    base_lat = LatencyStats()
    baseline = []
    with timer() as t_base:
        for q in queries:
            t0 = time.perf_counter()
            baseline.append(ev.evaluate(q))
            base_lat.record(time.perf_counter() - t0)

    # ---- service: all queries admitted concurrently, coalesced ----
    svc = WhatIfService(ev)
    with timer() as t_svc:
        results = svc.map(queries)
    svc.close()
    summary = svc.summary()

    for r, ref in zip(results, baseline):
        assert np.array_equal(r.total_cost, ref.total_cost), \
            "service diverged from sequential evaluate"
        for k in ref.outputs:
            assert np.array_equal(r.outputs[k], ref.outputs[k]), k
    assert summary["chunks"] < n_queries, (
        f"no coalescing: {summary['chunks']} chunks for {n_queries} queries"
    )

    speedup = t_base.s / max(t_svc.s, 1e-9)
    if not (quick or smoke):
        assert speedup >= 3.0, f"service speedup {speedup:.2f}x < 3x target"

    rows = [
        ["baseline (1 evaluate/query)", t_base.s,
         n_queries / t_base.s, base_lat.p50 * 1e3, base_lat.p99 * 1e3,
         n_queries],
        ["WhatIfService (coalesced)", t_svc.s,
         n_queries / t_svc.s, summary["latency_p50_s"] * 1e3,
         summary["latency_p99_s"] * 1e3, summary["chunks"]],
    ]
    lines = [
        f"workload: {n_queries} mixed queries ({n_rows} rows; probes/sweeps/"
        f"grids), chunk={ev.chunk}, devices={ev.num_devices}"
        f"{', smoke' if smoke else ', quick' if quick else ''}",
        "",
        "equivalence: service results **bit-for-bit identical** to "
        "sequential per-query evaluate calls (asserted)",
        f"coalescing: {summary['chunks']} evaluator calls for {n_queries} "
        f"queries ({summary['shared_chunks']} chunks shared by >1 query, "
        f"{summary['rows_padded']} padded slack rows, peak queue depth "
        f"{summary['peak_queue_depth']})",
        "",
    ]
    lines += table(
        ["path", "wall s", "queries/s", "p50 ms", "p99 ms", "eval calls"],
        rows,
    )
    lines += ["", f"**service speedup: {speedup:.2f}x** queries/s over the "
                  "one-evaluate-per-query baseline"]
    write_md("service.md", "Async what-if service throughput", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
