"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--quick]``.

One module per paper aspect (DESIGN.md §9 experiment index):

  E4  bench_sim_vs_analytic  analytic job cost vs task-scheduler simulation
  E5  bench_whatif           what-if engine throughput (vmap vs python)
  E6  bench_tuner            tuner vs exhaustive optimum
  E7  bench_mr_fit           fitted cost factors -> prediction error
  E8  bench_roofline         40-cell dry-run roofline table
  E9  bench_tpu_model        TPU analytical model vs compiled dry-run
  E11 bench_kernels          Pallas kernels vs jnp oracles
  E12 bench_service          async what-if service vs per-query baseline
  E13 bench_cluster          vectorized capacity planner vs per-scenario DES
  E14 bench_obs              observability overhead (bit-for-bit + < 5%)

Markdown reports land in artifacts/bench/, machine-readable metrics in
artifacts/bench/BENCH_results.json (one entry per module, merged across
invocations).
"""

from __future__ import annotations

import argparse
import time
import traceback

MODULES = [
    ("E4 sim_vs_analytic", "benchmarks.bench_sim_vs_analytic"),
    ("E5 whatif", "benchmarks.bench_whatif"),
    ("E6 tuner", "benchmarks.bench_tuner"),
    ("E7 mr_fit", "benchmarks.bench_mr_fit"),
    ("E8 roofline", "benchmarks.bench_roofline"),
    ("E9 tpu_model", "benchmarks.bench_tpu_model"),
    ("E11 kernels", "benchmarks.bench_kernels"),
    ("E12 service", "benchmarks.bench_service"),
    ("E13 cluster", "benchmarks.bench_cluster"),
    ("E14 obs", "benchmarks.bench_obs"),
    ("E15 cloud", "benchmarks.bench_cloud"),
    ("serving", "benchmarks.bench_serving"),
    ("analysis gate", "benchmarks.bench_analysis"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="", help="substring filter")
    args = ap.parse_args()

    from .common import RESULTS_NAME, report

    failures = 0
    for label, modname in MODULES:
        if args.only and args.only not in modname and args.only not in label:
            continue
        t0 = time.time()
        print(f"\n===== {label} ({modname}) =====", flush=True)
        try:
            mod = __import__(modname, fromlist=["run"])
            lines = mod.run(quick=args.quick)
            print("\n".join(lines))
            wall = time.time() - t0
            print(f"[done in {wall:.1f}s]")
            report(modname.rsplit(".", 1)[-1], wall_s=wall, ok=1)
        except Exception:
            failures += 1
            report(modname.rsplit(".", 1)[-1], wall_s=time.time() - t0, ok=0)
            print(f"[FAILED]\n{traceback.format_exc()[-3000:]}")
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")
    print(f"\nAll benchmarks complete; reports + {RESULTS_NAME} in "
          "artifacts/bench/")


if __name__ == "__main__":
    main()
