"""E5 — what-if engine throughput at production grid scale.

Three claims, in the order the search subsystem makes them:

1. **Equivalence** — a >= 10^5-config grid evaluated through the chunked,
   device-sharded path (:class:`repro.search.ChunkedEvaluator`) is
   bit-for-bit identical to the seed's unchunked single-device
   ``jit(vmap(model))`` call (padding rows masked out).  Asserted, not
   eyeballed.
2. **Scale** — the streaming on-device top-k path sweeps a ~10^6-config
   Cartesian space in bounded memory with ONE compile, reporting configs/s.
3. **Context** — the pure-Python oracle rate, to show why the vectorized
   formulation exists (the paper's tuning loop needs 10^4-10^6 evals).
"""

from __future__ import annotations

import numpy as np

from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.core.hadoop.ref import job_model
from repro.search import ChunkedEvaluator, evaluate_unchunked, search_topk, space_block, space_size
from .common import table, timer, write_md

# ~1.2e5 configs: the chunked-vs-unchunked equivalence grid (full mode).
EQ_SPACE = {
    "pSortMB": [16.0, 32.0, 64.0, 100.0, 128.0, 256.0, 512.0, 1024.0],
    "pSortFactor": [5.0, 10.0, 20.0, 50.0, 100.0],
    "pNumReducers": [2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0],
    "pShuffleInBufPerc": [0.3, 0.5, 0.7, 0.9],
    "pIsIntermCompressed": [0.0, 1.0],
    "pUseCombine": [0.0, 1.0],
    "pNumMappers": [32.0, 64.0, 128.0],
    "pSortRecPerc": [0.01, 0.05, 0.15],
    "pSplitSize": [64.0 * MiB, 128.0 * MiB, 256.0 * MiB],
}

# x8 more: the ~10^6-config streaming top-k space (never materialized).
TOPK_EXTRA = {
    "pInMemMergeThr": [100.0, 1000.0],
    "pShuffleMergePerc": [0.5, 0.66],
    "pReducerInBufPerc": [0.0, 0.35],
}


def _quick_space(space, n_axes=5, n_vals=3):
    return {k: v[:n_vals] for k, v in list(space.items())[:n_axes]}


def run(quick: bool = False) -> list[str]:
    hp, st, cf = HadoopParams(pNumNodes=16), ProfileStats(), CostFactors()
    eq_space = _quick_space(EQ_SPACE) if quick else EQ_SPACE
    topk_space = dict(eq_space, **({} if quick else TOPK_EXTRA))
    lines: list[str] = []

    # ---- 1: equivalence, chunked+sharded vs unchunked single-device ----
    n_eq = space_size(eq_space)
    ev = ChunkedEvaluator(hp, st, cf, chunk=1 << 13)
    cols = space_block(eq_space, 0, n_eq)

    with timer() as t_un:
        ref = evaluate_unchunked(ev.base_cfg, cols)
    ref_cost = np.where(ref["valid"] > 0, ref["j_totalCost"], np.inf)

    with timer() as t_ch:
        res = ev.evaluate(cols)

    identical = np.array_equal(res.total_cost, ref_cost)
    assert identical, "chunked/sharded path diverged from unchunked reference"
    lines += [
        f"equivalence grid: {n_eq} configs "
        f"({'quick mode, ' if quick else ''}devices={ev.num_devices}, "
        f"chunk={ev.chunk})",
        f"chunked+sharded == unchunked single-device: "
        f"**bit-for-bit {identical}** "
        f"({int(np.isfinite(ref_cost).sum())} valid configs)",
        f"compiles used by the chunked path: {ev.eval_cache_size()}",
        "",
    ]

    # ---- 2: streaming top-k throughput at ~10^6 configs ----
    n_topk = space_size(topk_space)
    # warm the top-k executable on a tiny same-keys sub-space
    search_topk(ev, {k: v[:1] for k, v in topk_space.items()}, k=10)
    with timer() as t_tk:
        top = search_topk(ev, topk_space, k=10)
    rate_topk = n_topk / t_tk.s

    best = top.best()
    lines += [
        f"streaming top-10 over {n_topk} configs "
        f"(grid never materialized, {ev.topk_cache_size()} compile): "
        f"{t_tk.s:.2f}s -> **{rate_topk:,.0f} configs/s**",
        f"best: {best.cost:.3f}s at "
        + ", ".join(f"{k}={v:g}" for k, v in best.assignment.items()),
        f"valid: {top.n_valid}/{top.n_evaluated}"
        + (f"; {sum(e.exact for e in top.entries)} top entries re-costed by "
           f"the exact simulator escape hatch" if any(e.exact for e in top.entries)
           else ""),
        "",
    ]

    # ---- 3: rates table (incl. the pure-Python oracle for context) ----
    n_py = min(2048 if not quick else 128, n_eq)
    sub = {k: v[:n_py] for k, v in cols.items()}
    with timer() as t_py:
        for i in range(n_py):
            job_model(
                hp.replace(
                    pSortMB=float(sub["pSortMB"][i]),
                    pSortFactor=int(sub["pSortFactor"][i]),
                    pNumReducers=int(sub["pNumReducers"][i]),
                ), st, cf,
            )
    py_rate = n_py / t_py.s

    rows = [
        ["python oracle (ref.job_model)", n_py, t_py.s, py_rate],
        ["unchunked jit(vmap) single-device", n_eq, t_un.s, n_eq / t_un.s],
        ["chunked+sharded full outputs", n_eq, t_ch.s, n_eq / t_ch.s],
        ["chunked+sharded streaming top-k", n_topk, t_tk.s, rate_topk],
    ]
    lines += table(["path", "configs", "wall s", "configs/s"], rows)
    lines += ["", f"speedup over python oracle: {rate_topk / py_rate:.0f}x"]
    write_md("whatif_throughput.md", "E5: what-if engine throughput", lines)
    return lines


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run)
