"""E5 — what-if engine throughput: closed-form model evaluations per second
via the vmapped/jitted JAX model vs the pure-Python oracle.

The paper's tuning use case needs ~10^4-10^6 model evaluations per search;
this benchmark shows the vectorized formulation sustains that in one
process (the reason core/hadoop/model.py exists next to ref.py).
"""

from __future__ import annotations

import numpy as np

from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats
from repro.core.hadoop.ref import job_model
from repro.core.whatif import evaluate_grid
from .common import table, timer, write_md


def run(quick: bool = False) -> list[str]:
    hp, st, cf = HadoopParams(pUseCombine=True), ProfileStats(), CostFactors()
    sizes = [256, 4096, 65536] if not quick else [256, 4096]
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        overrides = {
            "pSortMB": rng.choice([32, 64, 100, 128, 256], n).astype(float),
            "pSortFactor": rng.choice([5, 10, 20, 50], n).astype(float),
            "pNumReducers": rng.choice([4, 8, 16, 32, 64], n).astype(float),
        }
        evaluate_grid(hp, st, cf, {k: v[:8] for k, v in overrides.items()})  # warm
        with timer() as t:
            res = evaluate_grid(hp, st, cf, overrides)
        batched_rate = n / t.s

        n_py = min(n, 2048)
        with timer() as t2:
            for i in range(n_py):
                job_model(
                    hp.replace(
                        pSortMB=float(overrides["pSortMB"][i]),
                        pSortFactor=int(overrides["pSortFactor"][i]),
                        pNumReducers=int(overrides["pNumReducers"][i]),
                    ), st, cf,
                )
        py_rate = n_py / t2.s
        rows.append([n, t.s, batched_rate, py_rate, batched_rate / py_rate])
        best_i, best_cost, assign = res.best()

    lines = ["vmapped jnp model vs pure-Python oracle:", ""]
    lines += table(
        ["grid size", "batched s", "configs/s (jax)", "configs/s (python)",
         "speedup"], rows,
    )
    lines += ["", f"sample best: cost={best_cost:.3f}s at {assign}"]
    write_md("whatif_throughput.md", "E5: what-if engine throughput", lines)
    return lines
