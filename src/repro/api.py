"""repro.api — one facade over every registered cost model.

The repo grew three cost models behind the same evaluator interface — the
Hadoop job model (:class:`repro.search.ChunkedEvaluator`), the TPU step
model (:class:`repro.search.tpu.TpuEvaluator`) and the cluster capacity
planner (:class:`repro.cluster.evaluator.ClusterEvaluator`).  This module
is the single entry point over all of them:

>>> import repro.api as api
>>> from repro.spec import JobSpec
>>> spec = JobSpec().replace(pNumMappers=64, pNumReducers=16)
>>> rep = api.model(spec, {"pSortMB": 200.0})        # typed CostReport
>>> float(rep.phases.shuffle[0]), rep.phases.eq("shuffle")
>>> swept = api.sweep(spec, {"pSortMB": [50., 100., 200.]})
>>> best = api.tune(spec, {"pSortMB": [50., 100., 200.]}, strategy="descent")
>>> with api.serve(spec) as svc:                     # async what-if service
...     fut = svc.phase_query({"pSortMB": [50., 100., 200.]},
...                           phase="shuffle", total_max=300.0)

Backends register uniformly under a name (``register_model``); a *target*
everywhere below is a :class:`~repro.spec.JobSpec` (the Hadoop model), a
registered backend name (``"hadoop"``, ``"tpu"``, ``"cluster"``,
``"cloud"``) plus its
constructor kwargs, or an already-built evaluator.  Every evaluator behind
the facade satisfies the :class:`CostModel` protocol: a ``param_space``
describing its searchable axes (the single source for grid validation —
``tune`` rejects out-of-domain spaces *before* streaming them), a
``cost_key``, batched ``evaluate``, and an optional typed ``report``.

The stringly-typed paths (``repro.core.whatif``, ``repro.core.tuner``,
direct evaluator construction) remain fully supported; this facade is a
thin composition over them and is bit-for-bit equivalent (asserted in CI).
"""

from __future__ import annotations

from typing import Any, Callable, Mapping, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.spec import CostReport, JobSpec, ParamSpace

__all__ = [
    "CostModel",
    "register_model",
    "available_models",
    "get_evaluator",
    "model",
    "sweep",
    "tune",
    "calibrate",
    "serve",
    "observe",
]


def observe(trace: str | None = None, **kw):
    """Turn on instrumentation for a block: ``with api.observe("out.json")
    as ob: ...`` records metrics on ``ob.registry`` and spans on
    ``ob.tracer``, and writes a Perfetto-loadable Chrome trace on exit when
    ``trace`` is given.  Delegates to :func:`repro.obs.observe`."""
    from repro.obs import observe as _observe

    return _observe(trace, **kw)


@runtime_checkable
class CostModel(Protocol):
    """What a cost model must expose to live behind the facade."""

    chunk: int

    @property
    def cost_key(self) -> str: ...

    @property
    def param_space(self) -> ParamSpace: ...

    def evaluate(self, overrides: Mapping[str, Any]): ...

    def exact_cost(self, assignment: Mapping[str, float]) -> float | None: ...


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, tuple[Callable[..., Any], str]] = {}


def register_model(name: str, factory: Callable[..., Any], *,
                   doc: str = "", overwrite: bool = False) -> None:
    """Register an evaluator factory under a backend name."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"cost model {name!r} is already registered")
    _REGISTRY[name] = (factory, doc)


def available_models() -> dict[str, str]:
    """Registered backend names -> one-line descriptions."""
    return {name: doc for name, (_, doc) in sorted(_REGISTRY.items())}


def _hadoop_factory(spec: JobSpec | None = None, **kw):
    from repro.search.evaluator import ChunkedEvaluator, cached_evaluator

    spec = spec if spec is not None else JobSpec()
    chunk = kw.pop("chunk", None)
    if kw:     # non-default construction: no cache
        if chunk is not None:
            kw["chunk"] = chunk
        return ChunkedEvaluator.from_spec(spec, **kw)
    return cached_evaluator(spec.params, spec.stats, spec.costs, chunk)


def _tpu_factory(cfg=None, shape=None, **kw):
    from repro.search.tpu import TpuEvaluator

    if cfg is None or shape is None:
        raise TypeError(
            "the 'tpu' backend needs cfg= (a ModelConfig) and shape= "
            "(a repro.configs.shapes.Shape)"
        )
    return TpuEvaluator(cfg, shape, **kw)


def _cluster_factory(classes=None, **kw):
    from repro.cluster.evaluator import ClusterEvaluator

    return ClusterEvaluator(classes, **kw)


def _cloud_factory(classes=None, **kw):
    from repro.cloud import CloudEvaluator

    return CloudEvaluator(classes, **kw)


register_model(
    "hadoop", _hadoop_factory,
    doc="the paper's closed-form MapReduce job model (Eqs. 2-98), chunked/sharded",
)
register_model(
    "tpu", _tpu_factory,
    doc="TPU training-step cost model (dp/tp/n_micro/remat/ep mesh search)",
)
register_model(
    "cluster", _cluster_factory,
    doc="multi-job capacity planner (nodes + fast/slow fleet mix, slots, "
        "fifo/fair/fair_preempt/capacity policies, slowstart, arrival rate)",
)
register_model(
    "cloud", _cloud_factory,
    doc="dollar-cost elastic provisioning (on-demand/spot fleet mix, "
        "reclaim rate, autoscaler policy, dollars-per-job under an SLO)",
)


def get_evaluator(target=None, **kw) -> CostModel:
    """Resolve a facade *target* to a concrete evaluator.

    ``target`` may be a :class:`~repro.spec.JobSpec` (Hadoop model), a
    registered backend name with constructor kwargs, an evaluator instance
    (returned as-is), or ``None`` (paper-default Hadoop job).
    """
    if target is None or isinstance(target, JobSpec):
        return _REGISTRY["hadoop"][0](target, **kw)
    if isinstance(target, str):
        try:
            factory, _ = _REGISTRY[target]
        except KeyError:
            raise KeyError(
                f"unknown cost model {target!r}; registered: "
                f"{sorted(_REGISTRY)}"
            ) from None
        return factory(**kw)
    if hasattr(target, "evaluate") and hasattr(target, "cost_key"):
        if kw:
            raise TypeError(
                "constructor kwargs are only valid with a JobSpec or a "
                "backend name, not an already-built evaluator"
            )
        return target
    raise TypeError(
        f"cannot resolve a cost model from {type(target).__name__}; pass a "
        "JobSpec, a registered backend name, or an evaluator"
    )


# --------------------------------------------------------------------------
# the facade verbs
# --------------------------------------------------------------------------


def _as_rows(overrides: Mapping[str, Any]) -> dict[str, np.ndarray]:
    """Scalars -> 1-row columns so single-config probes fit ``evaluate``."""
    rows = {k: np.atleast_1d(np.asarray(v, dtype=np.float64))
            for k, v in overrides.items()}
    if not rows:
        raise ValueError("at least one override is required")
    return rows


def model(target=None, assignment: Mapping[str, float] | None = None,
          **kw) -> CostReport:
    """Cost one configuration; returns the typed :class:`CostReport`.

    ``assignment`` maps config keys to scalars (defaults to the target's
    base configuration).  For backends without phase reports (TPU,
    cluster), a :class:`repro.search.SearchResult` is returned instead.
    """
    ev = get_evaluator(target, **kw)
    if assignment:
        rows = _as_rows(assignment)
    else:
        base = getattr(ev, "base_cfg", None)
        if base is None:
            raise ValueError(
                "this backend has no base configuration; pass an assignment")
        key = next(iter(base))
        rows = {key: np.atleast_1d(np.asarray(base[key], dtype=np.float64))}
    return sweep(ev, rows)


def sweep(target=None, overrides: Mapping[str, Any] | None = None, **kw):
    """Batched evaluation; returns a :class:`CostReport` with ``(B,)``
    leaves (or a plain :class:`SearchResult` for report-less backends).

    ``overrides`` follows the evaluator contract: 1-D arrays sweep, scalars
    pin — the same rows a ``ChunkedEvaluator.evaluate`` call would take, and
    bit-for-bit the same numbers.
    """
    ev = get_evaluator(target, **kw)
    if not overrides:
        raise ValueError("sweep() needs an overrides mapping")
    rep = ev.report(overrides) if hasattr(ev, "report") else None
    return rep if rep is not None else ev.evaluate(overrides)


_STRATEGIES = ("grid", "random", "descent", "gradient", "topk")


def tune(target=None, space: Mapping[str, Sequence[float]] | None = None, *,
         strategy: str = "grid", k: int = 10, exact_fallback: bool = True,
         strategy_kw: Mapping[str, Any] | None = None, **kw):
    """Search ``space`` for the cheapest configuration.

    ``strategy`` is ``"grid"`` (exhaustive streamed top-k=1), ``"random"``,
    ``"descent"`` (coordinate descent), ``"gradient"`` (differentiates the
    cost model itself over a continuous relaxation of the space; falls back
    loudly to coordinate descent on non-differentiable backends) or
    ``"topk"`` (returns the k-best ranking).  The space is validated against
    the backend's ``param_space`` — unknown axes and out-of-domain
    candidates fail here, before anything is evaluated.
    """
    from repro.search.strategies import (
        coordinate_descent_ev,
        gradient_descent_ev,
        grid_search_ev,
        random_search_ev,
        search_topk,
    )

    if not space:
        raise ValueError("tune() needs a non-empty space mapping")
    if strategy not in _STRATEGIES:
        raise ValueError(f"strategy must be one of {_STRATEGIES}")
    ev = get_evaluator(target, **kw)
    ps = getattr(ev, "param_space", None)
    if ps is not None:
        space = ps.grid(space)
    skw = dict(strategy_kw or {})
    if strategy == "grid":
        return grid_search_ev(ev, space, exact_fallback=exact_fallback, **skw)
    if strategy == "random":
        return random_search_ev(ev, space, exact_fallback=exact_fallback, **skw)
    if strategy == "descent":
        return coordinate_descent_ev(ev, space, exact_fallback=exact_fallback,
                                     **skw)
    if strategy == "gradient":
        return gradient_descent_ev(ev, space, exact_fallback=exact_fallback,
                                   **skw)
    return search_topk(ev, space, k=k, exact_fallback=exact_fallback, **skw)


def calibrate(observations, params=None, **kw):
    """Fit cost factors to observed job costs by gradient descent.

    A thin alias of :func:`repro.calib.calibrate` — ``observations`` is a
    sequence of :class:`repro.calib.Observation` (a :class:`JobSpec` plus
    its observed cost), ``params`` the factor names to fit (defaults to all
    :data:`repro.calib.COST_FACTOR_NAMES`).  Returns the typed
    :class:`~repro.spec.CalibrationReport`.  Only the Hadoop closed-form
    model is differentiable; the TPU and cluster backends raise
    :class:`~repro.search.NotDifferentiableError` from their evaluators and
    have no calibration path here.

    >>> import repro.api as api
    >>> from repro.calib import Observation
    >>> obs = [Observation(spec, wall_s) for spec, wall_s in runs]
    >>> rep = api.calibrate(obs, params=["cCpuTermMs", "cIoReadMs"])
    >>> rep.fitted["cCpuTermMs"], rep.improvement()
    """
    from repro.calib import calibrate as _calibrate

    return _calibrate(observations, params, **kw)


def serve(target=None, *, keys: Sequence[str] | None = None,
          window_s: float = 0.0, **kw):
    """An async :class:`~repro.search.service.WhatIfService` over the target.

    Supports the full query surface — probes, sweeps, grids, and the typed
    per-phase queries (:meth:`WhatIfService.phase_query`: e.g. minimize
    shuffle time subject to a total-cost budget).  Use as a context manager.
    """
    from repro.search.service import WhatIfService

    ev = get_evaluator(target, **kw)
    return WhatIfService(ev, keys=keys, window_s=window_s)
