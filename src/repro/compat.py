"""Version-drift shims for the jax APIs this repo uses.

Policy (see README "compat shim policy"): any jax symbol that moved or
changed signature between the pinned 0.4.x line and current jax is imported
from HERE, never feature-detected at the call site.  Every module that needs
``shard_map`` (the MapReduce pipeline, cross-pod reduction, the EP MoE layout
and the config-search evaluator) goes through :func:`shard_map` below, so a
jax upgrade is a one-file change.

Currently shimmed:

* ``shard_map`` — ``jax.shard_map`` (>= 0.6, ``check_vma=`` kwarg) vs
  ``jax.experimental.shard_map.shard_map`` (0.4.x, ``check_rep=`` kwarg).
  The wrapper normalizes both spellings; callers always pass ``check_vma=``.
* ``make_mesh`` / ``default_search_devices`` — 1-D mesh construction for the
  sharded config-search evaluator (:mod:`repro.search`).
"""

from __future__ import annotations

from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = [
    "shard_map",
    "make_mesh",
    "default_search_devices",
    "pallas_tpu_compiler_params",
]


def _resolve_shard_map():
    """Return (callable, name-of-the-replication-check kwarg)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None and callable(fn):            # jax >= 0.6
        return fn, "check_vma"
    from jax.experimental.shard_map import shard_map as fn_exp  # jax 0.4.x

    return fn_exp, "check_rep"


_SHARD_MAP, _CHECK_KW = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """Normalized ``shard_map``: works on both old and new jax.

    ``check_vma`` is the new-jax name for the replication check; on 0.4.x it
    is forwarded as ``check_rep``.  ``None`` keeps the underlying default.
    """
    kw = {} if check_vma is None else {_CHECK_KW: check_vma}
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def pallas_tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new jax) vs ``pltpu.TPUCompilerParams``
    (0.4.x) — same fields, renamed class."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def default_search_devices() -> list:
    """All addressable devices, for sharding config-search chunks."""
    return list(jax.local_devices())


def make_mesh(devices: Sequence | None = None, axis: str = "search") -> Mesh:
    """1-D mesh over ``devices`` (default: every local device)."""
    devs = list(devices) if devices is not None else default_search_devices()
    return Mesh(np.asarray(devs), (axis,))
