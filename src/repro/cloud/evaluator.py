"""Price/performance capacity planning behind the ``Evaluator`` interface.

``CloudEvaluator`` is the economic layer on top of the cluster planner:
the same workload-on-cluster rollout (wave simulator batched, DES exact),
but the objective is **dollars per job under an SLO** instead of latency
at a fixed fleet.  Because it implements :class:`repro.search.Evaluator`,
every strategy (``grid_search_ev``, ``random_search_ev``,
``coordinate_descent_ev``, streaming ``search_topk``) and
:class:`repro.search.WhatIfService` walk the price-performance Pareto
frontier unchanged.

Override keys (the ``base_cfg`` universe, declared in :func:`cloud_space`):

  ``pOnDemandNodes`` / ``pSpotNodes`` — the priced two-class fleet (spot
  first; both classes run at baseline speed, they differ in price and
  reclaimability), ``spotReclaimRate`` (1/s exponential reclamation of
  spot capacity), ``autoscalePolicy`` / ``autoscaleHighWater`` (the
  :data:`~repro.cloud.autoscaler.AUTOSCALE_POLICIES` code and its
  scale-up trigger), ``sloLatency`` (per-job latency bound the fleet is
  bought to meet), ``pNumRacks`` / ``crossRackBw`` / ``oversubscription``
  (the :class:`repro.cluster.network.Topology` the fleet is wired with —
  racks=1 or infinite bandwidth is the flat network), plus the familiar
  ``pMaxMapsPerNode``, ``pMaxRedPerNode``, ``pReduceSlowstart``,
  ``schedPolicy`` and ``arrivalRate`` cluster knobs.

Cost semantics:

* ``c_cost`` (the search objective) is mean dollars-per-job when the
  workload's SLO attainment reaches ``slo_target``, else ``inf`` — an
  SLO-infeasible fleet is never "cheap", it is not a candidate.
* ``evaluate`` prices the wave rollout: base fleet billed over the
  workload span, autoscaled extras over their ``extra_billed_s``
  episodes, spot reclamation folded into task durations in expectation
  (:func:`~repro.cloud.pricing.spot_inflation` inside the simulator).
* ``exact_cost`` runs the DES with the real reclaim/provision event
  processes and bills the recorded per-node online episodes
  (:func:`~repro.cloud.pricing.bill_workload`).  A workload that cannot
  finish raises ``UnfinishedWorkloadError``; a workload that finishes
  but misses the SLO raises :class:`SloUnmetError` — both subclass
  :class:`repro.search.ExactCostUnavailable`, so fallback paths skip
  the candidate loudly instead of reporting a silent number.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.hadoop.simulator import SimConfig
from repro.search.evaluator import (
    Evaluator,
    ExactCostUnavailable,
    SearchResult,
    masked_total,
    pad_block,
    split_overrides,
)
from repro.spec import Axis, ParamSpace, Predicate, ProvisioningReport

from repro.cluster.evaluator import UnfinishedWorkloadError
from repro.cluster.network import Topology
from repro.cluster.sched import ClusterConfig, NodeClass, simulate_workload
from repro.cluster.vector_sim import (
    POLICIES,
    estimate_steps,
    pack_trace,
    simulate_batch,
)
from repro.cluster.workload import (
    JobClass,
    WorkloadTrace,
    default_job_classes,
    poisson_trace,
    rescale,
)

from .autoscaler import AUTOSCALE_POLICIES, ElasticFleet
from .pricing import bill_workload

__all__ = ["CloudEvaluator", "SloUnmetError", "cloud_space"]

_SLO_EPS = 1e-9


class SloUnmetError(ExactCostUnavailable):
    """The DES finished the workload but its SLO attainment fell short of
    the evaluator's ``slo_target`` — dollars-per-job is defined but the
    fleet is not a feasible candidate, so ``exact_cost`` raises instead of
    returning a cost the search could mistake for cheap.  Subclasses
    :class:`repro.search.ExactCostUnavailable`: generic fallback paths
    (top-k, descent, service) skip the candidate with a log line."""


def _fleet_has_nodes(cols: Mapping[str, np.ndarray]) -> np.ndarray:
    """``pOnDemandNodes + pSpotNodes >= 1`` — someone must run the work;
    unconstrained when either column is absent (validity_mask accepts
    partial columns)."""
    if "pOnDemandNodes" not in cols or "pSpotNodes" not in cols:
        return np.asarray(True)
    return (np.round(cols["pOnDemandNodes"])
            + np.round(cols["pSpotNodes"])) >= 1


def _reclaim_needs_spot(cols: Mapping[str, np.ndarray]) -> np.ndarray:
    """A positive ``spotReclaimRate`` with zero spot nodes is a nonsense
    config (the reclaim process has nothing to act on) — masked instead of
    silently ignored.  Spot nodes with rate 0 stay valid: cheap capacity
    that happens never to be reclaimed."""
    if "spotReclaimRate" not in cols or "pSpotNodes" not in cols:
        return np.asarray(True)
    return (cols["spotReclaimRate"] <= 0) | (np.round(cols["pSpotNodes"]) > 0)


def _racks_fit_fleet(cols: Mapping[str, np.ndarray]) -> np.ndarray:
    """``pNumRacks <= pOnDemandNodes + pSpotNodes`` — an empty rack is a
    mis-specified topology, not a bigger fleet."""
    if ("pNumRacks" not in cols or "pOnDemandNodes" not in cols
            or "pSpotNodes" not in cols):
        return np.asarray(True)
    return cols["pNumRacks"] <= cols["pOnDemandNodes"] + cols["pSpotNodes"]


@functools.lru_cache(maxsize=None)
def cloud_space() -> ParamSpace:
    """The elastic capacity planner's searchable axes.

    The bounds ARE the feasibility rule: node counts >= 0 with at least
    one node total (a cross-axis :class:`Predicate`), slots >= 1, a
    positive offered rate, reclaim rate >= 0 (and only meaningful with
    spot capacity — the second predicate), a policy code in range for
    both the scheduler and the autoscaler, and a positive SLO bound.
    """
    return ParamSpace([
        Axis("pOnDemandNodes", kind="int", lower=0, group="cloud",
             doc="on-demand (never reclaimed) nodes in the priced fleet"),
        Axis("pSpotNodes", kind="int", lower=0, group="cloud",
             doc="spot (reclaimable, cheaper) nodes in the priced fleet"),
        Axis("pMaxMapsPerNode", kind="int", lower=1, table="Table 1",
             group="cloud", doc="map slots per node"),
        Axis("pMaxRedPerNode", kind="int", lower=1, table="Table 1",
             group="cloud", doc="reduce slots per node"),
        Axis("pReduceSlowstart", kind="float", lower=None, unit="fraction",
             table="Table 1", group="cloud",
             doc="map completion fraction before reducers launch"),
        Axis("arrivalRate", kind="float", lower=0, lower_open=True,
             unit="jobs/s", group="cloud",
             doc="offered load the unit-rate trace is rescaled to"),
        Axis("schedPolicy", kind="int", lower=0, upper=3, group="cloud",
             doc="0 fifo | 1 fair | 2 fair_preempt | 3 capacity"),
        Axis("spotReclaimRate", kind="float", lower=0, unit="1/s",
             group="cloud",
             doc="exponential reclaim rate of every spot node (0 = never)"),
        Axis("autoscalePolicy", kind="int", lower=0, upper=2, group="cloud",
             doc="0 off | 1 queue (high-water trigger) | 2 predicted "
                 "(provision up front)"),
        Axis("autoscaleHighWater", kind="float", lower=0, unit="slots",
             group="cloud",
             doc="unmet-demand slots that trigger the queue policy"),
        Axis("sloLatency", kind="float", lower=0, lower_open=True, unit="s",
             group="cloud",
             doc="per-job latency bound; attainment is the fraction of "
                 "jobs at or under it"),
        Axis("pNumRacks", kind="int", lower=1, group="cloud",
             doc="racks the fleet is striped across (1 = flat network)"),
        Axis("crossRackBw", kind="float", lower=0, lower_open=True,
             unit="x nominal", group="cloud",
             doc="aggregate core-uplink bandwidth per rack, in units of one "
                 "flow's nominal rate (inf = never the bottleneck)"),
        Axis("oversubscription", kind="float", lower=1, group="cloud",
             doc="top-of-rack oversubscription factor dividing crossRackBw"),
    ], predicates=[
        Predicate("fleet has nodes", _fleet_has_nodes,
                  doc="on-demand + spot node count must be >= 1"),
        Predicate("reclaim rate needs spot capacity", _reclaim_needs_spot,
                  doc="a positive spotReclaimRate requires spot nodes"),
        Predicate("racks within fleet", _racks_fit_fleet,
                  doc="at least one node per rack"),
    ])


class CloudEvaluator(Evaluator):
    """Batched dollars-under-SLO evaluation over candidate priced fleets.

    Parameters
    ----------
    classes / traces / n_jobs / n_seeds / trace_seed : the workload, as in
        :class:`~repro.cluster.evaluator.ClusterEvaluator` — cost is
        averaged over the traces.
    base : cluster defaults for the non-priced knobs (slots, scheduler,
        slowstart).  Must be a homogeneous base (no ``node_classes``) —
        the fleet mix is what the price axes search over.
    base_rate : default offered load (jobs/s; ``arrivalRate`` override).
    on_demand_price / spot_price : $/hour per node of each class.
    elastic : provisioning lifecycle + autoscaler defaults
        (:class:`~repro.cloud.autoscaler.ElasticFleet`); the
        ``autoscalePolicy`` / ``autoscaleHighWater`` / ``spotReclaimRate``
        axes override its policy, trigger and rate per candidate.  Extra
        nodes bill at ``elastic.extra_hourly_price``, default the
        on-demand price.
    slo_target : required SLO attainment fraction (default 0.95) for a
        candidate to be costed at all — below it, ``c_cost`` is inf.
    sim : DES :class:`SimConfig` for ``exact_cost``.
    """

    def __init__(
        self,
        classes: Sequence[JobClass] | None = None,
        *,
        traces: Sequence[WorkloadTrace] | None = None,
        n_jobs: int = 32,
        n_seeds: int = 2,
        trace_seed: int = 0,
        base: ClusterConfig = ClusterConfig(),
        base_rate: float = 0.1,
        on_demand_price: float = 0.40,
        spot_price: float = 0.10,
        elastic: ElasticFleet = ElasticFleet(),
        slo_target: float = 0.95,
        capacities: Mapping[str, float] | None = None,
        sim: SimConfig = SimConfig(),
        chunk: int = 256,
        devices=None,
    ):
        if base.node_classes:
            raise ValueError(
                "CloudEvaluator's pOnDemandNodes/pSpotNodes axes define the "
                "fleet mix; pass a homogeneous base (no node_classes) and "
                "search the mix instead"
            )
        if on_demand_price < 0 or spot_price < 0:
            raise ValueError("hourly prices must be >= 0")
        if not 0.0 <= slo_target <= 1.0:
            raise ValueError("slo_target is a fraction in [0, 1]")
        self.classes = list(classes) if classes is not None \
            else default_job_classes()
        self.traces = list(traces) if traces is not None else [
            poisson_trace(self.classes, n_jobs, rate=1.0, seed=trace_seed + s)
            for s in range(n_seeds)
        ]
        packed = [pack_trace(t) for t in self.traces]
        #: (S, J) per-job constants shared by every scenario
        self._cols = {k: np.stack([p[k] for p in packed]) for k in packed[0]}
        self._base = base
        self._sim = sim
        self.on_demand_price = float(on_demand_price)
        self.spot_price = float(spot_price)
        self.slo_target = float(slo_target)
        self.elastic = elastic if elastic.extra_hourly_price is not None \
            else dataclasses.replace(
                elastic, extra_hourly_price=float(on_demand_price))
        self.capacities = dict(capacities) if capacities else {}
        # capacity-scheduler queues, exactly the ClusterEvaluator rule:
        # one global name universe, per-trace guarantees normalized over
        # the classes PRESENT in that trace
        qnames = sorted({jc.name for jc in self.classes}
                        | {a.klass.name for t in self.traces
                           for a in t.arrivals})
        qidx = {name: i for i, name in enumerate(qnames)}
        self._queue_cols = np.stack([
            np.asarray([qidx[a.klass.name] for a in t.arrivals], np.float64)
            for t in self.traces
        ])                                                      # (S, J)
        fracs = np.zeros((len(self.traces), len(qnames)))
        for s, t in enumerate(self.traces):
            present = sorted({a.klass.name for a in t.arrivals})
            w = {q: self.capacities.get(q, 1.0) for q in present}
            tot = sum(w.values()) or 1.0
            for q in present:
                fracs[s, qidx[q]] = w[q] / tot
        self._queue_fracs = fracs                               # (S, Q)
        self._devs = tuple(devices) if devices is not None \
            else tuple(compat.default_search_devices())
        self.num_devices = len(self._devs)
        self.chunk = -(-max(chunk, 1) // self.num_devices) * self.num_devices
        # strong-typed scalars (weak-typed defaults change the compile key
        # when an axis switches between scalar and batched-column form)
        fdt = jnp.result_type(float)
        self.base_cfg = {
            "pOnDemandNodes": jnp.asarray(float(base.num_nodes), dtype=fdt),
            "pSpotNodes": jnp.asarray(0.0, dtype=fdt),
            "pMaxMapsPerNode": jnp.asarray(
                float(base.map_slots_per_node), dtype=fdt),
            "pMaxRedPerNode": jnp.asarray(
                float(base.reduce_slots_per_node), dtype=fdt),
            "pReduceSlowstart": jnp.asarray(
                float(base.reduce_slowstart), dtype=fdt),
            "arrivalRate": jnp.asarray(float(base_rate), dtype=fdt),
            "schedPolicy": jnp.asarray(
                float(POLICIES.index(base.scheduler)), dtype=fdt),
            "spotReclaimRate": jnp.asarray(
                float(self.elastic.reclaim_rate), dtype=fdt),
            "autoscalePolicy": jnp.asarray(
                float(self.elastic.policy_code), dtype=fdt),
            "autoscaleHighWater": jnp.asarray(
                float(self.elastic.high_water), dtype=fdt),
            "sloLatency": jnp.asarray(float("inf"), dtype=fdt),
            "pNumRacks": jnp.asarray(
                float(base.topology.num_racks if base.topology else 1),
                dtype=fdt),
            "crossRackBw": jnp.asarray(
                float(base.topology.cross_rack_bw if base.topology
                      else float("inf")), dtype=fdt),
            "oversubscription": jnp.asarray(
                float(base.topology.oversub if base.topology else 1.0),
                dtype=fdt),
        }

    # ---------------- Evaluator interface ----------------

    @property
    def cost_key(self) -> str:
        return "c_cost"

    @property
    def param_space(self) -> ParamSpace:
        """Declared cloud axes — the single source of the knob mask."""
        return cloud_space()

    def grad_objective(self):
        from repro.search.evaluator import NotDifferentiableError

        raise NotDifferentiableError(
            "the dollar cost rides the discrete-event workload rollout "
            "(wave counts, reclaim/provision events) — piecewise-constant "
            "in every knob; gradient strategies fall back to coordinate "
            "descent here.  The pricing arithmetic itself IS differentiable "
            "and is registered as the 'cloud-pricing' analysis target."
        )

    def evaluate(self, overrides: Mapping[str, Any]) -> SearchResult:
        batched, static, n = split_overrides(self.base_cfg, overrides)
        out_blocks: dict[str, list[np.ndarray]] = {}
        for start in range(0, n, self.chunk):
            stop = min(start + self.chunk, n)
            rows, _ = pad_block(batched, start, stop, self.chunk)
            out = self._evaluate_rows(rows, static)
            for k, v in out.items():
                out_blocks.setdefault(k, []).append(v[: stop - start])
        outputs = {k: np.concatenate(v) for k, v in out_blocks.items()}
        total = masked_total(outputs, self.cost_key)
        return SearchResult(overrides=batched, outputs=outputs,
                            total_cost=total)

    def report(self, overrides) -> ProvisioningReport:
        """Typed evaluation: an overrides mapping (the ``api.sweep``
        convention) or an already-computed :class:`SearchResult`, lifted
        into the :class:`~repro.spec.ProvisioningReport` view."""
        result = overrides if isinstance(overrides, SearchResult) \
            else self.evaluate(overrides)
        return ProvisioningReport.from_outputs(result.outputs)

    def _resolve_config(
        self, cfg: Mapping[str, float]
    ) -> tuple[ClusterConfig, ElasticFleet] | None:
        """A flat assignment -> (cluster, elastic fleet), or ``None`` when
        the knobs violate the declared axis bounds / predicates."""
        od = int(round(cfg["pOnDemandNodes"]))
        sp = int(round(cfg["pSpotNodes"]))
        mpn = int(round(cfg["pMaxMapsPerNode"]))
        rpn = int(round(cfg["pMaxRedPerNode"]))
        poli = int(round(cfg["schedPolicy"]))
        rr = float(cfg["spotReclaimRate"])
        xpol = int(round(cfg["autoscalePolicy"]))
        hw = float(cfg["autoscaleHighWater"])
        slo = float(cfg["sloLatency"])
        racks = int(round(cfg["pNumRacks"]))
        xbw = float(cfg["crossRackBw"])
        osub = float(cfg["oversubscription"])
        if (od < 0 or sp < 0 or od + sp < 1 or mpn < 1 or rpn < 1
                or cfg["arrivalRate"] <= 0
                or not 0 <= poli < len(POLICIES)
                or rr < 0 or (rr > 0 and sp == 0)
                or not 0 <= xpol < len(AUTOSCALE_POLICIES)
                or hw < 0 or slo <= 0
                or racks < 1 or racks > od + sp or xbw <= 0 or osub < 1.0):
            return None
        fleet = ()
        if sp > 0:                  # spot first — the wave class-column order
            fleet += (NodeClass(sp, 1.0, self.spot_price, spot=True),)
        if od > 0:
            fleet += (NodeClass(od, 1.0, self.on_demand_price, spot=False),)
        cc = ClusterConfig(
            num_nodes=od + sp,
            map_slots_per_node=mpn, reduce_slots_per_node=rpn,
            scheduler=POLICIES[poli],
            reduce_slowstart=float(cfg["pReduceSlowstart"]),
            node_classes=fleet,
            capacities=tuple(sorted(self.capacities.items())),
            topology=Topology(num_racks=racks, cross_rack_bw=xbw,
                              oversub=osub) if racks > 1 else None,
        )
        el = dataclasses.replace(
            self.elastic, policy=AUTOSCALE_POLICIES[xpol],
            high_water=hw, reclaim_rate=rr)
        return cc, el

    def exact_cost(self, assignment: Mapping[str, float]) -> float:
        """The DES with real reclaim/provision events, billed per episode.

        The same objective as ``evaluate``: mean dollars-per-job over the
        traces.  Raises :class:`UnfinishedWorkloadError` when a trace
        cannot finish, :class:`SloUnmetError` when mean attainment misses
        ``slo_target`` — never a silent inf.
        """
        cfg = {k: float(np.asarray(v)) for k, v in self.base_cfg.items()}
        for k, v in assignment.items():
            if k not in cfg:
                raise KeyError(f"unknown config key: {k!r}")
            cfg[k] = float(v)
        resolved = self._resolve_config(cfg)
        if resolved is None:
            return float("inf")
        cc, el = resolved
        rate, slo = cfg["arrivalRate"], cfg["sloLatency"]
        dpj, attain = [], []
        for tr in self.traces:
            res = simulate_workload(rescale(tr, rate), cc, self._sim,
                                    elastic=el)
            if res.n_unfinished:
                raise UnfinishedWorkloadError(
                    f"{res.n_unfinished}/{len(res.jobs)} jobs never finished "
                    f"on {cc} — dollars-per-job is undefined; inspect "
                    "WorkloadResult.n_unfinished"
                )
            # bill from the first submit (the wave span's origin) to the
            # last finish, so both backends price the same window
            first = min(j.submit_time for j in res.jobs)
            dollars = bill_workload(res, cc, elastic=el,
                                    window=(first, res.makespan))
            dpj.append(dollars / max(len(res.jobs), 1))
            attain.append(float((res.latencies() <= slo).mean()))
        if float(np.mean(attain)) < self.slo_target - _SLO_EPS:
            raise SloUnmetError(
                f"SLO attainment {np.mean(attain):.3f} < target "
                f"{self.slo_target} at sloLatency={slo} — this fleet is "
                "not a feasible candidate"
            )
        return float(np.mean(dpj))

    # ---------------- internals ----------------

    def _evaluate_rows(self, rows: Mapping[str, np.ndarray],
                       static: Mapping[str, float]) -> dict[str, np.ndarray]:
        """One padded chunk -> per-row metrics (row x trace scenarios)."""
        b = self.chunk
        col = lambda k: rows[k] if k in rows else np.full(b, static[k])
        od = np.round(col("pOnDemandNodes"))
        sp = np.round(col("pSpotNodes"))
        mpn = np.round(col("pMaxMapsPerNode"))
        rpn = np.round(col("pMaxRedPerNode"))
        slow = col("pReduceSlowstart")
        rate = col("arrivalRate")
        pol = np.round(col("schedPolicy"))
        rr = col("spotReclaimRate")
        xpol = np.round(col("autoscalePolicy"))
        hw = col("autoscaleHighWater")
        slo = col("sloLatency")
        # the declared axis bounds + predicates ARE the mask
        ok, _ = self.param_space.validity_mask(
            {k: col(k) for k in self.base_cfg})
        # invalid rows still ride the vmapped rollout — sanitize their knobs
        # so a zero-slot lane cannot pin the whole chunk at the step cap
        od_s = np.maximum(od, 0.0)
        sp_s = np.maximum(sp, 0.0)
        od_s = np.where(od_s + sp_s < 1.0, 1.0, od_s)
        total_s = od_s + sp_s
        mpn_s = np.maximum(mpn, 1.0)
        rpn_s = np.maximum(rpn, 1.0)
        rate_s = np.where(rate > 0, rate, 1.0)
        pol_s = np.clip(pol, 0.0, float(len(POLICIES) - 1))
        rr_s = np.where(sp_s > 0, np.maximum(rr, 0.0), 0.0)
        xpol_s = np.clip(xpol, 0.0, float(len(AUTOSCALE_POLICIES) - 1))
        hw_s = np.maximum(hw, 0.0)
        slo_s = np.where(slo > 0, slo, np.inf)
        racks_s = np.clip(np.round(col("pNumRacks")), 1.0, total_s)
        xbw = col("crossRackBw")
        xbw_s = np.where(xbw > 0, xbw, np.inf)
        osub_s = np.maximum(col("oversubscription"), 1.0)

        el = self.elastic
        extra_on = np.where(xpol_s > 0.5, float(el.max_extra_nodes), 0.0)
        cols, s = self._cols, len(self.traces)
        rep = lambda a: np.repeat(a[:, None], s, axis=1).reshape(b * s)
        rep2 = lambda a: np.repeat(a, s, axis=0)        # (b, C) -> (b*s, C)
        perjob = lambda a: np.broadcast_to(
            a[None], (b,) + a.shape).reshape(b * s, -1)
        frac = (total_s - 1.0) / total_s
        scen = {
            "arrival": perjob(cols["arrival"]) / rep(rate_s)[:, None],
            "n_maps": perjob(cols["n_maps"]),
            "n_reds": perjob(cols["n_reds"]),
            "map_cost": perjob(cols["map_cost"]),
            "red_work": perjob(cols["red_work"]),
            "shuffle": perjob(cols["shuffle"]) * rep(frac)[:, None],
            "policy": rep(pol_s),
            "slowstart": rep(slow),
            "queue": perjob(self._queue_cols),
            "queue_frac": np.tile(self._queue_fracs, (b, 1)),
            # two class columns, spot first (both baseline speed — the
            # stable fastest-first sort keeps the declared order, and
            # autoscaled extra capacity joins the LAST = on-demand column)
            "map_slots": rep2(np.stack([sp_s * mpn_s, od_s * mpn_s], 1)),
            "red_slots": rep2(np.stack([sp_s * rpn_s, od_s * rpn_s], 1)),
            "speedup": rep2(np.stack(
                [np.ones_like(sp_s), np.ones_like(od_s)], axis=1)),
            "reclaim_rate": rep2(np.stack([rr_s, np.zeros_like(rr_s)], 1)),
            "autoscale": rep(xpol_s),
            "high_water": rep(hw_s),
            "provision_latency": rep(
                np.full(b, float(el.provision_latency))),
            "extra_map_slots": rep(extra_on * mpn_s),
            "extra_red_slots": rep(extra_on * rpn_s),
            "billing_quantum": rep(np.full(b, float(el.billing_quantum))),
            "topo_racks": rep(racks_s),
            "topo_cross_bw": rep(xbw_s),
            "topo_oversub": rep(osub_s),
        }
        if "dep" in cols:
            scen["dep"] = perjob(cols["dep"])
            scen["dep_kind"] = perjob(cols["dep_kind"])
        out = simulate_batch(scen, n_steps=estimate_steps(scen),
                             devices=self._devs)
        shp = (b, s)
        lat = np.asarray(out["latency"]).reshape(b, s, -1)      # (b, S, J)
        attain = np.where(
            np.isfinite(lat), lat <= rep(slo_s).reshape(b, s, 1), 0.0
        ).mean(axis=(1, 2))
        span = np.asarray(out["makespan"]).reshape(shp)         # (b, S)
        billed = np.asarray(out.get(
            "extra_billed_s", np.zeros(b * s))).reshape(shp)
        quantum = float(el.billing_quantum)
        if quantum > 0:
            span_b = np.ceil(span / quantum) * quantum
        else:
            span_b = span
        fleet_rate = sp_s * self.spot_price + od_s * self.on_demand_price
        extra_price = float(el.extra_hourly_price or 0.0)
        dollars = (fleet_rate[:, None] * span_b
                   + extra_price * extra_on[:, None] * billed) / 3600.0
        n_jobs = lat.shape[-1]
        dpj = (dollars / n_jobs).mean(axis=1)
        conv = np.asarray(out["converged"]).reshape(shp).min(axis=1)
        feasible = attain >= self.slo_target - _SLO_EPS
        return {
            "c_dollarsPerJob": dpj.astype(np.float64),
            "c_dollarMakespan": dollars.mean(axis=1).astype(np.float64),
            "c_sloAttain": attain.astype(np.float64),
            "c_meanLat": np.asarray(out["mean_latency"]).reshape(shp)
            .mean(axis=1).astype(np.float64),
            "c_p95Lat": np.asarray(out["p95_latency"]).reshape(shp)
            .mean(axis=1).astype(np.float64),
            "c_util": np.asarray(out["utilization"]).reshape(shp)
            .mean(axis=1).astype(np.float64),
            # the objective: dollars-per-job where the SLO holds, inf where
            # it does not — an infeasible fleet is never "cheap"
            "c_cost": np.where(feasible, dpj, np.inf).astype(np.float64),
            "valid": (ok & (conv > 0)).astype(np.float64),
        }
