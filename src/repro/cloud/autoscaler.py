"""Elastic-fleet provisioning lifecycle and autoscaling policies.

:class:`ElasticFleet` is the single value both simulator backends read
their cloud semantics from:

* the DES (``cluster.sched.simulate_workload(..., elastic=fleet)``)
  interprets it exactly — per-node reclaim processes, a provision
  latency before extra capacity comes online, teardown when the queue
  drains, per-episode minimum billing granularity;
* the wave simulator consumes it as scenario columns
  (:func:`wave_columns`) — one extra capacity block that switches on and
  off as a whole, with spot reclamation folded into task durations in
  expectation (``pricing.spot_inflation``).

Policies (``AUTOSCALE_POLICIES`` index == wire code):

======  =========  ====================================================
 code    name       behaviour
======  =========  ====================================================
 0       off        fixed fleet, never provisions
 1       queue      provision when unmet demand > ``high_water`` slots,
                    tear down when the queue drains
 2       predicted  provision once, up front, sized/justified by the
                    closed-form model (:func:`predicted_extra_nodes`)
======  =========  ====================================================
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

__all__ = [
    "AUTOSCALE_POLICIES",
    "ElasticFleet",
    "predicted_extra_nodes",
    "wave_columns",
]

AUTOSCALE_POLICIES = ("off", "queue", "predicted")


@dataclass(frozen=True)
class ElasticFleet:
    """Provisioning lifecycle + autoscaling policy for one fleet.

    ``reclaim_rate`` (1/s) applies to every ``spot`` node class in the
    cluster; ``0`` disables reclamation even for spot-flagged classes.
    Autoscaled extra nodes clone the slowest base class (never spot) and
    bill at ``extra_hourly_price`` when set, else that class's price.
    ``billing_quantum`` is the minimum billable seconds per online
    episode (e.g. 3600 for hour-granularity billing); ``0`` bills exact
    seconds.
    """

    policy: str = "off"
    max_extra_nodes: int = 0
    high_water: float = 0.0          # unmet-demand slots that trigger scale-up
    provision_latency: float = 0.0   # s between decision and capacity online
    billing_quantum: float = 0.0     # min billable s per online episode
    reclaim_rate: float = 0.0        # 1/s, exponential spot inter-reclaim
    seed: int = 0                    # reclaim-process RNG stream
    extra_hourly_price: float | None = None

    def __post_init__(self):
        if self.policy not in AUTOSCALE_POLICIES:
            raise ValueError(
                f"unknown autoscale policy: {self.policy!r} "
                f"(want one of {AUTOSCALE_POLICIES})")
        if self.max_extra_nodes < 0:
            raise ValueError("max_extra_nodes must be >= 0")
        if self.high_water < 0:
            raise ValueError("high_water must be >= 0")
        if self.provision_latency < 0:
            raise ValueError("provision_latency must be >= 0")
        if self.billing_quantum < 0:
            raise ValueError("billing_quantum must be >= 0")
        if self.reclaim_rate < 0:
            raise ValueError("reclaim_rate must be >= 0")
        if self.extra_hourly_price is not None and self.extra_hourly_price < 0:
            raise ValueError("extra_hourly_price must be >= 0")

    @property
    def policy_code(self) -> int:
        """Integer wire code (``AUTOSCALE_POLICIES`` index) shared by the
        DES, the wave columns, and the ``autoscalePolicy`` axis."""
        return AUTOSCALE_POLICIES.index(self.policy)


def predicted_extra_nodes(demand_slots: float, base_slots: int,
                          slots_per_node: int, max_extra: int) -> int:
    """Closed-form sizing for the ``predicted`` policy: how many extra
    nodes cover a predicted steady-state demand of ``demand_slots``
    concurrently-runnable tasks beyond the ``base_slots`` the fixed
    fleet already offers.  Clamped to ``[0, max_extra]``."""
    if slots_per_node <= 0 or max_extra <= 0:
        return 0
    deficit = float(demand_slots) - float(base_slots)
    if deficit <= 0.0:
        return 0
    return min(int(max_extra), int(math.ceil(deficit / slots_per_node)))


def wave_columns(fleet: "ElasticFleet", cluster, *, n_extra: int | None = None):
    """The wave simulator's view of an :class:`ElasticFleet`: the six
    scalar cloud columns plus the per-class ``reclaim_rate`` row for one
    scenario, keyed exactly as ``vector_sim.simulate_batch`` expects.

    ``cluster`` is the :class:`~repro.cluster.sched.ClusterConfig` whose
    class columns the scenario already carries — its declared class
    order determines which columns get the spot reclaim rate.
    ``n_extra`` overrides the provisioned block size (defaults to
    ``fleet.max_extra_nodes``, e.g. after :func:`predicted_extra_nodes`
    sizing).
    """
    classes = cluster.node_classes or (None,)
    rates = [
        float(fleet.reclaim_rate) if (nc is not None and nc.spot) else 0.0
        for nc in classes
    ]
    extra = fleet.max_extra_nodes if n_extra is None else int(n_extra)
    on = fleet.policy_code > 0 and extra > 0
    return {
        "reclaim_rate": np.asarray(rates, dtype=np.float64),
        "autoscale": float(fleet.policy_code),
        "high_water": float(fleet.high_water),
        "provision_latency": float(fleet.provision_latency),
        "extra_map_slots": float(extra * cluster.map_slots_per_node) if on
        else 0.0,
        "extra_red_slots": float(extra * cluster.reduce_slots_per_node) if on
        else 0.0,
        "billing_quantum": float(fleet.billing_quantum),
    }
