"""Dollar pricing for simulated fleets.

The Herodotou models predict *seconds*; in a pay-as-you-go cloud the
objective is *dollars under an SLO* (cf. Rizvandi et al., arXiv
1303.3632).  This module is the conversion layer between the two, shared
by both simulator backends:

* :func:`dollars_for` — traced, differentiable span -> dollars
  conversion used by the wave evaluator and the ``cloud-pricing``
  analysis target.  The billing-quantum ceil is applied only when the
  quantum is a *concrete* positive number so the differentiated path
  never contains a gradient-blocking ``ceil`` (PR 7 analysis gate).
* :func:`spot_inflation` — the wave simulator's expectation model of
  exponential spot reclamation: a task of duration ``d`` on a node
  reclaimed at rate ``lam`` needs ``(e^{lam d} - 1) / lam`` seconds of
  wall clock in expectation (restart-from-scratch semantics, matching
  the DES kill-and-requeue machinery).
* :func:`bill_workload` — host-side exact biller for DES results: walks
  the per-node online episodes recorded by ``simulate_workload``,
  clips them to the billing window, applies the minimum billing
  granularity per episode, and prices each node by its class.

Prices are $/hour throughout (the industry unit); simulated time is
seconds, so every conversion divides by 3600.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Sequence

import jax.numpy as jnp

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from repro.cloud.autoscaler import ElasticFleet
    from repro.cluster.sched import ClusterConfig, WorkloadResult

__all__ = ["spot_inflation", "dollars_for", "bill_workload"]

_EPS = 1e-9


def spot_inflation(rate, duration):
    """Expected wall-clock inflation factor for spot-reclaimed work.

    With exponential reclamation at ``rate`` (1/s) and restart-from-
    scratch semantics, a task of ``duration`` seconds takes
    ``(e^{rate * duration} - 1) / rate`` seconds in expectation; the
    factor returned here is that divided by ``duration``.  ``rate <= 0``
    (on-demand nodes) returns exactly 1.  Uses the double-``where``
    idiom so the guarded branch never produces ``inf * 0`` NaNs under
    ``grad``.
    """
    rate = jnp.maximum(jnp.asarray(rate, dtype=jnp.result_type(float)), 0.0)
    dur = jnp.asarray(duration, dtype=jnp.result_type(float))
    rate_safe = jnp.where(rate > 0.0, rate, 1.0)
    expected = jnp.expm1(rate_safe * dur) / (rate_safe * jnp.maximum(dur, _EPS))
    return jnp.where(rate > 0.0, expected, 1.0)


def dollars_for(span_s, node_counts, prices_hr, billing_quantum=0.0):
    """Dollar bill for a fleet held online for ``span_s`` seconds.

    ``node_counts`` and ``prices_hr`` ($/hour) broadcast against each
    other and are summed over their last axis; ``span_s`` broadcasts
    against the result, so batched evaluators can pass ``(B,)`` spans
    with ``(B, C)`` fleets.  When ``billing_quantum`` is a concrete
    (python) non-positive number the span passes through untouched and
    the traced graph contains no ``ceil`` — keeping the differentiable
    pricing path clean for the analysis gate.
    """
    span = jnp.asarray(span_s, dtype=jnp.result_type(float))
    counts = jnp.asarray(node_counts, dtype=jnp.result_type(float))
    prices = jnp.asarray(prices_hr, dtype=jnp.result_type(float))
    concrete_off = (
        isinstance(billing_quantum, (int, float)) and billing_quantum <= 0.0
    )
    if concrete_off:
        billed = span
    else:
        quantum = jnp.asarray(billing_quantum, dtype=jnp.result_type(float))
        q_safe = jnp.where(quantum > 0.0, quantum, 1.0)
        billed = jnp.where(
            quantum > 0.0, jnp.ceil(span / q_safe) * q_safe, span
        )
    fleet_rate = jnp.sum(counts * prices, axis=-1)
    return fleet_rate * billed / 3600.0


def _billed_seconds(episodes: Sequence[tuple[float, float]],
                    lo: float, hi: float, quantum: float) -> float:
    """Sum of quantized online-episode durations clipped to [lo, hi]."""
    total = 0.0
    for start, end in episodes:
        dur = min(end, hi) - max(start, lo)
        if dur <= 0.0:
            continue
        if quantum > 0.0:
            dur = math.ceil(dur / quantum - _EPS) * quantum
        total += dur
    return total


def bill_workload(result: "WorkloadResult", cluster: "ClusterConfig", *,
                  elastic: "ElasticFleet | None" = None,
                  window: tuple[float, float] | None = None) -> float:
    """Exact dollar bill for a DES run (the ``exact_cost`` pricing path).

    Walks ``result.node_online`` — the per-node ``(online, offline)``
    episodes recorded by ``simulate_workload`` — so reclaimed spot nodes
    stop billing while waiting for replacements and autoscaled extras
    bill only while provisioned.  Base nodes are priced by their
    ``NodeClass.hourly_price``; extra (autoscaled) nodes bill at
    ``elastic.extra_hourly_price`` when set, else their clone class's
    price.  ``window`` defaults to ``(0, result.makespan)``.
    """
    table = cluster.node_table()
    n_base = len(table)
    lo, hi = window if window is not None else (0.0, float(result.makespan))
    if not math.isfinite(hi):
        raise ValueError("cannot bill an unfinished workload (inf makespan)")
    quantum = float(elastic.billing_quantum) if elastic is not None else 0.0
    base_price = table[-1][2] if table else 0.0
    extra_price = base_price
    if elastic is not None and elastic.extra_hourly_price is not None:
        extra_price = float(elastic.extra_hourly_price)
    total = 0.0
    for nd, episodes in enumerate(result.node_online):
        price = table[nd][2] if nd < n_base else extra_price
        if price <= 0.0:
            continue
        total += price * _billed_seconds(episodes, lo, hi, quantum)
    return total / 3600.0
