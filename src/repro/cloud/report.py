"""Economic report glue: typed views and Pareto-frontier helpers.

The typed container itself (:class:`repro.spec.ProvisioningReport`)
lives in the spec layer next to :class:`~repro.spec.CostReport` so the
frozen API surface stays in one place; this module provides the
cloud-side conveniences built on top of it.
"""

from __future__ import annotations

from typing import Mapping

import numpy as np

from repro.spec import ProvisioningReport

__all__ = ["provisioning_report", "pareto_front"]


def provisioning_report(outputs: Mapping[str, object]) -> ProvisioningReport:
    """Lift a :meth:`CloudEvaluator.evaluate` output dict (the ``c_*``
    columns) into the typed, pytree-registered view."""
    return ProvisioningReport.from_outputs(outputs)


def pareto_front(costs, quality) -> np.ndarray:
    """Boolean mask of the (min-cost, min-quality) Pareto-optimal rows.

    Both metrics are *minimized* — pass e.g. ``dollars_per_job`` and
    ``p95_latency`` (negate a maximize-metric like ``slo_attainment``
    first).  A row is kept when no other row is at least as good on
    both axes and strictly better on one; non-finite rows are dominated
    by definition.
    """
    c = np.asarray(costs, dtype=np.float64).ravel()
    q = np.asarray(quality, dtype=np.float64).ravel()
    if c.shape != q.shape:
        raise ValueError(
            f"cost/quality shape mismatch: {c.shape} vs {q.shape}")
    finite = np.isfinite(c) & np.isfinite(q)
    keep = np.zeros(c.shape, dtype=bool)
    for i in np.nonzero(finite)[0]:
        others = finite.copy()
        others[i] = False
        dominated = np.any(
            (c[others] <= c[i]) & (q[others] <= q[i])
            & ((c[others] < c[i]) | (q[others] < q[i])))
        keep[i] = not dominated
    return keep
