"""repro.cloud — dollar-cost elastic provisioning on the cluster planner.

Herodotou's models predict *seconds*; in a pay-as-you-go cloud the
objective is *dollars under an SLO* (the billing connection of Rizvandi
et al., arXiv 1303.3632).  This package is that economic layer, built
on the PR-5 cluster machinery and the PR-8 observability substrate:

* :mod:`~repro.cloud.pricing` — $/hour node prices, the expected-cost
  model of exponential spot reclamation, and the exact per-episode DES
  biller (:func:`bill_workload`).
* :mod:`~repro.cloud.autoscaler` — :class:`ElasticFleet`: the
  provisioning lifecycle (provision latency, teardown, minimum billing
  granularity) plus the fixed / queue-depth / predicted-load policies,
  interpreted exactly by the DES and in expectation by the wave
  simulator (:func:`wave_columns`).
* :mod:`~repro.cloud.evaluator` — :class:`CloudEvaluator`: the
  dollars-under-SLO objective behind the standard
  :class:`repro.search.Evaluator` interface, so every strategy and
  :class:`~repro.search.WhatIfService` walk the price-performance
  Pareto frontier unchanged (:func:`pareto_front` extracts it).

The public surface below is frozen in ``spec/manifest.json`` and
guarded by ``tests/test_api_surface.py``.
"""

from .autoscaler import (
    AUTOSCALE_POLICIES,
    ElasticFleet,
    predicted_extra_nodes,
    wave_columns,
)
from .evaluator import CloudEvaluator, SloUnmetError, cloud_space
from .pricing import bill_workload, dollars_for, spot_inflation
from .report import pareto_front, provisioning_report

__all__ = [
    "AUTOSCALE_POLICIES",
    "CloudEvaluator",
    "ElasticFleet",
    "SloUnmetError",
    "bill_workload",
    "cloud_space",
    "dollars_for",
    "pareto_front",
    "predicted_extra_nodes",
    "provisioning_report",
    "spot_inflation",
    "wave_columns",
]
