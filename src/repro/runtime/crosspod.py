"""Cross-pod gradient reduction with compression (distributed-opt trick).

Within a pod, gradients reduce over ICI implicitly via pjit sharding.
*Across* pods the link is the slow DCN tier, so the pod-axis reduction is
expressed explicitly with shard_map + ``jax.lax.psum`` and the payload is
compressed first (bf16 or int8+error-feedback, ``repro.optim.compress``).

This is the Hadoop-paper NETCost lever: Eq. 90's network transfer shrinks
by the compression ratio exactly as a combiner shrinks shuffle bytes —
a *semantic* compressor applied before the wire.

Used by the multi-pod dry-run path and unit-tested numerically on a
2-device host mesh (tests/test_fault_tolerance.py::test_crosspod_compression).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.optim.compress import compress_grads, decompress_grads

__all__ = ["crosspod_reduce"]


def crosspod_reduce(grads, err, mesh: Mesh, *, method: str = "bf16", axis: str = "pod"):
    """Mean-reduce ``grads`` over the pod axis with compressed payloads.

    Returns (reduced_grads, new_error_state).  Leaves must already be
    identical within a pod (post ICI reduction).
    """
    if axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return grads, err

    npods = mesh.shape[axis]

    def body(g, e):
        if method == "int8":
            # Shared scale: pmax of per-pod |g+e| first (scalar per leaf,
            # negligible traffic), so the int32 psum of quantized payloads
            # dequantizes exactly once — per-pod scales would not reduce.
            def one(gl, el):
                corrected = gl.astype(jnp.float32) + el
                scale = jax.lax.pmax(
                    jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12), axis
                ) / 127.0
                q = jnp.clip(jnp.round(corrected / scale), -127, 127)
                deq = q * scale
                red = jax.lax.psum(q.astype(jnp.int32), axis).astype(
                    jnp.float32
                ) * scale / npods
                return red.astype(gl.dtype), corrected - deq

            flat_g, treedef = jax.tree.flatten(g)
            flat_e = jax.tree.leaves(e)
            outs = [one(gl, el) for gl, el in zip(flat_g, flat_e)]
            red = jax.tree.unflatten(treedef, [o[0] for o in outs])
            new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
        else:
            comp, new_err = compress_grads(g, e, method)
            summed = jax.tree.map(lambda c: jax.lax.psum(c, axis), comp)
            red = decompress_grads(
                jax.tree.map(lambda c: c / npods, summed), g, method
            )
        return red, new_err

    # Each leaf is replicated over the pod axis (pjit already reduced the
    # within-pod axes); shard_map sees the per-pod local view.
    rep = P()
    fn = shard_map(
        body, mesh=mesh, in_specs=(rep, rep), out_specs=(rep, rep),
        check_vma=False,
    )
    return fn(grads, err)
