from .stragglers import StragglerDetector, should_speculate
from .train_loop import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig", "StragglerDetector", "should_speculate"]
