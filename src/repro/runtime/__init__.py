"""repro.runtime — serving/training loops + the shared batching machinery.

``batching`` (admission queues, latency stats) is imported eagerly: it is
dependency-free and is also used by :mod:`repro.search.service`.  The
trainer/straggler symbols are resolved lazily (PEP 562) so that importing
the batching layer does not drag the whole model stack along.
"""

from .batching import AdmissionQueue, LatencyStats

__all__ = [
    "AdmissionQueue",
    "LatencyStats",
    "Trainer",
    "TrainerConfig",
    "StragglerDetector",
    "should_speculate",
]

_LAZY = {
    "Trainer": "train_loop",
    "TrainerConfig": "train_loop",
    "StragglerDetector": "stragglers",
    "should_speculate": "stragglers",
}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
