"""Production train loop: sharded step, checkpoint/auto-resume, straggler
hooks, gradient compression, failure injection.

The Trainer composes the pieces built elsewhere:

  model/step     repro.launch.steps.make_train_step (grad-accum lax.scan)
  sharding       repro.launch.sharding rules on any (dp, tp) mesh
  data           repro.data.TokenPipeline (stateless -> exact resume)
  checkpoints    repro.checkpoint.CheckpointManager (atomic/async/elastic)
  stragglers     repro.runtime.stragglers.StragglerDetector
  compression    repro.optim.compress (bf16 / int8+error-feedback) applied
                 to the cross-pod gradient reduction

Fault-tolerance contract (tested in tests/test_fault_tolerance.py):
``run()`` after a crash resumes from the newest valid checkpoint and
reproduces the exact parameter trajectory of an uninterrupted run
(bitwise, because data indexing is stateless and saves capture params +
optimizer state + step).
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.data import PipelineConfig, TokenPipeline
from repro.launch import sharding as shd
from repro.launch.steps import init_params, make_train_step
from repro.models import act_sharding
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init
from .stragglers import StragglerDetector

__all__ = ["Trainer", "TrainerConfig"]


@dataclass
class TrainerConfig:
    global_batch: int = 8
    seq_len: int = 128
    n_microbatches: int = 1
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    async_ckpt: bool = True
    log_every: int = 10
    seed: int = 0
    grad_compression: str = "none"      # none | bf16 | int8
    mesh_shape: tuple = ()              # () -> single-device (1,1)
    mesh_axes: tuple = ("data", "model")
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    # failure injection (tests): raise RuntimeError AFTER this step's save
    fail_at_step: int | None = None


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig):
        self.cfg = cfg
        self.tcfg = tcfg
        devs = np.array(jax.devices())
        shape = tcfg.mesh_shape or (len(devs), 1)
        self.mesh = Mesh(devs[: int(np.prod(shape))].reshape(shape), tcfg.mesh_axes)
        self.dp = self.mesh.shape[tcfg.mesh_axes[0]]

        self.pipeline = TokenPipeline(PipelineConfig(
            vocab_size=cfg.vocab_size,
            seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch,
            seed=tcfg.seed,
        ))
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.stragglers = StragglerDetector()
        self.metrics_log: list[dict] = []
        self.straggler_events: list[int] = []

        self._build()

    # ----------------------------------------------------------- compiled
    def _build(self):
        cfg, tcfg = self.cfg, self.tcfg
        params_shape = jax.eval_shape(
            partial(init_params, cfg=cfg), jax.random.PRNGKey(tcfg.seed)
        )
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        self.p_sharding = shd.named(self.mesh, shd.param_pspecs(cfg, params_shape, self.mesh))
        self.o_sharding = shd.named(self.mesh, shd.opt_pspecs(cfg, opt_shape, self.mesh))
        batch_axes = P(self.tcfg.mesh_axes[0])
        self.b_sharding = {
            "inputs": NamedSharding(self.mesh, batch_axes),
            "targets": NamedSharding(self.mesh, batch_axes),
            "mask": NamedSharding(self.mesh, batch_axes),
        }
        step = make_train_step(cfg, tcfg.opt, tcfg.n_microbatches)
        self._step = jax.jit(
            step,
            in_shardings=(self.p_sharding, self.o_sharding, self.b_sharding),
            out_shardings=(self.p_sharding, self.o_sharding, None),
            donate_argnums=(0, 1),
        )
        self._params_shape = params_shape
        self._opt_shape = opt_shape

    def _init_state(self):
        with self.mesh:
            params = jax.jit(
                partial(init_params, cfg=self.cfg),
                out_shardings=self.p_sharding,
            )(jax.random.PRNGKey(self.tcfg.seed))
            opt = jax.jit(adamw_init, out_shardings=self.o_sharding)(params)
        return params, opt

    # ----------------------------------------------------------- training
    def run(self, num_steps: int, *, resume: bool = True) -> dict:
        """Train to ``num_steps`` total; resumes from latest checkpoint."""
        tcfg = self.tcfg
        start = 0
        params = opt = None
        if resume:
            state, manifest = self.ckpt.restore(
                {"params": self._params_shape, "opt": self._opt_shape},
                shardings={"params": self.p_sharding, "opt": self.o_sharding},
            )
            if state is not None:
                params, opt = state["params"], state["opt"]
                start = manifest["step"] + 1
        if params is None:
            params, opt = self._init_state()

        act_sharding.clear_policy()
        last_loss = float("nan")
        with self.mesh:
            for step in range(start, num_steps):
                batch = self.pipeline.batch(step)
                t0 = time.perf_counter()
                params, opt, metrics = self._step(params, opt, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                if self.stragglers.observe("host0", dt):
                    self.straggler_events.append(step)
                last_loss = loss
                if step % tcfg.log_every == 0 or step == num_steps - 1:
                    rec = {
                        "step": step, "loss": loss, "time_s": dt,
                        "grad_norm": float(metrics.get("grad_norm", 0.0)),
                    }
                    self.metrics_log.append(rec)
                if tcfg.ckpt_every and (step + 1) % tcfg.ckpt_every == 0:
                    self.ckpt.save(
                        step, {"params": params, "opt": opt},
                        blocking=not tcfg.async_ckpt,
                        extra={"loss": loss},
                    )
                if tcfg.fail_at_step is not None and step == tcfg.fail_at_step:
                    self.ckpt.wait()
                    raise RuntimeError(f"injected failure at step {step}")
        self.ckpt.wait()
        self.ckpt.save(num_steps - 1, {"params": params, "opt": opt})
        return {
            "params": params, "opt": opt, "final_loss": last_loss,
            "log": self.metrics_log,
        }

    # -------------------------------------------------------------- utils
    def save_log(self, path: str):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            for rec in self.metrics_log:
                f.write(json.dumps(rec) + "\n")
