"""Generic admission/batching machinery shared by the serving loops.

Two production services in this repo have the same shape: heterogeneous
requests arrive over time, a fixed-size compiled executable does the work,
and throughput comes from packing waiting requests into that executable's
static batch.  :mod:`repro.runtime.serve_loop` does it with KV-cache slots
and lockstep decode ticks; :mod:`repro.search.service` does it with rows of
a :class:`~repro.search.evaluator.ChunkedEvaluator` chunk.  This module
holds the pieces both share so the admission semantics (FIFO, depth
accounting, end-to-end latency) stay identical:

* :class:`AdmissionQueue` — thread-safe FIFO with depth accounting and a
  condition variable for blocking consumers.  Single-threaded callers (the
  LM server's synchronous ``generate``) pay one uncontended lock per op.
* :class:`LatencyStats` — streaming latency recorder with p50/p99/mean.
  Latency is *end-to-end* by convention: measured from admission-queue
  entry to final completion, never from a mid-flight milestone (that was
  the ``Server.generate`` bug this module's extraction fixed).
"""

from __future__ import annotations

import math
import threading
from collections import deque
from typing import Generic, Iterator, TypeVar

from repro.obs.metrics import percentile_interp

__all__ = ["AdmissionQueue", "LatencyStats"]

T = TypeVar("T")


class LatencyStats:
    """Streaming end-to-end latency recorder (seconds) with percentiles.

    Percentiles use the repo's one interpolation rule
    (:func:`repro.obs.percentile_interp` — linear between order statistics,
    the same method ``numpy.percentile`` defaults to), with well-defined
    small-sample behavior: no samples -> 0.0, one sample -> that sample for
    every ``p``.  :meth:`merge` pools per-worker recorders losslessly.
    """

    def __init__(self) -> None:
        self._samples: list[float] = []
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(float(seconds))

    def merge(self, other: "LatencyStats") -> "LatencyStats":
        """Pool another recorder's samples into this one (e.g. combining
        per-worker stats).  Exact: percentiles of the merged recorder are
        percentiles of the union sample set.  Returns ``self``."""
        with other._lock:
            theirs = list(other._samples)
        with self._lock:
            self._samples.extend(theirs)
        return self

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._samples)

    def mean(self) -> float:
        with self._lock:
            return (math.fsum(self._samples) / len(self._samples)
                    if self._samples else 0.0)

    def percentile(self, p: float) -> float:
        """Linear-interpolated percentile; 0.0 when nothing was recorded."""
        with self._lock:
            return percentile_interp(sorted(self._samples), p)

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    def summary(self) -> dict[str, float]:
        return {
            "count": self.count,
            "mean_s": self.mean(),
            "p50_s": self.p50,
            "p99_s": self.p99,
        }


class AdmissionQueue(Generic[T]):
    """Thread-safe FIFO of pending work with depth accounting.

    Producers :meth:`put`; the consumer inspects the head with :meth:`peek`
    (so it can drain an item across several batches before retiring it with
    :meth:`pop`) or drains whole items with :meth:`take`.  :meth:`wait`
    blocks until work arrives or the queue is closed; :meth:`close` wakes
    every waiter so consumers can drain and exit.  ``peak_depth`` records
    the high-water mark for queue-pressure reporting.
    """

    def __init__(self, *, depth_gauge=None) -> None:
        self._items: deque[T] = deque()
        self._cond = threading.Condition()
        self._closed = False
        self.peak_depth = 0
        #: optional observability hook: any object with ``set(depth)`` (a
        #: repro.obs Gauge) called under the lock on every depth change.
        self.depth_gauge = depth_gauge

    def _depth_changed(self) -> None:
        if self.depth_gauge is not None:
            self.depth_gauge.set(len(self._items))

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)

    @property
    def depth(self) -> int:
        return len(self)

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def put(self, item: T) -> int:
        """Enqueue; returns the depth *including* the new item."""
        with self._cond:
            if self._closed:
                raise RuntimeError("cannot put into a closed AdmissionQueue")
            self._items.append(item)
            depth = len(self._items)
            self.peak_depth = max(self.peak_depth, depth)
            self._depth_changed()
            self._cond.notify_all()
            return depth

    def put_many(self, items: Iterator[T] | list[T]) -> int:
        """Enqueue a batch under one lock (single wake-up => one admission
        window sees all of them; the coalescing path in tests/benchmarks)."""
        with self._cond:
            if self._closed:
                raise RuntimeError("cannot put into a closed AdmissionQueue")
            n0 = len(self._items)
            self._items.extend(items)
            depth = len(self._items)
            self.peak_depth = max(self.peak_depth, depth)
            self._depth_changed()
            self._cond.notify_all()
            return depth - n0

    def peek(self) -> T | None:
        with self._cond:
            return self._items[0] if self._items else None

    def items(self) -> list[T]:
        """Shallow snapshot of the pending items (for depth/row gauges and
        consumers that select by predicate rather than strict FIFO)."""
        with self._cond:
            return list(self._items)

    def remove(self, item: T) -> bool:
        """Remove a specific pending item (identity match); ``False`` if it
        is no longer queued.  O(depth) — admission queues stay short."""
        with self._cond:
            try:
                self._items.remove(item)
                self._depth_changed()
                return True
            except ValueError:
                return False

    def pop(self) -> T | None:
        with self._cond:
            item = self._items.popleft() if self._items else None
            if item is not None:
                self._depth_changed()
            return item

    def take(self, max_items: int | None = None) -> list[T]:
        """Pop up to ``max_items`` (all pending when ``None``)."""
        with self._cond:
            n = len(self._items) if max_items is None else min(max_items,
                                                               len(self._items))
            out = [self._items.popleft() for _ in range(n)]
            if out:
                self._depth_changed()
            return out

    def wait(self, timeout: float | None = None) -> bool:
        """Block until the queue is non-empty or closed.  Returns ``True``
        when items are available, ``False`` on close-with-nothing-pending or
        timeout."""
        with self._cond:
            self._cond.wait_for(
                lambda: self._items or self._closed, timeout=timeout
            )
            return bool(self._items)

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
