"""Batched serving runtime: prefill + decode with slot-based continuous
batching over fixed-shape KV caches.

A :class:`Server` owns B cache slots.  Requests (token prompts) queue up;
free slots prefill them (one jit'd prefill per admission, right-padded to
the slot's max length), and a single jit'd decode step advances ALL slots
one token per tick — finished slots (EOS or max tokens) are recycled for
queued requests.  This is the standard production serving shape (fixed
compiled programs, dynamic request flow around them).

Per-slot decode positions live in a vector so different slots can be at
different positions inside one compiled decode step; each slot's cache is
written at its own position via the models' cache update logic (which
takes scalar ``pos`` — slots share a position during lockstep decode, so
admission aligns: a fresh request's cache is padded to the current tick.
For heterogeneous positions the serve step falls back to per-slot decode.)
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.runtime.batching import AdmissionQueue, LatencyStats

__all__ = ["Request", "Server"]


@dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    generated: list = field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0      # END-TO-END: admission -> last token emitted


class Server:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 256, temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self._prefill = jax.jit(
            lambda p, toks: lm.prefill(p, cfg, toks, max_len)
        )
        self._decode = jax.jit(
            lambda p, tok, caches, pos: lm.decode_step(p, cfg, tok, caches, pos)
        )
        self.stats = {"prefills": 0, "decode_ticks": 0, "tokens_out": 0}
        self.latency = LatencyStats()

    def _sample(self, logits: jax.Array, key) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits[0, -1]))
        return int(jax.random.categorical(key, logits[0, -1] / self.temperature))

    def generate(self, requests: list[Request],
                 max_slots: int | None = None) -> list[Request]:
        """Serve a list of requests with per-request caches (B=1 slots),
        batching decode ticks across active requests round-robin.

        Requests flow through the shared admission queue: at most
        ``max_slots`` are in flight at once (all of them when ``None``);
        a finished slot immediately admits the next queued request —
        the same continuous-batching shape as the what-if service.

        ``req.latency_s`` is END-TO-END (admission to last token), and
        ``stats["decode_ticks"]`` counts lockstep ticks — one per decode
        round, not one per active request per round.  (Both were wrong
        before: latency froze at prefill time and never saw decode, and
        the tick counter was really a decode-call counter.)
        """
        key = jax.random.PRNGKey(0)
        queue: AdmissionQueue[Request] = AdmissionQueue()
        t_admit: dict[int, float] = {}
        for req in requests:
            t_admit[id(req)] = time.perf_counter()
            queue.put(req)
        slots = len(requests) if max_slots is None else max(1, max_slots)

        active: list[tuple[Request, dict, int]] = []
        while active or len(queue):
            # admission: fill free slots from the queue (prefill each)
            while len(active) < slots:
                req = queue.pop()
                if req is None:
                    break
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, caches, pos = self._prefill(self.params, toks)
                self.stats["prefills"] += 1
                key, sub = jax.random.split(key)
                req.generated.append(self._sample(logits, sub))
                active.append((req, caches, int(pos)))

            # one lockstep decode tick over every unfinished slot
            ticked = False
            for i, (req, caches, pos) in enumerate(active):
                if len(req.generated) >= req.max_new_tokens:
                    continue
                if not ticked:
                    self.stats["decode_ticks"] += 1
                    ticked = True
                tok = jnp.asarray([[req.generated[-1]]], jnp.int32)
                logits, caches = self._decode(
                    self.params, tok, caches, jnp.asarray(pos, jnp.int32)
                )
                key, sub = jax.random.split(key)
                req.generated.append(self._sample(logits, sub))
                self.stats["tokens_out"] += 1
                active[i] = (req, caches, pos + 1)

            # retire finished slots (freeing them for queued requests)
            still: list[tuple[Request, dict, int]] = []
            for req, caches, pos in active:
                if len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    req.latency_s = time.perf_counter() - t_admit[id(req)]
                    self.latency.record(req.latency_s)
                else:
                    still.append((req, caches, pos))
            active = still
        return requests

    def throughput_batch(self, prompts: np.ndarray, new_tokens: int) -> dict:
        """Fixed-batch generation (all slots in lockstep) — the serving
        benchmark path: one prefill + ``new_tokens`` decode steps for a
        whole (B, S) prompt batch."""
        B = prompts.shape[0]
        t0 = time.perf_counter()
        logits, caches, pos = self._prefill(
            self.params, jnp.asarray(prompts, jnp.int32)
        )
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        prefill_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        outs = [tok]
        p = pos
        for _ in range(new_tokens - 1):
            logits, caches = self._decode(self.params, tok, caches, p)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            outs.append(tok)
            p = p + 1
        jax.block_until_ready(tok)
        decode_s = time.perf_counter() - t1
        return {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "tokens": B * new_tokens,
            "tok_per_s": B * new_tokens / max(decode_s, 1e-9),
            "output": np.concatenate([np.asarray(t) for t in outs], axis=1),
        }
