"""Batched serving runtime: prefill + decode with slot-based continuous
batching over fixed-shape KV caches.

A :class:`Server` owns B cache slots.  Requests (token prompts) queue up;
free slots prefill them (one jit'd prefill per admission, right-padded to
the slot's max length), and a single jit'd decode step advances ALL slots
one token per tick — finished slots (EOS or max tokens) are recycled for
queued requests.  This is the standard production serving shape (fixed
compiled programs, dynamic request flow around them).

Per-slot decode positions live in a vector so different slots can be at
different positions inside one compiled decode step; each slot's cache is
written at its own position via the models' cache update logic (which
takes scalar ``pos`` — slots share a position during lockstep decode, so
admission aligns: a fresh request's cache is padded to the current tick.
For heterogeneous positions the serve step falls back to per-slot decode.)
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterator, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.obs import MetricsRegistry
from repro.obs import current as _obs_current
from repro.runtime.batching import AdmissionQueue, LatencyStats

__all__ = ["Request", "Server"]


class _CounterView(Mapping):
    """Read-only mapping over a registry's ``server.*`` counters — the
    legacy ``Server.stats`` dict, now a view so the registry is the single
    source of truth."""

    _KEYS = ("prefills", "decode_ticks", "tokens_out")

    def __init__(self, registry: MetricsRegistry):
        self._registry = registry

    def __getitem__(self, key: str) -> int:
        if key not in self._KEYS:
            raise KeyError(key)
        return int(self._registry.counter(f"server.{key}").value)

    def __iter__(self) -> Iterator[str]:
        return iter(self._KEYS)

    def __len__(self) -> int:
        return len(self._KEYS)


@dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    generated: list = field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0      # END-TO-END: admission -> last token emitted


class Server:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 256, temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self._prefill = jax.jit(
            lambda p, toks: lm.prefill(p, cfg, toks, max_len)
        )
        self._decode = jax.jit(
            lambda p, tok, caches, pos: lm.decode_step(p, cfg, tok, caches, pos)
        )
        #: the server's own always-on registry (prefills/ticks/tokens live
        #: here; merge into an ambient one with ``ob.registry.merge``)
        self.metrics = MetricsRegistry()
        #: legacy read-only view kept for existing callers/tests
        self.stats = _CounterView(self.metrics)
        self.latency = LatencyStats()

    def _sample(self, logits: jax.Array, key) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits[0, -1]))
        return int(jax.random.categorical(key, logits[0, -1] / self.temperature))

    def generate(self, requests: list[Request],
                 max_slots: int | None = None) -> list[Request]:
        """Serve a list of requests with per-request caches (B=1 slots),
        batching decode ticks across active requests round-robin.

        Requests flow through the shared admission queue: at most
        ``max_slots`` are in flight at once (all of them when ``None``);
        a finished slot immediately admits the next queued request —
        the same continuous-batching shape as the what-if service.

        ``req.latency_s`` is END-TO-END (admission to last token), and
        ``stats["decode_ticks"]`` counts lockstep ticks — one per decode
        round, not one per active request per round.  (Both were wrong
        before: latency froze at prefill time and never saw decode, and
        the tick counter was really a decode-call counter.)
        """
        key = jax.random.PRNGKey(0)
        ob = _obs_current()
        queue: AdmissionQueue[Request] = AdmissionQueue(
            depth_gauge=self.metrics.gauge("server.queue_depth"))
        t_admit: dict[int, float] = {}
        for req in requests:
            t_admit[id(req)] = time.perf_counter()
            queue.put(req)
        slots = len(requests) if max_slots is None else max(1, max_slots)

        active: list[tuple[Request, dict, int]] = []
        while active or len(queue):
            # admission: fill free slots from the queue (prefill each)
            while len(active) < slots:
                req = queue.pop()
                if req is None:
                    break
                with ob.tracer.span("server.prefill", rid=req.rid,
                                    prompt_len=len(req.prompt)):
                    toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                    logits, caches, pos = self._prefill(self.params, toks)
                self.metrics.counter("server.prefills").inc()
                key, sub = jax.random.split(key)
                req.generated.append(self._sample(logits, sub))
                active.append((req, caches, int(pos)))

            # one lockstep decode tick over every unfinished slot
            ticked = False
            tokens_this_tick = 0
            for i, (req, caches, pos) in enumerate(active):
                if len(req.generated) >= req.max_new_tokens:
                    continue
                if not ticked:
                    self.metrics.counter("server.decode_ticks").inc()
                    ticked = True
                tok = jnp.asarray([[req.generated[-1]]], jnp.int32)
                logits, caches = self._decode(
                    self.params, tok, caches, jnp.asarray(pos, jnp.int32)
                )
                key, sub = jax.random.split(key)
                req.generated.append(self._sample(logits, sub))
                self.metrics.counter("server.tokens_out").inc()
                tokens_this_tick += 1
                active[i] = (req, caches, pos + 1)
            if ticked and ob.enabled:
                ob.tracer.counter("server", active_slots=len(active),
                                  queued=len(queue),
                                  tokens_per_tick=tokens_this_tick)

            # retire finished slots (freeing them for queued requests)
            still: list[tuple[Request, dict, int]] = []
            for req, caches, pos in active:
                if len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    req.latency_s = time.perf_counter() - t_admit[id(req)]
                    self.latency.record(req.latency_s)
                    self.metrics.histogram("server.latency_s").record(
                        req.latency_s)
                else:
                    still.append((req, caches, pos))
            active = still
        return requests

    def throughput_batch(self, prompts: np.ndarray, new_tokens: int) -> dict:
        """Fixed-batch generation (all slots in lockstep) — the serving
        benchmark path: one prefill + ``new_tokens`` decode steps for a
        whole (B, S) prompt batch."""
        B = prompts.shape[0]
        t0 = time.perf_counter()
        logits, caches, pos = self._prefill(
            self.params, jnp.asarray(prompts, jnp.int32)
        )
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        prefill_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        outs = [tok]
        p = pos
        for _ in range(new_tokens - 1):
            logits, caches = self._decode(self.params, tok, caches, p)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            outs.append(tok)
            p = p + 1
        jax.block_until_ready(tok)
        decode_s = time.perf_counter() - t1
        return {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "tokens": B * new_tokens,
            "tok_per_s": B * new_tokens / max(decode_s, 1e-9),
            "output": np.concatenate([np.asarray(t) for t in outs], axis=1),
        }
