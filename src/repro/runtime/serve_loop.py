"""Batched serving runtime: prefill + decode with slot-based continuous
batching over fixed-shape KV caches.

A :class:`Server` owns B cache slots.  Requests (token prompts) queue up;
free slots prefill them (one jit'd prefill per admission, right-padded to
the slot's max length), and a single jit'd decode step advances ALL slots
one token per tick — finished slots (EOS or max tokens) are recycled for
queued requests.  This is the standard production serving shape (fixed
compiled programs, dynamic request flow around them).

Per-slot decode positions live in a vector so different slots can be at
different positions inside one compiled decode step; each slot's cache is
written at its own position via the models' cache update logic (which
takes scalar ``pos`` — slots share a position during lockstep decode, so
admission aligns: a fresh request's cache is padded to the current tick.
For heterogeneous positions the serve step falls back to per-slot decode.)
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig

__all__ = ["Request", "Server"]


@dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    generated: list = field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class Server:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 256, temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self._prefill = jax.jit(
            lambda p, toks: lm.prefill(p, cfg, toks, max_len)
        )
        self._decode = jax.jit(
            lambda p, tok, caches, pos: lm.decode_step(p, cfg, tok, caches, pos)
        )
        self.stats = {"prefills": 0, "decode_ticks": 0, "tokens_out": 0}

    def _sample(self, logits: jax.Array, key) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits[0, -1]))
        return int(jax.random.categorical(key, logits[0, -1] / self.temperature))

    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve a list of requests with per-request caches (B=1 slots),
        batching decode ticks across active requests round-robin."""
        key = jax.random.PRNGKey(0)
        active: list[tuple[Request, dict, int]] = []
        for req in requests:
            t0 = time.perf_counter()
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, caches, pos = self._prefill(self.params, toks)
            self.stats["prefills"] += 1
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
            req.generated.append(nxt)
            req.latency_s = time.perf_counter() - t0
            active.append((req, caches, int(pos)))

        # lockstep decode ticks
        done = 0
        while done < len(active):
            done = 0
            for i, (req, caches, pos) in enumerate(active):
                if req.done or len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    done += 1
                    continue
                tok = jnp.asarray([[req.generated[-1]]], jnp.int32)
                logits, caches = self._decode(
                    self.params, tok, caches, jnp.asarray(pos, jnp.int32)
                )
                self.stats["decode_ticks"] += 1
                key, sub = jax.random.split(key)
                nxt = self._sample(logits, sub)
                req.generated.append(nxt)
                self.stats["tokens_out"] += 1
                active[i] = (req, caches, pos + 1)
        return [a[0] for a in active]

    def throughput_batch(self, prompts: np.ndarray, new_tokens: int) -> dict:
        """Fixed-batch generation (all slots in lockstep) — the serving
        benchmark path: one prefill + ``new_tokens`` decode steps for a
        whole (B, S) prompt batch."""
        B = prompts.shape[0]
        t0 = time.perf_counter()
        logits, caches, pos = self._prefill(
            self.params, jnp.asarray(prompts, jnp.int32)
        )
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        prefill_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        outs = [tok]
        p = pos
        for _ in range(new_tokens - 1):
            logits, caches = self._decode(self.params, tok, caches, p)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            outs.append(tok)
            p = p + 1
        jax.block_until_ready(tok)
        decode_s = time.perf_counter() - t1
        return {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "tokens": B * new_tokens,
            "tok_per_s": B * new_tokens / max(decode_s, 1e-9),
            "output": np.concatenate([np.asarray(t) for t in outs], axis=1),
        }
