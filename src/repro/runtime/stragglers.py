"""Straggler detection + speculative re-dispatch (Hadoop-style, pure).

Hadoop's speculative execution launches a duplicate of a task whose
*progress rate* lags the fleet; the first copy to finish wins.  On a TPU
pod the analogous unit is a *host* whose step time lags (failing HBM,
thermal throttling, a noisy neighbor on the host NIC): the synchronous
collective makes EVERY chip wait for the slowest, so one straggler
throttles the whole job — the same reason one slow map task delays every
reducer past the slowstart point.

The decision function is pure (unit-tested), consumed by two users:

* the **task-scheduler simulator** (``core/hadoop/simulator.py``) for
  wave-level what-if analysis — directly the paper's §5 mechanism;
* the **Trainer**, which tracks per-step (per-host at scale) times and
  surfaces `should_speculate`-positive hosts so an external orchestrator
  can re-dispatch their shard (re-assign the host's data shard + reshard,
  which elastic restore makes possible; on this single-host container the
  hook fires a callback and is failure-injection tested).
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["should_speculate", "StragglerDetector"]


def should_speculate(
    progress_rate: float,
    fleet_mean_rate: float,
    fleet_std_rate: float,
    *,
    remaining_work: float,
    est_fresh_time: float,
    slowness_sigmas: float = 1.0,
    min_remaining_ratio: float = 1.2,
) -> bool:
    """Hadoop's LATE-style heuristic.

    Launch a speculative copy iff the task is (a) significantly slower than
    the fleet — ``rate < mean - k*std`` — and (b) restarting is actually
    cheaper: projected remaining time exceeds a fresh execution estimate by
    ``min_remaining_ratio``.
    """
    if progress_rate <= 0:
        return True
    slow = progress_rate < fleet_mean_rate - slowness_sigmas * fleet_std_rate
    projected_remaining = remaining_work / progress_rate
    worth_it = projected_remaining > min_remaining_ratio * est_fresh_time
    return bool(slow and worth_it)


@dataclass
class StragglerDetector:
    """Per-worker EWMA step times + outlier flagging for the train loop."""

    alpha: float = 0.2
    sigmas: float = 3.0
    warmup: int = 5
    rel_margin: float = 0.5   # never flag < (1+rel_margin) x EWMA (var->0 guard)
    _ewma: dict = field(default_factory=dict)
    _var: dict = field(default_factory=dict)
    _count: dict = field(default_factory=dict)

    def observe(self, worker: str, step_time: float) -> bool:
        """Record a step time; True when this worker looks like a straggler."""
        n = self._count.get(worker, 0)
        mu = self._ewma.get(worker, step_time)
        var = self._var.get(worker, 0.0)
        is_straggler = False
        if n >= self.warmup:
            sd = max(var, 1e-12) ** 0.5
            thr = mu + max(self.sigmas * sd, self.rel_margin * mu)
            is_straggler = step_time > thr
        # EWMA update (skip updating stats with the outlier itself)
        if not is_straggler:
            delta = step_time - mu
            mu = mu + self.alpha * delta
            var = (1 - self.alpha) * (var + self.alpha * delta * delta)
        self._ewma[worker] = mu
        self._var[worker] = var
        self._count[worker] = n + 1
        return is_straggler

    def fleet_stats(self) -> tuple[float, float]:
        if not self._ewma:
            return 0.0, 0.0
        vals = list(self._ewma.values())
        mean = sum(vals) / len(vals)
        var = sum((v - mean) ** 2 for v in vals) / max(len(vals), 1)
        return mean, var ** 0.5
