"""Gradient calibration of the paper's cost model against observed costs.

The paper (§4, Table 3) obtains its cost factors from micro-benchmarks; the
Starfish-style profiler (:mod:`repro.mapreduce.profiler`) fits them with a
per-phase linear least squares.  Both treat the closed-form model as a
black box.  This module uses the model *itself* as the regression function:
because every equation in :func:`repro.core.hadoop.model.job_model_jnp` is
branch-free JAX (with straight-through round counts and double-``where``
guarded divisions), ``jax.grad`` of the predicted total cost w.r.t. any
Table-2/3 parameter is exact — so a handful of observed ``(JobSpec, cost)``
pairs suffice where sample-hungry polynomial regressions (Rizvandi et al.,
arXiv 1303.3632 / 1203.0651) need hundreds of training runs.

Parameters are optimized in an unconstrained space via the per-axis
transforms declared on :class:`repro.spec.Axis` metadata
(:meth:`Axis.relax` / :meth:`Axis.project`): positivity and bound
constraints hold by construction at every optimizer step, and cost factors
spanning 1e-9..1e-7 s/byte are fitted on a well-conditioned log scale.
The optimizer is the in-tree AdamW (:mod:`repro.optim.adamw`) with weight
decay pinned to zero — decay would drag physical constants toward zero.
"""

from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core.hadoop.params import CostFactors
from repro.obs import current as _obs_current
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.spec import CalibrationReport, JobSpec, hadoop_space

__all__ = [
    "Observation",
    "build_loss_fn",
    "calibrate",
    "observations_from_pairs",
    "COST_FACTOR_NAMES",
]

logger = logging.getLogger("repro.calib")

#: the Table-3 names — the default fit target.
COST_FACTOR_NAMES: tuple[str, ...] = tuple(CostFactors.__dataclass_fields__)


@dataclass(frozen=True)
class Observation:
    """One observed execution: a fully-specified job and its measured cost.

    ``cost`` is the observed total job cost in seconds — an engine wall
    time (:class:`repro.mapreduce.profiler.MeasuredRun`), a simulator
    trace total, or a replayed historical measurement.  ``weight`` scales
    this observation's contribution to the fit loss.
    """

    spec: JobSpec
    cost: float
    weight: float = 1.0

    def __post_init__(self):
        if not (self.cost > 0.0):
            raise ValueError(
                f"observation cost must be positive, got {self.cost!r}")


def observations_from_pairs(
    pairs: Iterable[tuple[JobSpec, float]]
) -> list[Observation]:
    """Replay adapter: ``(JobSpec, observed cost)`` pairs -> observations."""
    return [Observation(spec=s, cost=float(c)) for s, c in pairs]


def _stack_configs(observations: Sequence[Observation]):
    import jax.numpy as jnp

    packed = [o.spec.pack() for o in observations]
    return {k: jnp.stack([p[k] for p in packed]) for k in packed[0]}


def build_loss_fn(cols, names: Sequence[str], y, w, space=None):
    """Build the calibration loss ``u -> weighted mean squared rel. error``.

    ``cols`` is a stacked packed-config dict, ``names`` the axes being fitted
    (``u`` maps each to its unconstrained value), ``y``/``w`` the observed
    costs and weights.  Module-level (rather than a closure inside
    :func:`calibrate`) so ``repro.analysis`` can trace the exact loss that
    calibration differentiates.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.hadoop.model import job_model_jnp

    space = hadoop_space() if space is None else space
    names = list(names)

    def loss_fn(u):
        cfg = dict(cols)
        for n in names:
            cfg[n] = jnp.broadcast_to(space[n].project(u[n]), y.shape)
        out = job_model_jnp(cfg)
        rel = (out["j_totalCost"] - y) / y
        wv = w * jax.lax.stop_gradient(out["valid"])
        return jnp.sum(wv * rel * rel) / jnp.maximum(jnp.sum(wv), 1e-12)

    return loss_fn


def calibrate(
    observations: Sequence[Observation],
    params: Sequence[str] | None = None,
    *,
    init: Mapping[str, float] | None = None,
    steps: int = 400,
    peak_lr: float = 0.1,
    grad_clip_norm: float = 10.0,
    history_every: int = 10,
) -> CalibrationReport:
    """Fit the named parameters to the observed costs via ``jax.grad``.

    ``params`` may name any float axis of :func:`repro.spec.hadoop_space`
    that the packed config carries — all of ``CostFactors`` by default,
    optionally ``ProfileStats`` fields.  Starting values come from ``init``
    or, per parameter, from the first observation's spec.  The loss is the
    weighted mean *squared relative error* of the model's predicted total
    (Eq. 98) against the observed cost; rows the closed forms cannot model
    (``valid == 0``) are weighted out rather than poisoning the fit.

    Returns a :class:`repro.spec.CalibrationReport`; the fitted values are
    in-domain by construction (axis ``project`` transforms).  The reported
    parameters are the best seen along the trajectory, never worse on the
    fit set than the starting point.
    """
    import jax
    import jax.numpy as jnp

    if not observations:
        raise ValueError("calibrate() needs at least one observation")
    names = list(params) if params is not None else list(COST_FACTOR_NAMES)
    if not names:
        raise ValueError("calibrate() needs at least one parameter to fit")
    space = hadoop_space()
    cols = _stack_configs(observations)
    for n in names:
        ax = space[n]
        if n not in cols:
            raise KeyError(f"{n!r} is not a packed config key")
        if ax.kind != "float":
            raise ValueError(
                f"axis {n!r} is {ax.kind}; only float parameters are "
                "calibratable (int/bool knobs are search axes, not factors)")

    y = jnp.asarray([o.cost for o in observations], dtype=jnp.float64)
    w = jnp.asarray([o.weight for o in observations], dtype=jnp.float64)

    init = dict(init or {})
    start = {
        n: float(init.get(n, observations[0].spec[n])) for n in names
    }
    u0 = {n: jnp.asarray(space[n].relax(start[n])) for n in names}

    # Invalid rows are weighted out of the loss below; an *all*-invalid set
    # would silently "fit" a zero loss over zero rows, so fail loudly here.
    from repro.core.hadoop.model import job_model_jnp

    valid0 = np.asarray(job_model_jnp(cols)["valid"])
    n_valid = int(valid0.sum())
    if n_valid == 0:
        raise ValueError(
            f"none of the {len(observations)} observations is valid under "
            "the closed-form model (merge-domain constraints, see "
            "repro.spec.invalid_reasons) — there is nothing to fit"
        )
    if n_valid < len(observations):
        logger.warning(
            "calibrate: %d of %d observations are invalid under the "
            "closed-form model and will be weighted out of the fit",
            len(observations) - n_valid, len(observations),
        )

    loss_fn = build_loss_fn(cols, names, y, w, space)

    opt_cfg = AdamWConfig(
        peak_lr=peak_lr,
        warmup_steps=max(1, steps // 20),
        total_steps=steps,
        weight_decay=0.0,            # decay would pull physical constants to 0
        grad_clip_norm=grad_clip_norm,
    )
    state = adamw_init(u0)

    @jax.jit
    def step(u, state):
        loss, grads = jax.value_and_grad(loss_fn)(u)
        new_u, new_state, metrics = adamw_update(grads, state, u, opt_cfg)
        return loss, metrics["grad_norm"], new_u, new_state

    u = u0
    initial_loss = float(loss_fn(u0))
    best_loss, best_u = initial_loss, u0
    history: list[float] = [initial_loss]
    gnorm_history: list[float] = []
    ob = _obs_current()
    for i in range(steps):
        # `loss` is evaluated at the pre-update params `u` of this step
        loss, gnorm, new_u, state = step(u, state)
        fl = float(loss)
        if np.isfinite(fl) and fl < best_loss:
            best_loss, best_u = fl, u
        u = new_u
        if (i + 1) % max(1, history_every) == 0:
            history.append(fl)
            gnorm_history.append(float(gnorm))
            if ob.enabled:
                ob.tracer.counter("calibration", loss=fl,
                                  grad_norm=float(gnorm))
    final_loss = float(loss_fn(u))
    if np.isfinite(final_loss) and final_loss < best_loss:
        best_loss, best_u = final_loss, u

    fitted = {n: float(space[n].project(best_u[n])) for n in names}
    # loss/grad evaluations spent: one per step plus the two endpoint
    # loss_fn calls (the validity probe above is not a loss evaluation)
    n_model_evals = steps + 2
    report = CalibrationReport(
        fitted=fitted,
        initial=start,
        loss=best_loss,
        initial_loss=initial_loss,
        steps=steps,
        n_observations=len(observations),
        loss_history=tuple(history),
        grad_norm_history=tuple(gnorm_history),
        n_model_evals=n_model_evals,
    )
    if ob.enabled:
        ob.registry.counter("calib.runs").inc()
        ob.registry.counter("calib.steps").inc(steps)
        ob.registry.counter("calib.model_evals").inc(n_model_evals)
        ob.registry.gauge("calib.final_loss").set(best_loss)
    logger.info("calibrate: %s", report.summary())
    return report
