"""repro.calib — gradient calibration of cost models against observations.

The differentiable half of the paper's workflow: where ``repro.search``
asks "which config is cheapest given the model", this package asks "which
model parameters explain the observed costs".  Built entirely on the
existing stack — ``jax.grad`` through the branch-free job model,
:mod:`repro.optim` AdamW, and the per-axis bound transforms declared on
:class:`repro.spec.Axis` — and returns a
:class:`repro.spec.CalibrationReport`.

Entry points: :func:`calibrate` (the general fit), :class:`Observation`
(one ``(JobSpec, measured cost)`` pair), and the profiler adapter
:func:`repro.mapreduce.profiler.fit_cost_factors_autodiff` which
initializes at the per-phase least-squares solution and refines it on the
exact objective the paper reports (relative error of the Eq. 98 total).
"""

from .fit import COST_FACTOR_NAMES, Observation, calibrate, observations_from_pairs

__all__ = [
    "COST_FACTOR_NAMES",
    "Observation",
    "calibrate",
    "observations_from_pairs",
]
