"""Zero-dependency metrics core: counters, gauges, histograms, a registry.

The observability layer's number side.  Everything here is plain stdlib —
no jax, no numpy — so instrumented modules can import it without touching
the accelerator stack, and a :class:`MetricsRegistry` can live inside a
worker thread, a benchmark process, or a unit test with no setup.

Design rules (the contract the instrumented hot paths rely on):

* **Off is free.**  :data:`NULL_REGISTRY` hands out shared no-op
  instruments; ``NULL_REGISTRY.counter("x").inc()`` is a constant-time
  method call on a singleton that allocates nothing and takes no lock.
  Instrumentation that must skip even that guards on
  ``registry.enabled`` / ``Observability.enabled``.
* **Thread-safe.**  Real instruments take one uncontended lock per op;
  the registry locks only on instrument *creation* (get-or-create), so
  steady-state updates never contend on the registry itself.
* **Mergeable.**  ``MetricsRegistry.merge`` folds another registry (e.g. a
  per-worker one) into this one: counters add, gauges last-write-win,
  histograms pool their samples — the same semantics as
  :meth:`repro.runtime.batching.LatencyStats.merge`.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Iterable, Mapping

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "percentile_interp",
]


def percentile_interp(ordered: Iterable[float], p: float) -> float:
    """Exact linear-interpolated percentile of an already-sorted sequence.

    The one percentile implementation of the repo (histograms here,
    :class:`~repro.runtime.batching.LatencyStats`): ``rank = (n-1) * p/100``
    interpolated between the two neighbouring order statistics — identical
    to ``numpy.percentile(..., method="linear")`` but with well-defined
    small-sample behavior:

    * empty input  -> ``0.0`` (nothing observed, not ``nan``);
    * one sample   -> that sample for every ``p``;
    * an integral rank returns the order statistic *exactly* (no ``0 * inf``
      corner when the other neighbour is infinite);
    * equal neighbours (both ``inf`` included) return the common value.
    """
    vals = ordered if isinstance(ordered, (list, tuple)) else list(ordered)
    n = len(vals)
    if n == 0:
        return 0.0
    if n == 1:
        return float(vals[0])
    if p <= 0.0:
        return float(vals[0])
    if p >= 100.0:
        return float(vals[-1])
    rank = (n - 1) * (p / 100.0)
    lo = int(math.floor(rank))
    hi = min(lo + 1, n - 1)
    frac = rank - lo
    a, b = float(vals[lo]), float(vals[hi])
    if frac == 0.0 or a == b:
        return a
    return a + (b - a) * frac


class Counter:
    """A monotonically-increasing count (events, rows, compiles)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease (n={n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """A point-in-time value (queue depth, configs/s, padding fraction)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, d: float) -> None:
        with self._lock:
            self._value += float(d)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """A streaming sample distribution (latencies, chunk durations).

    Keeps raw samples (observability cardinalities here are small — one
    entry per chunk/query/step, not per config row), so percentiles are
    exact and merges are lossless.
    """

    __slots__ = ("name", "_samples", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._samples: list[float] = []
        self._lock = threading.Lock()

    def record(self, v: float) -> None:
        with self._lock:
            self._samples.append(float(v))

    def samples(self) -> list[float]:
        with self._lock:
            return list(self._samples)

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._samples)

    def percentile(self, p: float) -> float:
        with self._lock:
            return percentile_interp(sorted(self._samples), p)

    def summary(self) -> dict[str, float]:
        with self._lock:
            s = sorted(self._samples)
        n = len(s)
        return {
            "count": n,
            "sum": math.fsum(s),
            "mean": math.fsum(s) / n if n else 0.0,
            "min": s[0] if n else 0.0,
            "max": s[-1] if n else 0.0,
            "p50": percentile_interp(s, 50.0),
            "p99": percentile_interp(s, 99.0),
        }


class MetricsRegistry:
    """A named collection of instruments with snapshot/merge/JSON export.

    ``counter(name)`` / ``gauge(name)`` / ``histogram(name)`` get-or-create;
    asking for an existing name with a different instrument kind raises, so
    metric names cannot silently change meaning between call sites.
    """

    #: real registries record; the null registry overrides this to False so
    #: hot paths can skip even cheap bookkeeping with one attribute check.
    enabled: bool = True

    def __init__(self) -> None:
        self._instruments: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name)
            elif type(inst) is not cls:
                raise TypeError(
                    f"metric {name!r} is a {type(inst).__name__}, "
                    f"not a {cls.__name__}"
                )
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> dict[str, object]:
        """Flat ``{name: value}`` view; histograms expand to their summary
        dict.  Plain JSON-serializable types only."""
        with self._lock:
            items = list(self._instruments.items())
        out: dict[str, object] = {}
        for name, inst in sorted(items):
            if isinstance(inst, Histogram):
                out[name] = inst.summary()
            else:
                v = inst.value
                out[name] = int(v) if float(v).is_integer() else v
        return out

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold ``other`` (e.g. a per-worker registry) into this one.

        Counters add, gauges take the other's value (last write wins),
        histograms pool samples.  Returns ``self`` for chaining.
        """
        with other._lock:
            items = list(other._instruments.items())
        for name, inst in items:
            if isinstance(inst, Counter):
                self.counter(name).inc(inst.value)
            elif isinstance(inst, Gauge):
                self.gauge(name).set(inst.value)
            elif isinstance(inst, Histogram):
                mine = self.histogram(name)
                for v in inst.samples():
                    mine.record(v)
        return self

    def to_json(self, *, indent: int | None = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)


class _NullInstrument:
    """Shared do-nothing counter/gauge/histogram (the off switch)."""

    __slots__ = ()
    name = "null"
    value = 0.0
    count = 0

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def add(self, d: float) -> None:
        pass

    def record(self, v: float) -> None:
        pass

    def samples(self) -> list[float]:
        return []

    def percentile(self, p: float) -> float:
        return 0.0

    def summary(self) -> dict[str, float]:
        return {}


_NULL_INSTRUMENT = _NullInstrument()


class _NullRegistry(MetricsRegistry):
    """The default registry: every instrument is the shared no-op."""

    enabled = False

    def __init__(self) -> None:
        super().__init__()

    def _get(self, name: str, cls):
        return _NULL_INSTRUMENT

    def snapshot(self) -> dict[str, object]:
        return {}

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        return self


#: process-wide off switch — handed out by ``repro.obs.current()`` until an
#: ``observe()`` context installs a live registry.
NULL_REGISTRY: MetricsRegistry = _NullRegistry()


def _is_mapping(x) -> bool:
    return isinstance(x, Mapping)
