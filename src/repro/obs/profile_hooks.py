"""Bridges into jax's own instrumentation: profiler capture + compile events.

Two hooks, both strictly optional and gated on the ambient observability:

* :func:`profile_capture` — wrap a block in ``jax.profiler`` trace capture
  (TensorBoard-loadable) *and* an obs span, so device-level profiles line
  up with the host-side trace.
* :func:`install_compile_listener` — subscribe to ``jax.monitoring``
  backend-compile duration events and forward them to whatever
  Observability is ambient *at event time*.  jax listeners are global and
  effectively permanent, so we install exactly one process-wide dispatcher
  that is a no-op while observability is off.
"""

from __future__ import annotations

import contextlib
from typing import Iterator

__all__ = ["profile_capture", "install_compile_listener"]

_listener_installed = False

#: jax.monitoring event names worth surfacing (backend compile time is the
#: dominant one-off cost this repo cares about — one compile per key-set).
_EVENTS_OF_INTEREST = (
    "/jax/core/compile/backend_compile_duration",
    "/jax/core/compile/jaxpr_to_mlir_module_duration",
)


def _dispatch(event: str, duration_secs: float, **kwargs) -> None:
    from repro.obs import current

    ob = current()
    if not ob.enabled:
        return
    if not any(event.startswith(e) for e in _EVENTS_OF_INTEREST):
        return
    short = event.rsplit("/", 1)[-1]
    ob.registry.counter(f"jax.{short}").inc()
    ob.registry.histogram(f"jax.{short}_s").record(duration_secs)
    ob.tracer.instant(f"jax:{short}", scope="p", duration_s=duration_secs)


def install_compile_listener() -> bool:
    """Install the process-wide jax.monitoring dispatcher (idempotent).

    Returns True if the listener is active (now or from an earlier call),
    False when jax.monitoring is unavailable.
    """
    global _listener_installed
    if _listener_installed:
        return True
    try:
        from jax import monitoring
    except Exception:  # pragma: no cover - jax always present in this repo
        return False
    register = getattr(monitoring, "register_event_duration_secs_listener", None)
    if register is None:  # pragma: no cover - older/newer jax
        return False
    register(_dispatch)
    _listener_installed = True
    return True


@contextlib.contextmanager
def profile_capture(logdir: str) -> Iterator[None]:
    """Capture a ``jax.profiler`` trace for the block into ``logdir``.

    Pairs the device-level profile with a span on the ambient tracer so the
    two timelines can be cross-referenced.  Loads in TensorBoard or
    Perfetto (``logdir/plugins/profile/...``).
    """
    import jax

    from repro.obs import current

    ob = current()
    with ob.tracer.span("jax.profiler.capture", logdir=logdir):
        jax.profiler.start_trace(logdir)
        try:
            yield
        finally:
            jax.profiler.stop_trace()
