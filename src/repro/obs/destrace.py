"""Turn a cluster DES result into a Perfetto swimlane trace.

:func:`workload_trace` renders a :class:`~repro.cluster.sched.WorkloadResult`
as Chrome trace events on a **virtual-time** clock (1 simulated second =
1e6 trace µs, so Perfetto's ruler reads directly in simulated seconds):

* one process per node, one thread ("m0", "m1", ... / "r0", ...) per
  occupied slot lane — tasks pack into lanes exactly as they occupied
  slots, so the view is the cluster's Gantt chart;
* every task is an ``X`` span; *inside* it, sub-spans carve the task into
  the paper's phase vocabulary (:class:`repro.spec.report.PhaseBreakdown`):
  maps split into ``map_read / map_spill / map_merge / map_write``
  proportional to the job class's §2-§3 per-phase costs; reduces show the
  recorded ``network`` shuffle transfer (overlapping the job's maps) then
  ``shuffle / reduce_merge / reduce_write`` carved from the §4 costs;
* kills are instants (``preempt`` / ``failure`` / ``superseded`` /
  ``reclaim``) at the kill time — a spot reclamation renders under its
  own name, distinct from a scheduler preemption or a node failure;
  speculative copies are flagged in the span args;
* a "jobs" process holds one lane per job (``queued`` then ``running``),
  and a ``cluster`` counter track plots running maps/reduces over time;
* elastic/priced fleets (:mod:`repro.cloud`) add per-node
  ``provisioned`` / ``offline`` instants at capacity-episode boundaries
  plus ``fleet`` (online nodes) and ``spend`` (cumulative dollars)
  counter tracks swept from ``WorkloadResult.node_online``;
* DAG traces draw Perfetto flow arrows (``ph: s``/``f``) from each
  parent's stage boundary (``stage done`` / ``map done`` instant) to the
  child's ``released`` instant on the jobs lanes — barrier and slowstart
  edges are labeled by kind;
* non-flat topologies (``cluster.topology``) add a ``rack uplink util``
  counter track: per-rack cross-link utilization swept from the reduce
  records' shuffle spans against the rack's uplink capacity.

Pure host-side post-processing: reads the result's records, touches no jax.
"""

from __future__ import annotations

import functools

from repro.obs.trace import Tracer

__all__ = ["workload_trace", "SIM_SECOND_US"]

#: virtual-time scale: one simulated second rendered as this many trace µs.
SIM_SECOND_US = 1e6

_PID_JOBS = 2
_PID_NODE0 = 10          # node k -> pid _PID_NODE0 + k
_TID_REDUCE0 = 1000      # reduce lane k -> tid _TID_REDUCE0 + k


@functools.lru_cache(maxsize=256)
def _phase_fracs(jc) -> tuple[tuple[tuple[str, float], ...],
                              tuple[tuple[str, float], ...]]:
    """((map phase, fraction), ...), ((reduce phase, fraction), ...) for a
    :class:`~repro.cluster.workload.JobClass` — §2-§4 per-phase costs
    normalized within the task, the split the DES's scalar task costs hide."""
    from repro.core.hadoop.ref import job_model

    jm = job_model(jc.params, jc.stats, jc.costs)
    m = jm.map
    map_parts = (
        ("map_read", m.ioReadCost + m.cpuReadCost),
        ("map_spill", m.ioSpillCost + m.cpuSpillCost),
        ("map_merge", m.ioMergeCost + m.cpuMergeCost),
        ("map_write", m.ioMapWriteCost + m.cpuMapWriteCost),
    )
    r = jm.reduce
    red_parts = (
        ("shuffle", r.ioShuffleCost + r.cpuShuffleCost),
        ("reduce_merge", r.ioSortCost + r.cpuSortCost),
        ("reduce_write", r.ioWriteCost + r.cpuWriteCost),
    )

    def norm(parts):
        total = sum(v for _, v in parts)
        if total <= 0:
            return ()
        return tuple((k, v / total) for k, v in parts if v > 0)

    return norm(map_parts), norm(red_parts)


def _carve(tracer: Tracer, pid: int, tid: int, t0: float, t1: float,
           fracs) -> None:
    """Emit sub-spans splitting [t0, t1] (virtual s) by (name, frac) pairs."""
    span = t1 - t0
    if span <= 0 or not fracs:
        return
    at = t0
    for i, (name, frac) in enumerate(fracs):
        dur = span * frac if i < len(fracs) - 1 else t1 - at
        tracer.complete(name, at * SIM_SECOND_US, dur * SIM_SECOND_US,
                        pid=pid, tid=tid)
        at += dur


def workload_trace(trace, result, cluster, *, tracer: Tracer | None = None
                   ) -> Tracer:
    """Emit ``result`` (from :func:`repro.cluster.sched.simulate_workload`
    of ``trace`` on ``cluster``) as a virtual-time Perfetto swimlane.

    ``tracer`` defaults to the ambient one (:func:`repro.obs.current`); a
    fresh :class:`Tracer` is created when the ambient is the null tracer,
    so ``workload_trace(...).write(path)`` works standalone.  Returns the
    tracer written to.
    """
    if tracer is None:
        from repro.obs import current

        tracer = current().tracer
        if not tracer.enabled:
            tracer = Tracer()

    klass_of = {a.job_id: a.klass for a in trace.arrivals}
    # autoscaled extras live past cluster.num_nodes in node_online order
    n_nodes = max(1, cluster.num_nodes, len(result.node_online))
    for nd in range(n_nodes):
        tracer.process_name(_PID_NODE0 + nd, f"node {nd}")
    tracer.process_name(_PID_JOBS, "jobs")

    # ---- slot-lane packing: records reoccupy lanes as they did slots ----
    recs = sorted(result.records, key=lambda r: (r.start, r.end))
    lane_busy: dict[tuple[int, str], list[float]] = {}
    lanes_used: dict[tuple[int, str], int] = {}

    def lane_for(rec) -> int:
        key = (rec.node, rec.kind)
        ends = lane_busy.setdefault(key, [])
        for i, e in enumerate(ends):
            if e <= rec.start + 1e-12:
                ends[i] = rec.end
                return i
        ends.append(rec.end)
        lanes_used[key] = len(ends)
        return len(ends) - 1

    for rec in recs:
        lane = lane_for(rec)
        pid = _PID_NODE0 + rec.node
        tid = lane if rec.kind == "map" else _TID_REDUCE0 + lane
        jc = klass_of.get(rec.job_id)
        name = f"{jc.name if jc else 'job'}#{rec.job_id} {rec.kind}[{rec.index}]"
        args = {"job": rec.job_id, "index": rec.index}
        if rec.speculative:
            args["speculative"] = 1
        if rec.killed:
            args["killed"] = rec.kill_reason or "killed"
        tracer.complete(name, rec.start * SIM_SECOND_US,
                        (rec.end - rec.start) * SIM_SECOND_US,
                        pid=pid, tid=tid, **args)
        if rec.killed:
            tracer.instant(rec.kill_reason or "killed",
                           ts=rec.end * SIM_SECOND_US, pid=pid, tid=tid,
                           job=rec.job_id, index=rec.index)
            continue
        if jc is None:
            continue
        map_fracs, red_fracs = _phase_fracs(jc)
        if rec.kind == "map":
            _carve(tracer, pid, tid, rec.start, rec.end, map_fracs)
        else:
            # the recorded network transfer overlaps the job's maps; the
            # §4 shuffle/merge/write work fills the rest of the span
            work_start = rec.start
            if rec.shuffle_end > rec.start + 1e-12:
                tracer.complete("network", rec.start * SIM_SECOND_US,
                                (rec.shuffle_end - rec.start) * SIM_SECOND_US,
                                pid=pid, tid=tid)
                work_start = rec.shuffle_end
            _carve(tracer, pid, tid, work_start, rec.end, red_fracs)

    for (node, kind), n in sorted(lanes_used.items()):
        for lane in range(n):
            tid = lane if kind == "map" else _TID_REDUCE0 + lane
            tracer.thread_name(_PID_NODE0 + node, tid,
                               f"{kind[0]}{lane}",
                               sort_index=tid)

    # ---- per-job lanes: queued then running ----
    for js in result.jobs:
        tid = js.job_id
        tracer.thread_name(_PID_JOBS, tid, f"job {js.job_id} {js.name}",
                           sort_index=tid)
        if js.first_launch != float("inf"):
            tracer.complete("queued", js.submit_time * SIM_SECOND_US,
                            (js.first_launch - js.submit_time) * SIM_SECOND_US,
                            pid=_PID_JOBS, tid=tid)
            if js.finish != float("inf"):
                tracer.complete(
                    "running", js.first_launch * SIM_SECOND_US,
                    (js.finish - js.first_launch) * SIM_SECOND_US,
                    pid=_PID_JOBS, tid=tid,
                    n_maps=js.n_maps, n_reduces=js.n_reduces)

    # ---- DAG edges: flow arrows between stage-boundary instants ----
    stats_of = {js.job_id: js for js in result.jobs}
    flow_id = 0
    for a in trace.arrivals:
        child = stats_of.get(a.job_id)
        if child is None or child.first_launch == float("inf"):
            continue
        for parent_id, kind in a.deps:
            parent = stats_of.get(parent_id)
            if parent is None:
                continue
            t_rel = parent.map_finish if kind == "slowstart" else parent.finish
            if t_rel == float("inf"):
                continue
            boundary = "map done" if kind == "slowstart" else "stage done"
            tracer.instant(boundary, ts=t_rel * SIM_SECOND_US,
                           pid=_PID_JOBS, tid=parent_id,
                           job=parent_id, edge=kind)
            tracer.instant("released", ts=child.submit_time * SIM_SECOND_US,
                           pid=_PID_JOBS, tid=a.job_id,
                           job=a.job_id, parent=parent_id, edge=kind)
            flow_id += 1
            # raw Perfetto flow pair: the arrow from the parent's stage
            # boundary to the child's release on the jobs lanes
            tracer.event({"ph": "s", "id": flow_id, "cat": "dag",
                          "name": kind, "pid": _PID_JOBS, "tid": parent_id,
                          "ts": t_rel * SIM_SECOND_US})
            tracer.event({"ph": "f", "bp": "e", "id": flow_id, "cat": "dag",
                          "name": kind, "pid": _PID_JOBS, "tid": a.job_id,
                          "ts": child.submit_time * SIM_SECOND_US})

    # ---- per-rack cross-link utilization (topology-aware shuffles) ----
    topo = getattr(cluster, "topology", None)
    if topo is not None and not topo.is_flat:
        # each reduce's [start, shuffle_end] span is a cross-rack flow into
        # its rack; utilization = fair-share demand / uplink capacity
        rack_deltas: dict[float, list[float]] = {}
        for rec in recs:
            if rec.kind != "reduce" or rec.shuffle_end <= rec.start + 1e-12:
                continue
            rack = topo.rack_of(rec.node)
            d0 = rack_deltas.setdefault(rec.start, [0.0] * topo.num_racks)
            d1 = rack_deltas.setdefault(rec.shuffle_end,
                                        [0.0] * topo.num_racks)
            d0[rack] += 1.0
            d1[rack] -= 1.0
        live = [0.0] * topo.num_racks
        cap = topo.rack_capacity
        xr = topo.cross_frac
        for t in sorted(rack_deltas):
            live = [a + b for a, b in zip(live, rack_deltas[t])]
            util = {
                f"rack{r}": (min(1.0, xr * live[r] / cap) if cap > 0
                             and cap != float("inf") else 0.0)
                for r in range(topo.num_racks)
            }
            tracer.counter("rack uplink util", ts=t * SIM_SECOND_US,
                           pid=_PID_JOBS, **util)

    # ---- running-task counter track (event sweep over live records) ----
    deltas: dict[float, list[int]] = {}
    for rec in recs:
        d0 = deltas.setdefault(rec.start, [0, 0])
        d1 = deltas.setdefault(rec.end, [0, 0])
        k = 0 if rec.kind == "map" else 1
        d0[k] += 1
        d1[k] -= 1
    m = r = 0
    for t in sorted(deltas):
        dm, dr = deltas[t]
        m += dm
        r += dr
        tracer.counter("cluster running", ts=t * SIM_SECOND_US,
                       pid=_PID_JOBS, maps=m, reduces=r)

    # ---- elastic fleet: capacity episodes, fleet-size + spend tracks ----
    episodes = result.node_online
    table = cluster.node_table()
    priced = any(row[2] > 0 for row in table)
    elastic = (len(episodes) > len(table)
               or any(len(eps) != 1 for eps in episodes)
               or any(s > 0 for eps in episodes for s, _ in eps))
    if episodes and (priced or elastic):
        span = result.makespan
        # extras (nodes past the base table) clone the slowest class
        extra_price = table[-1][2] if table else 0.0
        events: list[tuple[float, int, float]] = []
        for nd, eps in enumerate(episodes):
            price = table[nd][2] if nd < len(table) else extra_price
            is_extra = nd >= len(table)
            for s0, e0 in eps:
                events.append((s0, 1, price))
                events.append((e0, -1, price))
                if s0 > 0:     # replacement or autoscale provision
                    tracer.instant("provisioned", ts=s0 * SIM_SECOND_US,
                                   pid=_PID_NODE0 + nd, tid=0, node=nd,
                                   extra=int(is_extra))
                if e0 < span - 1e-9:   # reclaim/failure/teardown, not EOS
                    tracer.instant("offline", ts=e0 * SIM_SECOND_US,
                                   pid=_PID_NODE0 + nd, tid=0, node=nd,
                                   extra=int(is_extra))
        events.sort()
        online, rate, spent = 0, 0.0, 0.0
        t_prev = 0.0
        for t, d, price in events:
            spent += rate * max(t - t_prev, 0.0)
            online += d
            rate += d * price / 3600.0
            t_prev = t
            tracer.counter("fleet", ts=t * SIM_SECOND_US, pid=_PID_JOBS,
                           online_nodes=online)
            if priced:
                tracer.counter("spend", ts=t * SIM_SECOND_US, pid=_PID_JOBS,
                               dollars=spent)
    return tracer
