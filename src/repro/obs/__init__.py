"""repro.obs — metrics, tracing, and profiling hooks for the whole stack.

The paper models a MapReduce job phase-by-phase so costs can be attributed;
this package does the same for the system that reproduces it.  One ambient
:class:`Observability` (a :class:`~repro.obs.metrics.MetricsRegistry` plus a
:class:`~repro.obs.trace.Tracer`) is visible to every instrumented
component via :func:`current`:

    import repro.api as api

    with api.observe(trace="run.json") as ob:
        svc.submit(...)                       # spans + counters recorded
    print(ob.registry.snapshot())             # {"service.queries": 42, ...}
    # run.json opens at https://ui.perfetto.dev

Off by default: :func:`current` returns null singletons until an
:func:`observe` context installs live ones, and every instrumented hot path
guards on ``ob.enabled``, so the disabled cost is one attribute check.
Instrumentation is strictly host-side — it never runs inside jitted code
and never changes what an instrumented component computes (CI asserts the
instrumented :class:`~repro.search.evaluator.ChunkedEvaluator` is
bit-for-bit identical to the uninstrumented one).

The ambient slot is process-global, *not* thread-local, on purpose: the
what-if service and serve-loop do their work on worker threads that must
see the ``observe()`` installed by the driving thread.
"""

from __future__ import annotations

import contextlib
from typing import Iterator

from repro.obs.metrics import (
    NULL_REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    percentile_interp,
)
from repro.obs.trace import NULL_TRACER, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "NULL_TRACER",
    "Observability",
    "Tracer",
    "current",
    "observe",
    "percentile_interp",
]


class Observability:
    """A registry + tracer pair; what instrumented components consume."""

    __slots__ = ("registry", "tracer")

    def __init__(self, registry: MetricsRegistry, tracer: Tracer):
        self.registry = registry
        self.tracer = tracer

    @property
    def enabled(self) -> bool:
        return self.registry.enabled or self.tracer.enabled


#: the ambient null default — ``current() is NULL_OBS`` means "off".
NULL_OBS = Observability(NULL_REGISTRY, NULL_TRACER)

_current: Observability = NULL_OBS


def current() -> Observability:
    """The ambient :class:`Observability` (null singletons when off)."""
    return _current


@contextlib.contextmanager
def observe(
    trace: str | None = None,
    *,
    registry: MetricsRegistry | None = None,
    tracer: Tracer | None = None,
) -> Iterator[Observability]:
    """Install a live ambient Observability for the duration of the block.

    ``trace="out.json"`` writes a Chrome trace-event file on exit (open it
    at https://ui.perfetto.dev).  Pass an explicit ``registry``/``tracer``
    to reuse existing instances (e.g. to accumulate across blocks); omitted
    ones are created fresh.  Restores the previous ambient value on exit,
    so contexts nest.
    """
    global _current
    ob = Observability(
        registry if registry is not None else MetricsRegistry(),
        tracer if tracer is not None else Tracer(),
    )
    prev = _current
    _current = ob
    try:
        yield ob
    finally:
        _current = prev
        if trace is not None:
            ob.tracer.write(trace)


def __getattr__(name: str):
    # Lazy: destrace pulls in repro.cluster (jax), profile_hooks pulls in
    # jax.profiler — neither belongs in the stdlib-only import path above.
    if name == "workload_trace":
        from repro.obs.destrace import workload_trace

        return workload_trace
    if name == "profile_capture":
        from repro.obs.profile_hooks import profile_capture

        return profile_capture
    if name == "install_compile_listener":
        from repro.obs.profile_hooks import install_compile_listener

        return install_compile_listener
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
