"""Span tracer emitting Chrome trace-event JSON (Perfetto-loadable).

The observability layer's timeline side.  A :class:`Tracer` collects
`trace-event format <https://ui.perfetto.dev>`_ records:

* ``span(name)`` — nested wall-clock duration events (``ph: B/E``) on the
  calling thread's track; thread-safe, nesting handled by the viewer.
* ``complete(...)`` — a single ``ph: X`` event with an explicit start and
  duration, used for *virtual-time* tracks (the cluster DES emits simulated
  seconds as microseconds; see :mod:`repro.obs.destrace`).
* ``instant(name)`` — ``ph: i`` markers (preemptions, failures, compiles).
* ``counter(track, **series)`` — ``ph: C`` counter tracks (queue depth,
  configs/s, loss curves).
* ``async_begin/async_end`` — ``ph: b/e`` events tied by id, for spans that
  start on one thread and finish on another (a query's submit→resolve life
  across the service worker).

Timestamps are microseconds from the tracer's construction
(``time.perf_counter`` based), so traces start at t=0.  All methods are
safe from any thread; each append takes one short lock.

``NULL_TRACER`` is the off switch: every method is a no-op and ``span()``
returns a shared reusable context manager, so disabled instrumentation
costs one attribute lookup and no allocation.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Iterator

__all__ = ["Tracer", "NULL_TRACER"]


class _Span:
    """Context manager emitting B on enter / E on exit for one tracer."""

    __slots__ = ("_tracer", "_name", "_args")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        self._tracer._emit("B", self._name, args=self._args)
        return self

    def __exit__(self, *exc) -> None:
        self._tracer._emit("E", self._name)


class Tracer:
    """Collects Chrome trace events; ``write(path)`` dumps Perfetto JSON."""

    #: mirrors MetricsRegistry.enabled — hot paths check one attribute.
    enabled: bool = True

    def __init__(self, *, process_name: str = "repro") -> None:
        self._t0 = time.perf_counter()
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._pid = 1
        self.process_name(self._pid, process_name)

    # ---------------------------------------------------------------- core

    def now_us(self) -> float:
        """Microseconds since tracer construction (the trace clock)."""
        return (time.perf_counter() - self._t0) * 1e6

    def _emit(
        self,
        ph: str,
        name: str,
        *,
        ts: float | None = None,
        dur: float | None = None,
        pid: int | None = None,
        tid: int | None = None,
        args: dict | None = None,
        extra: dict | None = None,
    ) -> None:
        ev: dict = {
            "name": name,
            "ph": ph,
            "ts": self.now_us() if ts is None else float(ts),
            "pid": self._pid if pid is None else pid,
            "tid": threading.get_ident() % 1_000_000 if tid is None else tid,
        }
        if dur is not None:
            ev["dur"] = float(dur)
        if args:
            ev["args"] = args
        if extra:
            ev.update(extra)
        with self._lock:
            self._events.append(ev)

    def event(self, ev: dict) -> None:
        """Append a raw pre-built trace event (virtual-time builders)."""
        with self._lock:
            self._events.append(ev)

    # ------------------------------------------------------------ wall time

    def span(self, name: str, **args) -> _Span:
        """``with tracer.span("evaluate", rows=n): ...`` — nested B/E pair."""
        return _Span(self, name, args or None)

    def complete(
        self,
        name: str,
        ts: float,
        dur: float,
        *,
        pid: int | None = None,
        tid: int | None = None,
        **args,
    ) -> None:
        """One ``ph: X`` event with explicit start/duration (virtual time)."""
        self._emit("X", name, ts=ts, dur=dur, pid=pid, tid=tid,
                   args=args or None)

    def instant(
        self,
        name: str,
        *,
        ts: float | None = None,
        pid: int | None = None,
        tid: int | None = None,
        scope: str = "t",
        **args,
    ) -> None:
        self._emit("i", name, ts=ts, pid=pid, tid=tid, args=args or None,
                   extra={"s": scope})

    def counter(
        self,
        track: str,
        *,
        ts: float | None = None,
        pid: int | None = None,
        **series: float,
    ) -> None:
        """One sample on a counter track (``ph: C``); each keyword is a
        series on that track."""
        self._emit("C", track, ts=ts, pid=pid, tid=0,
                   args={k: float(v) for k, v in series.items()})

    # ------------------------------------------------------- async (cross-thread)

    def async_begin(self, name: str, id: int, *, category: str = "repro",
                    **args) -> None:
        self._emit("b", name, args=args or None,
                   extra={"cat": category, "id": id})

    def async_end(self, name: str, id: int, *, category: str = "repro",
                  **args) -> None:
        self._emit("e", name, args=args or None,
                   extra={"cat": category, "id": id})

    def async_instant(self, name: str, id: int, *, category: str = "repro",
                      **args) -> None:
        self._emit("n", name, args=args or None,
                   extra={"cat": category, "id": id})

    # ------------------------------------------------------------- metadata

    def process_name(self, pid: int, name: str) -> None:
        self._emit("M", "process_name", ts=0.0, pid=pid, tid=0,
                   args={"name": name})

    def thread_name(self, pid: int, tid: int, name: str,
                    sort_index: int | None = None) -> None:
        self._emit("M", "thread_name", ts=0.0, pid=pid, tid=tid,
                   args={"name": name})
        if sort_index is not None:
            self._emit("M", "thread_sort_index", ts=0.0, pid=pid, tid=tid,
                       args={"sort_index": sort_index})

    # --------------------------------------------------------------- export

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def to_json(self) -> str:
        return json.dumps({"traceEvents": self.events()})

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _NullTracer(Tracer):
    """The default tracer: records nothing, allocates nothing per call."""

    enabled = False

    def __init__(self) -> None:
        self._t0 = 0.0
        self._events = []
        self._lock = threading.Lock()
        self._pid = 1

    def _emit(self, *a, **k) -> None:
        pass

    def event(self, ev: dict) -> None:
        pass

    def span(self, name: str, **args) -> _NullSpan:  # type: ignore[override]
        return _NULL_SPAN

    def events(self) -> list[dict]:
        return []


#: process-wide off switch — handed out by ``repro.obs.current()`` until an
#: ``observe()`` context installs a live tracer.
NULL_TRACER: Tracer = _NullTracer()


def _iter_events(tracer: Tracer) -> Iterator[dict]:
    yield from tracer.events()
