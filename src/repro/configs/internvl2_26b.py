"""internvl2-26b — InternViT frontend (STUB) + InternLM2-20B backbone.

[arXiv:2404.16821; hf]: backbone 48L, d_model 6144, 48 heads (GQA kv=8,
head_dim 128), d_ff 16384 (SwiGLU), vocab 92553, RoPE theta 1e6.
The ViT frontend is a stub per task spec: ``input_specs()`` provides
precomputed patch embeddings projected to d_model.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92_553,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision",
)
