"""stablelm-1.6b — StableLM-2 1.6B.

[hf:stabilityai/stablelm-2-1_6b; unverified]: 24L, d_model 2048, 32 heads
(kv=32, i.e. MHA, head_dim 64), d_ff 5632 (SwiGLU), vocab 100352,
LayerNorm, partial rotary (25% of head_dim).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100_352,
    mlp_type="swiglu",
    norm_type="layernorm",
    rope_fraction=0.25,
)
