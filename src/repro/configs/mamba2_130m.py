"""mamba2-130m — attention-free SSD (state-space duality) model.

[arXiv:2405.21060; unverified]: 24L, d_model 768, d_ff 0 (no FFN — the
Mamba block is the whole layer), vocab 50280, ssm_state 128,
expand 2 (d_inner 1536), head_dim 64 (24 SSD heads), conv width 4.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,          # unused: attention-free
    n_kv_heads=1,
    head_dim=1,
    d_ff=0,
    vocab_size=50_280,
    layer_pattern=("ssm",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=True,
)
