"""deepseek-moe-16b — fine-grained MoE with shared experts.

[arXiv:2401.06066; hf]: 28L, d_model 2048, 16 heads (kv=16, head_dim 128),
expert d_ff 1408, vocab 102400, 2 shared + 64 routed experts top-6,
first layer dense FFN (d_ff 10944).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102_400,
    mlp_type="swiglu",
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    d_expert=1408,
    moe_layer_start=1,
    d_ff_dense=10944,
)
