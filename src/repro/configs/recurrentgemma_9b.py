"""recurrentgemma-9b — Griffin-style hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; unverified]: 38L, d_model 4096, 16 heads (MQA kv=1,
head_dim 256), d_ff 12288 (GeGLU), vocab 256000, window 2048,
lru_width 4096, tied embeddings.  Pattern: (rglru, rglru, local-attn)
repeated; 38 = 2 prefix recurrent layers + 12 x 3.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    layer_pattern=("rglru", "rglru", "local"),
    prefix_pattern=("rglru", "rglru"),
    window_size=2048,
    mlp_type="geglu",
    tie_embeddings=True,
    rglru_width=4096,
)
