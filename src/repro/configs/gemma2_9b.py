"""gemma2-9b — local+global alternating attention, logit softcaps.

[arXiv:2408.00118; hf]: 42L, d_model 3584, 16 heads (GQA kv=8, head_dim 256),
d_ff 14336 (GeGLU), vocab 256000, sliding window 4096 on odd layers,
attn softcap 50, final softcap 30, sandwich (pre+post) RMSNorm,
tied embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    layer_pattern=("local", "attn"),
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_type="geglu",
    use_post_norm=True,
    tie_embeddings=True,
)
