"""Assigned input shapes and per-(arch x shape) input specifications.

The four assigned shape cells (LM shapes are seq_len x global_batch)::

    train_4k     seq  4 096   batch 256   training        -> train_step
    prefill_32k  seq 32 768   batch  32   inference       -> prefill
    decode_32k   seq 32 768   batch 128   decode w/ cache -> serve_step
    long_500k    seq 524 288  batch   1   long decode     -> serve_step

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input of that cell — weak-type-correct, shardable, and
allocation-free, which is what the multi-pod dry-run lowers against.

Applicability rules (see DESIGN.md §Shape-skips):
* ``long_500k`` only for architectures with bounded decode state
  (``cfg.supports_long_context``).
* VLM/audio frontends are stubs: specs include precomputed patch/frame
  embeddings instead of raw pixels/waveforms.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_lib
from repro.models import lm as lm_lib
from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "applicable", "input_specs", "skip_reason"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# VLM: number of patch-embedding positions inside the sequence budget.
_VLM_PATCHES = 1024
# enc-dec: target length as a fraction of the (source) sequence budget.
_ENCDEC_TGT_FRAC = 4


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """None if the (arch x shape) cell runs; otherwise why it is skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return "skip(full-attn): unbounded full-attention KV at 500k"
    return None


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    return skip_reason(cfg, shape) is None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct pytree for every input of this cell's step fn."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)

    if cfg.is_encdec:
        T = max(S // _ENCDEC_TGT_FRAC, 16)
        if shape.kind == "train":
            return {
                "src_embeds": _sds((B, S, cfg.d_model), act),
                "inputs": _sds((B, T), i32),
                "targets": _sds((B, T), i32),
            }
        if shape.kind == "prefill":
            return {
                "src_embeds": _sds((B, S, cfg.d_model), act),
                "inputs": _sds((B, 256), i32),
            }
        # decode: self cache sized T, cross cache sized S
        caches = jax.eval_shape(
            lambda: encdec_lib.cache_spec_encdec(cfg, B, T, S, act)
        )
        return {
            "token": _sds((B, 1), i32),
            "caches": caches,
            "pos": _sds((), i32),
        }

    if cfg.frontend == "vision":
        n_img = min(_VLM_PATCHES, S // 4)
        if shape.kind == "train":
            return {
                "extra_embeds": _sds((B, n_img, cfg.d_model), act),
                "inputs": _sds((B, S - n_img), i32),
                "targets": _sds((B, S - n_img), i32),
            }
        if shape.kind == "prefill":
            return {
                "extra_embeds": _sds((B, n_img, cfg.d_model), act),
                "inputs": _sds((B, S - n_img), i32),
            }
        caches = jax.eval_shape(lambda: lm_lib.cache_spec(cfg, B, S, act))
        return {"token": _sds((B, 1), i32), "caches": caches, "pos": _sds((), i32)}

    if shape.kind == "train":
        return {"inputs": _sds((B, S), i32), "targets": _sds((B, S), i32)}
    if shape.kind == "prefill":
        return {"inputs": _sds((B, S), i32)}
    caches = jax.eval_shape(lambda: lm_lib.cache_spec(cfg, B, S, act))
    return {"token": _sds((B, 1), i32), "caches": caches, "pos": _sds((), i32)}
