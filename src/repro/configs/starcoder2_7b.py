"""starcoder2-7b — GQA + RoPE code model.

[arXiv:2402.19173; hf]: 32L, d_model 4608, 36 heads (GQA kv=4, head_dim 128),
d_ff 18432 (GeLU), vocab 49152, LayerNorm, RoPE theta 1e5.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49_152,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_theta=100_000.0,
)
