"""Registry of assigned architectures and input shapes.

``get_config(name)`` resolves an ``--arch`` id to its exact public config;
``ARCHS`` lists all ten assigned architectures.  Shape cells and
ShapeDtypeStruct input builders live in :mod:`repro.configs.shapes`.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

from .shapes import SHAPES, ShapeSpec, applicable, input_specs, skip_reason

__all__ = [
    "ARCHS",
    "get_config",
    "SHAPES",
    "ShapeSpec",
    "applicable",
    "input_specs",
    "skip_reason",
]

ARCHS: dict[str, str] = {
    "gemma2-9b": "gemma2_9b",
    "starcoder2-7b": "starcoder2_7b",
    "granite-3-8b": "granite_3_8b",
    "stablelm-1.6b": "stablelm_1_6b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-26b": "internvl2_26b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mamba2-130m": "mamba2_130m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}


def get_config(name: str) -> ModelConfig:
    key = name.replace("_", "-")
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[key]}")
    return mod.CONFIG
