"""granite-3-8b — IBM Granite 3 dense decoder.

[hf:ibm-granite/granite-3.0 family; hf]: 40L, d_model 4096, 32 heads
(GQA kv=8, head_dim 128), d_ff 12800 (SwiGLU), vocab 49155, RMSNorm.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49_155,
    mlp_type="swiglu",
)
