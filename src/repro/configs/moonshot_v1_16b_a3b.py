"""moonshot-v1-16b-a3b — Moonlight-style fine-grained MoE.

[hf:moonshotai/Moonlight-16B-A3B; hf]: per the assignment: 48L,
d_model 2048, 16 heads (kv=16, head_dim 128), expert d_ff 1408,
vocab 163840, MoE 64 routed experts top-6 (no shared experts listed —
the deepseek sibling carries those).  First layer uses a dense FFN
(DeepSeek-style), remaining layers are MoE.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163_840,
    mlp_type="swiglu",
    n_experts=64,
    moe_top_k=6,
    d_expert=1408,
    moe_layer_start=1,
    d_ff_dense=11264,
)
