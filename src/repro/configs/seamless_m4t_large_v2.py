"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone.

[arXiv:2308.11596; hf]: d_model 1024, 16 heads (kv=16, head_dim 64),
d_ff 8192, vocab 256206.  Interpreted as 24 encoder + 24 decoder layers
(the assignment's "24L enc-dec"); the audio frontend is a stub per task
spec — ``input_specs()`` provides precomputed frame embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    mlp_type="gelu",
    norm_type="layernorm",
    tie_embeddings=True,
    frontend="audio",
)
