"""Multi-job workloads over the canonical MapReduce job profiles.

The paper costs a *single* job; a production cluster serves a stream of
them.  This module describes that stream:

* :class:`JobClass` — a job template: Table-1 parameters (mappers, reducers,
  sort buffer, ...) plus Table-2/3 profile statistics and cost factors for
  one of the :data:`repro.mapreduce.jobs.JOBS` profiles.  Per-task costs
  come from the paper's job model (:func:`task_costs`), exactly as in the
  single-job simulator.
* :class:`WorkloadTrace` — a sorted sequence of :class:`JobArrival` events.
* Trace generators — :func:`poisson_trace` (open-loop Poisson arrivals),
  :func:`bursty_trace` (on/off bursts), :func:`replayed_trace` (explicit
  submit times, e.g. replayed from a production log).

Traces are generated at a *unit* arrival rate and rescaled with
:func:`rescale`, so "arrival rate" can be a searched axis of the capacity
planner without regenerating (or re-uploading) the trace.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from repro.cluster.network import per_reducer_shuffle
from repro.core.hadoop.params import CostFactors, HadoopParams, MiB, ProfileStats
from repro.core.hadoop.ref import job_model
from repro.mapreduce.jobs import JOBS

__all__ = [
    "JobClass",
    "JobArrival",
    "WorkloadTrace",
    "StageEdge",
    "StageDag",
    "task_costs",
    "shuffle_full",
    "stage_output_bytes",
    "default_job_classes",
    "dag_from_templates",
    "dag_trace",
    "dag_report",
    "poisson_trace",
    "bursty_trace",
    "replayed_trace",
    "rescale",
]


@dataclass(frozen=True)
class JobClass:
    """A job template: one row of a workload mix.

    ``params`` carries the job-shaped Table-1 knobs (``pNumMappers``,
    ``pNumReducers``, ``pSortMB``...); cluster-shaped knobs (nodes, slots,
    slowstart) are supplied by the scheduler configuration at simulation
    time, so one class can be costed on any candidate cluster.
    """

    name: str
    params: HadoopParams
    stats: ProfileStats
    costs: CostFactors
    weight: float = 1.0      # relative arrival frequency in generated traces

    @property
    def n_maps(self) -> int:
        return self.params.pNumMappers

    @property
    def n_reduces(self) -> int:
        return self.params.pNumReducers


@functools.lru_cache(maxsize=1024)
def _job_model_cached(params: HadoopParams, stats: ProfileStats,
                      costs: CostFactors):
    """One :func:`job_model` evaluation per distinct (params, stats, costs).

    A workload trace repeats a handful of :class:`JobClass` templates over
    thousands of arrivals; the parameter dataclasses are frozen (hashable),
    so per-arrival callers (``pack_trace``, the DES's per-job setup) hit
    this cache and a 10k-job trace costs ~one model call per class instead
    of one per arrival.
    """
    return job_model(params, stats, costs)


def task_costs(jc: JobClass, *, num_nodes: int | None = None
               ) -> tuple[float, float, float]:
    """(map task cost, reduce task cost, per-reducer shuffle seconds).

    The same composition the single-job simulator uses: per-task I/O + CPU
    from the §2-§4 models, plus each reducer's serialized share of the
    network transfer (Eqs. 90-91).  ``num_nodes`` is the *cluster's* node
    count — it sets the remote fraction ``(n-1)/n`` of the shuffle, which is
    a capacity-planning knob, not a property of the job.  Memoized per
    (class, node count) via :func:`_job_model_cached`.
    """
    p = jc.params
    if num_nodes is not None:
        p = p.replace(pNumNodes=num_nodes)
    jm = _job_model_cached(p, jc.stats, jc.costs)
    map_cost = jm.map.ioCost + jm.map.cpuCost
    red_cost = jm.reduce.ioCost + jm.reduce.cpuCost if p.pNumReducers else 0.0
    shuffle = per_reducer_shuffle(jm.netCost, p.pNumReducers)
    return map_cost, red_cost, shuffle


def shuffle_full(jc: JobClass) -> float:
    """Per-reducer shuffle seconds in the all-remote limit ((n-1)/n -> 1).

    The vectorized simulator stores this node-independent constant per job
    and applies the remote fraction of each candidate cluster on device.
    Memoized per class via :func:`_job_model_cached`.
    """
    if jc.params.pNumReducers == 0:
        return 0.0
    jm = _job_model_cached(jc.params, jc.stats, jc.costs)
    size = jm.map.intermDataSize * jc.params.pNumMappers         # Eq. 90, frac=1
    return size * jc.costs.cNetworkCost / jc.params.pNumReducers


@dataclass(frozen=True)
class JobArrival:
    job_id: int
    klass: JobClass
    submit_time: float
    #: DAG edges gating this arrival: ``(parent_job_id, edge_kind)`` pairs,
    #: ``edge_kind`` in ``{"barrier", "slowstart"}``.  The job is held until
    #: every parent releases it — at the parent's finish (barrier) or at its
    #: map-phase completion (slowstart, overlapping the parent's reduce
    #: wave) — and then arrives at ``max(submit_time, release time)``.
    deps: tuple[tuple[int, str], ...] = ()


@dataclass(frozen=True)
class WorkloadTrace:
    """Arrivals sorted by (submit_time, job_id) — the FIFO service order."""

    arrivals: tuple[JobArrival, ...]

    def __post_init__(self):
        object.__setattr__(
            self, "arrivals",
            tuple(sorted(self.arrivals, key=lambda a: (a.submit_time, a.job_id))),
        )

    @property
    def n_jobs(self) -> int:
        return len(self.arrivals)

    @property
    def submit_times(self) -> np.ndarray:
        return np.asarray([a.submit_time for a in self.arrivals])


def rescale(trace: WorkloadTrace, rate: float) -> WorkloadTrace:
    """Speed a unit-rate trace up (rate > 1) or down: times scale by 1/rate."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    return WorkloadTrace(tuple(
        JobArrival(a.job_id, a.klass, a.submit_time / rate, a.deps)
        for a in trace.arrivals
    ))


# --------------------------------------------------------------------------
# the default workload mix
# --------------------------------------------------------------------------

# Table-2-style profiles for the canonical jobs of repro.mapreduce.jobs,
# derived from the map/reduce functions' semantics (see that module): each
# wordcount record emits 4 twelve-byte pairs, filter keeps an exact 20%,
# aggregate's combiner collapses the key space to 256 hot keys, sort moves
# every byte through unchanged.
_PROFILES: dict[str, dict] = {
    "wordcount": dict(
        stats=ProfileStats(sInputPairWidth=400.0, sMapPairsSel=4.0,
                           sMapSizeSel=4 * 12.0 / 400.0,
                           sCombinePairsSel=0.3, sCombineSizeSel=0.3),
        params=dict(pUseCombine=True, pNumMappers=16, pNumReducers=4),
        weight=4.0,
    ),
    "sort": dict(
        stats=ProfileStats(sInputPairWidth=100.0),
        params=dict(pNumMappers=32, pNumReducers=8),
        weight=1.0,
    ),
    "filter": dict(
        stats=ProfileStats(sInputPairWidth=200.0, sMapPairsSel=0.2,
                           sMapSizeSel=0.2),
        params=dict(pNumMappers=16, pNumReducers=2),
        weight=3.0,
    ),
    "aggregate": dict(
        stats=ProfileStats(sInputPairWidth=64.0, sMapSizeSel=16.0 / 64.0,
                           sCombinePairsSel=0.05, sCombineSizeSel=0.05),
        params=dict(pUseCombine=True, pNumMappers=16, pNumReducers=2),
        weight=2.0,
    ),
}


def default_job_classes(
    *,
    split_size: float = 64 * MiB,
    costs: CostFactors | None = None,
    names: Sequence[str] | None = None,
) -> list[JobClass]:
    """The standard 4-class mix over :data:`repro.mapreduce.jobs.JOBS`."""
    c = costs if costs is not None else CostFactors()
    out = []
    for name in (names if names is not None else _PROFILES):
        if name not in JOBS:
            raise KeyError(f"unknown job profile: {name!r}")
        prof = _PROFILES[name]
        p = HadoopParams(pSplitSize=split_size, **prof["params"])
        out.append(JobClass(name=name, params=p, stats=prof["stats"],
                            costs=c, weight=prof["weight"]))
    return out


# --------------------------------------------------------------------------
# DAG workloads: multi-stage jobs where stage outputs feed stage inputs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class StageEdge:
    """A dependency between two stages of a :class:`StageDag`.

    ``kind="barrier"`` releases the destination stage when the source stage
    fully finishes (Hive/Pig-style stage boundaries); ``kind="slowstart"``
    releases it when the source's *map phase* completes, overlapping the
    destination with the source's reduce wave — the DAG analogue of the
    paper's ``pSlowstartThreshold`` intra-job overlap.
    """

    src: int
    dst: int
    kind: str = "barrier"


@dataclass(frozen=True)
class StageDag:
    """A multi-stage job: stages (each a :class:`JobClass`) plus edges.

    Validated on construction: edge endpoints in range, no self-edges, no
    duplicate edges, acyclic (Kahn).  ``topo_order`` lists stage indices
    with every stage after all of its parents; ``is_serial`` is True for a
    width-1 chain — the case where the critical path *is* the makespan.
    """

    name: str
    stages: tuple[JobClass, ...]
    edges: tuple[StageEdge, ...] = ()

    def __post_init__(self):
        n = len(self.stages)
        if n == 0:
            raise ValueError("a StageDag needs at least one stage")
        seen = set()
        for e in self.edges:
            if e.kind not in ("barrier", "slowstart"):
                raise ValueError(f"unknown edge kind: {e.kind!r}")
            if not (0 <= e.src < n and 0 <= e.dst < n):
                raise ValueError(f"edge ({e.src}->{e.dst}) out of range for "
                                 f"{n} stages")
            if e.src == e.dst:
                raise ValueError(f"self-edge on stage {e.src}")
            if (e.src, e.dst) in seen:
                raise ValueError(f"duplicate edge ({e.src}->{e.dst})")
            seen.add((e.src, e.dst))
        self.topo_order          # raises on cycles

    @property
    def topo_order(self) -> tuple[int, ...]:
        n = len(self.stages)
        indeg = [0] * n
        children: dict[int, list[int]] = {}
        for e in self.edges:
            indeg[e.dst] += 1
            children.setdefault(e.src, []).append(e.dst)
        order = [i for i in range(n) if indeg[i] == 0]
        for i in order:
            for ch in children.get(i, ()):
                indeg[ch] -= 1
                if indeg[ch] == 0:
                    order.append(ch)
        if len(order) != n:
            raise ValueError(f"StageDag {self.name!r} has a cycle")
        return tuple(order)

    @property
    def is_serial(self) -> bool:
        """True for a width-1 chain: n-1 edges, every degree <= 1."""
        n = len(self.stages)
        if len(self.edges) != n - 1:
            return False
        outd = [0] * n
        ind = [0] * n
        for e in self.edges:
            outd[e.src] += 1
            ind[e.dst] += 1
        return max(outd, default=0) <= 1 and max(ind, default=0) <= 1

    def parents_of(self, stage: int) -> tuple[StageEdge, ...]:
        return tuple(e for e in self.edges if e.dst == stage)


def stage_output_bytes(jc: JobClass) -> float:
    """Final output bytes a stage writes — the next stage's input.

    The Table-1 dataflow identities, job-wide: with reduces the job writes
    ``outReduceSize * sOutCompressRatio`` per reducer (Eqs. 83 + 86), with
    a map-only job ``outMapSize * sOutCompressRatio`` per mapper (Eq. 8's
    compressed write).  Memoized per class via :func:`_job_model_cached`.
    """
    p = jc.params
    jm = _job_model_cached(p, jc.stats, jc.costs)
    if p.pNumReducers:
        return float(jm.reduce.outReduceSize * jc.stats.sOutCompressRatio
                     * p.pNumReducers)
    return float(jm.map.outMapSize * jc.stats.sOutCompressRatio
                 * p.pNumMappers)


def dag_from_templates(
    name: str,
    templates: Sequence[JobClass],
    edges: Sequence[StageEdge | tuple],
    *,
    split_size: float = 64 * MiB,
) -> StageDag:
    """Build a :class:`StageDag` whose dataflow is *derived*, not declared.

    Each non-root stage's input is the sum of its parents' final output
    bytes (:func:`stage_output_bytes`), so its mapper count is rewired to
    ``max(1, ceil(input_bytes / split_size))`` — exactly how Hadoop sizes a
    downstream job reading the upstream job's HDFS output.  Stages are
    processed in topological order so a rewired parent's output feeds its
    children's sizing.
    """
    norm_edges = tuple(e if isinstance(e, StageEdge) else StageEdge(*e)
                       for e in edges)
    dag = StageDag(name=name, stages=tuple(templates), edges=norm_edges)
    stages = list(dag.stages)
    for i in dag.topo_order:
        parent_edges = dag.parents_of(i)
        if not parent_edges:
            continue
        in_bytes = sum(stage_output_bytes(stages[e.src]) for e in parent_edges)
        n_maps = max(1, int(np.ceil(in_bytes / split_size)))
        jc = stages[i]
        stages[i] = JobClass(
            name=jc.name, stats=jc.stats, costs=jc.costs, weight=jc.weight,
            params=jc.params.replace(pNumMappers=n_maps,
                                     pSplitSize=split_size),
        )
    return StageDag(name=name, stages=tuple(stages), edges=norm_edges)


def dag_trace(
    dag: StageDag,
    *,
    n_instances: int = 1,
    inter_arrival: float = 0.0,
    submit_time: float = 0.0,
    job_id_base: int = 0,
) -> WorkloadTrace:
    """Expand a :class:`StageDag` into a dependency-carrying trace.

    Each instance contributes ``len(dag.stages)`` arrivals sharing one
    submit time; non-root stages carry ``deps`` edges so the DES (and,
    single-parent, the wave model) holds them until their parents release.
    Stage job-ids follow topological order, so every parent id is lower
    than its children's — the order :func:`pack_trace` requires.
    """
    if n_instances < 1:
        raise ValueError(f"n_instances must be >= 1, got {n_instances}")
    order = dag.topo_order
    arrivals = []
    jid = job_id_base
    for inst in range(n_instances):
        t0 = submit_time + inst * inter_arrival
        jid_of = {}
        for stage in order:
            jid_of[stage] = jid
            deps = tuple((jid_of[e.src], e.kind)
                         for e in dag.parents_of(stage))
            arrivals.append(JobArrival(jid, dag.stages[stage], t0, deps))
            jid += 1
    return WorkloadTrace(tuple(arrivals))


def dag_report(trace: WorkloadTrace, result):
    """Critical-path analysis of a simulated DAG trace.

    Pairs the trace's dependency edges with the DES's measured per-stage
    times and returns a typed :class:`repro.spec.DagReport`.  Defined here
    (not in ``repro.spec``) so the spec layer stays free of cluster
    imports; the report itself is a spec pytree.
    """
    from repro.spec import DagReport

    jobs = sorted(result.jobs, key=lambda js: js.job_id)
    idx = {js.job_id: k for k, js in enumerate(jobs)}
    edges = []
    for a in trace.arrivals:
        for parent, kind in a.deps:
            edges.append((idx[a.job_id], idx[parent], kind))
    return DagReport.from_times(
        submit=[js.submit_time for js in jobs],
        first_launch=[js.first_launch for js in jobs],
        map_finish=[js.map_finish for js in jobs],
        finish=[js.finish for js in jobs],
        edges=edges,
    )


# --------------------------------------------------------------------------
# trace generators (all unit-rate; compose with rescale())
# --------------------------------------------------------------------------


def _pick_classes(classes: Sequence[JobClass], n: int,
                  rng: np.random.Generator) -> list[JobClass]:
    w = np.asarray([jc.weight for jc in classes], dtype=np.float64)
    idx = rng.choice(len(classes), size=n, p=w / w.sum())
    return [classes[i] for i in idx]


def poisson_trace(classes: Sequence[JobClass], n_jobs: int, *,
                  rate: float = 1.0, seed: int = 0) -> WorkloadTrace:
    """Open-loop Poisson arrivals: exponential gaps of mean ``1/rate``."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_jobs)
    times = np.cumsum(gaps) - gaps[0]          # first job arrives at t=0
    picks = _pick_classes(classes, n_jobs, rng)
    return WorkloadTrace(tuple(
        JobArrival(i, jc, float(t)) for i, (jc, t) in enumerate(zip(picks, times))
    ))


def bursty_trace(classes: Sequence[JobClass], n_bursts: int, burst_size: int, *,
                 burst_gap: float = 60.0, intra_gap: float = 0.5,
                 seed: int = 0) -> WorkloadTrace:
    """On/off arrivals: ``n_bursts`` bursts of ``burst_size`` near-simultaneous
    jobs, ``burst_gap`` apart — the worst case for FIFO tail latency."""
    rng = np.random.default_rng(seed)
    picks = _pick_classes(classes, n_bursts * burst_size, rng)
    arrivals = []
    jid = 0
    for b in range(n_bursts):
        for k in range(burst_size):
            arrivals.append(JobArrival(jid, picks[jid],
                                       b * burst_gap + k * intra_gap))
            jid += 1
    return WorkloadTrace(tuple(arrivals))


def replayed_trace(times: Sequence[float],
                   classes: Sequence[JobClass] | Mapping[int, JobClass],
                   *, seed: int = 0) -> WorkloadTrace:
    """Replay explicit submit times (e.g. from a production log).

    ``classes`` is either a per-job mapping (job index -> class) or a pool
    to sample from by weight.
    """
    n = len(times)
    if isinstance(classes, Mapping):
        picks = [classes[i] for i in range(n)]
    else:
        picks = _pick_classes(list(classes), n, np.random.default_rng(seed))
    return WorkloadTrace(tuple(
        JobArrival(i, jc, float(t)) for i, (t, jc) in enumerate(zip(times, picks))
    ))
