"""Capacity planning behind the :class:`repro.search.Evaluator` interface.

``ClusterEvaluator`` makes *cluster* knobs — node count, slots per node,
scheduler policy, reduce slowstart, offered arrival rate — searchable by
every existing strategy (``grid_search_ev``, ``random_search_ev``,
``coordinate_descent_ev``, streaming ``search_topk``) and servable by
:class:`repro.search.WhatIfService`, exactly like the single-job Hadoop
model:

* ``evaluate`` expands each override row into (row x workload-seed)
  scenarios, rolls them out with the vectorized wave simulator
  (:mod:`repro.cluster.vector_sim`), and aggregates per-trace tail metrics;
* the cost is ``mean`` or ``p95`` job latency (submit -> finish) averaged
  over the workload seeds — the capacity-planning objective;
* ``exact_cost`` routes an assignment through the multi-job DES
  (:func:`repro.cluster.sched.simulate_workload`), the trusted reference —
  rows the wave model could not converge (``valid == 0``) are re-costed
  there by the standard escape hatch, never reported as a silent number.

Override keys (the ``base_cfg`` universe):

  ``pNumNodes``, ``pMaxMapsPerNode``, ``pMaxRedPerNode``,
  ``pReduceSlowstart``, ``schedFair`` (0 = FIFO, 1 = fair),
  ``arrivalRate`` (jobs/s offered to the cluster).
"""

from __future__ import annotations

import functools
from typing import Any, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.search.evaluator import (
    Evaluator,
    SearchResult,
    masked_total,
    pad_block,
    split_overrides,
)
from repro.spec import Axis, ParamSpace

from .sched import ClusterConfig, simulate_workload
from .vector_sim import estimate_steps, pack_trace, simulate_batch
from .workload import JobClass, WorkloadTrace, default_job_classes, poisson_trace, rescale

__all__ = ["ClusterEvaluator", "cluster_space"]

_OBJECTIVES = {"mean": "w_meanLat", "p95": "w_p95Lat"}


@functools.lru_cache(maxsize=None)
def cluster_space() -> ParamSpace:
    """The capacity planner's searchable axes (the ``base_cfg`` universe).

    The axis bounds ARE the planner's knob-validity rule: a row is valid
    when every (rounded) count is >= 1 and the offered rate is positive —
    exactly the mask :meth:`ClusterEvaluator.evaluate` applies before the
    vectorized rollout.  ``pReduceSlowstart`` is a fraction and
    ``schedFair`` a flag; neither contributes a validity bound.
    """
    return ParamSpace([
        Axis("pNumNodes", kind="int", lower=1, table="Table 1",
             group="cluster", doc="worker nodes in the candidate cluster"),
        Axis("pMaxMapsPerNode", kind="int", lower=1, table="Table 1",
             group="cluster", doc="map slots per node"),
        Axis("pMaxRedPerNode", kind="int", lower=1, table="Table 1",
             group="cluster", doc="reduce slots per node"),
        Axis("pReduceSlowstart", kind="float", lower=None, unit="fraction",
             table="Table 1", group="cluster",
             doc="map completion fraction before reducers launch"),
        Axis("schedFair", kind="bool", group="cluster",
             doc="fair-share scheduler (0 = FIFO)"),
        Axis("arrivalRate", kind="float", lower=0, lower_open=True,
             unit="jobs/s", group="cluster",
             doc="offered load the unit-rate trace is rescaled to"),
    ])


class ClusterEvaluator(Evaluator):
    """Batched workload-on-cluster evaluation over candidate cluster configs.

    Parameters
    ----------
    classes : job mix (default :func:`default_job_classes`).
    traces : explicit unit-rate workload traces; default ``n_seeds`` Poisson
        traces of ``n_jobs`` jobs each.  The cost of a config is averaged
        over the traces, so one lucky arrival pattern cannot pick the
        cluster.
    base : cluster defaults for keys a query leaves alone.
    base_rate : default offered load (jobs/s; ``arrivalRate`` override).
    objective : ``"p95"`` (default — tail latency is what capacity is
        bought for) or ``"mean"``.
    chunk : rows per vectorized call (rounded up to the device count).
    """

    def __init__(
        self,
        classes: Sequence[JobClass] | None = None,
        *,
        traces: Sequence[WorkloadTrace] | None = None,
        n_jobs: int = 32,
        n_seeds: int = 2,
        trace_seed: int = 0,
        base: ClusterConfig = ClusterConfig(),
        base_rate: float = 0.1,
        objective: str = "p95",
        chunk: int = 256,
        devices=None,
    ):
        if objective not in _OBJECTIVES:
            raise ValueError(f"objective must be one of {sorted(_OBJECTIVES)}")
        self.classes = list(classes) if classes is not None \
            else default_job_classes()
        self.traces = list(traces) if traces is not None else [
            poisson_trace(self.classes, n_jobs, rate=1.0, seed=trace_seed + s)
            for s in range(n_seeds)
        ]
        packed = [pack_trace(t) for t in self.traces]
        #: (S, J) per-job constants shared by every scenario
        self._cols = {k: np.stack([p[k] for p in packed]) for k in packed[0]}
        self._objective = objective
        self._base = base
        self._devs = tuple(devices) if devices is not None \
            else tuple(compat.default_search_devices())
        self.num_devices = len(self._devs)
        self.chunk = -(-max(chunk, 1) // self.num_devices) * self.num_devices
        self.base_cfg = {
            "pNumNodes": jnp.asarray(float(base.num_nodes)),
            "pMaxMapsPerNode": jnp.asarray(float(base.map_slots_per_node)),
            "pMaxRedPerNode": jnp.asarray(float(base.reduce_slots_per_node)),
            "pReduceSlowstart": jnp.asarray(float(base.reduce_slowstart)),
            "schedFair": jnp.asarray(1.0 if base.scheduler == "fair" else 0.0),
            "arrivalRate": jnp.asarray(float(base_rate)),
        }

    # ---------------- Evaluator interface ----------------

    @property
    def cost_key(self) -> str:
        return _OBJECTIVES[self._objective]

    @property
    def param_space(self) -> ParamSpace:
        """Declared cluster axes — the single source of the knob mask."""
        return cluster_space()

    def evaluate(self, overrides: Mapping[str, Any]) -> SearchResult:
        batched, static, n = split_overrides(self.base_cfg, overrides)
        out_blocks: dict[str, list[np.ndarray]] = {}
        for start in range(0, n, self.chunk):
            stop = min(start + self.chunk, n)
            rows, _ = pad_block(batched, start, stop, self.chunk)
            out = self._evaluate_rows(rows, static)
            for k, v in out.items():
                out_blocks.setdefault(k, []).append(v[: stop - start])
        outputs = {k: np.concatenate(v) for k, v in out_blocks.items()}
        total = masked_total(outputs, self.cost_key)
        return SearchResult(overrides=batched, outputs=outputs, total_cost=total)

    def exact_cost(self, assignment: Mapping[str, float]) -> float:
        """The multi-job DES on every trace; same objective, trusted path."""
        cfg = {k: float(np.asarray(v)) for k, v in self.base_cfg.items()}
        for k, v in assignment.items():
            if k not in cfg:
                raise KeyError(f"unknown config key: {k!r}")
            cfg[k] = float(v)
        nodes = int(round(cfg["pNumNodes"]))
        mpn = int(round(cfg["pMaxMapsPerNode"]))
        rpn = int(round(cfg["pMaxRedPerNode"]))
        rate = cfg["arrivalRate"]
        if nodes < 1 or mpn < 1 or rpn < 1 or rate <= 0:
            return float("inf")
        cc = ClusterConfig(
            num_nodes=nodes, map_slots_per_node=mpn, reduce_slots_per_node=rpn,
            scheduler="fair" if cfg["schedFair"] > 0.5 else "fifo",
            reduce_slowstart=cfg["pReduceSlowstart"],
        )
        vals = []
        for tr in self.traces:
            res = simulate_workload(rescale(tr, rate), cc)
            vals.append(res.p95_latency if self._objective == "p95"
                        else res.mean_latency)
        return float(np.mean(vals))

    # ---------------- internals ----------------

    def _evaluate_rows(self, rows: Mapping[str, np.ndarray],
                       static: Mapping[str, float]) -> dict[str, np.ndarray]:
        """One padded chunk -> per-row metrics (row x trace scenarios)."""
        b = self.chunk
        col = lambda k: rows[k] if k in rows else np.full(b, static[k])
        nodes = np.round(col("pNumNodes"))
        mpn = np.round(col("pMaxMapsPerNode"))
        rpn = np.round(col("pMaxRedPerNode"))
        rate = col("arrivalRate")
        fair = (col("schedFair") > 0.5).astype(np.float64)
        slow = col("pReduceSlowstart")
        # the declared axis bounds (int counts >= 1, rate > 0) ARE the mask
        ok, _ = self.param_space.validity_mask(
            {k: col(k) for k in self.base_cfg})
        # invalid rows are masked via ``ok``, but still ride the vmapped
        # rollout — sanitize their knobs so a zero-slot lane cannot pin the
        # whole chunk at the step cap (a lane that never finishes keeps the
        # while_loop running for everyone)
        nodes_s = np.maximum(nodes, 1.0)
        mpn_s = np.maximum(mpn, 1.0)
        rpn_s = np.maximum(rpn, 1.0)
        rate_s = np.where(rate > 0, rate, 1.0)

        cols, s = self._cols, len(self.traces)
        rep = lambda a: np.repeat(a[:, None], s, axis=1).reshape(b * s)
        perjob = lambda a: np.broadcast_to(
            a[None], (b,) + a.shape).reshape(b * s, -1)
        frac = (nodes_s - 1.0) / nodes_s
        scen = {
            "arrival": perjob(cols["arrival"]) / rep(rate_s)[:, None],
            "n_maps": perjob(cols["n_maps"]),
            "n_reds": perjob(cols["n_reds"]),
            "map_cost": perjob(cols["map_cost"]),
            "red_work": perjob(cols["red_work"]),
            "shuffle": perjob(cols["shuffle"]) * rep(frac)[:, None],
            "map_slots": rep(nodes_s * mpn_s),
            "red_slots": rep(nodes_s * rpn_s),
            "fair": rep(fair),
            "slowstart": rep(slow),
        }
        out = simulate_batch(scen, n_steps=estimate_steps(scen),
                             devices=self._devs)
        shp = (b, s)
        mean_lat = out["mean_latency"].reshape(shp).mean(axis=1)
        p95_lat = out["p95_latency"].reshape(shp).mean(axis=1)
        conv = out["converged"].reshape(shp).min(axis=1)
        return {
            "w_meanLat": mean_lat.astype(np.float64),
            "w_p95Lat": p95_lat.astype(np.float64),
            "w_makespan": out["makespan"].reshape(shp).mean(axis=1).astype(np.float64),
            "w_util": out["utilization"].reshape(shp).mean(axis=1).astype(np.float64),
            "valid": (ok & (conv > 0)).astype(np.float64),
        }
