"""Capacity planning behind the :class:`repro.search.Evaluator` interface.

``ClusterEvaluator`` makes *cluster* knobs — node count, fleet mix, slots
per node, scheduler policy, preemption, reduce slowstart, offered arrival
rate — searchable by every existing strategy (``grid_search_ev``,
``random_search_ev``, ``coordinate_descent_ev``, streaming
``search_topk``) and servable by :class:`repro.search.WhatIfService`,
exactly like the single-job Hadoop model:

* ``evaluate`` expands each override row into (row x workload-seed)
  scenarios, rolls them out with the vectorized wave simulator
  (:mod:`repro.cluster.vector_sim`), and aggregates per-trace tail metrics;
* the cost is ``mean`` or ``p95`` job latency (submit -> finish) averaged
  over the workload seeds — the capacity-planning objective;
* ``exact_cost`` routes an assignment through the multi-job DES
  (:func:`repro.cluster.sched.simulate_workload`), the trusted reference —
  rows the wave model could not converge (``valid == 0``) are re-costed
  there by the standard escape hatch, never reported as a silent number.
  A workload that cannot finish on the candidate cluster raises
  :class:`UnfinishedWorkloadError` instead of returning an inf latency
  (the PR-2 no-silent-inf policy).

Override keys (the ``base_cfg`` universe, declared in :func:`cluster_space`):

  ``pNumNodes``, ``pMaxMapsPerNode``, ``pMaxRedPerNode``,
  ``pReduceSlowstart``, ``schedFair`` (legacy 0 = FIFO, 1 = fair),
  ``arrivalRate`` (jobs/s offered to the cluster),
  ``pNumFastNodes`` / ``fastSpeedup`` (the fleet mix: that many nodes run
  their compute ``fastSpeedup`` x faster, the rest are baseline),
  ``schedPolicy`` (0 = fifo, 1 = fair, 2 = fair_preempt, 3 = capacity;
  overrides ``schedFair`` when nonzero), ``preemptTimeout`` (DES grace
  seconds before an over-share kill; the wave model preempts at event
  boundaries, so this knob only moves ``exact_cost``),
  ``pNumRacks`` / ``crossRackBw`` / ``oversubscription`` (the network
  topology of :class:`repro.cluster.network.Topology`: ``pNumRacks=1`` or
  infinite ``crossRackBw`` is the flat network; otherwise shuffle flows
  contend for each rack's ``crossRackBw / oversubscription`` uplink —
  max-min fair-shared in the DES, count-approximated in the wave model).
"""

from __future__ import annotations

import functools
from typing import Any, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.hadoop.simulator import SimConfig
from repro.search.evaluator import (
    Evaluator,
    ExactCostUnavailable,
    SearchResult,
    masked_total,
    pad_block,
    split_overrides,
)
from repro.spec import Axis, ParamSpace, Predicate

from .network import Topology
from .sched import ClusterConfig, NodeClass, simulate_workload
from .vector_sim import POLICIES, estimate_steps, pack_trace, simulate_batch
from .workload import JobClass, WorkloadTrace, default_job_classes, poisson_trace, rescale

__all__ = ["ClusterEvaluator", "UnfinishedWorkloadError", "cluster_space"]

_OBJECTIVES = {"mean": "w_meanLat", "p95": "w_p95Lat"}


class UnfinishedWorkloadError(ExactCostUnavailable):
    """The DES could not finish every job of the workload on this cluster
    (e.g. every node failed, or the trace outlives all slots) — the latency
    objective would be a silent ``inf``, so the evaluator raises instead.
    Subclasses :class:`repro.search.ExactCostUnavailable`, so the generic
    fallback paths (top-k, descent, service) skip the candidate with a log
    line instead of aborting a completed search."""


def _fast_fits_fleet(cols: Mapping[str, np.ndarray]) -> np.ndarray:
    """``pNumFastNodes <= pNumNodes`` — unconstrained when either column is
    absent from the masked batch (validity_mask accepts partial columns)."""
    if "pNumFastNodes" not in cols or "pNumNodes" not in cols:
        return np.asarray(True)
    return cols["pNumFastNodes"] <= cols["pNumNodes"]


def _racks_fit_fleet(cols: Mapping[str, np.ndarray]) -> np.ndarray:
    """``pNumRacks <= pNumNodes`` — an empty rack is a mis-specified
    topology, not a bigger cluster."""
    if "pNumRacks" not in cols or "pNumNodes" not in cols:
        return np.asarray(True)
    return cols["pNumRacks"] <= cols["pNumNodes"]


@functools.lru_cache(maxsize=None)
def cluster_space() -> ParamSpace:
    """The capacity planner's searchable axes (the ``base_cfg`` universe).

    The axis bounds ARE the planner's knob-validity rule: a row is valid
    when every (rounded) count is >= 1, the offered rate is positive, the
    fast-node count fits inside the fleet (``pNumFastNodes <= pNumNodes``,
    a cross-axis :class:`Predicate`), the fast class is at least baseline
    speed, and the policy code is one of the four schedulers — exactly the
    mask :meth:`ClusterEvaluator.evaluate` applies before the vectorized
    rollout.  ``pReduceSlowstart`` is a fraction and ``schedFair`` a flag;
    neither contributes a validity bound.
    """
    return ParamSpace([
        Axis("pNumNodes", kind="int", lower=1, table="Table 1",
             group="cluster", doc="worker nodes in the candidate cluster"),
        Axis("pMaxMapsPerNode", kind="int", lower=1, table="Table 1",
             group="cluster", doc="map slots per node"),
        Axis("pMaxRedPerNode", kind="int", lower=1, table="Table 1",
             group="cluster", doc="reduce slots per node"),
        Axis("pReduceSlowstart", kind="float", lower=None, unit="fraction",
             table="Table 1", group="cluster",
             doc="map completion fraction before reducers launch"),
        Axis("schedFair", kind="bool", group="cluster",
             doc="fair-share scheduler (0 = FIFO; legacy spelling of "
                 "schedPolicy=1)"),
        Axis("arrivalRate", kind="float", lower=0, lower_open=True,
             unit="jobs/s", group="cluster",
             doc="offered load the unit-rate trace is rescaled to"),
        Axis("pNumFastNodes", kind="int", lower=0, group="cluster",
             doc="nodes of the fast hardware class (rest are baseline)"),
        Axis("fastSpeedup", kind="float", lower=1, group="cluster",
             doc="compute speed factor of the fast class (>= baseline)"),
        Axis("schedPolicy", kind="int", lower=0, upper=3, group="cluster",
             doc="0 fifo | 1 fair | 2 fair_preempt | 3 capacity "
                 "(overrides schedFair when nonzero)"),
        Axis("preemptTimeout", kind="float", lower=0, unit="s",
             group="cluster",
             doc="grace before an over-share task is killed (DES only)"),
        Axis("pNumRacks", kind="int", lower=1, group="cluster",
             doc="racks the nodes are striped across (1 = flat network)"),
        Axis("crossRackBw", kind="float", lower=0, lower_open=True,
             unit="x nominal", group="cluster",
             doc="aggregate core-uplink bandwidth per rack, in units of one "
                 "flow's nominal rate (inf = never the bottleneck)"),
        Axis("oversubscription", kind="float", lower=1, group="cluster",
             doc="top-of-rack oversubscription factor dividing crossRackBw"),
    ], predicates=[
        Predicate(
            "fast nodes within fleet",
            _fast_fits_fleet,
            doc="the fast class cannot exceed the fleet size",
        ),
        Predicate(
            "racks within fleet",
            _racks_fit_fleet,
            doc="at least one node per rack",
        ),
    ])


class ClusterEvaluator(Evaluator):
    """Batched workload-on-cluster evaluation over candidate cluster configs.

    Parameters
    ----------
    classes : job mix (default :func:`default_job_classes`).
    traces : explicit unit-rate workload traces; default ``n_seeds`` Poisson
        traces of ``n_jobs`` jobs each.  The cost of a config is averaged
        over the traces, so one lucky arrival pattern cannot pick the
        cluster.
    base : cluster defaults for keys a query leaves alone (a heterogeneous
        ``node_classes`` base seeds ``pNumFastNodes``/``fastSpeedup``).
    base_rate : default offered load (jobs/s; ``arrivalRate`` override).
    capacities : capacity-scheduler guarantees, job-class name -> relative
        weight (normalized over the classes present in each trace; default
        equal shares) — used by both the wave model and the DES.
    sim : :class:`SimConfig` the DES (``exact_cost``) runs under — noise,
        speculation, node failures.  The wave model does not simulate
        failures; a failure schedule only moves the exact path.
    objective : ``"p95"`` (default — tail latency is what capacity is
        bought for) or ``"mean"``.
    chunk : rows per vectorized call (rounded up to the device count).
    """

    def __init__(
        self,
        classes: Sequence[JobClass] | None = None,
        *,
        traces: Sequence[WorkloadTrace] | None = None,
        n_jobs: int = 32,
        n_seeds: int = 2,
        trace_seed: int = 0,
        base: ClusterConfig = ClusterConfig(),
        base_rate: float = 0.1,
        capacities: Mapping[str, float] | None = None,
        sim: SimConfig = SimConfig(),
        objective: str = "p95",
        chunk: int = 256,
        devices=None,
    ):
        if objective not in _OBJECTIVES:
            raise ValueError(f"objective must be one of {sorted(_OBJECTIVES)}")
        self.classes = list(classes) if classes is not None \
            else default_job_classes()
        self.traces = list(traces) if traces is not None else [
            poisson_trace(self.classes, n_jobs, rate=1.0, seed=trace_seed + s)
            for s in range(n_seeds)
        ]
        packed = [pack_trace(t) for t in self.traces]
        #: (S, J) per-job constants shared by every scenario
        self._cols = {k: np.stack([p[k] for p in packed]) for k in packed[0]}
        self._objective = objective
        self._base = base
        self._sim = sim
        self.capacities = dict(capacities) if capacities else {}
        # capacity-scheduler queues: one global name universe (evaluator
        # classes + any trace-only classes), per-trace guarantees normalized
        # over the classes PRESENT in that trace — the DES's rule, so
        # evaluate() and exact_cost() agree on what a guarantee means.
        qnames = sorted({jc.name for jc in self.classes}
                        | {a.klass.name for t in self.traces
                           for a in t.arrivals})
        qidx = {name: i for i, name in enumerate(qnames)}
        self._queue_cols = np.stack([
            np.asarray([qidx[a.klass.name] for a in t.arrivals], np.float64)
            for t in self.traces
        ])                                                      # (S, J)
        fracs = np.zeros((len(self.traces), len(qnames)))
        for s, t in enumerate(self.traces):
            present = sorted({a.klass.name for a in t.arrivals})
            w = {q: self.capacities.get(q, 1.0) for q in present}
            tot = sum(w.values()) or 1.0
            for q in present:
                fracs[s, qidx[q]] = w[q] / tot
        self._queue_fracs = fracs                               # (S, Q)
        self._devs = tuple(devices) if devices is not None \
            else tuple(compat.default_search_devices())
        self.num_devices = len(self._devs)
        self.chunk = -(-max(chunk, 1) // self.num_devices) * self.num_devices
        fast_n, fast_spd = 0, 1.0
        if base.node_classes:
            # the axis space models a two-class fleet: N fast nodes
            # (speedup >= 1) + a unit-speed baseline — reject richer bases
            # instead of silently projecting them onto the wrong cluster
            fleet = sorted(base.node_classes, key=lambda nc: -nc.speedup)
            if (len(fleet) > 2 or fleet[-1].speedup < 1.0
                    or (len(fleet) == 2 and fleet[1].speedup != 1.0)):
                raise ValueError(
                    "ClusterEvaluator's pNumFastNodes/fastSpeedup axes model "
                    "a (fast + unit-speed baseline) fleet; base.node_classes "
                    f"= {base.node_classes} is not expressible — run richer "
                    "fleets through simulate_workload directly"
                )
            if fleet[0].speedup > 1.0:
                fast_n, fast_spd = fleet[0].count, fleet[0].speedup
        # strong-typed scalars (weak-typed defaults change the compile key
        # when an axis switches between scalar and batched-column form)
        fdt = jnp.result_type(float)
        self.base_cfg = {
            "pNumNodes": jnp.asarray(float(base.num_nodes), dtype=fdt),
            "pMaxMapsPerNode": jnp.asarray(
                float(base.map_slots_per_node), dtype=fdt),
            "pMaxRedPerNode": jnp.asarray(
                float(base.reduce_slots_per_node), dtype=fdt),
            "pReduceSlowstart": jnp.asarray(
                float(base.reduce_slowstart), dtype=fdt),
            "schedFair": jnp.asarray(
                1.0 if base.scheduler == "fair" else 0.0, dtype=fdt),
            "arrivalRate": jnp.asarray(float(base_rate), dtype=fdt),
            "pNumFastNodes": jnp.asarray(float(fast_n), dtype=fdt),
            "fastSpeedup": jnp.asarray(float(fast_spd), dtype=fdt),
            # fifo/fair bases seed schedPolicy=0 so the legacy schedFair
            # axis keeps full control (schedPolicy supersedes it when
            # nonzero); only the preemptive bases — which schedFair cannot
            # express — pin the policy code
            "schedPolicy": jnp.asarray(
                float(POLICIES.index(base.scheduler))
                if POLICIES.index(base.scheduler) >= 2 else 0.0, dtype=fdt),
            "preemptTimeout": jnp.asarray(
                float(base.preempt_timeout), dtype=fdt),
            "pNumRacks": jnp.asarray(
                float(base.topology.num_racks if base.topology else 1),
                dtype=fdt),
            "crossRackBw": jnp.asarray(
                float(base.topology.cross_rack_bw if base.topology
                      else float("inf")), dtype=fdt),
            "oversubscription": jnp.asarray(
                float(base.topology.oversub if base.topology else 1.0),
                dtype=fdt),
        }

    # ---------------- Evaluator interface ----------------

    @property
    def cost_key(self) -> str:
        return _OBJECTIVES[self._objective]

    @property
    def param_space(self) -> ParamSpace:
        """Declared cluster axes — the single source of the knob mask."""
        return cluster_space()

    def grad_objective(self):
        from repro.search.evaluator import NotDifferentiableError

        raise NotDifferentiableError(
            "cluster costs come from the discrete-event scheduler simulation "
            "(wave counts, preemption, arrival ordering) — piecewise-constant "
            "in every knob, so there is no useful gradient; gradient "
            "strategies fall back to coordinate descent here"
        )

    def evaluate(self, overrides: Mapping[str, Any]) -> SearchResult:
        batched, static, n = split_overrides(self.base_cfg, overrides)
        out_blocks: dict[str, list[np.ndarray]] = {}
        for start in range(0, n, self.chunk):
            stop = min(start + self.chunk, n)
            rows, _ = pad_block(batched, start, stop, self.chunk)
            out = self._evaluate_rows(rows, static)
            for k, v in out.items():
                out_blocks.setdefault(k, []).append(v[: stop - start])
        outputs = {k: np.concatenate(v) for k, v in out_blocks.items()}
        total = masked_total(outputs, self.cost_key)
        return SearchResult(overrides=batched, outputs=outputs, total_cost=total)

    def _resolve_config(self, cfg: Mapping[str, float]) -> ClusterConfig | None:
        """A flat assignment -> :class:`ClusterConfig`, or ``None`` when the
        knobs violate the declared axis bounds / predicates."""
        nodes = int(round(cfg["pNumNodes"]))
        mpn = int(round(cfg["pMaxMapsPerNode"]))
        rpn = int(round(cfg["pMaxRedPerNode"]))
        fast = int(round(cfg["pNumFastNodes"]))
        fspd = float(cfg["fastSpeedup"])
        poli = int(round(cfg["schedPolicy"]))
        racks = int(round(cfg["pNumRacks"]))
        xbw = float(cfg["crossRackBw"])
        osub = float(cfg["oversubscription"])
        if poli == 0 and cfg["schedFair"] > 0.5:
            poli = 1                       # legacy boolean spelling
        if (nodes < 1 or mpn < 1 or rpn < 1 or cfg["arrivalRate"] <= 0
                or fast < 0 or fast > nodes or fspd < 1.0
                or not 0 <= poli < len(POLICIES)
                or cfg["preemptTimeout"] < 0
                or racks < 1 or racks > nodes or xbw <= 0 or osub < 1.0):
            return None
        fleet = ()
        if fast > 0 and fspd > 1.0:
            fleet = (NodeClass(fast, fspd),) + (
                (NodeClass(nodes - fast, 1.0),) if nodes > fast else ())
        topo = Topology(num_racks=racks, cross_rack_bw=xbw, oversub=osub) \
            if racks > 1 else None
        return ClusterConfig(
            num_nodes=nodes, map_slots_per_node=mpn, reduce_slots_per_node=rpn,
            scheduler=POLICIES[poli],
            reduce_slowstart=cfg["pReduceSlowstart"],
            node_classes=fleet,
            preempt_timeout=float(cfg["preemptTimeout"]),
            capacities=tuple(sorted(self.capacities.items())),
            topology=topo,
        )

    def exact_cost(self, assignment: Mapping[str, float]) -> float:
        """The multi-job DES on every trace; same objective, trusted path.

        Raises :class:`UnfinishedWorkloadError` when a trace cannot finish
        on the candidate cluster (the latency objective would be inf).
        """
        cfg = {k: float(np.asarray(v)) for k, v in self.base_cfg.items()}
        for k, v in assignment.items():
            if k not in cfg:
                raise KeyError(f"unknown config key: {k!r}")
            cfg[k] = float(v)
        cc = self._resolve_config(cfg)
        if cc is None:
            return float("inf")
        rate = cfg["arrivalRate"]
        vals = []
        for tr in self.traces:
            res = simulate_workload(rescale(tr, rate), cc, self._sim)
            if res.n_unfinished:
                raise UnfinishedWorkloadError(
                    f"{res.n_unfinished}/{len(res.jobs)} jobs never finished "
                    f"on {cc} — the {self._objective} latency objective is "
                    "undefined (inf); inspect WorkloadResult.n_unfinished"
                )
            vals.append(res.p95_latency if self._objective == "p95"
                        else res.mean_latency)
        return float(np.mean(vals))

    # ---------------- internals ----------------

    def _evaluate_rows(self, rows: Mapping[str, np.ndarray],
                       static: Mapping[str, float]) -> dict[str, np.ndarray]:
        """One padded chunk -> per-row metrics (row x trace scenarios)."""
        b = self.chunk
        col = lambda k: rows[k] if k in rows else np.full(b, static[k])
        nodes = np.round(col("pNumNodes"))
        mpn = np.round(col("pMaxMapsPerNode"))
        rpn = np.round(col("pMaxRedPerNode"))
        rate = col("arrivalRate")
        fair = (col("schedFair") > 0.5).astype(np.float64)
        slow = col("pReduceSlowstart")
        fast = np.round(col("pNumFastNodes"))
        fspd = col("fastSpeedup")
        polx = np.round(col("schedPolicy"))
        # schedPolicy supersedes the legacy boolean when nonzero
        pol = np.where(polx > 0, polx, fair)
        # the declared axis bounds + predicates (counts >= 1, rate > 0,
        # fast class inside the fleet, speedup >= 1, policy code in range)
        # ARE the mask
        ok, _ = self.param_space.validity_mask(
            {k: col(k) for k in self.base_cfg})
        # invalid rows are masked via ``ok``, but still ride the vmapped
        # rollout — sanitize their knobs so a zero-slot lane cannot pin the
        # whole chunk at the step cap (a lane that never finishes keeps the
        # while_loop running for everyone)
        nodes_s = np.maximum(nodes, 1.0)
        mpn_s = np.maximum(mpn, 1.0)
        rpn_s = np.maximum(rpn, 1.0)
        rate_s = np.where(rate > 0, rate, 1.0)
        fast_s = np.clip(fast, 0.0, nodes_s)
        fspd_s = np.maximum(fspd, 1.0)
        pol_s = np.clip(pol, 0.0, float(len(POLICIES) - 1))
        base_n = nodes_s - fast_s
        racks = np.round(col("pNumRacks"))
        xbw = col("crossRackBw")
        osub = col("oversubscription")
        racks_s = np.clip(racks, 1.0, nodes_s)
        xbw_s = np.where(xbw > 0, xbw, np.inf)
        osub_s = np.maximum(osub, 1.0)

        cols, s = self._cols, len(self.traces)
        rep = lambda a: np.repeat(a[:, None], s, axis=1).reshape(b * s)
        rep2 = lambda a: np.repeat(a, s, axis=0)        # (b, C) -> (b*s, C)
        perjob = lambda a: np.broadcast_to(
            a[None], (b,) + a.shape).reshape(b * s, -1)
        frac = (nodes_s - 1.0) / nodes_s
        scen = {
            "arrival": perjob(cols["arrival"]) / rep(rate_s)[:, None],
            "n_maps": perjob(cols["n_maps"]),
            "n_reds": perjob(cols["n_reds"]),
            "map_cost": perjob(cols["map_cost"]),
            "red_work": perjob(cols["red_work"]),
            "shuffle": perjob(cols["shuffle"]) * rep(frac)[:, None],
            "policy": rep(pol_s),
            "slowstart": rep(slow),
            "queue": perjob(self._queue_cols),
            "queue_frac": np.tile(self._queue_fracs, (b, 1)),
            "topo_racks": rep(racks_s),
            "topo_cross_bw": rep(xbw_s),
            "topo_oversub": rep(osub_s),
        }
        if "dep" in cols:
            scen["dep"] = perjob(cols["dep"])
            scen["dep_kind"] = perjob(cols["dep_kind"])
        if np.any(fast_s > 0):
            # two class columns, fastest first: (fast fleet, baseline fleet)
            scen["map_slots"] = rep2(np.stack(
                [fast_s * mpn_s, base_n * mpn_s], 1))
            scen["red_slots"] = rep2(np.stack(
                [fast_s * rpn_s, base_n * rpn_s], 1))
            scen["speedup"] = rep2(np.stack(
                [fspd_s, np.ones_like(fspd_s)], axis=1))
        else:
            # all-homogeneous chunk: 1-D slot columns keep the lean
            # one-class kernel (no per-class wave state)
            scen["map_slots"] = rep(nodes_s * mpn_s)
            scen["red_slots"] = rep(nodes_s * rpn_s)
        out = simulate_batch(scen, n_steps=estimate_steps(scen),
                             devices=self._devs)
        shp = (b, s)
        mean_lat = out["mean_latency"].reshape(shp).mean(axis=1)
        p95_lat = out["p95_latency"].reshape(shp).mean(axis=1)
        conv = out["converged"].reshape(shp).min(axis=1)
        return {
            "w_meanLat": mean_lat.astype(np.float64),
            "w_p95Lat": p95_lat.astype(np.float64),
            "w_makespan": out["makespan"].reshape(shp).mean(axis=1).astype(np.float64),
            "w_util": out["utilization"].reshape(shp).mean(axis=1).astype(np.float64),
            "valid": (ok & (conv > 0)).astype(np.float64),
        }
