"""Topology-aware shuffle/network model (replaces the flat-pipe Eqs. 90-91).

The paper treats the network as a single flat pipe: the shuffle moves
``intermDataSize * pNumMappers * (n-1)/n`` bytes at ``cNetworkCost`` seconds
per byte (Eqs. 90-91) and each reducer serially pulls its ``1/pNumReducers``
share.  Real MapReduce clusters are rack-structured: per-node NICs feed
rack switches whose uplinks into the core are *oversubscribed*, and a
reduce wave is an incast — many concurrent flows converging on few links —
so communication pattern, not aggregate volume, sets the shuffle time
(Ceesay et al., arXiv 2005.11608).  This module is the one home of both
views:

* :func:`per_reducer_shuffle` — the flat term, hoisted verbatim from the
  single-job simulator and the cluster DES (the ``Topology.flat()``
  contract pins it bit-for-bit);
* :class:`Topology` — racks, per-link up/down bandwidth, cross-rack
  oversubscription.  Bandwidths are in units of the *nominal* flat-pipe
  rate (the bandwidth ``cNetworkCost`` implies), so a flow at rate 1.0
  transfers its flat-model shuffle seconds in exactly that many seconds
  and contention can only slow flows down, never speed them up;
* :func:`max_min_rates` / :func:`flow_rates` — host-side max-min fair
  share by progressive filling, used by the cluster DES to schedule
  concurrent shuffle flows on links exactly;
* :func:`effective_bandwidth` — the differentiable count-based
  approximation of the same fair share (uniform flows over racks), used
  by the closed-form job model and the wave simulator's vectorized
  rollout.  Divisions are double-``where`` guarded (PR-7 note): a
  ``where`` that merely selects away an ``x/0`` branch still differentiates
  to NaN, so every guarded quotient divides by a safe denominator first.

Layering: :mod:`repro.core` cannot depend on :mod:`repro.cluster` (see the
note in :mod:`repro.cluster.sched`), and this module sits below both — it
imports nothing from either package, so the single-job simulator and the
closed-form model can reach it through deferred function-level imports
without creating an import cycle.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Mapping, Sequence

import jax.numpy as jnp

__all__ = [
    "Topology",
    "per_reducer_shuffle",
    "max_min_rates",
    "flow_rates",
    "effective_bandwidth",
]

_INF = float("inf")


def per_reducer_shuffle(net_cost: float, num_reducers: int) -> float:
    """Each reducer's serialized share of the network transfer (Eqs. 90-91).

    This is the flat-pipe shuffle term, hoisted verbatim from the
    single-job simulator and the cluster DES's :func:`~repro.cluster.workload.task_costs`:
    the job's total network seconds (Eq. 91) split evenly across the
    reducers that pull it.  ``Topology.flat()`` runs reproduce it
    bit-for-bit (regression-gated).
    """
    return net_cost / num_reducers if num_reducers else 0.0


@dataclass(frozen=True)
class Topology:
    """A rack-structured cluster network.

    Nodes are assigned round-robin to ``num_racks`` racks
    (:meth:`rack_of`).  Capacities are in units of the nominal flat-pipe
    flow rate (1.0 = the bandwidth ``cNetworkCost`` implies), and
    ``float('inf')`` means "never the bottleneck":

    * ``down_bw`` / ``up_bw`` — per-node NIC receive / transmit capacity;
    * ``cross_rack_bw`` — raw capacity of one rack's aggregation downlink;
    * ``oversub`` — oversubscription factor; the *effective* rack downlink
      is ``cross_rack_bw / oversub`` (:attr:`rack_capacity`).

    A shuffle flow into a reducer on rack ``r`` draws on three links: the
    destination node's downlink, rack ``r``'s aggregation downlink for its
    cross-rack fraction ``(R-1)/R`` (map outputs are spread uniformly, so
    that share of the pull transits the core), and the shared source
    uplink pool.  :func:`flow_rates` max-min fair-shares concurrent flows
    across those links.
    """

    num_racks: int = 1
    down_bw: float = _INF
    up_bw: float = _INF
    cross_rack_bw: float = _INF
    oversub: float = 1.0

    def __post_init__(self):
        if self.num_racks < 1:
            raise ValueError(f"num_racks must be >= 1, got {self.num_racks}")
        for name in ("down_bw", "up_bw", "cross_rack_bw"):
            if not getattr(self, name) > 0:
                raise ValueError(f"{name} must be positive, got {getattr(self, name)}")
        if not self.oversub >= 1.0:
            raise ValueError(f"oversub must be >= 1, got {self.oversub}")

    @classmethod
    def flat(cls) -> "Topology":
        """The degenerate single-pipe network of Eqs. 90-91: one rack, no
        finite link, no contention.  Simulators bypass flow scheduling
        entirely for flat topologies, reproducing the seed model
        bit-for-bit."""
        return cls()

    @property
    def is_flat(self) -> bool:
        """True when no link constraint can ever bind (every flow runs at
        the nominal rate 1.0 regardless of concurrency)."""
        node_free = self.down_bw == _INF and self.up_bw == _INF
        rack_free = self.num_racks <= 1 or self.rack_capacity == _INF
        return node_free and rack_free

    @property
    def rack_capacity(self) -> float:
        """Effective aggregation-downlink capacity per rack."""
        return self.cross_rack_bw / self.oversub

    @property
    def cross_frac(self) -> float:
        """Fraction of a reducer's pull that crosses racks: map outputs are
        uniform over racks, so ``(R-1)/R`` of the bytes transit the core."""
        return (self.num_racks - 1) / self.num_racks

    def rack_of(self, node: int) -> int:
        return node % self.num_racks


def max_min_rates(
    usages: Sequence[Mapping[object, float]],
    capacities: Mapping[object, float],
    *,
    rate_cap: float = 1.0,
) -> list[float]:
    """Max-min fair rates by progressive filling.

    ``usages[i]`` maps link -> weight: flow ``i`` at rate ``r`` consumes
    ``weight * r`` of that link's capacity.  All flows' rates rise together
    from zero; when a link saturates, the flows crossing it freeze at the
    current level and the rest keep rising, up to ``rate_cap`` (the nominal
    application-limited rate — contention only slows flows down).
    Infinite-capacity links never constrain.  O(flows x links) per
    saturation round — fine for the DES's tens of concurrent flows.
    """
    n = len(usages)
    rates = [0.0] * n
    active = [i for i in range(n) if usages[i]]
    for i in range(n):
        if not usages[i]:
            rates[i] = rate_cap       # touches no finite link
    rem = {l: c for l, c in capacities.items() if c != _INF}
    level = 0.0
    while active:
        dt = rate_cap - level
        tight = None
        for link, cap in rem.items():
            w = sum(usages[i].get(link, 0.0) for i in active)
            if w <= 0.0:
                continue
            d = cap / w
            if d < dt - 1e-15:
                dt = d
                tight = link
        level += dt
        for i in active:
            rates[i] = level
        if tight is None:             # everyone reached the nominal rate
            break
        for link in rem:
            w = sum(usages[i].get(link, 0.0) for i in active)
            rem[link] = max(rem[link] - w * dt, 0.0)
        saturated = {l for l, c in rem.items() if c <= 1e-12}
        active = [i for i in active
                  if not any(l in saturated for l in usages[i])]
    return rates


def flow_rates(topo: Topology, dst_nodes: Sequence[int], num_nodes: int
               ) -> list[float]:
    """Max-min fair rates for concurrent shuffle flows, one per reducer.

    ``dst_nodes[i]`` is the node running flow ``i``'s reducer.  Each flow
    crosses its destination node's downlink (weight 1), its destination
    rack's aggregation downlink (weight = the cross-rack traffic fraction
    ``(R-1)/R``), and the shared source uplink pool of capacity
    ``num_nodes * up_bw`` (map outputs are spread over all nodes).  Rates
    are capped at the nominal 1.0.
    """
    if topo.is_flat or not dst_nodes:
        return [1.0] * len(dst_nodes)
    xr = topo.cross_frac
    capacities: dict[object, float] = {"up": num_nodes * topo.up_bw}
    usages: list[dict[object, float]] = []
    for nd in dst_nodes:
        use: dict[object, float] = {("node", nd): 1.0, "up": 1.0}
        if xr > 0.0:
            use[("rack", topo.rack_of(nd))] = xr
        capacities[("node", nd)] = topo.down_bw
        capacities[("rack", topo.rack_of(nd))] = topo.rack_capacity
        usages.append(use)
    return max_min_rates(usages, capacities, rate_cap=1.0)


def effective_bandwidth(num_racks, cross_rack_bw, oversub, num_flows):
    """Differentiable per-flow effective bandwidth under uniform incast.

    The count-based approximation of :func:`flow_rates` used where flows
    cannot be placed individually — the closed-form job model (all
    ``pNumReducers`` pulls concurrent) and the wave simulator's vmapped
    rollout (per-step running-reduce counts).  ``num_flows`` concurrent
    flows spread uniformly over ``num_racks`` racks; each rack's
    aggregation downlink (``cross_rack_bw / oversub``) carries the
    cross-rack fraction ``(R-1)/R`` of ``max(F/R, 1)`` flows, so

        bw = min(1, (cross_rack_bw/oversub) / ((R-1)/R * max(F/R, 1)))

    in units of the nominal flat-pipe rate.  Rack-level contention only:
    node NICs are exact-DES territory (see :func:`flow_rates`).  All
    inputs may be traced jnp scalars; every division is double-``where``
    guarded so gradients stay finite on the guarded branch.
    """
    racks = jnp.maximum(jnp.asarray(num_racks, dtype=jnp.result_type(float)), 1.0)
    osub = jnp.maximum(jnp.asarray(oversub, dtype=jnp.result_type(float)), 1.0)
    xbw = jnp.asarray(cross_rack_bw, dtype=jnp.result_type(float))
    flows = jnp.maximum(jnp.asarray(num_flows, dtype=jnp.result_type(float)), 0.0)

    rack_cap = xbw / osub                       # osub >= 1: safe divisor
    xr = (racks - 1.0) / racks                  # racks >= 1: safe divisor
    flows_per_rack = jnp.maximum(flows / racks, 1.0)
    demand = xr * flows_per_rack
    # contention binds only with >1 rack, >0 demand, and a finite link
    contended = (racks > 1.5) & (demand > 0.0) & jnp.isfinite(rack_cap)
    demand_safe = jnp.where(contended, jnp.where(demand > 0.0, demand, 1.0), 1.0)
    share = jnp.where(contended, rack_cap / demand_safe, 1.0)
    return jnp.minimum(share, 1.0)
