"""repro.cluster — multi-job workload simulation + vectorized capacity planning.

The paper (and ``repro.core.hadoop``) costs a *single* MapReduce job; this
subsystem answers the cluster-level questions a multi-tenant deployment
actually asks — how does a workload of concurrent jobs behave under slot
contention, and what cluster shape minimizes tail latency?  Three layers:

* :mod:`~repro.cluster.workload` — job classes over the canonical
  :data:`repro.mapreduce.jobs.JOBS` profiles and arrival traces (Poisson,
  bursty, replayed), generated at unit rate and rescaled so offered load is
  a searchable knob; :class:`StageDag` multi-stage (DAG) jobs whose
  dataflow is derived from the Table-1 identities (stage output bytes size
  the next stage's mappers), expanded by :func:`dag_trace` into
  dependency-carrying arrivals and analyzed by :func:`dag_report`
  (critical path vs makespan, a :class:`repro.spec.DagReport`).
* :mod:`~repro.cluster.network` — the topology model underneath all of it:
  :class:`Topology` (racks, per-link bandwidths, oversubscription) with
  max-min fair-shared shuffle flows for the DES, the differentiable
  :func:`effective_bandwidth` incast approximation for the wave model and
  the closed-form job model's topology hook.  ``Topology.flat()`` is
  bit-for-bit the seed's flat network.
* :mod:`~repro.cluster.sched` — the multi-job discrete-event simulator:
  FIFO / fair-share / preemptive fair-share / capacity scheduling over
  shared slot pools (kill-and-requeue preemption with a configurable
  grace timeout, per-job-class guaranteed capacities), heterogeneous
  fleets (:class:`NodeClass` speed factors), per-job queueing delay /
  latency / makespan, per-node busy time, with the single-job simulator's
  straggler / speculation / failure mechanics (and its exact behaviour on
  a one-job trace).  An optional elastic fleet
  (:class:`repro.cloud.ElasticFleet`) adds spot reclamation and
  autoscaled extra capacity with per-node online episodes for billing.
* :mod:`~repro.cluster.vector_sim` + :mod:`~repro.cluster.evaluator` — the
  wave-level JAX rollout (``while_loop`` over scheduling rounds, ``vmap``
  over scenarios, device-sharded via :mod:`repro.compat`) and
  :class:`ClusterEvaluator`, which plugs cluster knobs into every
  ``repro.search`` strategy and :class:`~repro.search.WhatIfService`.

``benchmarks/bench_cluster.py`` asserts DES<->vectorized agreement on
contention-free FIFO scenarios and measures scenario throughput;
``examples/capacity_planning.py`` is the end-to-end walkthrough.
"""

from .evaluator import ClusterEvaluator, UnfinishedWorkloadError, cluster_space
from .network import Topology, effective_bandwidth, per_reducer_shuffle
from .sched import (
    ClusterConfig,
    ClusterTaskRecord,
    JobStats,
    NodeClass,
    WorkloadResult,
    simulate_workload,
)
from .vector_sim import (
    POLICIES,
    estimate_steps,
    latency_quantile,
    pack_trace,
    simulate_batch,
)
from .workload import (
    JobArrival,
    JobClass,
    StageDag,
    StageEdge,
    WorkloadTrace,
    bursty_trace,
    dag_from_templates,
    dag_report,
    dag_trace,
    default_job_classes,
    poisson_trace,
    replayed_trace,
    rescale,
    shuffle_full,
    stage_output_bytes,
    task_costs,
)
from repro.core.hadoop.simulator import SimConfig

__all__ = [
    "JobClass",
    "JobArrival",
    "WorkloadTrace",
    "StageDag",
    "StageEdge",
    "default_job_classes",
    "dag_from_templates",
    "dag_trace",
    "dag_report",
    "poisson_trace",
    "bursty_trace",
    "replayed_trace",
    "rescale",
    "task_costs",
    "shuffle_full",
    "stage_output_bytes",
    "ClusterConfig",
    "ClusterTaskRecord",
    "JobStats",
    "NodeClass",
    "SimConfig",
    "Topology",
    "WorkloadResult",
    "simulate_workload",
    "effective_bandwidth",
    "per_reducer_shuffle",
    "POLICIES",
    "pack_trace",
    "estimate_steps",
    "latency_quantile",
    "simulate_batch",
    "ClusterEvaluator",
    "UnfinishedWorkloadError",
    "cluster_space",
]
