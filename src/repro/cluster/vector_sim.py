"""Vectorized wave-level cluster simulator (thousands of scenarios per call).

The Python DES (:mod:`repro.cluster.sched`) costs ONE (workload, cluster)
scenario per call — fine for a probe, hopeless for a capacity-planning grid.
This module rolls out the same wave mechanics as a JAX program: one
``lax.scan`` over *scheduling rounds* (global event times), ``vmap`` over
scenarios, device-sharded over the scenario axis via the :mod:`repro.compat`
shims — one compile per (step-count bucket, batch shape), exactly the
:class:`~repro.search.evaluator.ChunkedEvaluator` recipe.

Model (wave-discrete, deterministic):

* a job's launched tasks form *wave buckets* that complete together after
  one task duration — launches at an event join (and extend) the bucket;
* FIFO hands free slots to jobs in arrival order (prefix-sum allocation);
  fair-share water-fills the pool (fractional max-min shares);
* reduces honor slowstart and the two-phase semantics: waves launched
  before the job's maps finish stall, then complete at
  ``max(map_finish, start + shuffle) + work`` — the DES rule verbatim.

Fidelity: on **contention-free FIFO** scenarios (every job's wave gets its
full slot demand the moment it asks — serialized jobs, or an unsaturated
cluster) wave buckets coincide with the DES's task waves and the rollout
reproduces per-job finish times *exactly* (float32 rounding aside; the
agreement test asserts rtol 1e-3).  Under slot contention partial waves
merge into one bucket per job, a work-conserving approximation the
capacity planner accepts in exchange for ~3 orders of magnitude more
scenarios/s; ``ClusterEvaluator.exact_cost`` routes final candidates back
through the DES.

Scenario batches are dicts of arrays (B = scenarios, J = jobs):

  arrival (B, J)   n_maps (B, J)   n_reds (B, J)    map_cost (B, J)
  red_work (B, J)  shuffle (B, J)  map_slots (B,)   red_slots (B,)
  fair (B,)        slowstart (B,)

Use :func:`pack_trace` to turn a :class:`~repro.cluster.workload.
WorkloadTrace` into per-job columns, and :func:`estimate_steps` to bound
the scan length (truncated scenarios report ``converged == 0``, which the
evaluator maps to ``valid == 0`` — the exact-simulator escape hatch, never
a silent wrong number).
"""

from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from .workload import WorkloadTrace, shuffle_full, task_costs

__all__ = ["pack_trace", "estimate_steps", "simulate_batch"]

_EPS = 1e-3          # event-time / task-count slack (durations are >= ~0.1 s)
_INF = jnp.inf


def pack_trace(trace: WorkloadTrace) -> dict[str, np.ndarray]:
    """Per-job columns (J,) for one trace.  ``shuffle`` is the all-remote
    limit (:func:`~repro.cluster.workload.shuffle_full`); multiply by the
    candidate cluster's remote fraction ``(n-1)/n`` before simulating."""
    cols = {k: [] for k in ("arrival", "n_maps", "n_reds", "map_cost",
                            "red_work", "shuffle")}
    for a in trace.arrivals:
        mc, rc, _ = task_costs(a.klass)
        cols["arrival"].append(a.submit_time)
        cols["n_maps"].append(a.klass.n_maps)
        cols["n_reds"].append(a.klass.n_reduces)
        cols["map_cost"].append(mc)
        cols["red_work"].append(rc)
        cols["shuffle"].append(shuffle_full(a.klass))
    return {k: np.asarray(v, dtype=np.float64) for k, v in cols.items()}


def estimate_steps(scen: Mapping[str, np.ndarray], *, margin: float = 2.0
                   ) -> int:
    """Step *cap* covering every wave event, rounded up to a power of two
    so compile count stays bounded across workloads.  The rollout is a
    ``while_loop`` that stops at the batch's last event, so a generous cap
    costs nothing; ``margin`` absorbs wave fragmentation under contention,
    and truncation at the cap is detected, not silent (``converged``)."""
    ms = np.maximum(np.asarray(scen["map_slots"], dtype=np.float64), 1.0)
    rs = np.maximum(np.asarray(scen["red_slots"], dtype=np.float64), 1.0)
    waves = (np.ceil(scen["n_maps"] / ms[:, None]).sum(axis=1)
             + np.ceil(scen["n_reds"] / rs[:, None]).sum(axis=1))
    n_jobs = scen["arrival"].shape[-1]
    est = int(np.max(waves) * margin) + n_jobs + 8
    return 1 << (est - 1).bit_length()


# --------------------------------------------------------------------------
# core rollout (single scenario; vmapped + sharded below)
# --------------------------------------------------------------------------


def _allocate(demand, cap, fair, with_fair):
    """Hand ``cap`` free slots to per-job ``demand`` under both policies.

    Demands and allocations are whole slots (matching the DES's slot
    granularity — fractional fair shares would extend wave buckets by a
    full task duration for an epsilon of work and never converge).  Fair:
    floor of an equal split among demanding jobs, remainder spilled in
    arrival order (a one-pass max-min approximation; the DES is the
    slot-exact reference).  ``with_fair`` is static: a pure-FIFO batch
    compiles the lean prefix-only kernel (callers split rows by policy).
    """
    # FIFO: prefix allocation in arrival order (jobs are arrival-sorted).
    cum = jnp.cumsum(demand) - demand
    fifo = jnp.clip(cap - cum, 0.0, demand)
    if not with_fair:
        return fifo
    # Fair: integer equal shares, leftover spilled FIFO.
    act = demand > _EPS
    share = jnp.floor(cap / jnp.maximum(act.sum(), 1) + _EPS)
    a = jnp.minimum(demand, share)
    need = demand - a
    cum2 = jnp.cumsum(need) - need
    a = a + jnp.clip(jnp.floor(cap - a.sum() + _EPS) - cum2, 0.0, need)
    return jnp.where(fair > 0, a, fifo)


def _sim_one(s: dict, n_steps: int, with_fair: bool) -> dict:
    arrival = s["arrival"]
    n_maps = s["n_maps"]
    n_reds = s["n_reds"]
    map_cost = jnp.maximum(s["map_cost"], 1e-9)
    red_task = s["shuffle"] + s["red_work"]
    map_slots = s["map_slots"]
    red_slots = s["red_slots"]
    fair = s["fair"]
    slowstart = s["slowstart"]

    state0 = dict(
        k=jnp.asarray(0),
        t=arrival.min(),
        m_todo=n_maps * 1.0, m_run=jnp.zeros_like(arrival),
        m_end=jnp.full_like(arrival, _INF),
        r_todo=n_reds * 1.0, r_run=jnp.zeros_like(arrival),
        r_end=jnp.full_like(arrival, _INF),
        r_pre=jnp.zeros_like(arrival),
        r_pre_start=jnp.full_like(arrival, _INF),
        red_launch=jnp.full_like(arrival, _INF),
        map_fin=jnp.full_like(arrival, _INF),
        fin=jnp.full_like(arrival, _INF),
    )

    def step(st):
        t = st["t"]
        arrived = arrival <= t + _EPS

        # (a) wave buckets due now complete
        m_done_now = (st["m_run"] > _EPS) & (st["m_end"] <= t + _EPS)
        m_run = jnp.where(m_done_now, 0.0, st["m_run"])
        m_end = jnp.where(m_done_now, _INF, st["m_end"])
        r_done_now = (st["r_run"] > _EPS) & (st["r_end"] <= t + _EPS)
        r_run = jnp.where(r_done_now, 0.0, st["r_run"])
        r_end = jnp.where(r_done_now, _INF, st["r_end"])
        m_todo, r_todo = st["m_todo"], st["r_todo"]
        r_pre, r_pre_start = st["r_pre"], st["r_pre_start"]

        # (b) milestones: map fleet done, slowstart crossed, job finished
        maps_done = arrived & (m_todo <= _EPS) & (m_run <= _EPS)
        just_mf = jnp.isinf(st["map_fin"]) & maps_done
        map_fin = jnp.where(just_mf, t, st["map_fin"])

        done_cnt = n_maps - m_todo - m_run
        slow_ok = arrived & (done_cnt >= slowstart * n_maps - _EPS)
        red_launch = jnp.where(jnp.isinf(st["red_launch"]) & slow_ok, t,
                               st["red_launch"])

        # stalled pre-map-finish reduce wave resolves (the DES rule)
        resolve = just_mf & (r_pre > _EPS)
        e1 = jnp.maximum(map_fin, r_pre_start + s["shuffle"]) + s["red_work"]
        r_run = jnp.where(resolve, r_run + r_pre, r_run)
        r_end = jnp.where(resolve, e1, r_end)
        r_pre = jnp.where(resolve, 0.0, r_pre)
        r_pre_start = jnp.where(resolve, _INF, r_pre_start)

        reds_done = (r_todo <= _EPS) & (r_run <= _EPS) & (r_pre <= _EPS)
        finished = arrived & maps_done & jnp.where(n_reds > 0, reds_done, True)
        fin = jnp.where(jnp.isinf(st["fin"]) & finished, t, st["fin"])

        # (c) map slots
        m_demand = jnp.where(arrived & (m_todo > _EPS), m_todo, 0.0)
        k_m = _allocate(m_demand, map_slots - m_run.sum(), fair, with_fair)
        launched = k_m > _EPS
        m_end = jnp.where(
            launched,
            jnp.maximum(jnp.where(m_run > _EPS, m_end, -_INF), t + map_cost),
            m_end)
        m_run = m_run + k_m
        m_todo = m_todo - k_m

        # (d) reduce slots (gated on slowstart; pre-map-finish waves stall)
        r_demand = jnp.where((red_launch <= t + _EPS) & (r_todo > _EPS),
                             r_todo, 0.0)
        k_r = _allocate(r_demand, red_slots - r_run.sum() - r_pre.sum(),
                        fair, with_fair)
        launched_r = k_r > _EPS
        post = launched_r & maps_done
        pre = launched_r & ~maps_done
        r_end = jnp.where(
            post,
            jnp.maximum(jnp.where(r_run > _EPS, r_end, -_INF), t + red_task),
            r_end)
        r_run = jnp.where(post, r_run + k_r, r_run)
        r_pre = jnp.where(pre, r_pre + k_r, r_pre)
        r_pre_start = jnp.where(pre, jnp.minimum(r_pre_start, t), r_pre_start)
        r_todo = r_todo - k_r

        # (e) advance to the next event (freeze once none remain)
        t_next = jnp.minimum(
            jnp.where(arrival > t + _EPS, arrival, _INF).min(),
            jnp.minimum(m_end.min(), r_end.min()))
        t_new = jnp.where(jnp.isfinite(t_next), t_next, t)

        return dict(k=st["k"] + 1, t=t_new, m_todo=m_todo, m_run=m_run,
                    m_end=m_end, r_todo=r_todo, r_run=r_run, r_end=r_end,
                    r_pre=r_pre, r_pre_start=r_pre_start,
                    red_launch=red_launch, map_fin=map_fin, fin=fin)

    def cont(st):
        # stop at the last event — a frozen scenario pays no further steps
        return (st["k"] < n_steps) & ~jnp.isfinite(st["fin"]).all()

    st = jax.lax.while_loop(cont, step, state0)
    converged = jnp.isfinite(st["fin"]).all()
    fin = st["fin"]
    latency = fin - arrival
    busy = (n_maps * map_cost + n_reds * red_task).sum()
    span = jnp.maximum(fin.max() - arrival.min(), 1e-9)
    return dict(
        finish=fin,
        map_finish=st["map_fin"],
        latency=latency,
        converged=converged.astype(jnp.float32),
        mean_latency=latency.mean(),
        p95_latency=jnp.percentile(latency, 95.0),
        makespan=span,
        utilization=busy / (span * jnp.maximum(map_slots + red_slots, 1.0)),
    )


@functools.lru_cache(maxsize=32)
def _compiled(devs: tuple, n_steps: int, with_fair: bool):
    mesh = compat.make_mesh(list(devs), axis="search")

    def per_device(scen):
        return jax.vmap(lambda s: _sim_one(s, n_steps, with_fair))(scen)

    return jax.jit(compat.shard_map(
        per_device, mesh=mesh, in_specs=(P("search"),),
        out_specs=P("search"), check_vma=False,
    ))


def simulate_batch(
    scen: Mapping[str, np.ndarray],
    *,
    n_steps: int | None = None,
    devices=None,
) -> dict[str, np.ndarray]:
    """Roll out a batch of scenarios; returns per-scenario metrics plus
    per-job ``finish`` / ``latency`` arrays.  The batch is padded (edge-
    replicated) to the device count and sharded over it."""
    devs = tuple(devices) if devices is not None \
        else tuple(compat.default_search_devices())
    if n_steps is None:
        n_steps = estimate_steps(scen)
    b = scen["arrival"].shape[0]
    pad = (-b) % len(devs)
    arrs = {k: np.asarray(v) for k, v in scen.items()}
    if pad:
        arrs = {k: np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
                for k, v in arrs.items()}
    with_fair = bool(np.any(arrs["fair"] > 0))
    out = _compiled(devs, n_steps, with_fair)(arrs)
    return {k: np.asarray(v)[:b] for k, v in out.items()}
