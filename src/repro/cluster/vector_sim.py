"""Vectorized wave-level cluster simulator (thousands of scenarios per call).

The Python DES (:mod:`repro.cluster.sched`) costs ONE (workload, cluster)
scenario per call — fine for a probe, hopeless for a capacity-planning grid.
This module rolls out the same wave mechanics as a JAX program: one
``lax.scan`` over *scheduling rounds* (global event times), ``vmap`` over
scenarios, device-sharded over the scenario axis via the :mod:`repro.compat`
shims — one compile per (step-count bucket, batch shape), exactly the
:class:`~repro.search.evaluator.ChunkedEvaluator` recipe.

Model (wave-discrete, deterministic):

* a job's launched tasks form *wave buckets* that complete together after
  one task duration — launches at an event join (and extend) the bucket;
* **heterogeneous fleets**: slots live in per-class columns (``C`` node
  classes, fastest first); a task launched into class ``c`` runs its
  compute ``speedup[c]`` times faster (the shuffle is network-bound and
  unscaled), so each job carries one wave bucket *per class* and free
  slots fill fast classes first — the DES's free-slot order;
* FIFO hands free slots to jobs in arrival order (prefix-sum allocation);
  fair-share water-fills the pool (integer max-min shares);
* **preemptive policies** reallocate at wave boundaries: at every event
  the scheduler recomputes each job's *target* allocation over the total
  capacity — fair water-fill (``fair_preempt``) or per-queue guaranteed
  capacities with FIFO spill (``capacity``) — kills running slots above
  the target (requeued to the todo pool, slowest class first; killed work
  is lost, as in the DES's kill-and-requeue) and launches up to it.  The
  DES's ``preempt_timeout`` grace is below wave resolution: the wave
  model preempts immediately at event boundaries, which the agreement
  tolerance for preemptive scenarios absorbs;
* reduces honor slowstart and the two-phase semantics: waves launched
  before the job's maps finish stall, then complete at
  ``max(map_finish, start + shuffle) + work`` — the DES rule verbatim.

Fidelity: on **contention-free FIFO** scenarios (every job's wave gets its
full slot demand the moment it asks — serialized jobs, or an unsaturated
cluster) wave buckets coincide with the DES's task waves and the rollout
reproduces per-job finish times *exactly* (float32 rounding aside; the
agreement test asserts rtol 1e-3) — including heterogeneous fleets, where
both models fill the fast class first and each class's sub-wave completes
at its own scaled duration.  Under slot contention partial waves merge
into one bucket per (job, class), a work-conserving approximation the
capacity planner accepts in exchange for ~3 orders of magnitude more
scenarios/s; ``ClusterEvaluator.exact_cost`` routes final candidates back
through the DES.

Scenario batches are dicts of arrays (B = scenarios, J = jobs, C = node
classes, Q = capacity queues):

  arrival (B, J)    n_maps (B, J)     n_reds (B, J)     map_cost (B, J)
  red_work (B, J)   shuffle (B, J)    queue (B, J)
  map_slots (B, C)  red_slots (B, C)  speedup (B, C)
  policy (B,)       slowstart (B,)    queue_frac (B, Q)

**DAG workloads** add ``dep`` / ``dep_kind`` (B, J) columns (default -1 /
0): job ``j`` arrives once job ``dep[j]`` finishes (kind 0, barrier) or
finishes its map phase (kind 1, slowstart) — single-parent chains/trees
only; multi-parent joins go through the DES.  **Topology-aware shuffle**
adds ``topo_racks`` / ``topo_cross_bw`` / ``topo_oversub`` (B,) columns
(default 1 / inf / 1): each reduce wave's shuffle term is divided by the
rack-incast effective bandwidth
(:func:`repro.cluster.network.effective_bandwidth`) at its launch-time
concurrent-transfer count.  The bucket keeps its launch-time bandwidth —
the DES re-fair-shares continuously and is the exact reference, so
contended-incast agreement is gated at p95 (flat/uncontended rows stay
rtol-exact, the standard contract).

``policy`` is 0 = fifo, 1 = fair, 2 = fair_preempt, 3 = capacity (the
:data:`POLICIES` order).  :func:`simulate_batch` normalizes legacy inputs:
a ``fair`` (B,) column is accepted as ``policy``, 1-D ``map_slots`` /
``red_slots`` become one baseline class, ``speedup`` defaults to ones
(classes are re-sorted fastest-first), ``queue`` / ``queue_frac`` default
to a single queue.

**Elastic fleets** (:mod:`repro.cloud`) add optional columns, all
defaulting to the fixed-fleet zero:

  autoscale (B,)          0 = off, 1 = queue-depth, 2 = predicted-load
  high_water (B,)         unmet-task trigger threshold (queue policy)
  provision_latency (B,)  request -> schedulable seconds
  extra_map_slots (B,)    autoscaled capacity block (joins the LAST class)
  extra_red_slots (B,)
  billing_quantum (B,)    minimum billed seconds per capacity episode
  reclaim_rate (B, C)     spot reclaims per node-second, per class

Fleet size becomes a per-round dynamic column: the extra block turns on
one provisioning latency after its trigger and turns off at the first
event where nothing is queued and the block is idle; its billed seconds
(episodes rounded up to the billing quantum) come back as
``extra_billed_s``.  Spot reclamation enters in expectation: a class with
reclaim rate λ runs its task of length d in ``(e^{λd} - 1)/λ`` expected
seconds (restart-from-scratch under a Poisson reclaim process) — the DES
realizes actual reclaim draws and is the exact reference, so agreement on
reclaiming workloads is gated at the p95 level, not per-job (the PR 5
contract: contention-free autoscaled cases stay rtol-exact).

Use :func:`pack_trace` to turn a :class:`~repro.cluster.workload.
WorkloadTrace` into per-job columns, and :func:`estimate_steps` to bound
the scan length (truncated scenarios report ``converged == 0``, which the
evaluator maps to ``valid == 0`` — the exact-simulator escape hatch, never
a silent wrong number).
"""

from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.obs import current as _obs_current

from .network import effective_bandwidth
from .workload import WorkloadTrace, shuffle_full, task_costs

__all__ = ["POLICIES", "latency_quantile", "pack_trace", "estimate_steps",
           "simulate_batch"]

_EPS = 1e-3          # event-time / task-count slack (durations are >= ~0.1 s)
_INF = jnp.inf

#: scheduler-policy encoding of the ``policy`` scenario column — index into
#: this tuple; matches ``ClusterConfig.scheduler`` names.
POLICIES = ("fifo", "fair", "fair_preempt", "capacity")


def pack_trace(trace: WorkloadTrace) -> dict[str, np.ndarray]:
    """Per-job columns (J,) for one trace.  ``shuffle`` is the all-remote
    limit (:func:`~repro.cluster.workload.shuffle_full`); multiply by the
    candidate cluster's remote fraction ``(n-1)/n`` before simulating.
    ``queue`` is the job's capacity-scheduler queue: the index of its job
    class name in sorted order (the DES's queue enumeration)."""
    cols = {k: [] for k in ("arrival", "n_maps", "n_reds", "map_cost",
                            "red_work", "shuffle", "queue", "dep", "dep_kind")}
    qidx = {name: i for i, name in
            enumerate(sorted({a.klass.name for a in trace.arrivals}))}
    pos = {a.job_id: i for i, a in enumerate(trace.arrivals)}
    for a in trace.arrivals:
        mc, rc, _ = task_costs(a.klass)
        cols["arrival"].append(a.submit_time)
        cols["n_maps"].append(a.klass.n_maps)
        cols["n_reds"].append(a.klass.n_reduces)
        cols["map_cost"].append(mc)
        cols["red_work"].append(rc)
        cols["shuffle"].append(shuffle_full(a.klass))
        cols["queue"].append(qidx[a.klass.name])
        # DAG edge columns: index of the (single) parent, or -1; kind 0 =
        # barrier, 1 = slowstart.  The wave rollout gates arrival on the
        # parent's finish / map-finish column, which only expresses one
        # parent per job — joins stay DES territory.
        deps = a.deps
        if len(deps) > 1:
            raise ValueError(
                "the wave model supports single-parent DAG jobs; route "
                f"multi-parent job {a.job_id} through the DES")
        cols["dep"].append(pos[deps[0][0]] if deps else -1)
        cols["dep_kind"].append(
            1.0 if deps and deps[0][1] == "slowstart" else 0.0)
    return {k: np.asarray(v, dtype=np.float64) for k, v in cols.items()}


def estimate_steps(scen: Mapping[str, np.ndarray], *, margin: float = 2.0
                   ) -> int:
    """Step *cap* covering every wave event, rounded up to a power of two
    so compile count stays bounded across workloads.  The rollout is a
    ``while_loop`` that stops at the batch's last event, so a generous cap
    costs nothing; ``margin`` absorbs wave fragmentation under contention
    (doubled when preemptive rows are present — kills re-fragment waves),
    and truncation at the cap is detected, not silent (``converged``)."""
    def total(key):
        a = np.asarray(scen[key], dtype=np.float64)
        return np.maximum(a.sum(axis=-1) if a.ndim == 2 else a, 1.0)
    ms, rs = total("map_slots"), total("red_slots")
    waves = (np.ceil(scen["n_maps"] / ms[:, None]).sum(axis=1)
             + np.ceil(scen["n_reds"] / rs[:, None]).sum(axis=1))
    pol = np.asarray(scen.get("policy", scen.get("fair", 0.0)))
    if np.any(pol >= 2):
        margin = margin * 2.0
    n_jobs = scen["arrival"].shape[-1]
    est = int(np.max(waves) * margin) + n_jobs + 8
    if np.any(np.asarray(scen.get("dep", -1.0)) >= 0):
        # each DAG release costs one zero-advance step (the child arrives
        # one step after its parent's milestone lands)
        est += n_jobs
    if (np.any(np.asarray(scen.get("autoscale", 0.0)) > 0.5)
            or np.any(np.asarray(scen.get("extra_map_slots", 0.0)) > 0)):
        # elastic rows add provision/teardown events (the queue policy can
        # cycle once per burst) — waves above were counted on base slots
        # only, so this is the only extra headroom needed
        est += n_jobs + 8
    return 1 << (est - 1).bit_length()


# --------------------------------------------------------------------------
# allocation primitives (single scenario; all shapes noted for one row)
# --------------------------------------------------------------------------


def _prefix(demand, cap):
    """FIFO: prefix allocation in arrival order (jobs are arrival-sorted)."""
    cum = jnp.cumsum(demand) - demand
    return jnp.clip(cap - cum, 0.0, demand)


def _waterfill(demand, cap):
    """Fair: integer equal shares, leftover spilled FIFO (a one-pass
    max-min approximation; the DES is the slot-exact reference).  Whole
    slots throughout, matching the DES's slot granularity — fractional
    shares would extend wave buckets by a full task duration for an
    epsilon of work and never converge."""
    act = demand > _EPS
    share = jnp.floor(cap / jnp.maximum(act.sum(), 1) + _EPS)
    a = jnp.minimum(demand, share)
    need = demand - a
    cum2 = jnp.cumsum(need) - need
    return a + jnp.clip(jnp.floor(cap - a.sum() + _EPS) - cum2, 0.0, need)


def _capacity_fill(demand, cap, onehot, queue_frac):
    """Capacity scheduler target: pass 1 fills each queue up to its
    guaranteed slot count (``floor(frac * cap)``, FIFO within the queue);
    pass 2 spills the leftover capacity FIFO over the remaining demand."""
    # sum(floor(frac * cap)) <= cap because fracs sum to <= 1 (normalized
    # by _normalize), so pass 1 never over-allocates the pool
    qcap = jnp.floor(queue_frac * cap + _EPS)                 # (Q,)
    d_q = demand[:, None] * onehot                            # (J, Q)
    prev_q = ((jnp.cumsum(d_q, axis=0) - d_q) * onehot).sum(-1)
    budget = (onehot * qcap[None, :]).sum(-1)                 # (J,)
    a1 = jnp.clip(budget - prev_q, 0.0, demand)
    return a1 + _prefix(demand - a1, cap - a1.sum())


def _by_class(alloc, free_c):
    """Distribute per-job allocations over per-class free slots, fastest
    class first: job j's slots occupy the interval
    ``[cumsum(alloc)_{j-1}, cumsum(alloc)_j)`` of the concatenated
    class-ordered slot space — the order the DES's free-slot picker
    produces when it launches tasks one at a time."""
    if free_c.shape[0] == 1:       # homogeneous: keep the lean kernel
        return alloc[:, None]
    off_hi = jnp.cumsum(free_c)
    off_lo = off_hi - free_c
    start = (jnp.cumsum(alloc) - alloc)[:, None]
    stop = start + alloc[:, None]
    return jnp.clip(jnp.minimum(stop, off_hi[None, :])
                    - jnp.maximum(start, off_lo[None, :]), 0.0, None)


def _take_rev(amount, buckets):
    """Take ``amount[j]`` slots out of ``buckets[j, :]`` starting from the
    LAST class (slowest) — preemption victims lose slow slots first, the
    class-ordered analogue of the DES killing the newest launch."""
    rev = buckets[:, ::-1]
    cum = jnp.cumsum(rev, axis=1) - rev
    take = jnp.clip(amount[:, None] - cum, 0.0, rev)
    return take[:, ::-1]


def _quantize(dur, quantum):
    """Round a billing episode up to the minimum billing granularity
    (0 = per-second billing).  Double-where so quantum 0 never divides."""
    q_safe = jnp.where(quantum > 0, quantum, 1.0)
    return jnp.where(quantum > 0, jnp.ceil(dur / q_safe) * q_safe, dur)


def latency_quantile(values, q: float):
    """Linear-interpolated quantile of a 1-D array — the JAX twin of
    :func:`repro.obs.percentile_interp`, the repo's one percentile rule,
    with the same small-sample semantics: empty -> 0, one sample -> that
    sample for every ``q``, integral ranks return the order statistic
    exactly, and equal neighbours (both inf included) return the common
    value.  ``WorkloadResult.latency_quantile`` is the DES-side twin."""
    v = jnp.sort(jnp.ravel(jnp.asarray(values)))
    n = v.shape[0]
    if n == 0:
        return jnp.zeros((), dtype=jnp.result_type(float))
    if n == 1:
        return v[0]
    rank = jnp.clip(jnp.asarray(q, dtype=v.dtype), 0.0, 100.0) \
        / 100.0 * (n - 1)
    lo = jnp.floor(rank).astype(jnp.int32)
    hi = jnp.minimum(lo + 1, n - 1)
    frac = rank - lo.astype(v.dtype)
    a, b = v[lo], v[hi]
    # double-where (the PR 7 inf guard): when the neighbours agree or the
    # rank is integral the answer is ``a`` — never compute ``b - a`` there,
    # because with infinite neighbours that difference is inf - inf = nan
    same = (frac <= 0.0) | (a == b)
    delta = jnp.where(same, 0.0, b - a)
    return jnp.where(same, a, a + delta * frac)


# --------------------------------------------------------------------------
# core rollout (single scenario; vmapped + sharded below)
# --------------------------------------------------------------------------


def _sim_one(s: dict, n_steps: int, with_fair: bool, with_preempt: bool,
             with_capacity: bool, with_cloud: bool = False,
             with_dag: bool = False, with_topo: bool = False) -> dict:
    arrival = s["arrival"]
    n_maps = s["n_maps"]
    n_reds = s["n_reds"]
    map_cost = jnp.maximum(s["map_cost"], 1e-9)
    map_slots = s["map_slots"]          # (C,) per-class, fastest first
    red_slots = s["red_slots"]
    speedup = jnp.maximum(s["speedup"], 1e-9)
    policy = s["policy"]
    slowstart = s["slowstart"]
    J = arrival.shape[0]
    C = map_slots.shape[0]
    cap_m = map_slots.sum()
    cap_r = red_slots.sum()
    # per-class task durations: compute scales with the class, network not
    map_dur = map_cost[:, None] / speedup[None, :]            # (J, C)
    red_dur = s["shuffle"][:, None] + s["red_work"][:, None] / speedup[None, :]
    if with_cloud:
        # spot reclamation in expectation: restart-from-scratch under a
        # Poisson(λ) reclaim process makes a length-d task take
        # (e^{λd} - 1)/λ expected seconds (-> d as λ -> 0); stalled-reduce
        # resolution keeps the uninflated work term — reclaim rates sane
        # enough to converge make that correction second-order.  Double-
        # where so λ = 0 classes never divide by zero.
        rate = jnp.maximum(s["reclaim_rate"], 0.0)            # (C,)
        rate_safe = jnp.where(rate > 0, rate, 1.0)

        def inflate(d):
            return jnp.where(
                rate[None, :] > 0,
                jnp.expm1(rate_safe[None, :] * d) / rate_safe[None, :], d)

        map_dur = inflate(map_dur)
        red_dur = inflate(red_dur)
        x_policy = s["autoscale"]
        high_water = s["high_water"]
        x_lat = s["provision_latency"]
        x_m = s["extra_map_slots"]
        x_r = s["extra_red_slots"]
        x_quant = s["billing_quantum"]
        have_extra = (x_m + x_r) > _EPS
        # the autoscaled block joins the LAST class column: extra capacity
        # clones the baseline (slowest) class, the DES's rule
        onehot_last = (jnp.arange(C) == C - 1).astype(arrival.dtype)
    if with_capacity:
        qf = s["queue_frac"]
        onehot = (jnp.round(s["queue"])[:, None]
                  == jnp.arange(qf.shape[0])[None, :]).astype(arrival.dtype)
    if with_dag:
        # single-parent DAG edges: job j's arrival is gated on dep[j]'s
        # finish (barrier) or map-finish (slowstart) column
        dep = jnp.round(s["dep"]).astype(jnp.int32)
        dep_slow = s["dep_kind"] > 0.5
        pidx = jnp.clip(dep, 0, J - 1)

        def eligible_at(map_fin_col, fin_col):
            parent_t = jnp.where(dep_slow, map_fin_col[pidx], fin_col[pidx])
            return jnp.maximum(arrival, jnp.where(dep >= 0, parent_t, -_INF))
    if with_topo:
        def shuffle_eff(n_flows):
            # per-rack incast contention: concurrent transfers share the
            # aggregation downlinks; bw floor keeps the division benign on
            # degenerate zero-capacity rows (evaluators sanitize earlier)
            bw = effective_bandwidth(s["topo_racks"], s["topo_cross_bw"],
                                     s["topo_oversub"], n_flows)
            return s["shuffle"] / jnp.maximum(bw, 1e-9)

    def alloc_free(demand, free_c):
        """Non-preemptive policies: hand the free slots to demand."""
        a = _prefix(demand, free_c.sum())
        if with_fair:
            a = jnp.where(policy > 0.5, _waterfill(demand, free_c.sum()), a)
        return a

    def target_alloc(demand_tot, cap):
        """Preemptive policies: the ideal allocation over TOTAL capacity."""
        tgt = _waterfill(demand_tot, cap)
        if with_capacity:
            tgt = jnp.where(policy > 2.5,
                            _capacity_fill(demand_tot, cap, onehot, qf), tgt)
        return tgt

    state0 = dict(
        k=jnp.asarray(0),
        t=arrival.min(),
        m_todo=n_maps * 1.0, m_run=jnp.zeros((J, C), arrival.dtype),
        m_end=jnp.full((J, C), _INF, arrival.dtype),
        r_todo=n_reds * 1.0, r_run=jnp.zeros((J, C), arrival.dtype),
        r_end=jnp.full((J, C), _INF, arrival.dtype),
        r_pre=jnp.zeros((J, C), arrival.dtype),
        r_pre_start=jnp.full((J, C), _INF, arrival.dtype),
        red_launch=jnp.full_like(arrival, _INF),
        map_fin=jnp.full_like(arrival, _INF),
        fin=jnp.full_like(arrival, _INF),
    )
    if with_cloud:
        # predicted-load provisions up front: extra capacity is requested
        # the moment the workload starts (x_at = first arrival + latency);
        # the queue policy arms x_at when the trigger fires mid-run
        state0.update(
            x_on=jnp.zeros((), arrival.dtype),
            x_at=jnp.where((x_policy > 1.5) & have_extra,
                           arrival.min() + x_lat,
                           jnp.asarray(_INF, arrival.dtype)),
            x_t_on=jnp.asarray(_INF, arrival.dtype),
            x_billed=jnp.zeros((), arrival.dtype),
        )

    def step(st):
        t = st["t"]
        if with_dag:
            # releases land on the previous state's milestones, so a child
            # released at this instant arrives one (zero-advance) step later
            eligible = eligible_at(st["map_fin"], st["fin"])
        else:
            eligible = arrival
        arrived = eligible <= t + _EPS

        if with_cloud:
            # pending provisioning lands: the block comes online for this
            # round's allocation, one episode (x_t_on) starts billing
            turn_on = (st["x_at"] <= t + _EPS) & (st["x_on"] < 0.5)
            x_on = jnp.where(turn_on, 1.0, st["x_on"])
            x_at = jnp.where(turn_on, _INF, st["x_at"])
            x_t_on = jnp.where(turn_on, t, st["x_t_on"])
            x_billed = st["x_billed"]
            map_slots_t = map_slots + x_on * x_m * onehot_last
            red_slots_t = red_slots + x_on * x_r * onehot_last
            cap_m_t = cap_m + x_on * x_m
            cap_r_t = cap_r + x_on * x_r
        else:
            map_slots_t, red_slots_t = map_slots, red_slots
            cap_m_t, cap_r_t = cap_m, cap_r

        # (a) wave buckets due now complete (per job x class)
        m_done_now = (st["m_run"] > _EPS) & (st["m_end"] <= t + _EPS)
        m_run = jnp.where(m_done_now, 0.0, st["m_run"])
        m_end = jnp.where(m_done_now, _INF, st["m_end"])
        r_done_now = (st["r_run"] > _EPS) & (st["r_end"] <= t + _EPS)
        r_run = jnp.where(r_done_now, 0.0, st["r_run"])
        r_end = jnp.where(r_done_now, _INF, st["r_end"])
        m_todo, r_todo = st["m_todo"], st["r_todo"]
        r_pre, r_pre_start = st["r_pre"], st["r_pre_start"]

        # (b) milestones: map fleet done, slowstart crossed, job finished
        maps_done = arrived & (m_todo <= _EPS) & (m_run.sum(-1) <= _EPS)
        just_mf = jnp.isinf(st["map_fin"]) & maps_done
        map_fin = jnp.where(just_mf, t, st["map_fin"])

        done_cnt = n_maps - m_todo - m_run.sum(-1)
        slow_ok = arrived & (done_cnt >= slowstart * n_maps - _EPS)
        red_launch = jnp.where(jnp.isinf(st["red_launch"]) & slow_ok, t,
                               st["red_launch"])

        # stalled pre-map-finish reduce wave resolves (the DES rule)
        resolve = just_mf[:, None] & (r_pre > _EPS)
        if with_topo:
            # contention at resolve time: running + stalled transfers share
            # the racks (the DES recomputes continuously; this snapshot is
            # the wave approximation the agreement gate bounds at p95)
            shuf_res = shuffle_eff((r_run + r_pre).sum())
        else:
            shuf_res = s["shuffle"]
        e1 = (jnp.maximum(map_fin[:, None], r_pre_start + shuf_res[:, None])
              + s["red_work"][:, None] / speedup[None, :])
        r_end = jnp.where(
            resolve,
            jnp.maximum(jnp.where(r_run > _EPS, r_end, -_INF), e1), r_end)
        r_run = jnp.where(resolve, r_run + r_pre, r_run)
        r_pre = jnp.where(resolve, 0.0, r_pre)
        r_pre_start = jnp.where(resolve, _INF, r_pre_start)

        reds_done = ((r_todo <= _EPS) & (r_run.sum(-1) <= _EPS)
                     & (r_pre.sum(-1) <= _EPS))
        finished = arrived & maps_done & jnp.where(n_reds > 0, reds_done, True)
        fin = jnp.where(jnp.isinf(st["fin"]) & finished, t, st["fin"])

        # (c) map slots
        m_demand = jnp.where(arrived & (m_todo > _EPS), m_todo, 0.0)
        if with_preempt:
            preempt = policy > 1.5
            target = target_alloc(m_demand + m_run.sum(-1), cap_m_t)
            kill = jnp.where(preempt,
                             jnp.clip(m_run.sum(-1) - target, 0.0, None), 0.0)
            kill_c = _take_rev(kill, m_run)
            m_run = m_run - kill_c
            m_todo = m_todo + kill_c.sum(-1)     # killed work re-runs fully
            m_end = jnp.where(m_run > _EPS, m_end, _INF)
            m_demand = jnp.where(arrived & (m_todo > _EPS), m_todo, 0.0)
            free_m = map_slots_t - m_run.sum(0)
            alloc = jnp.where(
                preempt,
                jnp.clip(target - m_run.sum(-1), 0.0, m_demand),
                alloc_free(m_demand, free_m))
        else:
            free_m = map_slots_t - m_run.sum(0)
            alloc = alloc_free(m_demand, free_m)
        k_m = _by_class(alloc, free_m)
        launched = k_m > _EPS
        m_end = jnp.where(
            launched,
            jnp.maximum(jnp.where(m_run > _EPS, m_end, -_INF), t + map_dur),
            m_end)
        m_run = m_run + k_m
        m_todo = m_todo - k_m.sum(-1)

        # (d) reduce slots (gated on slowstart; pre-map-finish waves stall)
        r_demand = jnp.where((red_launch <= t + _EPS) & (r_todo > _EPS),
                             r_todo, 0.0)
        if with_preempt:
            run_tot = r_run.sum(-1) + r_pre.sum(-1)
            target = target_alloc(r_demand + run_tot, cap_r_t)
            kill = jnp.where(preempt, jnp.clip(run_tot - target, 0.0, None),
                             0.0)
            take_pre = _take_rev(kill, r_pre)      # stalled buckets first
            r_pre = r_pre - take_pre
            take_run = _take_rev(kill - take_pre.sum(-1), r_run)
            r_run = r_run - take_run
            r_todo = r_todo + (take_pre + take_run).sum(-1)
            r_pre_start = jnp.where(r_pre > _EPS, r_pre_start, _INF)
            r_end = jnp.where(r_run > _EPS, r_end, _INF)
            r_demand = jnp.where((red_launch <= t + _EPS) & (r_todo > _EPS),
                                 r_todo, 0.0)
            free_r = red_slots_t - r_run.sum(0) - r_pre.sum(0)
            alloc_r = jnp.where(
                preempt,
                jnp.clip(target - r_run.sum(-1) - r_pre.sum(-1), 0.0,
                         r_demand),
                alloc_free(r_demand, free_r))
        else:
            free_r = red_slots_t - r_run.sum(0) - r_pre.sum(0)
            alloc_r = alloc_free(r_demand, free_r)
        k_r = _by_class(alloc_r, free_r)
        launched_r = k_r > _EPS
        post = launched_r & maps_done[:, None]
        pre = launched_r & ~maps_done[:, None]
        if with_topo:
            # launch-time contention (this wave's transfers included); the
            # bucket keeps its launch-time bandwidth for its whole wave
            shuf_t = shuffle_eff((r_run + r_pre).sum() + k_r.sum())
            red_dur_t = (shuf_t[:, None]
                         + s["red_work"][:, None] / speedup[None, :])
            if with_cloud:
                red_dur_t = inflate(red_dur_t)
        else:
            red_dur_t = red_dur
        r_end = jnp.where(
            post,
            jnp.maximum(jnp.where(r_run > _EPS, r_end, -_INF), t + red_dur_t),
            r_end)
        r_run = jnp.where(post, r_run + k_r, r_run)
        r_pre = jnp.where(pre, r_pre + k_r, r_pre)
        r_pre_start = jnp.where(pre, jnp.minimum(r_pre_start, t), r_pre_start)
        r_todo = r_todo - k_r.sum(-1)

        # (e) autoscaler trigger / teardown (post-allocation, the DES's
        # evaluation points), then advance to the next event
        if with_cloud:
            unmet = (jnp.where(arrived, m_todo, 0.0).sum()
                     + jnp.where(red_launch <= t + _EPS, r_todo, 0.0).sum())
            trigger = ((x_policy > 0.5) & (x_policy < 1.5) & have_extra
                       & (unmet > high_water + _EPS)
                       & (x_on < 0.5) & jnp.isinf(x_at))
            x_at = jnp.where(trigger, t + x_lat, x_at)
            # teardown: nothing queued and the whole block idle (free slots
            # in its class cover it) -> close the billing episode.  The
            # queue policy re-arms on a later burst (x_at back to inf).
            free_m_now = map_slots_t - m_run.sum(0)
            free_r_now = red_slots_t - r_run.sum(0) - r_pre.sum(0)
            drop = ((x_on > 0.5) & (unmet <= _EPS)
                    & (free_m_now[-1] >= x_m - _EPS)
                    & (free_r_now[-1] >= x_r - _EPS))
            ep = t - jnp.where(x_on > 0.5, x_t_on, t)   # 0 when off, no inf
            x_billed = x_billed + jnp.where(drop, _quantize(ep, x_quant), 0.0)
            x_on = jnp.where(drop, 0.0, x_on)
            x_t_on = jnp.where(drop, _INF, x_t_on)

        if with_dag:
            # re-read eligibility off the UPDATED milestones so a future
            # release is a scheduled event, not a missed one
            elig_next = eligible_at(map_fin, fin)
        else:
            elig_next = arrival
        t_next = jnp.minimum(
            jnp.where(elig_next > t + _EPS, elig_next, _INF).min(),
            jnp.minimum(m_end.min(), r_end.min()))
        if with_cloud:
            t_next = jnp.minimum(t_next, x_at)
        t_new = jnp.where(jnp.isfinite(t_next), t_next, t)

        nxt = dict(k=st["k"] + 1, t=t_new, m_todo=m_todo, m_run=m_run,
                   m_end=m_end, r_todo=r_todo, r_run=r_run, r_end=r_end,
                   r_pre=r_pre, r_pre_start=r_pre_start,
                   red_launch=red_launch, map_fin=map_fin, fin=fin)
        if with_cloud:
            nxt.update(x_on=x_on, x_at=x_at, x_t_on=x_t_on, x_billed=x_billed)
        return nxt

    def cont(st):
        # stop at the last event — a frozen scenario pays no further steps
        return (st["k"] < n_steps) & ~jnp.isfinite(st["fin"]).all()

    st = jax.lax.while_loop(cont, step, state0)
    converged = jnp.isfinite(st["fin"]).all()
    fin = st["fin"]
    if with_dag:
        # a DAG child's service clock starts at its release (the DES sets
        # submit_time the same way); double-where: an unreleased child has
        # an infinite release, and inf - inf is the nan this guards against
        submit = eligible_at(st["map_fin"], st["fin"])
        sub_safe = jnp.where(jnp.isfinite(submit), submit, 0.0)
        latency = jnp.where(jnp.isfinite(submit), fin - sub_safe, _INF)
    else:
        latency = fin - arrival
    # nominal busy seconds (baseline-speed work estimate over all slots)
    busy = (n_maps * map_cost + n_reds * (s["shuffle"] + s["red_work"])).sum()
    span = jnp.maximum(fin.max() - arrival.min(), 1e-9)
    # percentile interpolates between sorted neighbours (lo + (hi-lo)*frac);
    # with >= 2 infinite latencies (unconverged scenario) that is inf - inf
    # = nan.  Double-where: the percentile only ever sees finite values, and
    # unconverged scenarios report inf — the same sentinel `finish` uses.
    lat_safe = jnp.where(jnp.isfinite(latency), latency, 0.0)
    out = dict(
        finish=fin,
        map_finish=st["map_fin"],
        latency=latency,
        converged=converged.astype(jnp.float32),
        mean_latency=latency.mean(),
        p95_latency=jnp.where(
            converged, latency_quantile(lat_safe, 95.0), jnp.inf),
        makespan=span,
        utilization=busy / (span * jnp.maximum(cap_m + cap_r, 1.0)),
    )
    if with_cloud:
        # close a still-open extra-capacity episode at the last finish (the
        # DES closes live online intervals at span the same way); inf for
        # unconverged rows, whose billed seconds are as unknown as their
        # finish times
        x_open = st["x_on"] > 0.5
        fin_max = fin.max()
        # double-where: an unconverged row has inf finish times (and an
        # open episode keeps x_t_on), so the subtraction only ever sees
        # finite operands; the result is overridden to inf below anyway
        end_safe = jnp.where(jnp.isfinite(fin_max), fin_max, 0.0)
        start_safe = jnp.where(jnp.isfinite(st["x_t_on"]), st["x_t_on"], 0.0)
        ep = jnp.where(x_open, jnp.maximum(end_safe - start_safe, 0.0), 0.0)
        billed = st["x_billed"] + jnp.where(x_open, _quantize(ep, x_quant),
                                            0.0)
        out["extra_billed_s"] = jnp.where(converged, billed, jnp.inf)
    return out


@functools.lru_cache(maxsize=32)
def _compiled(devs: tuple, n_steps: int, with_fair: bool, with_preempt: bool,
              with_capacity: bool, with_cloud: bool = False,
              with_dag: bool = False, with_topo: bool = False):
    mesh = compat.make_mesh(list(devs), axis="search")

    def per_device(scen):
        return jax.vmap(lambda s: _sim_one(
            s, n_steps, with_fair, with_preempt, with_capacity,
            with_cloud, with_dag, with_topo))(scen)

    return jax.jit(compat.shard_map(
        per_device, mesh=mesh, in_specs=(P("search"),),
        out_specs=P("search"), check_vma=False,
    ))


def _normalize(scen: Mapping[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Canonical scenario batch: legacy aliases resolved, class columns
    2-D and sorted fastest-first, queue columns defaulted."""
    arrs = {k: np.asarray(v) for k, v in scen.items()}
    b = arrs["arrival"].shape[0]
    if "policy" not in arrs:
        arrs["policy"] = arrs.pop("fair") if "fair" in arrs \
            else np.zeros(b, dtype=np.float64)
    arrs.pop("fair", None)
    for k in ("map_slots", "red_slots"):
        if arrs[k].ndim == 1:
            arrs[k] = arrs[k][:, None]
    if "speedup" not in arrs:
        arrs["speedup"] = np.ones_like(arrs["map_slots"])
    elif arrs["speedup"].ndim == 1:
        arrs["speedup"] = arrs["speedup"][:, None]
    # elastic-fleet columns (repro.cloud): default to the fixed fleet.
    # reclaim_rate is per class and must ride the fastest-first re-sort
    # with the slot columns; a 1-D rate applies to every class.
    if "reclaim_rate" not in arrs:
        arrs["reclaim_rate"] = np.zeros(arrs["map_slots"].shape,
                                        dtype=np.float64)
    else:
        rr = np.asarray(arrs["reclaim_rate"], dtype=np.float64)
        if rr.ndim == 1:
            rr = np.repeat(rr[:, None], arrs["map_slots"].shape[1], axis=1)
        arrs["reclaim_rate"] = rr
    for k in ("autoscale", "high_water", "provision_latency",
              "extra_map_slots", "extra_red_slots", "billing_quantum"):
        if k not in arrs:
            arrs[k] = np.zeros(b, dtype=np.float64)
    order = np.argsort(-arrs["speedup"], axis=1, kind="stable")
    for k in ("speedup", "map_slots", "red_slots", "reclaim_rate"):
        arrs[k] = np.take_along_axis(arrs[k], order, axis=1)
    # DAG / topology columns: defaults are the flat no-dependency network,
    # so legacy batches compile the same lean kernels (flag detection below)
    if "dep" not in arrs:
        arrs["dep"] = np.full(arrs["arrival"].shape, -1.0)
    if "dep_kind" not in arrs:
        arrs["dep_kind"] = np.zeros(arrs["arrival"].shape, dtype=np.float64)
    if "topo_racks" not in arrs:
        arrs["topo_racks"] = np.ones(b, dtype=np.float64)
    if "topo_cross_bw" not in arrs:
        arrs["topo_cross_bw"] = np.full(b, np.inf)
    if "topo_oversub" not in arrs:
        arrs["topo_oversub"] = np.ones(b, dtype=np.float64)
    if "queue" not in arrs:
        arrs["queue"] = np.zeros_like(arrs["arrival"])
    if "queue_frac" not in arrs:
        # default guarantees mirror the DES: equal shares over the queues
        # PRESENT in each row's trace (a single flat 1.0 would hand queue 0
        # a 100% guarantee and starve the rest under the capacity policy)
        qcol = np.round(arrs["queue"]).astype(np.int64)
        n_q = int(qcol.max()) + 1 if qcol.size else 1
        present = (qcol[:, :, None] == np.arange(n_q)[None, None, :]).any(1)
        arrs["queue_frac"] = present / np.maximum(
            present.sum(axis=1, keepdims=True), 1)
    else:
        # guarantees are fractions of the pool: renormalize rows that
        # oversubscribe it so pass-1 capacity fills cannot over-allocate
        qf = arrs["queue_frac"].astype(np.float64)
        tot = qf.sum(axis=1, keepdims=True)
        arrs["queue_frac"] = np.where(tot > 1.0, qf / np.maximum(tot, 1e-9), qf)
    return arrs


def simulate_batch(
    scen: Mapping[str, np.ndarray],
    *,
    n_steps: int | None = None,
    devices=None,
) -> dict[str, np.ndarray]:
    """Roll out a batch of scenarios; returns per-scenario metrics plus
    per-job ``finish`` / ``latency`` arrays.  The batch is padded (edge-
    replicated) to the device count and sharded over it.  Policy mix and
    class count are static compile keys: a pure-FIFO homogeneous batch
    compiles the same lean kernel as before the heterogeneity/preemption
    extension (callers split rows by policy, as ``bench_cluster`` does)."""
    devs = tuple(devices) if devices is not None \
        else tuple(compat.default_search_devices())
    if n_steps is None:
        n_steps = estimate_steps(scen)
    arrs = _normalize(scen)
    b = arrs["arrival"].shape[0]
    pad = (-b) % len(devs)
    if pad:
        arrs = {k: np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
                for k, v in arrs.items()}
    pol = arrs["policy"]
    with_fair = bool(np.any(pol > 0.5))
    with_preempt = bool(np.any(pol > 1.5))
    with_capacity = bool(np.any(pol > 2.5))
    with_cloud = bool(np.any(arrs["autoscale"] > 0.5)
                      or np.any(arrs["extra_map_slots"] > 0)
                      or np.any(arrs["extra_red_slots"] > 0)
                      or np.any(arrs["reclaim_rate"] > 0))
    with_dag = bool(np.any(arrs["dep"] >= 0))
    with_topo = bool(np.any(
        (arrs["topo_racks"] > 1.5)
        & np.isfinite(arrs["topo_cross_bw"]
                      / np.maximum(arrs["topo_oversub"], 1.0))))
    ob = _obs_current()
    with ob.tracer.span("vector_sim.simulate_batch", scenarios=b,
                        n_steps=n_steps):
        pre = _compiled.cache_info().misses if ob.enabled else 0
        out = _compiled(devs, n_steps, with_fair, with_preempt,
                        with_capacity, with_cloud, with_dag, with_topo)(arrs)
    if ob.enabled:
        reg = ob.registry
        reg.counter("vector_sim.batches").inc()
        reg.counter("vector_sim.scenarios").inc(b)
        reg.counter("vector_sim.scenarios_padded").inc(pad)
        if _compiled.cache_info().misses > pre:
            reg.counter("vector_sim.compiles").inc()
            ob.tracer.instant("wave-kernel compile", scope="p",
                              n_steps=n_steps)
    return {k: np.asarray(v)[:b] for k, v in out.items()}
