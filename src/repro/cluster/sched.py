"""Multi-job discrete-event cluster simulator (FIFO / fair / preemptive).

Extends the single-job Task Scheduler Simulator (paper §5(i),
:mod:`repro.core.hadoop.simulator`) to a *shared* virtual cluster: a
workload trace of jobs (:mod:`repro.cluster.workload`) contends for one
pool of map slots and one pool of reduce slots across the fleet's nodes.
Per-task costs still come from the paper's §2-§4 models, and the per-job
mechanics are the single-job simulator's, job-tagged:

* two-phase reduces — the shuffle overlaps the job's own map fleet; the
  sort/reduce/write work only runs once ALL of that job's map outputs
  exist;
* slowstart — a job's reducers launch once ``reduce_slowstart`` of its
  maps are done (a cluster-level knob here, so the planner can search it);
* stragglers / speculative execution / node failures — identical seeded
  mechanics (a node failure kills tasks of *every* job on the node and
  re-executes lost map outputs of unfinished jobs).

Heterogeneous fleets: ``ClusterConfig.node_classes`` describes a mixed
fleet (e.g. ``4 x fast + 8 x slow``) as :class:`NodeClass` entries.  A
node's *compute* durations (map work, reduce sort/reduce/write work) are
divided by its class ``speedup``; the shuffle is network-bound and is not
scaled.  The free-slot picker prefers faster nodes, so on an uncontended
fleet the fast class fills first — the same rule the vectorized wave model
uses, which is what keeps the two in agreement on contention-free cases.

Scheduling policies:

* ``fifo``  — free slots go to the earliest-submitted job with pending
  tasks of that kind (Hadoop's default JobQueueTaskScheduler).
* ``fair``  — free slots go to the job with the fewest running tasks of
  that kind: equal per-job shares, a slot-granular max-min approximation
  of the Hadoop Fair Scheduler without preemption.  ``JobClass.weight``
  is arrival frequency in generated traces, *not* a scheduling share —
  the vectorized model splits the same way, so ``evaluate`` and
  ``exact_cost`` agree on what "fair" means.
* ``fair_preempt`` — fair-share with preemption: when a demanding job has
  been held below the floor fair share for ``preempt_timeout`` seconds
  while another job runs above it, the scheduler kills the most-over-share
  job's newest task (speculative copies first) and requeues it — Hadoop
  Fair Scheduler ``minSharePreemptionTimeout`` semantics at job
  granularity.  Killed tasks re-run from scratch.
* ``capacity`` — per-job-class queues with guaranteed capacities
  (``ClusterConfig.capacities``: relative weights per class name,
  normalized over the classes present; default equal).  Free slots go
  first to the queue furthest below its guarantee (FIFO within a queue);
  a queue held below its guaranteed slot count for ``preempt_timeout``
  seconds reclaims slots by killing the newest task of the most
  over-guarantee queue.

Determinism: one seeded RNG drives every duration draw; event ties break on
a monotone sequence number, so runs are bit-identical given a seed.  With
one job the simulation reproduces
:func:`repro.core.hadoop.simulator.simulate_job` RNG-draw-for-RNG-draw
(tested, including jitter/straggler/speculation noise).  The mechanics are
deliberately *re-implemented* rather than imported: ``repro.core`` cannot
depend on ``repro.cluster``, so ``simulate_job`` cannot be a wrapper over
this engine without inverting the layering — the bit-for-bit equivalence
test is the drift guard that pins the two copies together.
"""

from __future__ import annotations

import heapq
import random
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Mapping

import numpy as np

from repro.core.hadoop.simulator import SimConfig, _duration
from repro.core.hadoop.params import HadoopParams
from repro.obs import current as _obs_current
from repro.obs import percentile_interp

from .network import Topology, flow_rates
from .workload import WorkloadTrace, task_costs

__all__ = [
    "NodeClass",
    "ClusterConfig",
    "ClusterTaskRecord",
    "JobStats",
    "WorkloadResult",
    "simulate_workload",
]

_INF = float("inf")
_EPS = 1e-9
_MAX_EVENTS = 5_000_000    # reclaim-storm bail-out (see the event loop)

_SCHEDULERS = ("fifo", "fair", "fair_preempt", "capacity")


@dataclass(frozen=True)
class NodeClass:
    """One hardware class of a mixed fleet: ``count`` nodes whose compute
    runs ``speedup`` times faster than the baseline (network is shared).

    ``hourly_price`` and ``spot`` are the :mod:`repro.cloud` pricing
    dimension: a node's capacity costs ``hourly_price`` dollars per online
    hour, and ``spot`` marks reclaimable (interruptible) capacity — a spot
    node is periodically reclaimed by the provider (exponential inter-
    reclaim times at the elastic fleet's ``reclaim_rate``) and replaced
    after the provisioning latency.  Both default to the pre-cloud
    behaviour: free, never reclaimed."""

    count: int
    speedup: float = 1.0
    hourly_price: float = 0.0
    spot: bool = False

    def __post_init__(self):
        if self.count < 0:
            raise ValueError(f"node class count must be >= 0, got {self.count}")
        if self.speedup <= 0:
            raise ValueError(f"node speedup must be positive, got {self.speedup}")
        if self.hourly_price < 0:
            raise ValueError(
                f"node hourly_price must be >= 0, got {self.hourly_price}")


@dataclass(frozen=True)
class ClusterConfig:
    """The capacity-planner's knobs: the shared cluster's shape + policy.

    ``node_classes`` describes a heterogeneous fleet; when empty the fleet
    is ``num_nodes`` baseline (speedup 1.0) nodes.  When given,
    ``num_nodes`` is derived from the class counts, so the rest of the
    code has a single source for the fleet size.
    """

    num_nodes: int = 4
    map_slots_per_node: int = 2
    reduce_slots_per_node: int = 2
    scheduler: str = "fifo"              # "fifo"|"fair"|"fair_preempt"|"capacity"
    reduce_slowstart: float = 0.05       # pReduceSlowstart, cluster-wide
    node_classes: tuple[NodeClass, ...] = ()
    preempt_timeout: float = 0.0         # grace s before an over-share kill
    capacities: tuple[tuple[str, float], ...] = ()   # class name -> rel. weight
    #: rack-structured network (:class:`repro.cluster.network.Topology`).
    #: ``None`` or :meth:`Topology.flat` is the paper's flat pipe: shuffle
    #: transfers run at the nominal rate with no contention, reproducing
    #: the pre-topology simulator bit-for-bit (regression-gated).  A
    #: contended topology schedules each reduce's transfer as a flow and
    #: max-min fair-shares the links on every flow start/finish.
    topology: Topology | None = None

    def __post_init__(self):
        if self.scheduler not in _SCHEDULERS:
            raise ValueError(f"unknown scheduler: {self.scheduler!r}")
        if self.preempt_timeout < 0:
            raise ValueError("preempt_timeout must be >= 0")
        if isinstance(self.capacities, Mapping):
            object.__setattr__(
                self, "capacities", tuple(sorted(self.capacities.items())))
        if self.node_classes:
            object.__setattr__(
                self, "num_nodes", sum(nc.count for nc in self.node_classes))

    @property
    def preemptive(self) -> bool:
        return self.scheduler in ("fair_preempt", "capacity")

    def node_table(self) -> list[tuple[float, bool, float, int]]:
        """Per-node ``(speedup, spot, hourly_price, class_index)`` rows,
        fastest class first — the node order :meth:`node_speeds`, the
        free-slot picker, and the wave model's class columns all share.
        Equal-speed classes keep their declared order (stable sort), which
        is how a (spot, on-demand) pair maps onto wave class columns."""
        if not self.node_classes:
            return [(1.0, False, 0.0, 0)] * max(1, self.num_nodes)
        rows: list[tuple[float, bool, float, int]] = []
        for ci, nc in enumerate(sorted(self.node_classes,
                                       key=lambda c: -c.speedup)):
            rows.extend([(nc.speedup, nc.spot, nc.hourly_price, ci)] * nc.count)
        return rows or [(1.0, False, 0.0, 0)]

    def node_speeds(self) -> list[float]:
        """Per-node compute speed factors, fastest class first (the order
        the free-slot picker and the wave model's class columns both use)."""
        return [row[0] for row in self.node_table()]

    @classmethod
    def from_params(cls, p: HadoopParams, *, scheduler: str = "fifo"
                    ) -> "ClusterConfig":
        return cls(num_nodes=p.pNumNodes,
                   map_slots_per_node=p.pMaxMapsPerNode,
                   reduce_slots_per_node=p.pMaxRedPerNode,
                   scheduler=scheduler,
                   reduce_slowstart=p.pReduceSlowstart)


@dataclass
class ClusterTaskRecord:
    job_id: int
    kind: str               # "map" | "reduce"
    index: int
    node: int
    start: float
    end: float
    speculative: bool = False
    killed: bool = False
    #: reduces only: when the task's own work began — the later of its
    #: network transfer finishing and its job's maps finishing.  The trace
    #: builder (repro.obs.destrace) renders [start, shuffle_end] as the
    #: overlapped "network" phase.  0.0 for maps and killed tasks.
    shuffle_end: float = 0.0
    #: why a killed record died:
    #: "preempt" | "failure" | "superseded" | "reclaim" (spot reclamation —
    #: unlike "failure" the node returns after the provisioning latency).
    kill_reason: str = ""


@dataclass
class JobStats:
    """Per-job service accounting on the shared cluster."""

    job_id: int
    name: str
    submit_time: float
    first_launch: float = _INF   # first task launch (queueing delay ends)
    map_finish: float = _INF
    finish: float = _INF
    n_maps: int = 0
    n_reduces: int = 0

    @property
    def queueing_delay(self) -> float:
        return self.first_launch - self.submit_time

    @property
    def latency(self) -> float:
        """Submit -> last task done (the planner's per-job cost)."""
        return self.finish - self.submit_time

    @property
    def makespan(self) -> float:
        """First launch -> last task done (the single-job notion)."""
        return self.finish - self.first_launch


@dataclass
class WorkloadResult:
    jobs: list[JobStats]
    makespan: float                       # absolute time of the last finish
    node_busy_s: list[float] = field(default_factory=list)
    slot_utilization: float = 0.0
    num_speculative_launched: int = 0
    num_speculative_won: int = 0
    num_failure_reruns: int = 0
    num_preempted: int = 0
    #: tasks killed + completed map outputs lost to spot reclamations
    #: (the elastic-fleet sibling of ``num_failure_reruns``)
    num_reclaimed: int = 0
    #: jobs whose ``finish`` is still inf when the event queue drained (e.g.
    #: every node failed) — latency aggregates are inf then, and this count
    #: is the explicit signal consumers must check instead of discovering
    #: the inf downstream.
    n_unfinished: int = 0
    records: list[ClusterTaskRecord] = field(default_factory=list)
    #: per-node ``[(online_from, online_to), ...]`` capacity episodes: base
    #: nodes open at t=0; failure/reclaim/autoscaler-teardown closes an
    #: episode, replacement/provisioning opens a new one.  This is the
    #: billing input (:func:`repro.cloud.bill_workload`) and the slot-
    #: utilization denominator.
    node_online: list[list[tuple[float, float]]] = field(default_factory=list)

    def latencies(self) -> np.ndarray:
        return np.asarray([j.latency for j in self.jobs])

    @property
    def mean_latency(self) -> float:
        return float(self.latencies().mean()) if self.jobs else 0.0

    def latency_quantile(self, q: float) -> float:
        """Linear-interpolated latency quantile (``q`` in [0, 100]) — the
        repo's single percentile rule (:func:`repro.obs.percentile_interp`),
        shared with the wave model's ``latency_quantile``.  inf when any
        job never finished: interpolating between infs would yield nan, so
        the unfinished workload is reported as an explicit inf instead."""
        if not self.jobs:
            return 0.0
        lat = self.latencies()
        if not np.isfinite(lat).all():
            return _INF
        return float(percentile_interp(np.sort(lat).tolist(), q))

    @property
    def p95_latency(self) -> float:
        return self.latency_quantile(95.0)


class _Job:
    """Mutable per-job scheduler state (single-job simulator state, tagged)."""

    __slots__ = (
        "jid", "name", "submit", "n_maps", "n_reds", "map_cost", "red_cost",
        "shuffle", "arrived", "pending_maps", "pending_reduces",
        "completed_maps", "completed_reduces", "map_output_node",
        "map_copies", "red_copies", "finished_map_durs", "finished_red_durs",
        "reducers_launched", "running_maps", "running_reds", "stats",
    )

    def __init__(self, jid: int, arrival, num_nodes: int):
        jc = arrival.klass
        self.jid = jid
        self.name = jc.name
        self.submit = arrival.submit_time
        self.n_maps = jc.n_maps
        self.n_reds = jc.n_reduces
        self.map_cost, self.red_cost, self.shuffle = task_costs(
            jc, num_nodes=num_nodes)
        self.arrived = False
        self.pending_maps = deque(range(self.n_maps))
        self.pending_reduces = deque(range(self.n_reds))
        self.completed_maps: set[int] = set()
        self.completed_reduces: set[int] = set()
        self.map_output_node: dict[int, int] = {}
        self.map_copies: dict[int, list[int]] = {}
        self.red_copies: dict[int, list[int]] = {}
        self.finished_map_durs: list[float] = []
        self.finished_red_durs: list[float] = []
        self.reducers_launched = self.n_maps == 0   # no maps -> no slowstart
        self.running_maps = 0
        self.running_reds = 0
        self.stats = JobStats(jid, self.name, self.submit,
                              n_maps=self.n_maps, n_reduces=self.n_reds)

    def maps_done(self) -> bool:
        return len(self.completed_maps) == self.n_maps

    def done(self) -> bool:
        return (self.maps_done()
                and len(self.completed_reduces) == self.n_reds)

    def running(self, kind: str) -> int:
        return self.running_maps if kind == "map" else self.running_reds

    def pending(self, kind: str) -> deque:
        return self.pending_maps if kind == "map" else self.pending_reduces

    def demands(self, kind: str) -> bool:
        """Arrived and holds or wants a ``kind`` slot (the share divisor)."""
        if not self.arrived:
            return False
        if kind == "map":
            return bool(self.pending_maps) or self.running_maps > 0
        return ((self.reducers_launched and bool(self.pending_reduces))
                or self.running_reds > 0)


def simulate_workload(
    trace: WorkloadTrace,
    cluster: ClusterConfig = ClusterConfig(),
    sim: SimConfig = SimConfig(),
    elastic=None,
) -> WorkloadResult:
    """Run a workload trace on a shared virtual cluster.

    ``elastic`` adds the :mod:`repro.cloud` provisioning lifecycle.  It is
    duck-typed (``repro.cluster`` must not depend on ``repro.cloud``):
    anything with the :class:`repro.cloud.ElasticFleet` attributes —
    ``policy_code`` (0 off / 1 queue-depth / 2 predicted-load),
    ``max_extra_nodes``, ``high_water``, ``provision_latency``,
    ``reclaim_rate`` (spot reclaims per node-second) and ``seed`` — works.
    Spot nodes (``NodeClass.spot``) are reclaimed at exponential intervals
    (kill + requeue with ``kill_reason="reclaim"``, lost map outputs
    re-executed, exactly the failure machinery) and replaced after the
    provisioning latency; autoscaled extra nodes clone the baseline
    (slowest) class and come online/offline as the policy demands.  The
    per-node capacity episodes land in ``WorkloadResult.node_online``.
    """
    _t_wall = time.perf_counter()
    rng = random.Random(sim.seed)

    # elastic-fleet knobs (absent -> the fixed-fleet fast path: no extra
    # nodes, no reclaim events, and the reclaim RNG stream is never drawn,
    # keeping fixed-fleet runs bit-identical to the pre-cloud simulator)
    el_policy = int(getattr(elastic, "policy_code", 0)) if elastic else 0
    el_extra = (int(getattr(elastic, "max_extra_nodes", 0))
                if elastic is not None and el_policy > 0 else 0)
    el_high = float(getattr(elastic, "high_water", 0.0)) if elastic else 0.0
    el_lat = (float(getattr(elastic, "provision_latency", 0.0))
              if elastic is not None else 0.0)
    el_rate = (float(getattr(elastic, "reclaim_rate", 0.0))
               if elastic is not None else 0.0)
    el_seed = int(getattr(elastic, "seed", 0)) if elastic else 0

    n_base = max(1, cluster.num_nodes)
    n_nodes = n_base + el_extra
    table = cluster.node_table()
    if len(table) != n_base:       # num_nodes floor for degenerate configs
        table = (table + [(1.0, False, 0.0, 0)] * n_base)[:n_base]
    # autoscaled nodes clone the baseline (slowest) class's speed and bill
    # as on-demand capacity: elastic top-up is never reclaimable
    base_speed, _, _, base_cls = table[-1]
    table = table + [(base_speed, False, 0.0, base_cls)] * el_extra
    speed = [row[0] for row in table]
    spot = [row[1] for row in table]
    cls_idx = [row[3] for row in table]
    is_extra = [nd >= n_base for nd in range(n_nodes)]

    map_slots = [cluster.map_slots_per_node] * n_base + [0] * el_extra
    red_slots = [cluster.reduce_slots_per_node] * n_base + [0] * el_extra
    # configured capacity per node (map_slots/red_slots are *free* counts);
    # zeroed when a node fails, so shares and utilization see live capacity
    cap_map = list(map_slots)
    cap_red = list(red_slots)
    fail_time = [_INF] * n_nodes
    # capacity episodes: base nodes online from t=0, extras offline until
    # provisioned.  Closed on failure/reclaim/teardown, reopened on
    # replacement/provisioning; the summary closes live episodes at span.
    online_from: list[float | None] = [0.0] * n_base + [None] * el_extra
    node_online: list[list[tuple[float, float]]] = [[] for _ in range(n_nodes)]
    # reclaim draws come from their own stream so a priced-but-stable fleet
    # replays the exact task-duration draw sequence of the fixed fleet
    rng_reclaim = random.Random((el_seed + 1) * 1_000_003 + sim.seed * 7919)
    reclaiming = el_rate > 0 and any(spot)
    scaling = el_policy > 0 and el_extra > 0
    policy = cluster.scheduler
    fair = policy in ("fair", "fair_preempt")
    capacity = policy == "capacity"

    jobs = [_Job(a.job_id, a, n_nodes) for a in trace.arrivals]
    by_id = {j.jid: j for j in jobs}
    res = WorkloadResult(jobs=[j.stats for j in jobs], makespan=0.0)

    # ---- DAG dependencies: a job with deps is held until released ----
    # dep edges ((parent_job_id, "barrier"|"slowstart") on JobArrival.deps)
    # gate a job's arrival: "barrier" releases when the parent finishes,
    # "slowstart" when the parent's map phase completes (its reduce wave —
    # the child's input producer in a pipelined Hive/Pig plan — is already
    # launched then, mirroring reduce_slowstart's map/shuffle overlap one
    # level up).  The released job re-arrives at the release time, which
    # becomes its submit time for queueing/latency accounting.
    dep_children: dict[int, list[tuple[int, str]]] = {}
    dep_count: dict[int, int] = {}
    for a in trace.arrivals:
        for parent_id, edge_kind in getattr(a, "deps", ()):
            if edge_kind not in ("barrier", "slowstart"):
                raise ValueError(f"unknown DAG edge kind: {edge_kind!r}")
            if parent_id not in by_id or parent_id == a.job_id:
                raise ValueError(
                    f"job {a.job_id} depends on unknown job {parent_id}")
            dep_children.setdefault(parent_id, []).append((a.job_id, edge_kind))
            dep_count[a.job_id] = dep_count.get(a.job_id, 0) + 1
    fired_edges: set[tuple[int, int, str]] = set()

    def release_children(parent_jid: int, now: float, edge_kind: str) -> None:
        for child_jid, k in dep_children.get(parent_jid, ()):
            if k != edge_kind or (parent_jid, child_jid, k) in fired_edges:
                continue
            fired_edges.add((parent_jid, child_jid, k))
            dep_count[child_jid] -= 1
            if dep_count[child_jid] == 0:
                child = by_id[child_jid]
                t_rel = max(child.submit, now)
                child.submit = t_rel
                child.stats.submit_time = t_rel
                push(t_rel, 1, "arrive", child_jid)

    # ---- topology-aware shuffle (contended racks only) ----
    # With a contended ClusterConfig.topology every reduce's transfer is a
    # flow: `flows[uid] = [remaining nominal seconds, dst node, rate]`, and
    # rates are recomputed as the max-min fair share on every flow start /
    # finish / kill.  Completion events are invalidated by comparing the
    # popped time against flow_end (the same lazy-invalidation trick the
    # rescheduled-reduce guard uses).  Flat/absent topologies never touch
    # any of this, keeping the seed code paths (and results) bit-for-bit.
    topo = cluster.topology
    contended = topo is not None and not topo.is_flat
    flows: dict[int, list] = {}
    flow_end: dict[int, float] = {}   # uid -> currently scheduled finish
    flow_done: dict[int, float] = {}  # uid -> actual transfer finish time
    flows_at = 0.0                    # clock of the last rate update

    def update_flows(now: float) -> None:
        nonlocal flows_at
        dt = now - flows_at
        if dt > 0.0:
            for f in flows.values():
                f[0] = max(f[0] - f[2] * dt, 0.0)
        flows_at = now

    def reassign_flows(now: float) -> None:
        rates = flow_rates(topo, [f[1] for f in flows.values()], n_nodes)
        for (fuid, f), rate in zip(flows.items(), rates):
            f[2] = rate
            end = now + f[0] / rate
            flow_end[fuid] = end
            push(end, 2, "flow", fuid)

    def start_flow(uid: int, node: int, nominal: float, now: float) -> None:
        update_flows(now)
        flows[uid] = [nominal, node, 1.0]
        reassign_flows(now)

    def drop_flow(uid: int, now: float) -> None:
        """Forget a killed/finished transfer; survivors speed up."""
        flow_done.pop(uid, None)
        if uid in flows:
            update_flows(now)
            del flows[uid]
            flow_end.pop(uid, None)
            reassign_flows(now)

    # capacity queues: one per job-class name; guaranteed share = the
    # class's weight (ClusterConfig.capacities, default 1.0) normalized
    # over the classes present in this trace.
    queue_names = sorted({j.name for j in jobs})
    cap_weights = dict(cluster.capacities)
    w_total = sum(cap_weights.get(q, 1.0) for q in queue_names) or 1.0
    guarantee_frac = {q: cap_weights.get(q, 1.0) / w_total for q in queue_names}

    # running[uid] = (jid, kind, index, node, start, end, speculative)
    running: dict[int, tuple] = {}
    reduce_durs: dict[int, tuple[float, float]] = {}   # uid -> (shuffle, work)
    uid_counter = 0
    seq_counter = 0
    clock = 0.0

    # Event heap: (time, order_class, seq, tag, payload).  order_class makes
    # simultaneous events deterministic: failures first, then arrivals, then
    # task completions (matching the single-job simulator, which applies a
    # failure before any completion at the same timestamp), then preemption
    # checks (a completion at the same instant may resolve the starvation).
    events: list[tuple] = []

    def push(time: float, order_class: int, tag: str, payload: int) -> None:
        nonlocal seq_counter
        heapq.heappush(events, (time, order_class, seq_counter, tag, payload))
        seq_counter += 1

    for ftime, fnode in sorted(sim.node_failures):
        push(ftime, 0, "fail", fnode)
    for j in jobs:
        if dep_count.get(j.jid, 0) == 0:      # DAG children wait for release
            push(j.submit, 1, "arrive", j.jid)
    if reclaiming:
        for nd in range(n_base):
            if spot[nd]:
                push(rng_reclaim.expovariate(el_rate), 0, "reclaim", nd)
    # predicted-load policy: the fleet-sizing decision is made up front
    # (from the closed-form model), so the extra capacity is requested the
    # moment the workload starts and lands one provisioning latency later
    extra_online = False
    extra_pending = False
    if scaling and el_policy == 2 and jobs:
        extra_pending = True
        push(min(j.submit for j in jobs) + el_lat, 1, "provision", 0)

    def workload_done() -> bool:
        return all(j.stats.finish != _INF for j in jobs)

    def set_offline(nd: int, now: float) -> None:
        if online_from[nd] is not None:
            node_online[nd].append((online_from[nd], now))
            online_from[nd] = None

    def free_slot(slots: list[int], prefer_not: int = -1) -> int:
        # fastest class first, then base fleet before autoscaled extras
        # (extras drain first, so teardown can catch them idle), then class
        # declaration order for equal-speed classes (spot before on-demand
        # in a cloud fleet), then the homogeneous tie-break: most free
        # slots, then node index.  This is the wave model's class-ordered
        # allocation rule — what keeps the two simulators in agreement on
        # contention-free cases.
        order = sorted(range(n_nodes),
                       key=lambda nd: (nd == prefer_not, -speed[nd],
                                       is_extra[nd], cls_idx[nd], -slots[nd]))
        for nd in order:
            if slots[nd] > 0:
                return nd
        return -1

    def launch(job: _Job, kind: str, index: int, now: float, *,
               speculative: bool = False, avoid_node: int = -1) -> bool:
        nonlocal uid_counter
        slots = map_slots if kind == "map" else red_slots
        node = free_slot(slots, prefer_not=avoid_node)
        if node < 0:
            return False
        slots[node] -= 1
        uid = uid_counter
        uid_counter += 1
        job.stats.first_launch = min(job.stats.first_launch, now)
        if kind == "map":
            dur = _duration(job.map_cost, rng, sim) / speed[node]
            end = now + dur
            running[uid] = (job.jid, kind, index, node, now, end, speculative)
            job.map_copies.setdefault(index, []).append(uid)
            job.running_maps += 1
            push(end, 2, "task", uid)
        else:
            # shuffle is network-bound (not node-scaled); the sort/reduce/
            # write work runs on the node's cores and scales with its class
            sh = _duration(job.shuffle, rng, sim) if job.shuffle > 0 else 0.0
            wk = (_duration(job.red_cost, rng, sim) / speed[node]
                  if job.red_cost > 0 else 0.0)
            reduce_durs[uid] = (sh, wk)
            job.red_copies.setdefault(index, []).append(uid)
            job.running_reds += 1
            if contended and sh > 0.0:
                # the transfer is a flow on the topology: its completion
                # arrives via a "flow" event at a fair-share-dependent time
                running[uid] = (job.jid, kind, index, node, now, _INF, speculative)
                start_flow(uid, node, sh, now)
            elif job.maps_done():
                end = now + sh + wk
                running[uid] = (job.jid, kind, index, node, now, end, speculative)
                push(end, 2, "task", uid)
            else:
                # Shuffle overlaps the job's maps; completion scheduled when
                # its last map output lands.
                running[uid] = (job.jid, kind, index, node, now, _INF, speculative)
        if speculative:
            res.num_speculative_launched += 1
        return True

    def schedule_waiting_reduces(job: _Job, now: float) -> None:
        for uid, (jid, kind, index, node, start, end, spec) in list(running.items()):
            if jid == job.jid and kind == "reduce" and end == _INF:
                if uid in flows:
                    continue    # transfer still in flight; its flow event resolves
                sh, wk = reduce_durs[uid]
                sh_done = flow_done[uid] if uid in flow_done else start + sh
                new_end = max(now, sh_done) + wk
                running[uid] = (jid, kind, index, node, start, new_end, spec)
                push(new_end, 2, "task", uid)

    # ---------------- scheduling policy ----------------

    def queue_running(kind: str) -> dict[str, int]:
        out = {q: 0 for q in queue_names}
        for j in jobs:
            out[j.name] += j.running(kind)
        return out

    def kind_capacity(kind: str) -> int:
        return sum(cap_map) if kind == "map" else sum(cap_red)

    def pick_job(kind: str):
        """The job the next free ``kind`` slot goes to, or None."""
        qrun = queue_running(kind) if capacity else None
        cap = kind_capacity(kind) if capacity else 0
        best = None
        best_key = None
        for j in jobs:
            if not j.arrived:
                continue
            if kind == "map":
                if not j.pending_maps:
                    continue
                load = j.running_maps
            else:
                if not (j.reducers_launched and j.pending_reduces):
                    continue
                load = j.running_reds
            if capacity:
                # queues furthest below their guaranteed share first,
                # FIFO within a queue (Hadoop CapacityScheduler ordering)
                guar = max(guarantee_frac[j.name] * cap, _EPS)
                key = (qrun[j.name] / guar, j.submit, j.jid)
            elif fair:
                # fair = equal per-job shares of each pool (JobClass.weight
                # is arrival frequency, not a scheduling share — the vector
                # model splits the same way, so evaluate() and exact_cost()
                # agree on what "fair" means)
                key = (load, j.submit, j.jid)
            else:
                key = (j.submit, j.jid)
            if best_key is None or key < best_key:
                best, best_key = j, key
        return best

    def fill_slots(now: float) -> None:
        for kind, slots in (("map", map_slots), ("reduce", red_slots)):
            while sum(slots) > 0:
                j = pick_job(kind)
                if j is None:
                    break
                pend = j.pending_maps if kind == "map" else j.pending_reduces
                if not launch(j, kind, pend[0], now):
                    break
                pend.popleft()

    # ---------------- autoscaler (elastic fleets) ----------------

    def unmet_demand() -> int:
        """Queued tasks the cluster has no slot for right now — pending maps
        of arrived jobs plus pending reduces past slowstart (the wave
        model's trigger signal, evaluated at the same post-allocation
        points, which is what lets the two simulators agree on *when* the
        autoscaler acts)."""
        q = 0
        for j in jobs:
            if not j.arrived:
                continue
            q += len(j.pending_maps)
            if j.reducers_launched:
                q += len(j.pending_reduces)
        return q

    def autoscale_check(now: float) -> None:
        nonlocal extra_online, extra_pending
        if not scaling:
            return
        q = unmet_demand()
        if (el_policy == 1 and not extra_online and not extra_pending
                and q > el_high + _EPS):
            extra_pending = True
            push(now + el_lat, 1, "provision", 0)
        if extra_online and q == 0 and all(
                map_slots[nd] == cap_map[nd] and red_slots[nd] == cap_red[nd]
                for nd in range(n_base, n_nodes)):
            # nothing queued and every extra node idle: release the block
            # (one billing episode per provision/teardown cycle)
            for nd in range(n_base, n_nodes):
                set_offline(nd, now)
                map_slots[nd] = red_slots[nd] = 0
                cap_map[nd] = cap_red[nd] = 0
            extra_online = False

    def maybe_speculate(now: float) -> None:
        if not sim.speculative_execution:
            return
        for uid, (jid, kind, index, node, start, end, spec) in list(running.items()):
            if spec or end == _INF:
                continue
            j = by_id[jid]
            if kind == "map":
                durs, completed, copies = (j.finished_map_durs,
                                           j.completed_maps, j.map_copies)
            else:
                if not j.maps_done():    # stalled shuffle != straggler
                    continue
                durs, completed, copies = (j.finished_red_durs,
                                           j.completed_reduces, j.red_copies)
            if len(durs) < sim.speculative_min_completed:
                continue
            if index in completed or len(copies.get(index, [])) > 1:
                continue
            mean = sum(durs) / len(durs)
            # reduces measure from the job's map finish: shuffle stall is
            # waiting, not work (mirrors the single-job simulator)
            eff_start = start if kind == "map" \
                else max(start, j.stats.map_finish)
            projected = end - eff_start
            if projected > sim.speculative_slowdown_thr * mean and now > eff_start:
                launch(j, kind, index, now, speculative=True, avoid_node=node)

    # ---------------- preemption (fair_preempt / capacity) ----------------

    # starved_since[kind]: when the current starvation episode began, or
    # None.  A "preempt" event is scheduled episode-start + timeout; kills
    # only happen if the episode is still live when it fires.
    starved_since: dict[str, float | None] = {"map": None, "reduce": None}
    _KIND_ID = {"map": 0, "reduce": 1}
    _ID_KIND = {0: "map", 1: "reduce"}

    def fair_floor(kind: str) -> int:
        n_demand = sum(1 for j in jobs if j.demands(kind))
        return kind_capacity(kind) // n_demand if n_demand else 0

    def starved_entities(kind: str) -> bool:
        """Is any demanding entity below its floor share with work queued?"""
        if capacity:
            qrun = queue_running(kind)
            cap = kind_capacity(kind)
            for q in queue_names:
                floor_q = int(guarantee_frac[q] * cap)
                if qrun[q] >= floor_q:
                    continue
                for j in jobs:
                    if j.name == q and j.arrived and j.pending(kind) and (
                            kind == "map" or j.reducers_launched):
                        return True
            return False
        floor = fair_floor(kind)
        for j in jobs:
            if not (j.arrived and j.pending(kind)):
                continue
            if kind == "reduce" and not j.reducers_launched:
                continue
            if j.running(kind) < floor:
                return True
        return False

    def pick_victim(kind: str) -> int | None:
        """The uid to kill: newest task (speculative copies first) of the
        entity furthest over its floor share / guarantee."""
        if capacity:
            qrun = queue_running(kind)
            cap = kind_capacity(kind)
            over = {q: qrun[q] - int(guarantee_frac[q] * cap)
                    for q in queue_names}
            victim_q = max((q for q in queue_names if over[q] > 0),
                           key=lambda q: (over[q], q), default=None)
            if victim_q is None:
                return None
            member = lambda jid: by_id[jid].name == victim_q
        else:
            floor = fair_floor(kind)
            over_jobs = [j for j in jobs if j.running(kind) > floor]
            if not over_jobs:
                return None
            victim_j = max(over_jobs,
                           key=lambda j: (j.running(kind) - floor, -j.jid))
            member = lambda jid: jid == victim_j.jid
        best_uid, best_key = None, None
        for uid, (jid, k, index, node, start, end, spec) in running.items():
            if k != kind or not member(jid):
                continue
            key = (spec, start, uid)     # speculative first, then newest
            if best_key is None or key > best_key:
                best_uid, best_key = uid, key
        return best_uid

    def kill_task(uid: int, now: float) -> None:
        jid, kind, index, node, start, end, spec = running.pop(uid)
        j = by_id[jid]
        (map_slots if kind == "map" else red_slots)[node] += 1
        copies = j.map_copies if kind == "map" else j.red_copies
        if uid in copies.get(index, []):
            copies[index].remove(uid)
        if kind == "map":
            j.running_maps -= 1
            completed, pending = j.completed_maps, j.pending_maps
        else:
            j.running_reds -= 1
            completed, pending = j.completed_reduces, j.pending_reduces
            reduce_durs.pop(uid, None)
            drop_flow(uid, now)
        res.records.append(
            ClusterTaskRecord(jid, kind, index, node, start, now, spec,
                              killed=True, kill_reason="preempt"))
        alive_copies = any(c in running for c in copies.get(index, []))
        if index not in completed and index not in pending and not alive_copies:
            pending.append(index)

    def do_preempt(kind: str, now: float) -> None:
        while starved_entities(kind):
            uid = pick_victim(kind)
            if uid is None:
                break
            kill_task(uid, now)
            res.num_preempted += 1
            fill_slots(now)       # pick_job hands the slot to the starved job

    def check_preempt(now: float) -> None:
        if not cluster.preemptive:
            return
        for kind in ("map", "reduce"):
            if starved_entities(kind) and pick_victim(kind) is not None:
                if starved_since[kind] is None:
                    starved_since[kind] = now
                    push(now + cluster.preempt_timeout, 3, "preempt",
                         _KIND_ID[kind])
            else:
                starved_since[kind] = None

    # ---------------- failures / spot reclamations ----------------

    def evict_node(enode: int, etime: float, reason: str) -> int:
        """Take a node out of service: kill its running tasks (requeued,
        recorded with ``kill_reason=reason``), resurrect completed map
        outputs unfinished jobs still need, zero its capacity and close its
        online episode.  Returns the number of tasks + outputs affected."""
        n_lost = 0
        for uid, (jid, kind, index, node, start, end, spec) in list(running.items()):
            if node != enode:
                continue
            del running[uid]
            j = by_id[jid]
            copies = j.map_copies if kind == "map" else j.red_copies
            if uid in copies.get(index, []):
                copies[index].remove(uid)
            if kind == "map":
                j.running_maps -= 1
                if index not in j.completed_maps and index not in j.pending_maps:
                    j.pending_maps.append(index)
            else:
                j.running_reds -= 1
                reduce_durs.pop(uid, None)      # killed copy: drop its draws
                drop_flow(uid, etime)
                if (index not in j.completed_reduces
                        and index not in j.pending_reduces):
                    j.pending_reduces.append(index)
            res.records.append(
                ClusterTaskRecord(jid, kind, index, node, start, etime,
                                  spec, killed=True, kill_reason=reason))
            n_lost += 1
        # Completed map outputs on the evicted node are lost for every job
        # whose reducers still need them.
        for j in jobs:
            if len(j.completed_reduces) >= j.n_reds:
                continue
            for midx, mnode in list(j.map_output_node.items()):
                if mnode == enode and midx in j.completed_maps:
                    j.completed_maps.discard(midx)
                    del j.map_output_node[midx]
                    if midx not in j.pending_maps:
                        j.pending_maps.append(midx)
                    n_lost += 1
        map_slots[enode] = 0
        red_slots[enode] = 0
        cap_map[enode] = 0
        cap_red[enode] = 0
        set_offline(enode, etime)
        return n_lost

    def fail_node(fnode: int, ftime: float) -> None:
        res.num_failure_reruns += evict_node(fnode, ftime, "failure")
        fail_time[fnode] = min(fail_time[fnode], ftime)

    def finish_job(job: _Job, now: float) -> None:
        if job.done() and not job.pending_maps and not job.pending_reduces:
            job.stats.finish = now
            # slowstart edges release here too (idempotent) so a parent that
            # never reports a map-phase transition still frees its children
            release_children(job.jid, now, "slowstart")
            release_children(job.jid, now, "barrier")

    # ---------------- event loop ----------------

    n_popped = 0
    while events:
        if n_popped >= _MAX_EVENTS:
            # pathological elastic configs (a reclaim rate so high tasks
            # never survive an online window) would cycle reclaim/replace
            # events forever — bail and let n_unfinished flag the run
            break
        n_popped += 1
        t, oc, _seq, tag, payload = heapq.heappop(events)
        clock = max(clock, t)

        if tag == "fail":
            fail_node(payload, t)
            fill_slots(clock)
            check_preempt(clock)
            autoscale_check(clock)
            continue

        if tag == "arrive":
            by_id[payload].arrived = True
            fill_slots(clock)
            check_preempt(clock)
            autoscale_check(clock)
            continue

        if tag == "reclaim":
            nd = payload
            if (workload_done() or online_from[nd] is None
                    or fail_time[nd] != _INF):
                continue                 # node already gone, or nothing left
            res.num_reclaimed += evict_node(nd, t, "reclaim")
            push(t + el_lat, 1, "replace", nd)
            fill_slots(clock)
            check_preempt(clock)
            autoscale_check(clock)
            continue

        if tag == "replace":
            nd = payload
            if workload_done() or fail_time[nd] != _INF:
                continue                 # nobody pays for capacity after
            map_slots[nd] = cap_map[nd] = cluster.map_slots_per_node
            red_slots[nd] = cap_red[nd] = cluster.reduce_slots_per_node
            online_from[nd] = t
            push(t + rng_reclaim.expovariate(el_rate), 0, "reclaim", nd)
            fill_slots(clock)
            check_preempt(clock)
            autoscale_check(clock)
            continue

        if tag == "provision":
            extra_pending = False
            if workload_done():
                continue
            extra_online = True
            for nd in range(n_base, n_nodes):
                map_slots[nd] = cap_map[nd] = cluster.map_slots_per_node
                red_slots[nd] = cap_red[nd] = cluster.reduce_slots_per_node
                online_from[nd] = t
            fill_slots(clock)
            check_preempt(clock)
            autoscale_check(clock)
            continue

        if tag == "preempt":
            kind = _ID_KIND[payload]
            since = starved_since[kind]
            if since is not None and t >= since + cluster.preempt_timeout - _EPS:
                do_preempt(kind, clock)
                starved_since[kind] = None
                check_preempt(clock)     # re-arm if still starved
            continue

        if tag == "flow":
            uid = payload
            if uid not in flows or flow_end.get(uid) != t:
                continue                 # flow killed or rates rescheduled it
            update_flows(t)
            del flows[uid]
            flow_end.pop(uid, None)
            flow_done[uid] = t
            reassign_flows(clock)        # survivors speed up
            jid, kind, index, node, start, end, spec = running[uid]
            job = by_id[jid]
            if job.maps_done():
                wk = reduce_durs[uid][1]
                new_end = t + wk
                running[uid] = (jid, kind, index, node, start, new_end, spec)
                push(new_end, 2, "task", uid)
            # else: still stalled on the map fleet; schedule_waiting_reduces
            # picks the task up (from flow_done) when the maps land
            continue

        uid = payload
        if uid not in running:
            continue                     # killed or superseded copy
        if running[uid][5] != t:
            continue                     # reduce end was rescheduled
        jid, kind, index, node, start, end, spec = running[uid]
        job = by_id[jid]
        if kind == "reduce" and not job.maps_done():
            # A failure resurrected map work; stall until it lands again.
            running[uid] = (jid, kind, index, node, start, _INF, spec)
            continue
        del running[uid]
        sh_end = 0.0
        if kind == "reduce":
            # end = work-start + wk, so end - wk is when the overlapped
            # network transfer stopped gating the task
            sh_end = end - reduce_durs.get(uid, (0.0, 0.0))[1]
        res.records.append(
            ClusterTaskRecord(jid, kind, index, node, start, end, spec,
                              shuffle_end=sh_end))

        if kind == "map":
            map_slots[node] += 1
            job.running_maps -= 1
            if index not in job.completed_maps:
                job.completed_maps.add(index)
                job.map_output_node[index] = node
                job.finished_map_durs.append(end - start)
                if spec:
                    res.num_speculative_won += 1
                for sib in job.map_copies.get(index, []):
                    if sib != uid and sib in running:
                        _, k2, i2, n2, s2, e2, sp2 = running.pop(sib)
                        map_slots[n2] += 1
                        job.running_maps -= 1
                        res.records.append(ClusterTaskRecord(
                            jid, k2, i2, n2, s2, clock, sp2, killed=True,
                            kill_reason="superseded"))
                job.map_copies[index] = []
            job.stats.map_finish = (clock if job.maps_done()
                                    else job.stats.map_finish)
            if (not job.reducers_launched and job.n_maps > 0
                    and len(job.completed_maps)
                    >= cluster.reduce_slowstart * job.n_maps):
                job.reducers_launched = True
            fill_slots(clock)
            if job.maps_done() and not job.pending_maps:
                schedule_waiting_reduces(job, clock)
                release_children(job.jid, clock, "slowstart")
            maybe_speculate(clock)
            if job.n_reds == 0:
                finish_job(job, clock)
        else:
            red_slots[node] += 1
            job.running_reds -= 1
            reduce_durs.pop(uid, None)
            flow_done.pop(uid, None)
            if index not in job.completed_reduces:
                job.completed_reduces.add(index)
                # stall-free duration (see maybe_speculate)
                job.finished_red_durs.append(
                    end - max(start, job.stats.map_finish))
                if spec:
                    res.num_speculative_won += 1
                for sib in job.red_copies.get(index, []):
                    if sib != uid and sib in running:
                        _, k2, i2, n2, s2, e2, sp2 = running.pop(sib)
                        red_slots[n2] += 1
                        job.running_reds -= 1
                        reduce_durs.pop(sib, None)
                        drop_flow(sib, clock)
                        res.records.append(ClusterTaskRecord(
                            jid, k2, i2, n2, s2, clock, sp2, killed=True,
                            kill_reason="superseded"))
                job.red_copies[index] = []
            fill_slots(clock)
            maybe_speculate(clock)
            finish_job(job, clock)

        check_preempt(clock)
        autoscale_check(clock)
        res.makespan = max(res.makespan, clock)

    # ---------------- completion / slot-occupancy summary ----------------
    # drift guard for the reduce_durs bookkeeping: an entry must not outlive
    # its running task (entries used to leak for the life of the simulation
    # on every failure-kill and speculative-sibling kill)
    assert set(reduce_durs) == {
        u for u, v in running.items() if v[1] == "reduce"
    }, "reduce_durs leaked entries for dead tasks"
    # flows are reduce transfers in flight: they must not outlive their task
    assert set(flows) <= set(reduce_durs), "flows leaked entries for dead tasks"
    res.n_unfinished = sum(1 for j in jobs if not np.isfinite(j.stats.finish))
    res.node_busy_s = [0.0] * n_nodes
    for rec in res.records:
        res.node_busy_s[rec.node] += rec.end - rec.start
    span = res.makespan
    for nd in range(n_nodes):        # close live capacity episodes at span
        set_offline(nd, span)
    res.node_online = node_online
    # capacity integrated over online time: a failed node only contributes
    # slot-seconds up to its failure, a reclaimed/autoscaled node only over
    # its online episodes (for a fixed fleet this reduces to the previous
    # min(span, fail_time) denominator exactly)
    per_node = cluster.map_slots_per_node + cluster.reduce_slots_per_node
    slot_seconds = sum(
        per_node * sum(max(0.0, min(e, span) - max(s, 0.0))
                       for s, e in node_online[nd])
        for nd in range(n_nodes))
    if slot_seconds > 0:
        res.slot_utilization = sum(res.node_busy_s) / slot_seconds
    ob = _obs_current()
    if ob.enabled:
        reg = ob.registry
        reg.counter("des.runs").inc()
        reg.counter("des.jobs").inc(len(jobs))
        reg.counter("des.tasks").inc(len(res.records))
        reg.counter("des.preempted").inc(res.num_preempted)
        reg.counter("des.failure_reruns").inc(res.num_failure_reruns)
        reg.counter("des.reclaimed").inc(res.num_reclaimed)
        reg.counter("des.speculative_launched").inc(
            res.num_speculative_launched)
        reg.histogram("des.makespan_s").record(res.makespan)
        el_us = (time.perf_counter() - _t_wall) * 1e6
        ob.tracer.complete("des.simulate", ob.tracer.now_us() - el_us, el_us,
                           jobs=len(jobs), scheduler=policy)
    return res
