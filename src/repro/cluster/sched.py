"""Multi-job discrete-event cluster simulator (FIFO + fair-share).

Extends the single-job Task Scheduler Simulator (paper §5(i),
:mod:`repro.core.hadoop.simulator`) to a *shared* virtual cluster: a
workload trace of jobs (:mod:`repro.cluster.workload`) contends for one
pool of map slots and one pool of reduce slots across ``num_nodes`` nodes.
Per-task costs still come from the paper's §2-§4 models, and the per-job
mechanics are the single-job simulator's, job-tagged:

* two-phase reduces — the shuffle overlaps the job's own map fleet; the
  sort/reduce/write work only runs once ALL of that job's map outputs
  exist;
* slowstart — a job's reducers launch once ``reduce_slowstart`` of its
  maps are done (a cluster-level knob here, so the planner can search it);
* stragglers / speculative execution / node failures — identical seeded
  mechanics (a node failure kills tasks of *every* job on the node and
  re-executes lost map outputs of unfinished jobs).

Scheduling policies:

* ``fifo``  — free slots go to the earliest-submitted job with pending
  tasks of that kind (Hadoop's default JobQueueTaskScheduler).
* ``fair``  — free slots go to the job with the fewest running tasks of
  that kind: equal per-job shares, a slot-granular max-min approximation
  of the Hadoop Fair Scheduler without preemption.  ``JobClass.weight``
  is arrival frequency in generated traces, *not* a scheduling share —
  the vectorized model splits the same way, so ``evaluate`` and
  ``exact_cost`` agree on what "fair" means.

Determinism: one seeded RNG drives every duration draw; event ties break on
a monotone sequence number, so runs are bit-identical given a seed.  With
one job the simulation reproduces
:func:`repro.core.hadoop.simulator.simulate_job` RNG-draw-for-RNG-draw
(tested, including jitter/straggler/speculation noise).  The mechanics are
deliberately *re-implemented* rather than imported: ``repro.core`` cannot
depend on ``repro.cluster``, so ``simulate_job`` cannot be a wrapper over
this engine without inverting the layering — the bit-for-bit equivalence
test is the drift guard that pins the two copies together.
"""

from __future__ import annotations

import heapq
import random
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.hadoop.simulator import SimConfig, _duration
from repro.core.hadoop.params import HadoopParams

from .workload import WorkloadTrace, task_costs

__all__ = [
    "ClusterConfig",
    "ClusterTaskRecord",
    "JobStats",
    "WorkloadResult",
    "simulate_workload",
]

_INF = float("inf")


@dataclass(frozen=True)
class ClusterConfig:
    """The capacity-planner's knobs: the shared cluster's shape + policy."""

    num_nodes: int = 4
    map_slots_per_node: int = 2
    reduce_slots_per_node: int = 2
    scheduler: str = "fifo"              # "fifo" | "fair"
    reduce_slowstart: float = 0.05       # pReduceSlowstart, cluster-wide

    def __post_init__(self):
        if self.scheduler not in ("fifo", "fair"):
            raise ValueError(f"unknown scheduler: {self.scheduler!r}")

    @classmethod
    def from_params(cls, p: HadoopParams, *, scheduler: str = "fifo"
                    ) -> "ClusterConfig":
        return cls(num_nodes=p.pNumNodes,
                   map_slots_per_node=p.pMaxMapsPerNode,
                   reduce_slots_per_node=p.pMaxRedPerNode,
                   scheduler=scheduler,
                   reduce_slowstart=p.pReduceSlowstart)


@dataclass
class ClusterTaskRecord:
    job_id: int
    kind: str               # "map" | "reduce"
    index: int
    node: int
    start: float
    end: float
    speculative: bool = False
    killed: bool = False


@dataclass
class JobStats:
    """Per-job service accounting on the shared cluster."""

    job_id: int
    name: str
    submit_time: float
    first_launch: float = _INF   # first task launch (queueing delay ends)
    map_finish: float = _INF
    finish: float = _INF
    n_maps: int = 0
    n_reduces: int = 0

    @property
    def queueing_delay(self) -> float:
        return self.first_launch - self.submit_time

    @property
    def latency(self) -> float:
        """Submit -> last task done (the planner's per-job cost)."""
        return self.finish - self.submit_time

    @property
    def makespan(self) -> float:
        """First launch -> last task done (the single-job notion)."""
        return self.finish - self.first_launch


@dataclass
class WorkloadResult:
    jobs: list[JobStats]
    makespan: float                       # absolute time of the last finish
    node_busy_s: list[float] = field(default_factory=list)
    slot_utilization: float = 0.0
    num_speculative_launched: int = 0
    num_speculative_won: int = 0
    num_failure_reruns: int = 0
    records: list[ClusterTaskRecord] = field(default_factory=list)

    def latencies(self) -> np.ndarray:
        return np.asarray([j.latency for j in self.jobs])

    @property
    def mean_latency(self) -> float:
        return float(self.latencies().mean()) if self.jobs else 0.0

    @property
    def p95_latency(self) -> float:
        return float(np.percentile(self.latencies(), 95)) if self.jobs else 0.0


class _Job:
    """Mutable per-job scheduler state (single-job simulator state, tagged)."""

    __slots__ = (
        "jid", "name", "submit", "n_maps", "n_reds", "map_cost", "red_cost",
        "shuffle", "arrived", "pending_maps", "pending_reduces",
        "completed_maps", "completed_reduces", "map_output_node",
        "map_copies", "red_copies", "finished_map_durs", "finished_red_durs",
        "reducers_launched", "running_maps", "running_reds", "stats",
    )

    def __init__(self, jid: int, arrival, num_nodes: int):
        jc = arrival.klass
        self.jid = jid
        self.name = jc.name
        self.submit = arrival.submit_time
        self.n_maps = jc.n_maps
        self.n_reds = jc.n_reduces
        self.map_cost, self.red_cost, self.shuffle = task_costs(
            jc, num_nodes=num_nodes)
        self.arrived = False
        self.pending_maps = deque(range(self.n_maps))
        self.pending_reduces = deque(range(self.n_reds))
        self.completed_maps: set[int] = set()
        self.completed_reduces: set[int] = set()
        self.map_output_node: dict[int, int] = {}
        self.map_copies: dict[int, list[int]] = {}
        self.red_copies: dict[int, list[int]] = {}
        self.finished_map_durs: list[float] = []
        self.finished_red_durs: list[float] = []
        self.reducers_launched = self.n_maps == 0   # no maps -> no slowstart
        self.running_maps = 0
        self.running_reds = 0
        self.stats = JobStats(jid, self.name, self.submit,
                              n_maps=self.n_maps, n_reduces=self.n_reds)

    def maps_done(self) -> bool:
        return len(self.completed_maps) == self.n_maps

    def done(self) -> bool:
        return (self.maps_done()
                and len(self.completed_reduces) == self.n_reds)


def simulate_workload(
    trace: WorkloadTrace,
    cluster: ClusterConfig = ClusterConfig(),
    sim: SimConfig = SimConfig(),
) -> WorkloadResult:
    """Run a workload trace on a shared virtual cluster."""
    rng = random.Random(sim.seed)
    n_nodes = max(1, cluster.num_nodes)
    map_slots = [cluster.map_slots_per_node] * n_nodes
    red_slots = [cluster.reduce_slots_per_node] * n_nodes
    fair = cluster.scheduler == "fair"

    jobs = [_Job(a.job_id, a, n_nodes) for a in trace.arrivals]
    by_id = {j.jid: j for j in jobs}
    res = WorkloadResult(jobs=[j.stats for j in jobs], makespan=0.0)

    # running[uid] = (jid, kind, index, node, start, end, speculative)
    running: dict[int, tuple] = {}
    reduce_durs: dict[int, tuple[float, float]] = {}   # uid -> (shuffle, work)
    uid_counter = 0
    seq_counter = 0
    clock = 0.0

    # Event heap: (time, order_class, seq, tag, payload).  order_class makes
    # simultaneous events deterministic: failures first, then arrivals, then
    # task completions (matching the single-job simulator, which applies a
    # failure before any completion at the same timestamp).
    events: list[tuple] = []

    def push(time: float, order_class: int, tag: str, payload: int) -> None:
        nonlocal seq_counter
        heapq.heappush(events, (time, order_class, seq_counter, tag, payload))
        seq_counter += 1

    for ftime, fnode in sorted(sim.node_failures):
        push(ftime, 0, "fail", fnode)
    for j in jobs:
        push(j.submit, 1, "arrive", j.jid)

    def free_slot(slots: list[int], prefer_not: int = -1) -> int:
        order = sorted(range(n_nodes), key=lambda nd: (nd == prefer_not, -slots[nd]))
        for nd in order:
            if slots[nd] > 0:
                return nd
        return -1

    def launch(job: _Job, kind: str, index: int, now: float, *,
               speculative: bool = False, avoid_node: int = -1) -> bool:
        nonlocal uid_counter
        slots = map_slots if kind == "map" else red_slots
        node = free_slot(slots, prefer_not=avoid_node)
        if node < 0:
            return False
        slots[node] -= 1
        uid = uid_counter
        uid_counter += 1
        job.stats.first_launch = min(job.stats.first_launch, now)
        if kind == "map":
            dur = _duration(job.map_cost, rng, sim)
            end = now + dur
            running[uid] = (job.jid, kind, index, node, now, end, speculative)
            job.map_copies.setdefault(index, []).append(uid)
            job.running_maps += 1
            push(end, 2, "task", uid)
        else:
            sh = _duration(job.shuffle, rng, sim) if job.shuffle > 0 else 0.0
            wk = _duration(job.red_cost, rng, sim) if job.red_cost > 0 else 0.0
            reduce_durs[uid] = (sh, wk)
            job.red_copies.setdefault(index, []).append(uid)
            job.running_reds += 1
            if job.maps_done():
                end = now + sh + wk
                running[uid] = (job.jid, kind, index, node, now, end, speculative)
                push(end, 2, "task", uid)
            else:
                # Shuffle overlaps the job's maps; completion scheduled when
                # its last map output lands.
                running[uid] = (job.jid, kind, index, node, now, _INF, speculative)
        if speculative:
            res.num_speculative_launched += 1
        return True

    def schedule_waiting_reduces(job: _Job, now: float) -> None:
        for uid, (jid, kind, index, node, start, end, spec) in list(running.items()):
            if jid == job.jid and kind == "reduce" and end == _INF:
                sh, wk = reduce_durs[uid]
                new_end = max(now, start + sh) + wk
                running[uid] = (jid, kind, index, node, start, new_end, spec)
                push(new_end, 2, "task", uid)

    # ---------------- scheduling policy ----------------

    def pick_job(kind: str):
        """The job the next free ``kind`` slot goes to, or None."""
        best = None
        best_key = None
        for j in jobs:
            if not j.arrived:
                continue
            if kind == "map":
                if not j.pending_maps:
                    continue
                load = j.running_maps
            else:
                if not (j.reducers_launched and j.pending_reduces):
                    continue
                load = j.running_reds
            # fair = equal per-job shares of each pool (JobClass.weight is
            # arrival frequency, not a scheduling share — the vector model
            # splits the same way, so evaluate() and exact_cost() agree on
            # what "fair" means)
            key = ((load,) if fair else ()) + (j.submit, j.jid)
            if best_key is None or key < best_key:
                best, best_key = j, key
        return best

    def fill_slots(now: float) -> None:
        for kind, slots in (("map", map_slots), ("reduce", red_slots)):
            while sum(slots) > 0:
                j = pick_job(kind)
                if j is None:
                    break
                pend = j.pending_maps if kind == "map" else j.pending_reduces
                if not launch(j, kind, pend[0], now):
                    break
                pend.popleft()

    def maybe_speculate(now: float) -> None:
        if not sim.speculative_execution:
            return
        for uid, (jid, kind, index, node, start, end, spec) in list(running.items()):
            if spec or end == _INF:
                continue
            j = by_id[jid]
            if kind == "map":
                durs, completed, copies = (j.finished_map_durs,
                                           j.completed_maps, j.map_copies)
            else:
                if not j.maps_done():    # stalled shuffle != straggler
                    continue
                durs, completed, copies = (j.finished_red_durs,
                                           j.completed_reduces, j.red_copies)
            if len(durs) < sim.speculative_min_completed:
                continue
            if index in completed or len(copies.get(index, [])) > 1:
                continue
            mean = sum(durs) / len(durs)
            # reduces measure from the job's map finish: shuffle stall is
            # waiting, not work (mirrors the single-job simulator)
            eff_start = start if kind == "map" \
                else max(start, j.stats.map_finish)
            projected = end - eff_start
            if projected > sim.speculative_slowdown_thr * mean and now > eff_start:
                launch(j, kind, index, now, speculative=True, avoid_node=node)

    def fail_node(fnode: int, ftime: float) -> None:
        for uid, (jid, kind, index, node, start, end, spec) in list(running.items()):
            if node != fnode:
                continue
            del running[uid]
            j = by_id[jid]
            copies = j.map_copies if kind == "map" else j.red_copies
            if uid in copies.get(index, []):
                copies[index].remove(uid)
            if kind == "map":
                j.running_maps -= 1
                if index not in j.completed_maps and index not in j.pending_maps:
                    j.pending_maps.append(index)
            else:
                j.running_reds -= 1
                if (index not in j.completed_reduces
                        and index not in j.pending_reduces):
                    j.pending_reduces.append(index)
            res.records.append(
                ClusterTaskRecord(jid, kind, index, node, start, ftime,
                                  spec, killed=True))
            res.num_failure_reruns += 1
        # Completed map outputs on the failed node are lost for every job
        # whose reducers still need them.
        for j in jobs:
            if len(j.completed_reduces) >= j.n_reds:
                continue
            for midx, mnode in list(j.map_output_node.items()):
                if mnode == fnode and midx in j.completed_maps:
                    j.completed_maps.discard(midx)
                    del j.map_output_node[midx]
                    if midx not in j.pending_maps:
                        j.pending_maps.append(midx)
                    res.num_failure_reruns += 1
        map_slots[fnode] = 0
        red_slots[fnode] = 0

    def finish_job(job: _Job, now: float) -> None:
        if job.done() and not job.pending_maps and not job.pending_reduces:
            job.stats.finish = now

    # ---------------- event loop ----------------

    while events:
        t, oc, _seq, tag, payload = heapq.heappop(events)
        clock = max(clock, t)

        if tag == "fail":
            fail_node(payload, t)
            fill_slots(clock)
            continue

        if tag == "arrive":
            by_id[payload].arrived = True
            fill_slots(clock)
            continue

        uid = payload
        if uid not in running:
            continue                     # killed or superseded copy
        if running[uid][5] != t:
            continue                     # reduce end was rescheduled
        jid, kind, index, node, start, end, spec = running[uid]
        job = by_id[jid]
        if kind == "reduce" and not job.maps_done():
            # A failure resurrected map work; stall until it lands again.
            running[uid] = (jid, kind, index, node, start, _INF, spec)
            continue
        del running[uid]
        res.records.append(
            ClusterTaskRecord(jid, kind, index, node, start, end, spec))

        if kind == "map":
            map_slots[node] += 1
            job.running_maps -= 1
            if index not in job.completed_maps:
                job.completed_maps.add(index)
                job.map_output_node[index] = node
                job.finished_map_durs.append(end - start)
                if spec:
                    res.num_speculative_won += 1
                for sib in job.map_copies.get(index, []):
                    if sib != uid and sib in running:
                        _, k2, i2, n2, s2, e2, sp2 = running.pop(sib)
                        map_slots[n2] += 1
                        job.running_maps -= 1
                        res.records.append(ClusterTaskRecord(
                            jid, k2, i2, n2, s2, clock, sp2, killed=True))
                job.map_copies[index] = []
            job.stats.map_finish = (clock if job.maps_done()
                                    else job.stats.map_finish)
            if (not job.reducers_launched and job.n_maps > 0
                    and len(job.completed_maps)
                    >= cluster.reduce_slowstart * job.n_maps):
                job.reducers_launched = True
            fill_slots(clock)
            if job.maps_done() and not job.pending_maps:
                schedule_waiting_reduces(job, clock)
            maybe_speculate(clock)
            if job.n_reds == 0:
                finish_job(job, clock)
        else:
            red_slots[node] += 1
            job.running_reds -= 1
            if index not in job.completed_reduces:
                job.completed_reduces.add(index)
                # stall-free duration (see maybe_speculate)
                job.finished_red_durs.append(
                    end - max(start, job.stats.map_finish))
                if spec:
                    res.num_speculative_won += 1
                for sib in job.red_copies.get(index, []):
                    if sib != uid and sib in running:
                        _, k2, i2, n2, s2, e2, sp2 = running.pop(sib)
                        red_slots[n2] += 1
                        job.running_reds -= 1
                        res.records.append(ClusterTaskRecord(
                            jid, k2, i2, n2, s2, clock, sp2, killed=True))
                job.red_copies[index] = []
            fill_slots(clock)
            maybe_speculate(clock)
            finish_job(job, clock)

        res.makespan = max(res.makespan, clock)

    # ---------------- slot-occupancy summary ----------------
    res.node_busy_s = [0.0] * n_nodes
    for rec in res.records:
        res.node_busy_s[rec.node] += rec.end - rec.start
    span = res.makespan
    slot_seconds = span * n_nodes * (
        cluster.map_slots_per_node + cluster.reduce_slots_per_node)
    if slot_seconds > 0:
        res.slot_utilization = sum(res.node_busy_s) / slot_seconds
    return res
