"""Fault-tolerant checkpointing: atomic, async, CRC-verified, elastic.

No external checkpoint library is assumed (none is installed); the format
is deliberately simple and robust:

    <dir>/step_000000123/
        manifest.json      # treedef, per-leaf {shape, dtype, crc32, file},
                           # step, logical sharding names, wall time
        leaf_00000.npy ... # one .npy per pytree leaf (host-local values)

Guarantees:

* **Atomicity** — written to ``step_N.tmp`` then ``os.rename``d; a crash
  mid-save never corrupts the latest valid checkpoint.  ``restore`` scans
  newest-to-oldest and skips any step whose manifest or CRCs fail.
* **Async** — ``save(..., blocking=False)`` snapshots to host memory
  (device_get) on the caller's thread, then writes on a background thread;
  the train loop overlaps the write with subsequent steps (the paper's
  overlap-compute-with-IO discipline).
* **Keep-N GC** — oldest checkpoints pruned after each successful save.
* **Elastic restore** — leaves are stored as *global logical* arrays (this
  container is single-process; at true multi-host scale each host would
  write its shard and the manifest records the sharding): restoring onto a
  different mesh just means device_put with the new sharding, so scaling
  from e.g. dp=4 to dp=8 between runs works (tested in
  ``tests/test_fault_tolerance.py::test_elastic_reshard``).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _restore_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """np.load round-trips ml_dtypes (bfloat16, fp8…) as raw void records;
    re-view them using the dtype recorded in the manifest."""
    if str(arr.dtype) == dtype_str:
        return arr
    try:
        target = np.dtype(dtype_str)
    except TypeError:
        import ml_dtypes

        target = np.dtype(getattr(ml_dtypes, dtype_str))
    if arr.dtype.kind == "V" and arr.dtype.itemsize == target.itemsize:
        return arr.view(target)
    return arr.astype(target)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._last_error: Exception | None = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree, *, blocking: bool = True, extra: dict | None = None):
        """Snapshot ``tree`` (any pytree of arrays) for ``step``."""
        self.wait()  # one in-flight async save at a time
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

        def write():
            try:
                tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
                final = os.path.join(self.dir, f"step_{step:09d}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                manifest = {
                    "step": step,
                    "treedef": str(treedef),
                    "time": time.time(),
                    "extra": extra or {},
                    "leaves": [],
                }
                for i, arr in enumerate(host_leaves):
                    fname = f"leaf_{i:05d}.npy"
                    np.save(os.path.join(tmp, fname), arr)
                    manifest["leaves"].append({
                        "file": fname,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "crc32": _crc(arr),
                    })
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._last_error = e

        if blocking:
            write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _load_step(self, step: int, example_tree=None, shardings=None):
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = []
        for meta in manifest["leaves"]:
            arr = np.load(os.path.join(path, meta["file"]))
            if _crc(arr) != meta["crc32"]:
                raise IOError(f"CRC mismatch in {path}/{meta['file']}")
            leaves.append(_restore_dtype(arr, meta["dtype"]))
        if example_tree is not None:
            treedef = jax.tree_util.tree_structure(example_tree)
        else:
            raise ValueError("restore requires example_tree for the treedef")
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        return tree, manifest

    def restore(self, example_tree, *, step: int | None = None, shardings=None):
        """Latest (or given) valid checkpoint; skips corrupt ones.

        ``shardings``: pytree of Sharding — device_put onto a (possibly
        different) mesh, enabling elastic scale-up/down.
        Returns (tree, manifest) or (None, None) when nothing valid exists.
        """
        self.wait()
        steps = [step] if step is not None else list(reversed(self.all_steps()))
        for s in steps:
            try:
                return self._load_step(s, example_tree, shardings)
            except Exception:
                continue
        return None, None
