"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --smoke --steps 200 --batch 8 --seq 128 --resume auto

On this CPU container ``--smoke`` selects the reduced same-family config
(the full configs are exercised via the dry-run); on a real pod the same
entry point drives the full config on the production mesh (--mesh dp,tp).
Auto-resume: with ``--resume auto`` the trainer continues from the newest
valid checkpoint in --ckpt-dir, surviving kill -9 at any point.
"""

from __future__ import annotations

import argparse

from repro.configs import ARCHS, get_config
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm-1.6b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--mesh", default="", help="dp,tp (default: all devices DP)")
    ap.add_argument("--log", default="artifacts/train_log.jsonl")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh_shape = tuple(int(x) for x in args.mesh.split(",")) if args.mesh else ()

    tcfg = TrainerConfig(
        global_batch=args.batch,
        seq_len=args.seq,
        n_microbatches=args.micro,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        mesh_shape=mesh_shape,
        opt=AdamWConfig(peak_lr=args.lr, total_steps=args.steps),
    )
    trainer = Trainer(cfg, tcfg)
    out = trainer.run(args.steps, resume=args.resume == "auto")
    trainer.save_log(args.log)
    first = next((r["loss"] for r in out["log"]), float("nan"))
    print(
        f"arch={cfg.name} steps={args.steps} "
        f"loss {first:.4f} -> {out['final_loss']:.4f} "
        f"(log: {args.log}, ckpts: {args.ckpt_dir})"
    )


if __name__ == "__main__":
    main()
