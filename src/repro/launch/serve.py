"""Serving launcher: batched generation demo with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch.steps import init_params
from repro.runtime.serve_loop import Server


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma2-9b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_len=args.max_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size, (args.batch, args.prompt_len))
    out = server.throughput_batch(prompts, args.new_tokens)
    print(
        f"arch={cfg.name} B={args.batch} prompt={args.prompt_len} "
        f"prefill {out['prefill_s']*1e3:.1f}ms "
        f"decode {out['decode_s']*1e3:.1f}ms "
        f"({out['tok_per_s']:.1f} tok/s)"
    )
    print("sample tokens:", out["output"][0, :12].tolist())


if __name__ == "__main__":
    main()
