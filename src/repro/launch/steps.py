"""Step-function factories shared by the dry-run, trainer, and server.

``make_train_step``  — gradient-accumulated (lax.scan over microbatches)
value_and_grad + AdamW update.  Microbatching bounds activation memory (the
"wave" structure of the paper's job model: microbatches are waves of work
over the same slots); accumulation is fp32.

``make_prefill_step`` / ``make_decode_step`` — serving paths returning
``{"logits", "caches"}`` dicts (named outputs keep the sharding rules
declarative).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import encdec as ed
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "pick_microbatches",
    "init_params",
]


def init_params(key: jax.Array, cfg: ModelConfig):
    return ed.init_encdec(key, cfg) if cfg.is_encdec else lm.init(key, cfg)


def _loss(cfg: ModelConfig) -> Callable:
    if cfg.is_encdec:
        return lambda p, b: ed.loss_fn_encdec(p, cfg, b)
    return lambda p, b: lm.loss_fn(p, cfg, b)


def pick_microbatches(
    global_batch: int, seq_len: int, dp_size: int, *, tokens_per_mb: int = 8192
) -> int:
    """Largest accumulation depth that keeps per-device microbatch tokens
    near ``tokens_per_mb`` while dividing the per-replica batch evenly."""
    per_dp = max(1, global_batch // max(dp_size, 1))
    want = max(1, (per_dp * seq_len) // tokens_per_mb)
    n = 1
    for cand in (1, 2, 4, 8, 16, 32):
        if cand <= want and per_dp % cand == 0 and global_batch % cand == 0:
            n = cand
    return n


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, n_microbatches: int = 1):
    loss_f = _loss(cfg)

    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(loss_f, has_aux=True)

        if n_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n_microbatches, -1) + x.shape[1:]), batch
            )

            def micro(carry, b):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, b)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            loss = loss / n_microbatches
            metrics = {}

        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params, opt_cfg)
        out_metrics = {"loss": loss, **opt_metrics}
        return params, opt_state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int, src_len: int | None = None):
    if cfg.is_encdec:

        def prefill_step(params, batch):
            logits, caches, pos = ed.prefill_encdec(
                params, cfg, batch["src_embeds"], batch["inputs"], max_len
            )
            return {"logits": logits, "caches": caches, "pos": pos}

    else:

        def prefill_step(params, batch):
            logits, caches, pos = lm.prefill(
                params, cfg, batch["inputs"], max_len,
                extra_embeds=batch.get("extra_embeds"),
            )
            return {"logits": logits, "caches": caches, "pos": pos}

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    if cfg.is_encdec:

        def decode_fn(params, batch):
            logits, caches = ed.decode_step_encdec(
                params, cfg, batch["token"], batch["caches"], batch["pos"]
            )
            return {"logits": logits, "caches": caches}

    else:

        def decode_fn(params, batch):
            logits, caches = lm.decode_step(
                params, cfg, batch["token"], batch["caches"], batch["pos"]
            )
            return {"logits": logits, "caches": caches}

    return decode_fn
