"""GSPMD sharding rules: DP / TP (Megatron) / EP / SP / FSDP in one rule set.

Axis assignment (mesh axes from ``launch.mesh``):

* ``pod``   — cross-pod data parallelism only (slow DCN links; parameters
  are replicated across pods, gradients all-reduce over it).
* ``data``  — in-pod data parallelism for activations **and** FSDP/ZeRO-3
  sharding for parameters + optimizer state (weights are all-gathered per
  scanned layer group at use; required to fit 26B-param optimizer state).
  For ``long_500k`` (batch=1) it is re-purposed as a sequence axis over the
  KV caches (split-KV decode).
* ``model`` — tensor parallelism (attention heads / FFN hidden / vocab),
  expert parallelism (MoE expert dim), and recurrent-width parallelism.

Rules are name+shape based over parameter pytrees, so the same function
covers every architecture, the optimizer state (which mirrors parameters),
and the KV/recurrent caches.
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig

__all__ = [
    "param_pspecs",
    "opt_pspecs",
    "input_pspecs",
    "output_pspecs",
    "named",
    "batch_axes",
]

REPL = P()


def _names(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
    return out


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def batch_axes(B: int, mesh: Mesh):
    """Longest (pod, data) prefix whose size divides the global batch."""
    cands = [("pod", "data"), ("data",), ()]
    for c in cands:
        if all(a in mesh.axis_names for a in c):
            size = math.prod(_axis_size(mesh, a) for a in c)
            if size and B % size == 0:
                return c if len(c) != 1 else c[0]
    return None


# ------------------------------------------------------------------ params

def _param_rule(names: list[str], ndim: int, cfg: ModelConfig, mesh: Mesh) -> P:
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    model_ok = lambda n: n % _axis_size(mesh, "model") == 0
    data_ok = lambda n: n % _axis_size(mesh, "data") == 0

    if leaf in ("scale", "bias", "ba", "bx", "conv_b", "A_log", "D", "dt_bias"):
        return REPL
    if leaf == "embed":
        # vocab over model when divisible (sharded-softmax layout for tied
        # heads); otherwise FSDP the d dim — the token gather stays local
        # either way (never replicate the table).
        if model_ok(cfg.vocab_size):
            return P("model", None)
        return P(None, "data" if data_ok(cfg.d_model) else None)
    if leaf == "lm_head":
        if model_ok(cfg.vocab_size):
            return P(None, "model")
        return P("data" if data_ok(cfg.d_model) else None, None)
    if parent in ("attn", "self", "cross"):
        d_ax = "data" if data_ok(cfg.d_model) else None
        if leaf == "q":
            return P(d_ax, "model" if model_ok(cfg.n_heads) else None, None)
        if leaf in ("k", "v"):
            return P(d_ax, "model" if model_ok(cfg.n_kv_heads) else None, None)
        if leaf == "o":
            return P("model" if model_ok(cfg.n_heads) else None, None, d_ax)
    if parent in ("mlp", "shared"):
        if leaf in ("wi", "wg"):
            return P("data" if data_ok(cfg.d_model) else None, "model")
        if leaf == "wo":
            return P("model", "data" if data_ok(cfg.d_model) else None)
    if leaf == "router":
        return P(None, None)
    if parent == "experts":  # (E, d, de) / (E, de, d): EP over model
        ep = "model" if model_ok(cfg.n_experts) else None
        if leaf in ("wi", "wg"):
            return P(ep, "data" if data_ok(cfg.d_model) else None, None)
        return P(ep, None, "data" if data_ok(cfg.d_model) else None)
    if parent == "rglru":
        r_ok = model_ok(cfg.d_rnn)
        if leaf in ("in_x", "in_g"):
            return P("data" if data_ok(cfg.d_model) else None,
                     "model" if r_ok else None)
        if leaf in ("wa", "wx"):
            return P(None, "model" if r_ok else None)
        if leaf == "conv_w":
            return P(None, "model" if r_ok else None)
        if leaf == "lam":
            return P("model" if r_ok else None)
        if leaf == "out":
            return P("model" if r_ok else None,
                     "data" if data_ok(cfg.d_model) else None)
    if parent == "ssm":
        if leaf == "in_proj":
            return P("data" if data_ok(cfg.d_model) else None, None)
        if leaf == "out_proj":
            return P(None, "data" if data_ok(cfg.d_model) else None)
        return REPL
    return REPL


_STACKED = {"groups", "enc", "dec"}


def param_pspecs(cfg: ModelConfig, params_shape: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching a params(-shaped) pytree."""

    def rule(path, leaf):
        names = _names(path)
        spec = _param_rule(names, leaf.ndim, cfg, mesh)
        if spec == REPL:
            return REPL
        if any(n in _STACKED for n in names):
            spec = P(*([None] + list(spec)))
        # pad to leaf rank (trailing dims replicated)
        pad = leaf.ndim - len(spec)
        if pad > 0:
            spec = P(*(list(spec) + [None] * pad))
        return spec

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_pspecs(cfg: ModelConfig, opt_shape: Any, mesh: Mesh) -> Any:
    """Optimizer state mirrors the params tree under 'm' and 'v'."""

    def rule(path, leaf):
        names = _names(path)
        if names and names[0] == "count":
            return REPL
        sub_path = path[1:]  # strip the 'm'/'v' level
        spec = _param_rule(_names(sub_path), leaf.ndim, cfg, mesh)
        if spec == REPL:
            return REPL
        if any(n in _STACKED for n in _names(sub_path)):
            spec = P(*([None] + list(spec)))
        pad = leaf.ndim - len(spec)
        if pad > 0:
            spec = P(*(list(spec) + [None] * pad))
        return spec

    return jax.tree_util.tree_map_with_path(rule, opt_shape)


# ------------------------------------------------------------------ inputs

def _cache_rule(names, leaf, cfg: ModelConfig, mesh: Mesh, B: int, long_ctx: bool) -> P:
    """Sharding for one cache leaf (kv / recurrent state / conv tail)."""
    bax = batch_axes(B, mesh)
    stacked = any(n in ("groups", "self", "cross") for n in names)
    name = names[-1]
    model = _axis_size(mesh, "model")

    if name in ("k", "v"):
        # (G?, B, KV, S, hd)
        kv_ax = "model" if cfg.n_kv_heads % model == 0 else None
        seq_axes = []
        if kv_ax is None:
            seq_axes.append("model")
        if bax is None:
            seq_axes = (["data"] + seq_axes) if "data" in mesh.axis_names else seq_axes
        seq_ax = tuple(seq_axes) if len(seq_axes) > 1 else (seq_axes[0] if seq_axes else None)
        spec = [bax, kv_ax, seq_ax, None]
    elif name == "h":
        # rglru (G?, B, r) | ssm (G?, B, H, P, N)
        base_ndim = leaf.ndim - (1 if stacked else 0)
        if base_ndim == 2:
            r_ax = "model" if cfg.d_rnn % model == 0 else None
            spec = [bax, r_ax]
        else:
            h_ax = "model" if cfg.ssm_state and cfg.n_ssm_heads % model == 0 else None
            spec = [bax, h_ax, None, None]
    elif name == "conv":
        ch_ax = None
        if cfg.rglru_width and cfg.d_rnn % model == 0 and leaf.shape[-1] == cfg.d_rnn:
            ch_ax = "model"
        spec = [bax, None, ch_ax]
    else:
        return REPL
    if stacked:
        spec = [None] + spec
    return P(*spec)


def input_pspecs(cfg: ModelConfig, shape: ShapeSpec, specs: Any, mesh: Mesh) -> Any:
    """Sharding tree matching ``configs.input_specs(cfg, shape)``."""
    B = shape.global_batch
    bax = batch_axes(B, mesh)
    long_ctx = shape.name == "long_500k"

    def rule(path, leaf):
        names = _names(path)
        top = names[0]
        if top in ("inputs", "targets", "mask", "token"):
            return P(*([bax] + [None] * (leaf.ndim - 1)))
        if top in ("extra_embeds", "src_embeds"):
            return P(*([bax] + [None] * (leaf.ndim - 1)))
        if top == "pos":
            return REPL
        if top == "caches":
            return _cache_rule(names, leaf, cfg, mesh, B, long_ctx)
        return REPL

    return jax.tree_util.tree_map_with_path(rule, specs)


def output_pspecs(cfg: ModelConfig, shape: ShapeSpec, out_shape: Any, mesh: Mesh) -> Any:
    """Used for serve-step outputs: logits + caches."""
    B = shape.global_batch
    bax = batch_axes(B, mesh)
    model = _axis_size(mesh, "model")

    def rule(path, leaf):
        names = _names(path)
        if names and names[0] == "logits":
            v_ax = "model" if cfg.vocab_size % model == 0 else None
            return P(bax, None, v_ax)
        if names and names[0] == "caches":
            return _cache_rule(names, leaf, cfg, mesh, B, shape.name == "long_500k")
        return REPL

    return jax.tree_util.tree_map_with_path(rule, out_shape)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def activation_policy(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> dict:
    """Activation-sharding policy installed by launchers (see
    repro.models.act_sharding): batch over DP axes, vocab-sharded logits,
    expert-sharded MoE buffers.  Keeps GSPMD propagation on the rails."""
    bax = batch_axes(shape.global_batch, mesh)
    model = _axis_size(mesh, "model")
    pol = {
        "residual": NamedSharding(mesh, P(bax, None, None)),
        "logits": NamedSharding(
            mesh,
            P(bax, None, "model" if cfg.vocab_size % model == 0 else None),
        ),
    }
    if cfg.n_experts and cfg.n_experts % model == 0:
        pol["moe_ecd"] = NamedSharding(mesh, P("model", None, None))
        # gather-dispatch reads the token table replicated (see moe.py)
        pol["moe_tokens"] = NamedSharding(mesh, P(None, None))
    return pol
