import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run the paper's OWN workload at pod scale: the shard_map MapReduce
pipeline (map -> seg_combine -> all_to_all shuffle -> reduce) lowered and
compiled against the 256-chip production mesh, with the shuffle's
collective bytes extracted — Eq. 90's netTransferSize measured from the
compiled HLO instead of predicted.

    PYTHONPATH=src python -m repro.launch.dryrun_mapreduce \
        --pairs-per-shard 1048576 --key-space 1048576
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats
from repro.core.hadoop.ref import network_model, job_model
from repro.core.roofline import collective_bytes, hlo_totals, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.mapreduce.distributed import make_pipeline, wordcount_map_jax


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pairs-per-shard", type=int, default=1 << 20)
    ap.add_argument("--key-space", type=int, default=1 << 20)
    ap.add_argument("--out", default="artifacts/dryrun/mapreduce_pipeline.json")
    args = ap.parse_args()

    mesh = make_production_mesh()            # (16, 16) = 256 chips
    n_shards = mesh.shape["data"] * mesh.shape["model"]
    # flatten both axes into one logical shuffle axis by using "data" for
    # mapper/reducer shards and "model" for intra-shard key blocks: here we
    # keep it simple — shuffle over "data" (16 mapper/reducer groups), the
    # model axis parallelizes the dense combine.
    total_pairs = args.pairs_per_shard * mesh.shape["data"]
    pipe = make_pipeline(
        mesh, map_fn=wordcount_map_jax, key_space=args.key_space,
        axis="data", use_pallas=False,
    )
    keys = jax.ShapeDtypeStruct((total_pairs,), jnp.int32)
    values = jax.ShapeDtypeStruct((total_pairs,), jnp.float32)
    with mesh:
        lowered = pipe.lower(keys, values)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    parsed = hlo_totals(hlo)
    cost = {k: float(v) for k, v in dict(compiled.cost_analysis()).items()
            if isinstance(v, (int, float))}
    terms = roofline_terms(cost, coll, 256, parsed=parsed)

    # the paper's Eq. 90 prediction for the same job shape
    hp = HadoopParams(
        pNumNodes=16, pNumMappers=16, pNumReducers=16,
        pSplitSize=args.pairs_per_shard * 12.0, pUseCombine=True,
    )
    st = ProfileStats(sInputPairWidth=12.0, sMapPairsSel=4.0, sMapSizeSel=4.0,
                      sCombinePairsSel=0.25, sCombineSizeSel=0.25)
    jm = job_model(hp, st, CostFactors())

    out = {
        "pairs": total_pairs,
        "key_space": args.key_space,
        "collectives": {"total_bytes": coll.total_bytes, "by_kind": coll.by_kind,
                        "count": coll.count},
        "roofline": {
            "compute_s": terms.compute_s, "memory_s": terms.memory_s,
            "collective_s": terms.collective_s, "bound": terms.bound,
        },
        "paper_eq90_net_bytes": jm.netTransferSize,
        "status": "ok",
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
