import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
# against the production meshes and extract roofline artifacts.
#
# The two lines above MUST stay the first statements in this file: JAX locks
# the device count at first initialization, and the dry-run needs 512
# placeholder host devices to build the 2x16x16 production mesh.  Tests and
# benchmarks never import this module, so they see the single real CPU.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
#       --shape train_4k --mesh both --out artifacts/dryrun
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun

import argparse
import functools
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, input_specs, skip_reason
from repro.core.roofline import collective_bytes, hlo_totals, model_flops, roofline_terms
from repro.launch import sharding as shd
from repro.models import act_sharding
from repro.models.opt_flags import OptFlags, clear_flags, set_flags
from repro.launch.mesh import make_production_mesh

# §Perf presets: named OptFlags bundles used by the hillclimb log
# (EXPERIMENTS.md §Perf).  "baseline" is the paper-faithful configuration.
OPT_PRESETS: dict[str, OptFlags] = {
    "baseline": OptFlags(),
    "moe-gather": OptFlags(moe_impl="gather"),
    "dp64-tp4": OptFlags(mesh_factor=(64, 4)),
    "dp32-tp8": OptFlags(mesh_factor=(32, 8)),
    "sharded-loss": OptFlags(sharded_loss=True),
    "moe-gather+dp64": OptFlags(moe_impl="gather", mesh_factor=(64, 4)),
    "moe-gather+loss": OptFlags(moe_impl="gather", sharded_loss=True),
    "dp64+loss": OptFlags(mesh_factor=(64, 4), sharded_loss=True),
    "moe-gather+dp64+loss": OptFlags(
        moe_impl="gather", mesh_factor=(64, 4), sharded_loss=True
    ),
    "dp32+loss": OptFlags(mesh_factor=(32, 8), sharded_loss=True),
    "flash": OptFlags(flash_bwd=True),
    "moe-gather+flash": OptFlags(moe_impl="gather", flash_bwd=True),
    "micro32": OptFlags(n_micro_override=32),
    "moe-shardmap": OptFlags(moe_impl="shardmap"),
    "moe-shardmap+flash": OptFlags(moe_impl="shardmap", flash_bwd=True),
    "inplace-cache": OptFlags(cache_update="inplace"),
    "inplace-cache+moe": OptFlags(cache_update="inplace", moe_impl="shardmap"),
    # suggested by the calibrated analytical model (examples/tpu_tuning.py)
    "dp128+flash": OptFlags(mesh_factor=(128, 2), flash_bwd=True),
    "moe-shardmap+dp64+flash": OptFlags(
        moe_impl="shardmap", mesh_factor=(64, 4), flash_bwd=True
    ),
    "moe-shardmap+dp32+flash": OptFlags(
        moe_impl="shardmap", mesh_factor=(32, 8), flash_bwd=True
    ),
    "einsum+micro32+flash": OptFlags(n_micro_override=32, flash_bwd=True),
    "moe-gather+micro32+flash": OptFlags(
        moe_impl="gather", n_micro_override=32, flash_bwd=True
    ),
    "dp64+flash": OptFlags(mesh_factor=(64, 4), flash_bwd=True),
    "moe-gather+dp64+flash": OptFlags(
        moe_impl="gather", mesh_factor=(64, 4), flash_bwd=True
    ),
    "dp64+flash+loss": OptFlags(
        mesh_factor=(64, 4), flash_bwd=True, sharded_loss=True
    ),
}
from repro.launch.steps import (
    init_params,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    pick_microbatches,
)
from repro.optim import AdamWConfig, adamw_init


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover - backend specific
        return {"error": repr(e)}
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_per_device_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
        )
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items() if isinstance(v, (int, float))}


def _bf16_params(shape_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32
        else s,
        shape_tree,
    )


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    hlo_path: str | None = None,
    opt: str = "baseline",
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    flags = OPT_PRESETS[opt]
    set_flags(flags)
    cell = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": 512 if multi_pod else 256,
        "opt": opt,
    }

    reason = skip_reason(cfg, shape)
    if reason:
        cell["status"] = reason
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod, factor=flags.mesh_factor)
    specs = input_specs(cfg, shape)
    b_named = shd.named(mesh, shd.input_pspecs(cfg, shape, specs, mesh))
    act_sharding.set_policy(shd.activation_policy(cfg, shape, mesh))

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            params_shape = jax.eval_shape(
                functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0)
            )
            opt_shape = jax.eval_shape(adamw_init, params_shape)
            p_named = shd.named(mesh, shd.param_pspecs(cfg, params_shape, mesh))
            o_named = shd.named(mesh, shd.opt_pspecs(cfg, opt_shape, mesh))
            dp = mesh.shape["data"] * (2 if multi_pod else 1)
            n_micro = flags.n_micro_override or pick_microbatches(
                shape.global_batch, shape.seq_len, dp
            )
            cell["n_microbatches"] = n_micro
            step = make_train_step(cfg, AdamWConfig(), n_micro)
            jitted = jax.jit(
                step,
                in_shardings=(p_named, o_named, b_named),
                out_shardings=(p_named, o_named, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shape, opt_shape, specs)
        else:
            params_shape = _bf16_params(
                jax.eval_shape(
                    functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0)
                )
            )
            p_named = shd.named(mesh, shd.param_pspecs(cfg, params_shape, mesh))
            if shape.kind == "prefill":
                step = make_prefill_step(cfg, max_len=shape.seq_len)
            else:
                step = make_decode_step(cfg)
            out_shape = jax.eval_shape(step, params_shape, specs)
            out_named = shd.named(
                mesh, shd.output_pspecs(cfg, shape, out_shape, mesh)
            )
            jitted = jax.jit(
                step,
                in_shardings=(p_named, b_named),
                out_shardings=out_named,
                donate_argnums=(1,) if shape.kind == "decode" else (),
            )
            lowered = jitted.lower(params_shape, specs)

        cell["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        cell["compile_s"] = round(time.time() - t1, 2)

    act_sharding.clear_policy()
    clear_flags()
    cell["memory"] = _mem_dict(compiled)
    cost = _cost_dict(compiled)
    cell["cost"] = {
        k: cost.get(k) for k in ("flops", "bytes accessed", "transcendentals")
        if k in cost
    }
    hlo = compiled.as_text()
    if hlo_path:
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
    coll = collective_bytes(hlo)
    parsed = hlo_totals(hlo)
    cell["collectives"] = {
        "total_bytes": coll.total_bytes,
        "count": coll.count,
        "by_kind": coll.by_kind,
    }
    cell["parsed"] = parsed
    mf = model_flops(cfg, shape)
    terms = roofline_terms(cost, coll, cell["chips"], mf, parsed)
    cell["roofline"] = {
        "compute_s": terms.compute_s,
        "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "bound": terms.bound,
        "model_flops": mf,
        "hlo_flops_per_chip": terms.flops,
        "hlo_flops_global": terms.flops * cell["chips"],
        "hbm_bytes_per_chip": terms.hbm_bytes,
        "coll_bytes_per_chip": terms.coll_bytes,
        "useful_ratio": terms.useful_ratio,
    }
    cell["status"] = "ok"
    return cell


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape id or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="every cell, both meshes")
    ap.add_argument("--opt", default="baseline", choices=sorted(OPT_PRESETS))
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = sorted(ARCHS) if (args.all or args.arch == "all") else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape == "all") else [args.shape]
    meshes = (
        [False, True] if (args.all or args.mesh == "both")
        else [args.mesh == "multi"]
    )
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                if args.opt != "baseline":
                    tag += f"__opt-{args.opt}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status", "").startswith(("ok", "skip")):
                        print(f"[cached] {tag}: {prev['status']}")
                        continue
                try:
                    cell = run_cell(
                        arch, shape_name, multi,
                        hlo_path=os.path.join(args.out, tag + ".hlo.gz"),
                        opt=args.opt,
                    )
                except Exception as e:
                    act_sharding.clear_policy()
                    clear_flags()
                    cell = {
                        "arch": arch, "shape": shape_name,
                        "mesh": "2x16x16" if multi else "16x16",
                        "status": f"FAIL: {e!r}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures += 1
                with open(path, "w") as f:
                    json.dump(cell, f, indent=1)
                rf = cell.get("roofline", {})
                print(
                    f"[{cell['status'][:60]}] {tag} "
                    f"compile={cell.get('compile_s', '-')}s "
                    f"bound={rf.get('bound', '-')} "
                    f"terms=({rf.get('compute_s', 0):.2e},"
                    f"{rf.get('memory_s', 0):.2e},{rf.get('collective_s', 0):.2e})s",
                    flush=True,
                )
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
