"""Launchers: mesh construction, sharding rules, dry-run, train/serve CLIs.

NOTE: ``repro.launch.dryrun`` force-sets XLA_FLAGS on import; never import
it from tests or benchmarks.  Everything else here is side-effect free.
"""
