"""Production mesh construction.

Defined as a FUNCTION (not a module-level constant) so importing this module
never touches JAX device state — critical because the dry-run forces 512
placeholder host devices while tests/benches must see the single real CPU.

Mesh layouts:

* single-pod:  (16, 16)      axes ("data", "model")          = 256 chips
* multi-pod:   (2, 16, 16)   axes ("pod", "data", "model")   = 512 chips

Axis roles (see DESIGN.md §6): "pod" = cross-pod data parallelism (DCN),
"data" = in-pod data parallelism + FSDP parameter sharding + sequence
sharding for long-context cells, "model" = tensor/expert parallelism (ICI).
"""

from __future__ import annotations

import numpy as np

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "mesh_devices"]


def mesh_devices(n: int):
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (see launch/dryrun.py)"
        )
    return np.array(devs[:n])


def make_production_mesh(
    *, multi_pod: bool = False, factor: tuple[int, int] | None = None
) -> jax.sharding.Mesh:
    """Production mesh.  ``factor=(dp, tp)`` refactors the SAME 256-chip
    pod grid into a different logical (data, model) split — a §Perf knob
    (e.g. starcoder2's 36 heads need tp ∈ {4, 12}; dp=64/tp=4 also cuts TP
    collective bytes 4x).  Device order is unchanged; only the logical view
    differs.  Default (16, 16)."""
    dp, tp = factor or (16, 16)
    assert dp * tp == 256, (dp, tp)
    shape = (2, dp, tp) if multi_pod else (dp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return jax.sharding.Mesh(mesh_devices(n).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over the real host devices (tests / local runs)."""
    n = data * model
    return jax.sharding.Mesh(mesh_devices(n).reshape(data, model), ("data", "model"))
