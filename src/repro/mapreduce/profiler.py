"""Starfish-style job profiler: measure Table-2 statistics and fit Table-3
cost factors from live engine runs, then predict other configurations.

This closes the paper's loop end-to-end **on real executions**:

  1. :func:`profile_job` runs the engine once and extracts the measured
     ProfileStats (selectivities, widths) — the paper's "job profile".
  2. :func:`fit_cost_factors` runs the engine over a set of configurations,
     assembles the paper's linear cost structure (every phase cost is
     Σ dataflow-quantity x cost-factor) and solves a non-negative least
     squares for the CostFactors.
  3. :func:`predict` evaluates the closed-form job model (ref.py) with the
     measured profile + fitted factors — the number Starfish would use for
     what-if analysis — and :func:`prediction_error` compares it against
     measured wall time at configs never used for fitting.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.hadoop import ref
from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats
from .engine import JobCounters, MapReduceEngine
from .jobs import JobSpec, make_input

__all__ = [
    "profile_job",
    "MeasuredRun",
    "run_measured",
    "fit_cost_factors",
    "fit_cost_factors_autodiff",
    "predict",
    "prediction_error",
    "prediction_error_from_runs",
]


def profile_job(jc: JobCounters, job: JobSpec, hp: HadoopParams) -> ProfileStats:
    """Extract the paper's Table-2 statistics from measured counters."""
    in_pairs = sum(m.inputPairs for m in jc.maps)
    in_bytes = sum(m.inputBytes for m in jc.maps)
    out_pairs = sum(m.outMapPairs for m in jc.maps)
    out_bytes = sum(m.outMapSize for m in jc.maps)
    interm_ratio = 0.3 if hp.pIsIntermCompressed else 1.0
    in_ratio = 0.4 if hp.pIsInCompressed else 1.0
    uncompressed_in = in_bytes / in_ratio

    kw = dict(
        sInputPairWidth=uncompressed_in / max(in_pairs, 1),
        sMapSizeSel=out_bytes / max(uncompressed_in, 1e-12),
        sMapPairsSel=out_pairs / max(in_pairs, 1),
        sInputCompressRatio=in_ratio,
        sIntermCompressRatio=interm_ratio,
        sOutCompressRatio=0.4 if hp.pIsOutCompressed else 1.0,
    )
    # combine selectivity: measured across the first spill (paper: per-spill)
    spill_in = sum(m.spillBufferPairs * m.numSpills for m in jc.maps)
    spill_out = sum(sum(m.spillFilePairs) for m in jc.maps)
    if hp.pUseCombine and spill_in:
        sel = min(spill_out / spill_in, 1.0)
        kw.update(sCombinePairsSel=sel, sCombineSizeSel=sel)
    if jc.reduces:
        red_in = sum(r.inReducePairs for r in jc.reduces)
        red_out = sum(r.outReducePairs for r in jc.reduces)
        red_out_b = sum(r.outReduceSize for r in jc.reduces)
        red_in_b = red_in * (out_bytes / max(out_pairs, 1))
        out_ratio = kw["sOutCompressRatio"]
        kw.update(
            sReducePairsSel=red_out / max(red_in, 1),
            sReduceSizeSel=(red_out_b / out_ratio) / max(red_in_b, 1e-12),
        )
    return ProfileStats(**kw)


@dataclass
class MeasuredRun:
    hp: HadoopParams
    stats: ProfileStats
    counters: JobCounters
    wall_s: float
    phase_times: dict


def run_measured(
    job: JobSpec,
    hp: HadoopParams,
    n_pairs: int,
    *,
    seed: int = 0,
    use_pallas_combine: bool = False,
) -> MeasuredRun:
    keys, values = make_input(job, n_pairs, seed=seed)
    eng = MapReduceEngine(hp, job, use_pallas_combine=use_pallas_combine)
    t0 = time.perf_counter()
    jc = eng.run_job(keys, values)
    wall = time.perf_counter() - t0
    return MeasuredRun(hp, profile_job(jc, job, hp), jc, wall, jc.phase_totals())


# ------------------------------------------------------------------ fitting

# Design matrix columns — the subset of Table 3 identifiable from phase
# timings of an uncompressed in-memory engine (compression costs are zero
# by the paper's Initializations; IO factors fold into the same per-byte
# slots the paper uses).
_FIT_COLS = [
    "cHdfsReadCost",        # per input byte          (read phase)
    "cMapCPUCost",          # per input pair          (map phase)
    "cPartitionCPUCost",    # per map-output pair     (collect)
    "cSortCPUCost",         # per pair-comparison     (spill sort)
    "cCombineCPUCost",      # per spilled pair        (spill combine)
    "cLocalIOCost",         # per merge byte          (map+reduce merges)
    "cMergeCPUCost",        # per merged pair         (merges)
    "cNetworkCost",         # per shuffled byte       (shuffle)
    "cReduceCPUCost",       # per reduce-input pair   (reduce)
    "cHdfsWriteCost",       # per output byte         (write)
]


def _design_row(run: MeasuredRun) -> tuple[np.ndarray, np.ndarray]:
    """Phase-time observations -> (A, y) rows with the paper's structure."""
    jc = run.counters
    t = run.phase_times
    R = max(run.hp.pNumReducers, 1)

    in_bytes = sum(m.inputBytes for m in jc.maps)
    in_pairs = sum(m.inputPairs for m in jc.maps)
    out_pairs = sum(m.outMapPairs for m in jc.maps)
    spilled = sum(m.numRecSpilled for m in jc.maps)
    sort_cmp = sum(
        m.spillBufferPairs * max(np.log2(max(m.spillBufferPairs / R, 2.0)), 1.0)
        * m.numSpills
        for m in jc.maps
    )
    merge_bytes = sum(m.mergeReadBytes + m.mergeWriteBytes for m in jc.maps)
    merge_pairs = sum(m.intermDataPairs for m in jc.maps)
    shuf_bytes = sum(r.totalShuffleSize for r in jc.reduces)
    red_pairs = sum(r.inReducePairs for r in jc.reduces)
    out_bytes = sum(r.outReduceSize for r in jc.reduces)
    sort_bytes = sum(r.sortMergeReadBytes for r in jc.reduces)
    shuf_pairs = sum(sum(r.shuffleFilePairs) for r in jc.reduces)

    rows, y = [], []

    def row(**cols):
        r = np.zeros(len(_FIT_COLS))
        for k, v in cols.items():
            r[_FIT_COLS.index(k)] = v
        return r

    rows.append(row(cHdfsReadCost=in_bytes)); y.append(t.get("read", 0.0))
    rows.append(row(cMapCPUCost=in_pairs)); y.append(t.get("map", 0.0))
    rows.append(row(cPartitionCPUCost=out_pairs)); y.append(t.get("collect", 0.0))
    rows.append(row(cSortCPUCost=sort_cmp, cCombineCPUCost=spilled))
    y.append(t.get("spill", 0.0))
    rows.append(row(cLocalIOCost=merge_bytes, cMergeCPUCost=merge_pairs))
    y.append(t.get("merge", 0.0))
    rows.append(row(cNetworkCost=shuf_bytes, cMergeCPUCost=shuf_pairs))
    y.append(t.get("shuffle", 0.0))
    rows.append(row(cLocalIOCost=sort_bytes, cMergeCPUCost=red_pairs))
    y.append(t.get("sort", 0.0))
    rows.append(row(cReduceCPUCost=red_pairs, cHdfsWriteCost=out_bytes))
    y.append(t.get("reduce_write", 0.0))
    return np.stack(rows), np.asarray(y)


def fit_cost_factors(runs: list[MeasuredRun]) -> CostFactors:
    """Non-negative least squares over all phase observations."""
    A = np.concatenate([_design_row(r)[0] for r in runs])
    y = np.concatenate([_design_row(r)[1] for r in runs])
    # scale columns for conditioning
    scale = np.maximum(A.max(axis=0), 1e-12)
    x, *_ = np.linalg.lstsq(A / scale, y, rcond=None)
    x = np.maximum(x / scale, 0.0)
    kw = dict(zip(_FIT_COLS, (float(v) for v in x)))
    return CostFactors().replace(**kw)


def fit_cost_factors_autodiff(
    runs: list[MeasuredRun], *, steps: int = 250, peak_lr: float = 0.05
):
    """Gradient refinement of :func:`fit_cost_factors` via :mod:`repro.calib`.

    A thin adapter: the per-phase least-squares solution seeds a
    ``jax.grad`` + AdamW fit of the same ``_FIT_COLS`` against each run's
    measured *wall time*, minimizing exactly the metric
    :func:`prediction_error` reports (squared relative error of the Eq. 98
    total).  The least squares is optimal for absolute phase-time error;
    the refinement re-targets the factors at relative total error, which is
    what transfers to held-out configurations.  Never worse than the seed
    on the fit runs (the calibrator keeps the best point seen, including
    the starting one).

    Returns ``(CostFactors, CalibrationReport)``.
    """
    from repro.calib import Observation, calibrate
    from repro.spec import JobSpec as TypedJobSpec

    init_costs = fit_cost_factors(runs)
    obs = [
        Observation(
            spec=TypedJobSpec(params=r.hp, stats=r.stats, costs=init_costs),
            cost=r.wall_s,
        )
        for r in runs
    ]
    report = calibrate(obs, params=list(_FIT_COLS), steps=steps, peak_lr=peak_lr)
    costs = init_costs.replace(**{k: report.fitted[k] for k in _FIT_COLS})
    return costs, report


def predict(
    hp: HadoopParams, stats: ProfileStats, costs: CostFactors
) -> float:
    """Closed-form total job cost (paper Eq. 98) in seconds."""
    jm = ref.job_model(hp, stats, costs)
    return jm.totalCost


def prediction_error_from_runs(
    fit_runs: list[MeasuredRun],
    test_runs: list[MeasuredRun],
    *,
    fit: str = "lstsq",
    steps: int = 250,
) -> dict:
    """Fit on measured runs, predict held-out runs; report relative errors.

    Taking already-measured runs (rather than configs) lets two fit methods
    be compared on the *same* executions — wall-time noise then cancels in
    the comparison (``benchmarks/bench_mr_fit.py`` relies on this).
    """
    if fit == "autodiff":
        costs, calibration = fit_cost_factors_autodiff(fit_runs, steps=steps)
    elif fit == "lstsq":
        costs, calibration = fit_cost_factors(fit_runs), None
    else:
        raise ValueError(f"unknown fit method {fit!r} (lstsq | autodiff)")
    stats = fit_runs[0].stats
    rows = []
    for run in test_runs:
        pred = predict(run.hp, run.stats, costs)
        rows.append({
            "hp": run.hp, "measured_s": run.wall_s, "predicted_s": pred,
            "rel_err": abs(pred - run.wall_s) / max(run.wall_s, 1e-9),
        })
    errs = [r["rel_err"] for r in rows]
    return {
        "costs": costs, "stats": stats, "rows": rows, "fit": fit,
        "calibration": calibration,
        "mean_rel_err": float(np.mean(errs)), "max_rel_err": float(np.max(errs)),
    }


def prediction_error(
    job: JobSpec,
    fit_hps: list[HadoopParams],
    test_hps: list[HadoopParams],
    n_pairs: int,
    *,
    seed: int = 0,
    fit: str = "lstsq",
) -> dict:
    """Fit on ``fit_hps``, predict ``test_hps``; report relative errors."""
    fit_runs = [run_measured(job, hp, n_pairs, seed=seed) for hp in fit_hps]
    test_runs = [run_measured(job, hp, n_pairs, seed=seed + 1) for hp in test_hps]
    return prediction_error_from_runs(fit_runs, test_runs, fit=fit)
