"""Executable MapReduce engine with faithful Hadoop phase semantics.

This is the ground truth the paper's closed-form dataflow models are
validated against (benchmark E7 / tests): every quantity the paper derives —
``numSpills``, ``spillFileSize``, merge-pass counts, shuffle-file counts,
``intermDataSize`` … — is *measured* here from an actual execution:

  map task   : read -> map -> collect (partition) -> spill (sort [+combine])
               -> multi-pass merge (io.sort.factor semantics, combiner in the
               final merge when wide enough)
  reduce task: shuffle (in-memory merge thresholds, disk merges at 2F-1)
               -> 3-step sort/merge -> reduce -> write

Orchestration is host-level Python/numpy — exactly as Hadoop's own task
runtime is JVM code around the sort/merge buffers — while the combiner
(the compute hot-spot) runs on the Pallas ``seg_combine`` kernel via
:func:`repro.kernels.seg_combine` when ``use_pallas_combine`` is set.
Byte sizes follow the paper's accounting: pair counts x pair widths, with
compression modeled by the ratio statistics (Table 2).

Every phase is wall-clock timed; :mod:`repro.mapreduce.profiler` fits the
paper's CostFactors (Table 3) to these timings and predicts other configs.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.hadoop.merge_math import merge_plan
from repro.core.hadoop.params import HadoopParams, MiB
from .jobs import JobSpec

__all__ = ["MapCounters", "ReduceCounters", "JobCounters", "MapReduceEngine"]


def _hash_partition(keys: np.ndarray, r: int) -> np.ndarray:
    return ((keys * 2654435761) % (1 << 31)) % r


@dataclass
class MapCounters:
    """Measured map-task dataflow (paper §2 quantities)."""
    inputPairs: int = 0
    inputBytes: float = 0.0            # on-disk (compressed) split bytes
    outMapPairs: int = 0
    outMapSize: float = 0.0
    spillBufferPairs: int = 0
    numSpills: int = 0
    spillFilePairs: list = field(default_factory=list)
    spillFileSize: list = field(default_factory=list)
    numMergePasses: int = 0
    numSpillsFinalMerge: int = 0
    usedCombineInMerge: bool = False
    mergeReadBytes: float = 0.0
    mergeWriteBytes: float = 0.0
    numRecSpilled: int = 0
    intermDataPairs: int = 0
    intermDataSize: float = 0.0
    times: dict = field(default_factory=dict)


@dataclass
class ReduceCounters:
    """Measured reduce-task dataflow (paper §3 quantities)."""
    totalShufflePairs: int = 0
    totalShuffleSize: float = 0.0      # compressed bytes fetched
    segmentComprSize: float = 0.0
    numSegInShuffleFile: int = 0
    numShuffleFiles: int = 0
    shuffleFilePairs: list = field(default_factory=list)
    numShuffleMerges: int = 0
    numSegmentsInMem: int = 0
    sortMergeReadBytes: float = 0.0
    inReducePairs: int = 0
    inReduceGroups: int = 0
    outReducePairs: int = 0
    outReduceSize: float = 0.0
    times: dict = field(default_factory=dict)


@dataclass
class JobCounters:
    maps: list = field(default_factory=list)       # MapCounters
    reduces: list = field(default_factory=list)    # ReduceCounters
    netTransferBytes: float = 0.0
    output: tuple | None = None                    # (keys, values)

    # --------------------------------------------------------- aggregates
    def phase_totals(self) -> dict:
        """Aggregate per-phase (bytes, pairs) + wall times for fitting."""
        t: dict[str, float] = {}
        for mc in self.maps:
            for k, v in mc.times.items():
                t[k] = t.get(k, 0.0) + v
        for rc in self.reduces:
            for k, v in rc.times.items():
                t[k] = t.get(k, 0.0) + v
        return t


class MapReduceEngine:
    """Execute a :class:`JobSpec` under :class:`HadoopParams` semantics."""

    def __init__(
        self,
        hp: HadoopParams,
        job: JobSpec,
        *,
        use_pallas_combine: bool = False,
    ):
        self.hp = hp
        self.job = job
        self.use_pallas_combine = use_pallas_combine
        if job.use_combine != hp.pUseCombine:
            # HadoopParams is authoritative (the tunable knob)
            self.use_combine = hp.pUseCombine
        else:
            self.use_combine = job.use_combine

    # ------------------------------------------------------------- combine
    def _combine(self, part: np.ndarray, keys: np.ndarray, vals: np.ndarray):
        """Merge same-(partition,key) pairs.  Inputs sorted by (part, key)."""
        if keys.size == 0:
            return part, keys, vals
        pk = np.stack([part, keys], 1)
        uniq, inverse = np.unique(pk, axis=0, return_inverse=True)
        if self.use_pallas_combine:
            from repro.kernels import seg_combine  # deferred: jax import

            summed = np.asarray(
                seg_combine(
                    np.asarray(vals, np.float32)[:, None],
                    inverse.astype(np.int32),
                    int(uniq.shape[0]),
                )
            )[:, 0]
        else:
            summed = np.zeros(uniq.shape[0], np.float32)
            np.add.at(summed, inverse, vals)
        return uniq[:, 0], uniq[:, 1], summed

    # ------------------------------------------------------------ map task
    def run_map_task(self, keys: np.ndarray, values: np.ndarray):
        hp, job = self.hp, self.job
        mc = MapCounters()
        R = max(hp.pNumReducers, 1)

        # ---- read + map (paper §2.1) ----
        t0 = time.perf_counter()
        mc.inputPairs = int(keys.shape[0])
        uncompressed = keys.shape[0] * job.pair_width
        ratio = hp.pIsInCompressed and 0.4 or 1.0
        mc.inputBytes = uncompressed * ratio
        mc.times["read"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        okeys, ovals = job.map_fn(keys, values)
        mc.outMapPairs = int(okeys.shape[0])
        mc.outMapSize = okeys.shape[0] * job.map_out_pair_width
        mc.times["map"] = time.perf_counter() - t0

        if hp.pNumReducers == 0:
            mc.intermDataPairs = mc.outMapPairs
            mc.intermDataSize = mc.outMapSize
            return [(okeys, ovals)], mc

        # ---- collect: partition (paper §2.2) ----
        t0 = time.perf_counter()
        part = _hash_partition(okeys, R)
        mc.times["collect"] = time.perf_counter() - t0

        # ---- spill: buffer sizing exactly as Eqs. 11-15 ----
        out_width = mc.outMapSize / max(mc.outMapPairs, 1)
        max_ser = int(
            hp.pSortMB * MiB * (1 - hp.pSortRecPerc) * hp.pSpillPerc
            // max(out_width, 1e-9)
        )
        max_acc = int(hp.pSortMB * MiB * hp.pSortRecPerc * hp.pSpillPerc // 16)
        buf_pairs = max(1, min(max_ser, max_acc, max(mc.outMapPairs, 1)))
        mc.spillBufferPairs = buf_pairs

        t0 = time.perf_counter()
        spills = []                    # list of (part, key, val) sorted chunks
        interm_ratio = 0.3 if hp.pIsIntermCompressed else 1.0
        for lo in range(0, mc.outMapPairs, buf_pairs):
            p, k, v = part[lo:lo+buf_pairs], okeys[lo:lo+buf_pairs], ovals[lo:lo+buf_pairs]
            order = np.lexsort((k, p))
            p, k, v = p[order], k[order], v[order]
            if self.use_combine:
                p, k, v = self._combine(p, k, v)
            spills.append((p, k, v))
            mc.spillFilePairs.append(int(k.shape[0]))
            mc.spillFileSize.append(
                k.shape[0] * job.map_out_pair_width * interm_ratio
            )
        mc.numSpills = len(spills)
        mc.numRecSpilled = sum(mc.spillFilePairs)
        mc.times["spill"] = time.perf_counter() - t0

        # ---- merge: io.sort.factor multi-pass semantics (paper §2.3) ----
        t0 = time.perf_counter()
        plan = merge_plan(mc.numSpills, hp.pSortFactor)
        mc.numMergePasses = plan.passes
        mc.numSpillsFinalMerge = plan.final_merge_width

        def merge_files(files):
            p = np.concatenate([f[0] for f in files])
            k = np.concatenate([f[1] for f in files])
            v = np.concatenate([f[2] for f in files])
            order = np.lexsort((k, p))
            return p[order], k[order], v[order]

        files = list(spills)
        if len(files) > 1:
            # first pass width P, then exactly-F passes (Eq. 20 semantics)
            widths = [plan.first_pass]
            remaining = len(files) - plan.first_pass
            while remaining >= hp.pSortFactor:
                widths.append(hp.pSortFactor)
                remaining -= hp.pSortFactor
            for w in widths:
                if len(files) <= hp.pSortFactor:
                    break
                if w <= 1:
                    continue
                group, files = files[:w], files[w:]
                merged = merge_files(group)
                rb = sum(g[1].shape[0] for g in group) * job.map_out_pair_width * interm_ratio
                mc.mergeReadBytes += rb
                mc.mergeWriteBytes += rb
                files.append(merged)

        # final merge -> single map-output file
        mc.usedCombineInMerge = (
            mc.numSpills > 1
            and self.use_combine
            and len(files) >= hp.pNumSpillsForComb
        )
        if len(files) > 1:
            mc.mergeReadBytes += sum(
                f[1].shape[0] for f in files
            ) * job.map_out_pair_width * interm_ratio
        p, k, v = merge_files(files) if len(files) > 1 else files[0]
        if mc.usedCombineInMerge:
            p, k, v = self._combine(p, k, v)
        mc.intermDataPairs = int(k.shape[0])
        mc.intermDataSize = k.shape[0] * job.map_out_pair_width * interm_ratio
        if len(spills) > 1:
            mc.mergeWriteBytes += mc.intermDataSize
        mc.times["merge"] = time.perf_counter() - t0

        segments = [
            (k[p == r], v[p == r]) for r in range(R)
        ]
        return segments, mc

    # --------------------------------------------------------- reduce task
    def run_reduce_task(self, segments: list):
        """``segments``: one (keys, values) tuple per mapper (this reducer's
        partition), sizes in *compressed* bytes per the paper's accounting."""
        hp, job = self.hp, self.job
        rc = ReduceCounters()
        interm_ratio = 0.3 if hp.pIsIntermCompressed else 1.0
        width = job.map_out_pair_width

        # ---- shuffle (paper §3.1) ----
        t0 = time.perf_counter()
        seg_pairs = [int(k.shape[0]) for k, _ in segments]
        rc.totalShufflePairs = sum(seg_pairs)
        seg_compr = [n * width * interm_ratio for n in seg_pairs]
        rc.totalShuffleSize = sum(seg_compr)
        rc.segmentComprSize = float(np.mean(seg_compr)) if seg_compr else 0.0
        seg_uncompr = rc.segmentComprSize / interm_ratio

        shuffle_buffer = hp.pShuffleInBufPerc * hp.pTaskMem
        merge_thr = hp.pShuffleMergePerc * shuffle_buffer

        if seg_uncompr < 0.25 * shuffle_buffer and seg_uncompr > 0:
            n_in_file = merge_thr / max(seg_uncompr, 1e-9)
            if np.ceil(n_in_file) * seg_uncompr <= shuffle_buffer:
                n_in_file = int(np.ceil(n_in_file))
            else:
                n_in_file = int(np.floor(n_in_file))
            n_in_file = max(1, min(n_in_file, hp.pInMemMergeThr))
        else:
            n_in_file = 1
        rc.numSegInShuffleFile = n_in_file

        # in-memory merges -> shuffle files on disk (combiner applies here
        # in Case 1 when merging actually happens)
        shuffle_files = []             # (keys, vals) sorted
        case1 = seg_uncompr < 0.25 * shuffle_buffer
        i = 0
        while i + n_in_file <= len(segments):
            group = segments[i:i + n_in_file]
            k = np.concatenate([g[0] for g in group])
            v = np.concatenate([g[1] for g in group])
            order = np.argsort(k, kind="stable")
            k, v = k[order], v[order]
            if self.use_combine and case1 and n_in_file > 1:
                _, k, v = self._combine(np.zeros_like(k), k, v)
            shuffle_files.append((k, v))
            rc.shuffleFilePairs.append(int(k.shape[0]))
            i += n_in_file
        in_mem = segments[i:]
        rc.numShuffleFiles = len(shuffle_files)
        rc.numSegmentsInMem = len(in_mem)

        # disk merges when shuffle files exceed 2F-1 (no combiner)
        F = hp.pSortFactor
        merged_files = []
        while len(shuffle_files) >= 2 * F - 1:
            group, shuffle_files = shuffle_files[:F], shuffle_files[F:]
            k = np.concatenate([g[0] for g in group])
            v = np.concatenate([g[1] for g in group])
            order = np.argsort(k, kind="stable")
            merged_files.append((k[order], v[order]))
            rc.numShuffleMerges += 1
        rc.times["shuffle"] = time.perf_counter() - t0

        # ---- sort/merge steps 1-3 (paper §3.2, counts via merge_math) ----
        t0 = time.perf_counter()
        on_disk = merged_files + shuffle_files
        files_to_merge = len(on_disk) + (1 if in_mem else 0)
        if files_to_merge > 1:
            plan = merge_plan(files_to_merge, F)
            rc.sortMergeReadBytes = (
                plan.interm_reads / max(files_to_merge, 1)
            ) * (sum(f[0].shape[0] for f in on_disk)
                 + sum(g[0].shape[0] for g in in_mem)) * width
        all_k = [f[0] for f in on_disk] + [g[0] for g in in_mem]
        all_v = [f[1] for f in on_disk] + [g[1] for g in in_mem]
        k = np.concatenate(all_k) if all_k else np.empty(0, np.int64)
        v = np.concatenate(all_v) if all_v else np.empty(0, np.float32)
        order = np.argsort(k, kind="stable")
        k, v = k[order], v[order]
        rc.times["sort"] = time.perf_counter() - t0

        # ---- reduce + write (paper §3.3) ----
        t0 = time.perf_counter()
        rc.inReducePairs = int(k.shape[0])
        out_k, out_v = [], []
        if k.size:
            uniq, starts = np.unique(k, return_index=True)
            rc.inReduceGroups = int(uniq.shape[0])
            bounds = np.append(starts, k.shape[0])
            if job.reduce_fn is None or job.reduce_pairs_per_group is None:
                out_k, out_v = [k], [v]
            else:
                groups = [
                    job.reduce_fn(v[bounds[i]:bounds[i+1]])
                    for i in range(uniq.shape[0])
                ]
                out_v = [np.concatenate(groups)]
                out_k = [np.repeat(uniq, [g.shape[0] for g in groups])]
        ok = np.concatenate(out_k) if out_k else np.empty(0, np.int64)
        ov = np.concatenate(out_v) if out_v else np.empty(0, np.float32)
        rc.outReducePairs = int(ok.shape[0])
        out_ratio = 0.4 if hp.pIsOutCompressed else 1.0
        rc.outReduceSize = ok.shape[0] * job.out_pair_width * out_ratio
        rc.times["reduce_write"] = time.perf_counter() - t0
        return (ok, ov), rc

    # -------------------------------------------------------------- driver
    def run_job(self, keys: np.ndarray, values: np.ndarray) -> JobCounters:
        hp = self.hp
        jc = JobCounters()
        M = max(hp.pNumMappers, 1)
        splits_k = np.array_split(keys, M)
        splits_v = np.array_split(values, M)

        all_segments: list[list] = [[] for _ in range(max(hp.pNumReducers, 1))]
        map_only_out = []
        for mk, mv in zip(splits_k, splits_v):
            segments, mc = self.run_map_task(mk, mv)
            jc.maps.append(mc)
            if hp.pNumReducers == 0:
                map_only_out.extend(segments)
            else:
                for r, seg in enumerate(segments):
                    all_segments[r].append(seg)

        if hp.pNumReducers == 0:
            ok = np.concatenate([s[0] for s in map_only_out])
            ov = np.concatenate([s[1] for s in map_only_out])
            jc.output = (ok, ov)
            return jc

        # network: all segments except the node-local fraction (Eq. 90)
        nodes = max(hp.pNumNodes, 1)
        total_interm = sum(mc.intermDataSize for mc in jc.maps)
        jc.netTransferBytes = total_interm * (nodes - 1) / nodes

        outs_k, outs_v = [], []
        for r in range(hp.pNumReducers):
            (ok, ov), rc = self.run_reduce_task(all_segments[r])
            jc.reduces.append(rc)
            outs_k.append(ok)
            outs_v.append(ov)
        jc.output = (np.concatenate(outs_k), np.concatenate(outs_v))
        return jc
