"""MapReduce-on-JAX: the executable substrate the paper's models predict.

* :mod:`engine`      — faithful Hadoop-semantics execution (buffers, spills,
  multi-pass merges, shuffle, reduce) with exact per-phase dataflow counters;
  the combiner runs on the Pallas ``seg_combine`` kernel.
* :mod:`distributed` — shard_map pipeline (map -> combine -> all_to_all
  shuffle -> sort -> reduce) for mesh execution and the multi-pod dry-run.
* :mod:`jobs`        — canonical benchmark jobs (wordcount, sort, filter,
  aggregate) with synthetic datasets.
* :mod:`profiler`    — Starfish-style profiler: measure ProfileStats +
  phase timings from a live run; fit CostFactors; predict other configs.
"""

from .engine import JobCounters, MapReduceEngine
from .jobs import JOBS, JobSpec, make_input

__all__ = ["MapReduceEngine", "JobCounters", "JobSpec", "JOBS", "make_input"]
