"""Canonical MapReduce jobs + synthetic datasets.

A :class:`JobSpec` is the engine-facing description of a job: the map and
reduce transforms (numpy-level, dynamic shapes — task orchestration is host
code in Hadoop too), whether a combiner applies, and the byte widths used
for the paper's size accounting.

Jobs are chosen so the profile statistics (Table 2) span the interesting
regimes:

  wordcount  — expansion map (pairs sel > 1), combiner highly reductive
  sort       — identity map/reduce, selectivities exactly 1 (exact-match
               validation of the dataflow equations is possible)
  filter     — map size/pairs selectivity < 1 (grep-style), no reduce work
  aggregate  — combiner + reducer collapse to one pair per key
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

__all__ = ["JobSpec", "JOBS", "make_input"]


@dataclass
class JobSpec:
    name: str
    # map: (keys, values) -> (keys, values); dynamic output length allowed
    map_fn: Callable[[np.ndarray, np.ndarray], tuple[np.ndarray, np.ndarray]]
    # reduce: applied per key group to the combined values
    reduce_fn: Callable[[np.ndarray], np.ndarray] | None
    use_combine: bool = False
    key_space: int = 1 << 15            # keys are ints in [0, key_space)
    pair_width: float = 100.0           # bytes per input K-V pair (accounting)
    map_out_pair_width: float = 100.0   # bytes per map-output pair
    out_pair_width: float = 100.0       # bytes per reduce-output pair
    # reduce output pairs per key group (1 = aggregate, None = identity)
    reduce_pairs_per_group: int | None = 1
    seed: int = 0
    meta: dict = field(default_factory=dict)


def make_input(
    job: JobSpec, n_pairs: int, *, seed: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Synthetic input split: (keys, values) with job-appropriate skew."""
    rng = np.random.default_rng(job.seed if seed is None else seed)
    if job.name == "wordcount":
        # records; the map tokenizes each into words (zipf-ish key skew)
        keys = rng.integers(0, job.key_space, n_pairs, dtype=np.int64)
    elif job.name == "sort":
        keys = rng.integers(0, job.key_space, n_pairs, dtype=np.int64)
    else:
        keys = rng.integers(0, job.key_space, n_pairs, dtype=np.int64)
    values = rng.random(n_pairs, dtype=np.float32)
    return keys, values


# ----------------------------------------------------------------- map fns

def _wordcount_map(keys: np.ndarray, values: np.ndarray):
    """Each record emits 4 'words'; word ids derived deterministically with a
    zipf-flavoured skew (frequent words get small ids)."""
    n = keys.shape[0]
    reps = 4
    base = np.repeat(keys, reps)
    offs = np.tile(np.arange(reps, dtype=np.int64), n)
    mixed = (base * 2654435761 + offs * 40503) % (1 << 31)
    # skew: half of all words map into a small hot set
    hot = (mixed % 2) == 0
    words = np.where(hot, mixed % 64, mixed % 8192)
    return words.astype(np.int64), np.ones(n * reps, np.float32)


def _identity_map(keys: np.ndarray, values: np.ndarray):
    return keys, values


def _filter_map(keys: np.ndarray, values: np.ndarray):
    keep = (keys % 5) == 0            # exact 20% selectivity by construction
    return keys[keep], values[keep]


def _aggregate_map(keys: np.ndarray, values: np.ndarray):
    return keys % 256, values          # collapse key space -> heavy combining


# -------------------------------------------------------------- reduce fns

def _sum_reduce(group_values: np.ndarray) -> np.ndarray:
    return np.asarray([group_values.sum()], np.float32)


def _identity_reduce(group_values: np.ndarray) -> np.ndarray:
    return group_values


JOBS: dict[str, JobSpec] = {
    "wordcount": JobSpec(
        name="wordcount",
        map_fn=_wordcount_map,
        reduce_fn=_sum_reduce,
        use_combine=True,
        key_space=1 << 15,
        pair_width=400.0,              # a text record
        map_out_pair_width=12.0,       # (word, 1)
        out_pair_width=12.0,
    ),
    "sort": JobSpec(
        name="sort",
        map_fn=_identity_map,
        reduce_fn=_identity_reduce,
        reduce_pairs_per_group=None,
        use_combine=False,
        key_space=1 << 20,
        pair_width=100.0,
        map_out_pair_width=100.0,
        out_pair_width=100.0,
    ),
    "filter": JobSpec(
        name="filter",
        map_fn=_filter_map,
        reduce_fn=_identity_reduce,
        reduce_pairs_per_group=None,
        use_combine=False,
        key_space=1 << 20,
        pair_width=200.0,
        map_out_pair_width=200.0,
        out_pair_width=200.0,
    ),
    "aggregate": JobSpec(
        name="aggregate",
        map_fn=_aggregate_map,
        reduce_fn=_sum_reduce,
        use_combine=True,
        key_space=1 << 20,
        pair_width=64.0,
        map_out_pair_width=16.0,
        out_pair_width=16.0,
    ),
}
