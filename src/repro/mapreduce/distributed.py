"""shard_map MapReduce pipeline: the paper's dataflow as mesh collectives.

The Hadoop pull-based shuffle becomes a single ``all_to_all`` over the mesh
axis (DESIGN.md §3): each of the N mapper shards combines its map output
into R = N dense per-partition blocks (``seg_combine`` — the Pallas
collect/partition/combine kernel), the all_to_all transposes mapper-major
blocks into reducer-major blocks, and the reduce is a per-key segmented sum
over the received segments.

The pipeline is fully jit-able with static shapes (dense key space), so it
can be:
  * executed on real devices (tests run it under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``),
  * lowered + compiled on the 256/512-chip production meshes by
    ``repro.launch.dryrun`` — giving the paper's own workload a roofline
    row where the collective term IS the shuffle (Eq. 90/91).

Keys are ints in [0, key_space); partitioning is range-based
(``key // (key_space / R)``), Hadoop's TotalOrderPartitioner analogue, so
the reduced output lands key-sorted across reducers — the sort the paper's
merge phases exist to produce.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

__all__ = ["wordcount_map_jax", "identity_map_jax", "mapreduce_pipeline", "make_pipeline"]


def wordcount_map_jax(keys: jax.Array, values: jax.Array, *, key_space: int):
    """jnp twin of jobs._wordcount_map (4 words per record, skewed ids)."""
    n = keys.shape[0]
    reps = 4
    base = jnp.repeat(keys, reps).astype(jnp.uint32)
    offs = jnp.tile(jnp.arange(reps, dtype=jnp.uint32), n)
    # uint32 wraparound == the numpy twin's int64 product mod 2**31
    mixed = ((base * jnp.uint32(2654435761) + offs * jnp.uint32(40503))
             & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
    hot = (mixed % 2) == 0
    words = jnp.where(hot, mixed % 64, mixed % 8192) % key_space
    return words, jnp.ones((n * reps,), values.dtype)


def identity_map_jax(keys: jax.Array, values: jax.Array, *, key_space: int):
    return keys % key_space, values


def mapreduce_pipeline(
    keys: jax.Array,            # (n_local,) int32 — this shard's split
    values: jax.Array,          # (n_local,) f32
    *,
    map_fn,
    key_space: int,
    num_shards: int,
    axis: str = "data",
    use_pallas: bool = True,
):
    """Per-shard body run under shard_map.  Returns this reducer's dense
    (key_space/num_shards,) combined+reduced output (sum semantics)."""
    mkeys, mvals = map_fn(keys, values, key_space=key_space)

    # collect/spill+combine: dense per-(partition, local key) sums
    block = key_space // num_shards
    if use_pallas:
        from repro.kernels import seg_combine

        dense = seg_combine(
            mvals[:, None], mkeys.astype(jnp.int32), key_space
        )[:, 0]
    else:
        dense = jnp.zeros((key_space,), jnp.float32).at[mkeys].add(
            mvals.astype(jnp.float32)
        )
    blocks = dense.reshape(num_shards, block)        # mapper-major segments

    # shuffle: all_to_all == Hadoop's copy phase over the mesh (Eq. 90).
    # tiled: row r of `blocks` goes to shard r; received rows stack back on
    # axis 0, so afterwards row m holds mapper m's segment for MY key range.
    recv = jax.lax.all_to_all(
        blocks, axis, split_axis=0, concat_axis=0, tiled=True
    )                                                 # (num_shards, block)

    # reduce-side merge + reduce: segments from every mapper, same key range
    return recv.sum(axis=0)                           # (block,)


def make_pipeline(
    mesh: Mesh,
    *,
    map_fn=wordcount_map_jax,
    key_space: int = 8192,
    axis: str = "data",
    use_pallas: bool = False,
):
    """jit-able global (keys, values) -> (key_space,) reduced sums."""
    num_shards = mesh.shape[axis]
    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    body = functools.partial(
        mapreduce_pipeline,
        map_fn=map_fn, key_space=key_space,
        num_shards=num_shards, axis=axis, use_pallas=use_pallas,
    )
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
        check_vma=False,
    )

    def run(keys, values):
        out = fn(keys, values)
        return out

    in_shardings = (
        NamedSharding(mesh, P(axis)),
        NamedSharding(mesh, P(axis)),
    )
    out_shardings = NamedSharding(mesh, P(axis))
    return jax.jit(run, in_shardings=in_shardings, out_shardings=out_shardings)
