"""repro.spec — typed, declarative descriptions of configs and costs.

The paper's value is its *structure*: three parameter tables (Tables 1-3)
and per-phase cost equations composed into job totals (Eqs. 2-98).  This
package is that structure as a first-class, typed API layer — the single
source every evaluator, strategy and service plumbs through instead of
re-inventing stringly-typed dict conventions:

* :mod:`~repro.spec.axes` — :class:`Axis` / :class:`Predicate` /
  :class:`ParamSpace`: declarative searchable axes (name, bounds, int vs
  float vs bool, unit, paper table) driving grid validation, override
  coercion and inspectable validity masks.  :func:`hadoop_space` is the
  paper's full Tables-1-3 space.
* :mod:`~repro.spec.job` — :class:`JobSpec`: the three parameter
  dataclasses as one frozen, hashable, pytree-registered value, losslessly
  convertible to/from the flat ``pack_config`` dict.
* :mod:`~repro.spec.report` — :class:`PhaseBreakdown` / :class:`CostReport`:
  the model's ``m_*``/``r_*``/``j_*`` dict outputs lifted into typed,
  vmap-able pytrees with paper equation numbers in field metadata and
  disaggregated validity (which §2.3 merge constraint failed, not just
  that one did).

The public surface of this package (and of :mod:`repro.api`) is frozen in
``manifest.json`` and guarded by ``tests/test_api_surface.py``; the
dict-key paths remain supported as thin adapters, bit-for-bit equal to the
typed path (asserted in CI over every ``mapreduce.JOBS`` profile).
"""

from .axes import Axis, ParamSpace, Predicate, hadoop_space
from .job import JobSpec
from .report import (
    PHASES,
    VALIDITY_CONSTRAINTS,
    CalibrationReport,
    CostReport,
    DagReport,
    PhaseBreakdown,
    ProvisioningReport,
    invalid_reason_counts,
    invalid_reasons,
)

__all__ = [
    "Axis",
    "Predicate",
    "ParamSpace",
    "hadoop_space",
    "JobSpec",
    "PhaseBreakdown",
    "CostReport",
    "CalibrationReport",
    "DagReport",
    "ProvisioningReport",
    "PHASES",
    "VALIDITY_CONSTRAINTS",
    "invalid_reason_counts",
    "invalid_reasons",
]
