"""Structured per-phase cost reports: :class:`PhaseBreakdown` + :class:`CostReport`.

The paper's output is not one number — it is a *decomposition*: per-phase
costs on the map side (read+map, collect/spill, merge, write) and the
reduce side (shuffle, sort/merge, reduce+write), plus the network transfer,
composed into job-level totals (Eqs. 92-98).  The batched model
(:func:`repro.core.hadoop.model.job_model_jnp`) emits all of it, but as a
flat ``m_*``/``r_*``/``j_*``-prefixed dict; this module lifts that dict
into typed, pytree-registered dataclasses whose fields carry the paper
equation numbers in their metadata:

* :class:`PhaseBreakdown` — the eight job-level phase costs, in seconds.
  They **sum to Eq. 98's total** (property-tested): each map phase is
  scaled by ``pNumMappers / map slots`` (Eqs. 92-93), each reduce phase by
  ``pNumReducers / reduce slots`` (Eqs. 94-95).
* :class:`CostReport` — the phase breakdown plus the job-level aggregates
  (Eqs. 96-98) and the *disaggregated* validity flags: where the flat path
  collapses ``mergeValid * step2Valid * step3Valid`` into one ``valid``
  float, a report says which closed-form constraint actually failed
  (:meth:`CostReport.invalid_reasons`).

Both classes are registered pytrees of arrays: they vmap, they ship
through jit, and a batched report is just a report whose leaves are
``(B,)`` columns.  ``total_cost`` is the model's ``j_totalCost`` array
*by reference* — the typed path is bit-for-bit the dict path.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, fields
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PhaseBreakdown",
    "CostReport",
    "CalibrationReport",
    "DagReport",
    "ProvisioningReport",
    "PHASES",
    "VALIDITY_CONSTRAINTS",
    "invalid_reason_counts",
    "invalid_reasons",
]


def _xp(*arrays):
    """numpy for numpy inputs, jax.numpy under jit/vmap (tracer-safe)."""
    import jax.numpy as jnp

    return jnp if any(isinstance(a, jax.Array) for a in arrays) else np


def _phase(eq: str, side: str, doc: str):
    return field(metadata={"eq": eq, "side": side, "doc": doc})


@dataclass(frozen=True)
class PhaseBreakdown:
    """Job-level per-phase costs in seconds (fields sum to Eq. 98).

    Field metadata carries the paper provenance:
    ``PhaseBreakdown.eq("shuffle") -> "Eqs. 35-61"``.
    """

    map_read: object = _phase(
        "Eqs. 2-4", "map", "read + decompress the split, run the map function")
    map_spill: object = _phase(
        "Eqs. 11-19", "map", "collect, serialize, sort, combine and spill")
    map_merge: object = _phase(
        "Eqs. 20-32", "map", "merge spill files into the final map output")
    map_write: object = _phase(
        "Eqs. 5-7", "map", "write map output to HDFS (map-only jobs)")
    shuffle: object = _phase(
        "Eqs. 35-61", "reduce", "fetch, buffer and shuffle-merge map segments")
    reduce_merge: object = _phase(
        "Eqs. 62-80", "reduce", "multi-step sort/merge of shuffled segments")
    reduce_write: object = _phase(
        "Eqs. 81-87", "reduce", "run the reduce function, write to HDFS")
    network: object = _phase(
        "Eqs. 90-91", "job", "cross-node shuffle transfer")

    @classmethod
    def names(cls) -> tuple[str, ...]:
        return tuple(f.name for f in fields(cls))

    @classmethod
    def eq(cls, name: str) -> str:
        return cls.__dataclass_fields__[name].metadata["eq"]

    @classmethod
    def describe(cls, name: str) -> str:
        m = cls.__dataclass_fields__[name].metadata
        return f"{m['doc']} ({m['eq']})"

    def total(self):
        """Sum of all phases == ``j_totalCost`` (Eqs. 96-98; tested)."""
        vals = [getattr(self, f.name) for f in fields(self)]
        out = vals[0]
        for v in vals[1:]:
            out = out + v
        return out

    def __getitem__(self, name: str):
        if name not in self.__dataclass_fields__:
            raise KeyError(
                f"unknown phase: {name!r} (phases: {list(self.names())})")
        return getattr(self, name)


PHASES: tuple[str, ...] = PhaseBreakdown.names()

#: constraint name -> (model output key, reduce-side?, human explanation).
#: These are the three §2.3 closed-form merge domains that the flat path
#: multiplies into a single ``valid`` float.
VALIDITY_CONSTRAINTS: dict[str, tuple[str, bool, str]] = {
    "mapMerge": (
        "m_mergeValid", False,
        "map-side spill merge out of the closed-form domain: "
        "numSpills > pSortFactor**2 (§2.3, Eqs. 20-26)",
    ),
    "shuffleMerge": (
        "r_step2Valid", True,
        "reduce-side disk merge (step 2) out of the closed-form domain: "
        "filesToMergeStep2 > pSortFactor**2 (Eq. 69)",
    ),
    "finalMerge": (
        "r_step3Valid", True,
        "reduce-side final merge (step 3) out of the closed-form domain: "
        "filesToMergeStep3 > pSortFactor**2 (Eq. 74)",
    ),
}


@dataclass(frozen=True)
class CostReport:
    """Typed view of one (or a batch of) job-model evaluation(s).

    Every leaf is an array; a batched report has ``(B,)`` columns.  The
    aggregate fields are the model's own outputs by reference (bit-for-bit
    with the ``j_*`` dict keys); ``phases`` re-scales the per-task phase
    costs to job level so they sum to ``total_cost``.
    """

    phases: PhaseBreakdown
    io_cost: object                 # Eq. 96  (j_ioJobCost)
    cpu_cost: object                # Eq. 97  (j_cpuJobCost)
    net_cost: object                # Eq. 91  (j_netCost)
    total_cost: object              # Eq. 98  (j_totalCost)
    valid: object                   # product of the three constraints below
    merge_valid: object             # §2.3 map-side domain (m_mergeValid)
    shuffle_valid: object           # Eq. 69 domain (r_step2Valid; 1 if map-only)
    sort_valid: object              # Eq. 74 domain (r_step3Valid; 1 if map-only)

    @classmethod
    def from_outputs(
        cls, outputs: Mapping[str, object], cfg: Mapping[str, object]
    ) -> "CostReport":
        """Build a report from flat model outputs + the (merged) config.

        ``outputs`` is a :func:`job_model_jnp` output dict (scalar or
        batched); ``cfg`` must resolve the five structural knobs the
        job-level scaling needs (``pNumMappers``, ``pNumReducers``,
        ``pNumNodes``, ``pMaxMapsPerNode``, ``pMaxRedPerNode``) — base
        config values with any swept columns merged over them.
        """
        xp = _xp(outputs["j_totalCost"])
        n_map = xp.asarray(cfg["pNumMappers"])
        n_red = xp.asarray(cfg["pNumReducers"])
        nodes = xp.asarray(cfg["pNumNodes"])
        m_scale = n_map / (nodes * xp.asarray(cfg["pMaxMapsPerNode"]))
        r_scale = n_red / (nodes * xp.asarray(cfg["pMaxRedPerNode"]))
        has_red = n_red > 0

        def mphase(io_key, cpu_key):
            return (outputs[io_key] + outputs[cpu_key]) * m_scale

        def rphase(io_key, cpu_key):
            return (outputs[io_key] + outputs[cpu_key]) * r_scale

        phases = PhaseBreakdown(
            map_read=mphase("m_ioReadCost", "m_cpuReadCost"),
            map_spill=mphase("m_ioSpillCost", "m_cpuSpillCost"),
            map_merge=mphase("m_ioMergeCost", "m_cpuMergeCost"),
            map_write=mphase("m_ioMapWriteCost", "m_cpuMapWriteCost"),
            shuffle=rphase("r_ioShuffleCost", "r_cpuShuffleCost"),
            reduce_merge=rphase("r_ioSortCost", "r_cpuSortCost"),
            reduce_write=rphase("r_ioWriteCost", "r_cpuWriteCost"),
            network=outputs["j_netCost"],
        )
        one = xp.ones_like(xp.asarray(outputs["valid"]))
        return cls(
            phases=phases,
            io_cost=outputs["j_ioJobCost"],
            cpu_cost=outputs["j_cpuJobCost"],
            net_cost=outputs["j_netCost"],
            total_cost=outputs["j_totalCost"],
            valid=outputs["valid"],
            merge_valid=outputs["m_mergeValid"],
            # the model zeroes ALL r_* outputs for map-only jobs, including
            # the flags; a constraint that cannot apply did not fail
            shuffle_valid=xp.where(has_red, outputs["r_step2Valid"], one),
            sort_valid=xp.where(has_red, outputs["r_step3Valid"], one),
        )

    # ---------------- validity introspection ----------------

    def invalid_reasons(self, i: int | None = None) -> list[str]:
        """Which closed-form constraints failed (for row ``i`` if batched)."""
        flags = {
            "mapMerge": self.merge_valid,
            "shuffleMerge": self.shuffle_valid,
            "finalMerge": self.sort_valid,
        }
        out = []
        for name, flag in flags.items():
            v = np.asarray(flag)
            failed = (v[i] if i is not None else v) == 0
            if np.any(failed):
                out.append(f"{name}: {VALIDITY_CONSTRAINTS[name][2]}")
        return out

    def best(self) -> int:
        """Index of the cheapest valid row (raises if none is valid)."""
        from repro.search.evaluator import InvalidGridError  # no import cycle at module load

        cost = np.where(np.asarray(self.valid) > 0,
                        np.asarray(self.total_cost), np.inf)
        if cost.size == 0 or not np.isfinite(cost).any():
            raise InvalidGridError(
                "no valid configuration in this report; reasons: "
                + "; ".join(self.invalid_reasons())
            )
        return int(np.argmin(cost))


@dataclass(frozen=True)
class ProvisioningReport:
    """Typed view of one (or a batch of) priced-fleet evaluation(s).

    The :class:`CostReport` of the economic layer
    (:class:`repro.cloud.CloudEvaluator`): dollars and SLO attainment
    instead of per-phase seconds.  Every leaf is an array and the class is
    a registered pytree — a batched report has ``(B,)`` columns, vmaps,
    and ships through jit like any output dict.
    """

    dollars_per_job: object      # workload bill / jobs served ($/job)
    dollar_makespan: object      # the whole workload's bill ($)
    slo_attainment: object       # fraction of jobs with latency <= sloLatency
    mean_latency: object         # seconds (submit -> finish)
    p95_latency: object          # seconds (latency_quantile(95) rule)
    utilization: object          # busy slot-seconds / online slot-seconds
    valid: object                # axis mask & simulator convergence

    @classmethod
    def from_outputs(cls, outputs: Mapping[str, object]
                     ) -> "ProvisioningReport":
        """Lift a :meth:`repro.cloud.CloudEvaluator.evaluate` output dict
        (the ``c_*`` columns) into the typed view, leaves by reference."""
        return cls(
            dollars_per_job=outputs["c_dollarsPerJob"],
            dollar_makespan=outputs["c_dollarMakespan"],
            slo_attainment=outputs["c_sloAttain"],
            mean_latency=outputs["c_meanLat"],
            p95_latency=outputs["c_p95Lat"],
            utilization=outputs["c_util"],
            valid=outputs["valid"],
        )


@dataclass(frozen=True)
class DagReport:
    """Critical-path decomposition of a measured multi-stage (DAG) run.

    Built from the cluster DES's per-stage times
    (:func:`repro.cluster.workload.dag_report`).  Every leaf is an array
    and the class is a registered pytree, like :class:`ProvisioningReport`.
    ``critical_path_s <= makespan_s`` always (property-tested): the path
    chains *measured* stage service times through the dependency edges, so
    scheduling/queueing slack can only add on top of it — equality means a
    serial (width-1) DAG ran back-to-back, and ``slack_s`` is the headroom
    a better schedule (or more slots) could recover.
    """

    critical_path_s: object    # longest dependency-respecting work chain (s)
    makespan_s: object         # first submit -> last stage finish (s)
    slack_s: object            # makespan - critical path
    stage_runtime_s: object    # (n,) measured per-stage service time
    stage_finish_s: object     # (n,) absolute per-stage finish time
    critical_stage: object     # index of the stage the critical path ends in

    @classmethod
    def from_times(cls, submit, first_launch, map_finish, finish, edges
                   ) -> "DagReport":
        """Build from measured per-stage times plus dependency edges.

        ``submit`` is each stage's *release* time (the DES overwrites a DAG
        child's submit with it), ``edges`` is ``(child, parent, kind)``
        triples with kind ``"barrier"`` or ``"slowstart"``.  The recurrence
        anchors each stage's measured runtime ``finish - first_launch`` at
        the latest of its release and its parents' path ends — a slowstart
        parent hands off at its path end minus its own post-map tail
        (``finish - map_finish``), since the child only needed the map
        phase.  Each anchor is ≤ the stage's actual first launch, which is
        what makes ``critical_path_s <= makespan_s`` an invariant rather
        than a tendency.
        """
        submit = np.asarray(submit, dtype=np.float64)
        first_launch = np.asarray(first_launch, dtype=np.float64)
        map_finish = np.asarray(map_finish, dtype=np.float64)
        finish = np.asarray(finish, dtype=np.float64)
        n = submit.shape[0]
        run = finish - first_launch
        parents: dict[int, list[tuple[int, str]]] = {}
        children: dict[int, list[int]] = {}
        indeg = [0] * n
        for child, parent, kind in edges:
            parents.setdefault(int(child), []).append((int(parent), kind))
            children.setdefault(int(parent), []).append(int(child))
            indeg[int(child)] += 1
        order = [i for i in range(n) if indeg[i] == 0]
        for i in order:                       # Kahn: parents precede children
            for ch in children.get(i, ()):
                indeg[ch] -= 1
                if indeg[ch] == 0:
                    order.append(ch)
        if len(order) != n:
            raise ValueError("dependency edges contain a cycle")
        cp_end = np.zeros(n, dtype=np.float64)
        for i in order:
            anchor = submit[i]
            for parent, kind in parents.get(i, ()):
                hand = cp_end[parent]
                if kind == "slowstart":
                    hand = hand - (finish[parent] - map_finish[parent])
                anchor = max(anchor, hand)
            cp_end[i] = anchor + run[i]
        t0 = submit.min() if n else 0.0
        span = finish.max() - t0 if n else 0.0
        cp = cp_end.max() - t0 if n else 0.0
        if n and not np.isfinite(finish).all():
            cp = span = float("inf")
        return cls(
            critical_path_s=jnp.asarray(cp),
            makespan_s=jnp.asarray(span),
            slack_s=jnp.asarray(span - cp if np.isfinite(span) else 0.0),
            stage_runtime_s=jnp.asarray(run),
            stage_finish_s=jnp.asarray(finish),
            critical_stage=jnp.asarray(int(np.argmax(cp_end)) if n else 0),
        )


@dataclass(frozen=True)
class CalibrationReport:
    """Result of one gradient-calibration run (:mod:`repro.calib`).

    The calibration counterpart of :class:`CostReport`: where a cost report
    decomposes one model *evaluation*, a calibration report decomposes one
    model *fit* — which parameters moved, from where to where, and how much
    of the observation error the fit removed.  Host-side values (plain
    floats), not a pytree: a report is what a fit returns, not what flows
    through jit.
    """

    fitted: dict[str, float]          # parameter name -> fitted value
    initial: dict[str, float]         # parameter name -> starting value
    loss: float                       # final loss (mean squared rel. error)
    initial_loss: float               # loss at the starting point
    steps: int                        # optimizer steps taken
    n_observations: int               # (JobSpec, cost) pairs fitted against
    loss_history: tuple[float, ...] = ()   # sampled loss trace
    #: grad-norm trace sampled at the same cadence as ``loss_history``
    #: (without the initial-point entry loss_history leads with)
    grad_norm_history: tuple[float, ...] = ()
    #: model evaluations the fit spent (loss/grad calls, incl. endpoints)
    n_model_evals: int = 0

    @property
    def param_names(self) -> tuple[str, ...]:
        return tuple(self.fitted)

    def improvement(self) -> float:
        """Fraction of the initial loss removed by the fit (0..1)."""
        if self.initial_loss <= 0.0:
            return 0.0
        return 1.0 - self.loss / self.initial_loss

    def delta(self, name: str) -> float:
        """Relative movement of one parameter from its starting value."""
        init = self.initial[name]
        if init == 0.0:
            return float("inf") if self.fitted[name] != 0.0 else 0.0
        return self.fitted[name] / init - 1.0

    def summary(self, top: int = 5) -> str:
        """A short human-readable fit digest (for logs and benchmarks)."""
        moved = sorted(
            self.fitted, key=lambda k: abs(self.delta(k)), reverse=True)
        lines = [
            f"calibrated {len(self.fitted)} parameter(s) over "
            f"{self.n_observations} observation(s) in {self.steps} steps: "
            f"loss {self.initial_loss:.3e} -> {self.loss:.3e} "
            f"({100.0 * self.improvement():.1f}% improvement)"
        ]
        for k in moved[:top]:
            lines.append(
                f"  {k}: {self.initial[k]:.4g} -> {self.fitted[k]:.4g}")
        return "\n".join(lines)


def invalid_reason_counts(
    outputs: Mapping[str, np.ndarray],
    cfg: Mapping[str, object] | None = None,
) -> dict[str, int]:
    """Per-constraint failure counts for a flat model-output batch.

    Used by the ``valid == 0`` exact-fallback log lines.  When ``cfg`` is
    given, reduce-side constraints are not counted for map-only rows
    (the model zeroes their flags there).  Returns only constraints whose
    output keys exist, so non-Hadoop evaluators yield ``{}``.
    """
    counts: dict[str, int] = {}
    for name, (key, reduce_side, _) in VALIDITY_CONSTRAINTS.items():
        if key not in outputs:
            continue
        failed = np.asarray(outputs[key]) == 0
        if reduce_side and cfg is not None and "pNumReducers" in cfg:
            failed = failed & (np.asarray(cfg["pNumReducers"]) > 0)
        n = int(np.sum(failed))
        if n:
            counts[name] = n
    return counts


def invalid_reasons(
    outputs: Mapping[str, np.ndarray],
    i: int,
    cfg: Mapping[str, object] | None = None,
) -> list[str]:
    """Human-readable failed constraints for row ``i`` of a flat batch."""
    out = []
    for name, (key, reduce_side, doc) in VALIDITY_CONSTRAINTS.items():
        if key not in outputs:
            continue
        if np.asarray(outputs[key]).ravel()[i] != 0:
            continue
        if reduce_side and cfg is not None and "pNumReducers" in cfg:
            n_red = np.asarray(cfg["pNumReducers"])
            if float(n_red.ravel()[i] if n_red.ndim else n_red) <= 0:
                continue
        out.append(f"{name}: {doc}")
    return out


def _register_struct(cls):
    names = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_pytree_node(
        cls,
        lambda x: (tuple(getattr(x, n) for n in names), None),
        lambda _, children: cls(*children),
    )


_register_struct(PhaseBreakdown)
_register_struct(CostReport)
_register_struct(ProvisioningReport)
_register_struct(DagReport)
