"""Declarative searchable-axis descriptions: :class:`Axis` + :class:`ParamSpace`.

The paper's Tables 1-3 define *what* a configuration is; this module makes
that structure machine-readable.  A :class:`ParamSpace` is the single source
of truth for

* **names + types** — which keys are sweepable, whether a key is an integer
  count, a boolean flag or a float, and which paper table it came from;
* **coercion** — how a float override (everything is a float array on
  device) routes back onto a typed dataclass field
  (:meth:`ParamSpace.apply`, replacing the old ad-hoc ``_coerce_field`` /
  ``apply_assignment`` pair in ``repro.search.evaluator``);
* **grid construction** — :meth:`ParamSpace.grid` validates a candidate
  space (unknown keys, out-of-bounds values, non-0/1 booleans) *before* a
  10^6-row product is streamed through an evaluator;
* **validity** — per-axis bounds plus named cross-axis :class:`Predicate`
  constraints produce a row mask with *inspectable* per-constraint reasons
  (:meth:`ParamSpace.validity_mask`), used by the cluster planner and the
  TPU tuner.  (The Hadoop job model's own validity — the §2.3 merge-math
  domain — depends on model outputs, not raw knobs, and is surfaced by
  :class:`repro.spec.report.CostReport` instead.)

Every cost model behind the :class:`repro.api.CostModel` facade exposes a
``param_space``; the axis-name sets are frozen in ``repro/spec/manifest.json``
and guarded by ``tests/test_api_surface.py``.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Callable, Iterator, Mapping, Sequence

import numpy as np

from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats

__all__ = ["Axis", "Predicate", "ParamSpace", "hadoop_space"]

_KINDS = ("float", "int", "bool")


@dataclass(frozen=True)
class Axis:
    """One searchable configuration axis (a row of a paper parameter table).

    ``kind`` drives coercion back onto typed fields: ``int``/``bool`` axes
    round (the device-side sweep is always float).  ``lower``/``upper`` are
    *physical* bounds used by :meth:`ParamSpace.grid` validation and the
    validity mask — not search ranges.
    """

    name: str
    kind: str = "float"
    lower: float | None = None
    upper: float | None = None
    lower_open: bool = False        # True: lower bound is exclusive
    unit: str = ""                  # "bytes", "fraction", "s/byte", ...
    table: str = ""                 # paper provenance ("Table 1", ...)
    group: str = ""                 # owning dataclass ("params"/"stats"/"costs")
    doc: str = ""

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"axis {self.name!r}: kind must be one of {_KINDS}")

    def coerce(self, value) -> int | bool | float:
        """One float override value -> the typed field value."""
        if self.kind == "int":
            return int(round(float(value)))
        if self.kind == "bool":
            return bool(round(float(value)))
        return float(value)

    def coerce_array(self, values: np.ndarray) -> np.ndarray:
        """Column form of :meth:`coerce` (ints/bools round to int64)."""
        v = np.asarray(values)
        if self.kind in ("int", "bool"):
            return np.round(v.astype(np.float64)).astype(np.int64)
        return v.astype(np.float64)

    def bounds_mask(self, values: np.ndarray) -> np.ndarray | None:
        """Per-row in-bounds mask (``None`` when the axis is unbounded).

        Boolean axes carry no bounds mask: their meaning is defined by
        coercion (``> 0.5`` rounds to True), not by a range.
        """
        if self.kind == "bool" or (self.lower is None and self.upper is None):
            return None
        v = self.coerce_array(values)
        ok = np.ones(v.shape, dtype=bool)
        if self.lower is not None:
            ok &= (v > self.lower) if self.lower_open else (v >= self.lower)
        if self.upper is not None:
            ok &= v <= self.upper
        return ok

    # ---------------- continuous relaxation ----------------

    def _relax_bounds(self) -> tuple[float | None, float | None]:
        # Boolean axes carry no declared bounds; their relaxation lives on
        # (0, 1) and rounds straight-through back to {0, 1}.
        if self.kind == "bool":
            return 0.0, 1.0
        lo = None if self.lower is None else float(self.lower)
        hi = None if self.upper is None else float(self.upper)
        return lo, hi

    def relax(self, value) -> np.ndarray:
        """Physical value -> unconstrained real (host-side, numpy).

        Inverse of :meth:`project` up to rounding: two-sided bounds use the
        logit, one-sided bounds the log offset (well-conditioned for cost
        factors spanning 1e-9..1e-7), unbounded axes the identity.  Values
        at a closed bound are nudged into the interior so the inverse stays
        finite.
        """
        v = np.asarray(value, dtype=np.float64)
        lo, hi = self._relax_bounds()
        if lo is not None and hi is not None:
            frac = np.clip((v - lo) / (hi - lo), 1e-9, 1.0 - 1e-9)
            return np.log(frac) - np.log1p(-frac)
        if lo is not None:
            return np.log(np.maximum(v - lo, 1e-30))
        if hi is not None:
            return np.log(np.maximum(hi - v, 1e-30))
        return v

    def project(self, u):
        """Unconstrained real -> differentiable in-domain value (device-side).

        The forward map of the relaxation: sigmoid for two-sided bounds,
        ``bound +/- exp(u)`` for one-sided, identity when unbounded.
        ``int``/``bool`` axes additionally round straight-through
        (:func:`repro.core.hadoop.merge_math.ste_round`): the forward value
        is an exact integer while the gradient treats the axis as
        continuous.
        """
        import jax
        import jax.numpy as jnp

        from repro.core.hadoop.merge_math import ste_round

        u = jnp.asarray(u)
        lo, hi = self._relax_bounds()
        if lo is not None and hi is not None:
            v = lo + (hi - lo) * jax.nn.sigmoid(u)
        elif lo is not None:
            v = lo + jnp.exp(u)
        elif hi is not None:
            v = hi - jnp.exp(u)
        else:
            v = u
        if self.kind in ("int", "bool"):
            v = ste_round(v)
        return v

    def check_values(self, values: Sequence[float]) -> None:
        """Raise ``ValueError`` on candidate values outside the axis domain."""
        v = np.asarray(list(values), dtype=np.float64)
        if self.kind == "bool":
            if not np.isin(np.round(v), (0.0, 1.0)).all():
                raise ValueError(
                    f"axis {self.name!r} is boolean; candidates must round "
                    f"to 0 or 1, got {values!r}"
                )
            return
        mask = self.bounds_mask(v)
        if mask is not None and not mask.all():
            bad = v[~mask]
            lo = f"({self.lower}" if self.lower_open else f"[{self.lower}"
            raise ValueError(
                f"axis {self.name!r}: candidate values {bad.tolist()} outside "
                f"domain {lo}, {self.upper}]"
            )


@dataclass(frozen=True)
class Predicate:
    """A named cross-axis validity constraint.

    ``fn`` receives *coerced* columns (ints/bools already rounded to int64)
    for every axis present and returns a boolean row mask.  The name is what
    shows up in validity-reason reports and fallback log lines.
    """

    name: str
    fn: Callable[[Mapping[str, np.ndarray]], np.ndarray]
    doc: str = ""


class ParamSpace:
    """An ordered, typed description of a model's searchable axes."""

    def __init__(self, axes: Sequence[Axis], predicates: Sequence[Predicate] = ()):
        self._axes: dict[str, Axis] = {}
        for ax in axes:
            if ax.name in self._axes:
                raise ValueError(f"duplicate axis: {ax.name!r}")
            self._axes[ax.name] = ax
        self.predicates = tuple(predicates)

    # ---------------- mapping-style introspection ----------------

    @property
    def axes(self) -> tuple[Axis, ...]:
        return tuple(self._axes.values())

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self._axes)

    def __contains__(self, name: str) -> bool:
        return name in self._axes

    def __getitem__(self, name: str) -> Axis:
        try:
            return self._axes[name]
        except KeyError:
            raise KeyError(
                f"unknown config key: {name!r} (known axes: {list(self._axes)})"
            ) from None

    def __iter__(self) -> Iterator[Axis]:
        return iter(self._axes.values())

    def __len__(self) -> int:
        return len(self._axes)

    # ---------------- coercion / routing ----------------

    def coerce(self, name: str, value) -> int | bool | float:
        return self[name].coerce(value)

    def coerce_assignment(self, assignment: Mapping[str, float]) -> dict:
        """Typed copy of a flat float assignment (raises on unknown keys)."""
        return {k: self[k].coerce(v) for k, v in assignment.items()}

    def apply(self, assignment: Mapping[str, float], *objs):
        """Route a flat assignment onto dataclass instances with coercion.

        For each object, fields named in ``assignment`` are replaced with
        the axis-coerced value; keys matching no object's fields are
        ignored (the historical ``apply_assignment`` contract).  Keys that
        are fields of an object use that axis's kind when the axis exists,
        otherwise plain float.
        """
        out = []
        for obj in objs:
            kw = {}
            for k, v in assignment.items():
                if k in obj.__dataclass_fields__:
                    kw[k] = self[k].coerce(v) if k in self else float(v)
            out.append(dataclasses.replace(obj, **kw) if kw else obj)
        return tuple(out)

    # ---------------- grid construction ----------------

    def grid(
        self, space: Mapping[str, Sequence[float]] | None = None, /, **axes
    ) -> dict[str, np.ndarray]:
        """Validated candidate space: ``{axis name: float64 candidates}``.

        The single entry point for building search spaces: unknown axis
        names, empty axes, out-of-bounds values and non-0/1 boolean
        candidates all fail *here*, before any evaluator streams the
        product.  The returned dict feeds ``repro.search`` strategies and
        ``WhatIfService.grid`` unchanged.
        """
        merged: dict[str, Sequence[float]] = dict(space or {})
        merged.update(axes)
        if not merged:
            raise ValueError("grid() needs at least one axis")
        out: dict[str, np.ndarray] = {}
        for name, values in merged.items():
            ax = self[name]
            vals = np.asarray(list(np.atleast_1d(values)), dtype=np.float64)
            if vals.size == 0:
                raise ValueError(f"axis {name!r} has no candidate values")
            ax.check_values(vals)
            out[name] = vals
        return out

    # ---------------- validity ----------------

    def validity_mask(
        self, cols: Mapping[str, np.ndarray]
    ) -> tuple[np.ndarray, dict[str, np.ndarray]]:
        """Row-validity of a column batch, with per-constraint masks.

        Returns ``(overall, reasons)`` where ``reasons`` maps constraint
        name (``"<axis> bounds"`` or a :class:`Predicate` name) to its own
        boolean mask — so a ``valid == 0`` row can say *which* constraint
        failed, not just that one did.
        """
        cols = {k: np.asarray(v) for k, v in cols.items()}
        for k in cols:
            self[k]                      # raise on unknown keys
        shape = np.broadcast_shapes(*(v.shape for v in cols.values())) \
            if cols else ()
        overall = np.ones(shape, dtype=bool)
        reasons: dict[str, np.ndarray] = {}
        for k, v in cols.items():
            m = self[k].bounds_mask(v)
            if m is not None:
                reasons[f"{k} bounds"] = np.broadcast_to(m, shape)
                overall = overall & m
        coerced = {k: self[k].coerce_array(v) for k, v in cols.items()}
        for pred in self.predicates:
            m = np.broadcast_to(np.asarray(pred.fn(coerced), dtype=bool), shape)
            reasons[pred.name] = m
            overall = overall & m
        return overall, reasons


# --------------------------------------------------------------------------
# the Hadoop space (paper Tables 1-3)
# --------------------------------------------------------------------------

# name -> (lower, upper, lower_open): physical domains, not search ranges.
_HADOOP_BOUNDS: dict[str, tuple[float | None, float | None, bool]] = {
    "pNumNodes": (1, None, False),
    "pTaskMem": (0, None, True),
    "pMaxMapsPerNode": (1, None, False),
    "pMaxRedPerNode": (1, None, False),
    "pNumMappers": (1, None, False),
    "pSortMB": (0, None, True),
    "pSpillPerc": (0, 1, True),
    "pSortRecPerc": (0, 1, False),
    "pSortFactor": (2, None, False),
    "pNumSpillsForComb": (0, None, False),
    "pNumReducers": (0, None, False),
    "pInMemMergeThr": (1, None, False),
    "pShuffleInBufPerc": (0, 1, False),
    "pShuffleMergePerc": (0, 1, False),
    "pReducerInBufPerc": (0, 1, False),
    "pReduceSlowstart": (0, 1, False),
    "pSplitSize": (0, None, True),
    "sInputPairWidth": (0, None, True),
    # Strictly positive: Eq. 10 (outPairWidth = outMapSize / outMapPairs)
    # divides by it; a profile observing literally zero map-output pairs has
    # no defined pair width, so 0 is outside the physical domain.
    "sMapPairsSel": (0, None, True),
    "sInputCompressRatio": (0, None, True),
    "sIntermCompressRatio": (0, None, True),
    "sOutCompressRatio": (0, None, True),
}

_HADOOP_UNITS: dict[str, str] = {
    "pTaskMem": "bytes",
    "pSortMB": "MB",
    "pSplitSize": "bytes",
    "pSpillPerc": "fraction",
    "pSortRecPerc": "fraction",
    "pShuffleInBufPerc": "fraction",
    "pShuffleMergePerc": "fraction",
    "pReducerInBufPerc": "fraction",
    "pReduceSlowstart": "fraction",
    "sInputPairWidth": "bytes/pair",
    "cHdfsReadCost": "s/byte",
    "cHdfsWriteCost": "s/byte",
    "cLocalIOCost": "s/byte",
    "cNetworkCost": "s/byte",
    "cMapCPUCost": "s/pair",
    "cReduceCPUCost": "s/pair",
    "cCombineCPUCost": "s/pair",
    "cPartitionCPUCost": "s/pair",
    "cSerdeCPUCost": "s/pair",
    "cSortCPUCost": "s/pair",
    "cMergeCPUCost": "s/pair",
    "cInUncomprCPUCost": "s/byte",
    "cIntermUncomprCPUCost": "s/byte",
    "cIntermComprCPUCost": "s/byte",
    "cOutComprCPUCost": "s/byte",
}


def _kind_of(field: dataclasses.Field) -> str:
    t = field.type if isinstance(field.type, str) else getattr(
        field.type, "__name__", "float")
    return {"int": "int", "bool": "bool"}.get(t, "float")


@functools.lru_cache(maxsize=None)
def hadoop_space() -> ParamSpace:
    """The paper's full configuration space, one axis per Table-1/2/3 field.

    Axis order matches :data:`repro.core.hadoop.model.CONFIG_KEYS` (the
    ``pack_config`` key order), so a packed flat config and the space
    enumerate identically.  Cached: the space is immutable.
    """
    axes = []
    for cls, table, group in (
        (HadoopParams, "Table 1", "params"),
        (ProfileStats, "Table 2", "stats"),
        (CostFactors, "Table 3", "costs"),
    ):
        for f in dataclasses.fields(cls):
            lower, upper, lo_open = _HADOOP_BOUNDS.get(f.name, (0, None, False))
            axes.append(Axis(
                name=f.name,
                kind=_kind_of(f),
                lower=lower,
                upper=upper,
                lower_open=lo_open,
                unit=_HADOOP_UNITS.get(f.name, ""),
                table=table,
                group=group,
            ))
    return ParamSpace(axes)
