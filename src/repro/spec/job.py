"""Typed job specification: the paper's three parameter tables as one value.

:class:`JobSpec` bundles the Table-1/2/3 dataclasses
(:class:`~repro.core.hadoop.params.HadoopParams`,
:class:`~repro.core.hadoop.params.ProfileStats`,
:class:`~repro.core.hadoop.params.CostFactors`) into a single frozen,
hashable, pytree-registered value — the unit every layer above passes
around instead of three positional dataclasses or a stringly-typed flat
dict.  Conversions are lossless both ways:

* :meth:`JobSpec.pack` -> the flat ``{key: jnp scalar}`` config the batched
  model (:func:`repro.core.hadoop.model.job_model_jnp`) consumes — exactly
  ``pack_config(params, stats, costs)``, so the typed path is bit-for-bit
  the dict path.
* :meth:`JobSpec.from_flat` <- a flat float mapping, with int/bool fields
  recovered through the :func:`repro.spec.axes.hadoop_space` axis kinds
  (round-tripping is property-tested in ``tests/test_spec.py``).

Pytree registration makes a ``JobSpec`` transparent to ``jax.tree_util``:
leaves are the 42 scalar field values in ``CONFIG_KEYS`` order, so specs
can be tree-mapped, stacked, or donated through jit boundaries without
bespoke plumbing.
"""

from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core.hadoop.model import CONFIG_KEYS, pack_config
from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats

from .axes import ParamSpace, hadoop_space

__all__ = ["JobSpec"]


@dataclass(frozen=True)
class JobSpec:
    """One fully-specified job: Hadoop knobs + data/UDF profile + cost factors.

    Frozen and hashable (all three members are frozen dataclasses of
    scalars), so a ``JobSpec`` can key caches — e.g. the facade's evaluator
    cache — the same way the ``(p, s, c)`` triple always did.
    """

    params: HadoopParams = HadoopParams()
    stats: ProfileStats = ProfileStats()
    costs: CostFactors = CostFactors()
    name: str = ""

    # ---------------- conversions ----------------

    def pack(self) -> dict[str, jnp.ndarray]:
        """The flat float config the batched model consumes (``pack_config``)."""
        return pack_config(self.params, self.stats, self.costs)

    @classmethod
    def from_flat(cls, cfg: Mapping[str, float], *, name: str = "") -> "JobSpec":
        """Inverse of :meth:`pack`: typed spec from a flat float mapping.

        Missing keys keep their dataclass defaults; int/bool fields are
        recovered via the axis kinds, so
        ``JobSpec.from_flat(spec.pack()) == spec`` exactly (in the repo's
        float64 mode — float32 packing quantizes float fields).
        """
        space = hadoop_space()
        objs = []
        for dc_cls in (HadoopParams, ProfileStats, CostFactors):
            kw = {
                k: space.coerce(k, float(cfg[k]))
                for k in dc_cls.__dataclass_fields__
                if k in cfg
            }
            objs.append(dc_cls(**kw))
        return cls(*objs, name=name)

    def replace(self, **assignment) -> "JobSpec":
        """New spec with flat-key overrides routed onto the right table.

        Accepts the same keys as the search layer's override dicts
        (``pSortMB=200.0, pUseCombine=1.0, ...``) with axis coercion, plus
        ``name=``.
        """
        name = assignment.pop("name", self.name)
        p, s, c = hadoop_space().apply(
            assignment, self.params, self.stats, self.costs)
        unknown = [
            k for k in assignment
            if not any(k in o.__dataclass_fields__ for o in (p, s, c))
        ]
        if unknown:
            raise KeyError(f"unknown config key(s): {unknown}")
        return JobSpec(p, s, c, name=name)

    # ---------------- introspection ----------------

    @property
    def param_space(self) -> ParamSpace:
        return hadoop_space()

    def __getitem__(self, key: str) -> float:
        for obj in (self.params, self.stats, self.costs):
            if key in obj.__dataclass_fields__:
                return getattr(obj, key)
        raise KeyError(f"unknown config key: {key!r}")


def _flatten_jobspec(spec: JobSpec):
    leaves = tuple(
        getattr(obj, f.name)
        for obj in (spec.params, spec.stats, spec.costs)
        for f in fields(obj)
    )
    return leaves, spec.name


def _unflatten_jobspec(name: str, leaves):
    it = iter(leaves)
    objs = []
    for dc_cls in (HadoopParams, ProfileStats, CostFactors):
        names = [f.name for f in fields(dc_cls)]
        objs.append(dc_cls(**{n: next(it) for n in names}))
    return JobSpec(*objs, name=name)


jax.tree_util.register_pytree_node(JobSpec, _flatten_jobspec, _unflatten_jobspec)

assert len(CONFIG_KEYS) == sum(
    len(fields(c)) for c in (HadoopParams, ProfileStats, CostFactors)
)
