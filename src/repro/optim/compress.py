"""Gradient compression for cross-pod all-reduce (distributed-opt trick).

Two compressors applied *before* the gradient all-reduce, with error
feedback so compression noise is unbiased over steps:

* ``bf16``  — round-to-bfloat16 (2x cross-pod traffic reduction, near-free).
* ``int8``  — per-tensor symmetric int8 quantization (4x), with an error
  feedback accumulator (Karimireddy et al.-style EF-SGD) carried in the
  runtime state.

The runtime applies these only to the slow ("pod") axis reduction; on-chip
ICI reductions stay full precision.  See ``repro.runtime.train_loop``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["compress_init", "compress_grads", "decompress_grads"]


def compress_init(params: Any, method: str) -> Any:
    if method == "int8":
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return None  # bf16 / none need no error state


def _quant_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: Any, err: Any, method: str):
    """Returns (compressed_tree, new_error_tree).

    compressed leaves: bf16 arrays, or (int8 values, fp32 scale) tuples.
    """
    if method == "none":
        return grads, err
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), err
    if method == "int8":
        outs, errs = [], []
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(err)
        for g, e in zip(flat_g, flat_e):
            corrected = g.astype(jnp.float32) + e
            q, scale = _quant_int8(corrected)
            deq = q.astype(jnp.float32) * scale
            outs.append((q, scale))
            errs.append(corrected - deq)
        return (
            jax.tree.unflatten(treedef, [o for o in outs]),
            jax.tree.unflatten(treedef, errs),
        )
    raise ValueError(f"unknown compression method {method!r}")


def decompress_grads(comp: Any, grads_like: Any, method: str) -> Any:
    if method == "none":
        return comp
    if method == "bf16":
        return jax.tree.map(
            lambda c, g: c.astype(g.dtype), comp, grads_like
        )
    if method == "int8":
        flat_c = jax.tree.leaves(comp, is_leaf=lambda x: isinstance(x, tuple))
        flat_g, treedef = jax.tree.flatten(grads_like)
        outs = [
            (q.astype(jnp.float32) * s).astype(g.dtype)
            for (q, s), g in zip(flat_c, flat_g)
        ]
        return jax.tree.unflatten(treedef, outs)
    raise ValueError(method)
