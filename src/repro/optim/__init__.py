"""Optimizers and distributed-optimization utilities."""

from .adamw import AdamWConfig, adamw_init, adamw_update, lr_at
from .compress import compress_grads, compress_init, decompress_grads

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "lr_at",
    "compress_grads", "compress_init", "decompress_grads",
]
