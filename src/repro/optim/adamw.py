"""AdamW with decoupled weight decay and linear-warmup cosine schedule.

Built here (no external optimizer dependency); the state pytree mirrors the
parameter pytree, so every sharding rule that applies to a parameter applies
verbatim to its first/second moments — checkpointing and elastic resharding
reuse one rule set for everything.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_at"]


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to ``min_lr_ratio * peak``."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.peak_lr * (
        cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any, state: dict, params: Any, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, count)

    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)

    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "count": count,
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
