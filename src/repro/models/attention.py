"""GQA attention: XLA-chunked prefill (flash-style online softmax) + decode.

Three execution paths, selected by the caller:

* ``chunked_attention``  — prefill/training path.  A ``lax.scan`` over KV
  chunks with online-softmax accumulation, so the (Sq x Sk) score matrix is
  never materialized in HBM — the XLA analogue of flash attention, and the
  formulation the Pallas kernel (``repro.kernels.flash_attention``) mirrors
  block-for-block.  Supports causal, sliding-window and bidirectional masks
  plus Gemma-2 logit soft-capping.
* ``decode_attention``   — single-query attention over a (possibly ring)
  KV cache, used by ``serve_step``.
* Pallas kernels         — TPU target; wired in via ``repro.kernels.ops``
  when ``attention_impl='pallas'`` (validated in interpret mode on CPU).

KV caches come in two layouts (chosen per layer kind):

* **full** — slot ``i`` holds position ``i``; size = max context.
* **ring** — slot ``i`` holds the latest position ``p == i (mod W)``; size =
  window ``W``.  Local-attention layers use ring caches, which is what makes
  ``long_500k`` decode memory O(W) instead of O(context) for those layers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .layers import apply_rope, softcap

__all__ = [
    "init_attention",
    "attention_projections",
    "chunked_attention",
    "decode_attention",
    "attn_block_prefill",
    "attn_block_decode",
    "init_kv_cache",
    "set_attention_impl",
    "get_attention_impl",
]

_NEG = -1e30

# "xla" (lax.scan online softmax) | "pallas" (repro.kernels, interpret on CPU).
_IMPL = "xla"


def set_attention_impl(impl: str) -> None:
    assert impl in ("xla", "pallas"), impl
    global _IMPL
    _IMPL = impl


def get_attention_impl() -> str:
    return _IMPL


def _dispatch_prefill(q, k, v, *, causal, window, logit_cap, q_offset):
    if _IMPL == "pallas":
        from repro.kernels import flash_attention as _fa  # lazy: optional path

        bq = min(128, max(8, q.shape[2]))
        bk = min(128, max(8, k.shape[2]))
        return _fa(q, k, v, causal, window, logit_cap, q_offset, bq, bk)
    from .opt_flags import get_flags

    if get_flags().flash_bwd:
        return flash_attention_xla(q, k, v, causal, window, logit_cap, q_offset)
    return chunked_attention(
        q, k, v, causal=causal, window=window, logit_cap=logit_cap,
        q_offset=q_offset,
    )


def _dispatch_decode(q, k_cache, v_cache, slot_pos, pos, *, window, logit_cap):
    if _IMPL == "pallas":
        from repro.kernels import gqa_decode_attention as _da

        return _da(
            q, k_cache, v_cache, slot_pos, pos,
            window=window, logit_cap=logit_cap,
        )
    return decode_attention(
        q, k_cache, v_cache, slot_pos, pos, window=window, logit_cap=logit_cap
    )


def init_attention(key, d: int, n_heads: int, n_kv: int, head_dim: int) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    so = (n_heads * head_dim) ** -0.5
    return {
        "q": jax.random.normal(kq, (d, n_heads, head_dim), jnp.float32) * s,
        "k": jax.random.normal(kk, (d, n_kv, head_dim), jnp.float32) * s,
        "v": jax.random.normal(kv, (d, n_kv, head_dim), jnp.float32) * s,
        "o": jax.random.normal(ko, (n_heads, head_dim, d), jnp.float32) * so,
    }


def attention_projections(p: dict, x: jax.Array):
    """x: (B, S, d) -> q (B, H, S, hd), k/v (B, KV, S, hd)."""
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bhsk", x, p["q"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bhsk", x, p["k"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bhsk", x, p["v"].astype(dtype))
    return q, k, v


def _mask_bias(q_pos, k_pos, *, causal: bool, window: int | None, k_len=None):
    """(Sq, C) additive mask bias in fp32."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    if k_len is not None:
        ok &= k_pos[None, :] < k_len
    return jnp.where(ok, 0.0, _NEG)


def chunked_attention(
    q: jax.Array,               # (B, H, Sq, hd)
    k: jax.Array,               # (B, KV, Sk, hd)
    v: jax.Array,               # (B, KV, Sk, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    logit_cap: float | None = None,
    q_offset: int = 0,
    chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention via lax.scan over KV chunks.  O(Sq*chunk) temps."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    G = H // KV
    dtype = q.dtype
    scale = hd ** -0.5

    chunk = min(chunk, Sk)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (Sk + pad) // chunk

    qg = q.reshape(B, KV, G, Sq, hd)
    q_pos = q_offset + jnp.arange(Sq)

    # (n_chunks, B, KV, chunk, hd)
    ks = k.reshape(B, KV, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, KV, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, idx = inputs
        k_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bngqh,bnch->bngqc", qg, kc).astype(jnp.float32) * scale
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)
        s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window, k_len=Sk)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bngqc,bnch->bngqh", p.astype(dtype), vc
        ).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Sq), _NEG, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (ks, vs, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, Sq, hd).astype(dtype)


def _chunk_mask_bias(q_pos, k_pos, *, causal, window, k_len):
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    ok &= k_pos[None, :] < k_len
    return jnp.where(ok, 0.0, _NEG)


def _flash_fwd_stats(q, k, v, causal, window, logit_cap, q_offset, chunk):
    """chunked_attention forward that also returns (m, l) softmax stats."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    G = H // KV
    dtype = q.dtype
    scale = hd ** -0.5
    chunk = min(chunk, Sk)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (Sk + pad) // chunk
    qg = q.reshape(B, KV, G, Sq, hd)
    q_pos = q_offset + jnp.arange(Sq)

    ks = k.reshape(B, KV, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, KV, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, idx = inputs
        k_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bngqh,bnch->bngqc", qg, kc).astype(jnp.float32) * scale
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)
        s = s + _chunk_mask_bias(q_pos, k_pos, causal=causal, window=window, k_len=Sk)
        m_new = jnp.maximum(m, s.max(axis=-1))
        pmat = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + pmat.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bngqc,bnch->bngqh", pmat.astype(dtype), vc
        ).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Sq), _NEG, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, jnp.arange(n_chunks)))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)
    return out.reshape(B, H, Sq, hd), m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_xla(q, k, v, causal=True, window=None, logit_cap=None, q_offset=0):
    """XLA flash attention with an O(Sq + chunk) *backward*.

    Plain autodiff through the ``lax.scan`` of :func:`chunked_attention`
    saves every per-chunk fp32 score/prob matrix for the backward —
    measured at ~45% of per-device HBM traffic on starcoder2/train_4k
    (EXPERIMENTS.md §Perf).  This custom VJP saves only (q, k, v, out, m,
    l) and *recomputes* scores chunk-by-chunk in the backward — the
    standard flash-attention backward, expressed in XLA."""
    out, _, _ = _flash_fwd_stats(q, k, v, causal, window, logit_cap, q_offset, 1024)
    return out


def _flashx_fwd(q, k, v, causal, window, logit_cap, q_offset):
    out, m, l = _flash_fwd_stats(q, k, v, causal, window, logit_cap, q_offset, 1024)
    return out, (q, k, v, out, m, l)


def _flashx_bwd(causal, window, logit_cap, q_offset, res, dout):
    q, k, v, out, m, l = res
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    chunk = min(1024, Sk)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (Sk + pad) // chunk
    dtype = q.dtype
    scale = hd ** -0.5

    qg = q.reshape(B, KV, G, Sq, hd)
    og = out.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    dog = dout.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    l_safe = jnp.maximum(l, 1e-30)
    q_pos = q_offset + jnp.arange(Sq)
    # delta_i = sum_h dout_ih * out_ih  (flash-bwd row correction)
    delta = jnp.sum(dog * og, axis=-1)                     # (B,KV,G,Sq)

    ks = k.reshape(B, KV, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, KV, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)

    def body(dq_acc, inputs):
        kc, vc, idx = inputs
        k_pos = idx * chunk + jnp.arange(chunk)
        raw = jnp.einsum("bngqh,bnch->bngqc", qg, kc).astype(jnp.float32) * scale
        if logit_cap is not None:
            t = jnp.tanh(raw / logit_cap)
            s = logit_cap * t
        else:
            s = raw
        s = s + _chunk_mask_bias(q_pos, k_pos, causal=causal, window=window, k_len=Sk)
        pmat = jnp.exp(s - m[..., None]) / l_safe[..., None]      # (B,KV,G,Sq,c)

        dv_c = jnp.einsum("bngqc,bngqh->bnch", pmat.astype(dtype), dog.astype(dtype))
        dp = jnp.einsum("bngqh,bnch->bngqc", dog.astype(dtype), vc).astype(jnp.float32)
        ds = pmat * (dp - delta[..., None])                        # d(s_used)
        if logit_cap is not None:
            ds = ds * (1.0 - t * t)                                # through tanh
        ds = (ds * scale).astype(dtype)
        dq_c = jnp.einsum("bngqc,bnch->bngqh", ds, kc)
        dk_c = jnp.einsum("bngqc,bngqh->bnch", ds, qg)
        return dq_acc + dq_c.astype(jnp.float32), (dk_c, dv_c)

    dq0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (ks, vs, jnp.arange(n_chunks)))
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(B, KV, n_chunks * chunk, hd)[:, :, :Sk]
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(B, KV, n_chunks * chunk, hd)[:, :, :Sk]
    return (
        dq.reshape(B, H, Sq, hd).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


flash_attention_xla.defvjp(_flashx_fwd, _flashx_bwd)


def decode_attention(
    q: jax.Array,               # (B, H, 1, hd)
    k_cache: jax.Array,         # (B, KV, S_cache, hd)
    v_cache: jax.Array,         # (B, KV, S_cache, hd)
    slot_pos: jax.Array,        # (S_cache,) int32: position held by each slot
    pos: jax.Array,             # scalar int32: current position
    *,
    window: int | None = None,
    logit_cap: float | None = None,
) -> jax.Array:
    """Single-token attention over a (full or ring) KV cache."""
    B, H, _, hd = q.shape
    KV = k_cache.shape[1]
    G = H // KV
    dtype = q.dtype
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bngh,bnch->bngc", qg, k_cache).astype(jnp.float32)
    s = s * hd ** -0.5
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        ok &= slot_pos > pos - window
    s = jnp.where(ok[None, None, None, :], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngc,bnch->bngh", w.astype(dtype), v_cache)
    return out.reshape(B, H, 1, hd).astype(dtype)


# ------------------------------------------------------------------ caches

def init_kv_cache(
    batch: int, n_kv: int, size: int, head_dim: int, dtype
) -> dict:
    """Layout for both full (size=max ctx) and ring (size=window) caches."""
    return {
        "k": jnp.zeros((batch, n_kv, size, head_dim), dtype),
        "v": jnp.zeros((batch, n_kv, size, head_dim), dtype),
    }


def _ring_slot_positions(pos: jax.Array, size: int) -> jax.Array:
    """Position stored in each ring slot at decode time ``pos``.

    Slot ``i`` holds the latest position p <= pos with p == i (mod size);
    slots never written (p < 0) are masked by the caller.
    """
    i = jnp.arange(size)
    return pos - jnp.mod(pos - i, size)


def _full_slot_positions(size: int) -> jax.Array:
    return jnp.arange(size)


# ------------------------------------------------------------------ blocks

def attn_block_prefill(
    p: dict,
    x: jax.Array,               # (B, S, d)
    inv_freq: jax.Array,
    *,
    kind: str,                  # "attn" | "local" | "encoder" | "cross"
    window: int,
    logit_cap: float | None,
    cache_size: int | None = None,   # build a cache of this size if not None
    kv_override: tuple | None = None,  # (k, v) for cross-attention
    q_offset: int = 0,
):
    """Prefill/training attention; optionally returns an initialized cache."""
    B, S, d = x.shape
    if kv_override is None:
        q, k, v = attention_projections(p, x)
    else:
        dtype = x.dtype
        q = jnp.einsum("bsd,dhk->bhsk", x, p["q"].astype(dtype))
        k, v = kv_override

    positions = q_offset + jnp.arange(S)
    if kind != "cross":
        q = apply_rope(q, positions[None, None, :], inv_freq)
        if kv_override is None:
            k = apply_rope(k, positions[None, None, :], inv_freq)

    causal = kind in ("attn", "local")
    win = window if kind == "local" else None
    out = _dispatch_prefill(
        q, k, v, causal=causal, window=win, logit_cap=logit_cap,
        q_offset=q_offset,
    )
    y = jnp.einsum("bhsk,hkd->bsd", out, p["o"].astype(x.dtype))

    cache = None
    if cache_size is not None:
        n_kv, hd = k.shape[1], k.shape[3]
        cache = init_kv_cache(B, n_kv, cache_size, hd, x.dtype)
        if kind == "local" and cache_size < S:
            take = cache_size
            last_pos = positions[S - take:]
            slots = jnp.mod(last_pos, cache_size)
            cache = {
                "k": cache["k"].at[:, :, slots].set(k[:, :, S - take:]),
                "v": cache["v"].at[:, :, slots].set(v[:, :, S - take:]),
            }
        else:
            upto = min(S, cache_size)
            cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k[:, :, :upto], 0, 2),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v[:, :, :upto], 0, 2),
            }
    return y, cache


def attn_block_decode(
    p: dict,
    x: jax.Array,               # (B, 1, d)
    cache: dict,
    pos: jax.Array,             # scalar int32 — position of this token
    inv_freq: jax.Array,
    *,
    kind: str,                  # "attn" | "local" | "cross"
    window: int,
    logit_cap: float | None,
):
    """One decode step: update cache (unless cross) and attend over it."""
    B, _, d = x.shape
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bhsk", x, p["q"].astype(dtype))
    size = cache["k"].shape[2]

    if kind == "cross":
        slot_pos = _full_slot_positions(size)
        out = _dispatch_decode(
            q, cache["k"], cache["v"], slot_pos, jnp.asarray(size, jnp.int32),
            window=None, logit_cap=logit_cap,
        )
        y = jnp.einsum("bhsk,hkd->bsd", out, p["o"].astype(dtype))
        return y, cache

    k = jnp.einsum("bsd,dhk->bhsk", x, p["k"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bhsk", x, p["v"].astype(dtype))
    posb = jnp.reshape(pos, (1, 1, 1))
    q = apply_rope(q, jnp.broadcast_to(posb, (B, 1, 1)), inv_freq)
    k = apply_rope(k, jnp.broadcast_to(posb, (B, 1, 1)), inv_freq)

    if kind == "local":
        slot = jnp.mod(pos, size)
        slot_pos = _ring_slot_positions(pos, size)
        win = window
    else:
        slot = pos
        slot_pos = _full_slot_positions(size)
        win = None

    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 2)

    out = _dispatch_decode(
        q, k_cache, v_cache, slot_pos, pos, window=win, logit_cap=logit_cap
    )
    y = jnp.einsum("bhsk,hkd->bsd", out, p["o"].astype(dtype))
    return y, {"k": k_cache, "v": v_cache}
