"""Mamba-2 block via the SSD (state-space duality) chunked algorithm.

The paper's SSD formulation splits the sequence into chunks: inside a chunk
the recurrence is computed in its quadratic "attention-like" dual form
(MXU-friendly (L x L) matmuls); across chunks only the (H, P, N) states are
carried by a ``lax.scan``.  Memory is O(L^2) per chunk instead of O(S^2),
and the sequential dependency is S/L steps instead of S — the TPU-native
adaptation of Mamba-2's CUDA kernel.

Decode is the exact O(1) recurrence: ``h = a h + dt * (B (x) x)``,
``y = C . h + D x``.

Layout notes: a single B/C group is shared across all heads (n_groups=1,
as in mamba2-130m); dt, A, D are per-head scalars.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_norm, init_norm

__all__ = ["init_ssm", "ssm_prefill", "ssm_decode", "init_ssm_state"]


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner_ssm
    H = cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_ch = d_in + 2 * N  # conv runs over [x, B, C]
    return d_in, H, P, N, conv_ch


def init_ssm(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, H, P, N, conv_ch = _dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": jax.random.normal(k1, (d, proj_out), jnp.float32) * d ** -0.5,
        "conv_w": jax.random.normal(k2, (cfg.ssm_conv, conv_ch), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),   # softplus ~ 0.12
        "norm": init_norm(d_in, "rmsnorm"),
        "out_proj": jax.random.normal(k4, (d_in, d), jnp.float32) * d_in ** -0.5,
    }


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, H, P, N, conv_ch = _dims(cfg)
    return {
        "h": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_in, H, P, N, _ = _dims(cfg)
    z = proj[..., :d_in]
    xbc = proj[..., d_in : d_in + d_in + 2 * N]
    dt = proj[..., -H:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq; xbc (B, S, C), w (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(K):  # K=4: unrolled shifts beat conv_general on TPU here
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def ssm_prefill(
    p: dict, x: jax.Array, cfg: ModelConfig, *, chunk: int = 128
) -> tuple[jax.Array, dict]:
    """x: (B, S, d) -> (y (B, S, d), final recurrent state)."""
    B, S, d = x.shape
    d_in, H, P, N, conv_ch = _dims(cfg)
    dtype = x.dtype

    proj = x @ p["in_proj"].astype(dtype)
    z, xbc_raw, dt_raw = _split_proj(cfg, proj)
    conv_tail = xbc_raw[:, max(0, S - (cfg.ssm_conv - 1)) :, :]
    xbc = _causal_conv(xbc_raw, p["conv_w"].astype(dtype), p["conv_b"].astype(dtype))
    xs = xbc[..., :d_in].reshape(B, S, H, P)
    Bm = xbc[..., d_in : d_in + N]                       # (B, S, N)
    Cm = xbc[..., d_in + N :]                            # (B, S, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    neg_A = jnp.exp(p["A_log"])                           # (H,)
    la = -neg_A * dt                                      # log decay, <= 0

    # ---- chunked SSD scan ----
    pad = (-S) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
    nC = (S + pad) // chunk

    def to_chunks(a):
        return a.reshape((B, nC, chunk) + a.shape[2:]).swapaxes(0, 1)

    xs_c, Bm_c, Cm_c, dt_c, la_c = map(to_chunks, (xs, Bm, Cm, dt, la))

    def body(h, inp):
        xc, bc, cc, dtc, lac = inp           # (B, L, ...) for one chunk
        cum = jnp.cumsum(lac, axis=1)        # (B, L, H)
        # inter-chunk: contribution of the carried state.
        y_inter = jnp.einsum("bln,bhpn->blhp", cc.astype(jnp.float32), h)
        y_inter = y_inter * jnp.exp(cum)[..., None]
        # intra-chunk quadratic dual form.
        cb = jnp.einsum("bin,bjn->bij", cc.astype(jnp.float32), bc.astype(jnp.float32))
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (B,L,L,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(mask[None, :, :, None], cb[..., None] * decay, 0.0)
        y_intra = jnp.einsum("bijh,bjh,bjhp->bihp", w, dtc, xc.astype(jnp.float32))
        # state update for the next chunk.
        wj = jnp.exp(cum[:, -1:, :] - cum) * dtc             # (B, L, H)
        s_add = jnp.einsum("bjh,bjn,bjhp->bhpn", wj, bc.astype(jnp.float32), xc.astype(jnp.float32))
        h_new = jnp.exp(cum[:, -1, :])[..., None, None] * h + s_add
        return h_new, (y_inter + y_intra)

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_final, y_c = jax.lax.scan(body, h0, (xs_c, Bm_c, Cm_c, dt_c, la_c))
    y = y_c.swapaxes(0, 1).reshape(B, S + pad, H, P)[:, :S]
    y = y + p["D"][None, None, :, None] * xs[:, :S].astype(jnp.float32)

    y = y.reshape(B, S, d_in).astype(dtype)
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm", cfg.norm_eps)
    out = y @ p["out_proj"].astype(dtype)

    # conv cache holds the last (K-1) *pre-activation* channel rows.
    km1 = cfg.ssm_conv - 1
    conv_cache = jnp.zeros((B, km1, conv_ch), dtype)
    take = min(S, km1)
    conv_cache = jax.lax.dynamic_update_slice_in_dim(
        conv_cache, conv_tail[:, -take:, :], km1 - take, 1
    )
    return out, {"h": h_final, "conv": conv_cache}


def ssm_decode(
    p: dict, x: jax.Array, state: dict, cfg: ModelConfig
) -> tuple[jax.Array, dict]:
    """One-token decode; x: (B, 1, d)."""
    B, _, d = x.shape
    d_in, H, P, N, conv_ch = _dims(cfg)
    dtype = x.dtype

    proj = x @ p["in_proj"].astype(dtype)
    z, xbc_raw, dt_raw = _split_proj(cfg, proj)

    hist = jnp.concatenate([state["conv"], xbc_raw], axis=1)  # (B, K, C)
    w = p["conv_w"].astype(dtype)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"].astype(dtype)
    )[:, None, :]
    new_conv = hist[:, 1:, :]

    xs = conv_out[..., :d_in].reshape(B, H, P)
    Bm = conv_out[:, 0, d_in : d_in + N]
    Cm = conv_out[:, 0, d_in + N :]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                                  # (B,H)

    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32), xs.astype(jnp.float32))
    h = a[..., None, None] * state["h"] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)

    y = y.reshape(B, 1, d_in).astype(dtype)
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm", cfg.norm_eps)
    out = y @ p["out_proj"].astype(dtype)
    return out, {"h": h, "conv": new_conv}
