"""Encoder-decoder LM (seamless-m4t backbone).

Encoder: bidirectional attention + MLP, scanned over ``cfg.n_enc_layers``.
Decoder: causal self-attention + cross-attention + MLP, scanned over
``cfg.n_layers``.  The audio frontend is a stub per the task spec:
``input_specs()`` supplies precomputed frame embeddings at ``d_model``.

Serving: cross-attention K/V are computed once from the encoder output at
prefill time and carried as a static cache; self-attention uses the same
full-cache machinery as the decoder-only models.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .act_sharding import constrain
from .attention import (
    attn_block_decode,
    attn_block_prefill,
    attention_projections,
    init_attention,
    init_kv_cache,
)
from .config import ModelConfig
from .layers import apply_mlp, apply_norm, init_embedding, init_mlp, init_norm, rope_frequencies, softcap

__all__ = ["init_encdec", "encode", "forward_encdec", "prefill_encdec",
           "decode_step_encdec", "loss_fn_encdec", "cache_spec_encdec"]


def _init_enc_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_norm(cfg.d_model, cfg.norm_type),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln2": init_norm(cfg.d_model, cfg.norm_type),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg.d_model, cfg.norm_type),
        "self": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln_x": init_norm(cfg.d_model, cfg.norm_type),
        "cross": init_attention(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln2": init_norm(cfg.d_model, cfg.norm_type),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_type),
    }


def init_encdec(key: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model),
        "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg))(
            jax.random.split(ks[1], cfg.n_enc_layers)
        ),
        "enc_norm": init_norm(cfg.d_model, cfg.norm_type),
        "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg))(
            jax.random.split(ks[2], cfg.n_layers)
        ),
        "final_norm": init_norm(cfg.d_model, cfg.norm_type),
    }


def encode(params: dict, cfg: ModelConfig, src_embeds: jax.Array, *, remat: bool = False):
    """src_embeds: (B, S_src, d) from the (stubbed) modality frontend."""
    inv_freq = rope_frequencies(cfg.head_dim, cfg.rope_fraction, cfg.rope_theta)
    x = src_embeds.astype(jnp.dtype(cfg.dtype))

    def body(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm_type, cfg.norm_eps)
        h, _ = attn_block_prefill(
            lp["attn"], h, inv_freq, kind="encoder",
            window=cfg.window_size, logit_cap=None,
        )
        x = constrain(x + h, "residual")
        h = apply_norm(lp["ln2"], x, cfg.norm_type, cfg.norm_eps)
        x = constrain(x + apply_mlp(lp["mlp"], h, cfg.mlp_type), "residual")
        return x, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, _ = jax.lax.scan(body, x, params["enc"])
    return apply_norm(params["enc_norm"], x, cfg.norm_type, cfg.norm_eps)


def _dec_layer_prefill(lp, x, enc_out, inv_freq, cfg, cache_len):
    h = apply_norm(lp["ln1"], x, cfg.norm_type, cfg.norm_eps)
    h, self_cache = attn_block_prefill(
        lp["self"], h, inv_freq, kind="attn", window=cfg.window_size,
        logit_cap=None, cache_size=cache_len,
    )
    x = constrain(x + h, "residual")
    # cross attention over encoder output
    dtype = x.dtype
    k = jnp.einsum("bsd,dhk->bhsk", enc_out, lp["cross"]["k"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bhsk", enc_out, lp["cross"]["v"].astype(dtype))
    h = apply_norm(lp["ln_x"], x, cfg.norm_type, cfg.norm_eps)
    h, _ = attn_block_prefill(
        lp["cross"], h, inv_freq, kind="cross", window=cfg.window_size,
        logit_cap=None, kv_override=(k, v),
    )
    x = constrain(x + h, "residual")
    h = apply_norm(lp["ln2"], x, cfg.norm_type, cfg.norm_eps)
    x = constrain(x + apply_mlp(lp["mlp"], h, cfg.mlp_type), "residual")
    cross_cache = {"k": k, "v": v} if cache_len is not None else None
    return x, self_cache, cross_cache


def forward_encdec(
    params: dict,
    cfg: ModelConfig,
    src_embeds: jax.Array,
    tgt_tokens: jax.Array,
    *,
    cache_len: int | None = None,
    remat: bool = False,
    logits_slice: int | None = None,
):
    """Teacher-forced encoder-decoder forward; returns (logits, caches)."""
    inv_freq = rope_frequencies(cfg.head_dim, cfg.rope_fraction, cfg.rope_theta)
    enc_out = encode(params, cfg, src_embeds, remat=remat)

    x = params["embed"][tgt_tokens].astype(jnp.dtype(cfg.dtype))

    def body(x, lp):
        x, self_c, cross_c = _dec_layer_prefill(lp, x, enc_out, inv_freq, cfg, cache_len)
        return x, (self_c, cross_c)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, (self_caches, cross_caches) = jax.lax.scan(body, x, params["dec"])

    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    if logits_slice is not None:
        x = x[:, -logits_slice:]
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    logits = softcap(logits, cfg.final_logit_softcap)

    caches = None
    if cache_len is not None:
        caches = {"self": self_caches, "cross": cross_caches}
    return logits, caches


def prefill_encdec(params, cfg, src_embeds, tgt_tokens, max_len: int):
    logits, caches = forward_encdec(
        params, cfg, src_embeds, tgt_tokens, cache_len=max_len, logits_slice=1
    )
    return logits, caches, jnp.asarray(tgt_tokens.shape[1], jnp.int32)


def decode_step_encdec(params, cfg, token, caches, pos):
    """One decode step; caches = {"self": stacked, "cross": stacked}."""
    inv_freq = rope_frequencies(cfg.head_dim, cfg.rope_fraction, cfg.rope_theta)
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))

    def body(x, inp):
        lp, self_c, cross_c = inp
        h = apply_norm(lp["ln1"], x, cfg.norm_type, cfg.norm_eps)
        h, self_c = attn_block_decode(
            lp["self"], h, self_c, pos, inv_freq, kind="attn",
            window=cfg.window_size, logit_cap=None,
        )
        x = x + h
        h = apply_norm(lp["ln_x"], x, cfg.norm_type, cfg.norm_eps)
        h, _ = attn_block_decode(
            lp["cross"], h, cross_c, pos, inv_freq, kind="cross",
            window=cfg.window_size, logit_cap=None,
        )
        x = x + h
        h = apply_norm(lp["ln2"], x, cfg.norm_type, cfg.norm_eps)
        x = x + apply_mlp(lp["mlp"], h, cfg.mlp_type)
        return x, self_c

    x, new_self = jax.lax.scan(body, x, (params["dec"], caches["self"], caches["cross"]))
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    logits = softcap(logits, cfg.final_logit_softcap)
    return logits, {"self": new_self, "cross": caches["cross"]}


def cache_spec_encdec(cfg: ModelConfig, batch: int, max_len: int, src_len: int, dtype):
    L = cfg.n_layers

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), tree)

    self_c = stack(init_kv_cache(batch, cfg.n_kv_heads, max_len, cfg.head_dim, dtype))
    cross_c = stack(init_kv_cache(batch, cfg.n_kv_heads, src_len, cfg.head_dim, dtype))
    return {"self": self_c, "cross": cross_c}


def loss_fn_encdec(params: dict, cfg: ModelConfig, batch: dict, *, remat: bool = True):
    logits, _ = forward_encdec(
        params, cfg, batch["src_embeds"], batch["inputs"], remat=remat
    )
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["targets"][..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("mask", jnp.ones_like(nll))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "tokens": jnp.sum(mask)}
