"""Fine-grained Mixture-of-Experts layer (DeepSeekMoE / Moonlight style).

Top-k routing over many small experts (+ optional always-on shared experts),
implemented with the capacity-based einsum dispatch that shards cleanly
under GSPMD: the expert dimension of ``experts/*`` tensors is laid out on
the ``model`` mesh axis (expert parallelism), so the two big einsums
(dispatch and combine) lower to all-to-all collectives on that axis — the
direct TPU analogue of the paper's shuffle phase, and modeled as such by
``repro.core.tpu_model``.

Returns the standard Switch-style load-balancing auxiliary loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.compat import shard_map

from .act_sharding import constrain
from .config import ModelConfig
from .layers import apply_mlp, init_mlp
from .opt_flags import get_flags

__all__ = ["init_moe", "apply_moe"]


def init_moe(key: jax.Array, cfg: ModelConfig) -> dict:
    d, E, de = cfg.d_model, cfg.n_experts, cfg.d_expert
    kr, ki, kg, ko, ks = jax.random.split(key, 5)
    s_in, s_out = d ** -0.5, de ** -0.5
    p = {
        "router": jax.random.normal(kr, (d, E), jnp.float32) * s_in,
        "experts": {
            "wi": jax.random.normal(ki, (E, d, de), jnp.float32) * s_in,
            "wg": jax.random.normal(kg, (E, d, de), jnp.float32) * s_in,
            "wo": jax.random.normal(ko, (E, de, d), jnp.float32) * s_out,
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks, d, cfg.n_shared_experts * de, "swiglu")
    return p


def apply_moe(
    p: dict, x: jax.Array, cfg: ModelConfig, *, capacity: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    ``capacity`` overrides the per-expert buffer depth; decode passes
    ``capacity=T`` (dropless — an expert can never receive more than every
    token), training uses the factor-based value.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    dtype = x.dtype
    xt = x.reshape(T, d)

    logits = (xt @ p["router"].astype(dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                       # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # Capacity-based dispatch (Switch-style), k-major priority so first
    # choices win buffer slots over second choices, etc.
    C = capacity if capacity is not None else max(
        1, math.ceil(cfg.moe_capacity_factor * T * K / E)
    )
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)               # (T, K, E)
    flat = onehot.transpose(1, 0, 2).reshape(K * T, E)             # k-major
    position = jnp.cumsum(flat, axis=0) - 1                        # (K*T, E)
    keep = (position < C) & (flat > 0)

    impl = get_flags().moe_impl
    if impl == "shardmap":
        y = _expert_compute_shardmap(p, cfg, x, idx, gate_vals, capacity, dtype)
    elif impl == "gather":
        y = _expert_compute_gather(
            p, cfg, xt, idx, gate_vals, position, keep, C, dtype
        )
    else:
        y = _expert_compute_einsum(
            p, cfg, xt, gate_vals, position, keep, C, dtype
        )

    if "shared" in p:
        y = y + apply_mlp(p["shared"], xt, "swiglu")

    # Switch load-balancing loss: E * sum_e f_e * p_e.
    f = onehot.sum(axis=1).astype(jnp.float32).mean(axis=0)
    pbar = probs.mean(axis=0)                  # mean router prob of e
    aux = E * jnp.sum(f * pbar)

    return y.reshape(B, S, d), aux


def _expert_ffn(p: dict, xin: jax.Array, dtype) -> jax.Array:
    """(E, C, d) buffers -> (E, C, d); expert dim EP-sharded on 'model'."""
    h = jnp.einsum("ecd,edf->ecf", xin, p["experts"]["wi"].astype(dtype))
    g = jnp.einsum("ecd,edf->ecf", xin, p["experts"]["wg"].astype(dtype))
    h = jax.nn.silu(g) * h
    return constrain(
        jnp.einsum("ecf,efd->ecd", h, p["experts"]["wo"].astype(dtype)),
        "moe_ecd",
    )


def _expert_compute_einsum(p, cfg, xt, gate_vals, position, keep, C, dtype):
    """Baseline: capacity one-hot dispatch/combine einsums.

    Cost: the dispatch/combine matmuls are 2*T*E*C*d with E*C ~= cf*K*T —
    QUADRATIC in per-device tokens; measured in the dry-run as ~30x the
    expert flops for moonshot/train_4k (see EXPERIMENTS.md §Perf)."""
    T, d = xt.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    slot = jax.nn.one_hot(position, C, dtype=jnp.float32)
    disp_flat = slot * keep[..., None].astype(jnp.float32)         # (K*T, E, C)
    disp = disp_flat.reshape(K, T, E, C).transpose(1, 0, 2, 3)     # (T, K, E, C)

    dispatch = disp.sum(axis=1)                                    # (T, E, C)
    combine = (disp * gate_vals[..., None, None]).sum(axis=1)      # (T, E, C)

    xin = constrain(
        jnp.einsum("tec,td->ecd", dispatch.astype(dtype), xt), "moe_ecd"
    )
    out = _expert_ffn(p, xin, dtype)
    return jnp.einsum("tec,ecd->td", combine.astype(dtype), out)


def _expert_compute_gather(p, cfg, xt, idx, gate_vals, position, keep, C, dtype):
    """Optimized dispatch: scatter/gather token indices instead of one-hot
    matmuls — O(T*K*d) data movement, identical routing semantics (same
    k-major capacity rule, bit-equal expert inputs/outputs).

    slot_tok[e, c] = index of the token occupying slot c of expert e
    (T = sentinel -> zero row).  Expert buffers are built by one gather and
    results returned by one gather; under EP the buffers stay sharded on
    the expert axis and XLA moves only the routed activations."""
    T, d = xt.shape
    E, K = cfg.n_experts, cfg.moe_top_k

    pos_tk = position.reshape(K, T, E)                             # k-major
    keep_tk = keep.reshape(K, T, E)
    # for each (k, t): its expert slot (or C -> dropped)
    idx_km = idx.T                                                 # (K, T)
    pos_sel = jnp.take_along_axis(
        pos_tk, idx_km[..., None], axis=2
    )[..., 0].astype(jnp.int32)                                    # (K, T)
    keep_sel = jnp.take_along_axis(keep_tk, idx_km[..., None], axis=2)[..., 0]

    # scatter token ids + gate values into (E, C) slot tables.  Dropped
    # entries target column C (out of bounds -> mode="drop").
    tok_ids = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (K, T))
    e_flat = jnp.where(keep_sel, idx_km, E - 1).reshape(-1)
    c_flat = jnp.where(keep_sel, pos_sel, C).reshape(-1)
    slot_tok = jnp.full((E, C), T, jnp.int32).at[e_flat, c_flat].set(
        jnp.where(keep_sel, tok_ids, T).reshape(-1), mode="drop"
    )
    gate_slot = jnp.zeros((E, C), jnp.float32).at[e_flat, c_flat].set(
        jnp.where(keep_sel, gate_vals.T, 0.0).reshape(-1), mode="drop"
    )

    # dispatch: one gather (sentinel row T reads zeros).  slot_tok is
    # EP-sharded on E; the token table stays data-sharded (explicitly
    # replicating it was measured WORSE — the global microbatch is 537 MB;
    # see EXPERIMENTS.md §Perf iter 4).
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), dtype)], axis=0)
    xin = constrain(xt_pad[slot_tok], "moe_ecd")                   # (E, C, d)
    out = _expert_ffn(p, xin, dtype)

    # combine: gate-weight in place and scatter-ADD back to tokens.  Each
    # expert shard accumulates its local slots into a (T+1, d) partial sum;
    # the cross-shard combine is one activation-sized all-reduce — never
    # materializes or transfers the (E*C, d) buffers (the iteration-1
    # regression; see EXPERIMENTS.md §Perf).
    # NB: scatter with the 2-D (E, C) index table directly — flattening to
    # (E*C, d) first merges away the EP-sharded expert dim and the
    # backward (a gather back to E*C rows) materializes unsharded fp32
    # buffers (+22 s of all-reduce in the iter-3 measurement).
    weighted = out * gate_slot[..., None].astype(out.dtype)        # (E, C, d)
    y = jnp.zeros((T + 1, d), out.dtype).at[slot_tok].add(weighted)[:T]
    return y.astype(dtype)


def _local_dispatch_tables(idx, gate_vals, E, K, C, base, E_loc):
    """Per-shard routing tables for experts [base, base+E_loc).

    Same k-major capacity rule as the global paths, applied to the LOCAL
    token set (T_loc tokens): position-in-expert via a cumsum over the
    k-major flattened assignments.  Returns (slot_tok, gate_slot) of shape
    (E_loc, C): token id per slot (T_loc = sentinel) and its gate.
    """
    T = idx.shape[0]
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)               # (T, K, E)
    flat = onehot.transpose(1, 0, 2).reshape(K * T, E)
    position = jnp.cumsum(flat, axis=0) - 1                        # (K*T, E)
    keep = (position < C) & (flat > 0)

    pos_tk = position.reshape(K, T, E)
    keep_tk = keep.reshape(K, T, E)
    idx_km = idx.T                                                 # (K, T)
    pos_sel = jnp.take_along_axis(pos_tk, idx_km[..., None], axis=2)[..., 0]
    keep_sel = jnp.take_along_axis(keep_tk, idx_km[..., None], axis=2)[..., 0]
    mine = keep_sel & (idx_km >= base) & (idx_km < base + E_loc)

    tok_ids = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (K, T))
    e_flat = jnp.where(mine, idx_km - base, 0).reshape(-1)
    c_flat = jnp.where(mine, pos_sel, C).reshape(-1).astype(jnp.int32)
    slot_tok = jnp.full((E_loc, C), T, jnp.int32).at[e_flat, c_flat].set(
        jnp.where(mine, tok_ids, T).reshape(-1), mode="drop"
    )
    gate_slot = jnp.zeros((E_loc, C), jnp.float32).at[e_flat, c_flat].set(
        jnp.where(mine, gate_vals.T, 0.0).reshape(-1), mode="drop"
    )
    return slot_tok, gate_slot


def _expert_compute_shardmap(p, cfg, x, idx, gate_vals, capacity, dtype):
    """Production EP layout via shard_map: per-DATA-shard routing, fully
    local dispatch/expert/combine, ONE activation-sized psum over the
    model axis per layer (+ its backward twin).

    Layout facts that make everything local: activations are replicated
    over 'model' and sharded over 'data'; expert weights are sharded over
    'model' on the expert dim.  Every model rank therefore already holds
    the tokens it needs and owns E/tp experts; rank r builds buffers for
    its experts from its replicated token copy and contributes a partial
    (T_loc, d) combine, summed by psum — the Megatron-MLP communication
    pattern, with the paper's shuffle realized as partition-local
    combining (a combiner running *before* the wire, Eq. 17's whole
    point).

    SEMANTIC NOTE (documented in EXPERIMENTS.md §Perf): capacity applies
    per data shard (C_loc = ceil(cf·K·T_loc/E)) — the standard production
    rule (per-device capacity) — whereas the faithful baseline applies it
    to the global microbatch.  With balanced routing the drop sets differ
    only at the margin; tests pin exact equivalence on 1-device meshes
    where the two rules coincide.
    """
    from jax.sharding import PartitionSpec as P

    from .act_sharding import current_mesh

    mesh = current_mesh()
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k

    if mesh is None or "model" not in mesh.axis_names:
        # no mesh installed (unit tests): degenerate 1-shard semantics
        mesh = None

    def block(x_loc, idx_loc, gates_loc, wi, wg, wo):
        Bl, Sl, _ = x_loc.shape
        T_loc = Bl * Sl
        E_loc = wi.shape[0]
        C_loc = capacity if capacity is not None else max(
            1, math.ceil(cfg.moe_capacity_factor * T_loc * K / E)
        )
        if mesh is not None:
            rank = jax.lax.axis_index("model")
        else:
            rank = jnp.int32(0)
        base = rank * E_loc

        xt = x_loc.reshape(T_loc, d)
        it = idx_loc.reshape(T_loc, K)
        gt = gates_loc.reshape(T_loc, K)
        slot_tok, gate_slot = _local_dispatch_tables(
            it, gt, E, K, C_loc, base, E_loc
        )
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), dtype)], axis=0)
        xin = xt_pad[slot_tok]                                     # (E_loc,C,d)
        h = jnp.einsum("ecd,edf->ecf", xin, wi.astype(dtype))
        g = jnp.einsum("ecd,edf->ecf", xin, wg.astype(dtype))
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo.astype(dtype))
        weighted = out * gate_slot[..., None].astype(out.dtype)
        y_part = jnp.zeros((T_loc + 1, d), out.dtype).at[slot_tok].add(
            weighted
        )[:T_loc]
        if mesh is not None:
            y_part = jax.lax.psum(y_part, "model")
        return y_part.reshape(Bl, Sl, d)

    if mesh is None:
        return block(
            x, idx.reshape(B, S, K), gate_vals.reshape(B, S, K),
            p["experts"]["wi"], p["experts"]["wg"], p["experts"]["wo"],
        ).reshape(B * S, d)

    batch_axes = ("pod", "data") if "pod" in mesh.axis_names else "data"
    bspec = P(batch_axes, None, None)
    espec = P("model", None, None)
    fn = shard_map(
        block,
        mesh=mesh,
        in_specs=(bspec, bspec, bspec, espec, espec, espec),
        out_specs=bspec,
        check_vma=False,
    )
    y = fn(
        x, idx.reshape(B, S, K), gate_vals.reshape(B, S, K),
        p["experts"]["wi"], p["experts"]["wg"], p["experts"]["wo"],
    )
    return y.reshape(B * S, d)
