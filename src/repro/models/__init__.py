"""Architecture substrate: configs, layers, and the model assemblies."""

from . import attention, encdec, layers, lm, moe, rglru, ssm  # noqa: F401
from .config import ModelConfig

__all__ = ["ModelConfig", "lm", "encdec", "attention", "layers", "moe", "rglru", "ssm"]
