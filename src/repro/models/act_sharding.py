"""Activation-sharding policy hooks.

Model code is mesh-agnostic; launchers install a policy mapping activation
*kinds* to shardings, and the model calls :func:`constrain` at layout-
critical points (residual stream, logits, MoE dispatch).  With no policy
installed (unit tests, single-device runs) every hook is a no-op.

Kinds:
* ``residual``  — (B, S, d) stream between blocks
* ``logits``    — (B, S, V)
* ``moe_ecd``   — (E, C, d) expert buffers
"""

from __future__ import annotations

from typing import Any

import jax

__all__ = ["set_policy", "clear_policy", "constrain", "policy_active"]

_POLICY: dict[str, Any] = {}


def set_policy(policy: dict[str, Any]) -> None:
    """policy: kind -> jax.sharding.NamedSharding (or None to skip kind)."""
    global _POLICY
    _POLICY = dict(policy)


def clear_policy() -> None:
    global _POLICY
    _POLICY = {}


def policy_active() -> bool:
    return bool(_POLICY)


def constrain(x: jax.Array, kind: str) -> jax.Array:
    sh = _POLICY.get(kind)
    if sh is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, sh)
    except (ValueError, TypeError):
        # shape/rank mismatch (e.g. decode S=1 vs padded spec): skip silently
        return x


def current_mesh():
    """Mesh of the installed policy (None when no policy / no mesh)."""
    for sh in _POLICY.values():
        mesh = getattr(sh, "mesh", None)
        if mesh is not None:
            return mesh
    return None
