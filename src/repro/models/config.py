"""Architecture configuration for all assigned model families.

One :class:`ModelConfig` covers dense / hybrid / MoE / SSM / VLM / enc-dec
LMs.  Per-architecture instances (exact public configs) live in
``repro/configs/<arch>.py``; reduced smoke variants are derived with
:meth:`ModelConfig.smoke`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

__all__ = ["ModelConfig"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|hybrid|moe|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer pattern, repeated to fill n_layers: members in
    # {"attn","local","rglru","ssm"}
    layer_pattern: tuple[str, ...] = ("attn",)
    # layers preceding the scanned pattern (e.g. recurrentgemma's 38 = 2 + 12*3)
    prefix_pattern: tuple[str, ...] = ()
    window_size: int = 4096          # sliding window of "local" layers
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0       # stablelm-style partial rotary
    norm_type: str = "rmsnorm"       # rmsnorm|layernorm
    mlp_type: str = "swiglu"         # swiglu|gelu
    use_post_norm: bool = False      # gemma2 sandwich norms
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # MoE (fine-grained, DeepSeekMoE-style)
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0
    moe_layer_start: int = 0         # layers < this use a dense FFN
    d_ff_dense: int = 0              # width of those dense FFN layers
    moe_capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2

    # RG-LRU (RecurrentGemma / Griffin)
    rglru_width: int = 0             # 0 -> d_model
    rglru_conv: int = 4

    # encoder-decoder
    n_enc_layers: int = 0            # >0 -> enc-dec model; n_layers = decoder

    # modality frontend stub: None | "vision" | "audio"
    frontend: str | None = None

    # compute dtype for activations (params are fp32)
    dtype: str = "bfloat16"

    # ----------------------------------------------------------------- utils
    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_prefix(self) -> int:
        return len(self.prefix_pattern) + (
            self.moe_layer_start if self.n_experts else 0
        )

    @property
    def n_groups(self) -> int:
        """Number of scan steps = layer-pattern repetitions."""
        n = self.n_layers - self.n_prefix
        assert n % self.pattern_len == 0, (
            f"{self.name}: {n} scanned layers not divisible by "
            f"pattern length {self.pattern_len}"
        )
        return n // self.pattern_len

    @property
    def n_enc_groups(self) -> int:
        return self.n_enc_layers  # encoder layers are homogeneous

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    @property
    def d_rnn(self) -> int:
        return self.rglru_width or self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_subquadratic(self) -> bool:
        """Does every layer avoid unbounded-context full attention?"""
        return all(m != "attn" for m in self.layer_pattern)

    @property
    def supports_long_context(self) -> bool:
        """Eligible for the long_500k shape (see DESIGN.md §Shape-skips).

        True when the architecture bounds per-token decode state growth:
        pure SSM / hybrid recurrent models, and gemma2's alternating
        local/global design (local layers use O(window) ring caches).
        """
        return self.is_subquadratic or "local" in self.layer_pattern

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=2 * self.pattern_len + self.n_prefix,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            window_size=min(self.window_size, 16),
            dtype="float32",
        )
        if self.n_experts:
            # capacity factor 8 = dropless at smoke scale, so the
            # prefill/decode consistency check is exact (capacity-based
            # dropping is length-dependent by construction and is covered
            # separately in tests/test_moe.py).
            kw.update(n_experts=8, moe_top_k=2, d_expert=32,
                      n_shared_experts=min(self.n_shared_experts, 1),
                      d_ff_dense=128, moe_capacity_factor=8.0)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=8)
        if self.rglru_width:
            kw.update(rglru_width=64)
        if self.n_enc_layers:
            kw.update(n_enc_layers=2)
        return self.replace(name=self.name + "-smoke", **kw)
