"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The temporal-mixing block of RecurrentGemma: a gated linear branch and a
recurrent branch (causal conv -> Real-Gated LRU), multiplied and projected
back to the residual stream.

RG-LRU recurrence (per channel)::

    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    a_t = exp(c * r_t * (-softplus(L)))     # decay in (0, 1), c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Prefill evaluates the linear recurrence with ``jax.lax.associative_scan``
(log-depth, fully parallel — the TPU-native formulation; no sequential
S-step loop); decode is the exact O(1) update.  The carried state plus a
(conv_width-1) conv tail is all the context the block keeps, which is why
recurrentgemma handles ``long_500k`` with O(1) per-layer state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig

__all__ = ["init_rglru", "rglru_prefill", "rglru_decode", "init_rglru_state"]

_C = 8.0  # Griffin's fixed gate sharpness


def init_rglru(key: jax.Array, cfg: ModelConfig) -> dict:
    d, r = cfg.d_model, cfg.d_rnn
    ks = jax.random.split(key, 6)
    s_d, s_r = d ** -0.5, r ** -0.5
    # Lambda init so a^c spans ~(0.9, 0.999), as in the Griffin paper.
    lam = jnp.log(jnp.expm1(jnp.linspace(2.0, 6.0, r)))
    return {
        "in_x": jax.random.normal(ks[0], (d, r), jnp.float32) * s_d,
        "in_g": jax.random.normal(ks[1], (d, r), jnp.float32) * s_d,
        "conv_w": jax.random.normal(ks[2], (cfg.rglru_conv, r), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((r,), jnp.float32),
        "wa": jax.random.normal(ks[3], (r, r), jnp.float32) * s_r,
        "ba": jnp.zeros((r,), jnp.float32),
        "wx": jax.random.normal(ks[4], (r, r), jnp.float32) * s_r,
        "bx": jnp.zeros((r,), jnp.float32),
        "lam": lam,
        "out": jax.random.normal(ks[5], (r, d), jnp.float32) * s_r,
    }


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    r = cfg.d_rnn
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru_conv - 1, r), dtype),
    }


def _gates(p: dict, x: jax.Array):
    """x: (..., r) conv output -> (log_a, gated_input) in fp32."""
    xf = x.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(xf @ p["wa"] + p["ba"])
    i_gate = jax.nn.sigmoid(xf @ p["wx"] + p["bx"])
    log_a = -_C * r_gate * jax.nn.softplus(p["lam"])      # <= 0
    a = jnp.exp(log_a)
    u = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_gate * xf)
    return a, u


def rglru_prefill(
    p: dict, x: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, dict]:
    """x: (B, S, d) -> (y (B, S, d), final state)."""
    B, S, d = x.shape
    dtype = x.dtype
    gate = jax.nn.gelu(x @ p["in_g"].astype(dtype))
    xr_raw = x @ p["in_x"].astype(dtype)                   # (B, S, r)

    km1 = cfg.rglru_conv - 1
    pad = jnp.pad(xr_raw, ((0, 0), (km1, 0), (0, 0)))
    conv = jnp.zeros_like(xr_raw)
    for i in range(cfg.rglru_conv):
        conv = conv + pad[:, i : i + S, :] * p["conv_w"].astype(dtype)[i]
    conv = conv + p["conv_b"].astype(dtype)

    a, u = _gates(p, conv)
    # h_t = a_t h_{t-1} + u_t  via associative scan: (a, u) o (a', u') =
    # (a a', a' u + u').
    def combine(lhs, rhs):
        a1, u1 = lhs
        a2, u2 = rhs
        return a1 * a2, a2 * u1 + u2

    h_all = jax.lax.associative_scan(combine, (a, u), axis=1)[1]  # (B, S, r)
    y = (h_all.astype(dtype) * gate) @ p["out"].astype(dtype)

    state = {
        "h": h_all[:, -1, :],
        "conv": jnp.zeros((B, km1, xr_raw.shape[-1]), dtype).at[:, -min(S, km1):, :].set(
            xr_raw[:, -min(S, km1):, :]
        ),
    }
    return y, state


def rglru_decode(
    p: dict, x: jax.Array, state: dict, cfg: ModelConfig
) -> tuple[jax.Array, dict]:
    """One-token decode; x: (B, 1, d)."""
    B, _, d = x.shape
    dtype = x.dtype
    gate = jax.nn.gelu(x @ p["in_g"].astype(dtype))        # (B, 1, r)
    xr = x @ p["in_x"].astype(dtype)

    hist = jnp.concatenate([state["conv"], xr], axis=1)    # (B, K, r)
    conv = jnp.einsum("bkr,kr->br", hist, p["conv_w"].astype(dtype))
    conv = conv + p["conv_b"].astype(dtype)

    a, u = _gates(p, conv)                                 # (B, r)
    h = a * state["h"] + u
    y = (h[:, None, :].astype(dtype) * gate) @ p["out"].astype(dtype)
    return y, {"h": h, "conv": hist[:, 1:, :]}
