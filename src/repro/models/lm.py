"""Decoder-only LM assembly: dense / hybrid / MoE / SSM / VLM families.

Key structural choices (all load-bearing for the multi-pod dry-run):

* **scan-over-layers** — layers are grouped by the repeating
  ``cfg.layer_pattern`` (e.g. gemma2's ``("local","attn")``); parameters of
  each pattern member are *stacked* over the group axis and the stack is
  consumed by one ``lax.scan``.  HLO size is O(pattern) instead of
  O(n_layers), which is what makes 42-48-layer models lower+compile quickly
  with 512 host devices.
* **heterogeneous prefix** — layers that break the pattern (e.g. the dense
  first FFN layer of DeepSeekMoE-style models, ``cfg.moe_layer_start``) are
  kept un-stacked in front of the scan.
* **caches as scanned pytrees** — each pattern member owns a cache pytree
  stacked over groups; decode scans over (params, cache) jointly.
* **functional API** — ``init(key, cfg)``, ``forward(...)``,
  ``prefill(...)``, ``decode_step(...)``, ``loss_fn(...)`` are pure; the
  runtime (pjit, remat, grad-accum) composes them.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .act_sharding import constrain
from .attention import attn_block_decode, attn_block_prefill, init_attention, init_kv_cache
from .config import ModelConfig
from .layers import apply_mlp, apply_norm, init_embedding, init_mlp, init_norm, rope_frequencies, softcap
from .moe import apply_moe, init_moe
from .rglru import init_rglru, init_rglru_state, rglru_decode, rglru_prefill
from .ssm import init_ssm, init_ssm_state, ssm_decode, ssm_prefill

__all__ = [
    "init",
    "forward",
    "prefill",
    "decode_step",
    "loss_fn",
    "prefix_kinds",
    "cache_spec",
]


# ------------------------------------------------------------------ structure

def prefix_kinds(cfg: ModelConfig) -> list[str]:
    """Unstacked layers preceding the scanned pattern groups."""
    kinds = list(cfg.prefix_pattern)
    if cfg.n_experts and cfg.moe_layer_start > 0:
        kinds += ["attn_dense"] * cfg.moe_layer_start
    return kinds


def _scan_layers(cfg: ModelConfig) -> int:
    return cfg.n_layers - len(prefix_kinds(cfg))


def _n_groups(cfg: ModelConfig) -> int:
    n = _scan_layers(cfg)
    assert n % cfg.pattern_len == 0, (cfg.name, n, cfg.layer_pattern)
    return n // cfg.pattern_len


def _ffn_kind(cfg: ModelConfig, kind: str) -> str:
    """Which FFN a member uses: moe | dense | none (ssm has none)."""
    if kind == "ssm":
        return "none"
    if kind == "attn_dense":
        return "dense"
    return "moe" if cfg.n_experts else "dense"


# ------------------------------------------------------------------ members

def _init_member(key: jax.Array, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": init_norm(d, cfg.norm_type)}
    mixer = kind if kind != "attn_dense" else "attn"
    if mixer in ("attn", "local"):
        p["attn"] = init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    elif mixer == "rglru":
        p["rglru"] = init_rglru(ks[0], cfg)
    elif mixer == "ssm":
        p["ssm"] = init_ssm(ks[0], cfg)
    else:
        raise ValueError(f"unknown member kind {kind!r}")
    if cfg.use_post_norm:
        p["post1"] = init_norm(d, cfg.norm_type)

    ffn = _ffn_kind(cfg, kind)
    if ffn != "none":
        p["ln2"] = init_norm(d, cfg.norm_type)
        if ffn == "moe":
            p["moe"] = init_moe(ks[1], cfg)
        else:
            width = cfg.d_ff_dense if (kind == "attn_dense" and cfg.d_ff_dense) else cfg.d_ff
            p["mlp"] = init_mlp(ks[1], d, width, cfg.mlp_type)
        if cfg.use_post_norm:
            p["post2"] = init_norm(d, cfg.norm_type)
    return p


def _apply_member_prefill(
    kind: str,
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    inv_freq: jax.Array,
    cache_size: int | None,
    q_offset: int,
):
    """Residual block for one member; returns (x, cache, aux)."""
    mixer = "attn" if kind == "attn_dense" else kind
    h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
    cache = None
    if mixer in ("attn", "local"):
        size = None
        if cache_size is not None:
            size = min(cache_size, cfg.window_size) if mixer == "local" else cache_size
        h, cache = attn_block_prefill(
            p["attn"], h, inv_freq,
            kind=mixer, window=cfg.window_size,
            logit_cap=cfg.attn_logit_softcap, cache_size=size,
            q_offset=q_offset,
        )
    elif mixer == "rglru":
        h, st = rglru_prefill(p["rglru"], h, cfg)
        cache = st if cache_size is not None else None
    elif mixer == "ssm":
        h, st = ssm_prefill(p["ssm"], h, cfg)
        cache = st if cache_size is not None else None
    if cfg.use_post_norm:
        h = apply_norm(p["post1"], h, cfg.norm_type, cfg.norm_eps)
    x = constrain(x + h, "residual")

    aux = jnp.zeros((), jnp.float32)
    ffn = _ffn_kind(cfg, kind)
    if ffn != "none":
        h = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
        if ffn == "moe":
            h, aux = apply_moe(p["moe"], h, cfg)
        else:
            h = apply_mlp(p["mlp"], h, cfg.mlp_type)
        if cfg.use_post_norm:
            h = apply_norm(p["post2"], h, cfg.norm_type, cfg.norm_eps)
        x = constrain(x + h, "residual")
    return x, cache, aux


def _apply_member_decode(
    kind: str,
    p: dict,
    x: jax.Array,
    cache,
    pos: jax.Array,
    cfg: ModelConfig,
    inv_freq: jax.Array,
):
    mixer = "attn" if kind == "attn_dense" else kind
    h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
    if mixer in ("attn", "local"):
        h, cache = attn_block_decode(
            p["attn"], h, cache, pos, inv_freq,
            kind=mixer, window=cfg.window_size,
            logit_cap=cfg.attn_logit_softcap,
        )
    elif mixer == "rglru":
        h, cache = rglru_decode(p["rglru"], h, cache, cfg)
    elif mixer == "ssm":
        h, cache = ssm_decode(p["ssm"], h, cache, cfg)
    if cfg.use_post_norm:
        h = apply_norm(p["post1"], h, cfg.norm_type, cfg.norm_eps)
    x = x + h

    ffn = _ffn_kind(cfg, kind)
    if ffn != "none":
        h = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
        if ffn == "moe":
            # Dropless at decode: capacity = T tokens can never overflow.
            h, _ = apply_moe(p["moe"], h, cfg, capacity=h.shape[0] * h.shape[1])
        else:
            h = apply_mlp(p["mlp"], h, cfg.mlp_type)
        if cfg.use_post_norm:
            h = apply_norm(p["post2"], h, cfg.norm_type, cfg.norm_eps)
        x = x + h
    return x, cache


# ------------------------------------------------------------------ caches

def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Any:
    """Zero-initialized cache pytree (prefix list + per-member stacks)."""
    G = _n_groups(cfg)

    def one(kind: str):
        mixer = "attn" if kind == "attn_dense" else kind
        if mixer == "attn":
            return init_kv_cache(batch, cfg.n_kv_heads, max_len, cfg.head_dim, dtype)
        if mixer == "local":
            return init_kv_cache(
                batch, cfg.n_kv_heads, min(max_len, cfg.window_size), cfg.head_dim, dtype
            )
        if mixer == "rglru":
            return init_rglru_state(cfg, batch, dtype)
        if mixer == "ssm":
            return init_ssm_state(cfg, batch, dtype)
        raise ValueError(kind)

    prefix = [one(k) for k in prefix_kinds(cfg)]
    groups = tuple(
        jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), one(k))
        for k in cfg.layer_pattern
    )
    return {"prefix": prefix, "groups": groups}


# ------------------------------------------------------------------ init

def init(key: jax.Array, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 8)
    G = _n_groups(cfg)
    params: dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": init_norm(cfg.d_model, cfg.norm_type),
    }
    pk = prefix_kinds(cfg)
    if pk:
        params["prefix"] = [
            _init_member(k, cfg, kind)
            for k, kind in zip(jax.random.split(keys[1], len(pk)), pk)
        ]
    params["groups"] = tuple(
        jax.vmap(lambda k, kind=kind: _init_member(k, cfg, kind))(
            jax.random.split(jax.random.fold_in(keys[2], mi), G)
        )
        for mi, kind in enumerate(cfg.layer_pattern)
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[3], (cfg.d_model, cfg.vocab_size), jnp.float32)
            * cfg.d_model ** -0.5
        )
    return params


# ------------------------------------------------------------------ forward

def _embed_tokens(params, cfg, tokens, extra_embeds):
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(dtype), x], axis=1)
    return constrain(x, "residual")


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    return constrain(softcap(logits, cfg.final_logit_softcap), "logits")


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    extra_embeds: jax.Array | None = None,
    cache_len: int | None = None,
    remat: bool = False,
    logits_slice: int | None = None,
):
    """Full-sequence forward.  Returns (logits, caches, aux_loss).

    ``cache_len``: build serve caches of this size (prefill mode); None for
    training.  ``logits_slice``: only produce logits for the last N
    positions (serving computes just the final-token logits).
    """
    inv_freq = rope_frequencies(cfg.head_dim, cfg.rope_fraction, cfg.rope_theta)
    x = _embed_tokens(params, cfg, tokens, extra_embeds)
    aux_total = jnp.zeros((), jnp.float32)

    prefix_caches = []
    for kind, p in zip(prefix_kinds(cfg), params.get("prefix", [])):
        x, cache, aux = _apply_member_prefill(kind, p, x, cfg, inv_freq, cache_len, 0)
        prefix_caches.append(cache)
        aux_total = aux_total + aux

    def body(carry, gp):
        x, aux = carry
        caches = []
        for mi, kind in enumerate(cfg.layer_pattern):
            x, cache, a = _apply_member_prefill(
                kind, gp[mi], x, cfg, inv_freq, cache_len, 0
            )
            caches.append(cache)
            aux = aux + a
        return (x, aux), tuple(caches)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    (x, aux_total), group_caches = jax.lax.scan(
        body, (x, aux_total), params["groups"]
    )

    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    if logits_slice is not None:
        x = x[:, -logits_slice:]
    logits = _logits(params, cfg, x)

    caches = None
    if cache_len is not None:
        caches = {"prefix": prefix_caches, "groups": group_caches}
    return logits, caches, aux_total


def prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    max_len: int,
    *,
    extra_embeds: jax.Array | None = None,
):
    """Serve-path prompt processing: last-token logits + primed caches."""
    logits, caches, _ = forward(
        params, cfg, tokens,
        extra_embeds=extra_embeds, cache_len=max_len, logits_slice=1,
    )
    seq = tokens.shape[1] + (extra_embeds.shape[1] if extra_embeds is not None else 0)
    return logits, caches, jnp.asarray(seq, jnp.int32)


def decode_step(
    params: dict,
    cfg: ModelConfig,
    token: jax.Array,        # (B, 1) int32
    caches: dict,
    pos: jax.Array,          # scalar int32: position of this token
):
    """One serving decode step.  Returns (logits (B,1,V), new caches)."""
    inv_freq = rope_frequencies(cfg.head_dim, cfg.rope_fraction, cfg.rope_theta)
    x = _embed_tokens(params, cfg, token, None)

    new_prefix = []
    for kind, p, cache in zip(
        prefix_kinds(cfg), params.get("prefix", []), caches["prefix"]
    ):
        x, cache = _apply_member_decode(kind, p, x, cache, pos, cfg, inv_freq)
        new_prefix.append(cache)

    from .opt_flags import get_flags

    if get_flags().cache_update == "inplace":
        # caches ride in the scan CARRY: one dynamic slice + in-place
        # update per group instead of streaming (copying) the full stacked
        # cache through xs->ys each token (§Perf decode optimization).
        G = _n_groups(cfg)

        def body_inplace(carry, inp):
            x, gcaches = carry
            gp, g = inp
            cache_g = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, g, 0, keepdims=False),
                gcaches,
            )
            new_caches = []
            for mi, kind in enumerate(cfg.layer_pattern):
                x, c = _apply_member_decode(
                    kind, gp[mi], x, cache_g[mi], pos, cfg, inv_freq
                )
                new_caches.append(c)
            gcaches = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new, g, 0
                ),
                gcaches, tuple(new_caches),
            )
            return (x, gcaches), None

        (x, new_groups), _ = jax.lax.scan(
            body_inplace, (x, caches["groups"]),
            (params["groups"], jnp.arange(G)),
        )
    else:
        def body(x, inp):
            gp, gcache = inp
            new_caches = []
            for mi, kind in enumerate(cfg.layer_pattern):
                x, c = _apply_member_decode(kind, gp[mi], x, gcache[mi], pos, cfg, inv_freq)
                new_caches.append(c)
            return x, tuple(new_caches)

        x, new_groups = jax.lax.scan(body, x, (params["groups"], caches["groups"]))

    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    logits = _logits(params, cfg, x)
    return logits, {"prefix": new_prefix, "groups": new_groups}


# ------------------------------------------------------------------ loss

def loss_fn(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    *,
    remat: bool = True,
    aux_weight: float = 0.01,
):
    """Next-token cross-entropy (+ MoE aux).  batch: inputs, targets[, mask,
    extra_embeds].  Targets aligned with the *token* part of the sequence."""
    extra = batch.get("extra_embeds")
    logits, _, aux = forward(
        params, cfg, batch["inputs"], extra_embeds=extra, remat=remat
    )
    if extra is not None:
        logits = logits[:, extra.shape[1]:]

    logits = logits.astype(jnp.float32)
    targets = batch["targets"]
    from .opt_flags import get_flags

    if get_flags().sharded_loss:
        # Vocab-shard-friendly cross-entropy: every (B,S,V) op is
        # elementwise (stays sharded on V); only (B,S)-sized reductions
        # cross the model axis.  Avoids the logits all-gather that
        # take_along_axis can trigger under GSPMD (§Perf: gemma2 256k
        # vocab made the baseline collective-bound).
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        z = jnp.sum(jnp.exp(logits - m), axis=-1)
        logz = jnp.log(z) + m[..., 0]
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
            == targets[..., None]
        )
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    else:
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("mask", jnp.ones_like(nll))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + aux_weight * aux
    metrics = {"loss": loss, "aux_loss": aux, "tokens": jnp.sum(mask)}
    return total, metrics
