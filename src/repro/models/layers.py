"""Common neural layers: norms, MLPs, embeddings, rotary positions.

Functional style throughout: ``init_*`` builds parameter pytrees (fp32),
``apply``-style functions are pure and dtype-polymorphic (activations run in
``config.dtype``, typically bf16; reductions in fp32 where it matters).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "init_norm", "apply_norm",
    "init_mlp", "apply_mlp",
    "init_embedding",
    "rope_frequencies", "apply_rope",
    "softcap",
]


# ------------------------------------------------------------------ norms

def init_norm(d: int, norm_type: str) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: dict, x: jax.Array, norm_type: str, eps: float) -> jax.Array:
    """RMSNorm / LayerNorm with fp32 statistics (bf16-safe)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dtype)


# ------------------------------------------------------------------ MLPs

def init_mlp(key: jax.Array, d: int, ff: int, mlp_type: str) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d ** -0.5
    scale_out = ff ** -0.5
    p = {
        "wi": jax.random.normal(k1, (d, ff), jnp.float32) * scale_in,
        "wo": jax.random.normal(k2, (ff, d), jnp.float32) * scale_out,
    }
    if mlp_type in ("swiglu", "geglu"):
        p["wg"] = jax.random.normal(k3, (d, ff), jnp.float32) * scale_in
    return p


def apply_mlp(p: dict, x: jax.Array, mlp_type: str) -> jax.Array:
    dtype = x.dtype
    h = x @ p["wi"].astype(dtype)
    if mlp_type in ("swiglu", "geglu"):
        g = x @ p["wg"].astype(dtype)
        act = jax.nn.silu if mlp_type == "swiglu" else jax.nn.gelu
        h = act(g) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"].astype(dtype)


# ------------------------------------------------------------------ embeddings

def init_embedding(key: jax.Array, vocab: int, d: int) -> jax.Array:
    return jax.random.normal(key, (vocab, d), jnp.float32) * (d ** -0.5)


# ------------------------------------------------------------------ RoPE

def rope_frequencies(head_dim: int, fraction: float, theta: float) -> jax.Array:
    """Inverse frequencies for the rotary subspace (fraction of head_dim)."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(
    x: jax.Array,            # (..., seq, head_dim)
    positions: jax.Array,    # (..., seq) int32
    inv_freq: jax.Array,     # (rot/2,)
) -> jax.Array:
    """Rotate the leading ``2*len(inv_freq)`` channels; pass the rest through."""
    rot = 2 * inv_freq.shape[0]
    if rot == 0:
        return x
    dtype = x.dtype
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, rot/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x_rot, x_pass = x[..., :rot].astype(jnp.float32), x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(dtype), x_pass], axis=-1) if rot < x.shape[-1] else y.astype(dtype)


# ------------------------------------------------------------------ misc

def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
