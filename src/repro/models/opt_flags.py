"""Performance-tuning flags (the framework's §Perf knob set).

The paper's thesis is that a phase-level cost model plus a tunable
configuration space turns performance into a search problem.  These are
the TPU-side knobs that the §Perf hillclimb (EXPERIMENTS.md) searches
over; they select between mathematically equivalent implementations, so
every flag combination must pass the same smoke tests.

Installed globally by launchers (same pattern as act_sharding policies) so
model code stays signature-stable.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["OptFlags", "set_flags", "get_flags", "clear_flags"]


@dataclass(frozen=True)
class OptFlags:
    # MoE dispatch implementation:
    #   "einsum" — capacity one-hot einsums (baseline; O(T^2) dispatch flops)
    #   "gather" — sort/scatter token indexing (O(T*K*d); same routing rule)
    moe_impl: str = "einsum"
    # Mesh factorization override (logical): (dp, tp) with dp*tp = chips per
    # pod.  None -> the launcher's default (16, 16).
    mesh_factor: tuple[int, int] | None = None
    # Cross-entropy: False -> rely on GSPMD propagation through
    # logsumexp/take_along_axis; True -> explicitly vocab-shard-friendly
    # formulation (local partial max/sum + tiny reductions).
    sharded_loss: bool = False
    # Keep the TP-boundary collectives in bf16 (cast back after the sum).
    bf16_collectives: bool = False
    # Flash-attention backward (custom_vjp, recomputes scores per chunk)
    # instead of autodiff-through-scan (which saves fp32 score matrices).
    flash_bwd: bool = False
    # Gradient-accumulation depth override (None -> pick_microbatches).
    # MoE working sets (capacity C, one-hot dispatch tensors) scale with
    # tokens-per-microbatch, so deeper accumulation shrinks them.
    n_micro_override: int | None = None
    # Decode KV-cache update strategy:
    #   "stream"  — caches are scan xs->ys (baseline; XLA copies the full
    #               stacked cache once per layer group per token)
    #   "inplace" — caches are scan CARRY state, updated per group with
    #               dynamic_update_index (aliasing-friendly while state)
    cache_update: str = "stream"


_FLAGS = OptFlags()


def set_flags(flags: OptFlags) -> None:
    global _FLAGS
    _FLAGS = flags


def get_flags() -> OptFlags:
    return _FLAGS


def clear_flags() -> None:
    global _FLAGS
    _FLAGS = OptFlags()
