"""Deterministic, shardable synthetic-token data pipeline.

Design rules (all load-bearing for fault tolerance at scale):

* **Stateless indexing** — batch ``step`` is a pure function of
  ``(seed, step)``: ``batch = f(seed, step)``.  Resume after failure needs
  no data-iterator checkpoint; a restored trainer at step k reproduces the
  exact token stream an uninterrupted run would have seen (tested
  bit-exactly in ``tests/test_fault_tolerance.py``).
* **Host sharding** — each host materializes only its slice of the global
  batch (``host_index / num_hosts``), the standard multi-pod input layout;
  ``global_batch`` must divide evenly.
* **Structured synthetic text** — tokens follow a mixed Markov/copy process
  (not iid noise) so language models actually have signal to learn: the
  e2e example's loss curve drops measurably within a few hundred steps.
* Labels are inputs shifted by one, with a loss mask that zeroes padding
  and the BOS position — the ``{"inputs","targets","mask"}`` contract of
  ``lm.loss_fn``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["PipelineConfig", "TokenPipeline"]


@dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0
    # synthetic process parameters
    n_states: int = 64            # Markov states
    copy_period: int = 97         # every k-th position copies an earlier token


class TokenPipeline:
    """Deterministic synthetic corpus with next-token structure."""

    def __init__(self, cfg: PipelineConfig):
        assert cfg.global_batch % cfg.num_hosts == 0, (
            "global batch must shard evenly over hosts"
        )
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        # fixed random Markov transition table (vocab partitioned by state)
        rng = np.random.default_rng(cfg.seed)
        self._trans = rng.integers(
            0, cfg.n_states, size=(cfg.n_states, 4), dtype=np.int64
        )
        self._state_vocab = rng.integers(
            2, cfg.vocab_size, size=(cfg.n_states, 8), dtype=np.int64
        )

    # ------------------------------------------------------------- batches
    def _sequences(self, step: int, rows: np.ndarray, length: int | None = None) -> np.ndarray:
        """Generate token rows for global row indices (vectorized Markov)."""
        cfg = self.cfg
        S = cfg.seq_len if length is None else length
        n = rows.shape[0]
        # Counter-based randomness keyed by (seed, step, GLOBAL row, t):
        # identical streams regardless of how rows are sharded over hosts.
        with np.errstate(over="ignore"):  # uint64 wraparound is the hash
            base = (
                rows.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
                + np.uint64((step * 0xBF58476D1CE4E5B9) % (1 << 64))
                + np.uint64((cfg.seed * 0x94D049BB133111EB) % (1 << 64))
            )
            t_idx = np.arange(S, dtype=np.uint64)
            mix = base[:, None] + t_idx[None, :] * np.uint64(0xD6E8FEB86659FD93)
            mix ^= mix >> np.uint64(33)
            mix *= np.uint64(0xFF51AFD7ED558CCD)
            mix ^= mix >> np.uint64(29)
        pick = (mix % np.uint64(4)).astype(np.int64)
        emit = ((mix >> np.uint64(8)) % np.uint64(8)).astype(np.int64)

        seeds = (rows.astype(np.int64) * 2_654_435_761 + step * 97) % (1 << 31)
        state = seeds % cfg.n_states
        toks = np.empty((n, S), np.int64)
        for t in range(S):
            toks[:, t] = self._state_vocab[state, emit[:, t]]
            state = self._trans[state, pick[:, t]]
        # copy structure: position t takes the token from t - period
        per = cfg.copy_period
        for t in range(per, S, per):
            toks[:, t] = toks[:, t - per]
        toks[:, 0] = 1  # BOS
        return toks

    def batch(self, step: int) -> dict:
        """This host's shard of global batch ``step``."""
        cfg = self.cfg
        lo = self.cfg.host_index * self.local_batch
        rows = np.arange(lo, lo + self.local_batch, dtype=np.int64)
        toks = self._sequences(step, rows, length=cfg.seq_len + 1)
        inputs = toks[:, :-1]
        targets = toks[:, 1:]
        mask = (targets != 0).astype(np.float32)
        return {
            "inputs": inputs.astype(np.int32),
            "targets": targets.astype(np.int32),
            "mask": mask,
        }

    def global_batch_checksum(self, step: int) -> int:
        """Host-layout-independent checksum (tested: 1 host == 4 hosts)."""
        cfg = self.cfg
        rows = np.arange(cfg.global_batch, dtype=np.int64)
        toks = self._sequences(step, rows)
        return int(np.bitwise_xor.reduce(toks.ravel() * (rows.sum() + 1)) )
