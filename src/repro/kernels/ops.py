"""Public jit'd entry points for the Pallas kernels.

Responsibilities kept OUT of the kernels themselves:
  * shape hygiene — pad head_dim to a lane multiple (128), seq lens to block
    multiples, un-pad outputs (zero-padded K columns are masked via k_len,
    zero-padded head dims contribute 0 to dots, so results are exact);
  * interpret-mode dispatch — on CPU (this container) kernels run with
    ``interpret=True``; on a real TPU backend they compile via Mosaic;
  * gradients — ``flash_attention`` carries a custom_vjp whose backward is
    the O(block)-memory jnp reference (recompute-based flash backward), so
    the Pallas forward is usable inside ``train_step``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_pallas
from .flash_attention import flash_attention_pallas
from .ref import flash_attention_ref
from .seg_combine import seg_combine_pallas

__all__ = ["flash_attention", "gqa_decode_attention", "seg_combine", "use_interpret"]

_LANE = 128


def use_interpret() -> bool:
    """Pallas interpret mode: required on CPU, off on real TPU."""
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ------------------------------------------------------------ flash attn

@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def flash_attention(
    q, k, v,
    causal: bool = True,
    window: int | None = None,
    logit_cap: float | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
):
    """Pallas flash attention with padding hygiene.  Shapes as attention.py:
    q (B,H,Sq,hd), k/v (B,KV,Sk,hd) -> (B,H,Sq,hd)."""
    return _flash_fwd_impl(
        q, k, v, causal, window, logit_cap, q_offset, block_q, block_k
    )


def _flash_fwd_impl(q, k, v, causal, window, logit_cap, q_offset, block_q, block_k):
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    sm_scale = hd ** -0.5

    qp = _pad_to(_pad_to(q, 3, _LANE), 2, block_q)
    kp = _pad_to(_pad_to(k, 3, _LANE), 2, block_k)
    vp = _pad_to(_pad_to(v, 3, _LANE), 2, block_k)

    out = flash_attention_pallas(
        qp, kp, vp,
        causal=causal, window=window, logit_cap=logit_cap,
        q_offset=q_offset, k_len=Sk, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k,
        interpret=use_interpret(),
    )
    return out[:, :, :Sq, :hd]


def _flash_fwd(q, k, v, causal, window, logit_cap, q_offset, block_q, block_k):
    out = _flash_fwd_impl(
        q, k, v, causal, window, logit_cap, q_offset, block_q, block_k
    )
    return out, (q, k, v)


def _flash_bwd(causal, window, logit_cap, q_offset, block_q, block_k, res, g):
    q, k, v = res
    # Recompute-based backward through the jnp reference (exact same math).
    f = functools.partial(
        flash_attention_ref,
        causal=causal, window=window, logit_cap=logit_cap, q_offset=q_offset,
    )
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ------------------------------------------------------------ decode attn

def gqa_decode_attention(
    q,                          # (B, H, 1, hd) — attention.py layout
    k_cache, v_cache,           # (B, KV, S, hd)
    slot_pos,                   # (S,) int32
    pos,                        # scalar int32
    *,
    window: int | None = None,
    logit_cap: float | None = None,
    block_k: int = 256,
):
    """Pallas decode attention; pads cache length + head_dim, un-pads out.
    Padded slots get slot_pos=-1 so the kernel masks them."""
    B, H, _, hd = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    sm_scale = hd ** -0.5
    block_k = min(block_k, max(_LANE, 1 << (S - 1).bit_length()))

    qg = q.reshape(B, KV, G, hd)
    qp = _pad_to(qg, 3, _LANE)
    kp = _pad_to(_pad_to(k_cache, 3, _LANE), 2, block_k)
    vp = _pad_to(_pad_to(v_cache, 3, _LANE), 2, block_k)
    sp = jnp.pad(slot_pos, (0, (-S) % block_k), constant_values=-1)

    out = decode_attention_pallas(
        qp, kp, vp, sp, pos,
        window=window, logit_cap=logit_cap, sm_scale=sm_scale,
        block_k=block_k, interpret=use_interpret(),
    )
    return out[..., :hd].reshape(B, H, 1, hd)


# ------------------------------------------------------------ seg combine

def seg_combine(
    values,                     # (N, D)
    part_ids,                   # (N,) int32; negative = dropped
    num_parts: int,
    *,
    block_n: int = 512,
    block_d: int = 256,
):
    """Per-partition sums (P, D) fp32 — MXU one-hot formulation."""
    N, D = values.shape
    block_n = min(block_n, max(8, 1 << (N - 1).bit_length()))
    block_d = min(block_d, max(_LANE, 1 << (D - 1).bit_length()))
    vp = _pad_to(_pad_to(values, 0, block_n), 1, block_d)
    pp = jnp.pad(part_ids, (0, (-N) % block_n), constant_values=-1)
    out = seg_combine_pallas(
        vp, pp, num_parts,
        block_n=block_n, block_d=block_d, interpret=use_interpret(),
    )
    return out[:, :D]
