"""Single-token GQA decode attention Pallas kernel (TPU target).

Serving hot-spot for ``decode_32k`` / ``long_500k``: one new query token
attends over a long KV cache.  The cache streams HBM→VMEM in
(block_k x head_dim) tiles; all G query heads of a KV group are processed
together so the score matmul is (G x hd)@(hd x bk) — MXU work instead of a
VPU dot per head.  Online softmax state (m, l, acc) lives in VMEM scratch
across the innermost cache-block grid dimension.

Ring caches (sliding-window layers) are handled via ``slot_pos``: an int32
array giving the token position stored in each cache slot (-1 = never
written).  Masking is ``slot_pos ∈ (pos - window, pos]`` — identical to the
XLA reference in ``models/attention.py``.

  grid = (batch, kv_heads, num_cache_blocks)            # cache innermost
  q tile    (1, 1, G, hd)      VMEM
  k,v tile  (1, 1, block_k, hd) VMEM
  slot_pos  (1, block_k)        VMEM  int32
  pos       (1, 1)              SMEM  int32 (scalar, dynamic)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

__all__ = ["decode_attention_kernel", "decode_attention_pallas"]

_NEG = -1e30
_LANES = 128


def decode_attention_kernel(
    pos_ref, q_ref, k_ref, v_ref, slot_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    window: int | None,
    logit_cap: float | None,
    num_k_blocks: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    slot_pos = slot_ref[0]                              # (bk,) int32
    pos = pos_ref[0, 0]                                 # scalar int32

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                           # (G, bk)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)

    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        ok &= slot_pos > pos - window
    s = jnp.where(ok[None, :], s, _NEG)

    m_prev = m_scr[:, 0]                                # (G,)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])                     # (G, bk)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[:, 0] * corr + p.sum(axis=-1)

    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                   # (G, hd)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[:, 0]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,               # (B, KV, G, hd) — hd % 128 == 0 (pre-padded)
    k_cache: jax.Array,         # (B, KV, S, hd)
    v_cache: jax.Array,         # (B, KV, S, hd)
    slot_pos: jax.Array,        # (S,) int32 — position held by each slot
    pos: jax.Array,             # scalar int32 — current decode position
    *,
    window: int | None = None,
    logit_cap: float | None = None,
    sm_scale: float | None = None,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, KV, G, hd = q.shape
    S = k_cache.shape[2]
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k

    kernel = functools.partial(
        decode_attention_kernel,
        scale=hd ** -0.5 if sm_scale is None else sm_scale,
        window=window,
        logit_cap=logit_cap,
        num_k_blocks=nk,
    )
    pos_arr = jnp.reshape(pos.astype(jnp.int32), (1, 1))
    slot2d = slot_pos.astype(jnp.int32).reshape(1, S)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, n, j: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, n, j: (b, n, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, n, j: (b, n, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, n, j: (b, n, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, n, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, n, j: (b, n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention",
    )(pos_arr, q, k_cache, v_cache, slot2d)
