"""Blocked flash attention Pallas kernel (TPU target).

The Hadoop-paper analogue: the map-side Spill/Merge pipeline streams data
through a bounded sort buffer instead of materializing everything; here the
(Sq x Sk) score matrix is never materialized in HBM — K/V stream HBM→VMEM in
(block_k x head_dim) tiles and an online softmax keeps O(block_q) state, the
TPU-native rethink of the same bounded-buffer streaming insight.

Layout / tiling
---------------
  grid = (batch, q_heads, num_q_blocks, num_k_blocks)   # k innermost
  q tile   (1, 1, block_q, head_dim)  VMEM
  k,v tile (1, 1, block_k, head_dim)  VMEM, kv head = q_head // group_size
  scratch  m,l: (block_q, 128) fp32 (lane-replicated), acc: (block_q, hd) fp32

The kv-block dimension is innermost and declared "arbitrary" so the scratch
accumulators persist across it; output is written on the final kv block.
Fully-masked (causal / sliding-window) kv blocks skip their matmuls via
``pl.when``.  MXU alignment: callers (ops.py) pad head_dim to a multiple of
128 and seq lens to block multiples; block_q/block_k default to 128.

Supports: causal and bidirectional attention, sliding-window (ring) masks,
Gemma-2 logit soft-capping, GQA (grouped KV heads), q position offsets
(continuation prefill), and a valid-KV-length mask for padded inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

_NEG = -1e30
_LANES = 128


def flash_attention_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    logit_cap: float | None,
    q_offset: int,
    k_len: int,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + iq * block_q
    k_start = ik * block_k

    # Block-level mask pruning (positions are global token indices).
    live = k_start < k_len
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window is not None:
        # k_pos > q_pos - window for some pair in the block
        live &= k_start + block_k - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                       # (bq, bk)
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = k_pos < k_len
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, _NEG)

        m_prev = m_scr[:, 0]                            # (bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])                 # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                  # (bq,)
        l_new = l_scr[:, 0] * corr + p.sum(axis=-1)

        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                               # (bq, hd)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[:, 0]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,               # (B, H, Sq, hd) — hd % 128 == 0 (pre-padded)
    k: jax.Array,               # (B, KV, Sk, hd)
    v: jax.Array,               # (B, KV, Sk, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    logit_cap: float | None = None,
    q_offset: int = 0,
    k_len: int | None = None,
    sm_scale: float | None = None,   # softmax scale; ops.py passes true_hd**-0.5
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call wrapper; shape padding/validation lives in ops.py."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    if k_len is None:
        k_len = Sk

    kernel = functools.partial(
        flash_attention_kernel,
        scale=hd ** -0.5 if sm_scale is None else sm_scale,
        causal=causal,
        window=window,
        logit_cap=logit_cap,
        q_offset=q_offset,
        k_len=k_len,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
    )
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
