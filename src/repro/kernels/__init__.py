"""Pallas TPU kernels for the substrate's compute hot-spots.

Three kernels, each with the pallas_call (``<name>.py``), the jit'd public
wrapper (``ops.py``) and a pure-jnp oracle (``ref.py``):

  flash_attention  — blocked prefill/training attention (online softmax)
  decode_attention — single-token GQA attention over (ring) KV caches
  seg_combine      — MXU segmented combine (Hadoop collect/partition/combine
                     analogue feeding the all_to_all shuffle)

On CPU (this container) they run in interpret mode; on TPU via Mosaic.
"""

from .ops import flash_attention, gqa_decode_attention, seg_combine, use_interpret

__all__ = ["flash_attention", "gqa_decode_attention", "seg_combine", "use_interpret"]
