"""Pure-jnp oracles for every Pallas kernel.

Each function materializes the full intermediate (score matrix / one-hot
matrix) in fp32 — O(Sq*Sk) memory, fine at test scale — and is the ground
truth the kernels are swept against in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "decode_attention_ref", "seg_combine_ref"]

_NEG = -1e30


def flash_attention_ref(
    q: jax.Array,               # (B, H, Sq, hd)
    k: jax.Array,               # (B, KV, Sk, hd)
    v: jax.Array,               # (B, KV, Sk, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    logit_cap: float | None = None,
    q_offset: int = 0,
    k_len: int | None = None,
    sm_scale: float | None = None,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    G = H // KV
    scale = hd ** -0.5 if sm_scale is None else sm_scale
    qg = q.reshape(B, KV, G, Sq, hd)

    s = jnp.einsum("bngqh,bnch->bngqc", qg, k).astype(jnp.float32) * scale
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)

    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    if k_len is not None:
        ok &= k_pos[None, :] < k_len
    s = jnp.where(ok, s, _NEG)

    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngqc,bnch->bngqh", w.astype(q.dtype), v)
    return out.reshape(B, H, Sq, hd).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,               # (B, KV, G, hd)
    k_cache: jax.Array,         # (B, KV, S, hd)
    v_cache: jax.Array,         # (B, KV, S, hd)
    slot_pos: jax.Array,        # (S,) int32
    pos: jax.Array,             # scalar int32
    *,
    window: int | None = None,
    logit_cap: float | None = None,
    sm_scale: float | None = None,
) -> jax.Array:
    hd = q.shape[-1]
    scale = hd ** -0.5 if sm_scale is None else sm_scale
    s = jnp.einsum("bngh,bnch->bngc", q, k_cache).astype(jnp.float32) * scale
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        ok &= slot_pos > pos - window
    s = jnp.where(ok[None, None, None, :], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngc,bnch->bngh", w.astype(q.dtype), v_cache)
    return out.astype(q.dtype)


def seg_combine_ref(
    values: jax.Array,          # (N, D)
    part_ids: jax.Array,        # (N,) int32; negative = dropped
    num_parts: int,
) -> jax.Array:
    """(P, D) fp32 per-partition sums via scatter-add."""
    vals = values.astype(jnp.float32)
    vals = jnp.where((part_ids >= 0)[:, None], vals, 0.0)
    idx = jnp.clip(part_ids, 0, num_parts - 1)
    return jnp.zeros((num_parts, values.shape[1]), jnp.float32).at[idx].add(vals)
