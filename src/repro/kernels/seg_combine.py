"""Segmented combine Pallas kernel — the TPU analogue of Hadoop's
Collect/Partition/Combine pipeline.

In the paper's map task, output pairs are partitioned by reducer, sorted,
and (optionally) combined before being spilled; the combiner shrinks data by
``sCombineSizeSel`` *before* it crosses the network.  On a TPU mesh the
shuffle is an ``all_to_all``; the pre-shuffle combine is a segmented
reduction keyed by destination partition.  A scatter-add does this on the
VPU, serially per element; instead we rethink it for the MXU: a one-hot
(P x block_n) partition matrix times the (block_n x D) value block is a
dense matmul that performs block_n fused adds per pass — this kernel is
that formulation.

  grid = (num_d_blocks, num_n_blocks)                  # n innermost
  values tile (block_n, block_d)  VMEM
  part ids    (1, block_n)        VMEM int32
  out tile    (P, block_d)        VMEM — same block for every n step,
                                   accumulated across the inner dimension.

Counts (pairs-per-partition, the paper's ``spillFilePairs`` measurement)
come from the same matmul with an all-ones value column, exposed by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import pallas_tpu_compiler_params

__all__ = ["seg_combine_kernel", "seg_combine_pallas"]


def seg_combine_kernel(v_ref, p_ref, o_ref, *, num_parts: int, block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = v_ref[...].astype(jnp.float32)               # (bn, bd)
    pid = p_ref[0]                                      # (bn,) int32

    rows = jax.lax.broadcasted_iota(jnp.int32, (num_parts, block_n), 0)
    onehot = (rows == pid[None, :]).astype(jnp.float32)  # (P, bn)
    o_ref[...] += jax.lax.dot_general(
        onehot, vals, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def seg_combine_pallas(
    values: jax.Array,          # (N, D) — pair payloads
    part_ids: jax.Array,        # (N,) int32 in [0, P); negative = dropped
    num_parts: int,
    *,
    block_n: int = 512,
    block_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Per-partition combined sums, shape (P, D) fp32."""
    N, D = values.shape
    assert N % block_n == 0 and D % block_d == 0, (N, D, block_n, block_d)

    kernel = functools.partial(
        seg_combine_kernel, num_parts=num_parts, block_n=block_n
    )
    pid2d = part_ids.astype(jnp.int32).reshape(1, N)
    return pl.pallas_call(
        kernel,
        grid=(D // block_d, N // block_n),
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (j, i)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((num_parts, block_d), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((num_parts, D), jnp.float32),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="seg_combine",
    )(values, pid2d)
