"""The analyzer's trace targets: every registered cost model + the
differentiated closures.

A :class:`TraceTarget` packages a callable-to-trace, the canonical example
arguments, and the per-input :class:`~repro.analysis.interval.Interval`
abstraction (from :mod:`repro.spec.axes` bounds where declared).  Checkers
consume the traced closed jaxpr — nothing here executes model math beyond
``jax.make_jaxpr`` tracing.

Targets:

* ``hadoop-model``   — the full branch-free job model (Eqs. 1-98) over the
  physical domain of :func:`repro.spec.hadoop_space`.
* ``hadoop-grad``    — the same jaxpr DCE'd to the ``j_totalCost`` output:
  exactly what :meth:`ChunkedEvaluator.grad_objective` differentiates.
* ``calib-loss``     — :func:`repro.calib.build_loss_fn` over canonical
  observations (the loss `jax.grad` descends in ``calibrate``).
* ``tuner-objective``— :func:`repro.search.strategies.build_relaxed_objective`
  for the Hadoop evaluator over a representative knob space.
* ``cluster-rollout``— the wave simulator ``_sim_one`` with every policy
  branch compiled in.
* ``cloud-rollout``  — the same rollout with the elastic-fleet path
  (``with_cloud``) compiled in: spot reclamation in expectation,
  autoscale on/off events, extra-capacity episode billing.
* ``cloud-pricing``  — the differentiable dollar path
  (``spot_inflation`` x ``dollars_for``) sensitivity studies descend;
  traced with a concrete zero billing quantum so it stays ceil-free.
* ``network-model``  — :func:`repro.cluster.network.effective_bandwidth`,
  the incast-contention factor the job model's topology hook divides
  Eq. 91's netCost by; differentiable in every topology knob.
* ``tpu-model``      — **not jaxpr-traceable** (a pure-numpy table model);
  registered with ``traceable=False`` so reports say *why* rather than
  silently skipping a registered model.  Its mask-contract obligations are
  checked at the AST level like every other evaluator.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

from .interval import BOOL, FINITE_TOP, Interval

__all__ = ["TraceTarget", "iter_targets", "trace_target", "dce_to_outputs"]


@dataclass
class TraceTarget:
    name: str
    doc: str
    traceable: bool = True
    grad_mode: bool = False
    #: () -> (closed_jaxpr, [Interval]) — built lazily, tracing is not free
    build: Callable | None = None
    skip_reason: str = ""
    #: output names aligned with the jaxpr outputs (dict-output targets)
    out_names: tuple[str, ...] = field(default_factory=tuple)


def _axis_interval(ax) -> Interval:
    if ax.kind == "bool":
        return BOOL
    return Interval.bounded(ax.lower, ax.upper, getattr(ax, "lower_open", False))


def dce_to_outputs(closed, keep: list[int]):
    """Dead-code-eliminate a closed jaxpr down to the kept output indices —
    the analyzer's way of restricting to the differentiated path (e.g. the
    cost output of ``grad_objective``, not the validity flags)."""
    from jax import core as jcore
    from jax.interpreters import partial_eval as pe

    jaxpr = closed.jaxpr
    used = [i in keep for i in range(len(jaxpr.outvars))]
    new_jaxpr, used_inputs = pe.dce_jaxpr(jaxpr, used)
    consts = [c for c, u in zip(closed.consts, used_inputs[:len(closed.consts)])
              ] if len(new_jaxpr.constvars) != len(jaxpr.constvars) else \
        list(closed.consts)
    # pe.dce_jaxpr drops unused invars; constvars stay (closed jaxpr consts
    # are invars only after conversion) — rebuild a ClosedJaxpr
    return jcore.ClosedJaxpr(new_jaxpr, consts), used_inputs


# ---------------------------------------------------------------------------
# individual builders
# ---------------------------------------------------------------------------


def _hadoop_cfg_and_intervals():
    from repro.core.hadoop.model import pack_config
    from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats
    from repro.spec import hadoop_space

    cfg = pack_config(HadoopParams(), ProfileStats(), CostFactors())
    space = hadoop_space()
    intervals = []
    for k in sorted(cfg):               # jax dict-pytree flatten order
        if k in space:
            intervals.append(_axis_interval(space[k]))
        else:
            intervals.append(Interval(0.0, math.inf, False, True))
    return cfg, intervals


def _build_hadoop_model():
    import jax

    from repro.core.hadoop.model import job_model_jnp

    cfg, intervals = _hadoop_cfg_and_intervals()
    names: list[str] = []

    def fn(c):
        out = job_model_jnp(c)
        names.extend(sorted(out))
        return {k: out[k] for k in sorted(out)}

    closed = jax.make_jaxpr(fn)(cfg)
    return closed, intervals, tuple(names)


def _build_hadoop_grad():
    import jax

    from repro.core.hadoop.model import job_model_jnp

    cfg, intervals = _hadoop_cfg_and_intervals()

    # exactly grad_objective's differentiated output: the raw total cost
    def fn(c):
        return job_model_jnp(c)["j_totalCost"]

    closed = jax.make_jaxpr(fn)(cfg)
    return closed, intervals, ("j_totalCost",)


def _canonical_observations():
    from repro.calib import Observation
    from repro.spec import JobSpec

    specs = [JobSpec(), JobSpec()]
    return [Observation(spec=s, cost=100.0 + 10.0 * i)
            for i, s in enumerate(specs)]


def _build_calib_loss():
    import jax
    import jax.numpy as jnp

    from repro.calib.fit import COST_FACTOR_NAMES, _stack_configs, build_loss_fn

    obs = _canonical_observations()
    cols = _stack_configs(obs)
    y = jnp.asarray([o.cost for o in obs], dtype=jnp.result_type(float))
    w = jnp.asarray([o.weight for o in obs], dtype=jnp.result_type(float))
    names = list(COST_FACTOR_NAMES)
    loss = build_loss_fn(cols, names, y, w)
    u0 = {n: jnp.asarray(0.0, dtype=jnp.result_type(float)) for n in names}
    closed = jax.make_jaxpr(loss)(u0)
    intervals = [FINITE_TOP for _ in names]   # unconstrained optimizer space
    return closed, intervals, ("loss",)


def _build_tuner_objective():
    import jax
    import jax.numpy as jnp

    from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats
    from repro.search.evaluator import ChunkedEvaluator
    from repro.search.strategies import build_relaxed_objective

    ev = ChunkedEvaluator(HadoopParams(), ProfileStats(), CostFactors(),
                          chunk=16)
    space = {
        "pSortMB": [50.0, 100.0, 200.0],
        "pSortFactor": [5.0, 10.0, 50.0],
        "pSpillPerc": [0.5, 0.8, 0.95],
    }
    raw_cost, _axes, keys = build_relaxed_objective(ev, space)
    u0 = {k: jnp.asarray(0.0, dtype=jnp.result_type(float)) for k in keys}
    closed = jax.make_jaxpr(raw_cost)(u0)
    return closed, [FINITE_TOP for _ in keys], ("cost",)


def _build_cloud_pricing():
    import jax
    import jax.numpy as jnp

    from repro.cloud.pricing import dollars_for, spot_inflation

    C = 2
    fdt = jnp.result_type(float)
    a = {
        "counts": jnp.ones((C,), dtype=fdt),
        "prices": jnp.full((C,), 0.4, dtype=fdt),
        "rate": jnp.full((C,), 1e-4, dtype=fdt),
        "span": jnp.asarray(3600.0, dtype=fdt),
        "task_s": jnp.asarray(30.0, dtype=fdt),
    }
    ivals = {
        "counts": Interval(0.0, math.inf, False, True),
        "prices": Interval(0.0, math.inf, False, True),
        "rate": Interval(0.0, math.inf, False, True),
        "span": Interval(0.0, math.inf, False, True),
        "task_s": Interval(0.0, math.inf, True, True),
    }

    # the expected dollar cost of a spot fleet: the wall-clock span
    # inflates by the reclamation model, the fleet rate prices it.  A
    # concrete billing_quantum=0 keeps the path ceil-free — exactly the
    # differentiable surface spot_planning sensitivity studies use.
    def fn(arg):
        infl = spot_inflation(arg["rate"], arg["task_s"])
        # per-class: counts[c] * prices[c] * span * infl[c] / 3600
        per_class = dollars_for(
            arg["span"] * infl, arg["counts"] * jnp.eye(C), arg["prices"])
        return per_class.sum()

    closed = jax.make_jaxpr(fn)(a)
    intervals = [ivals[k] for k in sorted(a)]
    return closed, intervals, ("dollars",)


def _build_cluster_rollout():
    import jax
    import jax.numpy as jnp

    from repro.cluster.vector_sim import _sim_one

    J, C, Q = 3, 2, 2
    s = {
        "arrival": jnp.zeros((J,)),
        "n_maps": jnp.ones((J,)),
        "n_reds": jnp.ones((J,)),
        "map_cost": jnp.ones((J,)),
        "red_work": jnp.ones((J,)),
        "shuffle": jnp.ones((J,)),
        "queue": jnp.zeros((J,)),
        "map_slots": jnp.ones((C,)),
        "red_slots": jnp.ones((C,)),
        "speedup": jnp.ones((C,)),
        "policy": jnp.asarray(0.0, dtype=jnp.result_type(float)),
        "slowstart": jnp.asarray(0.05, dtype=jnp.result_type(float)),
        "queue_frac": jnp.full((Q,), 0.5, dtype=jnp.result_type(float)),
    }
    ivals = {
        "arrival": Interval(0.0, math.inf, False, True),
        "n_maps": Interval(0.0, math.inf, False, True),
        "n_reds": Interval(0.0, math.inf, False, True),
        "map_cost": Interval(0.0, math.inf, False, True),
        "red_work": Interval(0.0, math.inf, False, True),
        "shuffle": Interval(0.0, math.inf, False, True),
        "queue": Interval(0.0, float(Q - 1)),
        "map_slots": Interval(0.0, math.inf, False, True),
        "red_slots": Interval(0.0, math.inf, False, True),
        "speedup": Interval(1.0, math.inf, False, True),
        "policy": Interval(0.0, 3.0),
        "slowstart": Interval(0.0, 1.0),
        "queue_frac": Interval(0.0, 1.0),
    }
    names: list[str] = []

    def fn(scen):
        out = _sim_one(scen, 8, True, True, True)
        names.extend(sorted(out))
        return {k: out[k] for k in sorted(out)}

    closed = jax.make_jaxpr(fn)(s)
    intervals = [ivals[k] for k in sorted(s)]
    return closed, intervals, tuple(names)


def _build_cloud_rollout():
    import jax
    import jax.numpy as jnp

    from repro.cluster.vector_sim import _sim_one

    J, C, Q = 3, 2, 2
    fdt = jnp.result_type(float)
    s = {
        "arrival": jnp.zeros((J,)),
        "n_maps": jnp.ones((J,)),
        "n_reds": jnp.ones((J,)),
        "map_cost": jnp.ones((J,)),
        "red_work": jnp.ones((J,)),
        "shuffle": jnp.ones((J,)),
        "queue": jnp.zeros((J,)),
        "map_slots": jnp.ones((C,)),
        "red_slots": jnp.ones((C,)),
        "speedup": jnp.ones((C,)),
        "policy": jnp.asarray(0.0, dtype=fdt),
        "slowstart": jnp.asarray(0.05, dtype=fdt),
        "queue_frac": jnp.full((Q,), 0.5, dtype=fdt),
        "reclaim_rate": jnp.full((C,), 1e-4, dtype=fdt),
        "autoscale": jnp.asarray(1.0, dtype=fdt),
        "high_water": jnp.asarray(2.0, dtype=fdt),
        "provision_latency": jnp.asarray(5.0, dtype=fdt),
        "extra_map_slots": jnp.asarray(2.0, dtype=fdt),
        "extra_red_slots": jnp.asarray(2.0, dtype=fdt),
        "billing_quantum": jnp.asarray(60.0, dtype=fdt),
    }
    nonneg = Interval(0.0, math.inf, False, True)
    ivals = {
        "arrival": nonneg,
        "n_maps": nonneg,
        "n_reds": nonneg,
        "map_cost": nonneg,
        "red_work": nonneg,
        "shuffle": nonneg,
        "queue": Interval(0.0, float(Q - 1)),
        "map_slots": nonneg,
        "red_slots": nonneg,
        "speedup": Interval(1.0, math.inf, False, True),
        "policy": Interval(0.0, 3.0),
        "slowstart": Interval(0.0, 1.0),
        "queue_frac": Interval(0.0, 1.0),
        "reclaim_rate": nonneg,
        "autoscale": Interval(0.0, 2.0),
        "high_water": nonneg,
        "provision_latency": nonneg,
        "extra_map_slots": nonneg,
        "extra_red_slots": nonneg,
        "billing_quantum": nonneg,
    }
    names: list[str] = []

    def fn(scen):
        out = _sim_one(scen, 8, True, True, True, True)
        names.extend(sorted(out))
        return {k: out[k] for k in sorted(out)}

    closed = jax.make_jaxpr(fn)(s)
    intervals = [ivals[k] for k in sorted(s)]
    return closed, intervals, tuple(names)


def _build_network_model():
    import jax
    import jax.numpy as jnp

    from repro.cluster.network import effective_bandwidth

    fdt = jnp.result_type(float)
    a = {
        "pNumRacks": jnp.asarray(4.0, dtype=fdt),
        "crossRackBw": jnp.asarray(2.0, dtype=fdt),
        "oversubscription": jnp.asarray(2.0, dtype=fdt),
        "nFlows": jnp.asarray(8.0, dtype=fdt),
    }
    ivals = {
        "pNumRacks": Interval(1.0, math.inf, False, True),
        "crossRackBw": Interval(0.0, math.inf, True, True),
        "oversubscription": Interval(1.0, math.inf, False, True),
        "nFlows": Interval(0.0, math.inf, False, True),
    }

    # the effective shuffle bandwidth dividing Eq. 91's netCost in the
    # closed-form topology hook — the surface pNumRacks / crossRackBw /
    # oversubscription gradients flow through
    def fn(arg):
        return effective_bandwidth(
            arg["pNumRacks"], arg["crossRackBw"],
            arg["oversubscription"], arg["nFlows"])

    closed = jax.make_jaxpr(fn)(a)
    intervals = [ivals[k] for k in sorted(a)]
    return closed, intervals, ("bandwidth",)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def iter_targets() -> list[TraceTarget]:
    """All analyzer targets, untraced (call :func:`trace_target` per item)."""
    return [
        TraceTarget(
            name="hadoop-model",
            doc="full job model (Eqs. 1-98) over the physical axis domain",
            build=_build_hadoop_model,
        ),
        TraceTarget(
            name="hadoop-grad",
            doc="the j_totalCost path grad_objective differentiates",
            build=_build_hadoop_grad,
            grad_mode=True,
        ),
        TraceTarget(
            name="calib-loss",
            doc="repro.calib.build_loss_fn over canonical observations",
            build=_build_calib_loss,
            grad_mode=True,
        ),
        TraceTarget(
            name="tuner-objective",
            doc="build_relaxed_objective raw cost (gradient_descent_ev)",
            build=_build_tuner_objective,
            grad_mode=True,
        ),
        TraceTarget(
            name="cluster-rollout",
            doc="vector_sim._sim_one wave rollout, all policies compiled in",
            build=_build_cluster_rollout,
        ),
        TraceTarget(
            name="cloud-rollout",
            doc="the wave rollout with the elastic-fleet path compiled in "
                "(spot reclamation, autoscaling, episode billing)",
            build=_build_cloud_rollout,
        ),
        TraceTarget(
            name="cloud-pricing",
            doc="the differentiable spot-pricing path (spot_inflation x "
                "dollars_for), quantum-free so grad stays clean",
            build=_build_cloud_pricing,
            grad_mode=True,
        ),
        TraceTarget(
            name="network-model",
            doc="the topology-aware effective shuffle bandwidth dividing "
                "Eq. 91's netCost (incast contention, differentiable)",
            build=_build_network_model,
            grad_mode=True,
        ),
        TraceTarget(
            name="tpu-model",
            doc="TPU step table model (registered CostModel 'tpu')",
            traceable=False,
            skip_reason=(
                "pure-numpy table model over integer mesh layouts — no jaxpr "
                "exists; covered by the AST-level mask-contract checker and "
                "its own shardability predicates"),
        ),
    ]


def trace_target(t: TraceTarget):
    """Build (closed_jaxpr, intervals, out_names) for a traceable target."""
    if not t.traceable:
        raise ValueError(f"target {t.name} is not traceable: {t.skip_reason}")
    closed, intervals, names = t.build()
    t.out_names = names
    return closed, intervals, names
